"""Render the §Roofline markdown table from results/dryrun*.jsonl
(later files override earlier ones per (arch, shape, mesh) cell)."""
import glob
import json
import sys

import os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
import repro.configs as C  # noqa: E402

recs = {}
src = {}
for path in sorted(glob.glob("results/dryrun*.jsonl")):
    for line in open(path):
        r = json.loads(line)
        key = (C.canon(r["arch"]), r["shape"], r["mesh"])
        recs[key] = r
        src[key] = os.path.basename(path)

valid = {(C.canon(a), s) for a, s in C.cells()}

print("| arch | shape | mesh | compute ms | memory ms | coll ms | "
      "dominant | bound s | useful | MFU@bound | fits HBM | GB/dev |")
print("|---|---|---|---:|---:|---:|---|---:|---:|---:|---|---:|")
nfit = 0
shown = 0
for (a, s, m), r in sorted(recs.items()):
    if (C.canon(a), s) not in valid:
        continue
    shown += 1
    nfit += bool(r["fits_hbm"])
    print(f"| {a} | {s} | {m} | {1e3*r['t_compute']:.1f} | "
          f"{1e3*r['t_memory']:.1f} | {1e3*r['t_collective']:.1f} | "
          f"{r['dominant']} | {r['bound_s']:.2f} | "
          f"{r['useful_frac']:.2f} | {100*r['mfu_at_bound']:.1f}% | "
          f"{'Y' if r['fits_hbm'] else 'N'} | "
          f"{r['total_bytes_per_dev']/1e9:.1f} |")
print(f"\n{shown} cells shown, {nfit} fit 16 GB HBM", file=sys.stderr)
