"""Observability subsystem (``repro.obs``): tracer/metrics units, the
exporter round-trips, estimator integration (bit-exactness at every obs
level, zero extra compiles at ``obs="trace"``, lazy import at
``obs="off"``), measured-vs-static comm reconciliation on 1 and 4
devices, and the serve drain's queue-wait/solve-wall latency split."""
import json
import sys

import numpy as np
import pytest

from conftest import run_with_devices

from repro.obs.metrics import (Counter, Histogram, MetricsRegistry,
                               record_solve_cost)
from repro.obs.trace import Tracer, load_chrome, load_jsonl


@pytest.fixture(autouse=True)
def _reset_obs_globals():
    """The tracer and registry are process-global singletons; leave them
    off and empty so no test observes another's spans or counters."""
    yield
    tr = sys.modules.get("repro.obs.trace")
    if tr is not None and tr._TRACER is not None:
        tr._TRACER.set_mode("off")
        tr._TRACER.clear()
    mt = sys.modules.get("repro.obs.metrics")
    if mt is not None and mt._REGISTRY is not None:
        mt._REGISTRY.clear()


# ---------------------------------------------------------------------------
# tracer
# ---------------------------------------------------------------------------

def test_tracer_off_is_inert():
    t = Tracer()
    with t.span("solve", p=8) as s:
        s.note(iters=3)
    t.event("tick")
    assert len(t) == 0 and t.snapshot() == ()


def test_tracer_records_spans_events_and_notes():
    t = Tracer(mode="summary")
    with t.span("solve", cat="solver", p=8) as s:
        s.note(iters=3)
        t.event("checkpoint", step=1)
    spans = t.snapshot()
    assert [s.name for s in spans] == ["checkpoint", "solve"]
    ev, sp = spans
    assert ev.phase == "instant" and ev.duration == 0.0
    assert sp.phase == "span" and sp.duration >= 0.0
    assert sp.args == {"p": 8, "iters": 3} and ev.args == {"step": 1}


def test_tracer_summary_filters_trace_level_spans():
    t = Tracer(mode="summary")
    with t.span("outer"):
        with t.span("inner", level="trace"):
            pass
    assert [s.name for s in t.snapshot()] == ["outer"]
    t.clear()
    t.set_mode("trace")
    with t.span("outer"):
        with t.span("inner", level="trace"):
            pass
    assert sorted(s.name for s in t.snapshot()) == ["inner", "outer"]


def test_tracer_ring_capacity_bounds_memory():
    t = Tracer(mode="trace", capacity=4)
    for i in range(10):
        t.event("e", i=i)
    spans = t.snapshot()
    assert len(spans) == 4
    assert [s.args["i"] for s in spans] == [6, 7, 8, 9]


def test_tracer_scoped_restores_mode():
    t = Tracer(mode="off")
    with t.scoped("trace"):
        assert t.mode == "trace"
        t.event("inside")
    assert t.mode == "off" and len(t) == 1


def test_jsonl_roundtrip(tmp_path):
    t = Tracer(mode="trace")
    with t.span("solve", cat="solver", p=16) as s:
        s.note(converged=True)
    t.event("mark", cat="batch", level="trace", wave=2)
    path = tmp_path / "trace.jsonl"
    assert t.export_jsonl(path) == 2
    back = load_jsonl(path)
    for orig, rt in zip(t.snapshot(), back):
        assert orig.to_json() == rt.to_json()


def test_chrome_roundtrip(tmp_path):
    t = Tracer(mode="trace")
    with t.span("solve", cat="solver", p=16):
        t.event("mark", level="trace", wave=2)
    path = tmp_path / "trace.json"
    assert t.export_chrome(path) == 2
    doc = json.loads(path.read_text())
    assert "traceEvents" in doc
    back = load_chrome(path)
    assert len(back) == 2
    for orig, rt in zip(sorted(t.snapshot(), key=lambda s: s.t_start),
                        sorted(back, key=lambda s: s.t_start)):
        assert (orig.name, orig.cat, orig.phase,
                orig.level) == (rt.name, rt.cat, rt.phase, rt.level)
        # chrome timestamps are integer-microsecond; 1 us round-trip slop
        assert abs(orig.t_start - rt.t_start) < 2e-6
        assert abs(orig.duration - rt.duration) < 2e-6
        assert {k: v for k, v in orig.args.items()} == rt.args


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------

def test_counter_monotone_and_gauge():
    reg = MetricsRegistry()
    c = reg.counter("reqs", variant="cov")
    c.inc()
    c.inc(2.5)
    assert c.value == 3.5
    with pytest.raises(ValueError):
        c.inc(-1)
    g = reg.gauge("depth")
    g.set(7)
    assert g.value == 7.0
    # get-or-create: same labels return the same object
    assert reg.counter("reqs", variant="cov") is c
    assert len(reg) == 2


def test_registry_type_clash_raises():
    reg = MetricsRegistry()
    reg.counter("x")
    with pytest.raises(TypeError):
        reg.gauge("x")


def test_histogram_quantiles_match_numpy_within_bucket_width():
    rng = np.random.default_rng(0)
    samples = rng.lognormal(mean=-6.0, sigma=1.5, size=5000)
    h = Histogram("lat")
    for v in samples:
        h.observe(v)
    assert h.total == len(samples)
    for q in (0.5, 0.95, 0.99):
        ref = float(np.quantile(samples, q))
        got = h.quantile(q)
        # interpolated inside an exponential bucket: within one bucket's
        # relative width of the exact sample quantile
        assert ref / h.growth <= got <= ref * h.growth, (q, got, ref)
    # extremes follow the same contract (a lone sample in the edge
    # bucket reads as the bucket midpoint, not the exact min/max)
    assert h.min <= h.quantile(0.0) <= h.min * h.growth
    assert h.max / h.growth <= h.quantile(1.0) <= h.max


def test_histogram_single_sample_and_empty():
    h = Histogram("lat")
    assert np.isnan(h.quantile(0.5))
    h.observe(0.125)
    for q in (0.0, 0.5, 1.0):
        assert h.quantile(q) == pytest.approx(0.125)


def test_prometheus_text_exposition():
    reg = MetricsRegistry()
    reg.counter("repro_solves_total", variant="cov").inc(3)
    reg.gauge("repro_queue_depth").set(2)
    hist = reg.histogram("repro_solve_wall_seconds", variant="cov")
    for v in (0.01, 0.02, 0.04):
        hist.observe(v)
    text = reg.to_prometheus()
    assert "# TYPE repro_solves_total counter" in text
    assert 'repro_solves_total{variant="cov"} 3' in text
    assert "# TYPE repro_queue_depth gauge" in text
    assert "# TYPE repro_solve_wall_seconds summary" in text
    assert 'quantile="0.5"' in text
    assert 'repro_solve_wall_seconds_count{variant="cov"} 3' in text
    snap = reg.snapshot()
    assert snap['repro_solves_total{variant="cov"}'] == 3
    assert snap['repro_solve_wall_seconds{variant="cov"}']["count"] == 3


def test_record_solve_cost_feeds_costmodel_counters():
    reg = MetricsRegistry()
    out = record_solve_cost(reg, variant="cov", p=64, n=128, iters=10,
                            ls_total=14, density=0.2, wall_s=0.05)
    assert out["flops"] > 0 and out["words"] >= 0
    assert reg.counter("repro_solves_total", variant="cov").value == 1
    assert reg.counter("repro_solve_iters_total", variant="cov").value == 10
    # n=None (precomputed Gram): no Gram-formation flops, still positive
    out2 = record_solve_cost(reg, variant="cov", p=64, n=None, iters=10,
                             ls_total=14, density=0.2)
    assert 0 < out2["flops"] < out["flops"]
    # obs variant uses the other closed form
    out3 = record_solve_cost(reg, variant="obs", p=64, n=128, iters=10,
                             ls_total=14, density=0.2)
    assert out3["flops"] > 0


# ---------------------------------------------------------------------------
# estimator integration
# ---------------------------------------------------------------------------

def _fit(obs, **cfg_overrides):
    from repro.core import graphs
    from repro.estimator import ConcordEstimator, SolverConfig

    prob = graphs.make_problem("chain", 24, 64, seed=0)
    cfg = dict(backend="reference", variant="cov", tol=1e-5, max_iters=60,
               obs=obs)
    cfg.update(cfg_overrides)
    est = ConcordEstimator(lam1=0.2, lam2=0.05, config=SolverConfig(**cfg))
    est.fit_cov(prob.s, n_samples=64)
    return est.report_


def test_obs_levels_are_bit_exact_and_carry_telemetry():
    base = _fit("off")
    assert base.telemetry is None
    for obs in ("summary", "trace"):
        rep = _fit(obs)
        np.testing.assert_array_equal(
            np.asarray(rep.omega), np.asarray(base.omega),
            err_msg=f"obs={obs!r} changed the estimate")
        assert rep.iters == base.iters and rep.ls_total == base.ls_total
        tele = rep.telemetry
        assert tele["obs"] == obs
        assert tele["flops"] > 0 and tele["words"] >= 0
        assert tele["dispatch_s"] >= 0 and tele["execute_s"] >= 0
        assert "_pending_cost" not in tele


def test_obs_config_validation():
    from repro.estimator import SolverConfig
    with pytest.raises(ValueError, match="obs"):
        SolverConfig(obs="verbose")


def test_obs_off_never_imports_the_obs_package():
    run_with_devices("""
import sys
import numpy as np
from repro.core import graphs
from repro.estimator import ConcordEstimator, SolverConfig
prob = graphs.make_problem("chain", 16, 40, seed=0)
cfg = SolverConfig(backend="reference", variant="cov", tol=1e-4,
                   max_iters=40, obs="off")
ConcordEstimator(lam1=0.2, config=cfg).fit_cov(prob.s)
loaded = [m for m in sys.modules if m.startswith("repro.obs")]
assert not loaded, f"obs='off' pulled in {loaded}"
print("OK")
""", n_devices=1, timeout=300)


def test_obs_trace_adds_zero_compiles(recompile_guard):
    from repro.core import prox

    _fit("trace")      # compile once (and pay the lazy obs import)
    _fit("off")
    with recompile_guard(solve=prox._solve_reference):
        _fit("trace")
        _fit("summary")
        _fit("off")


def test_fit_path_telemetry_and_span():
    from repro.core import graphs
    from repro.estimator import ConcordEstimator, SolverConfig
    from repro.obs.trace import get_tracer

    tracer = get_tracer()
    tracer.clear()
    prob = graphs.make_problem("chain", 20, 48, seed=0)
    cfg = SolverConfig(backend="reference", variant="cov", tol=1e-4,
                       max_iters=60, obs="summary")
    est = ConcordEstimator(penalty="l1", config=cfg)
    path = est.fit_path(s=prob.s, lam1_grid=[0.3, 0.2, 0.1],
                        n_samples=48, score_bic=False)
    tele = path.telemetry
    assert set(tele) >= {"lam1", "iters", "ls_total", "converged",
                         "objective", "wall_time_s"}
    assert all(len(v) == 3 for v in tele.values())
    assert np.all(tele["iters"] >= 1)
    names = [s.name for s in tracer.snapshot()]
    assert "fit_path" in names and "fit.reference" in names


# ---------------------------------------------------------------------------
# comm reconciliation: measured == static, exactly
# ---------------------------------------------------------------------------

def test_commwatch_reconciles_single_device_exactly():
    import jax
    import jax.numpy as jnp

    from repro.comm.grid import Grid1p5D
    from repro.core import distributed as dist
    from repro.obs.commwatch import CommWatch

    rng = np.random.default_rng(0)
    x = rng.standard_normal((40, 24))
    s = jnp.asarray(x.T @ x / 40)
    with CommWatch() as watch:
        res = dist.fit_cov(s, 0.3, grid=Grid1p5D(1, 1, 1), max_iters=5)
        jax.block_until_ready(res.omega)
    reports = watch.reconcile()
    assert reports, "no dispatches reconciled"
    for rep in reports:
        assert rep.ok, rep.render()
        assert rep.rows
        for r in rep.rows:
            assert r.measured_count == r.predicted_count > 0


@pytest.mark.slow
def test_reconcile_4dev_measured_equals_static_cov_and_obs():
    """THE acceptance assertion: on 4 devices, a traced solve's measured
    per-(prim, axes) collective invocation counts AND payload bytes equal
    the CA303 static comm_volume prediction exactly, for both the cov
    and obs variants, including a replicated (c_omega=2) grid."""
    run_with_devices("""
import numpy as np, jax, jax.numpy as jnp
from repro.comm.grid import Grid1p5D
from repro.core import distributed as dist
from repro.obs.commwatch import CommWatch

rng = np.random.default_rng(0)
x = rng.standard_normal((48, 32))
s = jnp.asarray(x.T @ x / 48)
for variant, cx, co in [("cov", 1, 1), ("cov", 2, 2),
                        ("obs", 1, 1), ("obs", 1, 2)]:
    g = Grid1p5D(4, cx, co)
    with CommWatch() as watch:
        if variant == "cov":
            res = dist.fit_cov(s, 0.3, grid=g, max_iters=6)
        else:
            res = dist.fit_obs(jnp.asarray(x), 0.3, grid=g, max_iters=6)
        jax.block_until_ready(res.omega)
    reports = watch.reconcile()
    assert reports, (variant, cx, co)
    for rep in reports:
        assert rep.ok, (variant, cx, co, rep.render())
        for r in rep.rows:
            assert r.measured_count == r.predicted_count > 0
        assert rep.measured_total == rep.predicted_total > 0
        print(variant, cx, co, "OK", int(rep.measured_total), "bytes")
print("OK")
""", n_devices=4)


@pytest.mark.slow
def test_estimator_trace_mode_reconciles_on_4_devices():
    """End-to-end through the estimator facade: ``obs="trace"`` on the
    distributed backend lands the reconciliation on the report's
    telemetry, every row exact."""
    run_with_devices("""
import numpy as np
from repro.core import graphs
from repro.estimator import ConcordEstimator, SolverConfig

prob = graphs.make_problem("chain", 24, 56, seed=0)
cfg = SolverConfig(backend="distributed", variant="cov", tol=1e-4,
                   max_iters=8, obs="trace")
est = ConcordEstimator(lam1=0.25, config=cfg)
est.fit_cov(prob.s, n_samples=56)
from fractions import Fraction
tele = est.report_.telemetry
assert tele is not None and tele["comm_reconcile_ok"] is True
reps = tele["comm_reconcile"]
assert reps and all(r["ok"] for r in reps)
assert all(Fraction(r["measured_bytes_total"]) > 0 for r in reps)
assert all(row["match"] for r in reps for row in r["rows"])
print("OK")
""", n_devices=4)


# ---------------------------------------------------------------------------
# serve drain latency split
# ---------------------------------------------------------------------------

def test_serve_obs_latency_split():
    import argparse

    from repro.launch.serve import serve_concord
    from repro.obs.metrics import get_registry

    get_registry().clear()
    stats = serve_concord(argparse.Namespace(
        requests=4, batch=2, p=16, n=40, lam2=0.05, tol=1e-4,
        max_iters=40, seed=0, obs="summary"))
    for arr in (stats.queue_wait_s, stats.solve_wall_s, stats.latency_s):
        assert arr is not None and arr.shape == (4,)
        assert np.all(arr >= 0)
    np.testing.assert_allclose(stats.latency_s,
                               stats.queue_wait_s + stats.solve_wall_s)
    # groups launch one after another (reordered by predicted length),
    # so at least one request waited behind another group's solve
    assert stats.queue_wait_s.max() > 0
    snap = get_registry().snapshot()
    assert snap["repro_serve_latency_seconds"]["count"] == 4
    assert snap["repro_serve_queue_wait_seconds"]["count"] == 4
    assert snap["repro_serve_solve_wall_seconds"]["count"] == 4
