"""Logical-axis -> PartitionSpec resolution + grid index math."""
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal CPU image — deterministic fallback
    from _hypothesis_fallback import given, settings, st

from jax.sharding import PartitionSpec as P

from repro.comm.grid import Grid1p5D
from repro.models.config import DEFAULT_RULES, logical_to_spec


class FakeMesh:
    def __init__(self, shape):
        self.shape = dict(shape)


def test_basic_mapping():
    mesh = FakeMesh({"data": 16, "model": 16})
    spec = logical_to_spec(("embed", "heads"), (2560, 4096), mesh,
                           DEFAULT_RULES)
    assert spec == P("data", "model")


def test_indivisible_falls_back_to_replicated():
    mesh = FakeMesh({"data": 16, "model": 16})
    logical_to_spec(("embed", "kv"), (2560, 2 * 128), mesh,
                    DEFAULT_RULES)
    # kv dim 256 % 16 == 0 -> sharded; but 2 heads * 80 = 160 % 16 == 0;
    # now an actually indivisible one:
    spec2 = logical_to_spec(("embed", "kv"), (2560, 250), mesh,
                            DEFAULT_RULES)
    assert spec2[1] is None


def test_axis_never_used_twice():
    mesh = FakeMesh({"data": 4, "model": 4})
    spec = logical_to_spec(("embed", "embed"), (16, 16), mesh,
                           DEFAULT_RULES)
    assert spec[0] == "data" and spec[1] is None


def test_kv_seq_fallback_order():
    """decode cache: batch takes pod+data, kv takes model -> kv_seq
    replicated; when kv can't shard, kv_seq picks up model (SP)."""
    mesh = FakeMesh({"pod": 2, "data": 16, "model": 16})
    # kv = 32 shards over model; kv_seq has nothing left
    spec = logical_to_spec(("batch", "kv", "kv_seq"), (128, 32, 32768),
                           mesh, DEFAULT_RULES)
    assert spec == P(("pod", "data"), "model", None)
    # kv = 2 cannot shard -> kv_seq gets model
    spec2 = logical_to_spec(("batch", "kv", "kv_seq"), (128, 2, 32768),
                            mesh, DEFAULT_RULES)
    assert spec2 == P(("pod", "data"), None, "model")
    # batch = 1 (long_500k): kv_seq gets the batch axes
    spec3 = logical_to_spec(("batch", "kv", "kv_seq"), (1, 2, 524288),
                            mesh, DEFAULT_RULES)
    assert spec3[0] is None
    assert spec3[2] == ("pod", "data", "model")  # full SP over all axes


@given(st.sampled_from([1, 2, 4, 8, 16]), st.sampled_from([1, 2, 4, 8]),
       st.sampled_from([1, 2, 4, 8]))
@settings(max_examples=40, deadline=None)
def test_grid_permutations_are_permutations(P_, cx, co):
    if cx * co > P_ or P_ % (cx * co):
        return
    g = Grid1p5D(P_, cx, co)
    # the (canonical, ring, n_r) combinations the 1.5D algorithms use:
    # n_r always matches the canonical layout's block count
    for canonical, ring in [("xlike", "x"), ("xlike", "omega"),
                            ("omegalike", "x"), ("omegalike", "omega")]:
        n_r = g.n_x if canonical == "xlike" else g.n_om
        perm = g.stagger_perm(canonical, ring, n_r)
        assert sorted(s for s, _ in perm) == list(range(P_))
        assert sorted(d for _, d in perm) == list(range(P_))
    for ring in ("x", "omega"):
        shift = g.shift_perm(ring, max(1, cx))
        assert sorted(d for _, d in shift) == list(range(P_))


def test_grid_flat_roundtrip():
    g = Grid1p5D(16, 2, 4)
    for f in range(16):
        assert g.coords_to_flat(*g.flat_to_coords(f)) == f
        assert g.omajor_to_flat(g.flat_to_omajor(f)) == f


def test_pad_p():
    g = Grid1p5D(8, 2, 2)
    assert g.pad_p(50) == 56
    assert g.pad_p(56) == 56
