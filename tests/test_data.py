"""Streaming data/Gram subsystem tests.

Agreement convention (project memory): exactness asserts run in float64 —
the accumulator's f64 contract holds with jax x64 BOTH off (host numpy
panels) and on (jnp panels), and streamed-vs-dense must match at 1e-10.
"""
import numpy as np
import pytest

import jax

from conftest import run_with_devices
from repro.core.matops import panel_gram
from repro.data import (
    GramAccumulator,
    as_source,
    available_families,
    compute_gram,
    make_scenario,
    open_shards,
    write_shards,
)
from repro.data.shards import is_streaming_input
from repro.data.transforms import get_transform, rank_transform_column

AGREE = 1e-10

MOMENT_TRANSFORMS = ["none", "center", "standardize"]


@pytest.fixture(scope="module")
def x_data():
    rng = np.random.default_rng(7)
    return rng.standard_normal((900, 41))


def _dense_reference(x, transform):
    x = np.asarray(x, np.float64)
    if transform == "none":
        z = x
    elif transform == "center":
        z = x - x.mean(0)
    elif transform == "standardize":
        z = (x - x.mean(0)) / x.std(0)
    else:  # rank
        z = np.stack([rank_transform_column(x[:, j])
                      for j in range(x.shape[1])], axis=1)
    return z.T @ z / x.shape[0]


# ---------------------------------------------------------------------------
# streamed vs one-shot agreement (the acceptance criterion)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("transform", MOMENT_TRANSFORMS + ["rank"])
def test_streamed_gram_matches_dense_over_chunks(x_data, transform):
    """>= 4 uneven chunks, any transform: the streamed f64 Gram matches
    the dense XᵀX/n of the transformed matrix to 1e-10."""
    g = compute_gram(x_data, transform=transform, chunk_rows=211)
    assert g.n_chunks >= 4 and g.n == 900 and g.p == 41
    ref = _dense_reference(x_data, transform)
    assert np.abs(g.s - ref).max() < AGREE
    assert g.s.dtype == np.float64
    np.testing.assert_array_equal(g.s, g.s.T)


@pytest.mark.parametrize("transform", MOMENT_TRANSFORMS)
def test_streamed_gram_f64_with_x64_enabled(x_data, transform):
    """Same agreement with the jnp panel path (jax x64 on)."""
    prev = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", True)
    try:
        g = compute_gram(as_source(x_data, chunk_rows=190),
                         transform=transform)
        assert np.abs(g.s - _dense_reference(x_data, transform)).max() < AGREE
    finally:
        jax.config.update("jax_enable_x64", prev)


def test_f64_accumulation_from_f32_chunks(x_data):
    """bf16/f32 shards still produce an f64 Gram: agreement against the
    dense product of the UPCAST data (dtype of the stream is recorded)."""
    x32 = x_data.astype(np.float32)
    g = compute_gram(as_source(x32, chunk_rows=180))
    ref = x32.astype(np.float64)
    assert np.abs(g.s - ref.T @ ref / 900).max() < AGREE
    assert g.s.dtype == np.float64 and g.source_dtype == "float32"


def test_chunk_order_invariance(x_data):
    """Welford/Chan merging: permuting the chunk order moves the result
    only at f64 summation-order level."""
    chunks = [x_data[lo:lo + 225] for lo in range(0, 900, 225)]
    g1 = compute_gram(chunks, transform="standardize")
    g2 = compute_gram(chunks[::-1], transform="standardize")
    assert np.abs(g1.s - g2.s).max() < 1e-12


def test_accumulator_merge_matches_single(x_data):
    a = GramAccumulator().update(x_data[:300]).update(x_data[300:400])
    b = GramAccumulator().update(x_data[400:850]).update(x_data[850:])
    merged = a.merge(b).finalize()
    one = compute_gram(x_data, transform="none")
    assert merged.n == 900
    assert np.abs(merged.s - one.s).max() < AGREE
    assert np.abs(merged.mean - one.mean).max() < 1e-12


def test_panel_gram_blocked_matches_direct(x_data):
    x64 = np.asarray(x_data, np.float64)
    out = np.asarray(panel_gram(x64, panel=7))
    assert out.dtype == np.float64            # host f64 path (x64 off)
    assert np.abs(out - x64.T @ x64).max() < AGREE


# ---------------------------------------------------------------------------
# transforms
# ---------------------------------------------------------------------------

def test_standardize_gram_is_correlation(x_data):
    g = compute_gram(x_data, transform="standardize")
    assert np.abs(np.diag(g.s) - 1.0).max() < 1e-12
    assert np.abs(g.s).max() <= 1.0 + 1e-12


def test_rank_transform_invariant_under_monotone_marginals(x_data):
    """The nonparanormal claim: strictly monotone per-column distortions
    leave the rank Gram bit-identical."""
    distorted = x_data.copy()
    distorted[:, 0] = np.exp(distorted[:, 0])
    distorted[:, 5] = distorted[:, 5] ** 3
    distorted[:, 9] = np.arctan(distorted[:, 9]) * 10.0
    g0 = compute_gram(x_data, transform="rank")
    g1 = compute_gram(distorted, transform="rank")
    np.testing.assert_array_equal(g0.s, g1.s)


def test_rank_requires_reiterable_source(x_data):
    gen = (x_data[lo:lo + 100] for lo in range(0, 900, 100))
    with pytest.raises(ValueError, match="re-iterable"):
        compute_gram(gen, transform="rank")


def test_rank_bounded_panels_match_wide_panels(x_data):
    """Shrinking the rank budget (1-column panels, many source sweeps)
    cannot change the answer — only the memory footprint."""
    tight = compute_gram(as_source(x_data, chunk_rows=300),
                         transform="rank", budget_bytes=900 * 8)
    wide = compute_gram(x_data, transform="rank")
    assert np.abs(tight.s - wide.s).max() < AGREE


def test_rank_rejects_accumulator_and_unknown_names():
    with pytest.raises(ValueError, match="two-pass"):
        GramAccumulator(transform="rank")
    with pytest.raises(ValueError, match="unknown transform"):
        get_transform("zscore")


# ---------------------------------------------------------------------------
# shard sources
# ---------------------------------------------------------------------------

def test_npy_shard_roundtrip(tmp_path, x_data):
    write_shards(x_data.astype(np.float32), tmp_path, rows_per_shard=256)
    src = open_shards(tmp_path, chunk_rows=100)
    assert src.reiterable and src.p == 41 and src.n_rows == 900
    g = compute_gram(src, transform="center")
    ref = _dense_reference(x_data.astype(np.float32), "center")
    assert np.abs(g.s - ref).max() < AGREE


def test_raw_shard_roundtrip(tmp_path, x_data):
    paths = write_shards(x_data, tmp_path, rows_per_shard=333, raw=True)
    src = open_shards(paths, chunk_rows=128)
    assert src.n_rows == 900
    g = compute_gram(src)
    assert np.abs(g.s - x_data.T @ x_data / 900).max() < AGREE


def test_mixed_shard_formats_rejected(tmp_path, x_data):
    """A stray .npy in a raw-shard set must refuse loudly — parsed as raw
    binary its 128-byte header would fold into the Gram as a garbage row
    (the size-multiple check can't catch it: the header is row-sized for
    p=16 f64)."""
    paths = write_shards(x_data, tmp_path, rows_per_shard=500, raw=True)
    np.save(tmp_path / "stray.npy", x_data[:10])
    with pytest.raises(ValueError, match="mixed shard formats"):
        open_shards(paths + [str(tmp_path / "stray.npy")])


def test_raw_shards_without_sidecar_rejected(tmp_path, x_data):
    paths = write_shards(x_data, tmp_path, rows_per_shard=500, raw=True)
    (tmp_path / "shards_meta.json").unlink()
    with pytest.raises(ValueError, match="sidecar"):
        open_shards(paths)


def test_is_streaming_input_discriminates(x_data):
    import jax.numpy as jnp
    assert is_streaming_input(iter([x_data]))
    assert is_streaming_input(lambda: iter([x_data]))
    assert is_streaming_input(as_source(x_data))
    assert not is_streaming_input(x_data)
    assert not is_streaming_input(jnp.zeros((3, 3)))
    assert not is_streaming_input([[1.0, 2.0], [3.0, 4.0]])


def test_one_shot_iterator_single_sweep_only(x_data):
    src = as_source(c for c in [x_data[:450], x_data[450:]])
    g = compute_gram(src)
    assert g.n == 900
    with pytest.raises(ValueError, match="consumed"):
        list(src.chunks())


# ---------------------------------------------------------------------------
# scenario suite
# ---------------------------------------------------------------------------

def test_scenario_registry_has_at_least_five_families():
    assert len(available_families()) >= 5
    assert {"banded", "hub", "erdos_renyi", "block",
            "scale_free"} <= set(available_families())


@pytest.mark.parametrize("family", sorted({"banded", "hub", "erdos_renyi",
                                           "block", "scale_free"}))
def test_scenario_omega_spd_exact_cond_and_stream(family):
    sc = make_scenario(family, p=40, cond=12.0, seed=3)
    ev = np.linalg.eigvalsh(sc.omega)
    assert ev[0] > 0                                 # SPD
    assert ev[-1] / ev[0] == pytest.approx(12.0, rel=1e-9)
    np.testing.assert_allclose(np.diag(sc.omega), 1.0)
    assert sc.avg_degree > 0                         # non-empty graph
    # seeded chunked sampler: re-iterable + byte-identical across opens
    s1, s2 = (sc.source(500, chunk_rows=128, seed=5) for _ in range(2))
    c1 = np.concatenate(list(s1.chunks()))
    c2 = np.concatenate(list(s2.chunks()))
    np.testing.assert_array_equal(c1, c2)
    assert c1.shape == (500, 40)
    # the stream's covariance approaches inv(Omega)
    big = sc.sample(6000, seed=1)
    emp = big.T @ big / 6000
    assert np.abs(emp - np.linalg.inv(sc.omega)).max() < 0.5


@pytest.mark.parametrize("family", sorted({"banded", "hub", "erdos_renyi",
                                           "block", "scale_free"}))
def test_scenario_recovery_smoke(family):
    """Per-generator end-to-end: stream -> Gram -> solve recovers a
    meaningful share of the true support (bounds calibrated well below
    the ~0.86+ PPV these settings actually achieve)."""
    from repro.core import graphs
    from repro.estimator import ConcordEstimator, SolverConfig

    sc = make_scenario(family, p=32, cond=8.0, seed=0)
    g = compute_gram(sc.source(1500, chunk_rows=400),
                     transform="standardize")
    cfg = SolverConfig(backend="reference", variant="cov", tol=1e-5,
                       max_iters=200)
    est = ConcordEstimator(lam1=0.1, lam2=0.05, config=cfg).fit_gram(g)
    assert est.report_.converged
    ppv, fdr = graphs.ppv_fdr(np.asarray(est.omega_), sc.omega)
    assert ppv >= 0.6, f"{family}: PPV {ppv:.2f}"


def test_scenario_heavy_tails():
    sc = make_scenario("banded", p=12, heavy_tail_df=4.0, seed=0)
    x = sc.sample(4000, seed=2)
    kurt = float(np.mean(x ** 4) / np.mean(x ** 2) ** 2)
    assert kurt > 4.0          # well above the Gaussian 3


def test_scenario_unknown_family():
    with pytest.raises(ValueError, match="unknown scenario family"):
        make_scenario("smallworld", p=16)


# ---------------------------------------------------------------------------
# estimator integration + input validation (satellite)
# ---------------------------------------------------------------------------

def _cfg():
    from repro.estimator import SolverConfig
    return SolverConfig(backend="reference", variant="cov", tol=1e-5,
                        max_iters=200)


def test_fit_cov_rejects_nonfinite_and_asymmetric(x_data):
    from repro.estimator import ConcordEstimator
    s = np.cov(x_data.T)
    bad = s.copy()
    bad[3, 4] = np.nan
    with pytest.raises(ValueError, match="NaN/Inf"):
        ConcordEstimator(lam1=0.2, config=_cfg()).fit_cov(bad, n_samples=900)
    with pytest.raises(ValueError, match="symmetric"):
        ConcordEstimator(lam1=0.2, config=_cfg()).fit_cov(
            s + np.triu(np.ones_like(s), k=1), n_samples=900)
    with pytest.raises(ValueError, match="square"):
        ConcordEstimator(lam1=0.2, config=_cfg()).fit_cov(s[:, :5])
    with pytest.raises(ValueError, match="n_samples"):
        ConcordEstimator(lam1=0.2, config=_cfg()).fit_cov(s, n_samples=0)


def test_fit_rejects_nonfinite_x(x_data):
    from repro.estimator import ConcordEstimator
    x = x_data.copy()
    x[5, 5] = np.inf
    with pytest.raises(ValueError, match="NaN/Inf"):
        ConcordEstimator(lam1=0.2, config=_cfg()).fit(x)


def test_fit_gram_duck_typing_and_validation(x_data):
    from repro.estimator import ConcordEstimator
    with pytest.raises(TypeError, match="GramResult-like"):
        ConcordEstimator(lam1=0.2).fit_gram(np.eye(4))
    g = compute_gram(x_data, transform="standardize")
    garbage = g._replace(s=np.full_like(g.s, np.nan))
    with pytest.raises(ValueError, match="NaN/Inf"):
        ConcordEstimator(lam1=0.2, config=_cfg()).fit_gram(garbage)


def test_streamed_fit_agrees_with_dense_solve_f64(x_data):
    """f64 solver agreement: a >=4-chunk streamed fit and the dense
    fit_cov of the same transformed data produce the same estimate."""
    from repro.estimator import ConcordEstimator
    prev = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", True)
    try:
        est_s = ConcordEstimator(lam1=0.15, lam2=0.05, config=_cfg()).fit(
            (x_data[lo:lo + 225] for lo in range(0, 900, 225)))
        ref = _dense_reference(x_data, "none")
        est_d = ConcordEstimator(lam1=0.15, lam2=0.05,
                                 config=_cfg()).fit_cov(ref, n_samples=900)
        gap = np.abs(np.asarray(est_s.omega_)
                     - np.asarray(est_d.omega_)).max()
        assert gap < 1e-8, gap
        assert est_s.report_.variant == "cov"
    finally:
        jax.config.update("jax_enable_x64", prev)


def test_fit_transform_kwarg_routes_array_through_pipeline(x_data):
    from repro.estimator import ConcordEstimator
    est = ConcordEstimator(lam1=0.2, lam2=0.05, config=_cfg())
    est.fit(x_data, transform="rank")
    assert est.report_.converged
    assert est.report_.variant == "cov"


def test_gram_chunk_rows_guidance():
    from repro.core.costmodel import Machine, gram_chunk_rows
    rows = gram_chunk_rows(1024)
    assert 256 <= rows <= 1 << 20
    # tighter budget -> smaller chunks, floor respected once the (p, p)
    # accumulator is accounted for
    tight = gram_chunk_rows(1024, budget_bytes=1024 * 1024 * 8 + 1e6)
    assert 256 <= tight <= rows
    # accumulator alone over budget -> no chunk size can help: raise
    with pytest.raises(ValueError, match="accumulator alone"):
        gram_chunk_rows(10 ** 6, machine=Machine())
    with pytest.raises(ValueError):
        gram_chunk_rows(0)


def test_gram_cli_prep_and_solve_from_gram(tmp_path):
    from repro.launch import gram as gram_cli
    from repro.launch import solve as solve_cli
    out = str(tmp_path / "art")
    gram_cli.main(["prep", "--scenario", "hub", "--p", "32", "--n", "3000",
                   "--chunk-rows", "512", "--transform", "standardize",
                   "--out", out])
    import json
    import os
    assert os.path.exists(os.path.join(out, "S.npy"))
    with open(os.path.join(out, "gram_meta.json")) as f:
        meta = json.load(f)
    assert meta["n"] == 3000 and meta["p"] == 32
    assert meta["transform"] == "standardize"
    assert meta["peak_bytes_streamed"] < meta["peak_bytes_dense"]
    rep = solve_cli.main(["--from-gram", out, "--lam1", "0.2",
                          "--backend", "reference", "--max-iters", "150"])
    assert rep.variant == "cov" and rep.omega.shape == (32, 32)


# ---------------------------------------------------------------------------
# distributed twin (one psum through comm/compat)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_distributed_gram_psum_matches_oneshot():
    run_with_devices("""
import numpy as np, jax
jax.config.update("jax_enable_x64", True)
from repro.data import distributed_gram, compute_gram
rng = np.random.default_rng(0)
parts = [rng.standard_normal((n, 19)) for n in (210, 401, 88, 301)]
full = np.concatenate(parts)
for tf in ["none", "center", "standardize"]:
    g = distributed_gram(parts, transform=tf, chunk_rows=97)
    ref = compute_gram(full, transform=tf)
    assert np.abs(g.s - ref.s).max() < 1e-10, tf
    assert g.n == 1000
try:
    distributed_gram(parts, transform="rank")
    raise SystemExit("rank must raise")
except ValueError:
    pass
print("OK")
""", n_devices=4)
