"""The sparsity-aware linear-algebra layer: block-mask exactness, the
block-gather product vs the dense oracle, crossover dispatch behaviour,
block-sparse vs dense SOLVE agreement (cov and obs), the cost-model
crossover, and the lazy kernel interpret mode."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal CPU image — deterministic fallback
    from _hypothesis_fallback import given, settings, st

from conftest import run_with_devices
from repro.core import matops
from repro.core.costmodel import (
    BlockSparseModel,
    blocksparse_matmul_time,
    calibrate_block_model,
    crossover_density,
    dense_matmul_time,
)


def _random_block_sparse(rng, p, bs, density):
    """Dense (p, p) array that is zero outside a random set of bs x bs
    blocks with expected block density ``density``."""
    a = rng.standard_normal((p, p)).astype(np.float32)
    nb = -(-p // bs)
    keep = rng.random((nb, nb)) < density
    for r in range(nb):
        for c in range(nb):
            if not keep[r, c]:
                a[r * bs:(r + 1) * bs, c * bs:(c + 1) * bs] = 0
    return a


def _oracle_mask(a, bs):
    """Block occupancy derived straight from jnp.nonzero coordinates."""
    p, q = a.shape
    nbr, nbc = -(-p // bs), -(-q // bs)
    mask = np.zeros((nbr, nbc), np.float32)
    rr, cc = np.nonzero(np.asarray(a))
    mask[rr // bs, cc // bs] = 1.0
    return mask


# ---------------------------------------------------------------------------
# mask + masked product (the linear-algebra layer itself)
# ---------------------------------------------------------------------------

@given(st.integers(0, 3), st.sampled_from([4, 8, 16]),
       st.sampled_from([0.0, 0.1, 0.4, 0.9]))
@settings(max_examples=12, deadline=None)
def test_block_mask_matches_nonzero_blocks(seed, bs, density):
    rng = np.random.default_rng(seed)
    p = 64 if bs != 16 else 96          # exercise exact and ragged tilings
    a = _random_block_sparse(rng, p, bs, density)
    a = a[: p - (seed % 3)]             # ragged rows -> padded edge tiles
    mask = matops.block_mask(jnp.asarray(a), bs)
    np.testing.assert_array_equal(np.asarray(mask), _oracle_mask(a, bs))


@given(st.integers(0, 3), st.sampled_from([4, 8, 16]),
       st.sampled_from([0.05, 0.2, 0.5]))
@settings(max_examples=12, deadline=None)
def test_masked_matmul_matches_dense(seed, bs, density):
    """The block-gather product agrees with the dense product to 1e-5 on
    random sparsity patterns (capacity == exact occupied count)."""
    rng = np.random.default_rng(seed)
    p, m = 96, 64
    a = _random_block_sparse(rng, p, bs, density)
    b = rng.standard_normal((p, m)).astype(np.float32)
    mask = matops.block_mask(jnp.asarray(a), bs)
    cap = max(1, int(np.asarray(mask).sum()))
    out = matops.masked_matmul(jnp.asarray(a), jnp.asarray(b), mask,
                               block_size=bs, capacity=cap)
    np.testing.assert_allclose(np.asarray(out), a @ b, rtol=1e-5, atol=1e-5)


def test_masked_matmul_padding_capacity_overshoot():
    """Capacity above the occupied count and non-divisible shapes are both
    handled (zero-masked padding picks, padded edge tiles)."""
    a = _random_block_sparse(np.random.default_rng(7), 64, 16, 0.2)[:50, :50]
    b = np.random.default_rng(8).standard_normal((50, 30)).astype(np.float32)
    mask = matops.block_mask(jnp.asarray(a), 16)
    out = matops.masked_matmul(jnp.asarray(a), jnp.asarray(b), mask,
                               block_size=16, capacity=15)
    np.testing.assert_allclose(np.asarray(out), a @ b, rtol=1e-5, atol=1e-5)


def test_dispatch_takes_dense_path_above_threshold():
    """Above the crossover threshold the dispatch MUST route dense: the
    sparse branch's capacity could not cover the occupied blocks, so value
    equality with the dense product proves the dense branch ran."""
    r = np.random.default_rng(3)
    a = _random_block_sparse(r, 64, 8, 0.8)
    b = r.standard_normal((64, 48)).astype(np.float32)
    mask = matops.block_mask(jnp.asarray(a), 8)
    assert float(matops.block_density(mask)) > 0.25
    policy = matops.MatmulPolicy("on", 8, 0.25)
    out = jax.jit(
        lambda a_, b_, m_: matops.matmul(a_, b_, mask=m_, policy=policy)
    )(jnp.asarray(a), jnp.asarray(b), mask)
    np.testing.assert_allclose(np.asarray(out), a @ b, rtol=1e-6, atol=1e-6)


def test_dispatch_exact_at_every_tier_capacity_boundary():
    """The rung selected by the dispatch must cover the occupied blocks
    EXACTLY at each tier capacity (the boundary where an off-by-one in
    searchsorted/capacity_tiers would silently drop blocks).  Occupied
    blocks all carry values, so any dropped block changes the product."""
    rng = np.random.default_rng(11)
    p, bs = 64, 8                   # 8x8 = 64 blocks
    total = (p // bs) ** 2
    policy = matops.MatmulPolicy("on", bs, 0.5)
    b = rng.standard_normal((p, 48)).astype(np.float32)
    fn = jax.jit(lambda a_, b_, m_: matops.matmul(a_, b_, mask=m_,
                                                  policy=policy))
    counts = {c for cap in matops.capacity_tiers(total, policy.threshold)
              for c in (cap, cap + 1)}
    for nnz in sorted(counts | {1, total - 1}):
        a = np.zeros((p, p), np.float32)
        ids = rng.choice(total, size=nnz, replace=False)
        for blk_id in ids:
            r, c = divmod(int(blk_id), p // bs)
            a[r * bs:(r + 1) * bs, c * bs:(c + 1) * bs] = \
                rng.standard_normal((bs, bs))
        mask = matops.block_mask(jnp.asarray(a), bs)
        assert int(np.asarray(mask).sum()) == nnz
        out = fn(jnp.asarray(a), jnp.asarray(b), mask)
        np.testing.assert_allclose(np.asarray(out), a @ b,
                                   rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# block-sparse solve vs dense solve (cov and obs)
# ---------------------------------------------------------------------------

@given(st.integers(0, 2), st.sampled_from([0.25, 0.35, 0.5]))
@settings(max_examples=6, deadline=None)
def test_sparse_solve_matches_dense_solve(seed, lam1):
    """Property: with sparse_matmul on, the solver output agrees with the
    dense path to 1e-5 on random problems (random sparsity patterns arise
    from the iterates themselves), for BOTH cov and obs variants.

    Runs in float64 so summation-order noise cannot flip line-search
    accepts: sparse and dense then follow identical trajectories and the
    1e-5 bound is meaningful (f32 fixed-point scatter is ~1e-4 even
    between two dense variants, see test_prox_solver tolerances)."""
    from repro.core import graphs
    from repro.core.prox import solve_reference

    jax.config.update("jax_enable_x64", True)
    try:
        prob = graphs.make_problem("chain", p=40, n=120, seed=seed)
        policy = matops.MatmulPolicy("on", 4, 0.6)
        for variant, data in (("cov", prob.s), ("obs", prob.x)):
            arr = jnp.asarray(data, jnp.float64)
            r0 = solve_reference(arr, lam1, 0.05, variant=variant,
                                 tol=1e-7, max_iters=400)
            r1 = solve_reference(arr, lam1, 0.05, variant=variant,
                                 tol=1e-7, max_iters=400,
                                 sparse_matmul=policy)
            np.testing.assert_allclose(np.asarray(r1.omega),
                                       np.asarray(r0.omega),
                                       rtol=0, atol=1e-5)
            assert 0.0 < float(r1.block_density) <= 1.0
    finally:
        jax.config.update("jax_enable_x64", False)


def test_sparse_solve_f32_same_support_and_objective():
    """In float32 the trajectories may diverge at line-search margins, but
    both paths must reach the same minimum: objectives agree tightly and
    the recovered edge sets match."""
    from repro.core import graphs
    from repro.core.objective import full_objective_cov
    from repro.core.prox import solve_reference

    prob = graphs.make_problem("chain", p=48, n=150, seed=1)
    s = jnp.asarray(prob.s)
    policy = matops.MatmulPolicy("on", 4, 0.6)
    r0 = solve_reference(s, 0.3, 0.05, tol=1e-6, max_iters=300)
    r1 = solve_reference(s, 0.3, 0.05, tol=1e-6, max_iters=300,
                         sparse_matmul=policy)
    f0 = float(full_objective_cov(r0.omega, s, 0.3, 0.05))
    f1 = float(full_objective_cov(r1.omega, s, 0.3, 0.05))
    assert abs(f0 - f1) < 1e-3, (f0, f1)
    np.testing.assert_array_equal(np.abs(np.asarray(r0.omega)) > 1e-4,
                                  np.abs(np.asarray(r1.omega)) > 1e-4)


def test_pallas_harvested_mask_matches_jnp_harvest():
    """use_pallas harvests the occupancy from the fused prox kernel's nnz
    lane; the solve must match the jnp-harvested one exactly in routing
    (same observed density) and to solver accuracy in values."""
    from repro.core import graphs
    from repro.core.prox import solve_reference

    prob = graphs.make_problem("chain", p=48, n=150, seed=1)
    s = jnp.asarray(prob.s)
    policy = matops.MatmulPolicy("on", 8, 0.6)
    r_jnp = solve_reference(s, 0.3, 0.05, tol=1e-6, max_iters=300,
                            sparse_matmul=policy)
    r_pal = solve_reference(s, 0.3, 0.05, tol=1e-6, max_iters=300,
                            sparse_matmul=policy, use_pallas=True)
    assert float(r_jnp.block_density) == float(r_pal.block_density)
    np.testing.assert_allclose(np.asarray(r_pal.omega),
                               np.asarray(r_jnp.omega), atol=2e-4)


@pytest.mark.slow
def test_distributed_sparse_matches_dense():
    run_with_devices("""
import numpy as np, jax, jax.numpy as jnp
from repro.core import graphs, matops
from repro.core.distributed import fit_cov, fit_obs
from repro.comm.grid import Grid1p5D
prob = graphs.make_problem("chain", p=48, n=120, seed=0)
pol = matops.MatmulPolicy("on", 2, 0.6)
for cx, co in [(1,1),(2,2)]:
    g = Grid1p5D(8, cx, co)
    r0 = fit_cov(jnp.asarray(prob.s), 0.3, 0.05, grid=g, tol=1e-6, max_iters=200)
    r1 = fit_cov(jnp.asarray(prob.s), 0.3, 0.05, grid=g, tol=1e-6, max_iters=200,
                 sparse_matmul=pol)
    assert np.abs(np.asarray(r0.omega)-np.asarray(r1.omega)).max() < 2e-3
    assert 0.0 < float(r1.block_density) < 1.0
for cx, co in [(1,1),(4,2),(1,8)]:
    g = Grid1p5D(8, cx, co)
    r0 = fit_obs(jnp.asarray(prob.x), 0.3, 0.05, grid=g, tol=1e-6, max_iters=200)
    r1 = fit_obs(jnp.asarray(prob.x), 0.3, 0.05, grid=g, tol=1e-6, max_iters=200,
                 sparse_matmul=pol)
    assert np.abs(np.asarray(r0.omega)-np.asarray(r1.omega)).max() < 2e-3
    assert 0.0 < float(r1.block_density) < 1.0
print("OK")
""", n_devices=8)


# ---------------------------------------------------------------------------
# estimator facade plumbing
# ---------------------------------------------------------------------------

def test_estimator_reports_density_and_nnz():
    from repro.core import graphs
    from repro.estimator import SolverConfig, fit

    prob = graphs.make_problem("chain", p=48, n=150, seed=1)
    s = jnp.asarray(prob.s)
    rep = fit(s=s, lam1=0.3, lam2=0.05, n_samples=150, backend="reference",
              variant="cov", tol=1e-6, sparse_matmul="on", sparse_block=4,
              sparse_threshold=0.6)
    assert rep.sparse_matmul == "on"
    assert rep.nnz_per_row is not None and rep.nnz_per_row >= 1.0
    assert 0.0 < rep.block_density < 1.0
    assert "density=" in rep.summary()
    # dense solves still populate the density column (post hoc)
    rep0 = fit(s=s, lam1=0.3, lam2=0.05, n_samples=150, backend="reference",
               variant="cov", tol=1e-6, sparse_block=4)
    assert 0.0 < rep0.block_density <= 1.0
    # config validation of the new knobs
    with pytest.raises(ValueError, match="sparse_matmul"):
        SolverConfig(sparse_matmul="sometimes")
    with pytest.raises(ValueError, match="sparse_block"):
        SolverConfig(sparse_block=0)
    with pytest.raises(ValueError, match="sparse_threshold"):
        SolverConfig(sparse_threshold=1.5)


def test_observed_density_feeds_model_selection():
    """A warm start's observed nnz/row replaces the static prior in the
    cost-model shape (the previous lambda step drives the next tune)."""
    from repro.core import distributed as dist
    from repro.estimator.backends import Problem, _problem_shape

    rng = np.random.default_rng(0)
    x = rng.standard_normal((100, 40)).astype(np.float32)
    problem = Problem.from_data(x=jnp.asarray(x))
    prior = _problem_shape(problem, 0.3)
    assert prior.d == dist.estimate_density(40, 100, 0.3)
    omega0 = np.eye(40, dtype=np.float32)
    omega0[0, 1] = omega0[1, 0] = 0.5
    observed = _problem_shape(problem, 0.3, omega0=omega0)
    assert observed.d == pytest.approx((40 + 2) / 40)


def test_auto_policy_threshold_is_cost_model_crossover():
    from repro.estimator import SolverConfig
    from repro.estimator.backends import _matmul_policy

    cfg = SolverConfig(sparse_matmul="auto", sparse_block=128)
    pol = _matmul_policy(cfg, 4096, 4096)
    model_thr = crossover_density(4096, 4096, 128)
    if pol is None:
        assert model_thr <= 0.0
    else:
        assert pol.threshold == pytest.approx(model_thr)
        # a user cap can only lower it
        cfg2 = cfg.replace(sparse_threshold=min(0.5, model_thr) / 2)
        pol2 = _matmul_policy(cfg2, 4096, 4096)
        assert pol2.threshold <= pol.threshold
    assert _matmul_policy(SolverConfig(), 4096, 4096) is None


# ---------------------------------------------------------------------------
# cost-model crossover
# ---------------------------------------------------------------------------

def test_crossover_density_sane_and_monotone():
    d = crossover_density(2048, 2048, 128)
    assert 0.0 < d < 1.0
    # cheaper gathers -> later crossover (sparse pays off at higher density)
    fast_gather = BlockSparseModel(gather_eff=1.0)
    slow_gather = BlockSparseModel(gather_eff=0.1)
    assert crossover_density(2048, 2048, 128, model=fast_gather) > \
        crossover_density(2048, 2048, 128, model=slow_gather)
    # at the crossover, modeled times match
    m, model = 2048, BlockSparseModel()
    dx = crossover_density(2048, m, 128, model=model)
    t_s = blocksparse_matmul_time(2048, m, dx, 128, model=model)
    t_d = dense_matmul_time(2048, m, model=model)
    assert t_s == pytest.approx(t_d, rel=1e-6)


def test_calibrate_block_model_roundtrip():
    """Calibration recovers a model whose predicted crossover matches the
    one implied by synthetic measurements generated from known constants."""
    truth = BlockSparseModel(dense_eff=0.7, sparse_eff=0.35, gather_eff=0.4)
    rows = []
    for p in (1024, 2048):
        for density in (0.05, 0.1, 0.2, 0.5, 1.0):
            rows.append({
                "p": p, "m": p, "block_size": 128, "density": density,
                "t_dense": dense_matmul_time(p, p, model=truth),
                "t_sparse": blocksparse_matmul_time(p, p, density, 128,
                                                    model=truth),
            })
    fitted = calibrate_block_model(rows)
    for p in (1024, 2048):
        assert crossover_density(p, p, 128, model=fitted) == pytest.approx(
            crossover_density(p, p, 128, model=truth), rel=1e-3)


# ---------------------------------------------------------------------------
# lazy kernel interpret mode
# ---------------------------------------------------------------------------

def test_kernel_interpret_is_lazy_and_overridable():
    from repro.kernels import ops

    assert ops.interpret_default() is (jax.default_backend() != "tpu")
    try:
        ops.set_interpret(False)
        assert ops.interpret_default() is False
        ops.set_interpret(True)
        assert ops.interpret_default() is True
        with pytest.raises(TypeError):
            ops.set_interpret("yes")
    finally:
        ops.set_interpret(None)
    assert ops.interpret_default() is (jax.default_backend() != "tpu")


def test_kernel_module_has_no_import_time_backend_probe():
    """Importing repro.kernels.ops must not evaluate the backend at import
    time (the INTERPRET module constant is gone; resolution is per call)."""
    import repro.kernels.ops as ops
    assert not hasattr(ops, "INTERPRET")
    assert ops._INTERPRET_OVERRIDE is None
