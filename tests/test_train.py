"""Training substrate: optimizer, schedules, data, checkpoint, fault."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal CPU image — deterministic fallback
    from _hypothesis_fallback import given, settings, st

from repro.train import checkpoint as ckpt
from repro.train.data import SyntheticLM
from repro.train.fault import Heartbeat, StragglerMonitor, retry
from repro.train.optim import (AdamW, SGDM, accumulate_gradients,
                               clip_by_global_norm, cosine_schedule,
                               global_norm, linear_schedule)


def test_adamw_minimizes_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    opt = AdamW(lr=0.1, weight_decay=0.0, clip_norm=100.0)
    state = opt.init(params)
    for _ in range(300):
        grads = {"w": 2 * params["w"]}
        params, state, _ = opt.update(grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_adamw_weight_decay_shrinks():
    params = {"w": jnp.ones(4) * 10.0}
    opt = AdamW(lr=0.01, weight_decay=0.5, clip_norm=100.0)
    state = opt.init(params)
    for _ in range(50):
        params, state, _ = opt.update({"w": jnp.zeros(4)}, state, params)
    assert float(params["w"].max()) < 10.0


def test_sgdm_minimizes_quadratic():
    params = {"w": jnp.asarray([4.0])}
    opt = SGDM(lr=0.05)
    state = opt.init(params)
    for _ in range(200):
        params, state, _ = opt.update({"w": 2 * params["w"]}, state, params)
    assert abs(float(params["w"][0])) < 1e-2


def test_clip_by_global_norm():
    tree = {"a": jnp.ones(100) * 10}
    clipped, g = clip_by_global_norm(tree, 1.0)
    np.testing.assert_allclose(float(global_norm(clipped)), 1.0, rtol=1e-5)
    assert float(g) == pytest.approx(100.0, rel=1e-5)


def test_schedules():
    lr = cosine_schedule(1.0, 10, 100)
    assert float(lr(0)) == 0.0
    assert float(lr(10)) == pytest.approx(1.0, rel=1e-3)
    assert float(lr(100)) == pytest.approx(0.1, rel=1e-2)  # min_frac
    lin = linear_schedule(1.0, 10, 100)
    assert float(lin(55)) == pytest.approx(0.5, rel=1e-2)


@given(st.integers(0, 100), st.integers(0, 100))
@settings(max_examples=10, deadline=None)
def test_data_pipeline_deterministic(step, other):
    """batch_at(step) is a pure function — the restart/straggler story."""
    src = SyntheticLM(vocab=100, seq_len=16, global_batch=2, seed=1)
    a = src.batch_at(step)
    b = src.batch_at(step)
    np.testing.assert_array_equal(np.asarray(a.tokens),
                                  np.asarray(b.tokens))
    if step != other:
        c = src.batch_at(other)
        assert not np.array_equal(np.asarray(a.tokens),
                                  np.asarray(c.tokens))


def test_data_targets_are_shifted():
    src = SyntheticLM(vocab=100, seq_len=16, global_batch=2, seed=1)
    b = src.batch_at(0)
    np.testing.assert_array_equal(np.asarray(b.targets[:, :-1]),
                                  np.asarray(b.tokens[:, 1:]))


def test_checkpoint_roundtrip(tmp_path):
    state = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
             "nested": {"b": jnp.ones(4, jnp.bfloat16)}}
    ckpt.save(str(tmp_path), 7, state, data_cursor=7)
    template = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), state)
    restored, manifest = ckpt.restore(str(tmp_path), template)
    assert manifest["step"] == 7
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), state, restored)


def test_checkpoint_gc_keeps_latest(tmp_path):
    state = {"a": jnp.zeros(2)}
    for s in (1, 2, 3, 4, 5):
        ckpt.save(str(tmp_path), s, state, keep=2)
    assert ckpt.latest_step(str(tmp_path)) == 5
    steps = sorted(os.listdir(tmp_path))
    assert len(steps) == 2


def test_checkpoint_atomic_no_tmp_left(tmp_path):
    ckpt.save(str(tmp_path), 1, {"a": jnp.zeros(2)})
    assert not any(d.endswith(".tmp") for d in os.listdir(tmp_path))


def test_elastic_restore_reshards(tmp_path):
    """Save from one layout, restore onto a different (virtual) mesh —
    the manifest's mesh is advisory only."""
    state = {"w": jnp.arange(16, dtype=jnp.float32).reshape(4, 4)}
    ckpt.save(str(tmp_path), 3, state)
    template = {"w": jax.ShapeDtypeStruct((4, 4), jnp.float32)}
    restored, _ = ckpt.restore(str(tmp_path), template, shardings=None)
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(state["w"]))


def test_heartbeat(tmp_path):
    hb = Heartbeat(str(tmp_path / "hb.json"))
    hb.beat(10, {"loss": 1.5})
    rec = hb.read()
    assert rec["step"] == 10 and rec["loss"] == 1.5
    assert not hb.is_stale(60.0)
    assert hb.is_stale(-1.0)


def test_straggler_monitor():
    mon = StragglerMonitor(threshold=2.0)
    flags = [mon.record(0.1) for _ in range(10)]
    assert not any(flags)
    assert mon.record(1.0)  # 10x slower than ewma


def test_retry_recovers():
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise OSError("transient")
        return 42

    assert retry(flaky, attempts=5, backoff_s=0.0) == 42


def test_preemption_checkpoint_resume(tmp_path):
    """Simulated preemption: guard flag set mid-run -> checkpoint written
    -> a second trainer resumes from it and finishes."""
    import repro.configs as C
    from repro.train.loop import TrainerConfig, train
    cfg = C.get_smoke("mamba2_130m")
    tc = TrainerConfig(seq_len=32, global_batch=2, steps=10,
                       ckpt_dir=str(tmp_path), ckpt_every=100,
                       log_every=0, peak_lr=1e-3)
    # run 1: stop after 3 steps via a fake guard
    import repro.train.loop as loop_mod

    class FakeGuard:
        def __init__(self):
            self.n = 0

        def install(self):
            return self

        def uninstall(self):
            pass

        @property
        def should_stop(self):
            self.n += 1
            return self.n >= 3

    orig = loop_mod.PreemptionGuard
    loop_mod.PreemptionGuard = FakeGuard
    try:
        res1 = train(cfg, tc)
    finally:
        loop_mod.PreemptionGuard = orig
    assert res1.preempted and res1.final_step < 10
    # run 2: resumes from the checkpoint and completes
    res2 = train(cfg, tc)
    assert res2.final_step == 10 and not res2.preempted


def test_accumulate_gradients_shapes():
    def loss(params, batch):
        return jnp.mean((params["w"] * batch["x"]) ** 2), {}
    params = {"w": jnp.ones(3)}
    batch = {"x": jnp.arange(12.0).reshape(4, 3)}
    (l1, _), g1 = accumulate_gradients(loss, params, batch, 1)
    (l2, _), g2 = accumulate_gradients(loss, params, batch, 2)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(g1["w"]), np.asarray(g2["w"]),
                               rtol=1e-5)
