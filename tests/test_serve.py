"""Serving-launcher coverage: the ``--workload concord`` micro-batching
drain (queue bucketing, tail padding, compiled-program reuse) that was
previously untested, plus the batched-vs-sequential agreement it prints.
"""
import argparse

import numpy as np
import pytest

import repro.estimator as est_mod
from repro.launch.serve import ConcordServeStats, serve_concord


def _args(**overrides) -> argparse.Namespace:
    base = dict(requests=5, batch=2, p=16, n=48, lam2=0.05,
                tol=1e-4, max_iters=60, seed=0)
    base.update(overrides)
    return argparse.Namespace(**base)


@pytest.fixture(scope="module")
def drained():
    """One real drain shared by the cheap asserts below (5 requests in
    micro-batches of 2: two full groups + one padded tail group)."""
    return serve_concord(_args())


def test_serve_concord_returns_all_requests_in_order(drained):
    assert isinstance(drained, ConcordServeStats)
    assert len(drained.reports) == 5
    # per-request penalties survive bucketing + padding in input order
    for rep, lam1 in zip(drained.reports, drained.lam1s):
        assert rep.lam1 == pytest.approx(float(lam1))


def test_serve_concord_pads_tail_group_for_program_reuse(drained):
    """5 requests at batch=2 -> 3 compiled launches, and the tail group is
    PADDED to the same (B, n, p) shape as the full groups — shape equality
    is exactly the compiled-program-reuse precondition (one executable
    serves every group)."""
    assert drained.n_groups == 3
    assert len(set(drained.group_shapes)) == 1
    assert drained.group_shapes[0] == (2, 48, 16)


def test_serve_concord_padding_results_are_dropped(drained):
    """The padding replica of the last request must not leak into the
    drained queue: exactly `requests` reports, and the final report solves
    the final request's lam1 (not a duplicate row)."""
    assert len(drained.reports) == 5
    assert drained.reports[-1].lam1 == pytest.approx(float(drained.lam1s[-1]))


def test_serve_concord_batched_agrees_with_sequential(drained):
    """The drain itself cross-checks every batched estimate against a
    sequential solve of the same request; f32 fixed points scatter ~1e-4
    (project memory), so the gate is loose but meaningful."""
    assert np.isfinite(drained.max_gap)
    assert drained.max_gap < 5e-3


def test_serve_concord_exact_multiple_needs_no_padding():
    """4 requests at batch=2: two groups, no padding anywhere."""
    calls = []
    real = est_mod.fit_batch

    def spy(x=None, **kw):
        calls.append(tuple(np.asarray(x).shape))
        return real(x=x, **kw)

    est_mod.fit_batch = spy
    try:
        stats = serve_concord(_args(requests=4))
    finally:
        est_mod.fit_batch = real
    assert calls == [(2, 48, 16), (2, 48, 16)]
    assert stats.n_groups == 2 and len(stats.reports) == 4


def test_serve_concord_single_request_pads_to_full_batch():
    stats = serve_concord(_args(requests=1, batch=3))
    assert stats.n_groups == 1
    assert stats.group_shapes == [(3, 48, 16)]
    assert len(stats.reports) == 1
