"""repro.analysis: every registered rule must have a tripping fixture.

The AST rules (CA1xx) are tripped on small inline source snippets run
through ``astpass.scan_source`` at contract-relevant fake paths; the
jaxpr rules (CA2xx) are tripped on synthetic manifest entries run through
``jaxprpass.run_entry`` — including a fixture copy of the Gram
panel/finalize path with a deliberately injected f64->f32 cast that CA201
must catch.  The comm rules (CA3xx) are tripped on fixture entries traced
under ``make_jaxpr(axis_env=...)`` with injected schedule defects: a
branch-divergent psum (the SPMD deadlock signature), a non-bijective
ppermute table, an extra all-reduce that breaks the declared byte budget,
redundant collectives, undeclared axes/kinds, and an f64 payload on a
declared-bf16 wire.  A registry test asserts the fixture set and the rule
registry stay in sync, so adding a rule without a fixture fails here.
"""
import json

import pytest

from repro.analysis import (astpass, baseline, cli, commpass, jaxprpass,
                            pallaspass)
from repro.analysis.findings import Finding, sort_findings
from repro.analysis.rules import (DEFAULT_PROFILE, SCRIPTS_PROFILE,
                                  all_rules, get_rule, profile_for_path)

from conftest import REPO

# ---------------------------------------------------------------------------
# tripping fixtures: rule id -> thunk returning the engine's findings
# ---------------------------------------------------------------------------

_TRIPS = {}


def trips(rule_id):
    def mark(fn):
        _TRIPS[rule_id] = fn
        return fn
    return mark


def _ast(relpath, source, profile=DEFAULT_PROFILE):
    return astpass.scan_source(relpath, source, profile)


@trips("CA100")
def _trip_unparseable():
    return _ast("src/repro/core/broken.py", "def f(:\n    pass\n")


@trips("CA101")
def _trip_host_call_in_trace():
    return _ast("src/repro/core/fake.py", """\
import jax
import jax.numpy as jnp

@jax.jit
def objective(x):
    return float(jnp.sum(x * x))
""")


@trips("CA102")
def _trip_python_branch_on_traced():
    return _ast("src/repro/core/fake.py", """\
import jax
import jax.numpy as jnp

@jax.jit
def step(omega):
    if jnp.any(omega > 0):
        return omega
    return -omega
""")


@trips("CA103")
def _trip_mutable_default_at_boundary():
    return _ast("src/repro/core/fake.py", """\
import jax

@jax.jit
def solve(x, history=[]):
    return x
""")


@trips("CA104")
def _trip_narrow_dtype_in_f64_module():
    return _ast("src/repro/core/matops.py", """\
import jax.numpy as jnp

def gramify(x):
    return jnp.asarray(x, jnp.float32)
""")


@trips("CA105")
def _trip_raw_collective_outside_layer():
    return _ast("src/repro/models/fake.py", """\
from jax import lax

def reduce_stats(x):
    return lax.psum(x, "i")
""")


@trips("CA106")
def _trip_host_sync_in_loop():
    return _ast("src/repro/core/fake.py", """\
import jax.numpy as jnp

def trace_path(path_points):
    return [float(jnp.trace(om)) for om in path_points]
""")


# -- jaxpr fixtures ---------------------------------------------------------

def _entry(name, build, *, axis_names=(), reuse=None,
           path="src/repro/data/gram.py"):
    e = {"name": name, "path": path, "axis_names": axis_names,
         "build": build}
    if reuse is not None:
        e["reuse"] = reuse
    return e


@trips("CA200")
def _trip_broken_entry():
    def build():
        raise RuntimeError("representative shapes unavailable")
    return jaxprpass.run_entry(
        _entry("test.broken_build", build), DEFAULT_PROFILE)


def _gram_finalize_downcast_build():
    """Fixture copy of the panel-Gram accumulate + finalize path with a
    deliberately injected narrow cast on the finalized Gram."""
    import jax.numpy as jnp

    def bad_panel_gram_finalize(x):
        n, p = x.shape[0], x.shape[1]
        panel = 2
        out = jnp.zeros((p, p), x.dtype)
        for lo in range(0, p, panel):
            out = out.at[lo:lo + panel].set(x[:, lo:lo + panel].T @ x)
        return (out / n).astype(jnp.float32)    # the injected downcast

    return {"fn": bad_panel_gram_finalize,
            "args": (jnp.linspace(0.0, 1.0, 24,
                                  dtype=jnp.float64).reshape(6, 4),)}


@trips("CA201")
def _trip_f64_downcast():
    return jaxprpass.run_entry(
        _entry("test.gram_finalize_downcast", _gram_finalize_downcast_build),
        DEFAULT_PROFILE)


@trips("CA202")
def _trip_recompile_per_value():
    import jax
    import jax.numpy as jnp
    from functools import partial

    @partial(jax.jit, static_argnames=("lam1",))
    def solve_with_static_penalty(x, lam1):
        return x * lam1                 # lam1 static -> one program per value

    def build():
        return {"fn": lambda x: solve_with_static_penalty(x, lam1=0.1),
                "args": (jnp.ones((3,), jnp.float64),)}

    def reuse():
        x = jnp.ones((3,), jnp.float64)
        return {"watched": {"solve": solve_with_static_penalty},
                "calls": [lambda: solve_with_static_penalty(x, lam1=0.1),
                          lambda: solve_with_static_penalty(x, lam1=0.2),
                          lambda: solve_with_static_penalty(x, lam1=0.3)]}

    return jaxprpass.run_entry(
        _entry("test.static_penalty_recompiles", build, reuse=reuse),
        DEFAULT_PROFILE)


def _undeclared_axis_build():
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.comm.compat import make_mesh, psum, shard_map, use_mesh

    mesh = make_mesh((1,), ("hosts",), devices=jax.devices()[:1])
    fn = shard_map(lambda x: psum(x, "hosts"), mesh=mesh,
                   in_specs=(P("hosts"),), out_specs=P())
    return {"fn": fn, "args": (jnp.zeros((1, 4), jnp.float64),),
            "ctx": lambda: use_mesh(mesh)}


@trips("CA203")
def _trip_undeclared_axis():
    return jaxprpass.run_entry(
        _entry("test.undeclared_axis", _undeclared_axis_build,
               axis_names=()),                  # psums over "hosts" anyway
        DEFAULT_PROFILE)


# -- comm fixtures ----------------------------------------------------------
# CA30x rules trip on fixture entries traced under make_jaxpr(axis_env=...)
# — the same no-devices ring tracing the real comm manifest uses — with
# deliberately injected schedule defects.

def _comm_entry(name, build, *, axis_names=("r",), comm=None, skip=None,
                path="src/repro/comm/matmul1p5d.py"):
    e = {"name": name, "path": path, "axis_names": axis_names,
         "build": build}
    if comm is not None:
        e["comm"] = comm
    if skip is not None:
        e["skip"] = skip
    return e


def _comm_findings(entry):
    findings, _ = commpass.run_entry(entry, DEFAULT_PROFILE)
    return findings


@trips("CA300")
def _trip_broken_comm_entry():
    def build():
        raise RuntimeError("ring shapes unavailable")
    return _comm_findings(_comm_entry("test.broken_comm_build", build))


@trips("CA301")
def _trip_branch_divergent_psum():
    """Injected SPMD deadlock: only one cond branch posts a psum."""
    import jax
    import jax.numpy as jnp

    def build():
        def step(x):
            return jax.lax.cond(
                x[0] > 0,
                lambda v: jax.lax.psum(v, "r"),   # branch 0: all-reduce
                lambda v: v * 2.0,                # branch 1: silence
                x)
        return {"fn": step, "args": (jnp.ones((4,), jnp.float64),),
                "axis_env": (("r", 4),)}

    return _comm_findings(_comm_entry("test.branch_divergent_psum", build))


@trips("CA302")
def _trip_non_bijective_ppermute():
    """Injected broken ring: rank 2 sends out of range, rank 3 is absent."""
    import jax
    import jax.numpy as jnp

    def build():
        def rotate(x):
            return jax.lax.ppermute(x, "r", ((0, 1), (1, 0), (2, 5)))
        return {"fn": rotate, "args": (jnp.ones((3,), jnp.float64),),
                "axis_env": (("r", 4),)}

    return _comm_findings(_comm_entry("test.non_bijective_perm", build))


def _xtx_grid_env():
    from repro.comm.grid import Grid1p5D
    grid = Grid1p5D(8, 2, 2)
    return grid, (("i", grid.n_i), ("j", grid.c_omega), ("k", grid.c_x))


def _xtx_contract():
    from repro.comm.matmul1p5d import COMM_CONTRACT
    return {"contract": COMM_CONTRACT["xtx_local"],
            "params": dict(p=32, n=12, n_devices=8, c_x=2, c_omega=2,
                           dtype="float64")}


@trips("CA303")
def _trip_extra_psum_breaks_volume():
    """Fixture copy of the X^T X ring with an injected extra all-reduce:
    the static byte count must disagree with the declared volume."""
    import jax
    import jax.numpy as jnp
    from repro.comm.matmul1p5d import xtx_local

    def build():
        grid, env = _xtx_grid_env()

        def bad_xtx(x):
            s = xtx_local(x, grid)
            return jax.lax.psum(s, "k")           # the injected collective
        x = jnp.ones((12, 32 // grid.n_x), jnp.float64)
        return {"fn": bad_xtx, "args": (x,), "axis_env": env}

    return _comm_findings(_comm_entry(
        "test.xtx_extra_psum", build, axis_names=("i", "j", "k"),
        comm=lambda: _xtx_contract()))


@trips("CA304")
def _trip_redundant_collectives():
    """psum of an already-psummed value + composable ppermute pair."""
    import jax
    import jax.numpy as jnp

    def build():
        def wasteful(x):
            once = jax.lax.psum(x, "r")
            twice = jax.lax.psum(once, "r")       # already replicated
            ring = ((0, 1), (1, 2), (2, 3), (3, 0))
            hop1 = jax.lax.ppermute(twice, "r", ring)
            hop2 = jax.lax.ppermute(hop1, "r", ring)   # compose the tables
            return hop2
        return {"fn": wasteful, "args": (jnp.ones((4,), jnp.float64),),
                "axis_env": (("r", 4),)}

    return _comm_findings(_comm_entry("test.redundant_collectives", build))


@trips("CA305")
def _trip_undeclared_ring_axis():
    """Schedule touches an axis/kind the COMM_CONTRACT does not declare."""
    import jax
    import jax.numpy as jnp
    from repro.comm.contract import CommContract

    contract = CommContract(entry="test.ring", axes=("r",),
                            kinds=("ppermute",))

    def build():
        def leak(x):
            y = jax.lax.ppermute(x, "r", ((0, 1), (1, 0)))
            return jax.lax.psum(y, "z")           # undeclared axis AND kind
        return {"fn": leak, "args": (jnp.ones((2,), jnp.float64),),
                "axis_env": (("r", 2), ("z", 2))}

    return _comm_findings(_comm_entry(
        "test.undeclared_ring_axis", build, axis_names=("r", "z"),
        comm=lambda: {"contract": contract, "params": {}}))


@trips("CA306")
def _trip_f64_on_compressed_wire():
    """f64 payload through a path whose contract declares a bf16 wire."""
    import jax
    import jax.numpy as jnp
    from repro.comm.contract import CommContract

    contract = CommContract(entry="test.compressed", axes=("r",),
                            kinds=("psum",), wire=("bfloat16",))

    def build():
        def allreduce(x):
            return jax.lax.psum(x, "r")           # ships f64, not bf16
        return {"fn": allreduce, "args": (jnp.ones((8,), jnp.float64),),
                "axis_env": (("r", 4),)}

    return _comm_findings(_comm_entry(
        "test.f64_on_compressed_wire", build,
        comm=lambda: {"contract": contract, "params": {}}))


# -- pallas fixtures --------------------------------------------------------
# CA40x rules trip on fixture KERNEL_ENTRIES-shaped dicts whose layouts
# are built from the REAL blocksparse kernel_layout() with hand-crafted
# prefetch row/col tables — the scatter-style output map is where every
# grid pathology (races, gaps, OOB ids) is easiest to inject honestly.

def _kernel_entry(name, layout, **kw):
    e = {"name": name, "path": "src/repro/kernels/blocksparse_matmul.py",
         "oracle": "blocksparse_matmul", "tolerance": "fp-tolerant",
         "configs": ({"label": "fixture"},), "layout": layout}
    e.update(kw)
    return e


def _pallas_findings(entry):
    findings, _ = pallaspass.run_entry(entry, DEFAULT_PROFILE)
    return findings


def _bsr_fixture_layout(rows, cols, *, p=16, bs=8, m=8, block_n=8,
                        declare_seq=True):
    """The real blocksparse geometry with fixture row/col id tables."""
    import numpy as np

    from repro.kernels import blocksparse_matmul as bsmm
    from repro.kernels.manifest import BlockArg, KernelLayout

    nb = len(rows)
    lay = bsmm.kernel_layout(nb, bs, p, m, block_n=block_n)
    return KernelLayout(
        grid=lay["grid"],
        inputs=(BlockArg("values", (nb, bs, bs), lay["in_specs"][0]),
                BlockArg("b", (p, m), lay["in_specs"][1])),
        outputs=(BlockArg("out", lay["out_shapes"][0], lay["out_specs"]),),
        prefetch=(np.asarray(rows), np.asarray(cols)),
        sequential={0: frozenset({1})} if declare_seq else {},
    )


@trips("CA400")
def _trip_broken_kernel_entry():
    def boom(cfg):
        raise RuntimeError("prefetch tables unavailable")
    return _pallas_findings(_kernel_entry("test.broken_kernel", boom))


@trips("CA401")
def _trip_non_contiguous_row_revisit():
    """Block-row 0 is written at grid steps 0 and 2 with step 1 writing
    row 1 in between: the declared-sequential accumulation is flushed
    and the second visit clobbers it."""
    return _pallas_findings(_kernel_entry(
        "test.row_revisit",
        lambda cfg: _bsr_fixture_layout([0, 1, 0], [0, 1, 1])))


def _trip_undeclared_write_race():
    """Same duplicate scatter ids but with NO sequential declaration:
    plain overlapping writes."""
    return _pallas_findings(_kernel_entry(
        "test.undeclared_race",
        lambda cfg: _bsr_fixture_layout([0, 0], [0, 1], p=8,
                                        declare_seq=False)))


@trips("CA402")
def _trip_output_coverage_gap():
    """Both nnz blocks land in block-row 0 of a 2-block-row output:
    block-row 1 ships whatever was in memory."""
    return _pallas_findings(_kernel_entry(
        "test.coverage_gap",
        lambda cfg: _bsr_fixture_layout([0, 0], [0, 1])))


@trips("CA403")
def _trip_out_of_bounds_block_col():
    """col id 5 indexes past the 2-block-row dense operand."""
    return _pallas_findings(_kernel_entry(
        "test.oob_col",
        lambda cfg: _bsr_fixture_layout([0, 1], [5, 0])))


@trips("CA404")
def _trip_narrow_accumulator_in_f64_kernel():
    import jax.numpy as jnp

    def trace():
        x = jnp.ones((4, 4), jnp.float64)
        return {"fn": lambda v: (v.astype(jnp.float32) @
                                 v.astype(jnp.float32).T
                                 ).astype(jnp.float64),
                "args": (x,)}

    return _pallas_findings(_kernel_entry(
        "test.narrow_accumulator", lambda cfg: _bsr_fixture_layout([0], [0]),
        configs=(), f64_contract=True, trace=trace))


@trips("CA405")
def _trip_missing_oracle_twin():
    return _pallas_findings(_kernel_entry(
        "test.missing_oracle", lambda cfg: _bsr_fixture_layout([0, 1], [0, 1]),
        configs=(), oracle="no_such_oracle", tolerance="vibes"))


@trips("CA406")
def _trip_smem_table_too_short():
    """The SMEM scalar table advertises fewer rows than the grid's lane
    indexing reads."""
    import dataclasses

    from repro.kernels import manifest

    def layout(cfg):
        lay = manifest._softthresh_layout(
            {"m": 32, "n": 32, "block": (16, 16)})
        return dataclasses.replace(lay, scalar_rows={0: 5})

    return _pallas_findings(_kernel_entry(
        "test.smem_short", layout,
        path="src/repro/kernels/softthresh.py",
        oracle="fused_prox_stats", tolerance="bit-exact"))


# ---------------------------------------------------------------------------
# the registry contract: every rule has a fixture, every fixture trips
# ---------------------------------------------------------------------------

def test_every_registered_rule_has_a_tripping_fixture():
    registered = {r.id for r in all_rules()}
    assert registered == set(_TRIPS), (
        f"rule registry and fixtures out of sync: registered "
        f"{sorted(registered)}, fixtures {sorted(_TRIPS)}")


@pytest.mark.parametrize("rule_id", sorted(_TRIPS))
def test_fixture_trips_its_rule(rule_id):
    rule = get_rule(rule_id)
    findings = _TRIPS[rule_id]()
    tripped = {f.rule for f in findings}
    assert rule_id in tripped, (
        f"{rule_id} ({rule.name}) fixture produced {sorted(tripped)}")
    for f in findings:
        assert f.message and f.path     # renderable findings only


def test_ca201_catches_injected_gram_downcast_specifically():
    findings = _TRIPS["CA201"]()
    hits = [f for f in findings if f.rule == "CA201"]
    assert len(hits) == 1
    assert hits[0].context == "test.gram_finalize_downcast"
    assert "f32" in hits[0].snippet or "float32" in hits[0].message


def test_ca202_names_the_watched_program():
    hits = [f for f in _TRIPS["CA202"]() if f.rule == "CA202"]
    assert len(hits) == 1
    assert hits[0].snippet == "solve"
    assert "2 new program" in hits[0].message


def test_ca303_reports_both_byte_counts():
    hits = [f for f in _TRIPS["CA303"]() if f.rule == "CA303"]
    assert len(hits) == 1
    # the injected psum all-reduces the (32, 8) f64 panel over "k"
    # (extent 2): 2*(2-1)/2 * 2048 = 2048 bytes on top of the declared
    # 3328
    assert "5376" in hits[0].message and "3328" in hits[0].message


def test_ca304_flags_both_redundancy_shapes():
    msgs = [f.message for f in _TRIPS["CA304"]() if f.rule == "CA304"]
    assert len(msgs) == 2
    assert any("already" in m for m in msgs)
    assert any("compose" in m for m in msgs)


def test_ca401_distinguishes_race_from_revisit_clobber():
    """The two write-hazard shapes produce distinct diagnoses: duplicate
    scatter ids with no sequential declaration are a RACE; declared but
    non-contiguous duplicates are a flush-then-clobber."""
    revisit = [f for f in _TRIPS["CA401"]() if f.rule == "CA401"]
    assert len(revisit) == 1
    assert "NON-consecutively" in revisit[0].message
    assert "clobbers" in revisit[0].message

    race = [f for f in _trip_undeclared_write_race() if f.rule == "CA401"]
    assert len(race) == 1
    assert "race" in race[0].message
    assert "NOT declare" in race[0].message


def test_ca402_names_the_missing_blocks():
    hits = [f for f in _TRIPS["CA402"]() if f.rule == "CA402"]
    assert len(hits) == 1
    assert "(1, 0)" in hits[0].message       # the unwritten block-row
    assert "stale" in hits[0].message


def test_ca403_reports_the_offending_grid_point():
    hits = [f for f in _TRIPS["CA403"]() if f.rule == "CA403"]
    assert len(hits) == 1
    assert "block index 5" in hits[0].message
    assert "[0, 2)" in hits[0].message


def test_ca405_module_coverage_catches_unregistered_kernels():
    """An empty registry must flag every pallas_call-bearing module."""
    hits = pallaspass.check_module_coverage([])
    flagged = {f.path for f in hits}
    assert "src/repro/kernels/softthresh.py" in flagged
    assert "src/repro/kernels/blocksparse_matmul.py" in flagged
    assert all(f.rule == "CA405" for f in hits)


def test_shipped_kernel_registry_is_clean():
    """The real KERNEL_ENTRIES must pass every CA4xx check — and the
    grid records must cover every registered entry/config."""
    from repro.kernels.manifest import KERNEL_ENTRIES

    findings, records = pallaspass.run_entries(KERNEL_ENTRIES,
                                               DEFAULT_PROFILE)
    assert findings == []
    assert [r["entry"] for r in records] == [e["name"]
                                             for e in KERNEL_ENTRIES]
    for rec, entry in zip(records, KERNEL_ENTRIES):
        assert [c["config"] for c in rec["configs"]] == \
            [c["label"] for c in entry["configs"]]
        assert all(c["points"] >= 1 for c in rec["configs"])


# ---------------------------------------------------------------------------
# negatives: the rules must NOT fire on the blessed idioms
# ---------------------------------------------------------------------------

def test_static_shape_reads_are_not_syncs():
    findings = _ast("src/repro/core/fake.py", """\
import numpy as np

def total_rows(chunks):
    return sum(int(np.asarray(c).shape[0]) for c in chunks)
""")
    assert findings == []


def test_module_dtype_policy_constant_is_exempt():
    findings = _ast("src/repro/core/matops.py", """\
import jax.numpy as jnp

DENSITY_DTYPE = jnp.float32
""")
    assert findings == []


def test_inline_allow_comment_suppresses():
    src = ("import jax.numpy as jnp\n\n"
           "def f(x):\n"
           "    return jnp.asarray(x, jnp.float32)  # ca: allow=CA104\n")
    assert _ast("src/repro/core/matops.py", src) == []


def test_compat_psum_is_not_flagged():
    findings = _ast("src/repro/models/fake.py", """\
from repro.comm.compat import psum

def reduce_stats(x):
    return psum(x, "i")
""")
    assert findings == []


def test_scripts_profile_relaxes_host_rules_keeps_layer_rules():
    host_src = """\
import jax.numpy as jnp

def bench(path_points):
    return [float(jnp.trace(om)) for om in path_points]
"""
    assert profile_for_path("benchmarks/bench_solver.py") is SCRIPTS_PROFILE
    assert _ast("benchmarks/bench_solver.py", host_src,
                SCRIPTS_PROFILE) == []
    collective_src = """\
from jax import lax

def bench(x):
    return lax.psum(x, "i")
"""
    hits = _ast("benchmarks/bench_solver.py", collective_src,
                SCRIPTS_PROFILE)
    assert {f.rule for f in hits} == {"CA105"}


def test_blessed_stagger_and_shift_rings_are_clean():
    """The real stagger + per-round-shift + team-finish idiom must not
    trip any CA30x rule, and its exact byte accounting must hold."""
    from repro.comm.matmul1p5d import ANALYSIS_ENTRIES

    for entry in ANALYSIS_ENTRIES:
        findings, record = commpass.run_entry(entry, DEFAULT_PROFILE)
        assert findings == [], (entry["name"], findings)
        assert record["static_bytes"] is not None
        assert record["static_bytes"] == record["contract"]["expected_bytes"]


def test_identity_stagger_counts_zero_bytes():
    """At c_x = c_omega = 1 the stagger/shift tables still appear in the
    jaxpr but (identity staggers) must cost nothing the analytic side
    doesn't also count — the schedules stay exactly accountable."""
    import jax.numpy as jnp
    from repro.comm.grid import Grid1p5D
    from repro.comm.matmul1p5d import COMM_CONTRACT, xtx_local

    grid = Grid1p5D(4, 1, 1)
    env = (("i", 4), ("j", 1), ("k", 1))

    def build():        # arrays under enable_x64, like the real manifest
        x = jnp.ones((6, 16 // grid.n_x), jnp.float64)
        return {"fn": lambda a: xtx_local(a, grid), "args": (x,),
                "axis_env": env}

    entry = _comm_entry(
        "test.xtx_c1", build,
        axis_names=("i", "j", "k"),
        comm=lambda: {"contract": COMM_CONTRACT["xtx_local"],
                      "params": dict(p=16, n=6, n_devices=4, c_x=1,
                                     c_omega=1, dtype="float64")})
    findings, record = commpass.run_entry(entry, DEFAULT_PROFILE)
    assert findings == []
    assert record["static_bytes"] == record["contract"]["expected_bytes"]


def test_reuse_at_stable_statics_is_clean():
    import jax
    import jax.numpy as jnp

    @jax.jit
    def solve(x, lam1):
        return x * lam1

    def build():
        return {"fn": lambda x: solve(x, jnp.asarray(0.1, x.dtype)),
                "args": (jnp.ones((3,), jnp.float64),)}

    def reuse():
        x = jnp.ones((3,), jnp.float64)
        return {"watched": {"solve": solve},
                "calls": [lambda: solve(x, 0.1), lambda: solve(x, 0.2),
                          lambda: solve(x, 0.3)]}

    findings = jaxprpass.run_entry(
        _entry("test.traced_penalty_reuses", build, reuse=reuse),
        DEFAULT_PROFILE)
    assert findings == []


# ---------------------------------------------------------------------------
# the repo itself scans clean (AST engine; the jaxpr engine runs in CI)
# ---------------------------------------------------------------------------

def test_repo_src_scans_clean_with_empty_baseline(capsys):
    rc = cli.main(["--engine", "ast", "--root", REPO])
    out = capsys.readouterr().out
    assert rc == 0, f"analyzer found regressions:\n{out}"
    assert "0 findings" in out


def test_checked_in_baseline_is_empty():
    path = f"{REPO}/analysis_baseline.json"
    assert json.loads(open(path, encoding="utf-8").read()) == []


def test_manifest_loads_unique_entries():
    from repro.analysis.manifest import load_entries
    entries = load_entries()
    names = [e["name"] for e in entries]
    assert len(names) == len(set(names))
    assert len(entries) >= 8
    for e in entries:
        assert callable(e["build"]) and e["path"].startswith("src/repro/")


# ---------------------------------------------------------------------------
# CLI and baseline mechanics
# ---------------------------------------------------------------------------

def test_cli_list_rules(capsys):
    assert cli.main(["--list-rules"]) == 0
    out = capsys.readouterr().out
    for r in all_rules():
        assert r.id in out


def _dirty_tree(tmp_path):
    pkg = tmp_path / "src" / "repro" / "core"
    pkg.mkdir(parents=True)
    (pkg / "matops.py").write_text(
        "import jax.numpy as jnp\n\n"
        "def gramify(x):\n"
        "    return jnp.asarray(x, jnp.float32)\n", encoding="utf-8")
    return tmp_path


def test_cli_json_report_and_exit_code(tmp_path, capsys):
    root = _dirty_tree(tmp_path)
    report = tmp_path / "out" / "report.json"
    rc = cli.main(["src", "--engine", "ast", "--root", str(root),
                   "--format", "json", "--output", str(report)])
    assert rc == 1
    data = json.loads(report.read_text(encoding="utf-8"))
    assert data["counts"]["findings"] == 1
    (finding,) = data["findings"]
    assert finding["rule"] == "CA104"
    assert finding["path"] == "src/repro/core/matops.py"
    assert json.loads(capsys.readouterr().out) == data


def test_cli_baseline_roundtrip_suppresses_then_goes_stale(tmp_path, capsys):
    root = _dirty_tree(tmp_path)
    argv = ["src", "--engine", "ast", "--root", str(root)]
    # 1. land the analyzer: park the pre-existing finding in the baseline
    assert cli.main(argv + ["--write-baseline"]) == 0
    capsys.readouterr()
    assert cli.main(argv) == 0
    assert "1 baseline-suppressed" in capsys.readouterr().out
    # 2. fix the finding: the parked fingerprint must go STALE and gate
    (root / "src" / "repro" / "core" / "matops.py").write_text(
        "import jax.numpy as jnp\n\nGRAM_DTYPE = jnp.float32\n",
        encoding="utf-8")
    assert cli.main(argv) == 1
    assert "stale baseline" in capsys.readouterr().out
    # 3. regenerate: empty baseline, clean exit
    assert cli.main(argv + ["--write-baseline"]) == 0
    capsys.readouterr()
    assert json.loads(
        (root / "analysis_baseline.json").read_text(encoding="utf-8")) == []
    assert cli.main(argv) == 0


def test_cli_changed_mode_scans_only_touched_files(tmp_path, capsys):
    """--changed restricts the AST engine to `git diff --name-only BASE`
    files: a pre-existing finding in an untouched file is invisible, one
    in a touched file gates; stale-baseline gating is off (a partial
    scan cannot adjudicate staleness)."""
    import subprocess

    root = _dirty_tree(tmp_path)
    # a second f64-contract module, clean at commit time
    clean = root / "src" / "repro" / "core" / "objective.py"
    clean.write_text("X = 1\n", encoding="utf-8")
    # a tracked file OUTSIDE the scan targets (tests/ fixture code trips
    # rules on purpose and must never enter a --changed scan)
    fixture = root / "tests" / "test_fixture.py"
    fixture.parent.mkdir(exist_ok=True)
    fixture.write_text("Y = 1\n", encoding="utf-8")

    def git(*argv):
        subprocess.run(["git", *argv], cwd=root, check=True,
                       capture_output=True)

    git("init", "-q")
    git("-c", "user.email=t@t", "-c", "user.name=t", "commit", "-q",
        "--allow-empty", "-m", "root")
    git("add", "-A")
    git("-c", "user.email=t@t", "-c", "user.name=t", "commit", "-q",
        "-m", "seed")        # dirty matops.py is now committed (pre-existing)

    argv = ["src", "--engine", "ast", "--root", str(root)]
    # untouched tree: nothing changed since HEAD -> nothing scanned
    assert cli.main(argv + ["--changed"]) == 0
    capsys.readouterr()
    # touch a tracked file so it now has a finding: only it is scanned
    # (git diff semantics: untracked files are not "changed" — stage them)
    clean.write_text("import jax.numpy as jnp\n\n"
                     "def f(x):\n"
                     "    return jnp.asarray(x, jnp.float32)\n",
                     encoding="utf-8")
    # changed-but-out-of-target fixture code stays invisible
    fixture.write_text("import numpy as np\n"
                       "import jax\n\n"
                       "@jax.jit\n"
                       "def g(x):\n"
                       "    return np.float64(x)\n", encoding="utf-8")
    assert cli.main(argv + ["--changed", "HEAD"]) == 1
    out = capsys.readouterr().out
    assert "objective.py" in out and "matops.py" not in out
    assert "test_fixture.py" not in out
    # full scan still sees the pre-existing finding too
    assert cli.main(argv) == 1
    assert "matops.py" in capsys.readouterr().out


def test_changed_mode_subsets_kernel_entries():
    """--changed scoping of the pallas registry: a changed kernel module
    keeps only its entry, a non-kernel file keeps none, and a shared
    kernel file (manifest/ops/ref) keeps the whole registry."""
    from repro.kernels.manifest import KERNEL_ENTRIES

    only = cli.subset_kernel_entries(
        KERNEL_ENTRIES, {"src/repro/kernels/flash_attention.py"})
    assert [e["name"] for e in only] == \
        ["kernels.flash_attention.flash_attention"]
    assert cli.subset_kernel_entries(
        KERNEL_ENTRIES, {"src/repro/core/prox.py"}) == []
    assert cli.subset_kernel_entries(
        KERNEL_ENTRIES, {"src/repro/kernels/ref.py"}) == \
        list(KERNEL_ENTRIES)


def test_cli_json_report_includes_kernel_grids(tmp_path, capsys):
    """--engine pallas emits the per-config grid records CI uploads."""
    report = tmp_path / "pallas.json"
    rc = cli.main(["--engine", "pallas", "--root", REPO, "--format",
                   "json", "--output", str(report)])
    assert rc == 0
    capsys.readouterr()
    data = json.loads(report.read_text(encoding="utf-8"))
    assert data["counts"]["findings"] == 0
    grids = {r["entry"]: r for r in data["kernel_grids"]}
    soft = grids["kernels.softthresh.fused_prox_stats"]
    assert soft["tolerance"] == "bit-exact"
    labels = {c["config"] for c in soft["configs"]}
    assert {"aligned", "edge-tile", "prime-p"} <= labels


def test_cli_json_report_includes_comm_schedules(tmp_path, capsys):
    """--engine comm emits the schedule traces + volume table CI uploads."""
    report = tmp_path / "comm.json"
    rc = cli.main(["--engine", "comm", "--root", REPO, "--format", "json",
                   "--output", str(report)])
    assert rc == 0
    capsys.readouterr()
    data = json.loads(report.read_text(encoding="utf-8"))
    assert data["counts"]["findings"] == 0
    schedules = {r["entry"]: r for r in data["comm_schedules"]}
    ring = schedules["comm.matmul1p5d.xtx_ring"]
    assert ring["static_bytes"] == ring["contract"]["expected_bytes"]
    assert any(e["prim"] == "ppermute" for e in ring["events"])


def test_findings_sort_and_fingerprint_ignore_line():
    a = Finding("CA104", "src/x.py", 10, "m", context="f", snippet="s")
    b = Finding("CA104", "src/x.py", 99, "m", context="f", snippet="s")
    assert a.fingerprint() == b.fingerprint()
    assert sort_findings([b, a]) == [a, b]
    new, suppressed, stale = baseline.split_by_baseline(
        [a, b], [a.fingerprint()])
    assert new == [] and suppressed == [a, b] and stale == []
