"""Multi-device tests (subprocess with virtual devices): 1.5D matmuls,
replication-aware transposes, distributed HP-CONCORD vs reference, and
the compressed collectives."""
import pytest

from conftest import run_with_devices


@pytest.mark.slow
def test_1p5d_matmuls_all_replications():
    run_with_devices("""
import numpy as np, jax, jax.numpy as jnp
from repro.comm.grid import Grid1p5D
from repro.comm import matmul1p5d as mm
from repro.comm.compat import use_mesh
P = 16
rng = np.random.default_rng(0)
for (cx, co) in [(1,1),(2,2),(4,2),(2,4),(4,4),(8,2),(16,1),(1,16)]:
    g = Grid1p5D(P, cx, co)
    mesh = g.make_mesh()
    p = g.pad_p(48); n = 8
    x = rng.standard_normal((n, p)).astype(np.float32)
    om = rng.standard_normal((p, p)).astype(np.float32)
    with use_mesh(mesh):
        s = mm.xtx(jnp.asarray(x), g, mesh, scale=1.0/n)
        np.testing.assert_allclose(np.asarray(s), x.T@x/n, rtol=1e-4, atol=1e-4)
        w = mm.omega_s(jnp.asarray(om), s, g, mesh)
        np.testing.assert_allclose(np.asarray(w), om@(x.T@x/n), rtol=1e-3, atol=1e-3)
        y = mm.omega_xt(jnp.asarray(om), jnp.asarray(x), g, mesh)
        np.testing.assert_allclose(np.asarray(y), om@x.T, rtol=1e-3, atol=1e-3)
        z = mm.y_x(y, jnp.asarray(x), g, mesh, scale=1.0/n)
        np.testing.assert_allclose(np.asarray(z), om@x.T@x/n, rtol=1e-3, atol=1e-3)
        wt = mm.transpose_xlike(w, g, mesh)
        np.testing.assert_allclose(np.asarray(wt), np.asarray(w).T, rtol=1e-5, atol=1e-5)
        zt = mm.transpose_omegalike(z, g, mesh)
        np.testing.assert_allclose(np.asarray(zt), np.asarray(z).T, rtol=1e-5, atol=1e-5)
print("OK")
""", n_devices=16)


@pytest.mark.slow
def test_distributed_cov_obs_match_reference():
    run_with_devices("""
import numpy as np, jax, jax.numpy as jnp
from repro.core import graphs
from repro.core.prox import fit_reference
from repro.core.distributed import fit_cov, fit_obs
from repro.comm.grid import Grid1p5D
prob = graphs.make_problem("chain", p=50, n=120, seed=0)
ref = fit_reference(jnp.asarray(prob.s), 0.15, 0.05, tol=1e-6, max_iters=200)
for cx, co in [(1,1),(2,2)]:
    g = Grid1p5D(8, cx, co)
    r = fit_cov(jnp.asarray(prob.s), 0.15, 0.05, grid=g, tol=1e-6, max_iters=200)
    assert abs(float(r.g_final) - float(ref.g_final)) < 1e-2
    assert np.abs(np.asarray(r.omega)-np.asarray(ref.omega)).max() < 5e-3
refo = fit_reference(jnp.asarray(prob.x), 0.15, 0.05, variant="obs", tol=1e-6, max_iters=200)
for cx, co in [(1,1),(4,2),(1,8)]:
    g = Grid1p5D(8, cx, co)
    r = fit_obs(jnp.asarray(prob.x), 0.15, 0.05, grid=g, tol=1e-6, max_iters=200)
    assert np.abs(np.asarray(r.omega)-np.asarray(refo.omega)).max() < 5e-3
print("OK")
""", n_devices=8)


@pytest.mark.slow
def test_estimator_front_door_auto_tunes():
    run_with_devices("""
import numpy as np, jax, jax.numpy as jnp
from repro.core import graphs, distributed
prob = graphs.make_problem("chain", p=40, n=300, seed=1)
res = distributed.fit(x=jnp.asarray(prob.x), lam1=0.15, lam2=0.05,
                      tol=1e-5, max_iters=200)
assert res.variant in ("cov", "obs")
ppv, fdr = graphs.ppv_fdr(np.asarray(res.omega), prob.omega0)
assert ppv > 0.5
print("OK", res.variant, ppv)
""", n_devices=8)


@pytest.mark.slow
def test_compressed_collectives():
    run_with_devices("""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.comm.collectives import (compressed_psum, ring_allreduce_int8,
                                    init_error_feedback)
from repro.comm.compat import make_mesh, shard_map, use_mesh
mesh = make_mesh((8,), ("d",))
rng = np.random.default_rng(0)
x = rng.standard_normal((8, 64)).astype(np.float32)

def f(xs):
    out, _ = compressed_psum({"g": xs}, "d", method="bf16")
    return out["g"]
with use_mesh(mesh):
    y = shard_map(f, mesh=mesh, in_specs=P("d"), out_specs=P("d"), check_vma=False)(jnp.asarray(x))
expected = x.sum(axis=0, keepdims=True).repeat(8, 0)
assert np.abs(np.asarray(y) - expected).max() / np.abs(expected).max() < 2e-2

def g(xs):
    return ring_allreduce_int8(xs[0], "d")[None]
with use_mesh(mesh):
    y2 = shard_map(g, mesh=mesh, in_specs=P("d"), out_specs=P("d"), check_vma=False)(jnp.asarray(x))
# each of the 2(n-1) ring hops requantizes: error ~ n/127
rel = np.abs(np.asarray(y2) - expected).max() / np.abs(expected).max()
assert rel < 0.15, rel
print("OK")
""", n_devices=8)


def test_error_feedback_unbiased_over_time():
    """int8 + error feedback: accumulated quantized sum converges to the
    true sum (the residual carries what quantization dropped)."""
    import numpy as np
    import jax.numpy as jnp
    from repro.comm.collectives import compress_tree, decompress_tree, \
        init_error_feedback
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.standard_normal(256).astype(np.float32))}
    state = init_error_feedback(g)
    acc_q = np.zeros(256, np.float32)
    for _ in range(50):
        payload, state = compress_tree(g, state, method="int8")
        acc_q += np.asarray(decompress_tree(payload, method="int8")["w"])
    acc_true = np.asarray(g["w"]) * 50
    rel = np.abs(acc_q - acc_true).max() / np.abs(acc_true).max()
    assert rel < 0.02, rel
