"""Mamba2 SSD: chunked scan == naive recurrence, continuation, decode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal CPU image — deterministic fallback
    from _hypothesis_fallback import given, settings, st

from repro.models.ssm import ssd_chunked, ssd_recurrent_ref


def _inputs(rng, B=2, L=64, nh=4, hp=8, g=2, N=16):
    x = rng.standard_normal((B, L, nh, hp)).astype(np.float32) * 0.5
    dt = np.abs(rng.standard_normal((B, L, nh))).astype(np.float32) * 0.1
    a = -np.abs(rng.standard_normal(nh)).astype(np.float32)
    b = rng.standard_normal((B, L, g, N)).astype(np.float32) * 0.3
    c = rng.standard_normal((B, L, g, N)).astype(np.float32) * 0.3
    return tuple(map(jnp.asarray, (x, dt, a, b, c)))


@pytest.mark.parametrize("chunk", [8, 16, 32, 64])
def test_chunked_equals_recurrent(chunk, rng):
    x, dt, a, b, c = _inputs(rng)
    yc, hc = ssd_chunked(x, dt, a, b, c, chunk=chunk)
    yr, hr = ssd_recurrent_ref(x, dt, a, b, c)
    np.testing.assert_allclose(np.asarray(yc), np.asarray(yr),
                               rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(np.asarray(hc), np.asarray(hr),
                               rtol=1e-3, atol=1e-4)


def test_state_continuation(rng):
    """Splitting the sequence and carrying h0 must be exact — this is the
    chunked-prefill/decode handoff invariant."""
    x, dt, a, b, c = _inputs(rng, L=64)
    yr, hr = ssd_recurrent_ref(x, dt, a, b, c)
    y1, h1 = ssd_chunked(x[:, :32], dt[:, :32], a, b[:, :32], c[:, :32],
                         chunk=16)
    y2, h2 = ssd_chunked(x[:, 32:], dt[:, 32:], a, b[:, 32:], c[:, 32:],
                         chunk=16, h0=h1)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate([y1, y2], 1)), np.asarray(yr),
        rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(hr),
                               rtol=1e-3, atol=1e-4)


def test_single_step_decode_matches(rng):
    """One-token recurrence (decode path) == last step of full scan."""
    x, dt, a, b, c = _inputs(rng, L=16)
    yr, hr = ssd_recurrent_ref(x, dt, a, b, c)
    _, h_prefix = ssd_recurrent_ref(x[:, :15], dt[:, :15], a,
                                    b[:, :15], c[:, :15])
    y1, h1 = ssd_recurrent_ref(x[:, 15:], dt[:, 15:], a, b[:, 15:],
                               c[:, 15:], h0=h_prefix)
    np.testing.assert_allclose(np.asarray(y1[:, 0]), np.asarray(yr[:, -1]),
                               rtol=1e-4, atol=1e-5)


@given(st.integers(0, 10))
@settings(max_examples=5, deadline=None)
def test_decay_bounded(seed):
    """With negative A and bounded inputs, the state norm stays bounded
    (stability of the SSD recurrence)."""
    rng = np.random.default_rng(seed)
    x, dt, a, b, c = _inputs(rng, L=128)
    _, h = ssd_chunked(x, dt, a, b, c, chunk=32)
    assert np.isfinite(np.asarray(h)).all()
    assert np.abs(np.asarray(h)).max() < 1e3


def test_mamba2_block_decode_equals_batch(rng):
    """Full mamba2 block: running L tokens at once == running them one
    at a time through the cache (decode semantics)."""
    import repro.configs as C
    from repro.models import ssm as S
    from repro.models import transformer as T
    cfg = C.get_smoke("mamba2_130m")
    params = T.init_params(cfg, jax.random.PRNGKey(0), max_len=32)
    p0 = jax.tree.map(lambda a: a[0], params["blocks"])  # first layer
    B, L, d = 2, 16, cfg.d_model
    x = jnp.asarray(rng.standard_normal((B, L, d)), jnp.float32) * 0.2

    full, _ = S.mamba2_block(cfg, p0, x)
    shp = S.ssm_cache_shape(cfg, B)
    cache = {"conv": jnp.zeros(shp["conv"], jnp.float32),
             "h": jnp.zeros(shp["h"], jnp.float32)}
    outs = []
    for t in range(L):
        o, cache = S.mamba2_block(cfg, p0, x[:, t:t + 1], cache=cache)
        outs.append(o)
    step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(step), np.asarray(full),
                               rtol=2e-2, atol=2e-3)
