"""Composable penalty API (core.penalty): closed-form prox identities,
bit-exact l1 compatibility across all three backends, per-lane penalty
params in one batched program, validation, and the two-stage adaptive
refit."""
import contextlib

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import batch, graphs
from repro.core.penalty import (
    PenaltySpec,
    adaptive_weights,
    as_penalty,
    parse_penalty,
    penalty_value_np,
)
from repro.core.prox import solve_reference


@contextlib.contextmanager
def x64():
    prev = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", True)
    try:
        yield
    finally:
        jax.config.update("jax_enable_x64", prev)


@pytest.fixture(scope="module")
def chain_problem():
    return graphs.make_problem("chain", p=48, n=150, seed=1)


# ---------------------------------------------------------------------------
# closed-form prox identities (f64, 1e-12)
# ---------------------------------------------------------------------------

def _z_grid(lam, hi):
    # dense sweep crossing every regime boundary, both signs
    pts = np.linspace(-hi, hi, 401)
    return np.concatenate([pts, [-lam, lam, 0.0]])


def test_l1_prox_matches_soft_threshold_f64():
    with x64():
        lam, tau = 0.3, 0.6
        z = jnp.asarray(_z_grid(lam, 3.0))
        spec = PenaltySpec.l1(lam)
        out = np.asarray(spec.prox(z[None, :], tau,
                                   diag_mask=jnp.zeros((1, z.size))))[0]
        expect = np.sign(z) * np.maximum(np.abs(z) - tau * lam, 0.0)
        np.testing.assert_allclose(out, expect, rtol=0, atol=1e-12)


def test_scad_prox_three_regime_closed_form_f64():
    with x64():
        lam, a, tau = 0.4, 3.7, 0.8
        z = np.asarray(_z_grid(lam, 4.0))
        spec = PenaltySpec.scad(lam, a)
        out = np.asarray(spec.prox(jnp.asarray(z)[None, :], tau,
                                   diag_mask=jnp.zeros((1, z.size))))[0]
        az = np.abs(z)
        r1 = np.sign(z) * np.maximum(az - tau * lam, 0.0)
        r2 = ((a - 1.0) * z - np.sign(z) * tau * a * lam) / (a - 1.0 - tau)
        expect = np.where(az <= (1.0 + tau) * lam, r1,
                          np.where(az <= a * lam, r2, z))
        np.testing.assert_allclose(out, expect, rtol=0, atol=1e-12)
        # the three-regime map is continuous at both boundaries
        for b in [(1.0 + tau) * lam, a * lam]:
            lo = np.asarray(spec.prox(jnp.asarray([[b - 1e-9]]), tau,
                                      diag_mask=jnp.zeros((1, 1)))).item()
            hi = np.asarray(spec.prox(jnp.asarray([[b + 1e-9]]), tau,
                                      diag_mask=jnp.zeros((1, 1)))).item()
            assert abs(lo - hi) < 1e-6


def test_scad_prox_solves_the_scalar_subproblem_f64():
    """prox_{tau*SCAD}(z) must beat a dense grid of alternatives on the
    scalar objective (x - z)^2/(2 tau) + SCAD(x)."""
    with x64():
        lam, a, tau = 0.4, 3.7, 0.8
        spec = PenaltySpec.scad(lam, a)

        def scad_val(x):
            ax = np.abs(x)
            quad = (2 * a * lam * ax - ax ** 2 - lam ** 2) / (2 * (a - 1))
            tail = 0.5 * lam * lam * (a + 1)
            return np.where(ax <= lam, lam * ax,
                            np.where(ax <= a * lam, quad, tail))

        xs = np.linspace(-4.0, 4.0, 40001)
        for z in [-3.0, -1.1, -0.5, 0.2, 0.9, 1.3, 2.5]:
            got = np.asarray(spec.prox(
                jnp.asarray([[z]]), tau,
                diag_mask=jnp.zeros((1, 1)))).item()
            obj = (xs - z) ** 2 / (2 * tau) + scad_val(xs)
            got_obj = (got - z) ** 2 / (2 * tau) + float(scad_val(got))
            assert got_obj <= obj.min() + 1e-6, (z, got, xs[obj.argmin()])


def test_mcp_prox_closed_form_and_subproblem_f64():
    with x64():
        lam, gamma, tau = 0.35, 2.5, 0.7
        z = np.asarray(_z_grid(lam, 3.0))
        spec = PenaltySpec.mcp(lam, gamma)
        out = np.asarray(spec.prox(jnp.asarray(z)[None, :], tau,
                                   diag_mask=jnp.zeros((1, z.size))))[0]
        az = np.abs(z)
        st = np.sign(z) * np.maximum(az - tau * lam, 0.0)
        expect = np.where(az <= gamma * lam, (gamma / (gamma - tau)) * st, z)
        np.testing.assert_allclose(out, expect, rtol=0, atol=1e-12)

        def mcp_val(x):
            ax = np.abs(x)
            return np.where(ax <= gamma * lam,
                            lam * ax - ax ** 2 / (2 * gamma),
                            0.5 * gamma * lam * lam)

        xs = np.linspace(-4.0, 4.0, 40001)
        for zz in [-2.0, -0.9, 0.3, 0.8, 1.5]:
            got = np.asarray(spec.prox(
                jnp.asarray([[zz]]), tau,
                diag_mask=jnp.zeros((1, 1)))).item()
            obj = (xs - zz) ** 2 / (2 * tau) + mcp_val(xs)
            got_obj = (got - zz) ** 2 / (2 * tau) + float(mcp_val(got))
            assert got_obj <= obj.min() + 1e-6


def test_weighted_prox_masks_f64():
    """w=0 leaves entries untouched, w=inf zeroes them exactly, finite
    weights scale the threshold; the diagonal passes through."""
    with x64():
        z = jnp.asarray(np.array([[1.0, 0.5, -0.2], [0.5, 2.0, 0.05],
                                  [-0.2, 0.05, 3.0]]))
        w = np.array([[0.0, 0.0, np.inf], [0.0, 0.0, 2.0],
                      [np.inf, 2.0, 0.0]])
        spec = PenaltySpec.weighted_l1(0.1, w)
        out = np.asarray(spec.prox(z, 1.0))
        assert out[0, 1] == 0.5                   # w=0: unpenalized
        assert out[0, 2] == 0.0 and out[2, 0] == 0.0   # w=inf: exact zero
        np.testing.assert_allclose(out[1, 2], 0.0)     # |0.05| < 0.1*2
        np.testing.assert_allclose(np.diag(out), np.diag(np.asarray(z)))
        # inf weights force zeros even at zero strength (no nan leak)
        out0 = np.asarray(spec.with_lam1(0.0).prox(z, 1.0))
        assert out0[0, 2] == 0.0 and np.isfinite(out0).all()


def test_penalty_value_closed_forms_f64():
    with x64():
        om = jnp.asarray(np.array([[2.0, 0.3, 0.0], [0.3, 1.0, -1.5],
                                   [0.0, -1.5, 1.0]]))
        l1 = PenaltySpec.l1(0.2)
        np.testing.assert_allclose(float(l1.value(om)), 0.2 * 2 * 1.8,
                                   atol=1e-12)
        assert penalty_value_np(l1, np.asarray(om)) == pytest.approx(
            float(l1.value(om)), abs=1e-12)
        w = np.full((3, 3), 2.0)
        np.fill_diagonal(w, 0.0)
        w[0, 2] = w[2, 0] = np.inf
        wl = PenaltySpec.weighted_l1(0.2, w)
        # omega is 0 where w is inf -> finite value, inf otherwise
        assert np.isfinite(float(wl.value(om)))
        np.testing.assert_allclose(float(wl.value(om)), 0.2 * 2 * 2.0 * 1.8,
                                   atol=1e-12)
        scad = PenaltySpec.scad(0.4, 3.7)
        mcp = PenaltySpec.mcp(0.4, 3.0)
        for spec in (scad, mcp):
            assert penalty_value_np(spec, np.asarray(om)) == pytest.approx(
                float(spec.value(om)), abs=1e-10)


# ---------------------------------------------------------------------------
# l1 spec is bit-exact against the legacy scalar-lam1 plumbing
# ---------------------------------------------------------------------------

def test_l1_spec_bit_exact_reference_f64(chain_problem):
    with x64():
        s = jnp.asarray(chain_problem.s, jnp.float64)
        legacy = solve_reference(s, 0.2, 0.05, tol=1e-7, max_iters=400)
        spec = solve_reference(s, penalty=PenaltySpec.l1(0.2, 0.05),
                               tol=1e-7, max_iters=400)
        en = solve_reference(s, penalty=PenaltySpec.elastic_net(0.2, 0.05),
                             tol=1e-7, max_iters=400)
        for r in (spec, en):
            np.testing.assert_array_equal(np.asarray(legacy.omega),
                                          np.asarray(r.omega))
            assert int(legacy.iters) == int(r.iters)
            assert int(legacy.ls_total) == int(r.ls_total)
            assert float(legacy.g_final) == float(r.g_final)


def test_l1_spec_bit_exact_distributed(chain_problem):
    from repro.comm.grid import Grid1p5D
    from repro.core.distributed import fit_cov, fit_obs

    g = Grid1p5D(1, 1, 1)
    s = jnp.asarray(chain_problem.s)
    legacy = fit_cov(s, 0.2, 0.05, grid=g, tol=1e-6, max_iters=200)
    spec = fit_cov(s, penalty=PenaltySpec.l1(0.2, 0.05), grid=g,
                   tol=1e-6, max_iters=200)
    np.testing.assert_array_equal(np.asarray(legacy.omega),
                                  np.asarray(spec.omega))
    assert int(legacy.iters) == int(spec.iters)
    x = jnp.asarray(chain_problem.x)
    legacy_o = fit_obs(x, 0.2, 0.05, grid=g, tol=1e-6, max_iters=200)
    spec_o = fit_obs(x, penalty=PenaltySpec.l1(0.2, 0.05), grid=g,
                     tol=1e-6, max_iters=200)
    np.testing.assert_array_equal(np.asarray(legacy_o.omega),
                                  np.asarray(spec_o.omega))


def test_l1_spec_bit_exact_batched(chain_problem):
    s = jnp.asarray(chain_problem.s)
    # the lam grid must ride in the data dtype (an f64 grid against f32
    # data trips the while_loop carry check — pre-existing solver contract)
    grid = jnp.asarray([0.3, 0.2, 0.15], s.dtype)
    legacy = batch.solve_path_batched(s, grid, 0.05, variant="cov", tol=1e-6)
    spec = batch.solve_path_batched(s, grid, penalty=PenaltySpec("l1", 0.0,
                                                                 0.05),
                                    variant="cov", tol=1e-6)
    np.testing.assert_array_equal(np.asarray(legacy.omega),
                                  np.asarray(spec.omega))
    np.testing.assert_array_equal(np.asarray(legacy.iters),
                                  np.asarray(spec.iters))


def test_l1_spec_bit_exact_fit_report(chain_problem):
    """FitReport fields (objective, iters, ls, density columns) identical
    between the legacy kwargs and the equivalent spec."""
    from repro.estimator import ConcordEstimator, SolverConfig

    cfg = SolverConfig(backend="reference", variant="cov", tol=1e-6,
                       max_iters=300)
    s = jnp.asarray(chain_problem.s)
    a = ConcordEstimator(lam1=0.2, lam2=0.05, config=cfg).fit_cov(
        s, n_samples=150).report_
    b = ConcordEstimator(penalty=PenaltySpec.l1(0.2, 0.05),
                         config=cfg).fit_cov(s, n_samples=150).report_
    np.testing.assert_array_equal(np.asarray(a.omega), np.asarray(b.omega))
    assert (a.iters, a.ls_total, a.objective, a.objective_smooth,
            a.nnz_per_row, a.block_density, a.converged) == \
           (b.iters, b.ls_total, b.objective, b.objective_smooth,
            b.nnz_per_row, b.block_density, b.converged)
    assert a.penalty == b.penalty == "l1"


# ---------------------------------------------------------------------------
# one compiled program: traced penalty params on paths and batched lanes
# ---------------------------------------------------------------------------

def test_warm_path_reuses_one_compiled_program(chain_problem,
                                               recompile_guard):
    """Across a lam1 grid (warm-started) the reference engine must not
    recompile: penalty params and omega0 are traced."""
    from repro.core import prox as prox_mod
    from repro.estimator import ConcordEstimator, SolverConfig

    cfg = SolverConfig(backend="reference", variant="cov", tol=1e-6,
                       max_iters=200)
    s = jnp.asarray(chain_problem.s)
    est = ConcordEstimator(lam1=0.2, lam2=0.05, config=cfg)
    est.fit_path(s=s, n_samples=150, lam1_grid=[0.3, 0.25])
    with recompile_guard(solve=prox_mod._solve_reference):
        est.fit_path(s=s, n_samples=150, lam1_grid=[0.28, 0.22, 0.18, 0.12])
    # a scad path shares one program across its points too
    est2 = ConcordEstimator(lam1=0.2, lam2=0.05, penalty="scad:3.7",
                            config=cfg)
    est2.fit_path(s=s, n_samples=150, lam1_grid=[0.3, 0.25])
    with recompile_guard(solve=prox_mod._solve_reference):
        est2.fit_path(s=s, n_samples=150, lam1_grid=[0.27, 0.21, 0.14])


def test_batched_lanes_with_per_lane_penalty_params_f64(recompile_guard):
    """Different lanes carry different penalty params (lam1 AND the MCP
    shape) in ONE compiled program, and each lane matches its sequential
    solve bit-for-bit in telemetry / to 1e-5 in f64 values."""
    with x64():
        prob = graphs.make_problem("chain", p=32, n=100, seed=3)
        s = jnp.asarray(prob.s, jnp.float64)
        lam1s = [0.2, 0.3, 0.25]
        gammas = [1.5, 3.0, 10.0]
        spec_b = PenaltySpec("mcp", jnp.asarray(lam1s), 0.05,
                             shape=jnp.asarray(gammas))
        bat = batch.solve_batch(jnp.stack([s] * 3), penalty=spec_b,
                                variant="cov", tol=1e-6)
        for k in range(3):
            ref = solve_reference(
                s, penalty=PenaltySpec.mcp(lam1s[k], gammas[k], 0.05),
                tol=1e-6)
            np.testing.assert_allclose(np.asarray(bat.omega[k]),
                                       np.asarray(ref.omega),
                                       rtol=0, atol=1e-5)
            assert int(bat.iters[k]) == int(ref.iters)
        # lanes genuinely differ (different shapes -> different estimates)
        assert float(np.abs(np.asarray(bat.omega[0])
                            - np.asarray(bat.omega[2])).max()) > 1e-6
        # same lane count, new param VALUES -> no recompile
        spec_c = PenaltySpec("mcp", jnp.asarray([0.22, 0.28, 0.24]), 0.05,
                             shape=jnp.asarray([2.0, 4.0, 8.0]))
        with recompile_guard(solve_batch=batch._solve_batch):
            batch.solve_batch(jnp.stack([s] * 3), penalty=spec_c,
                              variant="cov", tol=1e-6)


# ---------------------------------------------------------------------------
# solver behaviour of the new penalties
# ---------------------------------------------------------------------------

def test_scad_mcp_solves_converge_and_are_symmetric(chain_problem):
    s = jnp.asarray(chain_problem.s)
    for spec in (PenaltySpec.scad(0.25, 3.7, 0.05),
                 PenaltySpec.mcp(0.25, 3.0, 0.05)):
        r = solve_reference(s, penalty=spec, tol=1e-6, max_iters=400)
        assert bool(r.converged)
        om = np.asarray(r.omega)
        np.testing.assert_allclose(om, om.T, atol=1e-5)
        assert np.all(np.diag(om) > 0)


def test_scad_shrinks_large_entries_less_than_l1(chain_problem):
    """SCAD's unbiasedness: large true edges survive with less shrinkage
    than under l1 at the same lam1."""
    s = jnp.asarray(chain_problem.s)
    r_l1 = solve_reference(s, 0.3, 0.05, tol=1e-6, max_iters=400)
    r_sc = solve_reference(s, penalty=PenaltySpec.scad(0.3, 3.7, 0.05),
                           tol=1e-6, max_iters=400)
    off = ~np.eye(48, dtype=bool)
    big = np.abs(np.asarray(r_sc.omega))[off].max()
    assert big >= np.abs(np.asarray(r_l1.omega))[off].max() - 1e-6


def test_structural_constraints_through_estimator(chain_problem):
    """0/inf weights as structural edge constraints end-to-end."""
    from repro.estimator import ConcordEstimator, SolverConfig

    p = chain_problem.s.shape[0]
    w = np.ones((p, p))
    np.fill_diagonal(w, 0.0)
    w[0, 1] = w[1, 0] = np.inf       # forbid the strongest chain edge
    w[0, 5] = w[5, 0] = 0.0          # leave a non-edge unpenalized
    est = ConcordEstimator(
        penalty=PenaltySpec.weighted_l1(0.2, w, lam2=0.05),
        config=SolverConfig(backend="reference", variant="cov", tol=1e-6))
    est.fit_cov(jnp.asarray(chain_problem.s), n_samples=150)
    om = np.asarray(est.omega_)
    assert om[0, 1] == 0.0 and om[1, 0] == 0.0
    assert abs(om[0, 5]) > 0.0
    assert est.report_.penalty == "weighted_l1"


def test_weighted_pallas_kernel_matches_oracle(rng):
    from repro.kernels import ops, ref

    z = rng.standard_normal((96, 96)).astype(np.float32)
    w = np.abs(rng.standard_normal((96, 96))).astype(np.float32)
    w[3, 7] = np.inf
    mask = np.eye(96, dtype=np.float32)
    out, ld, l1, ss, md, bnnz = ops.fused_prox_stats(
        jnp.asarray(z), jnp.asarray(mask), 0.2, weights=jnp.asarray(w),
        block=(32, 32))
    ro, rld, rl1, rss, rmd, rbnnz = ref.fused_prox_stats(
        jnp.asarray(z), jnp.asarray(mask), 0.2, weights=jnp.asarray(w),
        block=(32, 32))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ro), atol=1e-6)
    assert np.asarray(out)[3, 7] == 0.0
    np.testing.assert_allclose(np.asarray(bnnz), np.asarray(rbnnz))
    for a, b in [(ld, rld), (l1, rl1), (ss, rss), (md, rmd)]:
        np.testing.assert_allclose(float(a), float(b), rtol=1e-5)


def test_weighted_solve_with_pallas_and_sparse_harvest(chain_problem):
    """use_pallas routes the weighted prox through the fused kernel's
    weight lane; the harvested occupancy mask keeps the sparse dispatch
    exact (f64 agreement with the dense jnp path)."""
    with x64():
        from repro.core.matops import MatmulPolicy

        s = jnp.asarray(chain_problem.s, jnp.float64)
        p = s.shape[0]
        w = np.ones((p, p))
        np.fill_diagonal(w, 0.0)
        spec = PenaltySpec.weighted_l1(0.25, w, 0.05)
        pol = MatmulPolicy("on", 16, 1.0)
        r_plain = solve_reference(s, penalty=spec, tol=1e-6, max_iters=300)
        r_pal = solve_reference(s, penalty=spec, tol=1e-6, max_iters=300,
                                sparse_matmul=pol, use_pallas=True)
        np.testing.assert_allclose(np.asarray(r_pal.omega),
                                   np.asarray(r_plain.omega),
                                   rtol=0, atol=1e-8)


def test_fit_batch_keeps_estimator_penalty_family():
    """lam1/lam2 overrides on fit_batch retune strengths only — a SCAD
    estimator batches SCAD lanes, not silently-l1 ones — and a penalty
    string keeps the estimator's strength."""
    from repro.estimator import ConcordEstimator, SolverConfig

    xs = np.stack([graphs.make_problem("chain", p=24, n=80, seed=k).x
                   for k in range(2)])
    cfg = SolverConfig(backend="reference", variant="obs", tol=1e-5)
    est = ConcordEstimator(lam1=0.2, lam2=0.05, penalty="scad:3.7",
                           config=cfg)
    rep = est.fit_batch(x=xs, lam1=[0.2, 0.3])
    assert [r.penalty for r in rep] == ["scad:3.7", "scad:3.7"]
    assert [r.lam1 for r in rep] == [0.2, 0.3]
    assert all(r.lam2 == 0.05 for r in rep)
    # a penalty string on the call takes strength from the estimator
    rep2 = est.fit_batch(x=xs, penalty="mcp:2.5")
    assert [r.penalty for r in rep2] == ["mcp:2.5", "mcp:2.5"]
    assert all(r.lam1 == 0.2 and r.lam2 == 0.05 for r in rep2)
    with pytest.raises(ValueError, match="already carries"):
        est.fit_batch(x=xs, penalty=PenaltySpec.l1(0.1), lam1=0.3)


def test_string_penalty_requires_strength():
    """Solver entry points refuse a penalty string without lam1 — a
    silently-defaulted strength would return a wrongly-regularized
    estimate with no error."""
    s = jnp.eye(8) + 0.1
    with pytest.raises(TypeError, match="lam1"):
        solve_reference(s, penalty="scad:3.7")
    with pytest.raises(TypeError, match="lam1"):
        batch.solve_batch(jnp.stack([s, s]), penalty="scad:3.7")
    with pytest.raises(TypeError, match="lam1"):
        as_penalty("scad:3.7")


@pytest.mark.slow
def test_weighted_spec_shards_across_devices():
    """The weight matrix shards with the Omega layout through shard_map
    (4 virtual devices, padded p): distributed weighted/SCAD solves agree
    with the single-device reference and keep structural zeros exact."""
    from conftest import run_with_devices

    code = """
import numpy as np, jax.numpy as jnp
from repro.core import graphs
from repro.core.distributed import fit_cov
from repro.core.prox import solve_reference
from repro.core.penalty import PenaltySpec
from repro.comm.grid import Grid1p5D

prob = graphs.make_problem("chain", p=37, n=120, seed=3)
s = jnp.asarray(prob.s)
w = np.ones((37, 37)); np.fill_diagonal(w, 0.0)
w[0, 1] = w[1, 0] = np.inf
spec = PenaltySpec.weighted_l1(0.25, w, 0.05)
rd = fit_cov(s, penalty=spec, grid=Grid1p5D(4, 1, 1), tol=1e-6,
             max_iters=200)
rr = solve_reference(s, penalty=spec, tol=1e-6, max_iters=200)
om = np.asarray(rd.omega)
assert om[0, 1] == 0.0 and om[1, 0] == 0.0
gap = float(np.abs(om - np.asarray(rr.omega)).max())
assert gap < 2e-3, gap
print("OK", gap)
"""
    out = run_with_devices(code, n_devices=4)
    assert "OK" in out


# ---------------------------------------------------------------------------
# adaptive two-stage refit
# ---------------------------------------------------------------------------

def test_adaptive_weights_shape_and_symmetry():
    om = np.array([[2.0, 0.5, 0.0], [0.5001, 1.0, -0.2], [0.0, -0.2, 3.0]])
    w = adaptive_weights(om, eps=1e-2)
    assert w.shape == (3, 3)
    np.testing.assert_array_equal(w, w.T)          # exactly symmetric
    assert np.all(np.diag(w) == 0.0)
    off = ~np.eye(3, dtype=bool)
    assert w[off].mean() == pytest.approx(1.0)     # normalized
    assert w[0, 2] == w[off].max()                 # zeros get max weight
    with pytest.raises(ValueError, match="eps"):
        adaptive_weights(om, eps=0.0)
    with pytest.raises(ValueError, match="square"):
        adaptive_weights(np.ones((2, 3)))


def test_fit_path_adaptive_two_stage(chain_problem):
    from repro.estimator import ConcordEstimator, SolverConfig

    cfg = SolverConfig(backend="reference", variant="cov", tol=1e-6,
                       max_iters=300)
    s = jnp.asarray(chain_problem.s)
    grid = [0.3, 0.2, 0.15]
    est = ConcordEstimator(lam2=0.05, config=cfg)
    path = est.fit_path(s=s, n_samples=150, lam1_grid=grid, adaptive=True)
    assert path.adaptive and path.stage1 is not None
    assert not path.stage1.adaptive
    assert all(r.penalty == "l1" for r in path.stage1)
    assert all(r.penalty == "weighted_l1" for r in path)
    assert len(path) == len(path.stage1) == len(grid)
    assert path.best_bic().bic is not None
    assert "adaptive stage 2" in path.summary()
    # the estimator lands on the stage-2 terminal fit
    assert est.report_ is path.reports[-1]
    # adaptive keeps (or improves) stage-1 recovery on the easy chain
    ppv1, _ = graphs.ppv_fdr(np.asarray(path.stage1.best_bic().omega),
                             chain_problem.omega0)
    ppv2, _ = graphs.ppv_fdr(np.asarray(path.best_bic().omega),
                             chain_problem.omega0)
    assert ppv2 >= ppv1 - 0.1


def test_fit_path_adaptive_batched_mode(chain_problem):
    from repro.estimator import ConcordEstimator, SolverConfig

    cfg = SolverConfig(backend="reference", variant="cov", tol=1e-6,
                       max_iters=300)
    path = ConcordEstimator(lam2=0.05, config=cfg).fit_path(
        s=jnp.asarray(chain_problem.s), n_samples=150,
        lam1_grid=[0.3, 0.2], adaptive=True, mode="batched")
    assert path.adaptive and path.mode == "batched"
    assert all(r.penalty == "weighted_l1" for r in path)


# ---------------------------------------------------------------------------
# validation + parsing + config/estimator surfaces
# ---------------------------------------------------------------------------

def test_spec_validation_rejects_bad_params():
    with pytest.raises(ValueError, match="lam1"):
        PenaltySpec.l1(-0.1)
    with pytest.raises(ValueError, match="lam2"):
        PenaltySpec.l1(0.1, float("nan"))
    with pytest.raises(ValueError, match="scad"):
        PenaltySpec.scad(0.1, a=2.0)
    with pytest.raises(ValueError, match="scad"):
        PenaltySpec.scad(0.1, a=-3.7)
    with pytest.raises(ValueError, match="mcp"):
        PenaltySpec.mcp(0.1, gamma=1.0)
    with pytest.raises(ValueError, match="mcp"):
        PenaltySpec.mcp(0.1, gamma=0.0)


def test_weight_validation_mirrors_problem_validation():
    ones = np.ones((4, 4))
    with pytest.raises(ValueError, match="square"):
        PenaltySpec.weighted_l1(0.1, np.ones((4, 3)))
    bad = ones.copy()
    bad[0, 1] = np.nan
    with pytest.raises(ValueError, match="NaN"):
        PenaltySpec.weighted_l1(0.1, bad)
    neg = ones.copy()
    neg[1, 2] = neg[2, 1] = -1.0
    with pytest.raises(ValueError, match="nonnegative"):
        PenaltySpec.weighted_l1(0.1, neg)
    asym = ones.copy()
    asym[0, 1] = 5.0
    with pytest.raises(ValueError, match="symmetric"):
        PenaltySpec.weighted_l1(0.1, asym)
    inf_asym = ones.copy()
    inf_asym[0, 1] = np.inf
    with pytest.raises(ValueError, match="inf"):
        PenaltySpec.weighted_l1(0.1, inf_asym)
    with pytest.raises(ValueError, match="weight"):
        PenaltySpec.weighted_l1(0.1, None)


def test_parse_penalty_forms():
    assert parse_penalty("l1") == ("l1", None)
    assert parse_penalty("scad") == ("scad", 3.7)
    assert parse_penalty("scad:3.5") == ("scad", 3.5)
    assert parse_penalty("mcp:2.5") == ("mcp", 2.5)
    with pytest.raises(ValueError, match="unknown penalty"):
        parse_penalty("bogus")
    with pytest.raises(ValueError, match="shape"):
        parse_penalty("l1:3.0")
    with pytest.raises(ValueError, match="not a number"):
        parse_penalty("scad:abc")


def test_as_penalty_normalization():
    spec = as_penalty("scad:3.5", lam1=0.2, lam2=0.01)
    assert spec.kind == "scad" and float(spec.shape) == 3.5
    assert as_penalty(None, lam1=0.3).kind == "l1"
    assert as_penalty(0.3).kind == "l1" and float(as_penalty(0.3).lam1) == 0.3
    ready = PenaltySpec.l1(0.1)
    assert as_penalty(ready) is ready
    with pytest.raises(ValueError, match="already carries"):
        as_penalty(ready, lam1=0.2)
    with pytest.raises(ValueError, match="weight"):
        as_penalty("weighted_l1", lam1=0.2)


def test_solver_config_penalty_field():
    from repro.estimator import SolverConfig

    cfg = SolverConfig(penalty="mcp:2.5")
    assert cfg.penalty == "mcp:2.5"
    with pytest.raises(ValueError, match="unknown penalty"):
        SolverConfig(penalty="bogus")
    with pytest.raises(ValueError, match="penalty"):
        SolverConfig(penalty=3)


def test_estimator_penalty_resolution(chain_problem):
    from repro.estimator import ConcordEstimator, SolverConfig

    # config.penalty string applies when the ctor gets no penalty
    cfg = SolverConfig(backend="reference", variant="cov", tol=1e-5,
                       penalty="scad:3.7")
    est = ConcordEstimator(lam1=0.25, lam2=0.05, config=cfg)
    assert est.penalty.kind == "scad"
    est.fit_cov(jnp.asarray(chain_problem.s), n_samples=150)
    assert est.report_.penalty == "scad:3.7"
    # an explicit spec wins over config.penalty, and rejects scalar kwargs
    spec = PenaltySpec.mcp(0.2, 2.5)
    assert ConcordEstimator(penalty=spec, config=cfg).penalty is spec
    with pytest.raises(ValueError, match="already carries"):
        ConcordEstimator(lam1=0.2, penalty=spec)
    # the legacy mutation surface keeps retuning the spec
    est2 = ConcordEstimator(lam1=0.1, lam2=0.05)
    est2.lam1 = 0.4
    assert float(est2.penalty.lam1) == 0.4
    est2.lam2 = 0.01
    assert float(est2.penalty.lam2) == 0.01
