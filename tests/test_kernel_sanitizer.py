"""Differential kernel sanitizer: every registered kernel vs its oracle.

The pytest face of ``repro-analyze --fuzz-kernels``: each
(entry, config) pair in ``kernels.manifest.KERNEL_ENTRIES`` runs the
kernel in interpret mode against its jitted ``ref.py`` oracle with
deterministic per-case seeding, and the declared tolerance class is
ENFORCED — the manifest's edge-tile, prime-p and inf-guarded-weight
configurations all go through here.  Meta-tests prove the harness has
teeth: a one-ulp perturbation must fail the bit-exact class (and pass
fp-tolerant), a crashed builder must surface as a failed case rather
than an error, and per-case seeding must replay bit-identically.
"""
import numpy as np
import pytest

from repro.analysis import cli, kernelfuzz
from repro.kernels.manifest import KERNEL_ENTRIES

from conftest import REPO

CASES = [(e, c) for e in KERNEL_ENTRIES for c in e["configs"]]
CASE_IDS = [f"{e['name'].split('.')[1]}-{c['label']}" for e, c in CASES]


@pytest.mark.parametrize("entry,cfg", CASES, ids=CASE_IDS)
def test_kernel_matches_oracle_at_declared_tolerance(entry, cfg):
    results = kernelfuzz.run_case(entry, cfg, seed=0)
    assert results, "fuzz builder compared no outputs"
    bad = kernelfuzz.failures(results)
    assert not bad, "\n".join(r.render() for r in bad)
    # a bit-exact entry must actually exercise the bit-exact comparator
    # on at least one output (per-output classes may relax the rest)
    if entry["tolerance"] == "bit-exact":
        assert any(r.tolerance == "bit-exact" for r in results)


# ---------------------------------------------------------------------------
# the harness has teeth
# ---------------------------------------------------------------------------

def test_bit_exact_class_fails_on_one_ulp():
    entry = {"name": "test.meta", "rtol": 1e-9, "atol": 1e-9}
    want = np.linspace(-1.0, 1.0, 16)
    got = want.copy()
    got[3] = np.nextafter(got[3], np.inf)        # one flipped ulp
    r = kernelfuzz._compare(entry, "cfg", "out", got, want, "bit-exact")
    assert not r.ok
    assert "1 element(s)" in r.detail and "bit-exact" in r.detail
    # the same perturbation is inside any honest fp tolerance
    assert kernelfuzz._compare(entry, "cfg", "out", got, want,
                               "fp-tolerant").ok
    clean = kernelfuzz._compare(entry, "cfg", "out", want, want,
                                "bit-exact")
    assert clean.ok and clean.max_abs_diff == 0.0


def test_fp_tolerant_class_fails_outside_declared_tolerance():
    entry = {"name": "test.meta", "rtol": 1e-12, "atol": 1e-12}
    want = np.ones(8)
    got = want + 1e-6
    r = kernelfuzz._compare(entry, "cfg", "out", got, want, "fp-tolerant")
    assert not r.ok and "rtol" in r.detail
    assert r.max_abs_diff == pytest.approx(1e-6)


def test_unknown_tolerance_and_shape_dtype_mismatches_fail():
    entry = {"name": "test.meta"}
    bad = kernelfuzz._compare(entry, "c", "o", np.ones(3), np.ones(3),
                              "close-enough")
    assert not bad.ok and "unknown tolerance class" in bad.detail
    mis = kernelfuzz._compare(entry, "c", "o", np.ones(3), np.ones(4),
                              "bit-exact")
    assert not mis.ok and "shape/dtype mismatch" in mis.detail
    dt = kernelfuzz._compare(entry, "c", "o", np.ones(3, np.float32),
                             np.ones(3), "fp-tolerant")
    assert not dt.ok and "shape/dtype mismatch" in dt.detail


def test_crashed_builder_surfaces_as_failed_case():
    entry = {"name": "test.crash", "fuzz": lambda cfg, rng: 1 // 0}
    [r] = kernelfuzz.run_case(entry, {"label": "boom"}, seed=0)
    assert not r.ok and r.output == "<error>"
    assert "fuzz builder raised" in r.detail
    assert "ZeroDivisionError" in r.detail


def test_empty_builder_is_a_failure_not_a_pass():
    entry = {"name": "test.empty", "fuzz": lambda cfg, rng: []}
    [r] = kernelfuzz.run_case(entry, {"label": "none"}, seed=0)
    assert not r.ok and r.output == "<empty>"


def test_case_seeding_is_deterministic_and_distinct():
    a = kernelfuzz.case_rng(0, "kernels.x.f", "aligned").standard_normal(8)
    b = kernelfuzz.case_rng(0, "kernels.x.f", "aligned").standard_normal(8)
    np.testing.assert_array_equal(a, b)
    c = kernelfuzz.case_rng(0, "kernels.x.f", "edge").standard_normal(8)
    d = kernelfuzz.case_rng(1, "kernels.x.f", "aligned").standard_normal(8)
    assert not np.array_equal(a, c)
    assert not np.array_equal(a, d)


def test_report_counts_and_case_table():
    results = [
        kernelfuzz.FuzzResult("e", "c", "out", "bit-exact", True),
        kernelfuzz.FuzzResult("e", "c", "out2", "fp-tolerant", False,
                              0.5, "outside tolerance"),
    ]
    rep = kernelfuzz.report(results, seed=7)
    assert rep["seed"] == 7
    assert rep["counts"] == {"cases": 2, "failures": 1}
    assert rep["cases"][1]["detail"] == "outside tolerance"
    assert [r.output for r in kernelfuzz.failures(results)] == ["out2"]


# ---------------------------------------------------------------------------
# the CLI gate
# ---------------------------------------------------------------------------

def _fake_registry(perturb: bool):
    """A one-entry registry whose fuzz builder optionally flips an ulp."""
    def fake_fuzz(cfg, rng):
        want = rng.standard_normal(4)
        got = want.copy()
        if perturb:
            got[0] = np.nextafter(got[0], np.inf)
        return [("out", got, want, "bit-exact")]

    return [{"name": "kernels.fake.k",
             "path": "src/repro/kernels/fake.py",
             "oracle": "fused_prox_stats", "tolerance": "bit-exact",
             "configs": ({"label": "only"},), "fuzz": fake_fuzz}]


def test_cli_fuzz_failure_gates_even_with_zero_findings(
        tmp_path, capsys, monkeypatch):
    import json

    import repro.kernels.manifest as manifest

    monkeypatch.setattr(manifest, "KERNEL_ENTRIES", _fake_registry(True))
    report = tmp_path / "fuzz.json"
    rc = cli.main(["src/repro/analysis", "--engine", "ast", "--root", REPO,
                   "--fuzz-kernels", "--format", "json",
                   "--output", str(report)])
    capsys.readouterr()
    assert rc == 1
    data = json.loads(report.read_text(encoding="utf-8"))
    assert data["counts"]["findings"] == 0        # static side is clean
    assert data["kernel_fuzz"]["counts"] == {"cases": 1, "failures": 1}
    case = data["kernel_fuzz"]["cases"][0]
    assert case["entry"] == "kernels.fake.k" and not case["ok"]


def test_cli_fuzz_pass_and_seed_passthrough(capsys, monkeypatch):
    import repro.kernels.manifest as manifest

    monkeypatch.setattr(manifest, "KERNEL_ENTRIES", _fake_registry(False))
    rc = cli.main(["src/repro/analysis", "--engine", "ast", "--root", REPO,
                   "--fuzz-kernels", "--fuzz-seed", "3"])
    out = capsys.readouterr().out
    assert rc == 0
    assert "kernel fuzz (seed 3): 1 case(s), 0 failure(s)." in out
