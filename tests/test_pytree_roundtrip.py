"""Pytree round-trip property tests, auto-discovered from the registries.

Two discovery sources, so a new penalty kind or a new pytree-registered
dataclass/NamedTuple carry gets round-trip coverage automatically (or
fails loudly here until a sample builder exists):

  * ``penalty.penalty_kinds()`` — every registered penalty family gets a
    PenaltySpec flatten/unflatten identity check, scalar and batched.
  * a module walk over the ``repro`` package finds (a) every dataclass
    registered via ``register_pytree_node_class`` (has tree_flatten AND
    tree_unflatten) and (b) every NamedTuple carry, and round-trips each.

The flatten/unflatten identity is what jit/vmap/shard_map rely on when
they rebuild carries at trace boundaries; static aux (penalty kind,
presence flags) must survive while numeric leaves stay traced.
"""
import dataclasses
import importlib
import inspect
import pkgutil

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import penalty

# subpackages walked for pytree classes; modules that fail to import are
# skipped (optional deps), but the walk itself must find the known carries
_WALK_ROOTS = ("repro.core", "repro.data", "repro.kernels", "repro.comm",
               "repro.estimator", "repro.models", "repro.launch")


def _walk_modules():
    for root in _WALK_ROOTS:
        try:
            pkg = importlib.import_module(root)
        except Exception:
            continue
        yield pkg
        if not hasattr(pkg, "__path__"):
            continue
        for info in pkgutil.iter_modules(pkg.__path__):
            try:
                yield importlib.import_module(f"{root}.{info.name}")
            except Exception:
                continue


def _discover(predicate):
    found = {}
    for mod in _walk_modules():
        for _, cls in inspect.getmembers(mod, inspect.isclass):
            if cls.__module__.startswith("repro.") and predicate(cls):
                found[f"{cls.__module__}.{cls.__qualname__}"] = cls
    return found


def _is_registered_dataclass(cls) -> bool:
    return (dataclasses.is_dataclass(cls)
            and "tree_flatten" in cls.__dict__
            and "tree_unflatten" in cls.__dict__)


def _is_namedtuple(cls) -> bool:
    return (issubclass(cls, tuple) and hasattr(cls, "_fields")
            and hasattr(cls, "_field_defaults"))


def _leaves_equal(a, b):
    la, ta = jax.tree_util.tree_flatten(a)
    lb, tb = jax.tree_util.tree_flatten(b)
    assert ta == tb, f"treedef changed on round trip: {ta} != {tb}"
    for x, y in zip(la, lb):
        if isinstance(x, (jax.Array, np.ndarray)) or isinstance(
                y, (jax.Array, np.ndarray)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        else:
            assert x == y


def _roundtrip(obj):
    leaves, treedef = jax.tree_util.tree_flatten(obj)
    rebuilt = jax.tree_util.tree_unflatten(treedef, leaves)
    assert type(rebuilt) is type(obj)
    _leaves_equal(obj, rebuilt)
    return rebuilt


# ---------------------------------------------------------------------------
# PenaltySpec: every registered kind, scalar and batched lanes
# ---------------------------------------------------------------------------

def _sample_spec(kind: str, lam1=0.3) -> penalty.PenaltySpec:
    """Build a validated sample spec from registry metadata alone."""
    defn = penalty._get_def(kind)
    shape = defn.default_shape if defn.has_shape else None
    spec = penalty.PenaltySpec(kind, lam1, 0.05, shape=shape)
    try:
        defn.validate(spec)
        return spec
    except ValueError as e:
        if "weight" not in str(e):
            raise
    w = jnp.abs(jnp.asarray(np.random.default_rng(0).normal(size=(6, 6))))
    spec = penalty.PenaltySpec(kind, lam1, 0.05, shape=shape,
                               weights=0.5 * (w + w.T))
    defn.validate(spec)
    return spec


@pytest.mark.parametrize("kind", penalty.penalty_kinds())
def test_penalty_spec_roundtrip_scalar(kind):
    spec = _sample_spec(kind)
    rebuilt = _roundtrip(spec)
    assert rebuilt.kind == spec.kind
    assert (rebuilt.shape is None) == (spec.shape is None)
    assert (rebuilt.weights is None) == (spec.weights is None)


@pytest.mark.parametrize("kind", penalty.penalty_kinds())
def test_penalty_spec_roundtrip_batched_lanes(kind):
    """(B,) lam1 lanes flatten to (B,) leaves and come back intact —
    exactly what solve_batch's vmap does to the spec."""
    spec = _sample_spec(kind).with_lam1(jnp.asarray([0.1, 0.2, 0.3]))
    rebuilt = _roundtrip(spec)
    assert rebuilt.lam1.shape == (3,)
    np.testing.assert_array_equal(np.asarray(rebuilt.lam1),
                                  np.asarray(spec.lam1))


@pytest.mark.parametrize("kind", penalty.penalty_kinds())
def test_penalty_spec_treedef_is_value_independent(kind):
    """Same kind, different numeric values -> identical treedef: the
    one-compiled-program-per-penalty-kind contract hangs on this."""
    a = jax.tree_util.tree_structure(_sample_spec(kind, lam1=0.1))
    b = jax.tree_util.tree_structure(_sample_spec(kind, lam1=0.9))
    assert a == b
    assert hash(a) == hash(b)


def test_penalty_spec_treedefs_differ_across_kinds():
    """Distinct kinds carry distinct static aux, forcing a retrace (each
    penalty family gets its own compiled program, never a silent reuse)."""
    tds = {k: jax.tree_util.tree_structure(_sample_spec(k))
           for k in penalty.penalty_kinds()}
    kinds = sorted(tds)
    for i, ki in enumerate(kinds):
        for kj in kinds[i + 1:]:
            assert tds[ki] != tds[kj], (ki, kj)


def test_penalty_spec_survives_tree_map():
    spec = _sample_spec("scad")
    doubled = jax.tree_util.tree_map(lambda x: x * 2, spec)
    assert isinstance(doubled, penalty.PenaltySpec)
    assert doubled.kind == "scad"
    np.testing.assert_allclose(float(doubled.lam1), 2 * float(spec.lam1))
    np.testing.assert_allclose(float(doubled.shape), 2 * float(spec.shape))


# ---------------------------------------------------------------------------
# registered dataclasses: discovery must stay in sync with the samples
# ---------------------------------------------------------------------------

#: sample builders for every pytree-REGISTERED dataclass in the repo.  The
#: discovery test below fails if a new registration appears without one.
_DATACLASS_SAMPLES = {
    "repro.core.penalty.PenaltySpec": lambda: _sample_spec("mcp"),
}


def test_every_registered_dataclass_has_a_roundtrip_sample():
    found = _discover(_is_registered_dataclass)
    assert set(found) == set(_DATACLASS_SAMPLES), (
        f"pytree-registered dataclasses changed: found {sorted(found)}, "
        f"samples cover {sorted(_DATACLASS_SAMPLES)}; add/remove a sample "
        f"builder in _DATACLASS_SAMPLES")


@pytest.mark.parametrize("name", sorted(_DATACLASS_SAMPLES))
def test_registered_dataclass_roundtrip(name):
    _roundtrip(_DATACLASS_SAMPLES[name]())


# ---------------------------------------------------------------------------
# NamedTuple carries: native pytrees, but the identity still deserves a
# regression net (a __new__ override or field reorder would break it)
# ---------------------------------------------------------------------------

def _namedtuple_sample(cls):
    return cls(*[jnp.asarray(float(i + 1)) for i in range(len(cls._fields))])


def test_namedtuple_carries_discovered():
    found = _discover(_is_namedtuple)
    expected = {
        "repro.core.prox.ProxResult", "repro.core.prox._Carry",
        "repro.core.prox._LsCarry", "repro.core.prox.VariantOps",
        "repro.core.objective.ProxState",
        "repro.core.distributed.FitResult",
        "repro.data.gram.GramResult",
    }
    missing = expected - set(found)
    assert not missing, f"walk lost known carries: {sorted(missing)}"


@pytest.mark.parametrize("name", [
    "repro.core.prox.ProxResult",
    "repro.core.prox._Carry",
    "repro.core.prox._LsCarry",
    "repro.core.objective.ProxState",
    "repro.core.distributed.FitResult",
    "repro.data.gram.GramResult",
])
def test_namedtuple_carry_roundtrip(name):
    found = _discover(_is_namedtuple)
    cls = found[name]
    sample = _namedtuple_sample(cls)
    rebuilt = _roundtrip(sample)
    assert rebuilt._fields == cls._fields
    assert len(jax.tree_util.tree_leaves(sample)) == len(cls._fields)
