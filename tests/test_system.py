"""End-to-end behaviour of the paper's system: full estimation pipeline
with cost-model-driven configuration, and the Section-5 clustering
pipeline on a synthetic 'cortex'."""
import numpy as np
import jax.numpy as jnp

from repro.core import clustering, distributed, graphs
from repro.core.costmodel import Machine, ProblemShape, tune
from repro.core.prox import fit_reference


def test_end_to_end_estimation_pipeline():
    """data -> cost model -> solver -> support metrics, single device."""
    prob = graphs.make_problem("chain", p=60, n=240, seed=11)
    shape = ProblemShape(p=60, n=240, d=3.0)
    best = tune(shape, 1, Machine())
    assert best.variant in ("cov", "obs")
    res = distributed.fit(x=jnp.asarray(prob.x), lam1=0.22, lam2=0.02,
                          tol=1e-6, max_iters=300)
    ppv, fdr = graphs.ppv_fdr(np.asarray(res.omega), prob.omega0)
    assert bool(res.converged)
    assert ppv > 0.8, ppv


def test_clustering_pipeline_beats_marginal_baseline():
    """Partial-correlation clusters >= marginal-correlation clusters on
    a region-structured problem (the Section 5 claim, miniaturized)."""
    side, region, n = 8, 4, 500
    p = side * side
    omega = np.eye(p, dtype=np.float32)
    nbrs = clustering.grid_neighbors(side, side)
    labels = np.zeros(p, dtype=np.int64)
    for idx in range(p):
        r, c = divmod(idx, side)
        labels[idx] = (r // region) * (side // region) + (c // region)
    for i in range(p):
        for j in nbrs[i]:
            if j > i and labels[i] == labels[j]:
                omega[i, j] = omega[j, i] = -0.28
    d = np.abs(omega).sum(1) - 1.0
    omega[np.diag_indices(p)] = d + 1.0
    x = graphs.sample_gaussian(omega, n, seed=3)
    s = jnp.asarray((x.T @ x) / n)

    r = fit_reference(s, 0.18, 0.05, tol=1e-5, max_iters=250)
    sup = graphs.support(np.asarray(r.omega), tol=1e-4)
    sup = sup | sup.T
    deg = clustering.degrees_from_support(sup)
    best = 0.0, 1
    for eps in (0.0, 0.5, 1.0):
        ph = clustering.persistence_watershed(deg.astype(float), nbrs,
                                              eps=eps)
        score = clustering.modified_jaccard(ph, labels)
        if score > best[0]:
            best = score, len(np.unique(ph))
    assert 0.0 < best[0] <= 1.0
    assert best[1] >= 2
