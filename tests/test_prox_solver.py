"""Reference proximal-gradient solver behaviour (core/prox.py)."""
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal CPU image — deterministic fallback
    from _hypothesis_fallback import given, settings, st

from repro.core import graphs
from repro.core.objective import full_objective_cov
from repro.core.prox import fit_reference


@pytest.fixture(scope="module")
def chain_problem():
    return graphs.make_problem("chain", p=48, n=150, seed=1)


def test_cov_obs_converge_to_same_solution(chain_problem):
    p = chain_problem
    r1 = fit_reference(jnp.asarray(p.s), 0.15, 0.05, tol=1e-6,
                       max_iters=300)
    r2 = fit_reference(jnp.asarray(p.x), 0.15, 0.05, variant="obs",
                       tol=1e-6, max_iters=300)
    assert bool(r1.converged) and bool(r2.converged)
    np.testing.assert_allclose(np.asarray(r1.omega), np.asarray(r2.omega),
                               atol=2e-3)


def test_objective_decreases(chain_problem):
    """F(Omega_hat) must be below F(Omega_0) = F(I)."""
    p = chain_problem
    r = fit_reference(jnp.asarray(p.s), 0.2, 0.05, tol=1e-6)
    f0 = full_objective_cov(jnp.eye(p.s.shape[0]), jnp.asarray(p.s),
                            0.2, 0.05)
    fhat = full_objective_cov(r.omega, jnp.asarray(p.s), 0.2, 0.05)
    assert float(fhat) < float(f0)


def test_solution_is_fixed_point(chain_problem):
    """prox step at the solution returns (approximately) the solution."""
    from repro.core.objective import gradient_from_w, prox_l1_offdiag
    p = chain_problem
    lam1, lam2 = 0.2, 0.05
    r = fit_reference(jnp.asarray(p.s), lam1, lam2, tol=1e-7, max_iters=500)
    om = r.omega
    grad = gradient_from_w(om, om @ jnp.asarray(p.s), lam2)
    tau = 1e-3
    step = prox_l1_offdiag(om - tau * grad, tau * lam1)
    np.testing.assert_allclose(np.asarray(step), np.asarray(om), atol=5e-4)


def test_diagonal_stays_positive(chain_problem):
    p = chain_problem
    r = fit_reference(jnp.asarray(p.s), 0.15, 0.0, tol=1e-6)
    assert np.all(np.diag(np.asarray(r.omega)) > 0)


def test_symmetry_preserved(chain_problem):
    p = chain_problem
    r = fit_reference(jnp.asarray(p.s), 0.15, 0.05, tol=1e-6)
    a = np.asarray(r.omega)
    np.testing.assert_allclose(a, a.T, atol=1e-5)


@given(st.floats(0.1, 0.6))
@settings(max_examples=8, deadline=None)
def test_sparsity_monotone_in_lam1(lam1):
    """Larger lam1 => no more edges (path monotonicity, statistical
    sanity of the estimator)."""
    p = graphs.make_problem("chain", p=32, n=100, seed=3)
    r1 = fit_reference(jnp.asarray(p.s), lam1, 0.05, tol=1e-5)
    r2 = fit_reference(jnp.asarray(p.s), lam1 + 0.2, 0.05, tol=1e-5)
    assert graphs.edge_count(np.asarray(r2.omega)) <= \
        graphs.edge_count(np.asarray(r1.omega)) + 2  # small slack


def test_support_recovery_chain():
    """On an easy chain problem the estimator finds mostly true edges
    (qualitative Table-1 check)."""
    p = graphs.make_problem("chain", p=64, n=400, seed=5)
    r = fit_reference(jnp.asarray(p.s), 0.22, 0.02, tol=1e-6, max_iters=400)
    ppv, fdr = graphs.ppv_fdr(np.asarray(r.omega), p.omega0)
    assert ppv > 0.85, f"PPV too low: {ppv}"


def test_warm_start_tau_reduces_ls_trials():
    p = graphs.make_problem("chain", p=48, n=150, seed=2)
    r0 = fit_reference(jnp.asarray(p.s), 0.15, 0.05, tol=1e-6)
    r1 = fit_reference(jnp.asarray(p.s), 0.15, 0.05, tol=1e-6,
                       warm_start_tau=True)
    # same solution
    np.testing.assert_allclose(np.asarray(r0.omega), np.asarray(r1.omega),
                               atol=2e-3)


def test_nongaussian_data_still_recovers():
    """CONCORD's pseudolikelihood makes no Gaussianity assumption."""
    p = graphs.make_problem("chain", p=48, n=400, seed=7, gaussian=False)
    r = fit_reference(jnp.asarray(p.s), 0.35, 0.02, tol=1e-5, max_iters=300)
    ppv, _ = graphs.ppv_fdr(np.asarray(r.omega), p.omega0)
    assert ppv > 0.7
