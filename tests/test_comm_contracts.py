"""CA303 acceptance sweep: static schedule bytes == analytic comm_volume.

For every 1.5D ring product (both gather flavors and the reduce flavor,
dense and masked) across a (P, c_x, c_omega, p, dtype) sweep, the comm
engine traces the ``_local`` schedule under ``make_jaxpr(axis_env=...)``,
derives bytes-on-wire from the jaxpr, and the result must EQUAL — as an
exact ``fractions.Fraction``, no tolerance — the analytic
``core.costmodel.comm_volume`` formula.  This is the paper's W term made
a test: any extra collective, missing round, or widened wire dtype
breaks the equality.

Also covers the exact volumes of the compressed collectives (int8 ring,
bf16 psum) and the unit conventions of ``collective_wire_bytes``.
"""
from fractions import Fraction

import pytest

from repro.analysis import commpass
from repro.analysis.rules import DEFAULT_PROFILE
from repro.comm.grid import Grid1p5D
from repro.core.costmodel import (
    collective_wire_bytes,
    comm_volume,
    compressed_psum_volume,
    ring_allreduce_int8_volume,
)

# (P, c_x, c_omega): replication off, on one side, on both, and deep
# rings; every config satisfies the layout constraints of all four
# flavors (c_x | n_x for xtx, c_omega | n_x for y_x / omega_xt)
GRIDS = [
    (4, 1, 1),
    (8, 2, 1),
    (8, 1, 2),
    (8, 2, 2),
    (16, 2, 2),
    (16, 4, 2),
]

FLAVORS = ("xtx", "omega_s", "y_x", "omega_xt")


def _axis_env(grid):
    return (("i", grid.n_i), ("j", grid.c_omega), ("k", grid.c_x))


def _build_flavor(flavor, grid, p, n, dtype, *, masked=False, bs=2):
    """Zero-arg build thunk tracing one ring product at the given shapes
    (arrays are made inside the thunk, i.e. under the engine's
    enable_x64 — an eager f64 array would silently be f32)."""
    def build():
        return _spec_flavor(flavor, grid, p, n, dtype, masked, bs)
    return build


def _spec_flavor(flavor, grid, p, n, dtype, masked, bs):
    import jax.numpy as jnp

    from repro.comm import matmul1p5d as mm
    from repro.comm import sparse1p5d as sp
    from repro.core import matops

    dt = jnp.dtype(dtype)
    blk_x, blk_om = p // grid.n_x, p // grid.n_om
    if flavor == "xtx":
        x = jnp.linspace(-1.0, 1.0, n * blk_x, dtype=dt).reshape(n, blk_x)
        return {"fn": lambda a: mm.xtx_local(a, grid), "args": (x,),
                "axis_env": _axis_env(grid)}
    if flavor == "omega_s":
        om = jnp.eye(blk_om, p, dtype=dt)
        s = jnp.ones((p, blk_x), dt)
        if masked:
            policy = matops.MatmulPolicy(mode="on", block_size=bs,
                                         threshold=0.5)
            mask = matops.block_mask(om, bs)
            return {"fn": lambda a, m, b: sp.omega_s_local_sparse(
                        a, m, b, grid, policy=policy,
                        canonical="omegalike"),
                    "args": (om, mask, s), "axis_env": _axis_env(grid)}
        return {"fn": lambda a, b: mm.omega_s_local(
                    a, b, grid, canonical="omegalike"),
                "args": (om, s), "axis_env": _axis_env(grid)}
    if flavor == "y_x":
        y = jnp.ones((blk_om, n), dt)
        x = jnp.ones((n, blk_x), dt)
        return {"fn": lambda a, b: mm.y_x_local(a, b, grid),
                "args": (y, x), "axis_env": _axis_env(grid)}
    if flavor == "omega_xt":
        om = jnp.eye(blk_om, p, dtype=dt)
        xt = jnp.ones((blk_x, n), dt)
        if masked:
            policy = matops.MatmulPolicy(mode="on", block_size=bs,
                                         threshold=0.5)
            mask = matops.block_mask(om, bs)
            return {"fn": lambda a, m, b: sp.omega_xt_local_sparse(
                        a, m, b, grid, policy=policy),
                    "args": (om, mask, xt), "axis_env": _axis_env(grid)}
        return {"fn": lambda a, b: mm.omega_xt_local(a, b, grid),
                "args": (om, xt), "axis_env": _axis_env(grid)}
    raise ValueError(flavor)


def _static_bytes(build):
    """Trace a build thunk and extract the schedule's exact byte count."""
    entry = {"name": "sweep", "path": "src/repro/comm/matmul1p5d.py",
             "axis_names": ("i", "j", "k"), "build": build}
    findings, record = commpass.run_entry(entry, DEFAULT_PROFILE)
    assert [f for f in findings if f.rule == "CA300"] == [], findings
    # structural rules must also stay silent on the blessed idioms
    assert findings == [], findings
    assert record["static_bytes"] is not None, record
    return Fraction(record["static_bytes"])


@pytest.mark.parametrize("P,c_x,c_omega", GRIDS)
@pytest.mark.parametrize("flavor", FLAVORS)
def test_static_bytes_match_analytic_volume(P, c_x, c_omega, flavor):
    grid = Grid1p5D(P, c_x, c_omega)
    p, n = 2 * P, 6
    build = _build_flavor(flavor, grid, p, n, "float64")
    expected = comm_volume(p, n, P, c_x, c_omega, flavor=flavor)
    assert _static_bytes(build) == expected.total, (flavor, P, c_x, c_omega)


@pytest.mark.parametrize("P,c_x,c_omega", [(8, 2, 2), (16, 4, 2)])
@pytest.mark.parametrize("flavor", ("omega_s", "omega_xt"))
def test_masked_static_bytes_match_analytic_volume(P, c_x, c_omega, flavor):
    """Gather flavor ships the int8 mask around the ring (counted);
    reduce flavor ships nothing extra (the mask is fixed and local)."""
    grid = Grid1p5D(P, c_x, c_omega)
    p, n, bs = 4 * P, 6, 2
    build = _build_flavor(flavor, grid, p, n, "float64", masked=True, bs=bs)
    expected = comm_volume(p, n, P, c_x, c_omega, flavor=flavor,
                           masked=(flavor == "omega_s"), block_size=bs)
    assert _static_bytes(build) == expected.total
    dense = comm_volume(p, n, P, c_x, c_omega, flavor=flavor)
    if flavor == "omega_s":
        assert expected.total > dense.total     # mask bytes are on the wire
    else:
        assert expected.total == dense.total    # fixed mask: free


@pytest.mark.parametrize("dtype,width", [("float64", 8), ("float32", 4)])
def test_wire_dtype_scales_volume_exactly(dtype, width):
    grid = Grid1p5D(8, 2, 2)
    p, n = 16, 6
    static = _static_bytes(_build_flavor("xtx", grid, p, n, dtype))
    expected = comm_volume(p, n, 8, 2, 2, flavor="xtx", dtype=dtype)
    assert static == expected.total
    f64 = comm_volume(p, n, 8, 2, 2, flavor="xtx", dtype="float64")
    assert expected.total * 8 == f64.total * width


def test_replication_cuts_ring_traffic():
    """The paper's point, as an exact inequality: at fixed P, replication
    c > 1 moves strictly fewer ring bytes than c = 1 (fewer rounds),
    paying with the team finish."""
    p, n, P = 32, 8, 16
    v1 = comm_volume(p, n, P, 1, 1, flavor="omega_xt")
    v4 = comm_volume(p, n, P, 1, 4, flavor="omega_xt")
    assert v4.rounds < v1.rounds
    assert v4.ring_bytes < v1.ring_bytes
    assert v4.finish_bytes > v1.finish_bytes


def test_collective_wire_byte_conventions():
    assert collective_wire_bytes("ppermute", 100, 4) == 100
    assert collective_wire_bytes("ppermute", 100, 4, moves=False) == 0
    assert collective_wire_bytes("ppermute", 100, 1) == 0
    assert collective_wire_bytes("psum", 100, 4) == Fraction(150)
    assert collective_wire_bytes("all_gather", 100, 4) == 300
    assert collective_wire_bytes("all_to_all", 100, 4) == 75
    assert collective_wire_bytes("reduce_scatter", 100, 4) == 75
    with pytest.raises(ValueError):
        collective_wire_bytes("axis_index", 1, 4)


def test_compressed_collective_volumes_match_schedules():
    """The collectives manifest entries' exact match, asserted directly."""
    from repro.comm import collectives as cc

    for entry in cc.ANALYSIS_ENTRIES:
        findings, record = commpass.run_entry(entry, DEFAULT_PROFILE)
        assert findings == [], (entry["name"], findings)
        assert record["static_bytes"] == record["contract"]["expected_bytes"]

    # and the closed forms themselves: 10 f64 elements over a 4-ring pad
    # to 3-element chunks; 3 rounds ship (3 int8 + 8B scale), the gather
    # ships 3 f64 chunks
    assert ring_allreduce_int8_volume(10, 4) == 3 * (3 + 8) + 3 * 3 * 8
    assert ring_allreduce_int8_volume(10, 1) == 0
    # bf16 all-reduce of 24 elements over 4: 2*(3/4)*24*2
    assert compressed_psum_volume(24, 4, method="bf16") == Fraction(72)


def test_every_comm_module_declares_contracts():
    """The four comm-layer modules all export COMM_CONTRACT, and every
    manifest entry of the ring modules binds one."""
    import repro.comm.collectives as cc
    import repro.comm.matmul1p5d as mm
    import repro.comm.sparse1p5d as sp
    import repro.core.distributed as dist

    for mod in (mm, sp, cc, dist):
        assert mod.COMM_CONTRACT, mod.__name__
        for contract in mod.COMM_CONTRACT.values():
            assert contract.entry
    for mod in (mm, sp, cc):
        for entry in mod.ANALYSIS_ENTRIES:
            comm = entry["comm"]()
            assert comm["contract"].volume is not None, entry["name"]
