"""Lane-compacting batched path engine: bit-exactness contracts, the fused
Pallas path-step megakernel vs its jnp oracle, chunk-program reuse across
live-lane counts, the host-BLAS stepper, pilot warm starts, and the
batched-vs-sequential cost model behind ``fit_path(mode="auto")``."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import batch, graphs
from repro.core.prox import solve_reference


@pytest.fixture(scope="module")
def x64():
    prev = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", True)
    yield
    jax.config.update("jax_enable_x64", prev)


@pytest.fixture(scope="module")
def chain48(x64):
    prob = graphs.make_problem("chain", p=48, n=150, seed=0)
    return jnp.asarray(prob.s, jnp.float64)


GRID = np.geomspace(0.4, 0.1, 6)


# ---------------------------------------------------------------------------
# compacted engine vs sequential: BIT-exact, not just allclose
# ---------------------------------------------------------------------------

def test_compact_path_is_bitexact_vs_sequential_f64(chain48):
    """Every compacted lane must reproduce its sequential solve to the
    BIT, with identical per-lane iteration and line-search trial counts —
    converged lanes freeze exactly, compaction only reorders scheduling."""
    kw = dict(variant="cov", tol=1e-6, max_iters=400)
    seq = [solve_reference(chain48, float(l1), 0.05, **kw) for l1 in GRID]
    bat, stats = batch.solve_path_batched(
        chain48, jnp.asarray(GRID), 0.05, **kw, return_stats=True)
    assert stats.schedule == "compact" and stats.n_lanes == len(GRID)
    assert stats.segments >= 1 and len(stats.occupancy) > 0
    for i in range(len(GRID)):
        np.testing.assert_array_equal(np.asarray(bat.omega[i]),
                                      np.asarray(seq[i].omega))
        assert int(bat.iters[i]) == int(seq[i].iters)
        assert int(bat.ls_total[i]) == int(seq[i].ls_total)
        assert bool(bat.converged[i]) == bool(seq[i].converged)


def test_compact_occupancy_timeline_is_consistent(chain48):
    """The occupancy timeline sums to the lane-step count and never
    exceeds the capacity in force at that step."""
    _, stats = batch.solve_path_batched(
        chain48, jnp.asarray(GRID), 0.05, variant="cov", tol=1e-6,
        max_iters=400, chunk=8, return_stats=True)
    occ = np.asarray(stats.occupancy)
    cap = np.asarray(stats.capacities)
    assert occ.shape == cap.shape
    assert int(occ.sum()) == stats.lane_steps
    assert int(cap.sum()) == stats.padded_lane_steps
    assert np.all(occ <= cap) and np.all(occ >= 0)
    assert 0.0 < stats.mean_occupancy <= 1.0
    assert "compact" in stats.summary()


# ---------------------------------------------------------------------------
# fused path-step megakernel vs the jnp oracle
# ---------------------------------------------------------------------------

def _kernel_case(c=3, p=24, seed=0, dtype=jnp.float64):
    rng = np.random.default_rng(seed)
    omega = jnp.asarray(
        np.eye(p) + 0.1 * rng.standard_normal((c, p, p)), dtype)
    w = jnp.asarray(rng.standard_normal((c, p, p)), dtype)
    tau = jnp.asarray(np.geomspace(0.5, 1.5, c), dtype)
    lam1 = jnp.asarray(np.linspace(0.1, 0.3, c), dtype)
    lam2 = jnp.asarray(np.linspace(0.0, 0.1, c), dtype)
    return omega, w, tau, lam1, lam2


@pytest.mark.parametrize("block", [8, 12, 24])
def test_megakernel_matches_oracle_bitwise(x64, block):
    """The Pallas megakernel must be BIT-identical to the jitted jnp
    oracle (the jit matters: eager dispatch fuses multiply-adds
    differently and can differ by one ulp); the tiled stats partials are
    order-sensitive, so they get a tight allclose instead."""
    from repro.kernels import ops as kops
    from repro.kernels import ref

    args = _kernel_case()
    cand, stats = kops.fused_path_step(*args, block=block, interpret=True)
    cand_ref, stats_ref = jax.jit(ref.fused_path_step)(*args)
    np.testing.assert_array_equal(np.asarray(cand), np.asarray(cand_ref))
    np.testing.assert_allclose(np.asarray(stats), np.asarray(stats_ref),
                               rtol=1e-12)
    # stats columns: dot_dg, dot_dd, sumsq, l1_offdiag, nnz
    assert stats.shape == (3, 5)
    assert np.all(np.asarray(stats)[:, 1] >= 0)    # <diff, diff>
    assert np.all(np.asarray(stats)[:, 4] >= 24)   # diagonal never thresholds


def test_megakernel_weighted_lane(x64):
    """Per-lane weight matrices thread through: inf weights pin entries to
    exactly zero, and the weighted kernel still matches the oracle to the
    bit."""
    from repro.kernels import ops as kops
    from repro.kernels import ref

    omega, w, tau, lam1, lam2 = _kernel_case()
    c, p = omega.shape[0], omega.shape[1]
    rng = np.random.default_rng(7)
    wts = rng.uniform(0.5, 2.0, (c, p, p))
    wts[0, 1, 2] = wts[0, 2, 1] = np.inf
    wts = jnp.asarray(wts, omega.dtype)
    cand, stats = kops.fused_path_step(omega, w, tau, lam1, lam2,
                                       weights=wts, block=8, interpret=True)
    cand_ref, stats_ref = jax.jit(ref.fused_path_step)(
        omega, w, tau, lam1, lam2, weights=wts)
    np.testing.assert_array_equal(np.asarray(cand), np.asarray(cand_ref))
    np.testing.assert_allclose(np.asarray(stats), np.asarray(stats_ref),
                               rtol=1e-12)
    assert float(cand[0, 1, 2]) == 0.0 and float(cand[0, 2, 1]) == 0.0


def test_megakernel_prime_p_falls_back_to_full_tile(x64):
    """p with no divisor <= block runs as one p x p tile — still exact."""
    from repro.kernels import ops as kops
    from repro.kernels import ref
    from repro.kernels.pathstep import _block_edge

    assert _block_edge(512, 256) == 256
    assert _block_edge(48, 256) == 48
    assert _block_edge(24, 8) == 8
    assert _block_edge(7, 4) == 7     # prime: whole matrix is the tile
    args = _kernel_case(c=2, p=7, seed=3)
    cand, stats = kops.fused_path_step(*args, block=4, interpret=True)
    cand_ref, stats_ref = jax.jit(ref.fused_path_step)(*args)
    np.testing.assert_array_equal(np.asarray(cand), np.asarray(cand_ref))
    np.testing.assert_allclose(np.asarray(stats), np.asarray(stats_ref),
                               rtol=1e-12)


def test_megakernel_drives_the_engine(x64):
    """use_pallas=True routes chunk trials through the megakernel and
    must leave trajectories unchanged: identical per-lane iteration
    counts and solutions matching the jnp trial path."""
    prob = graphs.make_problem("chain", p=24, n=80, seed=2)
    s = jnp.asarray(prob.s, jnp.float64)
    grid = jnp.asarray(np.geomspace(0.35, 0.12, 4))
    kw = dict(variant="cov", tol=1e-6, max_iters=300)
    base = batch.solve_path_batched(s, grid, 0.05, **kw)
    fused = batch.solve_path_batched(s, grid, 0.05, use_pallas=True, **kw)
    np.testing.assert_array_equal(np.asarray(fused.iters),
                                  np.asarray(base.iters))
    np.testing.assert_allclose(np.asarray(fused.omega),
                               np.asarray(base.omega), rtol=0, atol=1e-9)


# ---------------------------------------------------------------------------
# one chunk program across varying live-lane counts
# ---------------------------------------------------------------------------

def test_chunk_program_reused_across_live_lane_counts(x64, recompile_guard):
    """The compaction contract: within one capacity tier, any number of
    live lanes (the rest select-frozen) must hit the SAME compiled chunk
    program — compaction changes data, never the executable."""
    from functools import partial

    from repro.core.penalty import PenaltySpec

    p, c = 8, 4
    prob = graphs.make_problem("chain", p=p, n=40, seed=1)
    s = jnp.asarray(prob.s, jnp.float64)
    spec = PenaltySpec("l1", jnp.full((c,), 0.2, jnp.float64),
                       jnp.zeros((c,), jnp.float64))
    ridge = jnp.zeros((c,), jnp.float64)
    om0 = jnp.broadcast_to(jnp.eye(p, dtype=jnp.float64)[None], (c, p, p))
    statics = dict(variant="cov", tol=1e-6, max_iters=300, max_ls=30,
                   tau_schedule="restart", chunk=8, stacked=False,
                   tau_init=1.0, use_pallas=False)

    def run(n_live):
        lanes = batch._init_lanes(s, ridge, om0, variant="cov",
                                  stacked=False, tau_schedule="restart",
                                  tau_init=1.0)
        lanes = lanes._replace(done=jnp.arange(c) >= n_live)
        out, occ = batch._path_chunk(s, ridge, lanes, spec, **statics)
        out.omega.block_until_ready()
        return occ

    occ4 = run(c)   # warm the (capacity=4, statics) cache entry
    with recompile_guard(chunk=batch._path_chunk):
        occ2, occ1 = run(2), run(1)
    assert int(np.asarray(occ4).max()) == 4
    assert int(np.asarray(occ2).max()) == 2
    assert int(np.asarray(occ1).max()) == 1


# ---------------------------------------------------------------------------
# host-BLAS stepper and pilot warm starts
# ---------------------------------------------------------------------------

def test_host_gemm_matches_xla_and_is_wave_invariant(chain48):
    """gemm='host' replays the same flat-step recurrence through the
    platform BLAS: solutions agree tightly with the XLA route (identical
    iteration counts), and its wave partitioning is bit-invariant —
    solving all lanes at once equals solving one lane per wave."""
    if jax.default_backend() != "cpu":
        pytest.skip("host BLAS stepper is CPU-only")
    kw = dict(variant="cov", tol=1e-6, max_iters=400)
    xla = batch.solve_path_batched(chain48, jnp.asarray(GRID), 0.05, **kw)
    host = batch.solve_path_batched(chain48, jnp.asarray(GRID), 0.05,
                                    gemm="host", **kw)
    solo = batch.solve_path_batched(chain48, jnp.asarray(GRID), 0.05,
                                    gemm="host", max_lanes=1, **kw)
    np.testing.assert_array_equal(np.asarray(host.iters),
                                  np.asarray(xla.iters))
    np.testing.assert_allclose(np.asarray(host.omega),
                               np.asarray(xla.omega), rtol=0, atol=1e-8)
    np.testing.assert_array_equal(np.asarray(host.omega),
                                  np.asarray(solo.omega))
    np.testing.assert_array_equal(np.asarray(host.iters),
                                  np.asarray(solo.iters))


def test_pilot_warm_start_lanes_equal_their_sequential_twins(chain48):
    """warm_start='pilot' must preserve the engine's exactness contract:
    the pilot lane bit-equals a cold single-lane solve, every other lane
    bit-equals a single-lane solve warm-started from the pilot's
    solution."""
    kw = dict(variant="cov", tol=1e-6, max_iters=400)
    res, stats = batch.solve_path_batched(
        chain48, jnp.asarray(GRID), 0.05, warm_start="pilot",
        return_stats=True, **kw)
    pilot = stats.pilot_lane
    assert 0 <= pilot < len(GRID)
    for i in (pilot, 0, len(GRID) - 1):
        om0 = None if i == pilot else res.omega[pilot]
        solo = batch.solve_path_batched(
            chain48, jnp.asarray(GRID[i:i + 1]), 0.05, omega0=om0, **kw)
        np.testing.assert_array_equal(np.asarray(res.omega[i]),
                                      np.asarray(solo.omega[0]))
        assert int(res.iters[i]) == int(solo.iters[0])
        assert int(res.ls_total[i]) == int(solo.ls_total[0])


def test_pilot_warm_start_rejects_explicit_omega0(chain48):
    with pytest.raises(ValueError, match="pilot"):
        batch.solve_path_batched(chain48, jnp.asarray(GRID), 0.05,
                                 warm_start="pilot",
                                 omega0=jnp.eye(48, dtype=jnp.float64))


# ---------------------------------------------------------------------------
# cost model: fit_path(mode="auto")
# ---------------------------------------------------------------------------

def test_cost_model_mode_decision():
    from repro.core.costmodel import (choose_path_mode,
                                      predict_batched_speedup)

    grid = np.geomspace(0.4, 0.08, 8)
    # trivial grids never batch
    assert choose_path_mode([0.2]) == "sequential"
    assert choose_path_mode([]) == "sequential"
    # the tuned CPU config is predicted well past the hysteresis threshold
    tuned = dict(tau_schedule="greedy", chunk=8, gemm="host",
                 warm_start="pilot")
    s_tuned = predict_batched_speedup(grid, **tuned)
    s_plain = predict_batched_speedup(grid)
    assert s_tuned > 1.05
    assert choose_path_mode(grid, **tuned) == "batched"
    # each tuned ingredient helps: the plain config predicts slower
    assert s_tuned > s_plain


def test_fit_path_auto_mode_routes_and_surfaces_stats(chain48):
    from repro.estimator import ConcordEstimator, SolverConfig

    est = ConcordEstimator(
        lam1=0.2, lam2=0.05,
        config=SolverConfig(backend="reference", variant="cov", tol=1e-5,
                            tau_schedule="greedy", batch_chunk=8,
                            batch_warm_start="pilot"))
    grid = list(np.geomspace(0.4, 0.08, 8))
    path = est.fit_path(s=chain48, n_samples=150, lam1_grid=grid,
                        mode="auto")
    if jax.default_backend() == "cpu":
        assert path.mode == "batched"
        assert path.batch_stats is not None
        assert "compact" in path.batch_stats.summary()
        assert path.batch_stats.summary() in path.summary()
    # a single point can never amortize a batched program
    single = est.fit_path(s=chain48, n_samples=150, lam1_grid=[0.2],
                          mode="auto")
    assert single.mode == "sequential"
    assert single.batch_stats is None


def test_solver_config_validates_batch_knobs():
    from repro.estimator import SolverConfig

    with pytest.raises(ValueError, match="tau_schedule"):
        SolverConfig(tau_schedule="bogus")
    with pytest.raises(ValueError, match="batch_schedule"):
        SolverConfig(batch_schedule="bogus")
    with pytest.raises(ValueError, match="batch_chunk"):
        SolverConfig(batch_chunk=0)
    with pytest.raises(ValueError, match="batch_max_lanes"):
        SolverConfig(batch_max_lanes=0)
    with pytest.raises(ValueError, match="batch_gemm"):
        SolverConfig(batch_gemm="cublas")
    with pytest.raises(ValueError, match="batch_warm_start"):
        SolverConfig(batch_warm_start="bogus")


def test_fit_batch_surfaces_run_stats():
    from repro.estimator import fit_batch

    xs = np.stack([graphs.make_problem("chain", p=16, n=60, seed=k).x
                   for k in range(3)])
    rep = fit_batch(x=xs, lam1=[0.2, 0.25, 0.3], backend="reference",
                    variant="obs", tol=1e-5)
    assert rep.stats is not None and rep.stats.n_lanes == 3
    assert rep.stats.summary() in rep.summary()
