"""Pallas kernel sweeps vs the pure-jnp oracles (interpret=True on CPU)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize("shape", [(64, 64), (256, 300), (100, 50),
                                   (513, 257), (128, 1024)])
@pytest.mark.parametrize("dtype", [jnp.float32])
@pytest.mark.parametrize("alpha", [0.0, 0.3, 2.0])
def test_fused_prox_sweep(shape, dtype, alpha, rng):
    z = rng.standard_normal(shape).astype(dtype)
    p = min(shape)
    mask = np.zeros(shape, np.float32)
    mask[np.arange(p), np.arange(p)] = 1
    z[np.arange(p), np.arange(p)] = \
        np.abs(z[np.arange(p), np.arange(p)]) + 0.1
    out, ld, l1, ss, md, bnnz = ops.fused_prox_stats(
        jnp.asarray(z), jnp.asarray(mask), alpha)
    ro, rld, rl1, rss, rmd, rbnnz = ref.fused_prox_stats(
        jnp.asarray(z), jnp.asarray(mask), alpha)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ro), rtol=1e-6)
    np.testing.assert_allclose(float(ld), float(rld), rtol=1e-4)
    np.testing.assert_allclose(float(l1), float(rl1), rtol=1e-4)
    np.testing.assert_allclose(float(ss), float(rss), rtol=1e-4)
    np.testing.assert_allclose(float(md), float(rmd), rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(bnnz), np.asarray(rbnnz))


@pytest.mark.parametrize("shape,block", [((128, 96), (32, 32)),
                                         ((100, 70), (32, 32)),
                                         ((64, 64), (16, 32))])
def test_fused_prox_block_nnz_is_exact_occupancy(shape, block, rng):
    """The kernel's nnz stats lane IS the block-occupancy mask: it must
    match the jnp.nonzero-derived per-tile counts of the prox output."""
    z = rng.standard_normal(shape).astype(np.float32)
    p = min(shape)
    mask = np.zeros(shape, np.float32)
    mask[np.arange(p), np.arange(p)] = 1
    out, *_, bnnz = ops.fused_prox_stats(jnp.asarray(z), jnp.asarray(mask),
                                         0.8, block=block)
    out_np = np.asarray(out)
    bm = min(block[0], shape[0])
    bn = min(block[1], shape[1])
    gm, gn = -(-shape[0] // bm), -(-shape[1] // bn)
    expect = np.zeros((gm, gn))
    for i, j in zip(*np.nonzero(out_np)):
        expect[i // bm, j // bn] += 1
    np.testing.assert_array_equal(np.asarray(bnnz), expect)


@pytest.mark.parametrize("p,m,bs,density", [
    (96, 64, 16, 0.4), (128, 128, 32, 0.1), (64, 256, 16, 1.0),
    (64, 32, 16, 0.0),  # fully empty -> builder inserts zero blocks
])
def test_blocksparse_sweep(p, m, bs, density, rng):
    a = rng.standard_normal((p, p)).astype(np.float32)
    keep = rng.random((p // bs, p // bs)) < density
    for r in range(p // bs):
        for c in range(p // bs):
            if not keep[r, c]:
                a[r * bs:(r + 1) * bs, c * bs:(c + 1) * bs] = 0
    vals, rows, cols = ref.dense_to_block_csr(a, bs)
    b = rng.standard_normal((p, m)).astype(np.float32)
    out = ops.blocksparse_matmul(jnp.asarray(vals), jnp.asarray(rows),
                                 jnp.asarray(cols), jnp.asarray(b))
    np.testing.assert_allclose(np.asarray(out), a @ b, rtol=1e-4, atol=1e-4)


def test_blocksparse_dense_roundtrip(rng):
    a = rng.standard_normal((64, 64)).astype(np.float32)
    vals, rows, cols = ref.dense_to_block_csr(a, 16)
    back = ref.block_csr_to_dense(jnp.asarray(vals), jnp.asarray(rows),
                                  jnp.asarray(cols), 64)
    np.testing.assert_allclose(np.asarray(back), a, rtol=1e-6)


def test_blocksparse_rejects_non_contiguous_row_revisit():
    """row_idx [0, 1, 0] revisits block-row 0 after writing block-row 1:
    the kernel's sequential accumulation would flush and then clobber
    block-row 0, so the wrapper must refuse at trace time (the CA401
    revisit hazard, caught before any wrong numbers ship)."""
    vals = jnp.ones((3, 4, 4), jnp.float32)
    rows = jnp.asarray([0, 1, 0], jnp.int32)
    cols = jnp.asarray([0, 1, 1], jnp.int32)
    b = jnp.ones((8, 8), jnp.float32)
    with pytest.raises(ValueError, match="non-contiguously"):
        ops.blocksparse_matmul(vals, rows, cols, b)


def test_blocksparse_contiguous_duplicate_rows_accumulate(rng):
    """Duplicate row ids in one contiguous CSR run are the accumulation
    path, not a hazard: the result must match the dense product."""
    bs, p, m = 4, 8, 8
    a = rng.standard_normal((p, p)).astype(np.float32)
    a[bs:, :bs] = 0.0          # block (1, 0) empty -> rows [0, 0, 1]
    vals, rows, cols = ref.dense_to_block_csr(a, bs)
    np.testing.assert_array_equal(np.asarray(rows), [0, 0, 1])
    b = rng.standard_normal((p, m)).astype(np.float32)
    out = ops.blocksparse_matmul(jnp.asarray(vals), jnp.asarray(rows),
                                 jnp.asarray(cols), jnp.asarray(b))
    np.testing.assert_allclose(np.asarray(out), a @ b, rtol=1e-4,
                               atol=1e-4)


def test_blocksparse_validation_skips_traced_row_idx(rng):
    """Under jit the row table is a tracer: the host-side contiguity
    check must stand aside (the static CA401 pass owns that case) and
    tracing must succeed."""
    import jax

    bs, p, m = 4, 8, 8
    a = rng.standard_normal((p, p)).astype(np.float32)
    vals, rows, cols = ref.dense_to_block_csr(a, bs)
    b = rng.standard_normal((p, m)).astype(np.float32)

    @jax.jit
    def run(v, r, c, bb):
        return ops.blocksparse_matmul(v, r, c, bb, interpret=True)

    out = run(jnp.asarray(vals), jnp.asarray(rows), jnp.asarray(cols),
              jnp.asarray(b))
    np.testing.assert_allclose(np.asarray(out), a @ b, rtol=1e-4,
                               atol=1e-4)


def test_interpret_override_cannot_leak_part1():
    """Pins the module-global interpret override; the autouse conftest
    guard must restore it before part2 (file order is run order)."""
    ops.set_interpret(True)
    assert ops.interpret_default() is True


def test_interpret_override_cannot_leak_part2():
    assert ops._INTERPRET_OVERRIDE is None      # part1's pin was undone
    ops.set_interpret(False)
    ops.reset_interpret()
    assert ops._INTERPRET_OVERRIDE is None
    with pytest.raises(TypeError):
        ops.set_interpret("yes")


FLASH_CASES = [
    # B, Hq, Hkv, Lq, Lkv, D, causal, window, softcap
    (2, 4, 2, 128, 128, 64, True, None, None),
    (1, 4, 4, 256, 256, 32, True, 64, None),
    (1, 2, 1, 128, 128, 64, True, None, 30.0),
    (1, 2, 2, 64, 192, 32, True, None, None),    # Lq < Lkv
    (2, 2, 2, 128, 128, 64, False, None, None),
    (1, 2, 2, 160, 160, 32, True, None, None),   # edge tiles
    (1, 8, 2, 128, 128, 32, True, 32, 10.0),     # everything at once
]


@pytest.mark.parametrize("case", FLASH_CASES)
def test_flash_attention_sweep(case, rng):
    B, Hq, Hkv, Lq, Lkv, D, causal, window, cap = case
    q = rng.standard_normal((B, Hq, Lq, D)).astype(np.float32)
    k = rng.standard_normal((B, Hkv, Lkv, D)).astype(np.float32)
    v = rng.standard_normal((B, Hkv, Lkv, D)).astype(np.float32)
    o = ops.flash_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                            causal=causal, window=window, softcap=cap,
                            block_q=64, block_k=64)
    r = ref.attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                      causal=causal, window=window, softcap=cap)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r),
                               rtol=2e-3, atol=2e-3)


def test_flash_attention_bf16(rng):
    B, H, L, D = 1, 2, 128, 64
    q = (rng.standard_normal((B, H, L, D)) * 0.5).astype(np.float32)
    k = (rng.standard_normal((B, H, L, D)) * 0.5).astype(np.float32)
    v = (rng.standard_normal((B, H, L, D)) * 0.5).astype(np.float32)
    qb, kb, vb = (jnp.asarray(a, jnp.bfloat16) for a in (q, k, v))
    o = ops.flash_attention(qb, kb, vb, block_q=64, block_k=64)
    r = ref.attention(qb, kb, vb)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32),
                               rtol=5e-2, atol=5e-2)


def test_mea_attention_matches_flash_oracle(rng):
    """The XLA-native chunked attention (models/layers.py) and the Pallas
    kernel agree with the same oracle."""
    from repro.models.layers import mea_attention
    B, H, L, D = 1, 2, 128, 32
    q = jnp.asarray(rng.standard_normal((B, H, L, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, H, L, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, H, L, D)), jnp.float32)
    pos = jnp.arange(L)
    o1 = mea_attention(q, k, v, pos, pos, jnp.asarray(0, jnp.int32),
                       True, D ** -0.5, None, 32)
    o2 = ops.flash_attention(q, k, v, block_q=64, block_k=64)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=2e-3, atol=2e-3)
