"""Deterministic stand-in for ``hypothesis`` when it is not installed.

The test modules import

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _hypothesis_fallback import given, settings, st

With real hypothesis installed (see requirements-dev.txt) the suite gets
full randomized property testing.  Without it, this module runs each
``@given`` test over the cartesian product of a small fixed sample set per
strategy (bounds + midpoint), which keeps the properties exercised and the
suite collectable on minimal CPU images.

Only the strategy combinators this repo actually uses are implemented:
``integers``, ``floats``, ``sampled_from``.
"""
from __future__ import annotations

import functools
import inspect
import itertools


class _Strategy:
    def __init__(self, samples):
        self.samples = tuple(samples)


class _Strategies:
    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        mid = (min_value + max_value) // 2
        return _Strategy(sorted({min_value, mid, max_value}))

    @staticmethod
    def floats(min_value: float, max_value: float) -> _Strategy:
        mid = 0.5 * (min_value + max_value)
        return _Strategy(sorted({float(min_value), mid, float(max_value)}))

    @staticmethod
    def sampled_from(values) -> _Strategy:
        return _Strategy(values)


st = _Strategies()


def given(*strategies: _Strategy):
    """Run the test once per combination of the strategies' fixed samples."""
    def decorate(fn):
        cases = list(itertools.product(*(s.samples for s in strategies)))

        @functools.wraps(fn)
        def wrapper():
            for case in cases:
                fn(*case)

        # hide the drawn parameters from pytest's fixture resolution
        wrapper.__signature__ = inspect.Signature()
        return wrapper

    return decorate


def settings(*args, **kwargs):
    """No-op replacement for ``hypothesis.settings``."""
    def decorate(fn):
        return fn
    return decorate
