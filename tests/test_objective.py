"""CONCORD/PseudoNet objective + gradient correctness (core/objective.py)."""
import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal CPU image — deterministic fallback
    from _hypothesis_fallback import given, settings, st

from repro.core import objective as O

jax.config.update("jax_enable_x64", False)


def _rand_problem(p=12, n=30, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((n, p)).astype(np.float32)
    s = (x.T @ x / n).astype(np.float32)
    omega = np.eye(p, dtype=np.float32) + \
        0.05 * rng.standard_normal((p, p)).astype(np.float32)
    omega = (omega + omega.T) / 2
    np.fill_diagonal(omega, np.abs(np.diag(omega)) + 0.5)
    return jnp.asarray(x), jnp.asarray(s), jnp.asarray(omega)


def test_gradient_matches_autodiff():
    """grad g (closed form) == jax.grad of the smooth objective."""
    x, s, omega = _rand_problem()
    lam2 = 0.07

    def g(om):
        w = om @ s
        return O.smooth_objective_cov(om, w, lam2)

    auto = jax.grad(g)(omega)
    # the closed form assumes a symmetric iterate; symmetrize autodiff
    auto = (auto + auto.T) / 2
    w = omega @ s
    manual = O.gradient_from_w(omega, w, lam2)
    np.testing.assert_allclose(np.asarray(auto), np.asarray(manual),
                               rtol=2e-4, atol=2e-5)


def test_cov_obs_objectives_agree():
    x, s, omega = _rand_problem()
    n = x.shape[0]
    w = omega @ s
    y = omega @ x.T
    g_cov = O.smooth_objective_cov(omega, w, 0.1)
    g_obs = O.smooth_objective_obs(omega, y, n, 0.1)
    np.testing.assert_allclose(float(g_cov), float(g_obs), rtol=1e-4)


def test_full_objectives_agree():
    x, s, omega = _rand_problem()
    a = O.full_objective_cov(omega, s, 0.3, 0.1)
    b = O.full_objective_obs(omega, x, 0.3, 0.1)
    np.testing.assert_allclose(float(a), float(b), rtol=1e-4)


@given(st.floats(0.01, 2.0), st.integers(0, 5))
@settings(max_examples=20, deadline=None)
def test_soft_threshold_properties(alpha, seed):
    """S_alpha: shrinks toward 0, exact 0 inside [-alpha, alpha],
    non-expansive."""
    rng = np.random.default_rng(seed)
    z = jnp.asarray(rng.standard_normal(50).astype(np.float32) * 3)
    out = O.soft_threshold(z, alpha)
    a = np.asarray(out)
    zz = np.asarray(z)
    assert np.all(np.abs(a) <= np.abs(zz) + 1e-6)
    assert np.all(a[np.abs(zz) <= alpha] == 0)
    assert np.all(np.sign(a[a != 0]) == np.sign(zz[a != 0]))
    # non-expansiveness vs a second point
    z2 = z + 0.5
    out2 = O.soft_threshold(z2, alpha)
    assert np.all(np.abs(np.asarray(out2) - a) <= 0.5 + 1e-6)


def test_prox_keeps_diagonal():
    _, _, omega = _rand_problem()
    out = O.prox_l1_offdiag(omega, 10.0)  # huge alpha kills all offdiag
    a = np.asarray(out)
    np.testing.assert_allclose(np.diag(a), np.diag(np.asarray(omega)))
    assert np.all(a[~np.eye(a.shape[0], dtype=bool)] == 0)


def test_sufficient_decrease_accepts_tiny_step():
    """For small enough tau the line-search condition must hold."""
    x, s, omega = _rand_problem()
    lam2 = 0.05
    w = omega @ s
    g_old = O.smooth_objective_cov(omega, w, lam2)
    grad = O.gradient_from_w(omega, w, lam2)
    tau = 1e-4
    cand = O.prox_l1_offdiag(omega - tau * grad, tau * 0.2)
    g_new = O.smooth_objective_cov(cand, cand @ s, lam2)
    assert bool(O.sufficient_decrease(g_new, g_old, cand, omega, grad, tau))
