"""The unified ``repro.estimator`` facade: SolverConfig validation, backend
registry, backend agreement with the reference oracle, and warm-started
regularization paths."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import graphs
from repro.core.prox import solve_reference
from repro.estimator import (
    ConcordEstimator,
    FitReport,
    SolverConfig,
    available_backends,
    fit,
    get_backend,
    register_backend,
)


@pytest.fixture(scope="module")
def chain_problem():
    return graphs.make_problem("chain", p=48, n=150, seed=1)


REF_CONFIG = SolverConfig(backend="reference", variant="cov",
                          tol=1e-6, max_iters=300)


# ---------------------------------------------------------------------------
# (a) backend agreement with the reference oracle
# ---------------------------------------------------------------------------

def test_reference_backend_matches_fit_reference(chain_problem):
    s = jnp.asarray(chain_problem.s)
    oracle = solve_reference(s, 0.15, 0.05, tol=1e-6, max_iters=300)
    est = ConcordEstimator(lam1=0.15, lam2=0.05, config=REF_CONFIG)
    est.fit_cov(s, n_samples=150)
    np.testing.assert_allclose(np.asarray(est.omega_),
                               np.asarray(oracle.omega), atol=1e-5)
    assert est.report_.backend == "reference"
    assert est.report_.variant == "cov"
    assert est.report_.converged
    assert est.n_iter_ == int(oracle.iters)


def test_auto_backend_matches_fit_reference(chain_problem):
    """On one device, backend='auto' resolves to the reference engine and
    must agree with the oracle to 1e-5."""
    s = jnp.asarray(chain_problem.s)
    oracle = solve_reference(s, 0.15, 0.05, tol=1e-6, max_iters=300)
    est = ConcordEstimator(
        lam1=0.15, lam2=0.05,
        config=SolverConfig(backend="auto", tol=1e-6, max_iters=300))
    est.fit_cov(s, n_samples=150)
    np.testing.assert_allclose(np.asarray(est.omega_),
                               np.asarray(oracle.omega), atol=1e-5)
    assert est.report_.backend == "reference"   # resolved, not "auto"


def test_auto_backend_from_observations(chain_problem):
    """fit(X) through auto: variant is resolved by the cost model and the
    estimate still recovers the chain structure."""
    est = ConcordEstimator(
        lam1=0.15, lam2=0.05,
        config=SolverConfig(backend="auto", tol=1e-6, max_iters=300))
    est.fit(jnp.asarray(chain_problem.x))
    assert est.report_.variant in ("cov", "obs")
    s = jnp.asarray(chain_problem.s)
    oracle = solve_reference(s, 0.15, 0.05, tol=1e-6, max_iters=300)
    # cov/obs solutions of the same problem agree to solver tolerance
    np.testing.assert_allclose(np.asarray(est.omega_),
                               np.asarray(oracle.omega), atol=5e-3)


def test_functional_facade(chain_problem):
    rep = fit(s=jnp.asarray(chain_problem.s), lam1=0.2, lam2=0.05,
              backend="reference", variant="cov", tol=1e-5)
    assert isinstance(rep, FitReport)
    assert rep.converged
    assert rep.objective >= rep.objective_smooth  # l1 penalty is nonnegative
    assert rep.wall_time_s >= 0.0


# ---------------------------------------------------------------------------
# (b) warm-started paths
# ---------------------------------------------------------------------------

def test_fit_path_warm_matches_cold_with_fewer_iters(chain_problem):
    s = jnp.asarray(chain_problem.s)
    grid = [0.3, 0.25, 0.2, 0.15, 0.1]
    est = ConcordEstimator(lam2=0.05, config=REF_CONFIG)
    warm = est.fit_path(s=s, n_samples=150, lam1_grid=grid)
    cold = est.fit_path(s=s, n_samples=150, lam1_grid=grid,
                        warm_start=False)
    assert len(warm) == len(cold) == len(grid)
    # same final objective at every path point...
    for w, c in zip(warm, cold):
        assert w.lam1 == c.lam1
        assert abs(w.objective - c.objective) < 1e-3, (w.lam1, w.objective,
                                                       c.objective)
    # ...with strictly fewer cumulative outer iterations
    assert warm.total_iters < cold.total_iters, \
        (warm.total_iters, cold.total_iters)


def test_fit_path_is_sorted_descending_and_scored(chain_problem):
    s = jnp.asarray(chain_problem.s)
    path = ConcordEstimator(lam2=0.05, config=REF_CONFIG).fit_path(
        s=s, n_samples=150, lam1_grid=[0.1, 0.3, 0.2])
    assert list(path.lam1_grid) == [0.3, 0.2, 0.1]
    assert all(r.bic is not None for r in path)
    best = path.best_bic()
    assert best.bic == min(r.bic for r in path)
    # sparsity decreases (weakly) along the descending-lam1 path
    edges = [graphs.edge_count(np.asarray(r.omega)) for r in path]
    assert edges[0] <= edges[-1] + 2


def test_fit_path_from_observations(chain_problem):
    """Path from raw X (obs variant) agrees with the cov path solutions."""
    x = jnp.asarray(chain_problem.x)
    cfg = SolverConfig(backend="reference", variant="obs",
                       tol=1e-6, max_iters=300)
    path = ConcordEstimator(lam2=0.05, config=cfg).fit_path(
        x, lam1_grid=[0.2, 0.15])
    s = jnp.asarray(chain_problem.s)
    for rep in path:
        oracle = solve_reference(s, rep.lam1, 0.05, tol=1e-6, max_iters=300)
        np.testing.assert_allclose(np.asarray(rep.omega),
                                   np.asarray(oracle.omega), atol=5e-3)


# ---------------------------------------------------------------------------
# (c) validation
# ---------------------------------------------------------------------------

def test_config_rejects_bad_variant():
    with pytest.raises(ValueError, match="variant"):
        SolverConfig(variant="bogus")


def test_config_rejects_bad_knobs():
    with pytest.raises(ValueError, match="tol"):
        SolverConfig(tol=0.0)
    with pytest.raises(ValueError, match="max_iters"):
        SolverConfig(max_iters=0)
    with pytest.raises(ValueError, match="c_x"):
        SolverConfig(c_x=0)
    with pytest.raises(ValueError, match="c_omega"):
        SolverConfig(c_omega=-2)
    with pytest.raises(ValueError, match="dtype"):
        SolverConfig(dtype="float16")
    with pytest.raises(ValueError, match="backend"):
        SolverConfig(backend="")


def test_unknown_backend_raises(chain_problem):
    est = ConcordEstimator(
        lam1=0.2, config=SolverConfig(backend="nonexistent"))
    with pytest.raises(ValueError, match="unknown backend"):
        est.fit_cov(jnp.asarray(chain_problem.s))


def test_fit_path_rejects_bad_grids(chain_problem):
    s = jnp.asarray(chain_problem.s)
    est = ConcordEstimator(config=REF_CONFIG)
    with pytest.raises(ValueError, match="non-empty"):
        est.fit_path(s=s, lam1_grid=[])
    with pytest.raises(ValueError, match="finite"):
        est.fit_path(s=s, lam1_grid=[0.2, -0.1])
    with pytest.raises(ValueError, match="finite"):
        est.fit_path(s=s, lam1_grid=[0.2, float("nan")])


def test_fit_path_requires_n_samples_for_bic(chain_problem):
    s = jnp.asarray(chain_problem.s)
    est = ConcordEstimator(lam2=0.05, config=REF_CONFIG)
    with pytest.raises(ValueError, match="n_samples"):
        est.fit_path(s=s, lam1_grid=[0.2, 0.1])
    # score_bic=False lifts the requirement
    path = est.fit_path(s=s, lam1_grid=[0.2], score_bic=False)
    assert path[0].bic is None


def test_resolve_variant_respects_single_pin(chain_problem):
    """Pinning only one replication factor must yield a feasible grid (the
    tuner is constrained by the pin, not merged with it)."""
    from repro.estimator.backends import Problem, _resolve_variant
    problem = Problem.from_data(x=jnp.asarray(chain_problem.x))
    cfg = SolverConfig(backend="distributed", variant="obs", c_x=8)
    variant, c_x, c_omega = _resolve_variant(problem, 0.15, cfg, 8)
    assert (variant, c_x) == ("obs", 8)
    assert c_x * c_omega <= 8 and 8 % (c_x * c_omega) == 0
    # cov auto-tuned on many devices keeps the layout constraint
    cfg_cov = SolverConfig(backend="distributed", variant="cov")
    variant, c_x, c_omega = _resolve_variant(problem, 0.15, cfg_cov, 16)
    assert variant == "cov" and c_x == c_omega


def test_resolve_variant_rejects_infeasible_pins(chain_problem):
    from repro.estimator.backends import Problem, _resolve_variant
    problem = Problem.from_data(x=jnp.asarray(chain_problem.x))
    with pytest.raises(ValueError, match="c_x must equal c_omega"):
        _resolve_variant(problem, 0.15,
                         SolverConfig(variant="cov", c_x=4, c_omega=2), 8)
    with pytest.raises(ValueError, match="divide"):
        _resolve_variant(problem, 0.15,
                         SolverConfig(variant="obs", c_x=3, c_omega=3), 8)


def test_estimator_rejects_bad_penalties():
    with pytest.raises(ValueError, match="lam1"):
        ConcordEstimator(lam1=-0.1)
    with pytest.raises(ValueError, match="lam2"):
        ConcordEstimator(lam2=float("inf"))


def test_problem_validation():
    from repro.estimator import Problem
    with pytest.raises(ValueError, match="x .n, p. or s"):
        Problem.from_data()
    with pytest.raises(ValueError, match="square"):
        Problem.from_data(s=jnp.ones((3, 4)))


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_registry_lists_builtins_and_accepts_plugins(chain_problem):
    assert {"reference", "distributed", "auto"} <= set(available_backends())
    calls = []

    def myref(problem, penalty, config, omega0=None):
        # backends receive the penalty spec; its parameters are the
        # estimator's lam1/lam2
        calls.append(float(penalty.lam1))
        assert float(penalty.lam2) == 0.05
        return get_backend("reference")(problem, penalty,
                                        config.replace(backend="reference"),
                                        omega0)

    register_backend("myref-test", myref, overwrite=True)
    try:
        rep = fit(s=jnp.asarray(chain_problem.s), lam1=0.2, lam2=0.05,
                  backend="myref-test", variant="cov", tol=1e-5)
        assert calls == [0.2]
        assert rep.backend == "reference"
        with pytest.raises(ValueError, match="already registered"):
            register_backend("myref-test", myref)
    finally:
        import repro.estimator.backends as B
        B._REGISTRY.pop("myref-test", None)
