"""Per-architecture smoke + serve-path consistency tests.

The decode-vs-full-forward teacher-forcing test is the strongest cache
correctness check in the suite: it exercises the ring-buffered SWA
cache, GQA grouping, SSM state carry, the zamba shared-block cache and
the whisper cross-attention cache against the batch forward pass.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.models import lm, transformer as T
from repro.train.optim import AdamW


@pytest.mark.parametrize("arch", C.ARCHS)
def test_smoke_train_step(arch, rng):
    """Reduced config: one train step, finite loss, shapes preserved."""
    cfg = C.get_smoke(arch)
    params = T.init_params(cfg, jax.random.PRNGKey(0), max_len=64)
    B, L = 2, 32
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (B, L)), jnp.int32)
    frames = (jnp.zeros((B, cfg.enc_len, cfg.d_model), jnp.dtype(cfg.dtype))
              if cfg.enc_dec else None)
    batch = lm.Batch(tokens=tokens, targets=tokens, frames=frames)
    opt = AdamW()
    state = lm.TrainState(params, opt.init(params),
                          jnp.zeros((), jnp.int32))
    step = jax.jit(lm.make_train_step(cfg, opt, lambda s: 1e-3))
    new_state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params changed but kept structure/shapes
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        a.shape, b.shape), state.params, new_state.params)
    changed = jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.abs(a - b).max()) > 0,
        state.params, new_state.params))
    assert any(changed)


@pytest.mark.parametrize("arch", C.ARCHS)
def test_forward_no_nans(arch, rng):
    cfg = C.get_smoke(arch)
    params = T.init_params(cfg, jax.random.PRNGKey(1), max_len=64)
    B, L = 2, 32
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (B, L)), jnp.int32)
    frames = (jnp.zeros((B, cfg.enc_len, cfg.d_model), jnp.dtype(cfg.dtype))
              if cfg.enc_dec else None)
    h, _, _ = T.forward(cfg, params, tokens, jnp.arange(L),
                        enc_frames=frames)
    assert h.shape == (B, L, cfg.d_model)
    assert np.isfinite(np.asarray(h, np.float32)).all()
    logits = T.lm_head(cfg, params, h)
    assert logits.shape == (B, L, cfg.vocab_pad)
    # padded lanes are masked
    if cfg.vocab_pad != cfg.vocab:
        assert float(logits[..., cfg.vocab:].max()) < -1e29


DECODE_ARCHS = [a for a in C.ARCHS if a != "whisper_small"]


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_decode_matches_full_forward(arch, rng):
    """Teacher-forced decode through the cache must reproduce the full
    forward's next-token argmax at every position."""
    cfg = C.get_smoke(arch)
    # force fp32 for a tight comparison; SSD chunked-vs-recurrent orderings
    # legitimately differ at fp32, so SSM families get a looser atol
    cfg = cfg.with_(dtype="float32")
    # SSD single-step vs chunked accumulation orders drift at fp32; the
    # argmax assertion below is the exact-behaviour check for those
    tol = {"ssm": 5e-2, "hybrid": 1e-1}.get(cfg.family, 2e-3)
    max_len = 48
    params = T.init_params(cfg, jax.random.PRNGKey(2), max_len=max_len)
    B, L = 2, 24
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (B, L)), jnp.int32)

    h, _, _ = T.forward(cfg, params, tokens, jnp.arange(L))
    full_logits = T.lm_head(cfg, params, h)          # (B, L, V)

    Lp = 8
    cache = T.init_cache(cfg, B, max_len)
    prefill = lm.make_prefill(cfg, max_len)
    cache, logits = prefill(params, cache, tokens[:, :Lp])
    np.testing.assert_allclose(
        np.asarray(logits), np.asarray(full_logits[:, Lp - 1]),
        rtol=tol, atol=tol)

    decode = lm.make_decode_step(cfg)
    # teacher-forced decode: the prefill consumed tokens[0:Lp], so decode
    # feeds tokens[Lp:L-1] (feeding an already-cached token would corrupt
    # SSM state — the recurrence is not idempotent, unlike a KV write)
    for t in range(Lp, L - 1):
        cache, _ = decode(params, cache, tokens[:, t],
                          jnp.asarray(t, jnp.int32))
    # final check: the last position's logits reproduce the full forward
    h1, _, _ = T.forward(cfg, params, tokens[:, L - 1:],
                         jnp.asarray([L - 1]), caches=cache)
    step_logits = T.lm_head(cfg, params, h1)[:, 0]
    np.testing.assert_allclose(np.asarray(step_logits),
                               np.asarray(full_logits[:, L - 1]),
                               rtol=tol, atol=tol)
    # the serve path must agree on the greedy token regardless of family
    np.testing.assert_array_equal(
        np.asarray(jnp.argmax(step_logits, -1)),
        np.asarray(jnp.argmax(full_logits[:, L - 1], -1)))


def test_swa_ring_cache_correct(rng):
    """Sliding-window arch decoded far past the window: ring buffer must
    agree with the full forward (window masking) at fp32."""
    cfg = C.get_smoke("h2o_danube_1p8b").with_(dtype="float32", window=16)
    max_len = 64
    params = T.init_params(cfg, jax.random.PRNGKey(3), max_len=max_len)
    B, L = 1, 48                                   # 3x the window
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (B, L)), jnp.int32)
    h, _, _ = T.forward(cfg, params, tokens, jnp.arange(L))
    full_logits = T.lm_head(cfg, params, h)

    cache = T.init_cache(cfg, B, max_len)
    assert cache["k"].shape[3] == cfg.window       # ring is window-sized
    # (dim 0 is the stacked layer axis)
    prefill = lm.make_prefill(cfg, max_len)
    cache, _ = prefill(params, cache, tokens[:, :L - 1])
    h1, _, _ = T.forward(cfg, params, tokens[:, L - 1:],
                         jnp.asarray([L - 1]), caches=cache)
    step_logits = T.lm_head(cfg, params, h1)[:, 0]
    np.testing.assert_allclose(np.asarray(step_logits),
                               np.asarray(full_logits[:, L - 1]),
                               rtol=2e-3, atol=2e-3)


def test_chunked_attention_equals_ref_model_level(rng):
    """Whole-model equivalence of attention_impl chunked vs ref."""
    base = C.get_smoke("gemma2_27b").with_(dtype="float32")
    params = T.init_params(base, jax.random.PRNGKey(4), max_len=64)
    B, L = 2, 32
    tokens = jnp.asarray(rng.integers(0, base.vocab, (B, L)), jnp.int32)
    h1, _, _ = T.forward(base.with_(attention_impl="chunked"), params,
                         tokens, jnp.arange(L))
    h2, _, _ = T.forward(base.with_(attention_impl="ref"), params,
                         tokens, jnp.arange(L))
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                               rtol=2e-3, atol=2e-3)


def test_loss_chunking_invariant(rng):
    cfg = C.get_smoke("qwen2p5_3b").with_(dtype="float32")
    params = T.init_params(cfg, jax.random.PRNGKey(5), max_len=64)
    B, L = 2, 32
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (B, L)), jnp.int32)
    batch = lm.Batch(tokens=tokens, targets=tokens, frames=None)
    l0, _ = lm.loss_fn(cfg.with_(loss_chunk=0), params, batch)
    l1, _ = lm.loss_fn(cfg.with_(loss_chunk=8), params, batch)
    np.testing.assert_allclose(float(l0), float(l1), rtol=1e-5)


def test_microbatch_invariant(rng):
    """Gradient accumulation over micro-batches == full-batch step."""
    cfg = C.get_smoke("h2o_danube_1p8b").with_(dtype="float32")
    params = T.init_params(cfg, jax.random.PRNGKey(6), max_len=64)
    B, L = 4, 16
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (B, L)), jnp.int32)
    batch = lm.Batch(tokens=tokens, targets=tokens, frames=None)
    from functools import partial
    from repro.train.optim import accumulate_gradients
    (l1, _), g1 = accumulate_gradients(
        partial(lm.loss_fn, cfg), params, batch, 1)
    (l2, _), g2 = accumulate_gradients(
        partial(lm.loss_fn, cfg), params, batch, 4)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), rtol=1e-3, atol=1e-5), g1, g2)


def test_param_counts_match_public_numbers():
    expected = {
        "h2o_danube_1p8b": 1.8e9, "qwen2p5_3b": 3.1e9,
        "gemma2_27b": 27.2e9, "qwen1p5_110b": 111e9,
        "mixtral_8x22b": 141e9, "olmoe_1b_7b": 6.9e9,
        "chameleon_34b": 34e9, "mamba2_130m": 0.13e9,
        "zamba2_7b": 6.6e9, "whisper_small": 0.24e9,
    }
    for arch, target in expected.items():
        n = C.get(arch).param_count()
        assert abs(n - target) / target < 0.15, (arch, n, target)


def test_input_specs_cover_all_cells():
    cells = list(C.cells())
    # 10 archs x (train, prefill, decode) + 4 long_500k-capable archs
    assert len(cells) == 34
    long_archs = [a for a, s in cells if s == "long_500k"]
    assert set(long_archs) == {"h2o_danube_1p8b", "mixtral_8x22b",
                               "mamba2_130m", "zamba2_7b"}
    for arch, shape in cells[:6]:
        spec = C.input_specs(C.get(arch), shape)
        assert spec["kind"] in ("train", "prefill", "decode")


def test_virtual_expert_split_is_exact(rng):
    """ep_virtual: splitting each expert's d_ff into v independent
    'virtual experts' is an exact decomposition of the expert MLP
    (elementwise gating slices along f; partial down-projections add)."""
    from repro.models import layers as L
    base = C.get_smoke("mixtral_8x22b").with_(
        dtype="float32", expert_sharding="ep", capacity_factor=8.0)
    virt = base.with_(expert_sharding="ep_virtual", virtual_split=2)
    E, d, f = base.n_experts, base.d_model, base.d_ff_expert
    k = jax.random.PRNGKey(7)
    p_base = L.build_params(L.moe_schema(base), k, jnp.float32)
    # re-layout base weights into virtual form: f split into 2 slices
    def split_up(w):   # (E, d, f) -> (2E, d, f/2)
        return w.reshape(E, d, 2, f // 2).transpose(0, 2, 1, 3) \
                .reshape(2 * E, d, f // 2)
    def split_down(w):  # (E, f, d) -> (2E, f/2, d)
        return w.reshape(E, 2, f // 2, d).reshape(2 * E, f // 2, d)
    p_virt = {
        "moe_router": p_base["moe_router"],
        "moe_wg": split_up(p_base["moe_wg"]),
        "moe_wu": split_up(p_base["moe_wu"]),
        "moe_wd": split_down(p_base["moe_wd"]),
    }
    x = jnp.asarray(rng.standard_normal((2, 16, d)), jnp.float32) * 0.3
    y_base, _ = L.apply_moe(base, p_base, x)
    y_virt, _ = L.apply_moe(virt, p_virt, x)
    np.testing.assert_allclose(np.asarray(y_base), np.asarray(y_virt),
                               rtol=1e-4, atol=1e-5)


def test_positions_in_expert_matches_naive(rng):
    from repro.models.layers import positions_in_expert
    ids = rng.integers(0, 9, 1500).astype(np.int32)
    pos = np.asarray(positions_in_expert(jnp.asarray(ids), 9, block=128))
    cnt = np.zeros(9, np.int64)
    for i, e in enumerate(ids):
        assert pos[i] == cnt[e]
        cnt[e] += 1
