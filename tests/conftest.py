"""Shared test fixtures.

NOTE: no XLA_FLAGS here on purpose — smoke tests must see ONE device.
Multi-device tests spawn subprocesses with their own device-count flags
(see helpers.run_with_devices).
"""
import os
import subprocess
import sys

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def run_with_devices(code: str, n_devices: int = 8, timeout: int = 560):
    """Run a python snippet in a fresh process with n virtual devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=timeout)
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed:\nSTDOUT:\n{proc.stdout}\n"
            f"STDERR:\n{proc.stderr[-4000:]}")
    return proc.stdout


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
