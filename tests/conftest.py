"""Shared test fixtures.

NOTE: no XLA_FLAGS here on purpose — smoke tests must see ONE device.
Multi-device tests spawn subprocesses with their own device-count flags
(see helpers.run_with_devices).
"""
import os
import subprocess
import sys

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def run_with_devices(code: str, n_devices: int = 8, timeout: int = 560):
    """Run a python snippet in a fresh process with n virtual devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=timeout)
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed:\nSTDOUT:\n{proc.stdout}\n"
            f"STDERR:\n{proc.stderr[-4000:]}")
    return proc.stdout


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(autouse=True)
def _interpret_override_guard():
    """Restore the process-wide Pallas interpret override after every
    test so a ``kernels.ops.set_interpret(...)`` call inside one test can
    never leak into the next (the override is module-global state)."""
    from repro.kernels import ops

    prev = ops._INTERPRET_OVERRIDE
    yield
    ops.set_interpret(prev)


@pytest.fixture
def recompile_guard():
    """Context-manager factory asserting a region compiles NOTHING new on
    the watched jitted callables::

        with recompile_guard(solve=prox._solve_reference):
            est.fit_path(...)        # same shapes/statics -> cache holds

    Backed by ``repro.analysis.recompile`` (the same guard the CA202
    jaxpr rule uses); skips when the running jax build doesn't expose
    compiled-cache introspection."""
    import contextlib

    from repro.analysis.recompile import RecompileGuard, cache_size

    @contextlib.contextmanager
    def watch(**watched):
        if any(cache_size(f) is None for f in watched.values()):
            pytest.skip("jit cache introspection not available")
        guard = RecompileGuard(watched)
        with guard:
            yield guard
        grew = guard.grew()
        assert not grew, (
            f"unexpected recompile(s) at unchanged shapes/statics: {grew}")

    return watch
