"""Paper Lemmas 3.1-3.5 cost model + tuner (core/costmodel.py)."""
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal CPU image — deterministic fallback
    from _hypothesis_fallback import given, settings, st

from repro.core.costmodel import (EDISON, Machine,
                                  ProblemShape, cov_costs, cov_is_cheaper,
                                  enumerate_configs, obs_costs, tune)


def test_lemma31_crossover():
    """Cov cheaper iff d/p < (n/(p-n)) / t (Lemma 3.1)."""
    # d small, n moderate -> Cov wins
    assert cov_is_cheaper(ProblemShape(p=40000, n=10000, d=2, t=10))
    # d large, n tiny -> Obs wins
    assert not cov_is_cheaper(ProblemShape(p=40000, n=100, d=60, t=10))
    # n >= p -> always Cov
    assert cov_is_cheaper(ProblemShape(p=1000, n=2000, d=900, t=10))


def test_flop_formulas_match_lemma():
    s = ProblemShape(p=1000, n=100, d=10, s=20, t=5.0)
    m = Machine()
    cov = cov_costs(s, 16, 1, 1, m)
    obs = obs_costs(s, 16, 1, 1, m)
    assert cov.flops == 2 * 100 * 1000**2 + 2 * 10 * 1000**2 * (20 * 5 + 1)
    assert obs.flops == 2 * 100 * 1000**2 * 20 + \
        2 * 10 * 100 * 1000 * (20 * 5 + 1)


@given(st.integers(1, 6), st.integers(1, 6))
@settings(max_examples=30, deadline=None)
def test_replication_reduces_bandwidth(cx_pow, co_pow):
    """Lemma 3.3: words ~ nnz(R)/c_F — more replication, fewer words
    in the rotation terms."""
    P = 64
    cx, co = 2 ** (cx_pow % 4), 2 ** (co_pow % 4)
    if cx * co > P:
        return
    import math
    s = ProblemShape(p=4096, n=256, d=16, s=10, t=5.0)
    m = Machine()
    base = obs_costs(s, P, 1, 1, m)
    rep = obs_costs(s, P, cx, co, m)
    # the implementation's W decomposes exactly as Lemma 3.3 writes it:
    # rotation term (shrinks with c_omega) + transpose term
    def expected_words(cx_, co_):
        q = max(P / cx_**2, P / co_**2)
        rot = s.s * (s.t + 1) * s.n * s.p / co_
        transpose = s.p**2 * (cx_ * co_ / P) * q * math.log2(max(q, 2))
        return rot, transpose
    rot_b, tr_b = expected_words(1, 1)
    rot_r, tr_r = expected_words(cx, co)
    assert base.words == pytest.approx(rot_b + tr_b)
    assert rep.words == pytest.approx(rot_r + tr_r)
    # more Omega replication -> fewer words in the rotation term
    assert rot_r <= rot_b


def test_latency_saving_factor():
    """Lemma 3.3: L = P/(c_R c_F) messages per multiply."""
    s = ProblemShape(p=4096, n=256, d=16, s=10, t=5.0)
    m = Machine()
    l11 = obs_costs(s, 64, 1, 1, m).messages
    l44 = obs_costs(s, 64, 4, 4, m).messages
    assert l44 < l11 / 4  # at least the 16x rotation saving on main term


def test_tuner_returns_feasible():
    s = ProblemShape(p=10000, n=500, d=20)
    best = tune(s, 64)
    assert best.c_x * best.c_omega <= 64
    assert best.variant in ("cov", "obs")


def test_tuner_respects_memory_cap():
    m = Machine(hbm_bytes=1e6)  # absurdly small HBM
    s = ProblemShape(p=100000, n=500, d=20)
    with pytest.raises(ValueError):
        tune(s, 4, m)


def test_replication_beats_no_replication_modeled():
    """Fig-3 qualitative claim: some (c_X, c_Omega) > (1,1)."""
    s = ProblemShape(p=40000, n=100, d=4, s=30, t=10.0)
    cfgs = enumerate_configs(s, 512, Machine(), variants=("obs",))
    best = min(cfgs, key=lambda cb: cb.total)
    base = [c for c in cfgs if c.c_x == 1 and c.c_omega == 1][0]
    assert best.total < base.total
    assert best.c_x * best.c_omega > 1


def test_edison_machine_is_slower():
    s = ProblemShape(p=10000, n=500, d=20)
    t_tpu = tune(s, 64).total
    t_edison = tune(s, 64, EDISON).total
    assert t_edison > t_tpu
