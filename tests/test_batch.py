"""Batched multi-problem solve engine (core.batch + estimator surface) and
the solver-loop status-reporting fixes that ride with it: the stalled
line-search flag, the distributed-shim grid validation, and the compact
occupancy-mask dtype."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import batch, graphs, matops
from repro.core.prox import cov_ops, prox_gradient, solve_reference


@pytest.fixture(scope="module")
def chain_problem():
    return graphs.make_problem("chain", p=48, n=150, seed=1)


# ---------------------------------------------------------------------------
# stalled line-search flag (the converged=True lie)
# ---------------------------------------------------------------------------

def test_exhausted_line_search_reports_stalled_not_converged(chain_problem):
    """With max_ls=1 and a huge initial step, the single line-search trial
    overshoots (non-positive diagonal -> +inf objective) and the search
    exhausts without accepting: the solver must report stalled=True and
    converged=False, and the iterate must not move (the old code zeroed
    delta and claimed convergence)."""
    s = jnp.asarray(chain_problem.s)
    data = {"s": s, "lam2": jnp.asarray(0.05, s.dtype)}
    om0 = jnp.eye(s.shape[0], dtype=s.dtype)
    r = prox_gradient(om0, data, cov_ops(), lam1=0.2, tol=1e-6,
                      max_ls=1, tau_init=1e6)
    assert bool(r.stalled)
    assert not bool(r.converged)
    assert int(r.iters) == 1
    np.testing.assert_array_equal(np.asarray(r.omega), np.asarray(om0))


def test_genuine_convergence_is_not_stalled(chain_problem):
    r = solve_reference(jnp.asarray(chain_problem.s), 0.2, 0.05, tol=1e-6)
    assert bool(r.converged) and not bool(r.stalled)


def test_stalled_threads_through_fit_report(chain_problem):
    from repro.estimator import fit

    rep = fit(s=jnp.asarray(chain_problem.s), lam1=0.2, lam2=0.05,
              n_samples=150, backend="reference", variant="cov", tol=1e-6)
    assert rep.stalled is False and rep.converged is True
    assert "STALLED" not in rep.summary()


def test_stalled_threads_through_distributed_result(chain_problem):
    """FitResult/_scalar_specs carry the flag through shard_map."""
    from repro.comm.grid import Grid1p5D
    from repro.core.distributed import fit_cov

    r = fit_cov(jnp.asarray(chain_problem.s), 0.2, 0.05,
                grid=Grid1p5D(1, 1, 1), tol=1e-6, max_iters=200)
    assert bool(r.converged) and not bool(r.stalled)


# ---------------------------------------------------------------------------
# deprecated distributed.fit shim: no silent replication rewrite
# ---------------------------------------------------------------------------

def test_fit_shim_raises_on_infeasible_pinned_grid(chain_problem):
    from repro.core import distributed as dist

    with pytest.warns(DeprecationWarning):
        with pytest.raises(ValueError, match="must divide"):
            dist.fit(s=jnp.asarray(chain_problem.s), lam1=0.2,
                     variant="cov", c_x=3, c_omega=3)


def test_fit_shim_raises_on_pinned_cov_layout_mismatch(chain_problem):
    """A pinned c_omega != c_x for Cov must raise (the old code silently
    coerced c_omega = c_x), matching estimator.backends._check_grid."""
    from repro.core import distributed as dist

    with pytest.warns(DeprecationWarning):
        with pytest.raises(ValueError, match="must equal"):
            dist.fit(s=jnp.asarray(chain_problem.s), lam1=0.2,
                     variant="cov", c_x=1, c_omega=2, n_devices=2)


# ---------------------------------------------------------------------------
# compact occupancy-mask dtype
# ---------------------------------------------------------------------------

def test_block_mask_dtype_is_compact():
    """The occupancy mask travels the 1.5D ring with the operand, so it
    must be MASK_DTYPE (1 byte) regardless of the operand's dtype."""
    a32 = jnp.zeros((16, 16), jnp.float32).at[0, 0].set(1.0)
    assert matops.block_mask(a32, 4).dtype == matops.MASK_DTYPE
    prev_x64 = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", True)
    try:
        a64 = jnp.zeros((16, 16), jnp.float64).at[3, 9].set(2.0)
        m = matops.block_mask(a64, 4)
        assert m.dtype == matops.MASK_DTYPE
        assert jnp.dtype(matops.MASK_DTYPE).itemsize == 1
        assert int(m.sum()) == 1
    finally:
        jax.config.update("jax_enable_x64", prev_x64)


# ---------------------------------------------------------------------------
# batched engine vs the sequential reference (f64, per project memory
# f32 fixed points scatter ~1e-4, so agreement is asserted at 1e-5 in f64)
# ---------------------------------------------------------------------------

def test_batched_path_matches_sequential_reference_f64():
    prev_x64 = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", True)
    try:
        prob = graphs.make_problem("chain", p=48, n=150, seed=0)
        s = jnp.asarray(prob.s, jnp.float64)
        grid = np.geomspace(0.4, 0.1, 6)
        seq = [solve_reference(s, float(l1), 0.05, variant="cov",
                               tol=1e-7, max_iters=400) for l1 in grid]
        bat = batch.solve_path_batched(s, jnp.asarray(grid), 0.05,
                                       variant="cov", tol=1e-7,
                                       max_iters=400)
        for i in range(len(grid)):
            np.testing.assert_allclose(np.asarray(bat.omega[i]),
                                       np.asarray(seq[i].omega),
                                       rtol=0, atol=1e-5)
            # finished lanes freeze: per-problem telemetry is identical to
            # what the sequential solve reports
            assert int(bat.iters[i]) == int(seq[i].iters)
            assert int(bat.ls_total[i]) == int(seq[i].ls_total)
            assert bool(bat.converged[i]) == bool(seq[i].converged)
            assert not bool(bat.stalled[i])
    finally:
        jax.config.update("jax_enable_x64", prev_x64)


def test_batched_stacked_datasets_match_per_problem_solves_f64():
    prev_x64 = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", True)
    try:
        lam1s = [0.2, 0.25, 0.3]
        xs = jnp.stack([
            jnp.asarray(graphs.make_problem("chain", p=32, n=100,
                                            seed=k).x, jnp.float64)
            for k in range(3)])
        bat = batch.solve_batch(xs, jnp.asarray(lam1s), 0.05, variant="obs",
                                tol=1e-6)
        for k, l1 in enumerate(lam1s):
            ref = solve_reference(xs[k], l1, 0.05, variant="obs", tol=1e-6)
            np.testing.assert_allclose(np.asarray(bat.omega[k]),
                                       np.asarray(ref.omega),
                                       rtol=0, atol=1e-5)
            assert int(bat.iters[k]) == int(ref.iters)
    finally:
        jax.config.update("jax_enable_x64", prev_x64)


def test_solve_batch_rejects_unstacked_data():
    with pytest.raises(ValueError, match="stacked"):
        batch.solve_batch(jnp.eye(8), 0.2)


# ---------------------------------------------------------------------------
# estimator surface: fit_path(mode="batched"), fit_batch, BatchReport
# ---------------------------------------------------------------------------

def test_fit_path_batched_mode_matches_sequential(chain_problem,
                                                  recompile_guard):
    from repro.estimator import ConcordEstimator, SolverConfig

    x = jnp.asarray(chain_problem.x)
    grid = [0.35, 0.25, 0.18]
    est = ConcordEstimator(lam1=0.2, lam2=0.05,
                           config=SolverConfig(backend="reference",
                                               variant="cov", tol=1e-6))
    pseq = est.fit_path(x, lam1_grid=grid, warm_start=False)
    pbat = est.fit_path(x, lam1_grid=grid, mode="batched")
    assert pbat.mode == "batched" and not pbat.warm_start
    assert pbat.lam1_grid == pseq.lam1_grid
    for a, b in zip(pseq, pbat):
        # f32 cold-vs-cold: identical trajectories, tight agreement
        np.testing.assert_allclose(np.asarray(b.omega), np.asarray(a.omega),
                                   rtol=0, atol=1e-4)
        assert b.iters == a.iters
        assert b.bic == pytest.approx(a.bic, rel=1e-3)
    assert pbat.best_bic().lam1 == pseq.best_bic().lam1
    assert "batched" in pbat.summary()
    # estimator state mirrors the last path point (sklearn convention)
    assert est.report_ is pbat.reports[-1]
    with pytest.raises(ValueError, match="mode"):
        est.fit_path(x, lam1_grid=grid, mode="vectorized")
    # a second batched path at the same grid length reuses the program
    with recompile_guard(path=batch._solve_path_batched):
        est.fit_path(x, lam1_grid=[0.33, 0.24, 0.17], mode="batched")


def test_fit_batch_smoke_stacked_datasets(recompile_guard):
    from repro.estimator import BatchReport, ConcordEstimator, SolverConfig

    xs = np.stack([graphs.make_problem("chain", p=32, n=100, seed=k).x
                   for k in range(3)])
    est = ConcordEstimator(lam1=0.2, lam2=0.05,
                           config=SolverConfig(backend="reference",
                                               variant="obs", tol=1e-5))
    rep = est.fit_batch(x=xs, lam1=[0.2, 0.25, 0.3])
    assert isinstance(rep, BatchReport)
    assert rep.n_problems == len(rep) == 3
    assert [r.lam1 for r in rep] == [0.2, 0.25, 0.3]
    for r in rep:
        assert r.backend == "batched" and r.variant == "obs"
        assert np.asarray(r.omega).shape == (32, 32)
        assert r.converged and not r.stalled
    assert rep.all_converged and not rep.any_stalled
    assert rep.wall_time_s > 0
    assert sum(r.wall_time_s for r in rep) == pytest.approx(rep.wall_time_s)
    assert "one compiled solve" in rep.summary()
    assert est.report_ is rep.reports[-1]
    # same stacked shape, new penalties -> the compiled program holds
    with recompile_guard(solve_batch=batch._solve_batch):
        est.fit_batch(x=xs, lam1=[0.22, 0.26, 0.31])


def test_fit_batch_validation():
    from repro.estimator import fit_batch

    xs = np.zeros((2, 10, 8), np.float32)
    with pytest.raises(ValueError, match="exactly one"):
        fit_batch(x=xs, s=xs, lam1=0.1)
    with pytest.raises(ValueError, match="3-D"):
        fit_batch(x=np.zeros((10, 8), np.float32), lam1=0.1)
    with pytest.raises(ValueError, match="square"):
        fit_batch(s=xs, lam1=0.1)
    with pytest.raises(ValueError, match="reference"):
        fit_batch(x=xs, lam1=0.1, backend="distributed")


def test_fit_batch_cov_variant_forms_covariances():
    """variant='cov' with stacked raw datasets forms per-problem S and
    solves the Cov variant — same estimate as the Obs variant."""
    from repro.estimator import fit_batch

    xs = np.stack([graphs.make_problem("chain", p=32, n=100, seed=k).x
                   for k in range(2)])
    r_cov = fit_batch(x=xs, lam1=0.25, lam2=0.05, backend="reference",
                      variant="cov", tol=1e-6)
    r_obs = fit_batch(x=xs, lam1=0.25, lam2=0.05, backend="reference",
                      variant="obs", tol=1e-6)
    for a, b in zip(r_cov, r_obs):
        assert a.variant == "cov" and b.variant == "obs"
        np.testing.assert_allclose(np.asarray(a.omega), np.asarray(b.omega),
                                   atol=2e-3)


def test_fit_batch_reports_dense_routing():
    """The batched engine always runs dense products, so its reports must
    say sparse_matmul='off' even when the config asked for routing."""
    from repro.estimator import fit_batch

    xs = np.stack([graphs.make_problem("chain", p=32, n=100, seed=k).x
                   for k in range(2)])
    rep = fit_batch(x=xs, lam1=0.25, backend="reference", variant="obs",
                    tol=1e-5, sparse_matmul="auto")
    assert all(r.sparse_matmul == "off" for r in rep)
