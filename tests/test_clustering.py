"""Clustering pipeline (paper Section 5 / S.3.4-S.3.5)."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal CPU image — deterministic fallback
    from _hypothesis_fallback import given, settings, st

from repro.core import clustering as cl


def test_grid_neighbors():
    nbrs = cl.grid_neighbors(2, 3)
    assert len(nbrs) == 6
    assert set(nbrs[0]) == {1, 3}
    assert set(nbrs[4]) == {1, 3, 5}


def test_watershed_two_peaks():
    """Two separated peaks on a line -> two clusters at eps=0."""
    f = np.array([5, 4, 1, 4, 5], dtype=float)
    nbrs = [[1], [0, 2], [1, 3], [2, 4], [3]]
    labels = cl.persistence_watershed(f, nbrs, eps=0.0)
    assert len(np.unique(labels)) == 2
    assert labels[0] == labels[1] and labels[3] == labels[4]
    # large eps merges everything
    labels2 = cl.persistence_watershed(f, nbrs, eps=10.0)
    assert len(np.unique(labels2)) == 1


def test_watershed_eps_monotone():
    rng = np.random.default_rng(0)
    f = rng.random(64)
    nbrs = cl.grid_neighbors(8, 8)
    prev = None
    for eps in (0.0, 0.2, 0.5, 1.0):
        k = len(np.unique(cl.persistence_watershed(f, nbrs, eps=eps)))
        if prev is not None:
            assert k <= prev
        prev = k


def test_label_propagation_two_cliques():
    a = np.zeros((8, 8), bool)
    for grp in (range(4), range(4, 8)):
        for i in grp:
            for j in grp:
                if i != j:
                    a[i, j] = True
    labels = cl.label_propagation(a, seed=1)
    assert len(np.unique(labels)) == 2
    assert len(np.unique(labels[:4])) == 1
    assert len(np.unique(labels[4:])) == 1


def test_modified_jaccard_identity():
    c = np.array([0, 0, 1, 1, 2, 2])
    assert cl.modified_jaccard(c, c) == pytest.approx(1.0)


def test_modified_jaccard_invariance_to_relabeling():
    c1 = np.array([0, 0, 1, 1, 2, 2])
    c2 = np.array([5, 5, 9, 9, 7, 7])
    assert cl.modified_jaccard(c1, c2) == pytest.approx(1.0)


@given(st.integers(0, 20))
@settings(max_examples=10, deadline=None)
def test_modified_jaccard_bounds(seed):
    rng = np.random.default_rng(seed)
    c1 = rng.integers(0, 4, 30)
    c2 = rng.integers(0, 6, 30)
    s = cl.modified_jaccard(c1, c2)
    assert 0.0 <= s <= 1.0
    # symmetry
    assert s == pytest.approx(cl.modified_jaccard(c2, c1), abs=1e-9)


def test_threshold_covariance_graph():
    rng = np.random.default_rng(0)
    s = rng.standard_normal((10, 10))
    s = s + s.T
    g = cl.threshold_covariance_graph(s, 0.1)
    # keeps about 10% of the upper triangle
    frac = g[np.triu_indices(10, 1)].mean()
    assert 0.0 < frac < 0.3


def test_degrees_from_support():
    sup = np.zeros((4, 4), bool)
    sup[0, 1] = True  # only upper entry; must be symmetrized
    deg = cl.degrees_from_support(sup)
    assert list(deg) == [1, 1, 0, 0]
