"""mixtral-8x22b [moe] — 8 experts top-2, sliding-window attention.

56L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=32768 [arXiv:2401.04088]
8 experts do not divide the 16-way model axis, so each expert is
split into 2 virtual f-slice experts (exact decomposition) giving 16
dispatch experts over the 16-way "model" axis — pure EP, no
within-expert all-reduce (see EXPERIMENTS.md §Perf).
SWA => runs long_500k.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56, d_model=6144, n_heads=48, n_kv=8,
    d_ff=0, vocab=32768,
    n_experts=8, top_k=2, d_ff_expert=16384,
    expert_sharding="ep_virtual", virtual_split=2,
    window=8192, mlp="swiglu", norm="rmsnorm",
    rope_theta=1_000_000.0, tie_embeddings=False,
    n_micro=16, prefill_chunk=8192, remat_group=8,
)

SMOKE = CONFIG.with_(
    n_micro=1, loss_chunk=0,
    name="mixtral-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv=2,
    n_experts=4, top_k=2, d_ff_expert=96, vocab=256,
    window=32, remat=False,
)
