"""mamba2-130m [ssm] — attention-free SSD (state-space duality).

24L d_model=768 d_ff=0 vocab=50280 ssm_state=128 [arXiv:2405.21060]
O(1) decode state => runs long_500k.  The paper's CA-matmul technique is
inapplicable here (no huge dense bottleneck) — see DESIGN.md
§Arch-applicability; the arch runs WITHOUT the technique.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24, d_model=768, n_heads=0, n_kv=0,
    d_ff=0, vocab=50280,
    ssm_state=128, ssm_expand=2, ssm_headdim=64, ssm_conv=4,
    ssm_ngroups=1, ssm_chunk=256,
    norm="rmsnorm", tie_embeddings=True,
    n_micro=2,
)

SMOKE = CONFIG.with_(
    n_micro=1, loss_chunk=0,
    name="mamba2-smoke",
    n_layers=2, d_model=64, vocab=256,
    ssm_state=16, ssm_headdim=16, ssm_chunk=16,
    remat=False,
)
