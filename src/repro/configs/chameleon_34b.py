"""chameleon-34b [vlm] — early-fusion: VQ image tokens share the text
vocabulary, so the backbone is a plain decoder-only transformer with
qk-norm; the VQ-VAE image tokenizer is a STUB per the assignment
(input_specs provides token ids directly).

48L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=65536 [arXiv:2405.09818]
Full attention => long_500k skipped.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    family="vlm",
    n_layers=48, d_model=8192, n_heads=64, n_kv=8,
    d_ff=22016, vocab=65536,
    mlp="swiglu", norm="rmsnorm",
    rope_theta=10_000.0, tie_embeddings=False,
    loss_chunk=512, n_micro=16, prefill_chunk=8192, remat_group=4,
)

SMOKE = CONFIG.with_(
    n_micro=1, loss_chunk=0,
    name="chameleon-smoke",
    n_layers=2, d_model=64, n_heads=8, n_kv=2, d_ff=160, vocab=256,
    remat=False,
)
