"""whisper-small [audio] — encoder-decoder; the conv/mel frontend is a
STUB per the assignment (input_specs provides precomputed frame
embeddings (B, 1500, d)).

12L d_model=768 12H d_ff=3072 vocab=51865 [arXiv:2212.04356]
Learned absolute positions (rope_theta=0), LayerNorm + GELU.
Full-attention decoder => long_500k skipped.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="audio",
    n_layers=12, d_model=768, n_heads=12, n_kv=12,
    d_ff=3072, vocab=51865,
    enc_dec=True, n_enc_layers=12, enc_len=1500,
    mlp="gelu", norm="layernorm", rope_theta=0.0,
    tie_embeddings=True,
    n_micro=4,
)

SMOKE = CONFIG.with_(
    n_micro=1, loss_chunk=0,
    name="whisper-smoke",
    n_layers=2, n_enc_layers=2, d_model=64, n_heads=4, n_kv=4,
    d_ff=128, vocab=256, enc_len=32,
    remat=False,
)
