"""qwen2.5-3b [dense] — GQA with QKV bias.

36L d_model=2048 16H (GQA kv=2) d_ff=11008 vocab=151936 [hf:Qwen/Qwen2.5; hf]
Full attention => long_500k skipped (see DESIGN.md §Arch-applicability).
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-3b",
    family="dense",
    n_layers=36, d_model=2048, n_heads=16, n_kv=2,
    d_ff=11008, vocab=151936,
    qkv_bias=True, mlp="swiglu", norm="rmsnorm",
    rope_theta=1_000_000.0, tie_embeddings=True,
    n_micro=2,
)

SMOKE = CONFIG.with_(
    n_micro=1, loss_chunk=0,
    name="qwen2.5-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv=2, d_ff=160, vocab=320,
    remat=False,
)
