"""h2o-danube-1.8b [dense] — llama+mistral mix with sliding-window attention.

24L d_model=2560 32H (GQA kv=8) d_ff=6912 vocab=32000 [arXiv:2401.16818; hf]
SWA => runs long_500k with a ring-buffered window cache.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-1.8b",
    family="dense",
    n_layers=24, d_model=2560, n_heads=32, n_kv=8,
    d_ff=6912, vocab=32000,
    window=4096, mlp="swiglu", norm="rmsnorm",
    rope_theta=10_000.0, tie_embeddings=False,
    n_micro=2,
)

SMOKE = CONFIG.with_(
    n_micro=1, loss_chunk=0,
    name="h2o-danube-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv=2, d_ff=128, vocab=256,
    window=32, remat=False,
)
