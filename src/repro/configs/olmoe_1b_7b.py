"""olmoe-1b-7b [moe] — 64 experts top-8 (fine-grained MoE).

16L d_model=2048 16H (GQA kv=16) d_ff=1024/expert vocab=50304
[arXiv:2409.02060]  64 experts shard 4-per-device over the 16-way model
axis (EP); dispatch lowers to the expert-parallel all-to-all.
Full attention => long_500k skipped.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16, d_model=2048, n_heads=16, n_kv=16,
    d_ff=0, vocab=50304,
    n_experts=64, top_k=8, d_ff_expert=1024,
    expert_sharding="ep",
    mlp="swiglu", norm="rmsnorm",
    rope_theta=10_000.0, tie_embeddings=False,
    n_micro=4, prefill_chunk=8192,
)

SMOKE = CONFIG.with_(
    n_micro=1, loss_chunk=0,
    name="olmoe-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv=4,
    n_experts=8, top_k=2, d_ff_expert=64, vocab=256,
    remat=False,
)
