"""zamba2-7b [hybrid] — Mamba2 backbone + ONE shared attention block
(single parameter set) invoked every `shared_every` Mamba2 layers, each
invocation with its own KV cache.

81L d_model=3584 32H (GQA kv=32) d_ff=14336 ssm_state=64 vocab=32000
[arXiv:2411.15242]  81 = 27 groups x 3 mamba layers.
Sub-quadratic backbone => runs long_500k (shared-attn caches shard their
kv_seq axis over the data axis when batch=1).
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv=32,
    d_ff=14336, vocab=32000,
    shared_every=3,
    ssm_state=64, ssm_expand=2, ssm_headdim=64, ssm_conv=4,
    ssm_ngroups=1, ssm_chunk=256,
    mlp="swiglu", norm="rmsnorm",
    rope_theta=10_000.0, tie_embeddings=True,
    n_micro=4,
)

SMOKE = CONFIG.with_(
    n_micro=1, loss_chunk=0,
    name="zamba2-smoke",
    n_layers=4, d_model=64, n_heads=4, n_kv=4, d_ff=128, vocab=256,
    shared_every=2,
    ssm_state=16, ssm_headdim=16, ssm_chunk=16,
    remat=False,
)
