"""Assigned architecture configs (+ the paper's own HP-CONCORD configs).

``get(name)`` returns the full-size ModelConfig; ``get_smoke(name)`` a
reduced same-family config for CPU smoke tests.  ``input_specs`` builds
ShapeDtypeStruct stand-ins for every (arch x shape) dry-run cell.
"""
from __future__ import annotations

import importlib

ARCHS = [
    "h2o_danube_1p8b",
    "qwen2p5_3b",
    "gemma2_27b",
    "qwen1p5_110b",
    "mixtral_8x22b",
    "olmoe_1b_7b",
    "chameleon_34b",
    "mamba2_130m",
    "zamba2_7b",
    "whisper_small",
]

ALIASES = {
    "h2o-danube-1.8b": "h2o_danube_1p8b",
    "qwen2.5-3b": "qwen2p5_3b",
    "gemma2-27b": "gemma2_27b",
    "qwen1.5-110b": "qwen1p5_110b",
    "mixtral-8x22b": "mixtral_8x22b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "chameleon-34b": "chameleon_34b",
    "mamba2-130m": "mamba2_130m",
    "zamba2-7b": "zamba2_7b",
    "whisper-small": "whisper_small",
}

SHAPES = {
    "train_4k": dict(kind="train", seq_len=4096, global_batch=256),
    "prefill_32k": dict(kind="prefill", seq_len=32768, global_batch=32),
    "decode_32k": dict(kind="decode", seq_len=32768, global_batch=128),
    "long_500k": dict(kind="decode", seq_len=524288, global_batch=1),
}


def canon(name: str) -> str:
    return ALIASES.get(name, name.replace("-", "_").replace(".", "p"))


def get(name: str):
    mod = importlib.import_module(f".{canon(name)}", __package__)
    return mod.CONFIG


def get_smoke(name: str):
    mod = importlib.import_module(f".{canon(name)}", __package__)
    return mod.SMOKE


def long_context_ok(cfg) -> bool:
    """True iff the arch has a sub-quadratic decode memory/compute path:
    SSM state, hybrid, or uniform sliding-window attention."""
    return cfg.family in ("ssm", "hybrid") or bool(cfg.window)


def cells(include_long_skips: bool = False):
    """Yield every (arch, shape) cell per the assignment."""
    for a in ARCHS:
        cfg = get(a)
        for s in SHAPES:
            if s == "long_500k" and not long_context_ok(cfg) \
                    and not include_long_skips:
                continue
            yield a, s


def input_specs(cfg, shape_name: str):
    """ShapeDtypeStruct stand-ins for one dry-run cell (no allocation).

    train   -> {"batch": Batch}                    lowers train_step
    prefill -> {"tokens", "frames"?, "cache"}      lowers prefill
    decode  -> {"token", "step", "cache"}          lowers decode_step
    """
    import jax
    import jax.numpy as jnp
    from ..models import lm, transformer as T

    sh = SHAPES[shape_name]
    B, Lseq = sh["global_batch"], sh["seq_len"]
    i32 = jnp.int32
    sds = jax.ShapeDtypeStruct

    def tok(b, l):
        return sds((b, l), i32)

    if sh["kind"] == "train":
        frames = (sds((B, cfg.enc_len, cfg.d_model), jnp.dtype(cfg.dtype))
                  if cfg.enc_dec else None)
        return {"kind": "train",
                "batch": lm.Batch(tokens=tok(B, Lseq), targets=tok(B, Lseq),
                                  frames=frames)}
    cache = jax.eval_shape(lambda: T.init_cache(cfg, B, Lseq))
    if sh["kind"] == "prefill":
        frames = (sds((B, cfg.enc_len, cfg.d_model), jnp.dtype(cfg.dtype))
                  if cfg.enc_dec else None)
        return {"kind": "prefill", "tokens": tok(B, Lseq),
                "frames": frames, "cache": cache,
                "batch_size": B, "seq_len": Lseq}
    return {"kind": "decode", "token": sds((B,), i32),
            "step": sds((), i32), "cache": cache,
            "batch_size": B, "seq_len": Lseq}
