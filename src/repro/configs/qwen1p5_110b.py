"""qwen1.5-110b [dense] — the largest assigned dense arch; QKV bias.

80L d_model=8192 64H (GQA kv=8) d_ff=49152 vocab=152064 [hf:Qwen/Qwen1.5]
Full attention => long_500k skipped.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-110b",
    family="dense",
    n_layers=80, d_model=8192, n_heads=64, n_kv=8,
    d_ff=49152, vocab=152064,
    qkv_bias=True, mlp="swiglu", norm="rmsnorm",
    rope_theta=1_000_000.0, tie_embeddings=False,
    loss_chunk=512, n_micro=16, prefill_chunk=8192, remat_group=8,
)

SMOKE = CONFIG.with_(
    n_micro=1, loss_chunk=0,
    name="qwen1.5-smoke",
    n_layers=2, d_model=64, n_heads=8, n_kv=2, d_ff=192, vocab=384,
    remat=False,
)
