"""gemma2-27b [dense] — alternating local/global attention + logit softcaps.

46L d_model=4608 32H (GQA kv=16) d_ff=36864 vocab=256000 [arXiv:2408.00118]
Alternating pattern is expressed as a scanned per-layer window array
(local layers window=4096, global layers 0); attn softcap 50, final 30.
Full-attention global layers => long_500k skipped.
The 256k-vocab lm_head is the paper-shaped huge matmul: the ca_lm_head
knob routes it through the 1.5D replicated matmul (see §Perf hillclimb).
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b",
    family="dense",
    n_layers=46, d_model=4608, n_heads=32, n_kv=16,
    d_ff=36864, vocab=256000, head_dim=128,
    local_global=True, local_window=4096,
    softcap=50.0, final_softcap=30.0,
    mlp="swiglu", norm="rmsnorm", post_norm=True,
    rope_theta=10_000.0, tie_embeddings=True,
    loss_chunk=512, n_micro=8,
)

SMOKE = CONFIG.with_(
    n_micro=1, loss_chunk=0,
    name="gemma2-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv=2, d_ff=192, vocab=512,
    head_dim=16, local_window=32, remat=False,
)
