"""Gram-prep launcher: reduce a row-stream of X to its (p, p) sufficient
statistic on disk, once, up front — the out-of-core front half of an
HP-CONCORD solve.

  # synthesize a scenario stream (no X ever materialized) and prep it:
  PYTHONPATH=src python -m repro.launch.gram prep --scenario scale_free \\
      --p 512 --n 200000 --transform standardize --out results/gram_sf

  # or prep existing .npy / raw shard files:
  PYTHONPATH=src python -m repro.launch.gram prep --shards data/shards/ \\
      --transform rank --out results/gram_real

  # then solve from the artifact (no raw data needed ever again):
  PYTHONPATH=src python -m repro.launch.solve --from-gram results/gram_sf

``prep`` writes ``OUT/S.npy`` (float64 Gram of the transformed data) and
``OUT/gram_meta.json`` (n, p, transform, stream stats, chunk accounting,
peak-memory proxy).  The default chunk size comes from the cost model's
guidance (``core.costmodel.gram_chunk_rows``).  ``families`` lists the
scenario generators.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from ..core.costmodel import Machine, gram_chunk_rows
from ..data import (
    available_families,
    available_transforms,
    compute_gram,
    make_scenario,
    open_shards,
)
from ..data.gram import GramResult

META_NAME = "gram_meta.json"


def save_gram(result: GramResult, out_dir: str, *, extra: dict | None = None
              ) -> str:
    """Write OUT/S.npy + OUT/gram_meta.json (mean/var ride in the meta so
    the artifact is self-contained for scoring new data later)."""
    os.makedirs(out_dir, exist_ok=True)
    np.save(os.path.join(out_dir, "S.npy"), result.s)
    meta = result.to_meta()
    meta["mean"] = [float(v) for v in result.mean]
    meta["var"] = [float(v) for v in result.var]
    meta.update(extra or {})
    path = os.path.join(out_dir, META_NAME)
    with open(path, "w") as f:
        json.dump(meta, f, indent=2)
    return path


def load_gram(path: str) -> GramResult:
    """Reopen a ``prep`` artifact (a directory with S.npy + meta, or the
    S.npy path itself) as a :class:`GramResult` for ``fit_gram``."""
    d = path if os.path.isdir(path) else os.path.dirname(path)
    s = np.load(os.path.join(d, "S.npy"))
    meta_path = os.path.join(d, META_NAME)
    if not os.path.exists(meta_path):
        raise FileNotFoundError(
            f"{meta_path} missing — a Gram artifact needs its metadata "
            f"sidecar (rerun launch.gram prep)")
    with open(meta_path) as f:
        meta = json.load(f)
    p = s.shape[0]
    return GramResult(
        s=s, n=int(meta["n"]), p=p, transform=meta.get("transform", "none"),
        mean=np.asarray(meta.get("mean", [0.0] * p), np.float64),
        var=np.asarray(meta.get("var", [1.0] * p), np.float64),
        n_chunks=int(meta.get("n_chunks", 1)),
        source_dtype=meta.get("source_dtype", "float64"))


def _prep(args) -> str:
    chosen = [bool(args.scenario), bool(args.npy), bool(args.shards)]
    if sum(chosen) != 1:
        raise SystemExit("pass exactly one of --scenario / --npy / --shards")
    if args.scenario:
        sc = make_scenario(args.scenario, args.p, seed=args.seed,
                           cond=args.cond,
                           heavy_tail_df=args.heavy_tail_df)
        p = sc.p
        chunk_rows = args.chunk_rows or gram_chunk_rows(p, machine=Machine())
        data = sc.source(args.n, chunk_rows=chunk_rows, seed=args.seed + 1)
        src_desc = {"kind": "scenario", "family": sc.name,
                    "cond": sc.cond, "seed": args.seed,
                    "heavy_tail_df": args.heavy_tail_df}
    else:
        paths = args.npy.split(",") if args.npy else args.shards
        src = open_shards(paths, chunk_rows=args.chunk_rows or 4096)
        p = src.p
        chunk_rows = args.chunk_rows or gram_chunk_rows(p, machine=Machine())
        src = open_shards(paths, chunk_rows=chunk_rows)
        data = src
        src_desc = {"kind": "shards", "paths": paths}

    t0 = time.perf_counter()
    result = compute_gram(data, transform=args.transform,
                          chunk_rows=chunk_rows, panel=args.panel)
    wall = time.perf_counter() - t0
    # peak-memory proxy: resident f64 working set of the streamed pass vs
    # what the dense one-shot X would have needed (chunk capped at n; the
    # rank transform holds its n x w column-sweep buffer instead)
    state = p * p * 8
    resident = min(chunk_rows, result.n) * p * 8 * 2 + state
    if result.transform == "rank":
        from ..data.gram import RANK_BUDGET_BYTES
        w = max(1, min(p, RANK_BUDGET_BYTES // (result.n * 8)))
        resident = max(resident, result.n * w * 8 + state)
    dense = result.n * p * 8 + state
    meta_path = save_gram(result, args.out, extra={
        "source": src_desc,
        "chunk_rows": int(chunk_rows),
        "panel": int(args.panel),
        "wall_time_s": round(wall, 4),
        "rows_per_s": round(result.n / max(wall, 1e-9), 1),
        "peak_bytes_streamed": int(resident),
        "peak_bytes_dense": int(dense),
        "memory_ratio": round(dense / max(resident, 1), 2),
    })
    print(f"[gram prep] {result.transform} Gram of n={result.n} p={p} "
          f"({result.n_chunks} chunks of <= {chunk_rows} rows) in "
          f"{wall:.2f}s ({result.n / max(wall, 1e-9):.0f} rows/s); "
          f"resident ~{resident / 1e6:.1f} MB vs dense "
          f"{dense / 1e6:.1f} MB ({dense / max(resident, 1):.1f}x) "
          f"-> {meta_path}")
    return meta_path


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="streaming Gram prep (repro.data front door)")
    sub = ap.add_subparsers(dest="cmd", required=True)

    prep = sub.add_parser("prep", help="stream a source to S.npy + meta")
    prep.add_argument("--scenario", default=None,
                      choices=available_families(),
                      help="synthesize this scenario family's stream")
    prep.add_argument("--npy", default=None,
                      help="comma-separated .npy shard paths")
    prep.add_argument("--shards", default=None,
                      help="directory of .npy / raw shards")
    prep.add_argument("--out", required=True, help="artifact directory")
    prep.add_argument("--transform", default="standardize",
                      choices=available_transforms())
    prep.add_argument("--p", type=int, default=256)
    prep.add_argument("--n", type=int, default=100_000)
    prep.add_argument("--cond", type=float, default=10.0)
    prep.add_argument("--heavy-tail-df", type=float, default=None)
    prep.add_argument("--seed", type=int, default=0)
    prep.add_argument("--chunk-rows", type=int, default=0,
                      help="rows per chunk (0 = cost-model guidance, "
                           "core.costmodel.gram_chunk_rows)")
    prep.add_argument("--panel", type=int, default=512,
                      help="column-panel edge of the blocked X^T X")

    sub.add_parser("families", help="list scenario families")

    args = ap.parse_args(argv)
    if args.cmd == "families":
        for name in available_families():
            print(name)
        return available_families()
    return _prep(args)


if __name__ == "__main__":
    main()
