import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape)
cell on the production meshes and derive the roofline terms.

The two lines above MUST run before any jax import: they give this
process 512 placeholder CPU devices so jax.make_mesh can build the
16x16 single-pod and 2x16x16 multi-pod production meshes.

Per cell this script:
  1. builds the config + ShapeDtypeStruct input specs (no allocation),
  2. builds in/out shardings from the config's logical rules,
  3. jax.jit(step).lower(...).compile()   — sharding or OOM errors here
     are bugs in the system, not acceptable outcomes,
  4. prints compiled.memory_analysis() (proves the per-device program
     fits v5e HBM) and cost_analysis(),
  5. derives flops / HBM bytes / collective wire bytes.

TRIP-COUNT CORRECTION: XLA's cost_analysis counts a while-loop body ONCE
(verified empirically), so a scan-over-layers program under-reports
flops by ~n_layers.  The dry-run therefore lowers each cell two more
times with a small UNROLLED stack (1 and 2 structural units — a unit is
1 layer, 2 for gemma2's local/global alternation, shared_every for
zamba's groups) and extrapolates
      metric(n) = m(u) + (n_units - 1) * (m(2u) - m(u)),
which is exact for layer-homogeneous cost.  Memory analysis (the
fits-in-HBM proof) always comes from the REAL full-depth scanned
program.

Usage:
  python -m repro.launch.dryrun --arch gemma2-27b --shape train_4k
  python -m repro.launch.dryrun --arch gemma2-27b --shape train_4k --multi-pod
  python -m repro.launch.dryrun --all --out results/dryrun.jsonl
"""

import argparse
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from .. import configs as C
from ..models import lm, transformer as T
from ..models.config import logical_to_spec
from ..train.optim import AdamW, cosine_schedule
from . import roofline as R
from .mesh import make_production_mesh

HBM_BYTES = 16e9   # v5e per-chip


def _shard(mesh, logical, shape, rules):
    return NamedSharding(mesh, logical_to_spec(logical, shape, mesh, rules))


def _unit(cfg) -> int:
    """Smallest layer-count period over which cost is homogeneous."""
    if cfg.family == "hybrid":
        return cfg.shared_every
    if cfg.local_global:
        return 2
    return 1


def _lower(cfg, shape_name, mesh):
    """Lower one cell for `cfg` on `mesh`; returns the jax Lowered."""
    spec = C.input_specs(cfg, shape_name)
    rules = cfg.rules()
    sh = C.SHAPES[shape_name]
    max_len = sh["seq_len"]
    scalar = NamedSharding(mesh, P())

    params_sh = lm.param_shardings(cfg, mesh, max_len=max_len)
    params_shapes = jax.eval_shape(
        lambda: T.init_params(cfg, jax.random.PRNGKey(0), max_len=max_len))

    if spec["kind"] == "train":
        opt = AdamW()
        step_fn = lm.make_train_step(
            cfg, opt, cosine_schedule(3e-4, 100, 10000))
        opt_sh = lm.opt_shardings(cfg, mesh, opt, max_len=max_len)
        state_sh = lm.TrainState(params_sh, opt_sh, scalar)
        batch_sh = lm.batch_shardings(cfg, mesh)
        metrics_sh = {k: scalar for k in
                      ("loss", "aux_loss", "grad_norm", "lr")}
        state_shapes = lm.TrainState(
            params_shapes, jax.eval_shape(opt.init, params_shapes),
            jax.ShapeDtypeStruct((), jnp.int32))
        return jax.jit(
            step_fn, in_shardings=(state_sh, batch_sh),
            out_shardings=(state_sh, metrics_sh),
            donate_argnums=(0,),
        ).lower(state_shapes, spec["batch"]), spec

    cache_sh = lm.cache_shardings(cfg, mesh, spec["batch_size"], max_len)
    if spec["kind"] == "prefill":
        fn = lm.make_prefill(cfg, max_len)
        tok_sh = _shard(mesh, ("batch", "seq"), spec["tokens"].shape, rules)
        logits_sh = _shard(mesh, ("batch", "vocab"),
                           (spec["batch_size"], cfg.vocab_pad), rules)
        args = [params_shapes, spec["cache"], spec["tokens"]]
        in_sh = [params_sh, cache_sh, tok_sh]
        if cfg.enc_dec:
            args.append(spec["frames"])
            in_sh.append(_shard(mesh, ("batch", "seq", "embed"),
                                spec["frames"].shape, rules))
        return jax.jit(
            fn, in_shardings=tuple(in_sh),
            out_shardings=(cache_sh, logits_sh),
            donate_argnums=(1,),
        ).lower(*args), spec

    fn = lm.make_decode_step(cfg)
    tok_sh = _shard(mesh, ("batch",), spec["token"].shape, rules)
    return jax.jit(
        fn,
        in_shardings=(params_sh, cache_sh, tok_sh, scalar),
        out_shardings=(cache_sh, tok_sh),
        donate_argnums=(1,),
    ).lower(params_shapes, spec["cache"], spec["token"],
            jax.ShapeDtypeStruct((), jnp.int32)), spec


def _measure_unrolled(cfg, shape_name, mesh, u: int):
    """flops / bytes / wire-bytes of a `u`-unit unrolled lowering."""
    overrides = dict(n_layers=u, scan_layers=False, loss_chunk=0, n_micro=1,
                     attn_chunk=1 << 30)  # single-chunk mea: its scan body
    if cfg.enc_dec:                       # then runs exactly once
        overrides["n_enc_layers"] = u
    mcfg = cfg.with_(**overrides)
    lowered, _ = _lower(mcfg, shape_name, mesh)
    compiled = lowered.compile()
    cost = compiled.cost_analysis()
    colls = R.parse_collectives(compiled.as_text())
    return (float(cost.get("flops", 0.0)),
            float(cost.get("bytes accessed", 0.0)),
            colls.wire_bytes)


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
               config_overrides: dict | None = None, verbose: bool = True,
               measure: bool = True):
    """Lower + compile one cell; returns (record_dict, compiled)."""
    cfg = C.get(arch)
    if config_overrides:
        cfg = cfg.with_(**config_overrides)
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    n_dev = mesh.size
    sh = C.SHAPES[shape_name]

    from ..comm.compat import use_mesh
    with use_mesh(mesh):
        t0 = time.time()
        lowered, spec = _lower(cfg, shape_name, mesh)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

        cost = compiled.cost_analysis()
        mem = compiled.memory_analysis()
        hlo = compiled.as_text()
        raw_colls = R.parse_collectives(hlo)

        flops = float(cost.get("flops", 0.0))
        hbm = float(cost.get("bytes accessed", 0.0))
        wire = raw_colls.wire_bytes
        extrapolated = False
        if measure:
            u = _unit(cfg)
            n_units = cfg.n_layers // u
            if n_units > 1:
                m1 = _measure_unrolled(cfg, shape_name, mesh, u)
                m2 = _measure_unrolled(cfg, shape_name, mesh, 2 * u)
                flops = m1[0] + (n_units - 1) * (m2[0] - m1[0])
                hbm = m1[1] + (n_units - 1) * (m2[1] - m1[1])
                wire = m1[2] + (n_units - 1) * (m2[2] - m1[2])
                extrapolated = True

    roof = R.build_roofline(
        arch, shape_name, mesh_name, cfg, spec["kind"],
        sh["seq_len"], sh["global_batch"], n_dev,
        {"flops": flops, "bytes accessed": hbm}, mem, "")
    roof.wire_bytes = wire
    roof.t_collective = wire / R.LINK_BW
    roof.coll_counts = raw_colls.counts

    per_dev_bytes = (mem.argument_size_in_bytes + mem.temp_size_in_bytes +
                     mem.output_size_in_bytes - mem.alias_size_in_bytes)
    rec = roof.row()
    rec.update({
        "kind": spec["kind"],
        "n_devices": n_dev,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "extrapolated": extrapolated,
        "arg_bytes_per_dev": mem.argument_size_in_bytes,
        "temp_bytes_per_dev": mem.temp_size_in_bytes,
        "out_bytes_per_dev": mem.output_size_in_bytes,
        "alias_bytes_per_dev": mem.alias_size_in_bytes,
        "total_bytes_per_dev": per_dev_bytes,
        "fits_hbm": bool(per_dev_bytes <= HBM_BYTES),
        "model_flops_per_dev": roof.model_flops,
    })
    if verbose:
        print(f"== {arch} x {shape_name} on {mesh_name} "
              f"({spec['kind']}, {n_dev} devices)")
        print(f"   lower {t_lower:.1f}s  compile {t_compile:.1f}s  "
              f"(+ trip-count measurement: {extrapolated})")
        print(f"   memory_analysis: args {mem.argument_size_in_bytes/1e9:.2f} GB"
              f"  temp {mem.temp_size_in_bytes/1e9:.2f} GB"
              f"  out {mem.output_size_in_bytes/1e9:.2f} GB"
              f"  aliased {mem.alias_size_in_bytes/1e9:.2f} GB"
              f"  -> fits 16GB HBM: {rec['fits_hbm']}")
        print(f"   per-device: {flops:.3e} flops, {hbm:.3e} HBM bytes, "
              f"{wire/1e9:.3f} GB wire; collectives {roof.coll_counts}")
        print(f"   roofline: compute {roof.t_compute*1e3:.2f} ms | "
              f"memory {roof.t_memory*1e3:.2f} ms | "
              f"collective {roof.t_collective*1e3:.2f} ms "
              f"=> {roof.dominant}-bound, "
              f"useful {roof.useful_fraction:.2f}, "
              f"MFU@bound {roof.mfu_at_bound:.2%}")
    return rec, compiled


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(C.SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-measure", action="store_true",
                    help="skip the trip-count extrapolation lowerings")
    ap.add_argument("--out", default=None)
    ap.add_argument("--override", default=None,
                    help="JSON dict of ModelConfig overrides")
    args = ap.parse_args(argv)

    overrides = json.loads(args.override) if args.override else None
    cells = (list(C.cells()) if args.all
             else [(args.arch, args.shape)])
    meshes = [False, True] if (args.both_meshes or args.all) \
        else [args.multi_pod]

    records, failures = [], []
    for arch, shape in cells:
        for mp in meshes:
            try:
                rec, _ = lower_cell(arch, shape, multi_pod=mp,
                                    config_overrides=overrides,
                                    measure=not args.no_measure)
                records.append(rec)
            except Exception as e:
                traceback.print_exc()
                failures.append((arch, shape, mp, repr(e)))
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "a") as f:
            for r in records:
                f.write(json.dumps(r) + "\n")
    if failures:
        print(f"FAILED cells: {failures}", file=sys.stderr)
        sys.exit(1)
    print(f"dry-run OK: {len(records)} records")


if __name__ == "__main__":
    main()
