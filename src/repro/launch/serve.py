"""Serving launcher: batch concurrent requests through a compiled engine.

Two workloads share the same micro-batching idea — group same-shape
requests and run each group as ONE compiled program:

  * ``--workload lm`` (default): batched prefill + greedy decode loop.

      PYTHONPATH=src python -m repro.launch.serve --arch h2o-danube-1.8b \\
          --smoke --batch 4 --prompt-len 32 --gen 32

  * ``--workload concord``: a queue of concurrent estimation requests
    (multi-tenant / multi-subject solves, one dataset + penalty each) is
    bucketed by shape, difficulty-sorted within each bucket by the cost
    model's predicted iteration count (groups converge together, so the
    batched engine's lane compaction stays effective on mixed-difficulty
    queues), and drained in micro-batches of ``--batch`` through the
    batched multi-problem solve engine (``estimator.fit_batch`` ->
    ``core.batch``).  Partial groups are padded to the full batch size so
    every group reuses one compiled program.  Reports batched vs
    sequential throughput (requests/s).

      PYTHONPATH=src python -m repro.launch.serve --workload concord \\
          --requests 12 --batch 4 --p 64 --n 160
"""
from __future__ import annotations

import argparse
import contextlib
import time
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class ConcordServeStats(NamedTuple):
    """What one concord-workload drain did — returned (not just printed)
    so the micro-batching behavior is testable."""
    reports: list               # one FitReport per request, input order
    lam1s: np.ndarray           # the per-request penalties served
    n_groups: int               # compiled-program launches (ceil(R/batch))
    group_shapes: list          # (B, n, p) of each fit_batch call
    t_batched: float
    t_sequential: float
    max_gap: float              # max |Ω_batched - Ω_seq| across queue
    order: np.ndarray = None    # difficulty-sorted drain order (request
                                # indices, hardest first within each
                                # shape bucket)
    queue_wait_s: np.ndarray = None  # per-request: drain start -> its
                                     # group's compiled-program launch
    solve_wall_s: np.ndarray = None  # per-request: its group's fit_batch
                                     # wall (the request rode that program)
    latency_s: np.ndarray = None     # per-request end-to-end =
                                     # queue_wait_s + solve_wall_s


def _difficulty_buckets(shapes, lam1s, bsz: int):
    """Group request indices for the micro-batched drain: bucket by data
    shape (the compiled-program key), difficulty-sort each bucket by the
    cost model's predicted iteration count (hardest first — cheap
    requests are not padded up to a straggler's line search), then cut
    consecutive groups of ``bsz``.  Yields index lists of length <= bsz;
    similar-difficulty neighbors land in the same group, so every group
    converges together and the batched engine's compaction keeps lanes
    live."""
    from ..core.costmodel import predict_path_iters

    iters = np.asarray(predict_path_iters(lam1s), np.float64)
    buckets: dict = {}
    for i, shape in enumerate(shapes):
        buckets.setdefault(tuple(shape), []).append(i)
    for idx in buckets.values():
        # stable sort: equal predictions keep arrival order
        ordered = [idx[k] for k in np.argsort(-iters[idx], kind="stable")]
        for lo in range(0, len(ordered), bsz):
            yield ordered[lo:lo + bsz]


def serve_batch(cfg, params, prompts, gen: int, max_len: int,
                frames=None):
    """Greedy-decode ``gen`` tokens for a batch of prompts."""
    from ..models import lm, transformer as T
    B, Lp = prompts.shape
    cache = T.init_cache(cfg, B, max_len)
    prefill = jax.jit(lm.make_prefill(cfg, max_len))
    decode = jax.jit(lm.make_decode_step(cfg), donate_argnums=(1,))
    if cfg.enc_dec:
        cache, logits = prefill(params, cache, prompts, frames)
    else:
        cache, logits = prefill(params, cache, prompts)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    out = [tok]
    for i in range(gen - 1):
        cache, tok = decode(params, cache, tok,
                            jnp.asarray(Lp + i, jnp.int32))
        out.append(tok)
    return jnp.stack(out, axis=1)                  # (B, gen)


def serve_concord(args):
    """Drain a queue of concurrent estimation requests in micro-batches.

    Each request is an (n, p) dataset plus its own lam1.  Requests are
    bucketed by shape (the compiled-program key), each bucket is
    difficulty-sorted by the cost model's predicted iteration count
    (``_difficulty_buckets``) so a group's lanes converge together, and
    consecutive groups of ``--batch`` solve as one compiled program;
    partial groups are padded by repeating their final request (and the
    padding results dropped) so every group hits the same compiled
    executable.  A sequential drain of the same queue is timed as the
    baseline.
    """
    from ..core import graphs
    from ..estimator import ConcordEstimator, SolverConfig, fit_batch

    rng = np.random.default_rng(args.seed)
    reqs = [graphs.make_problem("chain", args.p, args.n,
                                seed=args.seed + i).x
            for i in range(args.requests)]
    xs = np.stack(reqs)                          # one shape bucket
    lam1s = rng.uniform(0.12, 0.3, size=args.requests)
    obs_mode = getattr(args, "obs", "off")
    config = SolverConfig(backend="reference", variant="obs",
                          tol=args.tol, max_iters=args.max_iters,
                          obs=obs_mode)
    bsz = max(1, args.batch)
    tracer = registry = None
    if obs_mode != "off":
        from ..obs.metrics import get_registry
        from ..obs.trace import get_tracer
        tracer = get_tracer()
        tracer.set_mode(obs_mode)
        registry = get_registry()

    # batched drain: difficulty/shape-bucketed groups, tail-padded to bsz
    # for compiled-program reuse; reports scatter back to input order.
    # Per-request latency splits into the time its group spent queued
    # behind earlier groups (queue wait) and its group's solve wall.
    t0 = time.time()
    drain0 = time.perf_counter()
    reports = [None] * args.requests
    queue_wait = np.zeros(args.requests)
    solve_wall = np.zeros(args.requests)
    group_shapes, order = [], []
    for group in _difficulty_buckets([x.shape for x in reqs], lam1s, bsz):
        order.extend(group)
        idx = group + [group[-1]] * (bsz - len(group))
        xg = jnp.asarray(xs[idx])
        group_shapes.append(tuple(xg.shape))
        g0 = time.perf_counter()
        group_span = (tracer.span("serve.group", cat="serve",
                                  requests=len(group), batch=bsz)
                      if tracer is not None else contextlib.nullcontext())
        with group_span:
            rep = fit_batch(x=xg, lam1=lam1s[idx],
                            lam2=args.lam2, config=config)
        gw = time.perf_counter() - g0
        for i, r in zip(group, rep.reports):
            reports[i] = r
            queue_wait[i] = g0 - drain0
            solve_wall[i] = gw
            if registry is not None:
                registry.histogram("repro_serve_queue_wait_seconds"
                                   ).observe(queue_wait[i])
                registry.histogram("repro_serve_solve_wall_seconds"
                                   ).observe(solve_wall[i])
                registry.histogram("repro_serve_latency_seconds"
                                   ).observe(queue_wait[i] + solve_wall[i])
            if tracer is not None:
                tracer.event("serve.request", cat="serve", request=i,
                             queue_wait_s=float(queue_wait[i]),
                             solve_wall_s=float(solve_wall[i]))
    t_batched = time.time() - t0

    # sequential baseline: one compiled solve per request
    est = ConcordEstimator(lam1=0.2, lam2=args.lam2, config=config)
    t0 = time.time()
    seq = []
    for i in range(args.requests):
        est.lam1 = float(lam1s[i])
        seq.append(est.fit(jnp.asarray(xs[i])).report_)
    t_sequential = time.time() - t0

    n_conv = sum(r.converged for r in reports)
    # one host pull for the whole agreement check, not one per request
    om_batched = np.stack([np.asarray(r.omega) for r in reports])
    om_seq = np.stack([np.asarray(r.omega) for r in seq])
    gap = float(np.max(np.abs(om_batched - om_seq)))
    latency = queue_wait + solve_wall
    print(f"served {args.requests} requests (p={args.p}, n={args.n}) in "
          f"micro-batches of {bsz}: batched {t_batched:.2f}s "
          f"({args.requests / t_batched:.2f} req/s) vs sequential "
          f"{t_sequential:.2f}s ({args.requests / t_sequential:.2f} req/s) "
          f"incl. compile; converged {n_conv}/{args.requests}; "
          f"max |Ω_batch - Ω_seq| {gap:.2e}")
    print(f"request latency: p50 {np.quantile(latency, .5):.3f}s "
          f"p99 {np.quantile(latency, .99):.3f}s "
          f"(queue wait p50 {np.quantile(queue_wait, .5):.3f}s, "
          f"solve wall p50 {np.quantile(solve_wall, .5):.3f}s)")
    if registry is not None:
        print(registry.to_prometheus())
    return ConcordServeStats(
        reports=reports, lam1s=lam1s, n_groups=len(group_shapes),
        group_shapes=group_shapes, t_batched=t_batched,
        t_sequential=t_sequential, max_gap=gap,
        order=np.asarray(order, np.int64),
        queue_wait_s=queue_wait, solve_wall_s=solve_wall,
        latency_s=latency)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", default="lm", choices=["lm", "concord"])
    ap.add_argument("--arch", default=None,
                    help="model config name (required for --workload lm)")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4,
                    help="micro-batch size (both workloads)")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    # concord-workload knobs
    ap.add_argument("--requests", type=int, default=12,
                    help="concord: queued estimation requests to drain")
    ap.add_argument("--p", type=int, default=64)
    ap.add_argument("--n", type=int, default=160)
    ap.add_argument("--lam2", type=float, default=0.05)
    ap.add_argument("--tol", type=float, default=1e-5)
    ap.add_argument("--max-iters", type=int, default=300)
    ap.add_argument("--obs", default="off",
                    choices=["off", "summary", "trace"],
                    help="concord: observability level (spans + request "
                         "latency histograms via repro.obs)")
    args = ap.parse_args(argv)

    if args.workload == "concord":
        return serve_concord(args)
    if args.arch is None:
        ap.error("--arch is required for --workload lm")
    from .. import configs as C
    from ..models import transformer as T

    cfg = C.get_smoke(args.arch) if args.smoke else C.get(args.arch)
    max_len = args.prompt_len + args.gen
    params = T.init_params(cfg, jax.random.PRNGKey(args.seed),
                           max_len=max_len)
    rng = np.random.default_rng(args.seed)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32)
    frames = (jnp.zeros((args.batch, cfg.enc_len, cfg.d_model),
                        jnp.dtype(cfg.dtype)) if cfg.enc_dec else None)
    t0 = time.time()
    toks = serve_batch(cfg, params, prompts, args.gen, max_len,
                       frames=frames)
    dt = time.time() - t0
    n = args.batch * args.gen
    print(f"generated {n} tokens in {dt:.2f}s "
          f"({n / dt:.1f} tok/s incl. compile)")
    print("sample:", np.asarray(toks[0][:16]))
    return toks


if __name__ == "__main__":
    main()
