"""Serving launcher: batch concurrent requests through a compiled engine.

Two workloads share the same micro-batching idea — group same-shape
requests and run each group as ONE compiled program:

  * ``--workload lm`` (default): batched prefill + greedy decode loop.

      PYTHONPATH=src python -m repro.launch.serve --arch h2o-danube-1.8b \\
          --smoke --batch 4 --prompt-len 32 --gen 32

  * ``--workload concord``: a queue of concurrent estimation requests
    (multi-tenant / multi-subject solves, one dataset + penalty each) is
    bucketed by shape, difficulty-sorted within each bucket by the cost
    model's predicted iteration count (groups converge together, so the
    batched engine's lane compaction stays effective on mixed-difficulty
    queues), and drained in micro-batches of ``--batch`` through the
    batched multi-problem solve engine (``estimator.fit_batch`` ->
    ``core.batch``).  Partial groups are padded to the full batch size so
    every group reuses one compiled program.  Reports batched vs
    sequential throughput (requests/s).

      PYTHONPATH=src python -m repro.launch.serve --workload concord \\
          --requests 12 --batch 4 --p 64 --n 160
"""
from __future__ import annotations

import argparse
import time
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class ConcordServeStats(NamedTuple):
    """What one concord-workload drain did — returned (not just printed)
    so the micro-batching behavior is testable."""
    reports: list               # one FitReport per request, input order
    lam1s: np.ndarray           # the per-request penalties served
    n_groups: int               # compiled-program launches (ceil(R/batch))
    group_shapes: list          # (B, n, p) of each fit_batch call
    t_batched: float
    t_sequential: float
    max_gap: float              # max |Ω_batched - Ω_seq| across queue
    order: np.ndarray = None    # difficulty-sorted drain order (request
                                # indices, hardest first within each
                                # shape bucket)


def _difficulty_buckets(shapes, lam1s, bsz: int):
    """Group request indices for the micro-batched drain: bucket by data
    shape (the compiled-program key), difficulty-sort each bucket by the
    cost model's predicted iteration count (hardest first — cheap
    requests are not padded up to a straggler's line search), then cut
    consecutive groups of ``bsz``.  Yields index lists of length <= bsz;
    similar-difficulty neighbors land in the same group, so every group
    converges together and the batched engine's compaction keeps lanes
    live."""
    from ..core.costmodel import predict_path_iters

    iters = np.asarray(predict_path_iters(lam1s), np.float64)
    buckets: dict = {}
    for i, shape in enumerate(shapes):
        buckets.setdefault(tuple(shape), []).append(i)
    for idx in buckets.values():
        # stable sort: equal predictions keep arrival order
        ordered = [idx[k] for k in np.argsort(-iters[idx], kind="stable")]
        for lo in range(0, len(ordered), bsz):
            yield ordered[lo:lo + bsz]


def serve_batch(cfg, params, prompts, gen: int, max_len: int,
                frames=None):
    """Greedy-decode ``gen`` tokens for a batch of prompts."""
    from ..models import lm, transformer as T
    B, Lp = prompts.shape
    cache = T.init_cache(cfg, B, max_len)
    prefill = jax.jit(lm.make_prefill(cfg, max_len))
    decode = jax.jit(lm.make_decode_step(cfg), donate_argnums=(1,))
    if cfg.enc_dec:
        cache, logits = prefill(params, cache, prompts, frames)
    else:
        cache, logits = prefill(params, cache, prompts)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    out = [tok]
    for i in range(gen - 1):
        cache, tok = decode(params, cache, tok,
                            jnp.asarray(Lp + i, jnp.int32))
        out.append(tok)
    return jnp.stack(out, axis=1)                  # (B, gen)


def serve_concord(args):
    """Drain a queue of concurrent estimation requests in micro-batches.

    Each request is an (n, p) dataset plus its own lam1.  Requests are
    bucketed by shape (the compiled-program key), each bucket is
    difficulty-sorted by the cost model's predicted iteration count
    (``_difficulty_buckets``) so a group's lanes converge together, and
    consecutive groups of ``--batch`` solve as one compiled program;
    partial groups are padded by repeating their final request (and the
    padding results dropped) so every group hits the same compiled
    executable.  A sequential drain of the same queue is timed as the
    baseline.
    """
    from ..core import graphs
    from ..estimator import ConcordEstimator, SolverConfig, fit_batch

    rng = np.random.default_rng(args.seed)
    reqs = [graphs.make_problem("chain", args.p, args.n,
                                seed=args.seed + i).x
            for i in range(args.requests)]
    xs = np.stack(reqs)                          # one shape bucket
    lam1s = rng.uniform(0.12, 0.3, size=args.requests)
    config = SolverConfig(backend="reference", variant="obs",
                          tol=args.tol, max_iters=args.max_iters)
    bsz = max(1, args.batch)

    # batched drain: difficulty/shape-bucketed groups, tail-padded to bsz
    # for compiled-program reuse; reports scatter back to input order
    t0 = time.time()
    reports = [None] * args.requests
    group_shapes, order = [], []
    for group in _difficulty_buckets([x.shape for x in reqs], lam1s, bsz):
        order.extend(group)
        idx = group + [group[-1]] * (bsz - len(group))
        xg = jnp.asarray(xs[idx])
        group_shapes.append(tuple(xg.shape))
        rep = fit_batch(x=xg, lam1=lam1s[idx],
                        lam2=args.lam2, config=config)
        for i, r in zip(group, rep.reports):
            reports[i] = r
    t_batched = time.time() - t0

    # sequential baseline: one compiled solve per request
    est = ConcordEstimator(lam1=0.2, lam2=args.lam2, config=config)
    t0 = time.time()
    seq = []
    for i in range(args.requests):
        est.lam1 = float(lam1s[i])
        seq.append(est.fit(jnp.asarray(xs[i])).report_)
    t_sequential = time.time() - t0

    n_conv = sum(r.converged for r in reports)
    # one host pull for the whole agreement check, not one per request
    om_batched = np.stack([np.asarray(r.omega) for r in reports])
    om_seq = np.stack([np.asarray(r.omega) for r in seq])
    gap = float(np.max(np.abs(om_batched - om_seq)))
    print(f"served {args.requests} requests (p={args.p}, n={args.n}) in "
          f"micro-batches of {bsz}: batched {t_batched:.2f}s "
          f"({args.requests / t_batched:.2f} req/s) vs sequential "
          f"{t_sequential:.2f}s ({args.requests / t_sequential:.2f} req/s) "
          f"incl. compile; converged {n_conv}/{args.requests}; "
          f"max |Ω_batch - Ω_seq| {gap:.2e}")
    return ConcordServeStats(
        reports=reports, lam1s=lam1s, n_groups=len(group_shapes),
        group_shapes=group_shapes, t_batched=t_batched,
        t_sequential=t_sequential, max_gap=gap,
        order=np.asarray(order, np.int64))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", default="lm", choices=["lm", "concord"])
    ap.add_argument("--arch", default=None,
                    help="model config name (required for --workload lm)")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4,
                    help="micro-batch size (both workloads)")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    # concord-workload knobs
    ap.add_argument("--requests", type=int, default=12,
                    help="concord: queued estimation requests to drain")
    ap.add_argument("--p", type=int, default=64)
    ap.add_argument("--n", type=int, default=160)
    ap.add_argument("--lam2", type=float, default=0.05)
    ap.add_argument("--tol", type=float, default=1e-5)
    ap.add_argument("--max-iters", type=int, default=300)
    args = ap.parse_args(argv)

    if args.workload == "concord":
        return serve_concord(args)
    if args.arch is None:
        ap.error("--arch is required for --workload lm")
    from .. import configs as C
    from ..models import transformer as T

    cfg = C.get_smoke(args.arch) if args.smoke else C.get(args.arch)
    max_len = args.prompt_len + args.gen
    params = T.init_params(cfg, jax.random.PRNGKey(args.seed),
                           max_len=max_len)
    rng = np.random.default_rng(args.seed)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32)
    frames = (jnp.zeros((args.batch, cfg.enc_len, cfg.d_model),
                        jnp.dtype(cfg.dtype)) if cfg.enc_dec else None)
    t0 = time.time()
    toks = serve_batch(cfg, params, prompts, args.gen, max_len,
                       frames=frames)
    dt = time.time() - t0
    n = args.batch * args.gen
    print(f"generated {n} tokens in {dt:.2f}s "
          f"({n / dt:.1f} tok/s incl. compile)")
    print("sample:", np.asarray(toks[0][:16]))
    return toks


if __name__ == "__main__":
    main()
