"""Serving launcher: batched prefill + greedy decode loop.

  PYTHONPATH=src python -m repro.launch.serve --arch h2o-danube-1.8b \
      --smoke --batch 4 --prompt-len 32 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from .. import configs as C
from ..models import lm, transformer as T


def serve_batch(cfg, params, prompts, gen: int, max_len: int,
                frames=None):
    """Greedy-decode ``gen`` tokens for a batch of prompts."""
    B, Lp = prompts.shape
    cache = T.init_cache(cfg, B, max_len)
    prefill = jax.jit(lm.make_prefill(cfg, max_len))
    decode = jax.jit(lm.make_decode_step(cfg), donate_argnums=(1,))
    if cfg.enc_dec:
        cache, logits = prefill(params, cache, prompts, frames)
    else:
        cache, logits = prefill(params, cache, prompts)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    out = [tok]
    for i in range(gen - 1):
        cache, tok = decode(params, cache, tok,
                            jnp.asarray(Lp + i, jnp.int32))
        out.append(tok)
    return jnp.stack(out, axis=1)                  # (B, gen)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = C.get_smoke(args.arch) if args.smoke else C.get(args.arch)
    max_len = args.prompt_len + args.gen
    params = T.init_params(cfg, jax.random.PRNGKey(args.seed),
                           max_len=max_len)
    rng = np.random.default_rng(args.seed)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32)
    frames = (jnp.zeros((args.batch, cfg.enc_len, cfg.d_model),
                        jnp.dtype(cfg.dtype)) if cfg.enc_dec else None)
    t0 = time.time()
    toks = serve_batch(cfg, params, prompts, args.gen, max_len,
                       frames=frames)
    dt = time.time() - t0
    n = args.batch * args.gen
    print(f"generated {n} tokens in {dt:.2f}s "
          f"({n / dt:.1f} tok/s incl. compile)")
    print("sample:", np.asarray(toks[0][:16]))
    return toks


if __name__ == "__main__":
    main()
