"""Launchers: production mesh, multi-pod dry-run, roofline derivation,
train/serve/solve CLIs.

NOTE: ``dryrun`` must be executed as a fresh process (it sets XLA_FLAGS
for 512 placeholder devices before importing jax); do not import it from
an already-initialized jax process.
"""
from . import mesh, roofline  # noqa: F401
