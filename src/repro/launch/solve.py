"""HP-CONCORD launcher: distributed sparse inverse covariance estimation
(the paper's own workload), through the ``repro.estimator`` facade.

  PYTHONPATH=src python -m repro.launch.solve --graph chain --p 200 \
      --n 400 --lam1 0.15 --backend auto

The cost model (paper Lemmas 3.1-3.5) picks the backend's Cov/Obs variant
and the (c_X, c_Omega) replication factors unless pinned.  ``--path`` runs
a lam1 path (the Section-5 model-selection sweep) and reports the BIC-best
point; ``--path-mode batched`` lowers the whole grid to one compiled
multi-problem program instead of sequential warm-started solves.
``--penalty scad:3.7`` (or ``mcp``, ``elastic_net``) swaps the prox
operator through the composable penalty API (``core.penalty``), and
``--path --adaptive`` runs the two-stage adaptive-lasso refit.

``--from-gram DIR`` solves straight from a ``launch.gram prep`` artifact
(S.npy + metadata) — the raw observations never enter this process:

  PYTHONPATH=src python -m repro.launch.solve --from-gram results/gram_sf \
      --lam1 0.15
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from ..core import distributed, graphs
from ..core.costmodel import Machine, ProblemShape, tune
from ..estimator import ConcordEstimator, SolverConfig


def _solve_from_gram(args):
    """Solve from a prepped Gram artifact: the raw data never loads."""
    from .gram import load_gram

    gram = load_gram(args.from_gram)
    config = SolverConfig(
        backend=args.backend, variant="cov",
        c_x=args.cx, c_omega=args.comega,
        tol=args.tol, max_iters=args.max_iters,
        sparse_matmul=args.sparse_matmul, sparse_block=args.sparse_block,
        sparse_threshold=args.sparse_threshold, penalty=args.penalty)
    est = ConcordEstimator(lam1=args.lam1, lam2=args.lam2, config=config)
    print(f"[gram] {gram.transform} Gram: n={gram.n} p={gram.p} "
          f"({gram.n_chunks} chunks, source dtype {gram.source_dtype})")
    if args.path:
        grid = [float(v) for v in args.path.split(",")]
        path = est.fit_path(s=jnp.asarray(gram.s), n_samples=gram.n,
                            lam1_grid=grid, mode=args.path_mode,
                            adaptive=args.adaptive)
        print(path.summary())
        chosen = path.best_bic()
        print(f"BIC-best lam1={chosen.lam1:g} (bic={chosen.bic:.1f})")
        rep = chosen
    else:
        rep = est.fit_gram(gram).report_
    print(rep.summary())
    est_omega = np.asarray(rep.omega)
    print(f"avg degree {graphs.avg_degree(est_omega):.2f}")
    return rep


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--graph", default="chain", choices=["chain", "random"])
    ap.add_argument("--p", type=int, default=200)
    ap.add_argument("--n", type=int, default=400)
    ap.add_argument("--lam1", type=float, default=0.15)
    ap.add_argument("--lam2", type=float, default=0.05)
    ap.add_argument("--penalty", default="l1", metavar="KIND",
                    help="penalty family (core.penalty string form): l1, "
                         "elastic_net, scad[:A], mcp[:GAMMA]; strength "
                         "comes from --lam1/--lam2")
    ap.add_argument("--adaptive", action="store_true",
                    help="two-stage adaptive-lasso refit of --path: "
                         "stage-1 l1 path, then each grid point refit "
                         "with weights 1/(|omega|+eps) built from its "
                         "own stage-1 estimate (pointwise)")
    ap.add_argument("--backend", default="auto",
                    choices=["auto", "reference", "distributed"])
    ap.add_argument("--variant", default="auto",
                    choices=["auto", "cov", "obs"])
    ap.add_argument("--cx", type=int, default=None)
    ap.add_argument("--comega", type=int, default=None)
    ap.add_argument("--tol", type=float, default=1e-5)
    ap.add_argument("--max-iters", type=int, default=300)
    ap.add_argument("--sparse-matmul", default="off",
                    choices=["off", "on", "auto"],
                    help="route Ω-side products through the block-sparse "
                         "matops layer once the observed iterate block "
                         "density crosses the threshold ('auto' takes the "
                         "threshold from the cost model crossover)")
    ap.add_argument("--sparse-block", type=int, default=128,
                    help="occupancy-mask tile edge for --sparse-matmul")
    ap.add_argument("--sparse-threshold", type=float, default=None,
                    help="block-density crossover override in (0, 1]")
    ap.add_argument("--path", default=None, metavar="LAM1S",
                    help="comma-separated lam1 grid: run a "
                         "regularization path instead of a single fit")
    ap.add_argument("--path-mode", default="sequential",
                    choices=["sequential", "batched"],
                    help="sequential: one warm-started solve per path "
                         "point; batched: the whole grid as ONE compiled "
                         "multi-problem program (core.batch)")
    ap.add_argument("--from-gram", default=None, metavar="DIR",
                    help="solve from a launch.gram prep artifact "
                         "(S.npy + gram_meta.json) instead of "
                         "synthesizing a problem")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    if args.adaptive and not args.path:
        ap.error("--adaptive needs --path (it refits a lam1 grid)")

    if args.from_gram:
        return _solve_from_gram(args)

    prob = graphs.make_problem(args.graph, args.p, args.n, seed=args.seed)
    P = len(jax.devices())
    shape = ProblemShape(p=args.p, n=args.n,
                         d=distributed.estimate_density(
                             args.p, args.n, args.lam1))
    best = tune(shape, P, Machine())
    print(f"[costmodel] P={P}: best variant={best.variant} "
          f"c_x={best.c_x} c_omega={best.c_omega} "
          f"T_model={best.total:.3e}s "
          f"(compute {best.t_compute:.2e} / latency {best.t_latency:.2e} "
          f"/ bandwidth {best.t_bandwidth:.2e})")

    config = SolverConfig(
        backend=args.backend, variant=args.variant,
        c_x=args.cx, c_omega=args.comega,
        tol=args.tol, max_iters=args.max_iters,
        sparse_matmul=args.sparse_matmul, sparse_block=args.sparse_block,
        sparse_threshold=args.sparse_threshold, penalty=args.penalty)
    est = ConcordEstimator(lam1=args.lam1, lam2=args.lam2, config=config)
    x = jnp.asarray(prob.x)

    if args.path:
        grid = [float(v) for v in args.path.split(",")]
        path = est.fit_path(x, lam1_grid=grid, mode=args.path_mode,
                            adaptive=args.adaptive)
        print(path.summary())
        chosen = path.best_bic()
        print(f"BIC-best lam1={chosen.lam1:g} (bic={chosen.bic:.1f})")
        rep = chosen
    else:
        rep = est.fit(x).report_

    est_omega = np.asarray(rep.omega)
    ppv, fdr = graphs.ppv_fdr(est_omega, prob.omega0)
    print(rep.summary())
    print(f"PPV {ppv:.3f}  FDR {fdr:.3f}  "
          f"avg degree {graphs.avg_degree(est_omega):.2f}")
    return rep


if __name__ == "__main__":
    main()
