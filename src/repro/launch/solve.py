"""HP-CONCORD launcher: distributed sparse inverse covariance estimation
(the paper's own workload).

  PYTHONPATH=src python -m repro.launch.solve --graph chain --p 200 \
      --n 400 --lam1 0.15 --variant auto

The cost model (paper Lemmas 3.1-3.5) picks the Cov/Obs variant and the
(c_X, c_Omega) replication factors unless pinned.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..core import distributed, graphs
from ..core.costmodel import Machine, ProblemShape, tune


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--graph", default="chain", choices=["chain", "random"])
    ap.add_argument("--p", type=int, default=200)
    ap.add_argument("--n", type=int, default=400)
    ap.add_argument("--lam1", type=float, default=0.15)
    ap.add_argument("--lam2", type=float, default=0.05)
    ap.add_argument("--variant", default="auto",
                    choices=["auto", "cov", "obs"])
    ap.add_argument("--cx", type=int, default=None)
    ap.add_argument("--comega", type=int, default=None)
    ap.add_argument("--tol", type=float, default=1e-5)
    ap.add_argument("--max-iters", type=int, default=300)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    prob = graphs.make_problem(args.graph, args.p, args.n, seed=args.seed)
    P = len(jax.devices())
    shape = ProblemShape(p=args.p, n=args.n,
                         d=distributed.estimate_density(
                             args.p, args.n, args.lam1))
    best = tune(shape, P, Machine())
    print(f"[costmodel] P={P}: best variant={best.variant} "
          f"c_x={best.c_x} c_omega={best.c_omega} "
          f"T_model={best.total:.3e}s "
          f"(compute {best.t_compute:.2e} / latency {best.t_latency:.2e} "
          f"/ bandwidth {best.t_bandwidth:.2e})")

    t0 = time.time()
    res = distributed.fit(
        x=jnp.asarray(prob.x), lam1=args.lam1, lam2=args.lam2,
        variant=args.variant, c_x=args.cx, c_omega=args.comega,
        tol=args.tol, max_iters=args.max_iters)
    dt = time.time() - t0
    est = np.asarray(res.omega)
    ppv, fdr = graphs.ppv_fdr(est, prob.omega0)
    print(f"variant={res.variant} grid=(c_x={res.grid.c_x}, "
          f"c_omega={res.grid.c_omega}) iters={int(res.iters)} "
          f"ls={int(res.ls_total)} converged={bool(res.converged)}")
    print(f"time {dt:.2f}s  objective {float(res.g_final):.4f}  "
          f"PPV {ppv:.3f}  FDR {fdr:.3f}  "
          f"avg degree {graphs.avg_degree(est):.2f}")
    return res


if __name__ == "__main__":
    main()
