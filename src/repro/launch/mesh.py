"""Production mesh construction.

Kept as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax initialization).

Target part: TPU v5e pods, 16x16 = 256 chips per pod; the multi-pod mesh
adds a leading "pod" axis (2 pods = 512 chips) used as pure data
parallelism (DCI-connected pods should not carry TP/EP traffic).
"""
from __future__ import annotations

import jax

from ..comm import compat


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh with Auto axis types (tests / examples)."""
    return compat.make_mesh(shape, axes)


def make_host_mesh(*, max_devices: int | None = None):
    """Small mesh over whatever devices exist (CPU tests): picks the
    largest (data, model) factorization."""
    n = len(jax.devices())
    if max_devices:
        n = min(n, max_devices)
    model = 1
    for m in (8, 4, 2, 1):
        if n % m == 0:
            model = m
            break
    return make_mesh((n // model, model), ("data", "model"))
