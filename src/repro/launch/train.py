"""Training launcher CLI.

  PYTHONPATH=src python -m repro.launch.train --arch h2o-danube-1.8b \
      --smoke --steps 200 --seq-len 512 --batch 8 --ckpt-dir /tmp/ckpt

``--smoke`` selects the reduced config (CPU-runnable); without it the
full config is used (expects a real TPU slice; mesh from --mesh).
"""
from __future__ import annotations

import argparse


from .. import configs as C
from ..train.loop import TrainerConfig, train
from .mesh import make_host_mesh, make_production_mesh


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=512)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--micro", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--heartbeat", default="")
    ap.add_argument("--mesh", default="host",
                    choices=["host", "pod", "multipod", "none"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = C.get_smoke(args.arch) if args.smoke else C.get(args.arch)
    mesh = None
    if args.mesh == "host":
        mesh = make_host_mesh()
    elif args.mesh == "pod":
        mesh = make_production_mesh()
    elif args.mesh == "multipod":
        mesh = make_production_mesh(multi_pod=True)

    tc = TrainerConfig(
        seq_len=args.seq_len, global_batch=args.batch, n_micro=args.micro,
        steps=args.steps, peak_lr=args.lr, ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every, heartbeat_path=args.heartbeat,
        seed=args.seed)
    res = train(cfg, tc, mesh=mesh)
    print(f"done: {res.final_step} steps, "
          f"loss {res.losses[0]:.4f} -> {res.losses[-1]:.4f}, "
          f"preempted={res.preempted}")
    return res


if __name__ == "__main__":
    main()
