"""Roofline-term derivation from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), all in seconds-per-step on the
TARGET part (TPU v5e):

    compute    = per_device_HLO_flops / peak_flops
    memory     = per_device_HLO_bytes / hbm_bw
    collective = sum over collective ops of wire_bytes / link_bw

``cost_analysis()`` reports the per-device partitioned program, so the
chips term is already folded in.  Collective bytes are parsed from the
optimized (post-SPMD) HLO text; per-op wire-byte conventions (ring
algorithms over ICI):

    all-gather         (n-1)/n * result_bytes
    reduce-scatter     (n-1)/n * operand_bytes
    all-reduce         2 (n-1)/n * operand_bytes   (RS + AG)
    all-to-all         (n-1)/n * operand_bytes
    collective-permute operand_bytes

n is taken from the op's replica-group size.  Link bandwidth is per-chip
aggregate ICI (v5e: ~50 GB/s/link; a 2D-torus chip has multiple links,
we charge the single busiest link, i.e. worst case serialization).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

# v5e target constants (also in core/costmodel.py Machine)
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:\S+))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", )
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    counts: dict = field(default_factory=dict)
    result_bytes: dict = field(default_factory=dict)
    wire_bytes: float = 0.0

    def add(self, kind: str, rbytes: int, group_n: int):
        self.counts[kind] = self.counts.get(kind, 0) + 1
        self.result_bytes[kind] = self.result_bytes.get(kind, 0) + rbytes
        frac = (group_n - 1) / group_n if group_n > 1 else 0.0
        if kind == "all-gather":
            # result is the gathered (large) buffer; each link carries
            # (n-1)/n of it but per-device INPUT is result/n
            self.wire_bytes += frac * rbytes
        elif kind == "reduce-scatter":
            # result is the scattered (small) buffer; operand = n * result
            self.wire_bytes += frac * rbytes * group_n
        elif kind == "all-reduce":
            self.wire_bytes += 2 * frac * rbytes
        elif kind == "all-to-all":
            self.wire_bytes += frac * rbytes
        elif kind == "collective-permute":
            self.wire_bytes += rbytes


def parse_collectives(hlo_text: str) -> CollectiveStats:
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        type_str, kind = m.group(1), m.group(2)
        rbytes = _shape_bytes(type_str)
        g = _GROUPS_RE.search(line)
        if g:
            group_n = len([x for x in g.group(1).split(",") if x.strip()])
        else:
            g2 = _GROUPS_V2_RE.search(line)
            group_n = int(g2.group(2)) if g2 else 2
        stats.add(kind, rbytes, group_n)
    return stats


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    flops: float                 # per-device HLO flops
    hbm_bytes: float             # per-device HLO bytes accessed
    wire_bytes: float            # per-device collective bytes on the wire
    t_compute: float
    t_memory: float
    t_collective: float
    model_flops: float           # 6 N D useful flops (per device)
    coll_counts: dict = field(default_factory=dict)
    mem_stats: dict = field(default_factory=dict)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def bound(self) -> float:
        """Roofline lower bound on step time: overlapping compute/memory/
        collective perfectly, time = max of the three terms."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_fraction(self) -> float:
        """MODEL_FLOPS / HLO_FLOPS — how much of compiled compute is
        forward/backward matmul work (catches remat/dispatch waste)."""
        return self.model_flops / self.flops if self.flops else 0.0

    @property
    def mfu_at_bound(self) -> float:
        """Model-flops utilization if the step ran exactly at the
        roofline bound — the 'roofline fraction' we report."""
        return (self.model_flops / PEAK_FLOPS) / self.bound \
            if self.bound else 0.0

    def row(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "wire_bytes": self.wire_bytes,
            "t_compute": self.t_compute, "t_memory": self.t_memory,
            "t_collective": self.t_collective,
            "dominant": self.dominant, "bound_s": self.bound,
            "useful_frac": self.useful_fraction,
            "mfu_at_bound": self.mfu_at_bound,
            **{f"n_{k}": v for k, v in self.coll_counts.items()},
        }


def model_flops_per_step(cfg, shape_kind: str, seq_len: int,
                         global_batch: int, n_devices: int) -> float:
    """6*N*D for training (fwd+bwd), 2*N_active per generated/processed
    token for inference, per device."""
    n_active = cfg.param_count(active_only=True)
    if shape_kind == "train":
        tokens = seq_len * global_batch
        total = 6.0 * n_active * tokens
    elif shape_kind == "prefill":
        tokens = seq_len * global_batch
        total = 2.0 * n_active * tokens
    else:  # decode: one token per sequence
        total = 2.0 * n_active * global_batch
    return total / n_devices


def build_roofline(arch: str, shape: str, mesh_name: str, cfg, kind: str,
                   seq_len: int, global_batch: int, n_devices: int,
                   cost: dict, mem_stats, hlo_text: str) -> Roofline:
    flops = float(cost.get("flops", 0.0))
    hbm = float(cost.get("bytes accessed", 0.0))
    colls = parse_collectives(hlo_text)
    mf = model_flops_per_step(cfg, kind, seq_len, global_batch, n_devices)
    ms = {}
    if mem_stats is not None:
        ms = {"args_gb": mem_stats.argument_size_in_bytes / 1e9,
              "out_gb": mem_stats.output_size_in_bytes / 1e9,
              "temp_gb": mem_stats.temp_size_in_bytes / 1e9}
    return Roofline(
        arch=arch, shape=shape, mesh=mesh_name,
        flops=flops, hbm_bytes=hbm, wire_bytes=colls.wire_bytes,
        t_compute=flops / PEAK_FLOPS,
        t_memory=hbm / HBM_BW,
        t_collective=colls.wire_bytes / LINK_BW,
        model_flops=mf,
        coll_counts=colls.counts,
        mem_stats=ms,
    )
