"""JAX-aware static analysis for the solver stack.

Four engines over one rule registry (:mod:`repro.analysis.rules`):

* :mod:`repro.analysis.astpass` — CA1xx, pure stdlib-``ast`` source
  rules (host calls under trace, dtype literals in f64 modules,
  collective-layer bypasses, ...);
* :mod:`repro.analysis.jaxprpass` — CA2xx, semantic checks that trace
  the per-layer ``ANALYSIS_ENTRIES`` manifests with ``jax.make_jaxpr``
  (f64 downcasts, recompiles, unbound psum axes);
* :mod:`repro.analysis.commpass` — CA3xx, SPMD collective-schedule
  checks: the ordered ppermute/psum/all_gather trace of every entry is
  extracted from its jaxpr (ring schedules via ``axis_env``, no devices
  needed) and verified against declared ``COMM_CONTRACT``s, including
  EXACT bytes-on-wire accounting vs ``core.costmodel.comm_volume``;
* :mod:`repro.analysis.pallaspass` — CA4xx, Pallas kernel grid/BlockSpec
  checks: every ``kernels.manifest.KERNEL_ENTRIES`` configuration's grid
  is enumerated concretely and each index map evaluated at every grid
  point (write races, coverage gaps, out-of-bounds blocks, narrow
  accumulators, oracle-twin declarations, SMEM-table consistency); the
  companion :mod:`repro.analysis.kernelfuzz` sanitizer differentially
  fuzzes each kernel against its ``ref.py`` oracle in interpret mode.

Run it as ``python -m repro.analysis`` (installed: ``repro-analyze``);
see README "Static analysis".
"""
from .findings import Finding, sort_findings
from .recompile import RecompileGuard, cache_size
from .rules import (
    DEFAULT_PROFILE,
    SCRIPTS_PROFILE,
    Profile,
    Rule,
    all_rules,
    get_rule,
    profile_for_path,
    register_rule,
)

__all__ = [
    "Finding",
    "sort_findings",
    "RecompileGuard",
    "cache_size",
    "Rule",
    "Profile",
    "register_rule",
    "get_rule",
    "all_rules",
    "profile_for_path",
    "DEFAULT_PROFILE",
    "SCRIPTS_PROFILE",
]
