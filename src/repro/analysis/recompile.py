"""Reusable recompile guard (generalizes the ad-hoc ``_cache_size``
assertion from the penalty tests).

``cache_size(fn)`` reads the compiled-program cache of a jitted callable;
:class:`RecompileGuard` wraps a code region and reports how many new
programs each watched callable compiled inside it.  The jaxpr engine
(CA202) and the ``recompile_guard`` pytest fixture both build on this.
"""
from __future__ import annotations

from dataclasses import dataclass, field


def cache_size(jitted) -> int | None:
    """Compiled-program cache size of a jitted callable, or None when the
    running jax build doesn't expose ``_cache_size`` (older/newer API)."""
    probe = getattr(jitted, "_cache_size", None)
    if probe is None:
        return None
    try:
        return int(probe())
    except Exception:
        return None


@dataclass
class RecompileGuard:
    """Watch jitted callables across a region; compare cache growth.

    >>> guard = RecompileGuard({"solve": _solve_reference})
    >>> with guard:
    ...     fit(...); fit(...)      # same shapes/statics
    >>> guard.deltas()              # {"solve": 0} when the cache held
    """

    watched: dict                          # name -> jitted callable
    _before: dict = field(default_factory=dict)
    _after: dict = field(default_factory=dict)

    def __enter__(self) -> "RecompileGuard":
        self._before = {k: cache_size(f) for k, f in self.watched.items()}
        self._after = {}
        return self

    def __exit__(self, *exc) -> None:
        self._after = {k: cache_size(f) for k, f in self.watched.items()}

    def snapshot(self) -> dict:
        """Refresh the 'after' side without exiting (for incremental use)."""
        self._after = {k: cache_size(f) for k, f in self.watched.items()}
        return self.deltas()

    def deltas(self) -> dict:
        """name -> programs compiled inside the region (None = cache size
        not observable on this jax build; treat as 'cannot check')."""
        out = {}
        for k in self.watched:
            b, a = self._before.get(k), self._after.get(k)
            out[k] = None if (b is None or a is None) else a - b
        return out

    def grew(self) -> dict:
        """Subset of deltas that are positive (actual recompiles)."""
        return {k: d for k, d in self.deltas().items()
                if d is not None and d > 0}
