"""comm engine: SPMD collective-schedule rules (CA301-CA306).

The jaxpr engine checks what a traced program COMPUTES; this engine
checks what it COMMUNICATES.  Every manifest entry is traced (ring
entries under ``axis_env``, so multi-device schedules trace on a
1-device container) and its jaxpr is walked into a **collective
schedule**: the ordered ppermute/psum/all_gather/... events with their
axis names, permutation tables, payload shapes/dtypes and control-flow
context — a ppermute inside a ``lax.scan`` of length R is one event
fired R times, a ``lax.cond`` records per-branch sub-schedules, a
``lax.while_loop`` poisons byte accounting (trip count is dynamic) but
still surfaces its events for the structural rules.

On that schedule:

  * CA301 — branches of one cond/switch post different collective
    sequences (the static signature of an SPMD deadlock);
  * CA302 — a ppermute table is not a bijection in range of the bound
    axis extent (and, under a contract, must cover the full ring);
  * CA303 — total bytes-on-wire derived from the schedule must EQUAL
    (as exact Fractions) the analytic ``core.costmodel`` volume the
    module's ``COMM_CONTRACT`` declares;
  * CA304 — collectives that move bytes for nothing (psum of an
    already-psummed value, composable back-to-back ppermutes);
  * CA305 — schedule disagrees with the declared contract (undeclared
    axis, undeclared collective kind, ring scan length != declared
    rounds);
  * CA306 — a payload dtype the contract does not allow on the wire.

Entry schema extensions over :mod:`repro.analysis.jaxprpass` (all
optional, so existing entries are valid comm entries with structural
checks only)::

    {
      ...,                           # name/path/axis_names/build as before
      "build": lambda: {
          ...,                       # fn/args/kwargs/ctx as before
          "axis_env": (("i", 2), ("j", 2), ("k", 2)),  # trace SPMD axes
          "axis_sizes": {"i": 1},    # extents when tracing through a mesh
      },
      "comm": lambda: {              # bind a declared COMM_CONTRACT
          "contract": CommContract(...),
          "params": {...},           # kwargs for the contract's callables
      },
      "skip": ("CA201",),            # per-entry rule opt-outs (a declared
    }                                # narrowing lives NEXT to its contract)

Byte conventions are ``core.costmodel.collective_wire_bytes``'s — the
single shared definition both sides of the CA303 equality use.
"""
from __future__ import annotations

import math
import traceback
from contextlib import nullcontext
from dataclasses import dataclass, field
from fractions import Fraction

from ..core.costmodel import collective_wire_bytes
from .findings import Finding
from .jaxprpass import _axis_names_of, _eqn_snippet, _sub_jaxprs
from .rules import Profile

#: payload-bearing collectives (axis_index & friends carry no wire bytes)
EVENT_PRIMS = frozenset({
    "psum", "pmin", "pmax", "psum_invariant", "ppermute", "pbroadcast",
    "all_gather", "all_gather_invariant", "all_to_all", "reduce_scatter",
    "psum_scatter",
})

_REDUCE_PRIMS = frozenset({"psum", "pmin", "pmax", "psum_invariant"})


@dataclass(frozen=True)
class CollectiveEvent:
    """One collective eqn in program order, with its repeat count."""
    prim: str
    axes: tuple            # mesh axis names the eqn binds
    extent: int | None     # product of bound axis sizes (None = unknown)
    shape: tuple           # invars[0] payload shape
    dtypes: tuple          # payload dtype of every array operand
    payload_bytes: int
    perm: tuple | None     # ppermute table
    times: int | None      # product of enclosing scan lengths (None: while)
    context: str           # control-flow path, e.g. "scan[2]"
    snippet: str

    @property
    def moves(self) -> bool:
        if self.perm is None:
            return True
        return any(s != d for s, d in self.perm)

    def wire_bytes(self) -> Fraction | None:
        """Critical-path bytes over all firings (None = indeterminate)."""
        if self.times is None or self.extent is None:
            return None
        one = collective_wire_bytes(
            self.prim, self.payload_bytes, self.extent, moves=self.moves)
        return self.times * one

    def signature(self) -> tuple:
        """What must agree across SPMD branches (CA301): everything a
        peer device matches on, which is NOT the permutation values."""
        return (self.prim, self.axes, self.shape, self.dtypes, self.times)

    def to_json(self) -> dict:
        wb = self.wire_bytes()
        return {
            "prim": self.prim, "axes": list(self.axes),
            "extent": self.extent, "shape": list(self.shape),
            "dtypes": list(self.dtypes), "times": self.times,
            "context": self.context, "perm": (
                None if self.perm is None else [list(p) for p in self.perm]),
            "bytes_on_wire": None if wb is None else str(wb),
        }


@dataclass
class Schedule:
    """The extracted collective schedule of one traced entry."""
    events: list = field(default_factory=list)
    #: (length, ppermute_inside, context, snippet) per lax.scan
    scans: list = field(default_factory=list)
    #: (branch_jaxprs, context, snippet) per lax.cond/switch
    conds: list = field(default_factory=list)
    #: True if a while_loop made repeat counts dynamic
    indeterminate: bool = False

    def total_bytes(self) -> Fraction | None:
        total = Fraction(0)
        for e in self.events:
            wb = e.wire_bytes()
            if wb is None:
                return None
            total += wb
        return total

    def to_json(self) -> dict:
        tb = self.total_bytes()
        return {"events": [e.to_json() for e in self.events],
                "static_bytes": None if tb is None else str(tb),
                "indeterminate": self.indeterminate}


def _payload(eqn):
    """(shape, dtypes, bytes) over the eqn's array operands."""
    shapes, dtypes, nbytes = [], [], 0
    for v in eqn.invars:
        aval = getattr(v, "aval", None)
        shape = getattr(aval, "shape", None)
        dtype = getattr(aval, "dtype", None)
        if shape is None or dtype is None:
            continue
        shapes.append(tuple(shape))
        dtypes.append(str(dtype))
        nbytes += math.prod(shape) * dtype.itemsize
    return (shapes[0] if shapes else ()), tuple(dtypes), nbytes


def _mul(a, b):
    return None if (a is None or b is None) else a * b


def _ctx(context: str, frame: str) -> str:
    return f"{context}/{frame}" if context else frame


def extract_schedule(jaxpr, axis_sizes: dict, *, _times: int | None = 1,
                     _context: str = "", _out: Schedule | None = None
                     ) -> Schedule:
    """Walk a (Closed)Jaxpr into program-order collective events.

    ``axis_sizes`` maps mesh axis name -> extent (from the entry's
    ``axis_env``/``axis_sizes``); an event binding an unlisted axis gets
    ``extent=None`` and poisons byte accounting but not the structural
    rules.
    """
    out = _out if _out is not None else Schedule()
    inner = getattr(jaxpr, "jaxpr", jaxpr)
    for eqn in inner.eqns:
        name = eqn.primitive.name
        if name == "scan":
            length = eqn.params.get("length")
            before = len(out.events)
            extract_schedule(eqn.params["jaxpr"], axis_sizes,
                             _times=_mul(_times, length),
                             _context=_ctx(_context, f"scan[{length}]"),
                             _out=out)
            has_pp = any(e.prim == "ppermute"
                         for e in out.events[before:])
            out.scans.append((length, has_pp, _context, _eqn_snippet(eqn)))
        elif name == "while":
            out.indeterminate = True
            for key in ("cond_jaxpr", "body_jaxpr"):
                extract_schedule(eqn.params[key], axis_sizes, _times=None,
                                 _context=_ctx(_context, "while[?]"),
                                 _out=out)
        elif name == "cond":
            branches = tuple(eqn.params["branches"])
            out.conds.append((branches, _context, _eqn_snippet(eqn)))
            # devices agreeing on the predicate run the SAME branch, so
            # the schedule follows one representative; CA301 fires if
            # the branches could disagree about what that schedule is
            extract_schedule(branches[0], axis_sizes, _times=_times,
                             _context=_ctx(_context, "cond"), _out=out)
        elif name in EVENT_PRIMS:
            axes = tuple(_axis_names_of(eqn))
            extent = 1
            for a in axes:
                size = axis_sizes.get(a)
                extent = _mul(extent, size)
            shape, dtypes, nbytes = _payload(eqn)
            perm = eqn.params.get("perm")
            out.events.append(CollectiveEvent(
                prim=name, axes=axes, extent=extent, shape=shape,
                dtypes=dtypes, payload_bytes=nbytes,
                perm=None if perm is None else tuple(map(tuple, perm)),
                times=_times, context=_context, snippet=_eqn_snippet(eqn)))
        else:
            for sub in _sub_jaxprs(eqn.params):
                extract_schedule(sub, axis_sizes, _times=_times,
                                 _context=_context, _out=out)
    return out


# -- per-entry checks -------------------------------------------------------

def _finding(rule, entry, message, snippet) -> Finding:
    return Finding(rule=rule, path=entry["path"], line=0,
                   context=entry["name"], message=message, snippet=snippet)


def check_branch_schedules(entry, schedule, axis_sizes) -> list:
    """CA301: every branch of a cond/switch must post the same ordered
    collective signature — devices disagreeing on the predicate would
    otherwise wait on collectives their peers never post."""
    out = []
    for branches, context, snippet in schedule.conds:
        sigs = []
        for br in branches:
            sub = extract_schedule(br, axis_sizes)
            sigs.append(tuple(e.signature() for e in sub.events))
        if not any(sigs):
            continue                    # no collectives anywhere: safe
        if len(set(sigs)) > 1:
            desc = " vs ".join(
                "[" + ", ".join(f"{s[0]}{list(s[1])}" for s in sig) + "]"
                for sig in sigs)
            out.append(_finding(
                "CA301", entry,
                f"cond/switch branches post divergent collective "
                f"schedules ({desc}){' at ' + context if context else ''}: "
                f"devices taking different branches deadlock — hoist the "
                f"collectives out of the branch or make every branch post "
                f"the identical sequence", snippet))
    return out


def check_ppermute_tables(entry, schedule, contract) -> list:
    """CA302: permutation tables must be in-range bijections (and cover
    the full ring when a COMM_CONTRACT declares the schedule)."""
    out = []
    for e in schedule.events:
        if e.prim != "ppermute" or e.perm is None:
            continue
        srcs = [s for s, _ in e.perm]
        dsts = [d for _, d in e.perm]
        problems = []
        if len(set(srcs)) != len(srcs):
            problems.append("duplicate source ranks")
        if len(set(dsts)) != len(dsts):
            problems.append("duplicate destination ranks")
        if e.extent is not None:
            bad = [r for r in srcs + dsts if not 0 <= r < e.extent]
            if bad:
                problems.append(
                    f"ranks {sorted(set(bad))} out of range for axis "
                    f"extent {e.extent}")
            if (not problems and contract is not None
                    and len(e.perm) != e.extent):
                problems.append(
                    f"covers {len(e.perm)}/{e.extent} ranks (a declared "
                    f"ring schedule must keep every device in the "
                    f"rotation)")
        if problems:
            out.append(_finding(
                "CA302", entry,
                f"ppermute over {list(e.axes)}"
                f"{' at ' + e.context if e.context else ''} is not a "
                f"valid ring permutation: {'; '.join(problems)} — data "
                f"on the missing lanes is silently dropped/zeroed",
                e.snippet))
    return out


def check_volume(entry, schedule, contract, params) -> list:
    """CA303: schedule bytes must EQUAL the contract's analytic bytes."""
    expected = contract.expected_volume(params)
    if expected is None:
        return []
    expected = Fraction(expected)
    static = schedule.total_bytes()
    if static is None:
        return [_finding(
            "CA303", entry,
            f"COMM_CONTRACT declares an exact volume "
            f"({expected} bytes/invocation"
            f"{', ' + contract.volume_class if contract.volume_class else ''}"
            f") but the traced schedule's byte count is indeterminate "
            f"(dynamic trip count or unbound axis extent) — a volume "
            f"contract requires a statically accountable schedule",
            "indeterminate schedule")]
    if static != expected:
        return [_finding(
            "CA303", entry,
            f"traced schedule moves {static} bytes/invocation but the "
            f"COMM_CONTRACT"
            f"{' (' + contract.volume_class + ')' if contract.volume_class else ''}"
            f" declares {expected} (analytic core.costmodel volume at "
            f"{params}) — an extra collective, a missing round, or a "
            f"widened wire dtype crept into the schedule",
            f"static={static} expected={expected}")]
    return []


def check_redundant(entry, jaxpr) -> list:
    """CA304: per-body dataflow — psum of an already-psummed value over a
    subset of the same axes, or ppermute-of-ppermute whose intermediate
    has no other consumer (one composed table does the same work in one
    hop)."""
    out = []
    for body in _all_bodies(jaxpr):
        produced = {}                   # var id -> (prim, axes, eqn)
        uses: dict[int, int] = {}
        for eqn in body.eqns:
            for v in eqn.invars:
                if hasattr(v, "aval") and not hasattr(v, "val"):
                    uses[id(v)] = uses.get(id(v), 0) + 1
        outvars = {id(v) for v in body.outvars if hasattr(v, "aval")}
        for eqn in body.eqns:
            name = eqn.primitive.name
            if name not in EVENT_PRIMS:
                continue
            axes = frozenset(_axis_names_of(eqn))
            for v in eqn.invars:
                src = produced.get(id(v))
                if src is None:
                    continue
                src_prim, src_axes, src_eqn = src
                if (name in _REDUCE_PRIMS and src_prim in _REDUCE_PRIMS
                        and axes <= src_axes):
                    out.append(_finding(
                        "CA304", entry,
                        f"{name} over {sorted(axes)} of a value already "
                        f"reduced by {src_prim} over {sorted(src_axes)}: "
                        f"the operand is replicated on those axes, so "
                        f"this collective moves bytes to multiply by the "
                        f"axis size (almost certainly a double-reduce "
                        f"bug)", _eqn_snippet(eqn)))
                elif (name == "ppermute" and src_prim == "ppermute"
                        and axes == src_axes and uses.get(id(v), 0) == 1
                        and id(v) not in outvars):
                    out.append(_finding(
                        "CA304", entry,
                        f"back-to-back ppermutes over {sorted(axes)} "
                        f"whose intermediate has no other consumer: "
                        f"compose the permutation tables into one hop "
                        f"(half the wire bytes, half the launches)",
                        _eqn_snippet(eqn)))
            for v in eqn.outvars:
                produced[id(v)] = (name, axes, eqn)
    return out


def _all_bodies(jaxpr):
    """Yield every Jaxpr body (top level + nested) exactly once."""
    inner = getattr(jaxpr, "jaxpr", jaxpr)
    yield inner
    for eqn in inner.eqns:
        for sub in _sub_jaxprs(eqn.params):
            yield from _all_bodies(sub)


def check_contract_schedule(entry, schedule, contract, params) -> list:
    """CA305: axes, kinds, and ring-scan rounds vs the declaration."""
    out = []
    allowed_axes = set(contract.axes if contract.axes is not None
                       else entry.get("axis_names") or ())
    kinds = None if contract.kinds is None else set(contract.kinds)
    seen = set()
    for e in schedule.events:
        undeclared = [a for a in e.axes if a not in allowed_axes]
        if undeclared and ("axes", e.prim, tuple(undeclared)) not in seen:
            seen.add(("axes", e.prim, tuple(undeclared)))
            out.append(_finding(
                "CA305", entry,
                f"{e.prim} binds axis(es) {undeclared} but the "
                f"COMM_CONTRACT declares {sorted(allowed_axes)} — the "
                f"schedule touches a ring the contract does not cover",
                e.snippet))
        if kinds is not None and e.prim not in kinds and \
                ("kind", e.prim) not in seen:
            seen.add(("kind", e.prim))
            out.append(_finding(
                "CA305", entry,
                f"schedule posts `{e.prim}` but the COMM_CONTRACT only "
                f"declares {sorted(kinds)} — an undeclared collective "
                f"kind changes the communication pattern", e.snippet))
    rounds = contract.expected_rounds(params)
    if rounds is not None:
        for length, has_pp, context, snippet in schedule.scans:
            if has_pp and length != rounds:
                out.append(_finding(
                    "CA305", entry,
                    f"ring scan runs {length} round(s)"
                    f"{' at ' + context if context else ''} but the "
                    f"COMM_CONTRACT declares {rounds} — the rotation "
                    f"visits the wrong number of blocks", snippet))
    return out


def check_wire_dtypes(entry, schedule, contract, operand_dtypes) -> list:
    """CA306: every payload dtype must be on the contract's wire list
    ("operand" = the entry's own operand dtypes, "mask" = the int8
    occupancy-mask dtype)."""
    if contract.wire is None:
        return []
    allowed = set()
    for t in contract.wire:
        if t == "operand":
            allowed.update(operand_dtypes)
        elif t == "mask":
            from ..core.matops import MASK_DTYPE
            allowed.add(str(MASK_DTYPE.dtype) if hasattr(MASK_DTYPE, "dtype")
                        else str(MASK_DTYPE.__name__))
        else:
            allowed.add(t)
    out = []
    seen = set()
    for e in schedule.events:
        for dt in e.dtypes:
            if dt in allowed or (e.prim, dt) in seen:
                continue
            seen.add((e.prim, dt))
            out.append(_finding(
                "CA306", entry,
                f"{e.prim}"
                f"{' at ' + e.context if e.context else ''} ships "
                f"{dt} but the COMM_CONTRACT wire policy allows only "
                f"{sorted(allowed)} — the declared bytes-on-wire budget "
                f"silently multiplies", e.snippet))
    return out


# -- driver -----------------------------------------------------------------

def _error_finding(entry, stage, exc) -> Finding:
    tb = traceback.format_exception_only(type(exc), exc)[-1].strip()
    return Finding(
        rule="CA300", path=entry["path"], line=0, context=entry["name"],
        message=f"comm entry failed during {stage}: {tb} — a broken entry "
                f"means the collective-schedule checks did not run",
        snippet=stage)


def run_entry(entry: dict, profile: Profile):
    """Trace + check one manifest entry.  Returns (findings, record);
    record is the JSON-able schedule trace (None when tracing failed).
    Never raises: failures surface as CA300."""
    import jax
    from jax.experimental import enable_x64

    skip = set(entry.get("skip") or ())
    active = {r for r in profile.rules if r.startswith("CA3")} - skip
    if not active:
        return [], None
    try:
        with enable_x64():
            spec = entry["build"]()
            ctx = spec.get("ctx") or nullcontext
            fn, args = spec["fn"], tuple(spec.get("args", ()))
            kwargs = dict(spec.get("kwargs", {}))
            axis_env = spec.get("axis_env")
            mk = {} if axis_env is None else {"axis_env": list(axis_env)}
            with ctx():
                jaxpr = jax.make_jaxpr(
                    lambda *a: fn(*a, **kwargs), **mk)(*args)
    except Exception as e:              # noqa: BLE001 - report, don't die
        return [_error_finding(entry, "trace", e)], None

    axis_sizes = dict(axis_env or ())
    axis_sizes.update(spec.get("axis_sizes") or {})
    schedule = extract_schedule(jaxpr, axis_sizes)

    comm = entry.get("comm")
    comm = comm() if callable(comm) else comm
    contract = None if comm is None else comm["contract"]
    params = {} if comm is None else dict(comm.get("params") or {})
    operand_dtypes = {str(getattr(v.aval, "dtype", ""))
                      for v in getattr(jaxpr, "jaxpr", jaxpr).invars}

    findings = []
    try:
        if "CA301" in active:
            findings += check_branch_schedules(entry, schedule, axis_sizes)
        if "CA302" in active:
            findings += check_ppermute_tables(entry, schedule, contract)
        if "CA304" in active:
            findings += check_redundant(entry, jaxpr)
        if contract is not None:
            if "CA303" in active:
                findings += check_volume(entry, schedule, contract, params)
            if "CA305" in active:
                findings += check_contract_schedule(
                    entry, schedule, contract, params)
            if "CA306" in active:
                findings += check_wire_dtypes(
                    entry, schedule, contract, operand_dtypes)
    except Exception as e:              # noqa: BLE001
        return findings + [_error_finding(entry, "check", e)], None

    record = {"entry": entry["name"], "path": entry["path"],
              **schedule.to_json()}
    if contract is not None:
        expected = contract.expected_volume(params)
        record["contract"] = {
            "volume_class": contract.volume_class,
            "rounds": contract.expected_rounds(params),
            "expected_bytes": None if expected is None else
            str(Fraction(expected)),
            "params": {k: str(v) for k, v in params.items()},
        }
    return findings, record


def run_entries(entries, profile: Profile):
    """Returns (findings, schedule_records) over the whole manifest."""
    findings, records = [], []
    for entry in entries:
        f, rec = run_entry(entry, profile)
        findings.extend(f)
        if rec is not None:
            records.append(rec)
    return findings, records
