"""pallas engine: Pallas kernel grid/BlockSpec rules (CA4xx).

The AST/jaxpr/comm engines stop at the ``pallas_call`` boundary: a write
race in a scatter-style output index map, a coverage gap leaving stale
output tiles, or an out-of-bounds block id are all invisible to them.
This engine closes that gap CONCRETELY: every
``kernels.manifest.KERNEL_ENTRIES`` configuration's grid is enumerated
(grids are small — thousands of points) and every BlockSpec index map is
evaluated at every grid point, with the scalar-prefetch vectors bound
exactly as ``PrefetchScalarGridSpec`` binds them.

On that enumeration:

  * CA401 — two grid points write the same output block along grid dims
    the kernel does not declare as sequential accumulation, or a
    declared accumulation revisits a block non-consecutively (TPU grids
    execute sequentially, last dim fastest, and an output block is
    flushed when its index changes — a non-contiguous revisit clobbers);
  * CA402 — the written blocks fail to tile the output array;
  * CA403 — a block index leaves [0, cdiv(dim, block)) for any operand;
  * CA404 — ``make_jaxpr`` of the kernel function (f64-contract entries
    only) shows a float64 value narrowing inside the traced body;
  * CA405 — a ``pallas_call``-bearing kernel module registers no entry,
    or an entry names a missing ``ref.py`` oracle / unknown tolerance
    class;
  * CA406 — index-map arity vs grid (+ prefetch) rank, block rank vs
    operand rank, block dims vs operand dims, SMEM scalar-table rows vs
    the grid's lane demand.

Like the other engines it never raises: a broken entry surfaces as
CA400 so it cannot mask the rest.  ``run_entries`` returns
``(findings, records)`` with JSON-able per-entry grid records for the
CI artifact, mirroring the comm engine.
"""
from __future__ import annotations

import ast
import inspect
import itertools
import traceback
from pathlib import Path

from .findings import Finding
from .jaxprpass import NARROW_FLOATS, _eqn_snippet, iter_eqns
from .rules import Profile

#: grid-size ceiling per configuration — a registry mistake (e.g. a
#: full-size production shape) must fail loudly, not hang the gate
MAX_GRID_POINTS = 1_000_000


def _finding(rule: str, entry: dict, message: str, *,
             snippet: str = "") -> Finding:
    return Finding(rule=rule, path=entry["path"], line=0,
                   context=entry["name"], message=message, snippet=snippet)


def _error_finding(entry: dict, stage: str, exc: BaseException) -> Finding:
    tb = traceback.format_exception_only(type(exc), exc)[-1].strip()
    return Finding(
        rule="CA400", path=entry["path"], line=0, context=entry["name"],
        message=f"kernel entry failed during {stage}: {tb} — a broken "
                f"entry means the grid/BlockSpec checks did not run",
        snippet=stage)


# -- geometry helpers -------------------------------------------------------

def _cdiv(a: int, b: int) -> int:
    return -(-a // b)


def _grid_points(grid) -> list:
    return list(itertools.product(*(range(int(g)) for g in grid)))


def _map_arity(index_map) -> int:
    """Non-default positional parameter count of an index map (bound
    closure constants like flash attention's ``g=group`` don't count)."""
    params = inspect.signature(index_map).parameters.values()
    return sum(1 for p in params
               if p.kind in (p.POSITIONAL_ONLY, p.POSITIONAL_OR_KEYWORD)
               and p.default is p.empty)


def _eval_map(spec, point, prefetch) -> tuple:
    idx = spec.index_map(*point, *prefetch)
    if not isinstance(idx, tuple):
        idx = (idx,)
    return tuple(int(v) for v in idx)


def _nblocks(arg) -> tuple:
    return tuple(_cdiv(dim, bs)
                 for dim, bs in zip(arg.shape, arg.spec.block_shape))


def _block_args(layout, role: str):
    """(position, BlockArg) pairs of one side, SMEM scalar specs
    (block_shape None) excluded — they have no index map."""
    args = layout.inputs if role == "in" else layout.outputs
    return [(k, a) for k, a in enumerate(args)
            if a.spec.block_shape is not None]


# -- per-config checks ------------------------------------------------------

def check_spec_shapes(entry: dict, label: str, layout) -> list:
    """CA406: grid/BlockSpec/SMEM scalar-table consistency."""
    out = []
    where = f"config '{label}'"
    if any(int(g) < 1 for g in layout.grid):
        out.append(_finding(
            "CA406", entry,
            f"{where}: grid {tuple(layout.grid)} has a non-positive "
            f"dimension — the kernel body would never run",
            snippet=f"grid={tuple(layout.grid)}"))
        return out
    want_arity = len(layout.grid) + len(layout.prefetch)
    for role in ("in", "out"):
        for k, arg in _block_args(layout, role):
            bs = arg.spec.block_shape
            tag = f"{where}: {role}[{k}] '{arg.name}'"
            if len(bs) != len(arg.shape):
                out.append(_finding(
                    "CA406", entry,
                    f"{tag}: block shape {tuple(bs)} has rank {len(bs)} "
                    f"but the operand is rank {len(arg.shape)} "
                    f"{tuple(arg.shape)}",
                    snippet=f"{arg.name}: block={tuple(bs)}"))
                continue
            if any(int(b) < 1 for b in bs) or any(
                    int(b) > int(d) for b, d in zip(bs, arg.shape)):
                out.append(_finding(
                    "CA406", entry,
                    f"{tag}: block shape {tuple(bs)} does not fit the "
                    f"operand shape {tuple(arg.shape)} (every block dim "
                    f"must be in [1, dim])",
                    snippet=f"{arg.name}: block={tuple(bs)}"))
            arity = _map_arity(arg.spec.index_map)
            if arity != want_arity:
                out.append(_finding(
                    "CA406", entry,
                    f"{tag}: index map takes {arity} grid argument(s) "
                    f"but the grid rank plus scalar-prefetch count is "
                    f"{want_arity} — the map would be called with the "
                    f"wrong arity",
                    snippet=f"{arg.name}: arity {arity} != {want_arity}"))
    for k, rows in layout.scalar_rows.items():
        arg = layout.inputs[k]
        have = int(arg.shape[0]) if arg.shape else 0
        if have < rows:
            out.append(_finding(
                "CA406", entry,
                f"{where}: SMEM scalar table in[{k}] '{arg.name}' holds "
                f"{have} row(s) but the grid's lane indexing reads up to "
                f"row {rows - 1} — the kernel body would read past the "
                f"table",
                snippet=f"{arg.name}: rows {have} < {rows}"))
    return out


def check_bounds(entry: dict, label: str, layout, points) -> list:
    """CA403: every evaluated block index inside the padded bounds."""
    out = []
    for role in ("in", "out"):
        for k, arg in _block_args(layout, role):
            nb = _nblocks(arg)
            flagged = set()
            for point in points:
                idx = _eval_map(arg.spec, point, layout.prefetch)
                if len(idx) != len(nb):
                    if ("rank", k, role) not in flagged:
                        flagged.add(("rank", k, role))
                        out.append(_finding(
                            "CA406", entry,
                            f"config '{label}': {role}[{k}] "
                            f"'{arg.name}' index map returns "
                            f"{len(idx)} coordinate(s) for a rank-"
                            f"{len(nb)} block grid at grid point "
                            f"{point}",
                            snippet=f"{arg.name}: {idx}"))
                    continue
                for d, (i, n) in enumerate(zip(idx, nb)):
                    if 0 <= i < n or (d, k, role) in flagged:
                        continue
                    flagged.add((d, k, role))
                    out.append(_finding(
                        "CA403", entry,
                        f"config '{label}': {role}[{k}] '{arg.name}' "
                        f"block index {i} along dim {d} is outside "
                        f"[0, {n}) at grid point {point} (operand "
                        f"{tuple(arg.shape)}, block "
                        f"{tuple(arg.spec.block_shape)}) — the kernel "
                        f"would address past the padded operand",
                        snippet=f"{arg.name}[{d}]: {i} not in [0, {n})"))
    return out


def check_races(entry: dict, label: str, layout, points) -> list:
    """CA401: overlapping output writes along undeclared dims, and
    non-contiguous revisits of a declared sequential accumulation."""
    out = []
    for k, arg in _block_args(layout, "out"):
        nb = _nblocks(arg)
        # points are enumerated in execution order (row-major, last grid
        # dim fastest — TPU semantics), so `lin` is the grid step index
        writes: dict = {}
        for lin, point in enumerate(points):
            idx = _eval_map(arg.spec, point, layout.prefetch)
            if len(idx) != len(nb) or not all(
                    0 <= i < n for i, n in zip(idx, nb)):
                continue        # CA403/CA406 territory
            writes.setdefault(idx, []).append((lin, point))
        declared = layout.sequential.get(k, frozenset())
        seen_race = False
        seen_revisit = False
        for blk, hits in sorted(writes.items()):
            if len(hits) < 2:
                continue
            pts = [p for _, p in hits]
            varying = {d for d in range(len(layout.grid))
                       if len({p[d] for p in pts}) > 1}
            undeclared = varying - set(declared)
            if undeclared and not seen_race:
                seen_race = True
                (l0, p0), (l1, p1) = hits[0], hits[1]
                out.append(_finding(
                    "CA401", entry,
                    f"config '{label}': out[{k}] '{arg.name}' block "
                    f"{blk} is written by {len(hits)} grid points (e.g. "
                    f"{p0} and {p1}) that differ along grid dim(s) "
                    f"{sorted(undeclared)} which the kernel does NOT "
                    f"declare as sequential accumulation — overlapping "
                    f"output writes race (scatter indices must be "
                    f"unique, or the dim declared sequential)",
                    snippet=f"{arg.name}{blk}: points {p0} vs {p1}"))
            elif not undeclared and not seen_revisit:
                lins = [ln for ln, _ in hits]
                if max(lins) - min(lins) != len(lins) - 1:
                    seen_revisit = True
                    out.append(_finding(
                        "CA401", entry,
                        f"config '{label}': out[{k}] '{arg.name}' block "
                        f"{blk} is revisited NON-consecutively along its "
                        f"declared sequential dim(s) "
                        f"{sorted(declared)} (grid steps {sorted(lins)}) "
                        f"— the output block is flushed when its index "
                        f"changes, so the later visit clobbers the "
                        f"earlier partial sums (duplicate scatter ids "
                        f"must form one contiguous run)",
                        snippet=f"{arg.name}{blk}: steps {sorted(lins)}"))
    return out


def check_coverage(entry: dict, label: str, layout, points) -> list:
    """CA402: the written blocks must tile every output array."""
    out = []
    for k, arg in _block_args(layout, "out"):
        nb = _nblocks(arg)
        written = set()
        for point in points:
            idx = _eval_map(arg.spec, point, layout.prefetch)
            if len(idx) == len(nb) and all(
                    0 <= i < n for i, n in zip(idx, nb)):
                written.add(idx)
        expected = set(itertools.product(*(range(n) for n in nb)))
        missing = sorted(expected - written)
        if missing:
            shown = ", ".join(map(str, missing[:4]))
            if len(missing) > 4:
                shown += ", ..."
            out.append(_finding(
                "CA402", entry,
                f"config '{label}': out[{k}] '{arg.name}' — "
                f"{len(missing)} of {len(expected)} output blocks are "
                f"never written ({shown}): unwritten blocks ship stale "
                f"memory",
                snippet=f"{arg.name}: missing {shown}"))
    return out


# -- whole-entry checks -----------------------------------------------------

def check_accumulator(entry: dict) -> list:
    """CA404: trace the kernel function at f64 and walk its (nested)
    jaxprs — the interpret-mode pallas_call body traces as jax ops — for
    float64 values narrowing to f32/f16/bf16."""
    import jax
    from jax.experimental import enable_x64

    with enable_x64():
        spec = entry["trace"]()
        fn, args = spec["fn"], tuple(spec.get("args", ()))
        kwargs = dict(spec.get("kwargs", {}))
        jaxpr = jax.make_jaxpr(lambda *a: fn(*a, **kwargs))(*args)
    out = []
    seen = set()
    for eqn in iter_eqns(jaxpr):
        prim = eqn.primitive.name
        if prim == "convert_element_type":
            src = getattr(eqn.invars[0].aval, "dtype", None)
            dst = eqn.params.get("new_dtype")
            if src is None or dst is None:
                continue
            if str(src) == "float64" and str(dst) in NARROW_FLOATS:
                key = ("convert", str(dst))
                if key in seen:
                    continue
                seen.add(key)
                out.append(_finding(
                    "CA404", entry,
                    f"float64 value narrowed to {dst} inside the traced "
                    f"kernel body of '{entry['name']}': the f64 "
                    f"iteration contract must hold inside the kernel "
                    f"(accumulate at the operand dtype, or exempt the "
                    f"kernel from the f64 contract explicitly)",
                    snippet=_eqn_snippet(eqn)))
        elif prim == "dot_general":
            pref = eqn.params.get("preferred_element_type")
            srcs = {str(getattr(v.aval, "dtype", "")) for v in eqn.invars}
            if pref is not None and srcs == {"float64"} and \
                    str(pref) in NARROW_FLOATS:
                key = ("dot", str(pref))
                if key in seen:
                    continue
                seen.add(key)
                out.append(_finding(
                    "CA404", entry,
                    f"dot_general over float64 operands accumulates at "
                    f"preferred_element_type={pref} inside "
                    f"'{entry['name']}': a narrow MXU accumulator "
                    f"breaks the f64 contract",
                    snippet=_eqn_snippet(eqn)))
    return out


def check_oracle(entry: dict) -> list:
    """CA405 (per-entry half): the declared oracle twin must exist on
    kernels.ref and the tolerance class must be a known one."""
    from ..kernels import ref
    from ..kernels.manifest import TOLERANCE_CLASSES

    out = []
    oracle = entry.get("oracle")
    if not oracle or not hasattr(ref, oracle):
        out.append(_finding(
            "CA405", entry,
            f"entry '{entry['name']}' declares oracle {oracle!r} but "
            f"kernels.ref has no such function — every kernel needs a "
            f"pure-jnp twin to be differentially testable",
            snippet=f"oracle={oracle!r}"))
    tol = entry.get("tolerance")
    if tol not in TOLERANCE_CLASSES:
        out.append(_finding(
            "CA405", entry,
            f"entry '{entry['name']}' declares tolerance class {tol!r}; "
            f"it must be one of {TOLERANCE_CLASSES} so the sanitizer "
            f"knows whether to compare bit-exactly or within rtol/atol",
            snippet=f"tolerance={tol!r}"))
    return out


def _module_has_pallas_call(path: Path) -> bool:
    try:
        tree = ast.parse(path.read_text(encoding="utf-8"))
    except (OSError, SyntaxError):
        return False            # unreadable/broken source is CA100's job
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        fn = node.func
        if (isinstance(fn, ast.Attribute) and fn.attr == "pallas_call") \
                or (isinstance(fn, ast.Name) and fn.id == "pallas_call"):
            return True
    return False


def check_module_coverage(entries) -> list:
    """CA405 (registry half): every kernels/*.py module that issues a
    ``pallas_call`` must be covered by at least one registry entry."""
    from .. import kernels as kpkg

    covered = {e.get("path") for e in entries}
    out = []
    kdir = Path(kpkg.__file__).resolve().parent
    for f in sorted(kdir.glob("*.py")):
        rel = f"src/repro/kernels/{f.name}"
        if rel in covered or not _module_has_pallas_call(f):
            continue
        out.append(Finding(
            rule="CA405", path=rel, line=0, context="kernels.manifest",
            message=f"{rel} issues pallas_call but registers no "
                    f"KERNEL_ENTRIES entry: the kernel ships with no "
                    f"oracle twin, no declared tolerance class and no "
                    f"grid/BlockSpec verification",
            snippet=f.name))
    return out


# -- driver -----------------------------------------------------------------

def run_entry(entry: dict, profile: Profile):
    """Check one registry entry.  Returns (findings, record); record is
    the JSON-able grid summary (None when nothing ran).  Never raises:
    failures surface as CA400 findings."""
    findings = []
    skip = set(entry.get("skip") or ())
    active = ({"CA401", "CA402", "CA403", "CA404", "CA405", "CA406"}
              & profile.rules) - skip
    if "CA405" in active:
        try:
            findings.extend(check_oracle(entry))
        except Exception as e:      # noqa: BLE001 - report, don't die
            findings.append(_error_finding(entry, "oracle", e))
    if "CA404" in active and entry.get("f64_contract") \
            and entry.get("trace") is not None:
        try:
            findings.extend(check_accumulator(entry))
        except Exception as e:      # noqa: BLE001
            findings.append(_error_finding(entry, "trace", e))

    cfg_records = []
    for cfg in entry.get("configs", ()):
        label = cfg.get("label", "?")
        try:
            layout = entry["layout"](cfg)
            npoints = 1
            for g in layout.grid:
                npoints *= int(g)
            if npoints > MAX_GRID_POINTS:
                raise ValueError(
                    f"grid {tuple(layout.grid)} has {npoints} points "
                    f"(> {MAX_GRID_POINTS}): register a reduced shape")
            points = _grid_points(layout.grid)
        except Exception as e:      # noqa: BLE001
            findings.append(_error_finding(entry, f"layout[{label}]", e))
            continue
        try:
            if "CA406" in active:
                findings.extend(check_spec_shapes(entry, label, layout))
            if "CA403" in active:
                findings.extend(check_bounds(entry, label, layout, points))
            if "CA401" in active:
                findings.extend(check_races(entry, label, layout, points))
            if "CA402" in active:
                findings.extend(
                    check_coverage(entry, label, layout, points))
        except Exception as e:      # noqa: BLE001
            findings.append(_error_finding(entry, f"checks[{label}]", e))
            continue
        cfg_records.append({
            "config": label,
            "grid": [int(g) for g in layout.grid],
            "points": len(points),
            "sequential": {str(k): sorted(v) for k, v in
                           layout.sequential.items()},
        })
    record = None
    if cfg_records or active:
        record = {"entry": entry["name"], "path": entry["path"],
                  "oracle": entry.get("oracle"),
                  "tolerance": entry.get("tolerance"),
                  "configs": cfg_records}
    return findings, record


def run_entries(entries, profile: Profile, *, all_entries=None):
    """Check a registry subset.  ``all_entries`` (default: ``entries``)
    is the full registry the CA405 module-coverage check runs against —
    under ``--changed`` scoping the per-entry checks shrink but coverage
    stays whole-program.  Returns (findings, grid_records)."""
    findings, records = [], []
    for entry in entries:
        f, rec = run_entry(entry, profile)
        findings.extend(f)
        if rec is not None:
            records.append(rec)
    if "CA405" in profile.rules:
        findings.extend(check_module_coverage(
            entries if all_entries is None else all_entries))
    return findings, records
