"""Finding model shared by both analysis engines.

A :class:`Finding` is one rule violation at one source location.  Its
``fingerprint`` intentionally ignores the line *number* (only the rule,
the file, the enclosing symbol and the stripped source text participate)
so a checked-in baseline survives unrelated edits that shift lines.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass


@dataclass(frozen=True)
class Finding:
    rule: str               # rule id, e.g. "CA101"
    path: str               # repo-relative posix path
    line: int               # 1-based line number (0 = whole-module/entry)
    message: str            # human explanation of this occurrence
    context: str = ""       # enclosing symbol (function/class qualname,
    #                         or manifest entry name for jaxpr findings)
    snippet: str = ""       # stripped source line / jaxpr eqn text

    def fingerprint(self) -> tuple:
        return (self.rule, self.path, self.context, self.snippet)

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    def render(self) -> str:
        loc = f"{self.path}:{self.line}" if self.line else self.path
        ctx = f" [{self.context}]" if self.context else ""
        out = f"{loc}: {self.rule}{ctx}: {self.message}"
        if self.snippet:
            out += f"\n    {self.snippet}"
        return out


def sort_findings(findings) -> list:
    return sorted(findings, key=lambda f: (f.path, f.line, f.rule))
