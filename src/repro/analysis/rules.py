"""Rule registry and per-directory profiles.

Every check either engine can emit is declared here with a stable id:

  * ``CA1xx`` — AST engine (``astpass``): pure-syntax contracts, no jax
    import needed, run on any python file.
  * ``CA2xx`` — jaxpr engine (``jaxprpass``): semantic contracts checked
    by tracing the entry-point manifest with ``jax.make_jaxpr`` at
    representative shapes.
  * ``CA3xx`` — comm engine (``commpass``): SPMD collective-schedule
    contracts — the ordered ppermute/psum/all_gather trace of each
    manifest entry is extracted from its jaxpr (multi-device ring
    schedules via ``axis_env`` tracing, no devices needed) and checked
    for deadlock signatures, permutation validity, declared
    ``COMM_CONTRACT``s and exact bytes-on-wire accounting against
    ``core.costmodel.comm_volume``.
  * ``CA4xx`` — pallas engine (``pallaspass``): Pallas kernel
    grid/BlockSpec contracts — every ``kernels.manifest.KERNEL_ENTRIES``
    configuration's grid is enumerated concretely and each index map
    evaluated at every grid point, checking output write races, coverage
    gaps, out-of-bounds block indices, narrow accumulators in
    f64-contract kernel bodies, oracle-twin declarations and
    grid/BlockSpec/SMEM-table shape consistency.

A :class:`Profile` is the set of rule ids active for a directory tree.
``src/repro`` runs the full ``default`` profile; ``benchmarks/`` /
``examples/`` / ``scripts/`` run the relaxed ``scripts`` profile (host
code by construction: python-level branching, host scalars and ad-hoc
dtypes are the point there, but collective/layer-bypass and jit-boundary
hazards still apply).

Adding a rule for a new backend: register it here (pick the next free id
in the engine's range), implement it in the engine module keyed on the
id, and add a tripping fixture to ``tests/test_analysis.py`` — the
registry test asserts every registered rule has a fixture that trips it.
"""
from __future__ import annotations

from dataclasses import dataclass, field


@dataclass(frozen=True)
class Rule:
    id: str
    name: str
    engine: str             # "ast" | "jaxpr" | "comm" | "pallas"
    description: str


_RULES: dict[str, Rule] = {}


def register_rule(rule: Rule, *, overwrite: bool = False) -> Rule:
    if not overwrite and rule.id in _RULES:
        raise ValueError(f"rule {rule.id} already registered")
    if rule.engine not in ("ast", "jaxpr", "comm", "pallas"):
        raise ValueError(f"unknown engine {rule.engine!r}")
    _RULES[rule.id] = rule
    return rule


def get_rule(rule_id: str) -> Rule:
    try:
        return _RULES[rule_id]
    except KeyError:
        raise ValueError(
            f"unknown rule {rule_id!r}; registered: {sorted(_RULES)}"
        ) from None


def all_rules() -> list[Rule]:
    return [_RULES[k] for k in sorted(_RULES)]


# ---------------------------------------------------------------------------
# the registry
# ---------------------------------------------------------------------------

register_rule(Rule(
    "CA100", "unparseable-source", "ast",
    "file failed to parse: nothing else can be checked until it does "
    "(always reported, independent of the active profile)",
))
register_rule(Rule(
    "CA101", "host-call-in-traced-code", "ast",
    "host-side call (float()/int()/bool()/.item()/.tolist()/np.*/print) "
    "inside a jit/vmap/shard_map-traced function: breaks tracing or "
    "silently constant-folds a traced value",
))
register_rule(Rule(
    "CA102", "python-branch-on-traced-value", "ast",
    "python if/while/assert whose test computes a jax value "
    "(jnp./jax./lax. call in the test) inside a traced function: raises "
    "TracerBoolConversionError or freezes a data-dependent branch at "
    "trace time — use lax.cond/jnp.where",
))
register_rule(Rule(
    "CA103", "impure-jit-boundary", "ast",
    "mutable default argument on a traced function, or an unregistered "
    "dataclass crossing a jit boundary (pass pytree-registered specs; "
    "mutable defaults alias state across traces)",
))
register_rule(Rule(
    "CA104", "dtype-literal-in-f64-module", "ast",
    "sub-64-bit float dtype literal (float32/float16/bfloat16) in an "
    "f64-contract module: the Gram/solve chain accumulates in float64 "
    "by contract — declare any intentional narrow dtype once as a "
    "module-level *_DTYPE constant so the policy is named and greppable",
))
register_rule(Rule(
    "CA105", "raw-collective-bypass", "ast",
    "mesh/shard_map entry APIs or collective primitives reached through "
    "raw jax attributes outside the collective layer: route "
    "shard_map/make_mesh/set_mesh and module-level psum through "
    "comm/compat.py (one module absorbs jax API skew; comm/ and "
    "core/distributed.py are the blessed lax.* call sites)",
))
register_rule(Rule(
    "CA106", "host-sync-in-loop", "ast",
    "device->host scalar pull (float()/int()/.item() over a jnp./np. "
    "expression) inside a python loop or comprehension: one blocking "
    "transfer per iteration — batch the device work, pull once",
))

register_rule(Rule(
    "CA200", "manifest-entry-error", "jaxpr",
    "a manifest entry failed to build/trace/execute: the semantic checks "
    "did not run for that entry point (always reported — a broken entry "
    "must not silently skip its contracts)",
))
register_rule(Rule(
    "CA201", "f64-downcast-in-jaxpr", "jaxpr",
    "convert_element_type from float64 to a narrower float in the jaxpr "
    "of a manifest entry point traced at f64: the distributed iteration "
    "must be bit-identical to the sequential one, so the f64 contract "
    "may never silently narrow",
))
register_rule(Rule(
    "CA202", "unexpected-recompile", "jaxpr",
    "compiled-program cache grew when a manifest entry was re-invoked "
    "with new parameter VALUES at unchanged shapes/statics: a lambda "
    "path or serving loop would recompile per point — keep penalty "
    "params and warm starts traced",
))
register_rule(Rule(
    "CA203", "psum-axis-not-in-mesh", "jaxpr",
    "collective primitive in a traced entry point names a mesh axis the "
    "entry does not declare: the axis would be unbound (or silently "
    "bound to the wrong mesh) at run time",
))

register_rule(Rule(
    "CA300", "comm-entry-error", "comm",
    "a manifest entry failed to build/trace for the comm engine: the "
    "collective-schedule checks did not run for that entry point "
    "(always reported — a broken entry must not silently skip)",
))
register_rule(Rule(
    "CA301", "branch-divergent-schedule", "comm",
    "lax.cond/lax.switch branches inside a traced SPMD region execute "
    "different collective sequences: devices taking different branches "
    "post mismatched collectives — the static signature of a distributed "
    "deadlock (hoist the collectives out of the branch, or make every "
    "branch post the identical sequence)",
))
register_rule(Rule(
    "CA302", "non-bijective-ppermute", "comm",
    "ppermute permutation table is not a bijection over the bound mesh "
    "axis extent (duplicate source/destination, out-of-range rank, or — "
    "under a declared COMM_CONTRACT — partial ring coverage): data is "
    "silently dropped/zeroed instead of rotated",
))
register_rule(Rule(
    "CA303", "comm-volume-mismatch", "comm",
    "statically derived bytes-on-wire of the traced collective schedule "
    "(ring rounds x block bytes + team psum/allgather bytes) does not "
    "equal the analytic core.costmodel.comm_volume the COMM_CONTRACT "
    "declares: an extra collective, a missing round or a widened wire "
    "dtype crept into the schedule",
))
register_rule(Rule(
    "CA304", "redundant-collective", "comm",
    "collective that moves bytes for nothing: psum of a value that is "
    "already the result of a psum over the same axes, or back-to-back "
    "ppermutes over the same axes whose intermediate has no other "
    "consumer (compose the permutation tables into one hop)",
))
register_rule(Rule(
    "CA305", "comm-contract-violation", "comm",
    "traced schedule disagrees with the module's declared COMM_CONTRACT: "
    "a collective binds an undeclared axis, posts an undeclared "
    "collective kind, or a ring scan runs a different number of rounds "
    "than the contract declares",
))
register_rule(Rule(
    "CA306", "wire-dtype-policy", "comm",
    "collective ships a payload dtype the COMM_CONTRACT does not allow "
    "on the wire (e.g. float64 through a path whose contract declares a "
    "compressed bf16/int8 wire format): the declared bytes-on-wire "
    "budget silently multiplies",
))
register_rule(Rule(
    "CA400", "kernel-entry-error", "pallas",
    "a KERNEL_ENTRIES registration failed to build its layout or trace "
    "its kernel body: the grid/BlockSpec checks did not run for that "
    "configuration (always reported — a broken entry must not silently "
    "skip its contracts)",
))
register_rule(Rule(
    "CA401", "kernel-write-race", "pallas",
    "two grid points map to the same output block along grid dims the "
    "kernel does not declare as sequential accumulation (a parallel "
    "write race), or a declared accumulation revisits the block "
    "non-consecutively (the block is flushed when its index changes, so "
    "the later visit clobbers the earlier partial sums — the "
    "blocksparse duplicate-row scatter hazard)",
))
register_rule(Rule(
    "CA402", "kernel-coverage-gap", "pallas",
    "the union of output blocks written over the whole grid fails to "
    "tile the output array: unwritten blocks ship whatever stale memory "
    "the buffer held (e.g. a block-CSR row list missing a block-row)",
))
register_rule(Rule(
    "CA403", "kernel-block-oob", "pallas",
    "an input/output BlockSpec index map evaluates outside "
    "[0, cdiv(dim, block)) at some grid point given the padded array "
    "bounds: the kernel reads or writes past the operand (e.g. a "
    "block-CSR col id addressing beyond the dense operand's block rows)",
))
register_rule(Rule(
    "CA404", "kernel-narrow-accumulator", "pallas",
    "the traced body of an f64-contract kernel narrows a float64 value "
    "(convert_element_type to f32/f16/bf16, or a dot_general with a "
    "narrow preferred_element_type over f64 operands): the solver's f64 "
    "iteration contract must hold inside the kernel too",
))
register_rule(Rule(
    "CA405", "kernel-missing-oracle", "pallas",
    "a pallas_call site ships without a registered ref.py oracle twin, "
    "or its KERNEL_ENTRIES declaration names a missing oracle / an "
    "unknown tolerance class: every kernel must declare bit-exact or "
    "fp-tolerant and be differentially testable against pure jnp",
))
register_rule(Rule(
    "CA406", "kernel-spec-inconsistent", "pallas",
    "grid/BlockSpec/SMEM scalar-table shape inconsistency: index-map "
    "arity differs from the grid (+ scalar-prefetch) rank, block rank "
    "differs from the operand rank, a block dim exceeds the operand "
    "dim, or the SMEM table holds fewer rows than the grid's lane "
    "indexing reads",
))


# ---------------------------------------------------------------------------
# profiles
# ---------------------------------------------------------------------------

AST_RULES = frozenset(r.id for r in all_rules() if r.engine == "ast")
JAXPR_RULES = frozenset(r.id for r in all_rules() if r.engine == "jaxpr")
COMM_RULES = frozenset(r.id for r in all_rules() if r.engine == "comm")
PALLAS_RULES = frozenset(r.id for r in all_rules() if r.engine == "pallas")


@dataclass(frozen=True)
class Profile:
    """The rule subset + per-rule knobs active for one directory tree."""
    name: str
    rules: frozenset = AST_RULES | JAXPR_RULES | COMM_RULES | PALLAS_RULES
    # modules under the f64 accumulation contract (CA104), matched as
    # posix path suffixes
    f64_modules: tuple = ()
    # path suffixes allowed to touch lax collectives directly (CA105)
    collective_layer: tuple = ()
    extra: dict = field(default_factory=dict)


#: modules where a 32-bit float literal would narrow the paper's f64
#: iteration/accumulation contract (flash_attention is excluded: an
#: attention kernel's f32 accumulator is its own, unrelated contract)
F64_CONTRACT_MODULES = (
    "repro/core/objective.py",
    "repro/core/prox.py",
    "repro/core/matops.py",
    "repro/core/batch.py",
    "repro/core/distributed.py",
    "repro/core/penalty.py",
    "repro/data/gram.py",
    "repro/data/transforms.py",
    "repro/comm/matmul1p5d.py",
    "repro/comm/sparse1p5d.py",
    "repro/kernels/softthresh.py",
    "repro/kernels/pathstep.py",
    "repro/kernels/blocksparse_matmul.py",
    "repro/kernels/ref.py",
    "repro/kernels/ops.py",
)

#: the blessed raw-lax-collective call sites (CA105): the comm layer
#: itself and the shard_map drivers that live inside it conceptually
COLLECTIVE_LAYER = (
    "repro/comm/",
    "repro/core/distributed.py",
)

DEFAULT_PROFILE = Profile(
    name="default",
    rules=AST_RULES | JAXPR_RULES | COMM_RULES | PALLAS_RULES,
    f64_modules=F64_CONTRACT_MODULES,
    collective_layer=COLLECTIVE_LAYER,
)

#: benchmarks/examples/scripts: host-side drivers by design.  Python
#: branching on results, ad-hoc dtypes and per-iteration host pulls are
#: the point of a script, so CA102/CA104/CA106 are off; trace-breaking
#: host calls, jit-boundary impurities and collective-layer bypasses
#: still apply (scripts share the solver entry points).
SCRIPTS_PROFILE = Profile(
    name="scripts",
    rules=frozenset({"CA101", "CA103", "CA105"}),
    f64_modules=(),
    collective_layer=COLLECTIVE_LAYER,
)

#: the observability layer (repro/obs/): host-side by construction — the
#: tracer reads clocks, the comm watcher re-traces jaxprs, the registry
#: mutates python dicts — so the traced-code host-call rules (CA101) and
#: the in-loop host-sync rule (CA106) do not apply; nothing in obs/ runs
#: inside a jitted program (the CA202 reuse recipe proves it).  Trace
#: hygiene for what obs *touches* (dtype discipline, jit-boundary purity,
#: collective-layer routing) still applies.
OBS_PROFILE = Profile(
    name="obs",
    rules=frozenset({"CA102", "CA103", "CA105"}),
    f64_modules=(),
    collective_layer=COLLECTIVE_LAYER,
)

PROFILES = {p.name: p for p in (DEFAULT_PROFILE, SCRIPTS_PROFILE,
                                OBS_PROFILE)}

_SCRIPT_DIR_HINTS = ("benchmarks/", "examples/", "scripts/")

_OBS_DIR_HINT = "repro/obs/"


def profile_for_path(relpath: str) -> Profile:
    """Per-directory profile resolution (posix relpath from repo root)."""
    rp = relpath.replace("\\", "/")
    if any(rp.startswith(h) or f"/{h}" in rp for h in _SCRIPT_DIR_HINTS):
        return SCRIPTS_PROFILE
    if rp.startswith(_OBS_DIR_HINT) or f"/{_OBS_DIR_HINT}" in rp:
        return OBS_PROFILE
    return DEFAULT_PROFILE
