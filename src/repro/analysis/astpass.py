"""AST engine: syntactic JAX-contract rules (CA1xx), stdlib-``ast`` only.

The engine is deliberately import-free with respect to jax — it parses
source, so it can run on any file (including benchmarks) without
initializing a backend.  Per module it works in three passes:

  1. resolve import origins (``jnp`` -> ``jax.numpy``, ``shard_map`` ->
     ``repro.comm.compat.shard_map`` / ``jax.experimental...``), so rules
     key on *where a name came from*, not on spelling;
  2. discover TRACED functions: decorated with jit/vmap/pmap/shard_map
     (including ``partial(jax.jit, ...)``), passed by name into a tracing
     call (``shard_map``, ``lax.while_loop``, ``pallas_call``,
     ``make_jaxpr``, ...), then closed over nested defs and same-module
     callees (a function called from a traced body is traced too);
  3. run the rule visitors with that traced-scope map.

This is a linter, not an interpreter: cross-module call graphs are out of
scope (the jaxpr engine covers the real entry points semantically), and
``static_argnames`` parsed off the jit decorator exempt the declared
host-side parameters.

Inline suppression: a line containing ``# ca: allow=CA1xx`` (comma list,
or ``allow=*``) suppresses findings on that line; prefer the checked-in
baseline file for anything longer-lived.
"""
from __future__ import annotations

import ast
import re
from dataclasses import dataclass, field

from .findings import Finding
from .rules import Profile

# -- name sets --------------------------------------------------------------

#: final path components that mark a callee/decorator as entering a trace
TRACING_NAMES = frozenset({
    "jit", "vmap", "pmap", "shard_map", "make_jaxpr", "eval_shape",
    "while_loop", "fori_loop", "scan", "cond", "switch",
    "pallas_call", "checkpoint", "remat", "grad", "value_and_grad",
    "custom_jvp", "custom_vjp", "named_call",
})

#: origin prefixes under which TRACING_NAMES count (a bare builtin
#: ``map``/``filter`` never resolves to these)
_TRACING_PREFIXES = ("jax", "repro.", "functools")

HOST_SCALAR_BUILTINS = frozenset({"float", "int", "bool", "complex"})
HOST_PULL_METHODS = frozenset({"item", "tolist", "to_py"})

#: lax collectives that must stay inside the collective layer (CA105)
COLLECTIVE_PRIMS = frozenset({
    "psum", "pmean", "pmin", "pmax", "ppermute", "pbroadcast",
    "all_gather", "all_to_all", "psum_scatter", "axis_index", "axis_size",
})

#: mesh/shard_map entry APIs that must come from comm/compat (CA105)
COMPAT_ONLY_ORIGINS = frozenset({
    "jax.shard_map",
    "jax.experimental.shard_map.shard_map",
    "jax.make_mesh",
    "jax.set_mesh",
    "jax.sharding.get_abstract_mesh",
    "jax.sharding.Mesh",
})

NARROW_FLOAT_DTYPES = frozenset({"float32", "float16", "bfloat16"})
_NARROW_DTYPE_STRINGS = NARROW_FLOAT_DTYPES | {"f32", "f16", "bf16"}

_ALLOW_RE = re.compile(r"#\s*ca:\s*allow=([A-Z0-9*,\s]+)")


def _line_allows(source_lines: list[str], lineno: int, rule_id: str) -> bool:
    if not (1 <= lineno <= len(source_lines)):
        return False
    m = _ALLOW_RE.search(source_lines[lineno - 1])
    if not m:
        return False
    allowed = {t.strip() for t in m.group(1).split(",")}
    return "*" in allowed or rule_id in allowed


# -- import-origin resolution -----------------------------------------------

def _collect_imports(tree: ast.Module) -> dict[str, str]:
    """alias -> dotted origin ('jnp' -> 'jax.numpy'); relative imports
    keep their module path with the leading dots stripped."""
    origins: dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                origins[(a.asname or a.name.split(".")[0])] = (
                    a.name if a.asname else a.name.split(".")[0])
                if a.asname is None and "." in a.name:
                    # `import jax.numpy` binds `jax`; remember the root
                    origins[a.name.split(".")[0]] = a.name.split(".")[0]
        elif isinstance(node, ast.ImportFrom):
            mod = (node.module or "").lstrip(".") or ""
            for a in node.names:
                if a.name == "*":
                    continue
                origin = f"{mod}.{a.name}" if mod else a.name
                origins[a.asname or a.name] = origin
    return origins


def _origin_of(node: ast.AST, imports: dict[str, str]) -> str | None:
    """Dotted origin of a Name/Attribute chain, or None if the base name
    was not imported (a local def, builtin, or parameter)."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        base = imports.get(node.id)
        if base is None:
            return None
        parts.append(base)
        return ".".join(reversed(parts))
    return None


def _is_jaxish(origin: str | None) -> bool:
    return origin is not None and (
        origin == "jax" or origin.startswith(("jax.", "numpy")))


def _unwrap_partial(call: ast.Call, imports) -> ast.AST:
    """partial(jax.jit, ...) -> jax.jit (first positional arg)."""
    origin = _origin_of(call.func, imports)
    if origin and origin.split(".")[-1] == "partial" and call.args:
        return call.args[0]
    return call.func


# -- traced-function discovery ----------------------------------------------

@dataclass
class _FnInfo:
    node: ast.AST
    qualname: str
    parent: "_FnInfo | None"
    traced: bool = False
    static_names: frozenset = frozenset()
    callees: set = field(default_factory=set)   # local function names called


def _static_names_from_decorators(fn, imports) -> frozenset:
    """static_argnames declared on a jit decorator (strings only)."""
    names: set[str] = set()
    for dec in fn.decorator_list:
        call = dec if isinstance(dec, ast.Call) else None
        if call is None:
            continue
        target = _unwrap_partial(call, imports)
        origin = _origin_of(target, imports)
        if not origin or origin.split(".")[-1] != "jit":
            continue
        for kw in call.keywords:
            if kw.arg == "static_argnames":
                for n in ast.walk(kw.value):
                    if isinstance(n, ast.Constant) and isinstance(n.value,
                                                                  str):
                        names.add(n.value)
    return frozenset(names)


class _FnCollector(ast.NodeVisitor):
    """Collect function defs (with nesting), their local call edges, and
    the set of function names referenced inside tracing calls."""

    def __init__(self, imports: dict[str, str]):
        self.imports = imports
        self.fns: dict[int, _FnInfo] = {}        # id(node) -> info
        self.by_name: dict[str, list[_FnInfo]] = {}
        self.trace_marked: set[str] = set()      # names passed to tracers
        self._stack: list[_FnInfo] = []
        self._class_stack: list[str] = []

    def _qual(self, name: str) -> str:
        scope = [f.qualname for f in self._stack[-1:]] or self._class_stack[-1:]
        return f"{scope[0]}.{name}" if scope else name

    def visit_ClassDef(self, node: ast.ClassDef):
        self._class_stack.append(self._qual(node.name))
        self.generic_visit(node)
        self._class_stack.pop()

    def _visit_fn(self, node):
        info = _FnInfo(
            node=node, qualname=self._qual(node.name),
            parent=self._stack[-1] if self._stack else None,
            static_names=_static_names_from_decorators(node, self.imports),
        )
        self.fns[id(node)] = info
        self.by_name.setdefault(node.name, []).append(info)
        for dec in node.decorator_list:
            target = (_unwrap_partial(dec, self.imports)
                      if isinstance(dec, ast.Call) else dec)
            origin = _origin_of(target, self.imports)
            if (origin and origin.split(".")[-1] in TRACING_NAMES
                    and origin.startswith(_TRACING_PREFIXES)):
                info.traced = True
        self._stack.append(info)
        self.generic_visit(node)
        self._stack.pop()

    visit_FunctionDef = _visit_fn
    visit_AsyncFunctionDef = _visit_fn

    def visit_Call(self, node: ast.Call):
        if self._stack:
            callee = node.func
            if isinstance(callee, ast.Name):
                self._stack[-1].callees.add(callee.id)
        origin = _origin_of(_unwrap_partial(node, self.imports), self.imports)
        if (origin and origin.split(".")[-1] in TRACING_NAMES
                and origin.startswith(_TRACING_PREFIXES)):
            for arg in list(node.args) + [kw.value for kw in node.keywords]:
                target = arg
                if isinstance(target, ast.Call):
                    target = _unwrap_partial(target, self.imports)
                if isinstance(target, ast.Name):
                    self.trace_marked.add(target.id)
        self.generic_visit(node)


def _resolve_traced(collector: _FnCollector) -> None:
    """Fixpoint closure: decorator/marker-traced functions, their nested
    defs, and their same-module callees are all traced."""
    for name in collector.trace_marked:
        for info in collector.by_name.get(name, []):
            info.traced = True
    changed = True
    while changed:
        changed = False
        for info in collector.fns.values():
            if info.traced:
                continue
            if info.parent is not None and info.parent.traced:
                info.traced = changed = True
        for info in collector.fns.values():
            if not info.traced:
                continue
            for callee in info.callees:
                for target in collector.by_name.get(callee, []):
                    if not target.traced and target.parent is None:
                        target.traced = changed = True


# -- the rule pass ----------------------------------------------------------

def _contains_jax_call(node: ast.AST, imports) -> bool:
    for n in ast.walk(node):
        if isinstance(n, ast.Call):
            if _is_jaxish(_origin_of(n.func, imports)):
                return True
            if (isinstance(n.func, ast.Attribute)
                    and n.func.attr in ("any", "all")):
                return True
    return False


_STATIC_ATTRS = ("shape", "ndim", "dtype", "size")


def _is_static_metadata(node: ast.AST) -> bool:
    """A (possibly subscripted) ``.shape``/``.ndim``/``.size``/``.dtype``
    read: host metadata, not device data — never a sync."""
    while isinstance(node, ast.Subscript):
        node = node.value
    return isinstance(node, ast.Attribute) and node.attr in _STATIC_ATTRS


def _mentions_traced_param(node: ast.AST, params: frozenset) -> bool:
    """A parameter Name occurs NOT as the base of a static attribute
    (``x.shape`` is host-side metadata, ``x`` itself is traced)."""
    static_bases = set()
    for n in ast.walk(node):
        if isinstance(n, ast.Attribute) and n.attr in _STATIC_ATTRS:
            for b in ast.walk(n.value):
                if isinstance(b, ast.Name):
                    static_bases.add(id(b))
    for n in ast.walk(node):
        if (isinstance(n, ast.Name) and n.id in params
                and id(n) not in static_bases):
            return True
    return False


def _is_mutable_default(node: ast.AST) -> bool:
    if isinstance(node, (ast.List, ast.Dict, ast.Set)):
        return True
    return (isinstance(node, ast.Call) and isinstance(node.func, ast.Name)
            and node.func.id in ("list", "dict", "set"))


class _RuleVisitor(ast.NodeVisitor):
    def __init__(self, relpath: str, source_lines: list[str],
                 imports: dict[str, str], collector: _FnCollector,
                 profile: Profile):
        self.relpath = relpath
        self.lines = source_lines
        self.imports = imports
        self.collector = collector
        self.profile = profile
        self.findings: list[Finding] = []
        self._fn_stack: list[_FnInfo] = []
        self._loop_depth = 0
        self._dtype_exempt: set[int] = set()     # node ids inside *_DTYPE =
        self._in_f64_module = any(
            relpath.endswith(m) for m in profile.f64_modules)
        self._in_collective_layer = any(
            s in relpath or relpath.endswith(s.rstrip("/"))
            for s in profile.collective_layer
        ) or relpath.endswith("compat.py")
        self._unregistered_dataclasses: set[str] = set()

    # -- emission ----------------------------------------------------

    def _emit(self, rule: str, node: ast.AST, message: str):
        if rule not in self.profile.rules:
            return
        line = getattr(node, "lineno", 0)
        if _line_allows(self.lines, line, rule):
            return
        snippet = (self.lines[line - 1].strip()
                   if 1 <= line <= len(self.lines) else "")
        ctx = self._fn_stack[-1].qualname if self._fn_stack else "<module>"
        self.findings.append(Finding(
            rule=rule, path=self.relpath, line=line, message=message,
            context=ctx, snippet=snippet))

    # -- module prep -------------------------------------------------

    def scan_module(self, tree: ast.Module):
        for node in tree.body:
            if (isinstance(node, ast.Assign) and len(node.targets) == 1
                    and isinstance(node.targets[0], ast.Name)
                    and node.targets[0].id.endswith("_DTYPE")):
                for sub in ast.walk(node):
                    self._dtype_exempt.add(id(sub))
        self._find_unregistered_dataclasses(tree)
        self.visit(tree)

    def _find_unregistered_dataclasses(self, tree: ast.Module):
        registered: set[str] = set()
        for node in ast.walk(tree):
            if isinstance(node, ast.Call):
                origin = _origin_of(node.func, self.imports) or ""
                if origin.split(".")[-1] in ("register_dataclass",
                                             "register_pytree_node"):
                    for arg in node.args:
                        if isinstance(arg, ast.Name):
                            registered.add(arg.id)
        for node in ast.walk(tree):
            if not isinstance(node, ast.ClassDef):
                continue
            is_dc = is_reg = False
            for dec in node.decorator_list:
                target = dec.func if isinstance(dec, ast.Call) else dec
                origin = _origin_of(target, self.imports) or ""
                leaf = origin.split(".")[-1] if origin else (
                    target.id if isinstance(target, ast.Name) else "")
                if leaf == "dataclass":
                    is_dc = True
                if leaf in ("register_pytree_node_class",
                            "register_pytree_with_keys_class"):
                    is_reg = True
            if is_dc and not is_reg and node.name not in registered:
                self._unregistered_dataclasses.add(node.name)

    # -- scope bookkeeping -------------------------------------------

    def _visit_fn(self, node):
        info = self.collector.fns.get(id(node))
        self._fn_stack.append(info)
        if info is not None and info.traced:
            self._check_fn_boundary(node, info)
        outer_loops = self._loop_depth
        self._loop_depth = 0
        self.generic_visit(node)
        self._loop_depth = outer_loops
        self._fn_stack.pop()

    visit_FunctionDef = _visit_fn
    visit_AsyncFunctionDef = _visit_fn

    def _traced(self) -> _FnInfo | None:
        for info in reversed(self._fn_stack):
            if info is not None and info.traced:
                return info
        return None

    def _traced_params(self) -> frozenset:
        names: set[str] = set()
        for info in self._fn_stack:
            if info is None or not info.traced:
                continue
            a = info.node.args
            for arg in (a.posonlyargs + a.args + a.kwonlyargs
                        + ([a.vararg] if a.vararg else [])
                        + ([a.kwarg] if a.kwarg else [])):
                if arg.arg not in info.static_names:
                    names.add(arg.arg)
        return frozenset(names)

    # -- CA103: jit-boundary impurities -------------------------------

    def _check_fn_boundary(self, node, info: _FnInfo):
        a = node.args
        for arg, default in zip(
                (a.posonlyargs + a.args)[-len(a.defaults):]
                if a.defaults else [], a.defaults):
            if _is_mutable_default(default):
                self._emit(
                    "CA103", default,
                    f"traced function '{info.qualname}' has a mutable "
                    f"default for '{arg.arg}': the default is created once "
                    f"and aliased across every trace")
        for arg, default in zip(a.kwonlyargs, a.kw_defaults):
            if default is not None and _is_mutable_default(default):
                self._emit(
                    "CA103", default,
                    f"traced function '{info.qualname}' has a mutable "
                    f"default for '{arg.arg}'")
        for arg in a.posonlyargs + a.args + a.kwonlyargs:
            if arg.annotation is None:
                continue
            for n in ast.walk(arg.annotation):
                if (isinstance(n, ast.Name)
                        and n.id in self._unregistered_dataclasses):
                    self._emit(
                        "CA103", arg.annotation,
                        f"parameter '{arg.arg}' of traced function "
                        f"'{info.qualname}' is an unregistered dataclass "
                        f"'{n.id}': register it as a pytree "
                        f"(jax.tree_util.register_dataclass / "
                        f"register_pytree_node_class) before it crosses "
                        f"the jit boundary")

    # -- loops (for CA106) --------------------------------------------

    def visit_For(self, node):
        self._loop_depth += 1
        self.generic_visit(node)
        self._loop_depth -= 1

    visit_AsyncFor = visit_For

    def visit_While(self, node):
        self._check_branch(node.test, "while")
        self._loop_depth += 1
        self.generic_visit(node)
        self._loop_depth -= 1

    def _visit_comp(self, node):
        self._loop_depth += 1
        self.generic_visit(node)
        self._loop_depth -= 1

    visit_ListComp = _visit_comp
    visit_SetComp = _visit_comp
    visit_DictComp = _visit_comp
    visit_GeneratorExp = _visit_comp

    # -- CA102: python branch on traced value -------------------------

    def visit_If(self, node):
        self._check_branch(node.test, "if")
        self.generic_visit(node)

    def visit_Assert(self, node):
        self._check_branch(node.test, "assert")
        self.generic_visit(node)

    def _check_branch(self, test: ast.AST, kw: str):
        info = self._traced()
        if info is None:
            return
        if _contains_jax_call(test, self.imports):
            self._emit(
                "CA102", test,
                f"python `{kw}` on a value computed by a jax call inside "
                f"traced '{info.qualname}': concretizes a tracer (use "
                f"lax.cond / jnp.where, or hoist the check out of the "
                f"traced region)")

    # -- calls: CA101 / CA105 / CA106 ---------------------------------

    def visit_Call(self, node: ast.Call):
        info = self._traced()
        origin = _origin_of(node.func, self.imports)
        if info is not None:
            self._check_host_call(node, info, origin)
        self._check_collective(node, origin)
        self._check_host_sync_loop(node, origin)
        self.generic_visit(node)

    def _check_host_call(self, node: ast.Call, info: _FnInfo,
                         origin: str | None):
        func = node.func
        if isinstance(func, ast.Name) and func.id in HOST_SCALAR_BUILTINS:
            if node.args and (
                    _contains_jax_call(node.args[0], self.imports)
                    or _mentions_traced_param(node.args[0],
                                              self._traced_params())):
                self._emit(
                    "CA101", node,
                    f"`{func.id}()` on a traced value inside "
                    f"'{info.qualname}': concretizes the tracer (keep it "
                    f"a jax scalar, or mark the argument static)")
            return
        if isinstance(func, ast.Attribute) and func.attr in HOST_PULL_METHODS:
            self._emit(
                "CA101", node,
                f"`.{func.attr}()` inside traced '{info.qualname}': "
                f"device->host pull under trace")
            return
        if isinstance(func, ast.Name) and func.id == "print":
            self._emit(
                "CA101", node,
                f"`print()` inside traced '{info.qualname}': runs once at "
                f"trace time, not per step (use jax.debug.print)")
            return
        if origin and origin.startswith("numpy"):
            self._emit(
                "CA101", node,
                f"numpy call `{origin}` inside traced '{info.qualname}': "
                f"numpy executes at trace time on abstract values (use "
                f"jnp, or hoist the constant out of the traced region)")

    def _check_collective(self, node: ast.Call, origin: str | None):
        if origin is None or self._in_collective_layer:
            return
        if origin in COMPAT_ONLY_ORIGINS:
            leaf = origin.split(".")[-1]
            self._emit(
                "CA105", node,
                f"raw `{origin}` bypasses comm/compat.py: import "
                f"`{leaf if leaf != 'Mesh' else 'make_mesh'}` from "
                f"repro.comm.compat so one module absorbs jax API skew")
            return
        parts = origin.split(".")
        if (parts[-1] in COLLECTIVE_PRIMS
                and origin.startswith(("jax.lax.", "jax."))
                and "compat" not in origin):
            self._emit(
                "CA105", node,
                f"raw collective `{origin}` outside the collective layer "
                f"(comm/, core/distributed.py): import it from "
                f"repro.comm.compat so call sites stay auditable")

    def _check_host_sync_loop(self, node: ast.Call, origin: str | None):
        if self._loop_depth == 0:
            return
        func = node.func
        is_pull = (
            (isinstance(func, ast.Name) and func.id in ("float", "int"))
            or (isinstance(func, ast.Attribute)
                and func.attr in HOST_PULL_METHODS))
        if not is_pull:
            return
        probe = node.args[0] if node.args else (
            func.value if isinstance(func, ast.Attribute) else None)
        if probe is not None and _is_static_metadata(probe):
            return      # .shape/.ndim/.size reads are host metadata
        if probe is not None and _contains_jax_call(probe, self.imports):
            self._emit(
                "CA106", node,
                "device->host scalar pull inside a loop/comprehension: "
                "each iteration blocks on a transfer — stack the device "
                "values and pull once outside the loop")

    # -- CA104: dtype literals in f64-contract modules ----------------

    def visit_Attribute(self, node: ast.Attribute):
        if self._in_f64_module and id(node) not in self._dtype_exempt:
            origin = _origin_of(node, self.imports)
            if origin:
                parts = origin.split(".")
                if (parts[-1] in NARROW_FLOAT_DTYPES
                        and parts[0] in ("jax", "numpy", "jnp", "np")):
                    self._emit(
                        "CA104", node,
                        f"narrow float dtype literal `{origin}` in an "
                        f"f64-contract module: derive the dtype from the "
                        f"operand, or name the policy once in a "
                        f"module-level *_DTYPE constant")
        self.generic_visit(node)

    def visit_keyword(self, node: ast.keyword):
        if (self._in_f64_module and node.arg == "dtype"
                and isinstance(node.value, ast.Constant)
                and isinstance(node.value.value, str)
                and node.value.value in _NARROW_DTYPE_STRINGS
                and id(node.value) not in self._dtype_exempt):
            self._emit(
                "CA104", node.value,
                f"narrow float dtype string {node.value.value!r} in an "
                f"f64-contract module")
        self.generic_visit(node)


# -- entry point ------------------------------------------------------------

def scan_source(relpath: str, source: str, profile: Profile) -> list[Finding]:
    """Run the AST rules over one file's source text."""
    try:
        tree = ast.parse(source, filename=relpath)
    except SyntaxError as e:
        return [Finding(rule="CA100", path=relpath, line=e.lineno or 0,
                        message=f"syntax error: {e.msg}", context="<module>")]
    imports = _collect_imports(tree)
    collector = _FnCollector(imports)
    collector.visit(tree)
    _resolve_traced(collector)
    visitor = _RuleVisitor(relpath, source.splitlines(), imports,
                           collector, profile)
    visitor.scan_module(tree)
    return visitor.findings


def scan_file(path, relpath: str, profile: Profile) -> list[Finding]:
    with open(path, encoding="utf-8") as f:
        return scan_source(relpath, f.read(), profile)
