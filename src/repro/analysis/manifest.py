"""Entry-point manifest collection.

Each solver layer declares its own traceable entry points in a
module-level ``ANALYSIS_ENTRIES`` list (schema documented in
:mod:`repro.analysis.jaxprpass`) — the manifest lives WITH the code it
describes, so adding a backend means adding entries next to the new
entry points, not editing the analysis package.  This module only knows
which layers to ask.
"""
from __future__ import annotations

import importlib

#: the solver layers that export ``ANALYSIS_ENTRIES``
MANIFEST_MODULES = (
    "repro.core.prox",          # sequential reference solve
    "repro.core.batch",         # batched lambda-path / multi-problem engine
    "repro.core.distributed",   # 1.5D shard_map drivers (cov + obs)
    "repro.data.gram",          # streaming Gram reduce + panel compute core
    "repro.kernels.ops",        # Pallas prox dispatch (interpret mode)
    "repro.comm.matmul1p5d",    # 1.5D ring products (axis_env schedules)
    "repro.comm.sparse1p5d",    # masked ring products (mask on the wire)
    "repro.comm.collectives",   # compressed wire formats (int8 ring, bf16)
    "repro.obs.commwatch",      # traced-solve CA202 reuse recipe (obs)
)


def load_entries(modules=MANIFEST_MODULES) -> list:
    """Import the manifest modules and concatenate their entries.

    Raises ImportError eagerly: a layer that fails to import is a finding
    in itself and must not be silently skipped.
    """
    entries: list = []
    for name in modules:
        mod = importlib.import_module(name)
        declared = getattr(mod, "ANALYSIS_ENTRIES", None)
        if declared is None:
            raise AttributeError(
                f"manifest module {name} exports no ANALYSIS_ENTRIES; "
                f"every solver layer must declare its entry points")
        entries.extend(declared)
    names = [e["name"] for e in entries]
    dupes = {n for n in names if names.count(n) > 1}
    if dupes:
        raise ValueError(f"duplicate manifest entry names: {sorted(dupes)}")
    return entries
