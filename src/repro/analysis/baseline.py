"""Checked-in suppression baseline.

The baseline file (``analysis_baseline.json`` at the repo root) is a
JSON list of finding fingerprints — rule + path + enclosing symbol +
stripped source text, deliberately line-number-free so unrelated edits
don't invalidate it.  The intended steady state is an EMPTY list: the
baseline exists to land the analyzer on a codebase with pre-existing
findings and burn them down, not to park new ones.  ``--write-baseline``
regenerates it from the current findings; entries that no longer match
anything are reported as stale so the file shrinks monotonically.
"""
from __future__ import annotations

import json
from pathlib import Path

_KEYS = ("rule", "path", "context", "snippet")


def load_baseline(path) -> list[tuple]:
    p = Path(path)
    if not p.exists():
        return []
    data = json.loads(p.read_text(encoding="utf-8"))
    if not isinstance(data, list):
        raise ValueError(f"baseline {p} must be a JSON list, got "
                         f"{type(data).__name__}")
    out = []
    for i, item in enumerate(data):
        if not isinstance(item, dict) or not all(k in item for k in _KEYS):
            raise ValueError(
                f"baseline {p} entry {i} must be an object with keys "
                f"{_KEYS}, got {item!r}")
        out.append(tuple(item[k] for k in _KEYS))
    return out


def write_baseline(findings, path) -> None:
    entries = sorted({f.fingerprint() for f in findings})
    data = [dict(zip(_KEYS, e)) for e in entries]
    Path(path).write_text(
        json.dumps(data, indent=2) + "\n", encoding="utf-8")


def split_by_baseline(findings, baseline: list[tuple]):
    """-> (new, suppressed, stale_baseline_entries)."""
    allowed = set(baseline)
    new, suppressed = [], []
    matched = set()
    for f in findings:
        fp = f.fingerprint()
        if fp in allowed:
            suppressed.append(f)
            matched.add(fp)
        else:
            new.append(f)
    stale = sorted(allowed - matched)
    return new, suppressed, stale
