"""jaxpr engine: semantic JAX-contract rules (CA2xx).

Where the AST engine reads source, this engine runs the tracer: every
solver layer exports an ``ANALYSIS_ENTRIES`` manifest (collected by
:mod:`repro.analysis.manifest`) describing its real entry points at
representative shapes.  Each entry is traced with ``jax.make_jaxpr``
under ``enable_x64`` and its (nested) jaxprs are walked for

  * CA201 — ``convert_element_type`` narrowing float64 to a smaller
    float: the f64 Gram/solve contract may never silently downcast;
  * CA203 — collective primitives (psum & friends) naming a mesh axis the
    entry did not declare;

and, for entries that ship a ``reuse`` recipe, the compiled-program
caches are watched across repeat invocations at unchanged shapes/statics
(CA202 — generalizing the penalty tests' ``_cache_size`` assertion).

Entry schema (each item of a module's ``ANALYSIS_ENTRIES`` list)::

    {
      "name": "core.prox.solve_reference",   # finding context
      "path": "src/repro/core/prox.py",      # finding location
      "axis_names": ("i", "j", "k"),          # mesh axes psum may bind
      "build": lambda: {                      # called under enable_x64
          "fn": callable,                     # what to make_jaxpr
          "args": tuple, "kwargs": dict,      # representative operands
          "ctx": optional () -> contextmanager,   # e.g. use_mesh(mesh)
      },
      "reuse": optional lambda: {             # CA202, executed (not traced)
          "watched": {"label": jitted_fn},    # caches to snapshot
          "calls": [thunk, ...],              # calls[0] warms, rest must
      },                                      # not grow any cache
      "skip": ("CA201", ...),                 # optional per-entry opt-outs
    }                                         # (a declared narrowing lives
                                              # next to its contract)

The build spec may also carry ``"axis_env"`` (a tuple of (axis, size)
pairs passed to ``make_jaxpr``) so SPMD ring functions trace their
multi-device schedules without devices, and ``"axis_sizes"`` /
``"comm"`` consumed by the comm engine (see
:mod:`repro.analysis.commpass`).

``build``/``reuse`` are zero-arg thunks so importing a layer module never
builds arrays or touches the backend.
"""
from __future__ import annotations

import traceback
from contextlib import nullcontext

from .findings import Finding
from .recompile import RecompileGuard
from .rules import Profile

NARROW_FLOATS = ("float32", "float16", "bfloat16")

#: primitives whose params can bind mesh axis names
COLLECTIVE_PRIM_NAMES = frozenset({
    "psum", "pmin", "pmax", "ppermute", "pbroadcast", "all_gather",
    "all_to_all", "reduce_scatter", "psum_scatter", "axis_index",
    "psum_invariant", "all_gather_invariant",
})

_AXIS_PARAM_KEYS = ("axes", "axis_name", "axis_index_groups_axis")


def iter_eqns(jaxpr):
    """Yield every eqn in a (Closed)Jaxpr, descending into sub-jaxprs held
    in eqn params (pjit/while/cond/scan/shard_map/custom_* bodies)."""
    inner = getattr(jaxpr, "jaxpr", jaxpr)     # ClosedJaxpr -> Jaxpr
    for eqn in inner.eqns:
        yield eqn
        for sub in _sub_jaxprs(eqn.params):
            yield from iter_eqns(sub)


def _sub_jaxprs(params: dict):
    for value in params.values():
        yield from _jaxprs_in(value)


def _jaxprs_in(value):
    if hasattr(value, "eqns") or hasattr(value, "jaxpr"):
        yield value
    elif isinstance(value, (tuple, list)):
        for v in value:
            yield from _jaxprs_in(v)


def _axis_names_of(eqn) -> list:
    names = []
    for key in _AXIS_PARAM_KEYS:
        v = eqn.params.get(key)
        if v is None:
            continue
        for name in v if isinstance(v, (tuple, list)) else (v,):
            if isinstance(name, str):
                names.append(name)
    return names


def _eqn_snippet(eqn) -> str:
    text = " ".join(str(eqn).split())
    return text if len(text) <= 160 else text[:157] + "..."


# -- per-entry checks -------------------------------------------------------

def check_downcasts(entry: dict, jaxpr) -> list:
    """CA201: f64 -> narrow-float convert_element_type anywhere in the
    traced program."""
    out = []
    for eqn in iter_eqns(jaxpr):
        if eqn.primitive.name != "convert_element_type":
            continue
        src = getattr(eqn.invars[0].aval, "dtype", None)
        dst = eqn.params.get("new_dtype")
        if src is None or dst is None:
            continue
        if str(src) == "float64" and str(dst) in NARROW_FLOATS:
            out.append(Finding(
                rule="CA201", path=entry["path"], line=0,
                context=entry["name"], snippet=_eqn_snippet(eqn),
                message=f"float64 value narrowed to {dst} inside traced "
                        f"entry '{entry['name']}': the f64 contract must "
                        f"not silently downcast (derive the dtype from "
                        f"the operand or name a *_DTYPE policy)"))
    return out


def check_collective_axes(entry: dict, jaxpr) -> list:
    """CA203: collective primitive binds an axis the entry didn't declare."""
    declared = set(entry.get("axis_names") or ())
    out = []
    seen = set()
    for eqn in iter_eqns(jaxpr):
        if eqn.primitive.name not in COLLECTIVE_PRIM_NAMES:
            continue
        for name in _axis_names_of(eqn):
            if name in declared or (eqn.primitive.name, name) in seen:
                continue
            seen.add((eqn.primitive.name, name))
            out.append(Finding(
                rule="CA203", path=entry["path"], line=0,
                context=entry["name"], snippet=_eqn_snippet(eqn),
                message=f"`{eqn.primitive.name}` binds mesh axis "
                        f"{name!r} but entry '{entry['name']}' declares "
                        f"axes {sorted(declared) or '()'} — the axis "
                        f"would be unbound (or bound to the wrong mesh) "
                        f"at run time"))
    return out


def check_reuse(entry: dict) -> list:
    """CA202: repeat invocations at unchanged shapes/statics must not grow
    any watched compiled-program cache after the warmup call."""
    recipe = entry["reuse"]()
    watched, calls = recipe["watched"], recipe["calls"]
    if not calls:
        return []
    calls[0]()                                  # warmup: may compile
    guard = RecompileGuard(watched)
    with guard:
        for call in calls[1:]:
            call()
    out = []
    for label, delta in guard.grew().items():
        out.append(Finding(
            rule="CA202", path=entry["path"], line=0,
            context=entry["name"], snippet=label,
            message=f"'{label}' compiled {delta} new program(s) when "
                    f"'{entry['name']}' was re-invoked with new parameter "
                    f"values at unchanged shapes/statics — a lambda path "
                    f"would recompile per point (keep penalty params and "
                    f"warm starts traced, not static)"))
    return out


# -- driver -----------------------------------------------------------------

def _error_finding(entry: dict, stage: str, exc: BaseException) -> Finding:
    tb = traceback.format_exception_only(type(exc), exc)[-1].strip()
    return Finding(
        rule="CA200", path=entry["path"], line=0, context=entry["name"],
        message=f"manifest entry failed during {stage}: {tb} — a broken "
                f"entry point means the contract checks did not run",
        snippet=stage)


def run_entry(entry: dict, profile: Profile) -> list:
    """Trace + check one manifest entry.  Never raises: failures surface
    as CA200 findings so one broken entry can't mask the rest."""
    import jax
    from jax.experimental import enable_x64

    findings = []
    skip = set(entry.get("skip") or ())
    active = ({"CA201", "CA202", "CA203"} & profile.rules) - skip
    if {"CA201", "CA203"} & active:
        try:
            with enable_x64():
                spec = entry["build"]()
                ctx = spec.get("ctx") or nullcontext
                fn, args = spec["fn"], tuple(spec.get("args", ()))
                kwargs = dict(spec.get("kwargs", {}))
                # ring entries trace their SPMD schedules without devices
                # by binding the mesh axes via make_jaxpr's axis_env
                axis_env = spec.get("axis_env")
                mk = {} if axis_env is None else {"axis_env": list(axis_env)}
                with ctx():
                    jaxpr = jax.make_jaxpr(
                        lambda *a: fn(*a, **kwargs), **mk)(*args)
        except Exception as e:           # noqa: BLE001 - report, don't die
            return [_error_finding(entry, "trace", e)]
        if "CA201" in active:
            findings.extend(check_downcasts(entry, jaxpr))
        if "CA203" in active:
            findings.extend(check_collective_axes(entry, jaxpr))
    if "CA202" in active and entry.get("reuse") is not None:
        try:
            with enable_x64():
                findings.extend(check_reuse(entry))
        except Exception as e:           # noqa: BLE001
            findings.append(_error_finding(entry, "reuse", e))
    return findings


def run_entries(entries, profile: Profile) -> list:
    findings = []
    for entry in entries:
        findings.extend(run_entry(entry, profile))
    return findings
