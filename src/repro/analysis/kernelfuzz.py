"""Differential kernel sanitizer: interpret-mode fuzz vs ref.py oracles.

The static CA4xx pass proves the GEOMETRY of every registered kernel;
this harness proves the ARITHMETIC: each ``KERNEL_ENTRIES`` entry's fuzz
builder runs the kernel in interpret mode (kernel body executed as jax
ops on CPU) against its jitted pure-jnp oracle at every manifest
configuration — edge tiles, the prime-p full-tile fallback, inf-guarded
weight lanes — and the declared tolerance class is ENFORCED:

  * ``bit-exact`` outputs are compared with
    ``np.testing.assert_array_equal`` — one flipped ulp fails;
  * ``fp-tolerant`` outputs use ``np.allclose`` at the entry's
    rtol/atol.

Seeding is deterministic per (seed, entry, config) via
``np.random.SeedSequence`` over stable CRC32 digests (no PYTHONHASHSEED
dependence), so CI failures replay locally with the same arrays.
Exposed as ``repro-analyze --fuzz-kernels`` and as the pytest module
``tests/test_kernel_sanitizer.py``.
"""
from __future__ import annotations

import traceback
import zlib
from dataclasses import asdict, dataclass

import numpy as np


@dataclass(frozen=True)
class FuzzResult:
    """One compared output of one (entry, config) fuzz case."""
    entry: str
    config: str
    output: str
    tolerance: str
    ok: bool
    max_abs_diff: float = 0.0
    detail: str = ""

    def to_json(self) -> dict:
        return asdict(self)

    def render(self) -> str:
        status = "ok" if self.ok else "FAIL"
        out = (f"{status}: {self.entry} [{self.config}] {self.output} "
               f"({self.tolerance}, max|diff|={self.max_abs_diff:.3e})")
        if self.detail:
            out += f" — {self.detail}"
        return out


def case_rng(seed: int, entry_name: str, label: str):
    """Deterministic per-case generator, stable across processes."""
    return np.random.default_rng(np.random.SeedSequence([
        seed, zlib.crc32(entry_name.encode()), zlib.crc32(label.encode())]))


def _compare(entry: dict, label: str, name: str, got, want,
             tol_class: str) -> FuzzResult:
    from ..kernels.manifest import TOLERANCE_CLASSES

    g, w = np.asarray(got), np.asarray(want)
    base = dict(entry=entry["name"], config=label, output=name,
                tolerance=tol_class)
    if tol_class not in TOLERANCE_CLASSES:
        return FuzzResult(ok=False, detail=f"unknown tolerance class "
                          f"{tol_class!r} (CA405 contract)", **base)
    if g.shape != w.shape or g.dtype != w.dtype:
        return FuzzResult(
            ok=False, detail=f"shape/dtype mismatch: kernel "
            f"{g.shape}/{g.dtype} vs oracle {w.shape}/{w.dtype}", **base)
    finite = np.isfinite(g) & np.isfinite(w)
    mad = float(np.max(np.abs(g[finite] - w[finite]))) \
        if finite.any() else 0.0
    if tol_class == "bit-exact":
        try:
            np.testing.assert_array_equal(g, w)
            return FuzzResult(ok=True, max_abs_diff=mad, **base)
        except AssertionError:
            n_bad = int(np.sum(~((g == w) | (np.isnan(g) & np.isnan(w)))))
            return FuzzResult(
                ok=False, max_abs_diff=mad,
                detail=f"{n_bad} element(s) differ from the oracle but "
                       f"the entry declares bit-exact", **base)
    ok = bool(np.allclose(g, w, rtol=entry.get("rtol", 1e-12),
                          atol=entry.get("atol", 1e-12)))
    detail = "" if ok else (
        f"outside rtol={entry.get('rtol')}/atol={entry.get('atol')}")
    return FuzzResult(ok=ok, max_abs_diff=mad, detail=detail, **base)


def run_case(entry: dict, cfg: dict, *, seed: int = 0) -> list:
    """Fuzz one (entry, config) pair under enable_x64.  Returns a list
    of :class:`FuzzResult` (one per compared output).  Never raises: a
    crashed builder surfaces as a single failed result."""
    from jax.experimental import enable_x64

    label = cfg.get("label", "?")
    rng = case_rng(seed, entry["name"], label)
    try:
        with enable_x64():
            cases = entry["fuzz"](cfg, rng)
            results = [_compare(entry, label, name, got, want, tol)
                       for name, got, want, tol in cases]
    except Exception as e:          # noqa: BLE001 - report, don't die
        tb = traceback.format_exception_only(type(e), e)[-1].strip()
        return [FuzzResult(entry=entry["name"], config=label,
                           output="<error>", tolerance="-", ok=False,
                           detail=f"fuzz builder raised: {tb}")]
    if not results:
        return [FuzzResult(entry=entry["name"], config=label,
                           output="<empty>", tolerance="-", ok=False,
                           detail="fuzz builder compared no outputs")]
    return results


def fuzz_entries(entries, *, seed: int = 0) -> list:
    """Run every configuration of every entry.  Returns all results
    (use :func:`failures` to gate)."""
    results = []
    for entry in entries:
        for cfg in entry.get("configs", ()):
            results.extend(run_case(entry, cfg, seed=seed))
    return results


def failures(results) -> list:
    return [r for r in results if not r.ok]


def report(results, *, seed: int) -> dict:
    """The JSON artifact block CI uploads under ``kernel_fuzz``."""
    bad = failures(results)
    return {
        "seed": seed,
        "cases": [r.to_json() for r in results],
        "counts": {"cases": len(results), "failures": len(bad)},
    }
