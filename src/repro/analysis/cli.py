"""``python -m repro.analysis`` (also installed as ``repro-analyze``) —
run all four engines, gate on findings.

Exit status: 0 = clean (after baseline), 1 = unsuppressed findings or
kernel-fuzz failures, 2 = usage / internal error.  ``--format json``
(optionally with ``--output``) emits the machine report CI uploads as an
artifact; it includes the comm engine's extracted collective schedules,
the pallas engine's per-config grid records (``kernel_grids``), and —
with ``--fuzz-kernels`` — the differential sanitizer's case table
(``kernel_fuzz``).

``--changed [BASE]`` restricts the AST engine to files touched since
``BASE`` (``git diff --name-only``, default HEAD) that lie under the
scan targets, for fast pre-commit runs, and subsets the pallas engine's
``KERNEL_ENTRIES`` to changed kernel modules (the whole registry when a
shared kernel file — manifest/ops/ref — changed; the CA405
module-coverage check stays whole-program either way).  The jaxpr and
comm engines ALWAYS run whole-program: they trace entry-point manifests,
and an entry's jaxpr pulls in every layer it calls — there is no
meaningful per-file subset of a traced program.  Stale-baseline gating
is skipped under ``--changed`` (a partial scan cannot tell a fixed
finding from an unscanned one).

``--fuzz-kernels`` additionally runs every registered kernel in
interpret mode against its ``ref.py`` oracle across the manifest's
parameter grid (seeded via ``--fuzz-seed``), enforcing each entry's
declared tolerance class; any failed case fails the gate even with zero
static findings.
"""
from __future__ import annotations

import argparse
import json
import subprocess
import sys
from pathlib import Path

from . import astpass, commpass, jaxprpass, pallaspass
from .baseline import load_baseline, split_by_baseline, write_baseline
from .findings import sort_findings
from .rules import DEFAULT_PROFILE, all_rules, profile_for_path

DEFAULT_TARGETS = ("src", "benchmarks")
DEFAULT_BASELINE = "analysis_baseline.json"

_SKIP_PARTS = {"__pycache__", ".git", ".venv", "build", "dist"}


def iter_python_files(targets, root: Path):
    for target in targets:
        path = (root / target) if not Path(target).is_absolute() \
            else Path(target)
        if path.is_file():
            yield path
            continue
        if not path.is_dir():
            raise FileNotFoundError(f"no such file or directory: {path}")
        for f in sorted(path.rglob("*.py")):
            if not _SKIP_PARTS.intersection(f.parts):
                yield f


def changed_files(root: Path, base: str, targets=DEFAULT_TARGETS) -> list:
    """Python files ``git diff --name-only BASE`` reports under the scan
    targets (files outside them — e.g. tests/ fixture code that trips
    rules on purpose — are excluded, matching the full-scan roots).
    ``targets=None`` skips the target filter and returns every changed
    python file (the kernel-registry subsetting wants repo-wide paths)."""
    out = subprocess.run(
        ["git", "diff", "--name-only", base, "--"],
        cwd=root, capture_output=True, text=True, check=True).stdout
    roots = None if targets is None else [
        ((root / t) if not Path(t).is_absolute() else Path(t)).resolve()
        for t in targets]
    files = []
    for line in out.splitlines():
        f = root / line
        if not (line.endswith(".py") and f.is_file()):
            continue
        rf = f.resolve()
        if roots is None or any(r == rf or r in rf.parents for r in roots):
            files.append(f)
    return files


def subset_kernel_entries(entries, changed_rel: set) -> list:
    """``--changed`` scoping for the pallas engine: keep entries whose
    kernel module changed; a change to any shared kernel file
    (manifest/ops/ref) invalidates the whole registry."""
    from repro.kernels.manifest import SHARED_KERNEL_FILES
    if any(p in changed_rel for p in SHARED_KERNEL_FILES):
        return list(entries)
    return [e for e in entries if e.get("path") in changed_rel]


def run_ast_engine(targets, root: Path, *, files=None) -> list:
    findings = []
    if files is None:
        files = iter_python_files(targets, root)
    for f in files:
        try:
            rel = f.relative_to(root).as_posix()
        except ValueError:
            rel = f.as_posix()
        findings.extend(astpass.scan_file(f, rel, profile_for_path(rel)))
    return findings


def run_jaxpr_engine() -> list:
    from .manifest import load_entries
    return jaxprpass.run_entries(load_entries(), DEFAULT_PROFILE)


def run_comm_engine():
    """Returns (findings, schedule_records)."""
    from .manifest import load_entries
    return commpass.run_entries(load_entries(), DEFAULT_PROFILE)


def run_pallas_engine(changed_rel=None):
    """Returns (findings, grid_records).  ``changed_rel`` (a set of
    repo-relative posix paths) subsets the per-entry checks under
    ``--changed``; the CA405 module-coverage check always sees the full
    registry."""
    from repro.kernels.manifest import KERNEL_ENTRIES
    entries = KERNEL_ENTRIES if changed_rel is None \
        else subset_kernel_entries(KERNEL_ENTRIES, changed_rel)
    return pallaspass.run_entries(entries, DEFAULT_PROFILE,
                                  all_entries=KERNEL_ENTRIES)


def run_kernel_fuzz(seed: int, changed_rel=None):
    """Returns (failed_results, report_dict) from the differential
    sanitizer over the (possibly ``--changed``-subset) registry."""
    from repro.kernels.manifest import KERNEL_ENTRIES

    from . import kernelfuzz
    entries = KERNEL_ENTRIES if changed_rel is None \
        else subset_kernel_entries(KERNEL_ENTRIES, changed_rel)
    results = kernelfuzz.fuzz_entries(entries, seed=seed)
    return kernelfuzz.failures(results), kernelfuzz.report(results,
                                                           seed=seed)


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="JAX-aware static analysis for the repro solver stack "
                    "(AST rules CA1xx, jaxpr rules CA2xx, collective-"
                    "schedule rules CA3xx, Pallas kernel rules CA4xx).")
    ap.add_argument("targets", nargs="*", default=list(DEFAULT_TARGETS),
                    help="files/directories to scan with the AST engine "
                         f"(default: {' '.join(DEFAULT_TARGETS)})")
    ap.add_argument("--root", default=".",
                    help="repo root paths are resolved against (default: .)")
    ap.add_argument("--engine",
                    choices=("ast", "jaxpr", "comm", "pallas", "all"),
                    default="all")
    ap.add_argument("--changed", nargs="?", const="HEAD", default=None,
                    metavar="BASE",
                    help="AST engine: only scan files changed since BASE "
                         "(git diff --name-only; default HEAD); the pallas "
                         "engine subsets KERNEL_ENTRIES to changed kernel "
                         "modules. jaxpr/comm engines still run whole-"
                         "program; stale-baseline gating is skipped")
    ap.add_argument("--fuzz-kernels", action="store_true",
                    help="also run the differential kernel sanitizer: "
                         "every registered kernel in interpret mode vs "
                         "its ref.py oracle across the manifest grid, "
                         "enforcing declared tolerance classes (failures "
                         "fail the gate)")
    ap.add_argument("--fuzz-seed", type=int, default=0, metavar="N",
                    help="base seed of the kernel sanitizer (default: 0; "
                         "per-case seeds derive deterministically from "
                         "it)")
    ap.add_argument("--format", choices=("human", "json"), default="human")
    ap.add_argument("--output", default=None,
                    help="write the report here as well as stdout")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help="suppression baseline JSON, relative to --root "
                         f"(default: {DEFAULT_BASELINE})")
    ap.add_argument("--write-baseline", action="store_true",
                    help="regenerate the baseline from current findings "
                         "and exit 0")
    ap.add_argument("--list-rules", action="store_true")
    return ap


def _render_report(new, suppressed, stale, fmt: str,
                   comm_schedules=None, kernel_grids=None,
                   kernel_fuzz=None) -> str:
    if fmt == "json":
        report = {
            "findings": [f.to_json() for f in new],
            "suppressed": [f.to_json() for f in suppressed],
            "stale_baseline": [list(e) for e in stale],
            "counts": {
                "findings": len(new),
                "suppressed": len(suppressed),
                "stale_baseline": len(stale),
            },
        }
        if comm_schedules is not None:
            report["comm_schedules"] = comm_schedules
        if kernel_grids is not None:
            report["kernel_grids"] = kernel_grids
        if kernel_fuzz is not None:
            report["kernel_fuzz"] = kernel_fuzz
        return json.dumps(report, indent=2)
    lines = [f.render() for f in new]
    if stale:
        lines.append("")
        lines.append(f"{len(stale)} stale baseline entr"
                     f"{'y' if len(stale) == 1 else 'ies'} (no longer "
                     f"match anything — remove them):")
        lines.extend(f"  {e}" for e in stale)
    if kernel_fuzz is not None:
        counts = kernel_fuzz["counts"]
        if counts["failures"]:
            lines.append("")
            lines.extend(c["entry"] and
                         f"  {c['entry']} [{c['config']}] {c['output']} "
                         f"({c['tolerance']}): {c['detail'] or 'failed'}"
                         for c in kernel_fuzz["cases"] if not c["ok"])
        lines.append("")
        lines.append(f"kernel fuzz (seed {kernel_fuzz['seed']}): "
                     f"{counts['cases']} case(s), "
                     f"{counts['failures']} failure(s).")
    lines.append("")
    lines.append(f"{len(new)} finding{'s' if len(new) != 1 else ''}"
                 + (f", {len(suppressed)} baseline-suppressed"
                    if suppressed else "")
                 + ".")
    return "\n".join(lines).lstrip("\n")


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    if args.list_rules:
        for r in all_rules():
            print(f"{r.id}  [{r.engine:5}]  {r.name}\n    {r.description}")
        return 0

    root = Path(args.root).resolve()
    findings = []
    comm_schedules = None
    kernel_grids = None
    changed_rel = None
    try:
        if args.changed is not None:
            # repo-relative paths of ALL changed python files (unfiltered
            # by targets): the kernel registry lives under src/ but its
            # subsetting must not depend on the AST targets argument
            changed_rel = {
                f.resolve().relative_to(root).as_posix()
                for f in changed_files(root, args.changed, None)}
        if args.engine in ("ast", "all"):
            files = None
            if args.changed is not None:
                files = changed_files(root, args.changed, args.targets)
            findings.extend(run_ast_engine(args.targets, root, files=files))
        if args.engine in ("jaxpr", "all"):
            findings.extend(run_jaxpr_engine())
        if args.engine in ("comm", "all"):
            comm_findings, comm_schedules = run_comm_engine()
            findings.extend(comm_findings)
        if args.engine in ("pallas", "all"):
            pallas_findings, kernel_grids = run_pallas_engine(changed_rel)
            findings.extend(pallas_findings)
    except (FileNotFoundError, ImportError, AttributeError, ValueError,
            subprocess.CalledProcessError) as e:
        print(f"repro.analysis: error: {e}", file=sys.stderr)
        return 2
    findings = sort_findings(findings)

    baseline_path = root / args.baseline
    if args.write_baseline:
        write_baseline(findings, baseline_path)
        print(f"wrote {len(findings)} fingerprint"
              f"{'s' if len(findings) != 1 else ''} to {baseline_path}")
        return 0

    fuzz_failed, fuzz_report = [], None
    if args.fuzz_kernels:
        try:
            fuzz_failed, fuzz_report = run_kernel_fuzz(args.fuzz_seed,
                                                       changed_rel)
        except (ImportError, AttributeError, ValueError) as e:
            print(f"repro.analysis: error: {e}", file=sys.stderr)
            return 2

    baseline = load_baseline(baseline_path)
    new, suppressed, stale = split_by_baseline(findings, baseline)
    if args.changed is not None:
        stale = []      # a partial scan cannot adjudicate staleness
    report = _render_report(new, suppressed, stale, args.format,
                            comm_schedules, kernel_grids, fuzz_report)
    print(report)
    if args.output:
        Path(args.output).parent.mkdir(parents=True, exist_ok=True)
        Path(args.output).write_text(report + "\n", encoding="utf-8")
    return 1 if (new or stale or fuzz_failed) else 0
