"""``python -m repro.analysis`` — run both engines, gate on findings.

Exit status: 0 = clean (after baseline), 1 = unsuppressed findings,
2 = usage / internal error.  ``--format json`` (optionally with
``--output``) emits the machine report CI uploads as an artifact.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from . import astpass, jaxprpass
from .baseline import load_baseline, split_by_baseline, write_baseline
from .findings import sort_findings
from .rules import DEFAULT_PROFILE, all_rules, profile_for_path

DEFAULT_TARGETS = ("src", "benchmarks")
DEFAULT_BASELINE = "analysis_baseline.json"

_SKIP_PARTS = {"__pycache__", ".git", ".venv", "build", "dist"}


def iter_python_files(targets, root: Path):
    for target in targets:
        path = (root / target) if not Path(target).is_absolute() \
            else Path(target)
        if path.is_file():
            yield path
            continue
        if not path.is_dir():
            raise FileNotFoundError(f"no such file or directory: {path}")
        for f in sorted(path.rglob("*.py")):
            if not _SKIP_PARTS.intersection(f.parts):
                yield f


def run_ast_engine(targets, root: Path) -> list:
    findings = []
    for f in iter_python_files(targets, root):
        try:
            rel = f.relative_to(root).as_posix()
        except ValueError:
            rel = f.as_posix()
        findings.extend(astpass.scan_file(f, rel, profile_for_path(rel)))
    return findings


def run_jaxpr_engine() -> list:
    from .manifest import load_entries
    return jaxprpass.run_entries(load_entries(), DEFAULT_PROFILE)


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="JAX-aware static analysis for the repro solver stack "
                    "(AST rules CA1xx, jaxpr rules CA2xx).")
    ap.add_argument("targets", nargs="*", default=list(DEFAULT_TARGETS),
                    help="files/directories to scan with the AST engine "
                         f"(default: {' '.join(DEFAULT_TARGETS)})")
    ap.add_argument("--root", default=".",
                    help="repo root paths are resolved against (default: .)")
    ap.add_argument("--engine", choices=("ast", "jaxpr", "all"),
                    default="all")
    ap.add_argument("--format", choices=("human", "json"), default="human")
    ap.add_argument("--output", default=None,
                    help="write the report here as well as stdout")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help="suppression baseline JSON, relative to --root "
                         f"(default: {DEFAULT_BASELINE})")
    ap.add_argument("--write-baseline", action="store_true",
                    help="regenerate the baseline from current findings "
                         "and exit 0")
    ap.add_argument("--list-rules", action="store_true")
    return ap


def _render_report(new, suppressed, stale, fmt: str) -> str:
    if fmt == "json":
        return json.dumps({
            "findings": [f.to_json() for f in new],
            "suppressed": [f.to_json() for f in suppressed],
            "stale_baseline": [list(e) for e in stale],
            "counts": {
                "findings": len(new),
                "suppressed": len(suppressed),
                "stale_baseline": len(stale),
            },
        }, indent=2)
    lines = [f.render() for f in new]
    if stale:
        lines.append("")
        lines.append(f"{len(stale)} stale baseline entr"
                     f"{'y' if len(stale) == 1 else 'ies'} (no longer "
                     f"match anything — remove them):")
        lines.extend(f"  {e}" for e in stale)
    lines.append("")
    lines.append(f"{len(new)} finding{'s' if len(new) != 1 else ''}"
                 + (f", {len(suppressed)} baseline-suppressed"
                    if suppressed else "")
                 + ".")
    return "\n".join(lines).lstrip("\n")


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    if args.list_rules:
        for r in all_rules():
            print(f"{r.id}  [{r.engine:5}]  {r.name}\n    {r.description}")
        return 0

    root = Path(args.root).resolve()
    findings = []
    try:
        if args.engine in ("ast", "all"):
            findings.extend(run_ast_engine(args.targets, root))
        if args.engine in ("jaxpr", "all"):
            findings.extend(run_jaxpr_engine())
    except (FileNotFoundError, ImportError, AttributeError, ValueError) as e:
        print(f"repro.analysis: error: {e}", file=sys.stderr)
        return 2
    findings = sort_findings(findings)

    baseline_path = root / args.baseline
    if args.write_baseline:
        write_baseline(findings, baseline_path)
        print(f"wrote {len(findings)} fingerprint"
              f"{'s' if len(findings) != 1 else ''} to {baseline_path}")
        return 0

    baseline = load_baseline(baseline_path)
    new, suppressed, stale = split_by_baseline(findings, baseline)
    report = _render_report(new, suppressed, stale, args.format)
    print(report)
    if args.output:
        Path(args.output).parent.mkdir(parents=True, exist_ok=True)
        Path(args.output).write_text(report + "\n", encoding="utf-8")
    return 1 if (new or stale) else 0
