"""Backend registry for the ``repro.estimator`` facade.

A *backend* is a callable

    backend(problem, penalty, config, omega0=None) -> FitReport

registered under a name, where ``penalty`` is a
:class:`repro.core.penalty.PenaltySpec` (a bare float is also accepted
and treated as the lam1 of an l1 penalty).  Three ship by default:

  ``reference``    single-device jitted solve (``core.prox``); warm starts
                   and lam1/lam2 are traced so a regularization path reuses
                   one compiled program.
  ``distributed``  the 1.5D shard_map drivers (``core.distributed``);
                   replication factors come from the config or the tuner.
  ``auto``         consults ``core.costmodel.tune`` (paper Lemmas 3.1-3.5)
                   for variant + replication, then dispatches to
                   ``reference`` on one device or ``distributed`` otherwise.

``register_backend`` lets downstream code plug in new engines (e.g. a GPU
Pallas solver) without touching the estimator.
"""
from __future__ import annotations

import time
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from ..comm.grid import Grid1p5D
from ..core import distributed as dist
from ..core import matops, prox
from ..core.penalty import PenaltySpec, as_penalty, penalty_value_np
from ..core.costmodel import (
    Machine,
    ProblemShape,
    crossover_density,
    enumerate_configs,
    tune,
)
from .config import SolverConfig
from .report import FitReport

#: |entry| below this counts as a structural zero when observing iterate
#: density (matches the soft-threshold exact zeros; guards fp noise).
NNZ_TOL = 1e-8

#: default block-density threshold for sparse_matmul="on"
DEFAULT_SPARSE_THRESHOLD = 0.25


#: relative asymmetry above this rejects an input "covariance" — genuine
#: sample covariances are symmetric to machine precision; anything worse
#: is a transposed/buggy input, not rounding.
SYMMETRY_RTOL = 1e-6


def _require_finite(name: str, arr) -> None:
    if not bool(np.all(np.isfinite(np.asarray(arr)))):
        raise ValueError(
            f"{name} contains NaN/Inf; refusing to fit (a non-finite input "
            f"silently produces a garbage estimate — clean or impute the "
            f"data first)")


def _require_symmetric(s) -> None:
    sh = np.asarray(s)
    scale = float(np.max(np.abs(sh))) if sh.size else 0.0
    asym = float(np.max(np.abs(sh - sh.T))) if sh.size else 0.0
    if asym > SYMMETRY_RTOL * max(scale, 1.0):
        raise ValueError(
            f"s must be symmetric: max |s - s^T| = {asym:.3e} at scale "
            f"{scale:.3e} — pass a genuine Gram/covariance (see "
            f"data.compute_gram for streamed construction)")


class Problem(NamedTuple):
    """Input data for one estimation problem (either x or s, maybe both)."""
    x: jax.Array | None         # (n, p) observations
    s: jax.Array | None         # (p, p) sample covariance
    n: int                      # sample count (for s-only problems: given)
    p: int

    @staticmethod
    def from_data(x=None, s=None, n_samples: int | None = None) -> "Problem":
        if x is None and s is None:
            raise ValueError("pass x (n, p) or s (p, p)")
        if n_samples is not None and (not isinstance(n_samples, (int,
                np.integer)) or n_samples < 1):
            raise ValueError(f"n_samples must be a positive int, got "
                             f"{n_samples!r}")
        if x is not None:
            x = jnp.asarray(x)
            if x.ndim != 2:
                raise ValueError(f"x must be 2-D (n, p), got shape {x.shape}")
            _require_finite("x", x)
        if s is not None:
            s = jnp.asarray(s)
            if s.ndim != 2 or s.shape[0] != s.shape[1]:
                raise ValueError(f"s must be square (p, p), got {s.shape}")
            _require_finite("s", s)
            _require_symmetric(s)
        if x is not None and s is not None and x.shape[1] != s.shape[0]:
            raise ValueError(
                f"x has p={x.shape[1]} columns but s is {s.shape}")
        p = (x if x is not None else s).shape[-1]
        n = x.shape[0] if x is not None else (n_samples or p)
        return Problem(x=x, s=s, n=int(n), p=int(p))

    def cov(self) -> jax.Array:
        """The (p, p) sample covariance, formed on demand."""
        if self.s is not None:
            return self.s
        return (self.x.T @ self.x) / self.n


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

BackendFn = Callable[..., FitReport]

_REGISTRY: dict[str, BackendFn] = {}


def register_backend(name: str, fn: BackendFn, *,
                     overwrite: bool = False) -> None:
    if not overwrite and name in _REGISTRY:
        raise ValueError(f"backend {name!r} already registered")
    _REGISTRY[name] = fn


def get_backend(name: str) -> BackendFn:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown backend {name!r}; available: {available_backends()}"
        ) from None


def available_backends() -> list[str]:
    return sorted(_REGISTRY)


# ---------------------------------------------------------------------------
# shared helpers
# ---------------------------------------------------------------------------

def _cast(arr, config: SolverConfig):
    if config.dtype is None:
        return arr
    return jnp.asarray(arr, jnp.dtype(config.dtype))


def _variant_candidates(problem: Problem, config: SolverConfig) -> tuple:
    variants = ("cov", "obs") if problem.x is not None else ("cov",)
    if config.variant != "auto":
        variants = (config.variant,)
    return variants


def observed_nnz_per_row(omega) -> float:
    """Average nonzeros per row of an iterate (the cost model's ``d``)."""
    om = np.asarray(omega)
    return max(1.0, float(np.count_nonzero(np.abs(om) > NNZ_TOL))
               / om.shape[0])


def _problem_shape(problem: Problem, lam1: float,
                   omega0=None) -> ProblemShape:
    """Cost-model shape for the solve.  With a warm start available (e.g.
    the previous lambda step on a path), its OBSERVED density replaces the
    static ``estimate_density`` prior — the tuner then sees the sparsity
    the iterates actually have."""
    if omega0 is not None:
        d = observed_nnz_per_row(omega0)
    else:
        d = dist.estimate_density(problem.p, problem.n, lam1)
    return ProblemShape(p=problem.p, n=problem.n, d=d)


def _matmul_policy(config: SolverConfig, p: int,
                   m: int) -> matops.MatmulPolicy | None:
    """Resolve the config's sparse_matmul knobs into a static routing
    policy for an Ω-side product with ``m`` output columns.  ``"auto"``
    takes its threshold from the cost model's dense↔block-sparse crossover
    (never routing sparse above the modeled break-even density)."""
    mode = config.sparse_matmul
    if mode == "off":
        return None
    if mode == "on":
        thr = (config.sparse_threshold if config.sparse_threshold is not None
               else DEFAULT_SPARSE_THRESHOLD)
    else:  # auto
        thr = crossover_density(p, m, config.sparse_block)
        if config.sparse_threshold is not None:
            thr = min(thr, config.sparse_threshold)
    if thr <= 0.0:
        return None
    return matops.MatmulPolicy(mode, config.sparse_block, float(thr))


def _check_grid(variant: str, c_x: int, c_omega: int,
                n_devices: int) -> tuple[str, int, int]:
    if variant == "cov" and c_x != c_omega:
        raise ValueError(
            f"Cov keeps Omega in the X-like layout, so c_x must equal "
            f"c_omega (got c_x={c_x}, c_omega={c_omega})")
    if c_x * c_omega > n_devices or n_devices % (c_x * c_omega):
        raise ValueError(
            f"replication c_x*c_omega={c_x * c_omega} must divide "
            f"n_devices={n_devices} (got c_x={c_x}, c_omega={c_omega})")
    return variant, c_x, c_omega


def _resolve_variant_only(problem: Problem, lam1: float,
                          config: SolverConfig, omega0=None) -> str:
    """Variant for the single-device reference engine (replication moot)."""
    if config.variant != "auto":
        return config.variant
    best = tune(_problem_shape(problem, lam1, omega0), 1, Machine(),
                _variant_candidates(problem, config))
    return best.variant


def _resolve_variant(problem: Problem, lam1: float, config: SolverConfig,
                     n_devices: int, omega0=None) -> tuple[str, int, int]:
    """Pin down (variant, c_x, c_omega) for a distributed solve.

    User-pinned values are validated (raising on an infeasible grid, never
    silently overridden); anything left open is chosen by the cost model,
    enumerating only combinations consistent with the pins and with the
    layout constraints (Cov needs c_x == c_omega; the product must divide
    the device count)."""
    if config.variant != "auto" and config.c_x and config.c_omega:
        return _check_grid(config.variant, config.c_x, config.c_omega,
                           n_devices)
    variants = _variant_candidates(problem, config)
    if n_devices == 1:
        if config.variant != "auto":
            return _check_grid(config.variant, config.c_x or 1,
                               config.c_omega or 1, n_devices)
        best = tune(_problem_shape(problem, lam1, omega0), 1, Machine(),
                    variants)
        return _check_grid(best.variant, config.c_x or 1,
                           config.c_omega or 1, n_devices)
    cands = [
        cb for cb in enumerate_configs(_problem_shape(problem, lam1, omega0),
                                       n_devices, Machine(), variants)
        if (config.c_x is None or cb.c_x == config.c_x)
        and (config.c_omega is None or cb.c_omega == config.c_omega)
        and n_devices % (cb.c_x * cb.c_omega) == 0
        and (cb.variant != "cov" or cb.c_x == cb.c_omega)
    ]
    if not cands:
        raise ValueError(
            f"no feasible (variant, c_x, c_omega) for n_devices={n_devices} "
            f"with variant={config.variant!r} c_x={config.c_x} "
            f"c_omega={config.c_omega}")
    best = min(cands, key=lambda cb: cb.total)
    return _check_grid(best.variant, best.c_x, best.c_omega, n_devices)


def _offdiag_l1(omega) -> float:
    om = np.asarray(omega)
    return float(np.sum(np.abs(om)) - np.sum(np.abs(np.diag(om))))


def _solve_with_obs(config: SolverConfig, backend: str, variant: str,
                    solve, *, p: int, n: int, n_devices: int = 1,
                    c_x: int = 1, c_omega: int = 1):
    """Run ``solve()`` (returning a result with ``.omega``) under the
    configured observability level.

    ``obs="off"`` is the exact pre-obs code path — ``repro.obs`` is never
    imported, no tracer state exists.  Otherwise the solve runs inside a
    span (at ``"trace"`` additionally split into the dispatch fence —
    trace + compile + enqueue — and the ``block_until_ready`` execution
    drain), the solve metrics feed the process registry, and the
    host-boundary telemetry dict lands on the report.  Nothing here is
    visible to jax tracing, so compiled programs and numerics are
    identical at every level."""
    if config.obs == "off":
        t0 = time.perf_counter()
        res = solve()
        jax.block_until_ready(res.omega)
        return res, time.perf_counter() - t0, None
    from ..obs.trace import get_tracer
    tracer = get_tracer()
    with tracer.scoped(config.obs):
        t0 = time.perf_counter()
        with tracer.span(f"fit.{backend}", variant=variant, p=p, n=n,
                         n_devices=n_devices) as span:
            with tracer.span("dispatch", level="trace", variant=variant):
                res = solve()
            t1 = time.perf_counter()
            with tracer.span("execute", level="trace", variant=variant):
                jax.block_until_ready(res.omega)
        wall = time.perf_counter() - t0
        iters, ls_total = int(res.iters), int(res.ls_total)
        span.note(iters=iters, ls_total=ls_total,
                  converged=bool(res.converged))
        telemetry = {
            "obs": config.obs,
            "dispatch_s": t1 - t0,
            "execute_s": wall - (t1 - t0),
            "ls_per_iter": ls_total / max(iters, 1),
            # the registry feed needs the OBSERVED density, and _report
            # already scans the estimate for its nnz/occupancy columns —
            # defer record_solve_cost to there so the p^2 host scan runs
            # once, not twice (at p=512 the duplicate scan alone was the
            # bulk of the obs="summary" overhead)
            "_pending_cost": dict(
                variant=variant, p=p, n=n, iters=iters, ls_total=ls_total,
                n_devices=n_devices, c_x=c_x, c_omega=c_omega,
                wall_s=wall),
        }
    return res, wall, telemetry


def _as_spec(penalty) -> PenaltySpec:
    """Backend-entry normalization: spec passes through, a bare number is
    the lam1 of an l1 penalty (plugin-backend ergonomics)."""
    return as_penalty(penalty)


def _report(res, *, lam1, lam2, wall, backend, variant, config=None,
            c_x=1, c_omega=1, n_devices=1, penalty=None,
            telemetry=None) -> FitReport:
    g = float(res.g_final)
    config = config or SolverConfig()
    if penalty is None:
        penalty = PenaltySpec("l1", lam1, lam2)
    # Always compute the final estimate's occupancy post hoc: the solver's
    # in-loop telemetry (res.block_density) reads 1.0 both for genuinely
    # dense iterates AND whenever the policy was dropped downstream (e.g.
    # a block size that does not tile the distributed shard), so it cannot
    # back the report's density column on its own.  One nonzero scan feeds
    # both the nnz/row and the block-occupancy columns.
    om = np.asarray(res.omega)
    nz = np.abs(om) > NNZ_TOL
    nnz_per_row = max(1.0, float(nz.sum()) / om.shape[0])
    if telemetry is not None and "_pending_cost" in telemetry:
        # deferred obs registry feed (see _solve_with_obs): the density
        # the cost model wants is exactly this scan's nnz/row
        from ..obs.metrics import get_registry, record_solve_cost
        pc = telemetry.pop("_pending_cost")
        cost = record_solve_cost(get_registry(),
                                 density=nnz_per_row / om.shape[0], **pc)
        telemetry["flops"] = cost["flops"]
        telemetry["words"] = cost["words"]
    bs = config.sparse_block
    edges = np.arange(0, om.shape[0], bs)
    occ = np.add.reduceat(np.add.reduceat(nz, edges, axis=0),
                          edges, axis=1) > 0
    block_density = float(occ.mean())
    return FitReport(
        omega=res.omega,
        lam1=float(lam1), lam2=float(lam2),
        iters=int(res.iters), ls_total=int(res.ls_total),
        converged=bool(res.converged),
        stalled=bool(res.stalled),
        objective=g + penalty_value_np(penalty, res.omega),
        objective_smooth=g,
        penalty=penalty.label(),
        wall_time_s=float(wall),
        backend=backend, variant=variant,
        c_x=int(c_x), c_omega=int(c_omega), n_devices=int(n_devices),
        nnz_per_row=nnz_per_row,
        block_density=block_density,
        sparse_matmul=config.sparse_matmul,
        telemetry=telemetry,
    )


# ---------------------------------------------------------------------------
# built-in backends
# ---------------------------------------------------------------------------

def reference_backend(problem: Problem, penalty, config: SolverConfig,
                      omega0=None) -> FitReport:
    """Single-device jitted solve; the workhorse of warm-started paths."""
    spec = _as_spec(penalty)
    lam1 = float(np.asarray(spec.lam1))
    variant = _resolve_variant_only(problem, lam1, config, omega0)
    if variant == "cov":
        data = _cast(problem.cov(), config)
    else:
        if problem.x is None:
            raise ValueError("Obs variant requires the data matrix x")
        data = _cast(problem.x, config)
    if omega0 is not None:
        omega0 = jnp.asarray(omega0, data.dtype)
    policy = _matmul_policy(
        config, problem.p, problem.p if variant == "cov" else problem.n)

    def solve():
        return prox.solve_reference(
            data, penalty=spec, omega0=omega0, variant=variant,
            tol=config.tol, max_iters=config.max_iters,
            max_ls=config.max_ls, warm_start_tau=config.warm_start_tau,
            sparse_matmul=policy, use_pallas=config.use_pallas)

    res, wall, telemetry = _solve_with_obs(
        config, "reference", variant, solve, p=problem.p, n=problem.n)
    return _report(res, lam1=lam1, lam2=float(np.asarray(spec.lam2)),
                   wall=wall, backend="reference", variant=variant,
                   config=config, penalty=spec, telemetry=telemetry)


def distributed_backend(problem: Problem, penalty, config: SolverConfig,
                        omega0=None) -> FitReport:
    """1.5D shard_map solve over all (or ``config.n_devices``) devices."""
    spec = _as_spec(penalty)
    lam1 = float(np.asarray(spec.lam1))
    n_dev = config.n_devices or len(jax.devices())
    variant, c_x, c_omega = _resolve_variant(problem, lam1, config, n_dev,
                                             omega0)
    grid = Grid1p5D(n_dev, c_x, c_omega)
    policy = _matmul_policy(
        config, problem.p, problem.p if variant == "cov" else problem.n)
    if variant != "cov" and problem.x is None:
        raise ValueError("Obs variant requires the data matrix x")

    def solve():
        if variant == "cov":
            return dist.fit_cov(
                _cast(problem.cov(), config), penalty=spec, grid=grid,
                tol=config.tol, max_iters=config.max_iters,
                max_ls=config.max_ls, warm_start_tau=config.warm_start_tau,
                use_pallas=config.use_pallas, omega0=omega0,
                sparse_matmul=policy)
        return dist.fit_obs(
            _cast(problem.x, config), penalty=spec, grid=grid,
            tol=config.tol, max_iters=config.max_iters,
            max_ls=config.max_ls, warm_start_tau=config.warm_start_tau,
            use_pallas=config.use_pallas, omega0=omega0,
            sparse_matmul=policy)

    # obs="trace" arms the comm reconciliation watcher around the dense
    # dispatch (the sparse policy's mask traffic has no analytic twin yet)
    watch = None
    if config.obs == "trace" and policy is None:
        from ..obs.commwatch import CommWatch
        watch = CommWatch().install()
    try:
        res, wall, telemetry = _solve_with_obs(
            config, "distributed", variant, solve, p=problem.p,
            n=problem.n, n_devices=n_dev, c_x=grid.c_x,
            c_omega=grid.c_omega)
    finally:
        if watch is not None:
            watch.uninstall()
    if watch is not None and telemetry is not None:
        recon = watch.reconcile()
        telemetry["comm_reconcile"] = [r.to_json() for r in recon]
        telemetry["comm_reconcile_ok"] = all(r.ok for r in recon)
    return _report(res, lam1=lam1, lam2=float(np.asarray(spec.lam2)),
                   wall=wall, backend="distributed", variant=res.variant,
                   config=config, c_x=grid.c_x, c_omega=grid.c_omega,
                   n_devices=n_dev, penalty=spec, telemetry=telemetry)


def auto_backend(problem: Problem, penalty, config: SolverConfig,
                 omega0=None) -> FitReport:
    """Cost-model-driven dispatch (the paper's decision procedure): resolve
    variant + replication via ``costmodel.tune``, then run on the reference
    engine (one device) or the distributed engine (several)."""
    spec = _as_spec(penalty)
    n_dev = config.n_devices or len(jax.devices())
    variant, c_x, c_omega = _resolve_variant(
        problem, float(np.asarray(spec.lam1)), config, n_dev, omega0)
    pinned = config.replace(variant=variant, c_x=c_x, c_omega=c_omega)
    if n_dev == 1:
        return reference_backend(problem, spec, pinned, omega0)
    return distributed_backend(problem, spec, pinned, omega0)


register_backend("reference", reference_backend)
register_backend("distributed", distributed_backend)
register_backend("auto", auto_backend)
