"""Rich fit results for the ``repro.estimator`` facade.

``FitReport`` is the per-solve record (estimate + solver telemetry + the
backend/grid the dispatcher actually chose); ``PathResult`` aggregates the
reports of a regularization path (warm-started sequential or batched) and
adds model selection; ``BatchReport`` aggregates the per-problem reports
of one batched multi-problem solve (``fit_batch``).

Convergence semantics: ``converged`` is True only on a genuine
``delta < tol`` exit.  ``stalled`` is True when the line search exhausted
``max_ls`` trials without accepting a step (the iterate stopped moving at
machine precision — the solver used to misreport this as convergence).
The two flags are mutually exclusive; both False means the iteration cap
hit first.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

import numpy as np


@dataclass(frozen=True)
class FitReport:
    """Everything a caller may want to know about one solve."""
    omega: object               # (p, p) estimate (jax or numpy array)
    lam1: float
    lam2: float
    iters: int                  # outer proximal-gradient iterations
    ls_total: int               # total line-search trials
    converged: bool
    objective: float            # full objective g + lam1*||offdiag||_1
    objective_smooth: float     # smooth part g (logdet + quad + ridge)
    wall_time_s: float
    backend: str                # backend that actually ran ("reference"/...)
    variant: str                # "cov" or "obs" as resolved
    c_x: int = 1
    c_omega: int = 1
    n_devices: int = 1
    bic: float | None = None    # filled in by fit_path for model selection
    nnz_per_row: float | None = None    # observed nnz/row of the estimate
    block_density: float | None = None  # occupied-block fraction at
                                        # sparse_block granularity
    sparse_matmul: str = "off"          # Ω-product routing mode that ran
    stalled: bool = False       # line search exhausted max_ls with no accept
                                # (mutually exclusive with converged)
    penalty: str = "l1"         # penalty label ("l1", "scad:3.7",
                                # "weighted_l1", ...); objective includes
                                # this penalty's nonsmooth value
    telemetry: dict | None = None   # obs!="off" only: host-boundary solve
                                    # telemetry (dispatch vs execute wall
                                    # split, analytic flop/word totals at
                                    # the observed shape, mean ls trials
                                    # per iteration); None when obs="off"

    def summary(self) -> str:
        dens = ""
        if self.block_density is not None:
            dens = (f" density={self.block_density:.3f}"
                    f"[{self.sparse_matmul}]")
        if self.nnz_per_row is not None:
            dens += f" nnz/row={self.nnz_per_row:.1f}"
        stall = " STALLED" if self.stalled else ""
        pen = f" pen={self.penalty}" if self.penalty != "l1" else ""
        return (f"[{self.backend}/{self.variant} c_x={self.c_x} "
                f"c_omega={self.c_omega}] lam1={self.lam1:g}{pen} "
                f"iters={self.iters} ls={self.ls_total} "
                f"converged={self.converged}{stall} obj={self.objective:.4f}"
                f"{dens} t={self.wall_time_s:.3f}s")


def pseudo_bic(omega, s, n: int, *, tol: float = 1e-8) -> float:
    """BIC under the CONCORD pseudo-likelihood: ``2n * g0 + log(n) * |E|``
    with g0 the unpenalized smooth objective and |E| the edge count.  Used
    by ``fit_path`` for one-call model selection (lam1 sweep -> best BIC)."""
    om = np.asarray(omega, dtype=np.float64)
    sm = np.asarray(s, dtype=np.float64)
    diag = np.diag(om)
    if np.any(diag <= 0):
        return float("inf")
    g0 = -np.sum(np.log(diag)) + 0.5 * np.sum((om @ sm) * om)
    p = om.shape[0]
    edges = (np.count_nonzero(np.abs(om) > tol) - p) / 2.0
    return float(2.0 * n * g0 + math.log(max(n, 2)) * edges)


@dataclass(frozen=True)
class PathResult:
    """Result of a regularization path (descending lam1).

    ``mode`` records how the grid ran: ``"sequential"`` (one solve per
    point, optionally warm-started) or ``"batched"`` (the whole grid as
    one compiled multi-problem program, ``core.batch``).

    ``fit_path(adaptive=True)`` returns the STAGE-2 weighted path with
    ``adaptive=True`` and the stage-1 l1 path attached as ``stage1``.

    ``batch_stats`` (batched mode only) is the engine's
    :class:`~repro.core.batch.BatchRunStats` — segment count, wave sizes
    and the active-lane occupancy timeline of the compact schedule."""
    reports: tuple[FitReport, ...] = field(default_factory=tuple)
    warm_start: bool = True
    mode: str = "sequential"
    adaptive: bool = False
    stage1: "PathResult | None" = None
    batch_stats: object | None = None

    def __post_init__(self):
        object.__setattr__(self, "reports", tuple(self.reports))

    @property
    def lam1_grid(self) -> tuple[float, ...]:
        return tuple(r.lam1 for r in self.reports)

    @property
    def omegas(self) -> list:
        return [r.omega for r in self.reports]

    @property
    def total_iters(self) -> int:
        return int(sum(r.iters for r in self.reports))

    @property
    def total_ls(self) -> int:
        return int(sum(r.ls_total for r in self.reports))

    @property
    def wall_time_s(self) -> float:
        return float(sum(r.wall_time_s for r in self.reports))

    @property
    def telemetry(self) -> dict:
        """Convergence telemetry as a structured time series along the
        path: one numpy array per field, indexed by grid point (the
        host-boundary view — per-iteration state never leaves the
        compiled solver loop)."""
        reps = self.reports
        return {
            "lam1": np.array([r.lam1 for r in reps]),
            "objective": np.array([r.objective for r in reps]),
            "objective_smooth": np.array([r.objective_smooth for r in reps]),
            "iters": np.array([r.iters for r in reps]),
            "ls_total": np.array([r.ls_total for r in reps]),
            "converged": np.array([r.converged for r in reps]),
            "nnz_per_row": np.array([
                np.nan if r.nnz_per_row is None else r.nnz_per_row
                for r in reps]),
            "block_density": np.array([
                np.nan if r.block_density is None else r.block_density
                for r in reps]),
            "wall_time_s": np.array([r.wall_time_s for r in reps]),
        }

    def best_bic(self) -> FitReport:
        """Report with the lowest pseudo-likelihood BIC along the path."""
        scored = [r for r in self.reports if r.bic is not None]
        if not scored:
            raise ValueError("no BIC scores on this path (fit without data?)")
        return min(scored, key=lambda r: r.bic)

    def __len__(self) -> int:
        return len(self.reports)

    def __iter__(self):
        return iter(self.reports)

    def __getitem__(self, i):
        return self.reports[i]

    def summary(self) -> str:
        lines = [r.summary() for r in self.reports]
        how = ("batched" if self.mode == "batched"
               else ("warm" if self.warm_start else "cold") + " starts")
        if self.adaptive:
            how += ", adaptive stage 2"
        lines.append(f"path total: {self.total_iters} outer iters, "
                     f"{self.total_ls} ls trials, {self.wall_time_s:.3f}s "
                     f"({how})")
        if self.batch_stats is not None:
            lines.append(self.batch_stats.summary())
        return "\n".join(lines)


@dataclass(frozen=True)
class BatchReport:
    """Result of one batched multi-problem solve (``fit_batch``).

    ``reports`` holds one :class:`FitReport` per stacked problem, in input
    order.  The whole batch ran as ONE compiled program, so only the
    aggregate wall time is physical; each report carries its 1/B share.
    ``stats`` is the engine's :class:`~repro.core.batch.BatchRunStats`
    (schedule, segments, occupancy timeline).
    """
    reports: tuple[FitReport, ...] = field(default_factory=tuple)
    wall_time_s: float = 0.0    # end-to-end time of the one batched solve
    stats: object | None = None

    def __post_init__(self):
        object.__setattr__(self, "reports", tuple(self.reports))

    @property
    def n_problems(self) -> int:
        return len(self.reports)

    @property
    def omegas(self) -> list:
        return [r.omega for r in self.reports]

    @property
    def total_iters(self) -> int:
        return int(sum(r.iters for r in self.reports))

    @property
    def all_converged(self) -> bool:
        return all(r.converged for r in self.reports)

    @property
    def any_stalled(self) -> bool:
        return any(r.stalled for r in self.reports)

    def __len__(self) -> int:
        return len(self.reports)

    def __iter__(self):
        return iter(self.reports)

    def __getitem__(self, i):
        return self.reports[i]

    def summary(self) -> str:
        lines = [r.summary() for r in self.reports]
        lines.append(
            f"batch total: {self.n_problems} problems, {self.total_iters} "
            f"outer iters, {self.wall_time_s:.3f}s as one compiled solve "
            f"(converged {sum(r.converged for r in self.reports)}"
            f"/{self.n_problems}"
            + (f", stalled {sum(r.stalled for r in self.reports)}"
               if self.any_stalled else "") + ")")
        if self.stats is not None:
            lines.append(self.stats.summary())
        return "\n".join(lines)
