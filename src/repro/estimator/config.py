"""Unified solver configuration for the ``repro.estimator`` facade.

``SolverConfig`` collects every solver knob that used to be scattered
across ``fit_reference`` keyword args, ``distributed.fit`` keyword args and
``launch/solve.py`` argparse flags into one frozen, validated dataclass.
It is hashable, so backends can use it (or fields of it) as part of a jit
static key, and ``dataclasses.replace`` gives cheap derived configs.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

from ..core.penalty import parse_penalty
from ..core.prox import TAU_SCHEDULES

VARIANTS = ("auto", "cov", "obs")

SPARSE_MATMUL_MODES = ("off", "on", "auto")

_DTYPES = ("float32", "float64", "bfloat16")

BATCH_SCHEDULES = ("compact", "monolithic")

#: "auto" resolves per fit: the host BLAS stepper on CPU Cov compact
#: batches (where it beats one-core XLA), plain XLA everywhere else
BATCH_GEMMS = ("auto", "xla", "host")

BATCH_WARM_STARTS = (None, "pilot")

#: runtime observability levels (``repro.obs``): "off" never imports the
#: obs package; "summary" records coarse per-solve spans + metrics;
#: "trace" adds fine spans (compile/execute split, segments, chunks) and
#: arms the comm reconciliation watcher on distributed solves
OBS_MODES = ("off", "summary", "trace")


@dataclass(frozen=True)
class SolverConfig:
    """Every knob of a CONCORD/HP-CONCORD solve, in one place.

    backend        which engine runs the solve: ``"reference"`` (single
                   device), ``"distributed"`` (1.5D shard_map drivers) or
                   ``"auto"`` (consults the paper's cost model, picks the
                   engine, variant and replication factors).  Backends are
                   looked up in the registry (``repro.estimator.backends``)
                   at fit time, so plugins may register new names.
    variant        ``"cov"`` (Algorithm 2, forms S), ``"obs"`` (Algorithm 3,
                   S never formed) or ``"auto"`` (cost-model crossover).
    c_x/c_omega    1.5D replication factors; ``None`` lets the tuner pick.
    n_devices      device count for the distributed grid; ``None`` = all.
    tol            relative-change convergence tolerance.
    max_iters      outer proximal-gradient iteration cap.
    max_ls         per-iteration line-search trial cap.
    warm_start_tau warm-start the line-search step size between outer
                   iterations (beyond-paper knob; saves 20-40% trials).
    dtype          compute dtype name (``None`` keeps the input dtype).
    use_pallas     use the fused Pallas prox kernel in solves (also makes
                   the block-occupancy harvest free, see sparse_matmul).
    sparse_matmul  Ω-side product routing (the matops layer):
                   ``"off"`` — always dense; ``"on"`` — block-sparse
                   below ``sparse_threshold``; ``"auto"`` — threshold from
                   the cost model's dense↔block-sparse crossover
                   (``core.costmodel.crossover_density``).
    sparse_block   occupancy-mask tile edge (128 = MXU-aligned on TPU; on
                   small/distributed problems it must divide the per-shard
                   Omega block or the solve falls back to dense).
    sparse_threshold
                   block-density crossover for ``"on"`` (default 0.25 when
                   None); for ``"auto"`` it caps the model's threshold.
    tau_schedule   per-iteration line-search start rule
                   (``core.prox.TAU_SCHEDULES``): ``None`` defers to
                   ``warm_start_tau`` (its legacy boolean form),
                   ``"restart"``/``"warm"``/``"greedy"`` force one.
    batch_schedule compact (segmented lane compaction, default) or
                   monolithic (one vmapped while_loop) batched engine.
    batch_chunk    flat steps per compact segment (compaction cadence).
    batch_max_lanes
                   wave-size cap for the compact engine (``None`` = one
                   wave; small caps help cache-limited hosts).
    batch_gemm     aux-product route of the compact engine: ``"xla"``,
                   ``"host"`` (host BLAS stepper; CPU + Cov only) or
                   ``"auto"`` (host exactly when that combination holds).
    batch_warm_start
                   ``"pilot"`` solves the median-difficulty lane first and
                   warm-starts the rest from it (path mode); ``None`` runs
                   all lanes cold.
    obs            runtime observability (``repro.obs``): ``"off"``
                   (default — the obs package is never even imported),
                   ``"summary"`` (coarse per-solve spans, solve metrics
                   and latency histograms; <2% wall overhead, gated by
                   ``benchmarks/obs_overhead.py``) or ``"trace"`` (adds
                   compile-vs-execute split spans, per-segment/chunk
                   spans, and measured-vs-static comm reconciliation on
                   distributed solves).  Purely host-side: never part of
                   any jit static key, never traced — identical compiled
                   programs and bit-exact results at every level.
    penalty        penalty family as a string form parsed by
                   ``core.penalty.parse_penalty``: ``"l1"`` (default),
                   ``"elastic_net"``, ``"scad"``/``"scad:3.7"``,
                   ``"mcp"``/``"mcp:2.5"``.  Strength comes from the
                   estimator's ``lam1``/``lam2``; penalties needing
                   matrix parameters (``weighted_l1``) are passed as a
                   ``PenaltySpec`` on the estimator instead.  The config
                   stays a hashable string so it can key jit statics.
    """
    backend: str = "auto"
    variant: str = "auto"
    c_x: int | None = None
    c_omega: int | None = None
    n_devices: int | None = None
    tol: float = 1e-5
    max_iters: int = 500
    max_ls: int = 30
    warm_start_tau: bool = False
    dtype: str | None = None
    use_pallas: bool = False
    sparse_matmul: str = "off"
    sparse_block: int = 128
    sparse_threshold: float | None = None
    penalty: str = "l1"
    tau_schedule: str | None = None
    batch_schedule: str = "compact"
    batch_chunk: int = 32
    batch_max_lanes: int | None = None
    batch_gemm: str = "auto"
    batch_warm_start: str | None = None
    obs: str = "off"

    def __post_init__(self):
        if not isinstance(self.backend, str) or not self.backend:
            raise ValueError(f"backend must be a non-empty string, got "
                             f"{self.backend!r}")
        if self.variant not in VARIANTS:
            raise ValueError(f"variant must be one of {VARIANTS}, got "
                             f"{self.variant!r}")
        for name in ("c_x", "c_omega"):
            v = getattr(self, name)
            if v is not None and (not isinstance(v, int) or v < 1):
                raise ValueError(f"{name} must be a positive int or None, "
                                 f"got {v!r}")
        if self.n_devices is not None and self.n_devices < 1:
            raise ValueError(f"n_devices must be >= 1, got {self.n_devices}")
        if not (self.tol > 0.0):
            raise ValueError(f"tol must be > 0, got {self.tol}")
        if self.max_iters < 1:
            raise ValueError(f"max_iters must be >= 1, got {self.max_iters}")
        if self.max_ls < 1:
            raise ValueError(f"max_ls must be >= 1, got {self.max_ls}")
        if self.dtype is not None and self.dtype not in _DTYPES:
            raise ValueError(f"dtype must be one of {_DTYPES} or None, got "
                             f"{self.dtype!r}")
        if self.sparse_matmul not in SPARSE_MATMUL_MODES:
            raise ValueError(f"sparse_matmul must be one of "
                             f"{SPARSE_MATMUL_MODES}, got "
                             f"{self.sparse_matmul!r}")
        if not isinstance(self.sparse_block, int) or self.sparse_block < 1:
            raise ValueError(f"sparse_block must be a positive int, got "
                             f"{self.sparse_block!r}")
        if self.sparse_threshold is not None and not (
                0.0 < self.sparse_threshold <= 1.0):
            raise ValueError(f"sparse_threshold must be in (0, 1] or None, "
                             f"got {self.sparse_threshold!r}")
        if self.tau_schedule is not None and \
                self.tau_schedule not in TAU_SCHEDULES:
            raise ValueError(f"tau_schedule must be one of {TAU_SCHEDULES} "
                             f"or None, got {self.tau_schedule!r}")
        if self.batch_schedule not in BATCH_SCHEDULES:
            raise ValueError(f"batch_schedule must be one of "
                             f"{BATCH_SCHEDULES}, got "
                             f"{self.batch_schedule!r}")
        if not isinstance(self.batch_chunk, int) or self.batch_chunk < 1:
            raise ValueError(f"batch_chunk must be a positive int, got "
                             f"{self.batch_chunk!r}")
        if self.batch_max_lanes is not None and (
                not isinstance(self.batch_max_lanes, int)
                or self.batch_max_lanes < 1):
            raise ValueError(f"batch_max_lanes must be a positive int or "
                             f"None, got {self.batch_max_lanes!r}")
        if self.batch_gemm not in BATCH_GEMMS:
            raise ValueError(f"batch_gemm must be one of {BATCH_GEMMS}, "
                             f"got {self.batch_gemm!r}")
        if self.batch_warm_start not in BATCH_WARM_STARTS:
            raise ValueError(f"batch_warm_start must be one of "
                             f"{BATCH_WARM_STARTS}, got "
                             f"{self.batch_warm_start!r}")
        if self.obs not in OBS_MODES:
            raise ValueError(f"obs must be one of {OBS_MODES}, got "
                             f"{self.obs!r}")
        if not isinstance(self.penalty, str):
            raise ValueError(
                f"config.penalty must be a penalty string form (got "
                f"{type(self.penalty).__name__}); pass PenaltySpec objects "
                f"to the estimator, not the config")
        parse_penalty(self.penalty)     # raises ValueError on bad forms

    def replace(self, **changes) -> "SolverConfig":
        """Functional update (frozen dataclass)."""
        return dataclasses.replace(self, **changes)
