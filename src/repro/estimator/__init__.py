"""repro.estimator — the unified public API for sparse inverse covariance
estimation (the HP-CONCORD facade).

    from repro.estimator import ConcordEstimator, SolverConfig

    est = ConcordEstimator(lam1=0.15, lam2=0.05,
                           config=SolverConfig(backend="auto"))
    est.fit(X)                      # -> est.omega_, est.report_
    path = est.fit_path(X, lam1_grid=[0.3, 0.25, 0.2, 0.15, 0.1])
    best = path.best_bic()          # model selection in one call

    # whole grid as ONE compiled multi-problem program (core.batch):
    path = est.fit_path(X, lam1_grid=[...], mode="batched")
    # B stacked datasets (multi-subject / server micro-batch):
    rep = fit_batch(x=X_stack, lam1=0.15)       # -> BatchReport

    # pluggable penalties (core.penalty): SCAD path, adaptive lasso, ...
    est = ConcordEstimator(lam1=0.15, penalty="scad:3.7")
    est = ConcordEstimator(penalty=PenaltySpec.weighted_l1(0.15, W))
    path = est.fit_path(X, lam1_grid=[...], adaptive=True)   # 2-stage refit

Layers:
  penalty   PenaltySpec — pluggable prox operators (re-exported from
            ``repro.core.penalty``): l1 / elastic_net / weighted_l1
            (adaptive lasso, 0/inf structural constraints) / scad / mcp
  config    SolverConfig — every solver knob, frozen + validated
  backends  registry: "reference" | "distributed" | "auto" (cost-model)
  report    FitReport / PathResult / BatchReport — rich results + BIC
  batch     fit_batch + the batched lam1-path engine (one XLA program)
  estimator ConcordEstimator + functional ``fit`` / ``fit_path``

The old entry points (``core.prox.fit_reference``, ``core.distributed.fit``)
remain as deprecated shims; the bare ``lam1=``/``lam2=`` kwargs are the
deprecated legacy penalty surface (shimmed into the equivalent l1 spec).
"""
from ..core.penalty import (  # noqa: F401
    PenaltySpec,
    adaptive_weights,
    as_penalty,
    parse_penalty,
    penalty_kinds,
    register_penalty,
)
from .backends import (  # noqa: F401
    Problem,
    auto_backend,
    available_backends,
    distributed_backend,
    get_backend,
    reference_backend,
    register_backend,
)
from .batch import fit_batch  # noqa: F401
from .config import SolverConfig  # noqa: F401
from .estimator import ConcordEstimator, fit, fit_path  # noqa: F401
from .report import (  # noqa: F401
    BatchReport,
    FitReport,
    PathResult,
    pseudo_bic,
)

__all__ = [
    "BatchReport",
    "ConcordEstimator",
    "FitReport",
    "PathResult",
    "PenaltySpec",
    "Problem",
    "SolverConfig",
    "adaptive_weights",
    "as_penalty",
    "auto_backend",
    "available_backends",
    "distributed_backend",
    "fit",
    "fit_batch",
    "fit_path",
    "get_backend",
    "parse_penalty",
    "penalty_kinds",
    "pseudo_bic",
    "reference_backend",
    "register_backend",
    "register_penalty",
]
