"""``ConcordEstimator`` — the sklearn-style front door to every solver.

One object, five entry points:

    est = ConcordEstimator(lam1=0.15, lam2=0.05)
    est.fit(X)                      # (n, p) observations — or ANY chunk
                                    # stream (generator, shard paths, ...)
    est.fit_cov(S, n_samples=n)     # (p, p) sample covariance
    est.fit_gram(gram_result)       # streamed Gram from repro.data
    path = est.fit_path(X, lam1_grid=[...])        # warm-started lam1 path
    best = path.best_bic()                         # model selection

All solver knobs live in a frozen ``SolverConfig``; the backend registry
(``"reference"`` / ``"distributed"`` / ``"auto"``) decides what actually
runs.  ``fit_path`` runs the grid descending with warm starts: each point
starts from the previous solution (and, on the reference backend, reuses
the same compiled program, since lam1 and omega0 are traced arguments).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Iterable

from .backends import Problem, get_backend
from .config import SolverConfig
from .report import FitReport, PathResult, pseudo_bic


def _validate_lam1(lam1) -> float:
    lam1 = float(lam1)
    if not math.isfinite(lam1) or lam1 < 0:
        raise ValueError(f"lam1 must be finite and >= 0, got {lam1}")
    return lam1


def _validate_grid(lam1_grid) -> list[float]:
    try:
        grid = [float(v) for v in lam1_grid]
    except TypeError:
        raise ValueError(f"lam1_grid must be an iterable of floats, got "
                         f"{lam1_grid!r}") from None
    if not grid:
        raise ValueError("lam1_grid must be non-empty")
    for v in grid:
        if not math.isfinite(v) or v <= 0:
            raise ValueError(f"lam1_grid values must be finite and > 0, "
                             f"got {v}")
    return grid


class ConcordEstimator:
    """Sparse inverse covariance estimation via CONCORD/HP-CONCORD.

    Parameters mirror sklearn's covariance estimators: the penalties are
    constructor arguments, solver mechanics live in ``config``.  After
    ``fit``/``fit_cov`` the instance exposes ``omega_`` (the estimate),
    ``report_`` (a :class:`FitReport`) and ``n_iter_``.
    """

    def __init__(self, lam1: float = 0.1, lam2: float = 0.0,
                 config: SolverConfig | None = None):
        self.lam1 = _validate_lam1(lam1)
        self.lam2 = float(lam2)
        if self.lam2 < 0 or not math.isfinite(self.lam2):
            raise ValueError(f"lam2 must be finite and >= 0, got {lam2}")
        self.config = config or SolverConfig()
        if not isinstance(self.config, SolverConfig):
            raise TypeError(f"config must be a SolverConfig, got "
                            f"{type(self.config).__name__}")
        self.omega_ = None
        self.report_: FitReport | None = None
        self.n_iter_: int | None = None

    # -- single fits ----------------------------------------------------

    def _solve(self, problem: Problem, lam1: float, omega0=None) -> FitReport:
        backend = get_backend(self.config.backend)
        return backend(problem, lam1, self.lam2, self.config, omega0)

    def _finish(self, report: FitReport) -> "ConcordEstimator":
        self.report_ = report
        self.omega_ = report.omega
        self.n_iter_ = report.iters
        return self

    def fit(self, x, *, omega0=None, transform: str | None = None,
            chunk_rows: int | None = None) -> "ConcordEstimator":
        """Fit from observations (either variant works).

        ``x`` may be an in-memory (n, p) matrix, OR any chunk stream the
        data subsystem understands — a generator/iterator of row-blocks,
        a ``ChunkSource``, shard file paths, or a zero-arg factory (see
        ``repro.data.shards``).  Streams (and arrays with ``transform``
        set) are reduced to their f64 Gram by ``data.compute_gram``
        without ever materializing X, then solved through the Cov
        variant — the out-of-core front door."""
        from ..data.shards import is_streaming_input
        if is_streaming_input(x) or transform is not None:
            from ..data.gram import compute_gram
            gram = compute_gram(x, transform=transform or "none",
                                chunk_rows=chunk_rows)
            return self.fit_gram(gram, omega0=omega0)
        problem = Problem.from_data(x=x)
        return self._finish(self._solve(problem, self.lam1, omega0))

    def fit_cov(self, s, *, n_samples: int | None = None,
                omega0=None) -> "ConcordEstimator":
        """Fit from a (p, p) sample covariance (forces the Cov variant)."""
        problem = Problem.from_data(s=s, n_samples=n_samples)
        return self._finish(self._solve(problem, self.lam1, omega0))

    def fit_gram(self, gram, *, omega0=None) -> "ConcordEstimator":
        """Fit from a streamed Gram (``data.compute_gram`` /
        ``distributed_gram`` / the ``launch.gram prep`` artifact).

        Accepts a :class:`repro.data.GramResult` or anything exposing
        ``.s`` (the (p, p) Gram) and ``.n`` (rows streamed); the sample
        count rides along so BIC model selection downstream stays
        meaningful.  Validation (symmetry, finiteness) applies as in
        ``fit_cov``."""
        s = getattr(gram, "s", None)
        n = getattr(gram, "n", None)
        if s is None or n is None:
            raise TypeError(
                f"fit_gram wants a GramResult-like object with .s and .n "
                f"(got {type(gram).__name__}); for a plain covariance "
                f"array use fit_cov(s, n_samples=...)")
        problem = Problem.from_data(s=s, n_samples=int(n))
        return self._finish(self._solve(problem, self.lam1, omega0))

    # -- regularization path --------------------------------------------

    def fit_path(self, x=None, lam1_grid: Iterable[float] = (), *,
                 s=None, n_samples: int | None = None,
                 warm_start: bool = True,
                 score_bic: bool = True,
                 mode: str = "sequential") -> PathResult:
        """Fit a descending lam1 path.

        ``mode="sequential"`` (default) solves the grid point by point;
        each point starts from the previous solution (``warm_start``),
        which typically converges in a fraction of the cold-start
        iterations — the paper's Section-5 model-selection sweep as a
        single call.  ``warm_start=False`` runs every point cold (for
        benchmarking).

        ``mode="batched"`` lowers the ENTIRE grid to one compiled
        multi-problem program (``core.batch``): every point solves
        concurrently against the shared data, finished points freeze while
        stragglers keep iterating.  Warm starts do not apply (points run
        concurrently, cold); the engine is the single-device reference
        loop.  Per-point estimates match the sequential reference path
        (1e-5 agreement is asserted in float64 by the test suite).

        With ``score_bic`` each report carries a pseudo-likelihood BIC so
        ``PathResult.best_bic()`` picks a model in one line.
        """
        if mode not in ("sequential", "batched"):
            raise ValueError(f"mode must be 'sequential' or 'batched', "
                             f"got {mode!r}")
        grid = _validate_grid(lam1_grid)
        if score_bic and x is None and n_samples is None:
            raise ValueError(
                "BIC scoring needs the sample count: pass n_samples "
                "alongside s, or score_bic=False")
        problem = Problem.from_data(x=x, s=s, n_samples=n_samples)
        # form the covariance once for the whole path (cov-variant backends
        # and BIC scoring would otherwise recompute X^T X / n per point)
        if problem.s is None and (score_bic or self.config.variant != "obs"):
            problem = problem._replace(s=problem.cov())
        s_mat = problem.s if score_bic else None
        grid = sorted(grid, reverse=True)
        if mode == "batched":
            from .batch import batched_path_reports
            reports, _ = batched_path_reports(problem, grid, self.lam2,
                                              self.config)
        else:
            reports = []
            omega0 = None
            for lam1 in grid:
                rep = self._solve(problem, lam1,
                                  omega0 if warm_start else None)
                reports.append(rep)
                omega0 = rep.omega
        if score_bic:
            reports = [
                dataclasses.replace(
                    rep, bic=pseudo_bic(rep.omega, s_mat, problem.n))
                for rep in reports
            ]
        result = PathResult(reports=tuple(reports),
                            warm_start=warm_start and mode == "sequential",
                            mode=mode)
        self._finish(reports[-1])
        return result

    # -- batched multi-problem solves -----------------------------------

    def fit_batch(self, x=None, *, s=None, lam1=None, lam2=None,
                  omega0=None):
        """Solve stacked (B, ...) problems as one compiled batched program.

        ``x``: (B, n, p) stacked observation matrices or ``s``: (B, p, p)
        stacked covariances; ``lam1``/``lam2`` default to the estimator's
        penalties and may be length-B sequences for per-problem values.
        Returns a :class:`repro.estimator.report.BatchReport`; the last
        problem's report also lands on ``report_``/``omega_`` (sklearn
        convention, mirroring ``fit_path``)."""
        from .batch import fit_batch as _fit_batch
        result = _fit_batch(
            x, s=s, lam1=self.lam1 if lam1 is None else lam1,
            lam2=self.lam2 if lam2 is None else lam2,
            omega0=omega0, config=self.config)
        self._finish(result.reports[-1])
        return result


# ---------------------------------------------------------------------------
# functional facade
# ---------------------------------------------------------------------------

def fit(x=None, *, s=None, lam1: float, lam2: float = 0.0,
        n_samples: int | None = None, transform: str | None = None,
        chunk_rows: int | None = None,
        config: SolverConfig | None = None, **knobs) -> FitReport:
    """One-call fit through the facade.  ``x`` may be a matrix or a chunk
    stream (``transform``/``chunk_rows`` ride through to the streaming
    Gram pipeline).  Extra keyword args are SolverConfig fields (e.g.
    ``backend="distributed"``, ``tol=1e-6``)."""
    cfg = (config or SolverConfig()).replace(**knobs) if knobs else \
        (config or SolverConfig())
    est = ConcordEstimator(lam1=lam1, lam2=lam2, config=cfg)
    if x is not None:
        est.fit(x, transform=transform, chunk_rows=chunk_rows)
    else:
        est.fit_cov(s, n_samples=n_samples)
    return est.report_


def fit_path(x=None, lam1_grid: Iterable[float] = (), *, s=None,
             lam2: float = 0.0, n_samples: int | None = None,
             warm_start: bool = True, score_bic: bool = True,
             mode: str = "sequential",
             config: SolverConfig | None = None, **knobs) -> PathResult:
    """One-call regularization path through the facade (sequential
    warm-started, or ``mode="batched"`` for one compiled program)."""
    cfg = (config or SolverConfig()).replace(**knobs) if knobs else \
        (config or SolverConfig())
    est = ConcordEstimator(lam1=1.0, lam2=lam2, config=cfg)
    return est.fit_path(x, lam1_grid, s=s, n_samples=n_samples,
                        warm_start=warm_start, score_bic=score_bic,
                        mode=mode)
