"""``ConcordEstimator`` — the sklearn-style front door to every solver.

One object, five entry points:

    est = ConcordEstimator(lam1=0.15, lam2=0.05)
    est.fit(X)                      # (n, p) observations — or ANY chunk
                                    # stream (generator, shard paths, ...)
    est.fit_cov(S, n_samples=n)     # (p, p) sample covariance
    est.fit_gram(gram_result)       # streamed Gram from repro.data
    path = est.fit_path(X, lam1_grid=[...])        # warm-started lam1 path
    best = path.best_bic()                         # model selection

The penalty is pluggable (``repro.core.penalty``): pass
``penalty="scad:3.7"`` / ``"mcp"`` / ``"elastic_net"`` (strength from
``lam1``/``lam2``), or a full :class:`PenaltySpec` (e.g.
``PenaltySpec.weighted_l1(lam1, W)`` for adaptive lasso and structural
0/inf edge constraints).  The bare ``lam1=``/``lam2=`` kwargs are the
DEPRECATED legacy form: they keep working and construct the equivalent
l1 spec (bit-identical solve), but new code should hand the estimator a
spec — see the README migration table.

All solver knobs live in a frozen ``SolverConfig``; the backend registry
(``"reference"`` / ``"distributed"`` / ``"auto"``) decides what actually
runs.  ``fit_path`` runs the grid descending with warm starts: each point
starts from the previous solution (and, on the reference backend, reuses
the same compiled program, since every penalty parameter and omega0 are
traced arguments).  ``fit_path(adaptive=True)`` runs the two-stage
adaptive lasso: an l1 stage-1 path, weights ``1/(|omega_hat| + eps)``
from its BIC-best point, then a weighted stage-2 path over the same grid.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Iterable

import numpy as np

from ..core.penalty import PenaltySpec, adaptive_weights, as_penalty
from .backends import Problem, get_backend
from .config import SolverConfig
from .report import FitReport, PathResult, pseudo_bic


def _validate_grid(lam1_grid) -> list[float]:
    try:
        grid = [float(v) for v in lam1_grid]
    except TypeError:
        raise ValueError(f"lam1_grid must be an iterable of floats, got "
                         f"{lam1_grid!r}") from None
    if not grid:
        raise ValueError("lam1_grid must be non-empty")
    for v in grid:
        if not math.isfinite(v) or v <= 0:
            raise ValueError(f"lam1_grid values must be finite and > 0, "
                             f"got {v}")
    return grid


class ConcordEstimator:
    """Sparse inverse covariance estimation via CONCORD/HP-CONCORD.

    Parameters mirror sklearn's covariance estimators: the penalty is a
    constructor argument, solver mechanics live in ``config``.  After
    ``fit``/``fit_cov`` the instance exposes ``omega_`` (the estimate),
    ``report_`` (a :class:`FitReport`) and ``n_iter_``.

    ``penalty`` accepts a :class:`PenaltySpec`, a string form ("l1",
    "elastic_net", "scad:3.7", "mcp:2.5" — strength from ``lam1``/
    ``lam2``), or None (then ``config.penalty`` applies, default "l1").
    The scalar ``lam1``/``lam2`` kwargs are the deprecated legacy surface
    and are shimmed into the equivalent spec.
    """

    def __init__(self, lam1: float | None = None, lam2: float | None = None,
                 penalty: PenaltySpec | str | None = None,
                 config: SolverConfig | None = None):
        self.config = config or SolverConfig()
        if not isinstance(self.config, SolverConfig):
            raise TypeError(f"config must be a SolverConfig, got "
                            f"{type(self.config).__name__}")
        if isinstance(penalty, PenaltySpec):
            if lam1 is not None or lam2 is not None:
                raise ValueError(
                    "a PenaltySpec already carries lam1/lam2; pass either "
                    "the spec or the scalar kwargs, not both")
            spec = penalty
        else:
            # the estimator keeps its historical lam1 default of 0.1; the
            # lower solver layers require an explicit strength
            spec = as_penalty(penalty if penalty is not None
                              else self.config.penalty,
                              lam1=0.1 if lam1 is None else lam1,
                              lam2=lam2)
        self.penalty: PenaltySpec = spec
        self._lam1 = float(np.asarray(spec.lam1))
        self._lam2 = float(np.asarray(spec.lam2))
        self.omega_ = None
        self.report_: FitReport | None = None
        self.n_iter_: int | None = None

    # -- legacy scalar surface (deprecated, kept working) ---------------
    # ``est.lam1 = v`` mutation predates the penalty spec; the setters
    # rebuild the spec so old code that retunes the strength in place
    # keeps solving with the new value.

    @property
    def lam1(self) -> float:
        return self._lam1

    @lam1.setter
    def lam1(self, value) -> None:
        self._lam1 = float(value)
        self.penalty = self.penalty.with_lam1(self._lam1)

    @property
    def lam2(self) -> float:
        return self._lam2

    @lam2.setter
    def lam2(self, value) -> None:
        self._lam2 = float(value)
        self.penalty = dataclasses.replace(self.penalty, lam2=self._lam2)

    # -- single fits ----------------------------------------------------

    def _solve(self, problem: Problem, spec: PenaltySpec,
               omega0=None) -> FitReport:
        backend = get_backend(self.config.backend)
        return backend(problem, spec, self.config, omega0)

    def _finish(self, report: FitReport) -> "ConcordEstimator":
        self.report_ = report
        self.omega_ = report.omega
        self.n_iter_ = report.iters
        return self

    def fit(self, x, *, omega0=None, transform: str | None = None,
            chunk_rows: int | None = None) -> "ConcordEstimator":
        """Fit from observations (either variant works).

        ``x`` may be an in-memory (n, p) matrix, OR any chunk stream the
        data subsystem understands — a generator/iterator of row-blocks,
        a ``ChunkSource``, shard file paths, or a zero-arg factory (see
        ``repro.data.shards``).  Streams (and arrays with ``transform``
        set) are reduced to their f64 Gram by ``data.compute_gram``
        without ever materializing X, then solved through the Cov
        variant — the out-of-core front door."""
        from ..data.shards import is_streaming_input
        if is_streaming_input(x) or transform is not None:
            from ..data.gram import compute_gram
            gram = compute_gram(x, transform=transform or "none",
                                chunk_rows=chunk_rows)
            return self.fit_gram(gram, omega0=omega0)
        problem = Problem.from_data(x=x)
        return self._finish(self._solve(problem, self.penalty, omega0))

    def fit_cov(self, s, *, n_samples: int | None = None,
                omega0=None) -> "ConcordEstimator":
        """Fit from a (p, p) sample covariance (forces the Cov variant)."""
        problem = Problem.from_data(s=s, n_samples=n_samples)
        return self._finish(self._solve(problem, self.penalty, omega0))

    def fit_gram(self, gram, *, omega0=None) -> "ConcordEstimator":
        """Fit from a streamed Gram (``data.compute_gram`` /
        ``distributed_gram`` / the ``launch.gram prep`` artifact).

        Accepts a :class:`repro.data.GramResult` or anything exposing
        ``.s`` (the (p, p) Gram) and ``.n`` (rows streamed); the sample
        count rides along so BIC model selection downstream stays
        meaningful.  Validation (symmetry, finiteness) applies as in
        ``fit_cov``."""
        s = getattr(gram, "s", None)
        n = getattr(gram, "n", None)
        if s is None or n is None:
            raise TypeError(
                f"fit_gram wants a GramResult-like object with .s and .n "
                f"(got {type(gram).__name__}); for a plain covariance "
                f"array use fit_cov(s, n_samples=...)")
        problem = Problem.from_data(s=s, n_samples=int(n))
        return self._finish(self._solve(problem, self.penalty, omega0))

    # -- regularization path --------------------------------------------

    def _resolve_path_mode(self, mode: str, grid: list[float]) -> str:
        """``fit_path(mode="auto")``: consult the cost model's
        batched-vs-sequential predictor with the engine knobs this config
        would actually run (tau schedule, chunk, gemm route, pilot warm
        start)."""
        if mode != "auto":
            return mode
        import jax

        from ..core.costmodel import choose_path_mode
        from ..core.prox import resolve_tau_schedule
        gemm = self.config.batch_gemm
        if gemm == "auto":
            # mirror the batch layer's resolution; the predictor only
            # needs the step-cost class, not the exact dtype gate
            gemm = "host" if jax.default_backend() == "cpu" else "xla"
        return choose_path_mode(
            grid,
            tau_schedule=resolve_tau_schedule(
                self.config.tau_schedule, self.config.warm_start_tau),
            chunk=self.config.batch_chunk,
            max_iters=self.config.max_iters,
            gemm=gemm, warm_start=self.config.batch_warm_start)

    def _run_path(self, problem: Problem, grid: list[float],
                  spec: PenaltySpec, mode: str, warm_start: bool,
                  score_bic: bool, s_mat):
        if self.config.obs != "off":
            from ..obs.trace import get_tracer
            tracer = get_tracer()
            with tracer.scoped(self.config.obs):
                with tracer.span("fit_path", points=len(grid),
                                 mode=mode) as span:
                    reports, stats = self._run_path_inner(
                        problem, grid, spec, mode, warm_start, score_bic,
                        s_mat)
                span.note(total_iters=sum(r.iters for r in reports))
            return reports, stats
        return self._run_path_inner(problem, grid, spec, mode, warm_start,
                                    score_bic, s_mat)

    def _run_path_inner(self, problem: Problem, grid: list[float],
                        spec: PenaltySpec, mode: str, warm_start: bool,
                        score_bic: bool, s_mat):
        stats = None
        if mode == "batched":
            from .batch import batched_path_reports
            reports, _, stats = batched_path_reports(
                problem, grid, self.config, penalty=spec)
        else:
            reports = []
            omega0 = None
            for lam1 in grid:
                rep = self._solve(problem, spec.with_lam1(lam1),
                                  omega0 if warm_start else None)
                reports.append(rep)
                omega0 = rep.omega
        if score_bic:
            reports = [
                dataclasses.replace(
                    rep, bic=pseudo_bic(rep.omega, s_mat, problem.n))
                for rep in reports
            ]
        return reports, stats

    def fit_path(self, x=None, lam1_grid: Iterable[float] = (), *,
                 s=None, n_samples: int | None = None,
                 warm_start: bool = True,
                 score_bic: bool = True,
                 mode: str = "sequential",
                 adaptive: bool = False,
                 adaptive_eps: float = 1e-3) -> PathResult:
        """Fit a descending lam1 path.

        ``mode="sequential"`` (default) solves the grid point by point;
        each point starts from the previous solution (``warm_start``),
        which typically converges in a fraction of the cold-start
        iterations — the paper's Section-5 model-selection sweep as a
        single call.  ``warm_start=False`` runs every point cold (for
        benchmarking).

        ``mode="batched"`` lowers the ENTIRE grid to one compiled
        multi-problem program (``core.batch``): every point solves
        concurrently against the shared data, finished points freeze while
        stragglers keep iterating.  Warm starts do not apply (points run
        concurrently, cold); the engine is the single-device reference
        loop.  Per-point estimates match the sequential reference path
        (1e-5 agreement is asserted in float64 by the test suite).

        The path runs the estimator's penalty at every grid point
        (``spec.with_lam1`` — one compiled program on the reference
        backend, since penalty parameters are traced).

        ``adaptive=True`` runs the TWO-STAGE adaptive lasso instead:
        stage 1 is a plain l1 path over the grid, then each grid point is
        refit with ``weighted_l1`` weights ``1/(|omega_hat| +
        adaptive_eps)`` built from stage 1's estimate AT THE SAME lam1
        (the pointwise two-stage refit — a single dense anchor would pin
        the whole stage-2 path to the anchor's sparsity).  In batched
        mode the per-point weight matrices ride as one (B, p, p) lane-
        batched spec leaf through the single compiled program.  Returns
        the stage-2 path with ``adaptive=True`` and ``stage1`` attached.

        ``mode="auto"`` consults the cost model
        (``core.costmodel.choose_path_mode``): batched when the compact
        engine's predicted speedup over a sequential sweep of this grid
        clears the threshold, sequential otherwise.

        With ``score_bic`` each report carries a pseudo-likelihood BIC so
        ``PathResult.best_bic()`` picks a model in one line.
        """
        if mode not in ("sequential", "batched", "auto"):
            raise ValueError(f"mode must be 'sequential', 'batched' or "
                             f"'auto', got {mode!r}")
        grid = _validate_grid(lam1_grid)
        mode = self._resolve_path_mode(mode, grid)
        if score_bic and x is None and n_samples is None:
            raise ValueError(
                "BIC scoring needs the sample count: pass n_samples "
                "alongside s, or score_bic=False")
        problem = Problem.from_data(x=x, s=s, n_samples=n_samples)
        # form the covariance once for the whole path (cov-variant backends
        # and BIC scoring would otherwise recompute X^T X / n per point)
        if problem.s is None and (score_bic or self.config.variant != "obs"):
            problem = problem._replace(s=problem.cov())
        s_mat = problem.s if score_bic else None
        grid = sorted(grid, reverse=True)
        warm = warm_start and mode == "sequential"
        spec1 = self.penalty
        if adaptive and spec1.kind != "l1":
            # stage 1 of the adaptive refit is always a plain l1 path
            spec1 = PenaltySpec("l1", self.lam1, self.lam2)
        reports, bstats = self._run_path(problem, grid, spec1, mode,
                                         warm_start, score_bic, s_mat)
        stage1 = PathResult(reports=tuple(reports), warm_start=warm,
                            mode=mode, batch_stats=bstats)
        if not adaptive:
            self._finish(reports[-1])
            return stage1
        weights = [adaptive_weights(rep.omega, eps=adaptive_eps)
                   for rep in stage1.reports]
        bstats2 = None
        if mode == "batched":
            from .batch import batched_path_reports
            # per-point weight matrices = one (B, p, p) lane-batched leaf
            spec2 = PenaltySpec("weighted_l1", grid[0], self.lam2,
                                weights=np.stack(weights))
            reports2, _, bstats2 = batched_path_reports(
                problem, grid, self.config, penalty=spec2)
        else:
            reports2 = []
            omega0 = None
            for lam1, w in zip(grid, weights):
                spec2 = PenaltySpec("weighted_l1", lam1, self.lam2,
                                    weights=w)
                rep = self._solve(problem, spec2,
                                  omega0 if warm_start else None)
                reports2.append(rep)
                omega0 = rep.omega
        if score_bic:
            reports2 = [
                dataclasses.replace(
                    rep, bic=pseudo_bic(rep.omega, s_mat, problem.n))
                for rep in reports2
            ]
        result = PathResult(reports=tuple(reports2), warm_start=warm,
                            mode=mode, adaptive=True, stage1=stage1,
                            batch_stats=bstats2)
        self._finish(reports2[-1])
        return result

    # -- batched multi-problem solves -----------------------------------

    def fit_batch(self, x=None, *, s=None, lam1=None, lam2=None,
                  penalty=None, omega0=None):
        """Solve stacked (B, ...) problems as one compiled batched program.

        ``x``: (B, n, p) stacked observation matrices or ``s``: (B, p, p)
        stacked covariances.  The batch runs the estimator's penalty
        FAMILY; ``lam1``/``lam2`` override only the strengths (scalars or
        length-B sequences — a SCAD estimator with ``lam1=[...]`` stays
        SCAD per lane).  ``penalty`` replaces the spec outright: a string
        form (strength from lam1/lam2, defaulting to the estimator's) or
        a full :class:`PenaltySpec` whose numeric leaves may carry a
        leading (B,) lane axis (per-lane penalty parameters in one
        compiled program).  Returns a
        :class:`repro.estimator.report.BatchReport`; the last problem's
        report also lands on ``report_``/``omega_`` (sklearn convention,
        mirroring ``fit_path``)."""
        from .batch import fit_batch as _fit_batch
        if penalty is None:
            spec = self.penalty
            if lam1 is not None:
                spec = spec.with_lam1(np.asarray(lam1, np.float64))
            if lam2 is not None:
                spec = dataclasses.replace(
                    spec, lam2=np.asarray(lam2, np.float64))
        elif isinstance(penalty, str):
            spec = as_penalty(penalty,
                              lam1=self.lam1 if lam1 is None else lam1,
                              lam2=self.lam2 if lam2 is None else lam2)
        else:
            if lam1 is not None or lam2 is not None:
                raise ValueError(
                    "a PenaltySpec already carries lam1/lam2; pass either "
                    "the spec or the scalar overrides, not both")
            spec = as_penalty(penalty)
        result = _fit_batch(x, s=s, penalty=spec, omega0=omega0,
                            config=self.config)
        self._finish(result.reports[-1])
        return result


# ---------------------------------------------------------------------------
# functional facade
# ---------------------------------------------------------------------------

def fit(x=None, *, s=None, lam1: float | None = None, lam2: float = 0.0,
        penalty: PenaltySpec | str | None = None,
        n_samples: int | None = None, transform: str | None = None,
        chunk_rows: int | None = None,
        config: SolverConfig | None = None, **knobs) -> FitReport:
    """One-call fit through the facade.  ``x`` may be a matrix or a chunk
    stream (``transform``/``chunk_rows`` ride through to the streaming
    Gram pipeline).  ``penalty`` swaps the penalty family (spec or string
    form).  Extra keyword args are SolverConfig fields (e.g.
    ``backend="distributed"``, ``tol=1e-6``)."""
    cfg = (config or SolverConfig()).replace(**knobs) if knobs else \
        (config or SolverConfig())
    if isinstance(penalty, PenaltySpec):
        est = ConcordEstimator(penalty=penalty, config=cfg)
    else:
        est = ConcordEstimator(lam1=lam1, lam2=lam2, penalty=penalty,
                               config=cfg)
    if x is not None:
        est.fit(x, transform=transform, chunk_rows=chunk_rows)
    else:
        est.fit_cov(s, n_samples=n_samples)
    return est.report_


def fit_path(x=None, lam1_grid: Iterable[float] = (), *, s=None,
             lam2: float = 0.0,
             penalty: PenaltySpec | str | None = None,
             n_samples: int | None = None,
             warm_start: bool = True, score_bic: bool = True,
             mode: str = "sequential", adaptive: bool = False,
             config: SolverConfig | None = None, **knobs) -> PathResult:
    """One-call regularization path through the facade (sequential
    warm-started, ``mode="batched"`` for one compiled program, or
    ``adaptive=True`` for the two-stage adaptive lasso)."""
    cfg = (config or SolverConfig()).replace(**knobs) if knobs else \
        (config or SolverConfig())
    if isinstance(penalty, PenaltySpec):
        est = ConcordEstimator(penalty=penalty, config=cfg)
    else:
        est = ConcordEstimator(lam1=1.0, lam2=lam2, penalty=penalty,
                               config=cfg)
    return est.fit_path(x, lam1_grid, s=s, n_samples=n_samples,
                        warm_start=warm_start, score_bic=score_bic,
                        mode=mode, adaptive=adaptive)
