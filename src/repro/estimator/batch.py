"""Batched estimator surface: ``fit_batch`` + the batched lam1-path engine.

Thin facade over :mod:`repro.core.batch` (the vmap'd multi-problem solve
engine).  Two entry points:

  * ``fit_batch`` — solve B stacked independent problems (multi-subject /
    multi-tenant workloads, server micro-batches) as ONE compiled program;
    returns a :class:`BatchReport` aggregating per-problem
    :class:`FitReport`s.  ``penalty`` accepts a
    :class:`~repro.core.penalty.PenaltySpec` whose numeric leaves may be
    (B,)-batched so different lanes run different penalty parameters.
  * ``batched_path_reports`` — the engine behind
    ``ConcordEstimator.fit_path(mode="batched")``: a whole lam1 grid
    against shared data as one program.

The engine runs the single-device reference loop (dense products); the
distributed 1.5D drivers remain per-problem backends.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from ..core import batch as core_batch
from ..core.penalty import PenaltySpec, normalize_penalty
from ..core.prox import ProxResult
from .backends import Problem, _cast, _report
from .config import SolverConfig
from .report import BatchReport, FitReport


def _check_engine(config: SolverConfig) -> None:
    if config.backend == "distributed":
        raise ValueError(
            "the batched engine runs the single-device reference loop; "
            "use backend='reference' or 'auto' (distributed solves stay "
            "per-problem)")


def _resolve_batch_variant(config: SolverConfig, have_s: bool) -> str:
    """The batched engine's variant="auto" rule (both entry points): Cov
    when a covariance is already available (paths form S once and its
    products are p x p), Obs for raw stacked datasets (no per-problem
    covariance pass needed)."""
    if config.variant != "auto":
        return config.variant
    return "cov" if have_s else "obs"


def _resolve_batch_gemm(config: SolverConfig, variant: str, dtype) -> str:
    """``batch_gemm="auto"``: the host BLAS stepper exactly where it is
    legal and measured faster — CPU backend, Cov variant, compact
    schedule, megakernel off, f64 compute (where its agreement with the
    XLA route is validated) — else plain XLA."""
    if config.batch_gemm != "auto":
        return config.batch_gemm
    if (variant == "cov" and config.batch_schedule == "compact"
            and not config.use_pallas and jnp.dtype(dtype) == jnp.float64
            and jax.default_backend() == "cpu"):
        return "host"
    return "xla"


def _slice_result(res: ProxResult, i: int) -> ProxResult:
    """Per-problem view of a batched ProxResult (leading (B,) axis)."""
    return ProxResult(*(f[i] for f in res))


def batch_reports(res: ProxResult, lam1s, lam2s, wall: float, *,
                  variant: str, config: SolverConfig,
                  backend: str = "batched",
                  penalty: PenaltySpec | None = None) -> list[FitReport]:
    """Split one batched ProxResult into per-problem FitReports.

    ``penalty`` is the (possibly lane-batched) spec the batch ran with;
    each report gets its own lane (``PenaltySpec.lane``) so objectives
    and labels reflect per-lane penalty parameters.  The batch ran as one
    compiled program, so per-problem wall time is not physical — each
    report carries its 1/B share (sums reproduce the measured total)."""
    b = len(lam1s)
    # the engine always runs dense products (the block-sparse lax.switch
    # would execute every branch under vmap) — report the routing mode
    # that actually ran, whatever the config asked for
    config = config.replace(sparse_matmul="off")
    lanes = [None] * b
    if penalty is not None:
        lanes = [penalty.lane(i, b).with_lam1(float(lam1s[i]))
                 for i in range(b)]
    return [
        _report(_slice_result(res, i), lam1=float(lam1s[i]),
                lam2=float(lam2s[i]), wall=wall / b, backend=backend,
                variant=variant, config=config, penalty=lanes[i])
        for i in range(b)
    ]


def fit_batch(x=None, *, s=None, lam1=None, lam2=0.0, penalty=None,
              omega0=None, config: SolverConfig | None = None,
              **knobs) -> BatchReport:
    """Solve B stacked problems as one compiled batched program.

    ``x``: (B, n, p) stacked observation matrices, or ``s``: (B, p, p)
    stacked sample covariances — one shape for the whole batch (bucket
    requests by shape before calling).  ``lam1``/``lam2`` are scalars
    (shared) or length-B sequences (per-problem); ``penalty`` instead
    passes a full :class:`PenaltySpec` (or string form), any of whose
    numeric leaves may carry a leading (B,) lane axis for per-lane
    penalty parameters in the one compiled program.  ``omega0`` is None,
    one shared (p, p) warm start, or stacked (B, p, p).  Extra keyword
    args are ``SolverConfig`` fields.  Returns a :class:`BatchReport`.
    """
    cfg = (config or SolverConfig()).replace(**knobs) if knobs else \
        (config or SolverConfig())
    _check_engine(cfg)
    if (x is None) == (s is None):
        raise ValueError("pass exactly one of x (B, n, p) or s (B, p, p)")
    data = jnp.asarray(x if x is not None else s)
    if data.ndim != 3:
        raise ValueError(f"batched data must be 3-D stacked problems, got "
                         f"shape {data.shape}")
    if s is not None and data.shape[-1] != data.shape[-2]:
        raise ValueError(f"s must stack square matrices, got {data.shape}")
    variant = _resolve_batch_variant(cfg, have_s=s is not None)
    if variant == "obs" and x is None:
        raise ValueError("Obs variant requires the stacked data matrices x")
    if variant == "cov" and x is not None:
        # form the per-problem covariances in one batched einsum
        n = data.shape[1]
        data = jnp.einsum("bni,bnj->bij", data, data) / n
    data = _cast(data, cfg)
    b = data.shape[0]
    if penalty is not None:
        spec = normalize_penalty(penalty, lam1, lam2)
        # exact user-passed penalties for the reports (compute-dtype casts
        # only feed the solver)
        lam1s = np.broadcast_to(np.asarray(spec.lam1, np.float64), (b,))
        lam2s = np.broadcast_to(np.asarray(spec.lam2, np.float64), (b,))
        t0 = time.perf_counter()
        res, stats = core_batch.solve_batch(
            data, penalty=spec, omega0=omega0, variant=variant,
            tol=cfg.tol, max_iters=cfg.max_iters, max_ls=cfg.max_ls,
            warm_start_tau=cfg.warm_start_tau,
            tau_schedule=cfg.tau_schedule, schedule=cfg.batch_schedule,
            chunk=cfg.batch_chunk, max_lanes=cfg.batch_max_lanes,
            gemm=_resolve_batch_gemm(cfg, variant, data.dtype),
            return_stats=True)
    else:
        if lam1 is None:
            raise TypeError("pass lam1 (or penalty=)")
        spec = None
        lam1s = np.broadcast_to(np.asarray(lam1, np.float64), (b,))
        lam2s = np.broadcast_to(np.asarray(lam2, np.float64), (b,))
        t0 = time.perf_counter()
        res, stats = core_batch.solve_batch(
            data, jnp.asarray(lam1s, data.dtype),
            jnp.asarray(lam2s, data.dtype),
            omega0=omega0, variant=variant,
            tol=cfg.tol, max_iters=cfg.max_iters, max_ls=cfg.max_ls,
            warm_start_tau=cfg.warm_start_tau,
            tau_schedule=cfg.tau_schedule, schedule=cfg.batch_schedule,
            chunk=cfg.batch_chunk, max_lanes=cfg.batch_max_lanes,
            gemm=_resolve_batch_gemm(cfg, variant, data.dtype),
            return_stats=True)
    jax.block_until_ready(res.omega)
    wall = time.perf_counter() - t0
    reports = batch_reports(res, lam1s, lam2s, wall, variant=variant,
                            config=cfg, penalty=spec)
    return BatchReport(reports=tuple(reports), wall_time_s=wall,
                       stats=stats)


def batched_path_reports(problem: Problem, grid: list[float],
                         config: SolverConfig, *,
                         penalty: PenaltySpec | None = None,
                         lam2: float = 0.0,
                         omega0=None):
    """Run a whole lam1 grid against shared data as one compiled program.

    ``penalty`` (optional) is the spec template whose lam1 the grid
    replaces — SCAD/MCP/weighted paths lower to the same single program.
    The engine knobs (``batch_schedule``/``batch_chunk``/
    ``batch_max_lanes``/``batch_gemm``/``batch_warm_start``/
    ``tau_schedule``/``use_pallas``) come from the config.  Returns
    (per-point reports in ``grid`` order, total wall seconds, the
    engine's :class:`~repro.core.batch.BatchRunStats`).  Engine behind
    ``ConcordEstimator.fit_path(mode="batched")``."""
    _check_engine(config)
    variant = _resolve_batch_variant(config, have_s=problem.s is not None)
    if variant == "cov":
        data = _cast(problem.cov(), config)
    else:
        if problem.x is None:
            raise ValueError("Obs variant requires the data matrix x")
        data = _cast(problem.x, config)
    if omega0 is not None:
        omega0 = jnp.asarray(omega0, data.dtype)
    lam1s = jnp.asarray(grid, data.dtype)
    if penalty is not None:
        lam2 = float(np.asarray(penalty.lam2))
    t0 = time.perf_counter()
    res, stats = core_batch.solve_path_batched(
        data, lam1s, lam2, penalty=penalty, omega0=omega0, variant=variant,
        tol=config.tol, max_iters=config.max_iters, max_ls=config.max_ls,
        warm_start_tau=config.warm_start_tau,
        tau_schedule=config.tau_schedule, schedule=config.batch_schedule,
        chunk=config.batch_chunk, max_lanes=config.batch_max_lanes,
        use_pallas=config.use_pallas,
        gemm=_resolve_batch_gemm(config, variant, data.dtype),
        warm_start=config.batch_warm_start, return_stats=True)
    jax.block_until_ready(res.omega)
    wall = time.perf_counter() - t0
    lam2s = [lam2] * len(grid)
    spec_b = penalty.with_lam1(np.asarray(grid, np.float64)) \
        if penalty is not None else None
    return batch_reports(res, grid, lam2s, wall, variant=variant,
                         config=config, penalty=spec_b), wall, stats
