"""Scenario generators: (Ω_true, seeded chunked sampler) pairs for ≥5
graph families, with controlled condition number.

The benchmark suite used to exercise exactly one synthetic world (the
chain graph).  This module is the scenario layer the ROADMAP's
"as many scenarios as you can imagine" asks for:

  family          structure
  ``banded``      k-banded precision (chain is band=1): local dependence
  ``hub``         star groups — a few high-degree hub variables
  ``erdos_renyi`` homogeneous random graph, expected degree controlled
  ``block``       block-diagonal communities, dense within, none across
  ``scale_free``  Barabási–Albert preferential attachment (power-law
                  degrees — the hard case for uniform-penalty recovery)

Every family builds a symmetric off-diagonal weight pattern A and then
sets the diagonal ANALYTICALLY for an exact target condition number:
Ω = (A + δI)/δ with δ = (λmax(A) − κ·λmin(A))/(κ − 1), which makes
cond(Ω) = κ exactly and diag(Ω) = 1 (support of A untouched).

Sampling never materializes X: :meth:`Scenario.source` returns a
re-iterable chunk source whose chunk i is drawn from
``default_rng((family_hash, seed, i))`` — tera-style n streams straight
into ``data.gram`` in (chunk_rows, p) blocks, and re-iteration (or a
second process) reproduces the exact same stream.  ``heavy_tail_df``
switches the marginals to a multivariate-t-style scale mixture (same
precision structure, heavier tails) — the non-Gaussian worlds the rank
transform exists for.
"""
from __future__ import annotations

import zlib
from typing import Callable, NamedTuple

import numpy as np

from .shards import CallableSource

__all__ = [
    "SCENARIO_FAMILIES", "Scenario", "available_families", "make_scenario",
    "register_family",
]

DEFAULT_COND = 10.0


# ---------------------------------------------------------------------------
# off-diagonal weight patterns (symmetric, zero diagonal)
# ---------------------------------------------------------------------------

def _banded_weights(p: int, rng, *, band: int = 2, weight: float = 0.4,
                    decay: float = 0.5) -> np.ndarray:
    a = np.zeros((p, p))
    for k in range(1, min(band, p - 1) + 1):
        w = weight * decay ** (k - 1)
        idx = np.arange(p - k)
        a[idx, idx + k] = w
        a[idx + k, idx] = w
    return a


def _hub_weights(p: int, rng, *, group: int = 16,
                 weight: float = 0.35) -> np.ndarray:
    a = np.zeros((p, p))
    for lo in range(0, p, group):
        hub = lo
        for v in range(lo + 1, min(lo + group, p)):
            w = weight * rng.uniform(0.6, 1.0)
            a[hub, v] = a[v, hub] = w
    return a


def _erdos_renyi_weights(p: int, rng, *, avg_degree: float = 4.0,
                         weight: float = 0.3) -> np.ndarray:
    prob = min(1.0, avg_degree / max(p - 1, 1))
    upper = np.triu(rng.random((p, p)) < prob, k=1)
    signs = rng.choice([-1.0, 1.0], size=(p, p))
    mags = rng.uniform(0.5, 1.0, size=(p, p)) * weight
    w = np.where(upper, signs * mags, 0.0)
    return w + w.T


def _block_weights(p: int, rng, *, block: int = 8,
                   weight: float = 0.3) -> np.ndarray:
    a = np.zeros((p, p))
    for lo in range(0, p, block):
        hi = min(lo + block, p)
        b = hi - lo
        signs = rng.choice([-1.0, 1.0], size=(b, b))
        mags = rng.uniform(0.5, 1.0, size=(b, b)) * weight
        w = np.triu(signs * mags, k=1)
        a[lo:hi, lo:hi] = w + w.T
    return a


def _scale_free_weights(p: int, rng, *, m: int = 2,
                        weight: float = 0.3) -> np.ndarray:
    """Barabási–Albert preferential attachment: each arriving node links
    to ``m`` existing nodes with probability proportional to degree."""
    a = np.zeros((p, p))
    m = max(1, min(m, p - 1))
    repeated: list[int] = list(range(m))      # degree-weighted urn
    for v in range(m, p):
        chosen: set[int] = set()
        while len(chosen) < min(m, v):
            if repeated:
                pick = repeated[int(rng.integers(len(repeated)))]
            else:
                pick = int(rng.integers(v))
            if pick != v:
                chosen.add(pick)
        for t in chosen:
            w = weight * rng.uniform(0.5, 1.0) * rng.choice([-1.0, 1.0])
            a[v, t] = a[t, v] = w
            repeated.extend([v, t])
    return a


SCENARIO_FAMILIES: dict[str, Callable] = {}


def register_family(name: str, builder: Callable, *,
                    overwrite: bool = False) -> None:
    """Plug in a new family: ``builder(p, rng, **kw) -> (p, p) symmetric
    zero-diagonal weights``."""
    if not overwrite and name in SCENARIO_FAMILIES:
        raise ValueError(f"family {name!r} already registered")
    SCENARIO_FAMILIES[name] = builder


def available_families() -> list[str]:
    return sorted(SCENARIO_FAMILIES)


register_family("banded", _banded_weights)
register_family("hub", _hub_weights)
register_family("erdos_renyi", _erdos_renyi_weights)
register_family("block", _block_weights)
register_family("scale_free", _scale_free_weights)


# ---------------------------------------------------------------------------
# conditioning + the Scenario object
# ---------------------------------------------------------------------------

def _condition(a: np.ndarray, cond: float) -> tuple[np.ndarray, float]:
    """Ω = (A + δI)/δ with δ solving (λmax+δ)/(λmin+δ) = cond exactly.
    Returns (Ω, achieved cond).  diag(Ω) = 1; support(Ω) = support(A)."""
    if cond <= 1.0:
        raise ValueError(f"cond must be > 1, got {cond}")
    ev = np.linalg.eigvalsh(a)
    lmin, lmax = float(ev[0]), float(ev[-1])
    if lmax - lmin < 1e-12:                     # empty graph -> identity
        return np.eye(a.shape[0]) + a * 0.0, 1.0
    delta = (lmax - cond * lmin) / (cond - 1.0)
    omega = (a + delta * np.eye(a.shape[0])) / delta
    return omega, (lmax + delta) / (lmin + delta)


class Scenario(NamedTuple):
    """(Ω_true, sampler) pair: the ground truth and a way to stream X."""
    name: str               # family name
    p: int
    omega: np.ndarray       # (p, p) f64 true precision, diag = 1
    cond: float             # achieved condition number (== requested)
    seed: int               # graph-structure seed
    heavy_tail_df: float | None = None   # None -> Gaussian marginals

    @property
    def avg_degree(self) -> float:
        off = np.abs(self.omega) > 1e-12
        return float((off.sum() - self.p) / self.p)

    def _chunks(self, n: int, chunk_rows: int, seed: int):
        try:
            from scipy.linalg import solve_triangular
        except ImportError:              # pragma: no cover - minimal envs
            solve_triangular = None
        chol = np.linalg.cholesky(self.omega)   # Ω = L Lᵀ, X = Z L⁻ᵀ
        tag = zlib.crc32(self.name.encode()) & 0x7FFFFFFF
        i = 0
        for lo in range(0, n, chunk_rows):
            m = min(chunk_rows, n - lo)
            rng = np.random.default_rng((tag, self.seed, seed, i))
            z = rng.standard_normal((m, self.p))
            if solve_triangular is not None:
                # back-substitution: O(m p^2) per chunk; a generic solve
                # would re-LU the same triangular factor every chunk
                x = solve_triangular(chol.T, z.T, lower=False).T
            else:
                x = np.linalg.solve(chol.T, z.T).T
            if self.heavy_tail_df is not None:
                chi = rng.chisquare(self.heavy_tail_df,
                                    size=(m, 1)) / self.heavy_tail_df
                x = x / np.sqrt(chi)
            yield x
            i += 1

    def source(self, n: int, *, chunk_rows: int = 4096,
               seed: int = 0) -> CallableSource:
        """Re-iterable chunk source for n rows — the stream identity is
        (family, structure seed, sample seed, chunk_rows); re-iterating
        or re-opening with the same tuple reproduces the byte-identical
        stream, chunk by chunk, without ever holding X."""
        return CallableSource(
            lambda: self._chunks(n, chunk_rows, seed),
            p=self.p, n_rows=n)

    def sample(self, n: int, *, seed: int = 0,
               chunk_rows: int = 4096) -> np.ndarray:
        """Materialized (n, p) sample — small-n tests and baselines only."""
        return np.concatenate(list(self._chunks(n, chunk_rows, seed)))


def make_scenario(family: str, p: int, *, seed: int = 0,
                  cond: float = DEFAULT_COND,
                  heavy_tail_df: float | None = None,
                  **family_kw) -> Scenario:
    """Build one scenario: family weights -> exact-cond Ω -> sampler."""
    try:
        builder = SCENARIO_FAMILIES[family]
    except KeyError:
        raise ValueError(
            f"unknown scenario family {family!r}; available: "
            f"{available_families()}") from None
    rng = np.random.default_rng((zlib.crc32(family.encode()), seed))
    a = np.asarray(builder(int(p), rng, **family_kw), np.float64)
    if a.shape != (p, p) or np.abs(a - a.T).max() > 1e-12 \
            or np.abs(np.diag(a)).max() > 1e-12:
        raise ValueError(
            f"family {family!r} produced an invalid weight pattern")
    omega, achieved = _condition(a, cond)
    return Scenario(name=family, p=int(p), omega=omega,
                    cond=float(achieved), seed=int(seed),
                    heavy_tail_df=heavy_tail_df)
