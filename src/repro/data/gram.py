"""Out-of-core Gram accumulation: row-blocks in, a (p, p) f64 Gram out.

HP-CONCORD only ever needs the sufficient statistic S = XᵀX/n (of
suitably transformed data), so tera-scale n never has to sit in memory:

    acc = GramAccumulator(transform="standardize")
    for chunk in source:            # (m_i, p) row-blocks, any dtype
        acc.update(chunk)
    result = acc.finalize()         # GramResult: S, n, stream stats
    ConcordEstimator(...).fit_gram(result)

Mechanics:

  * every panel product runs BLOCKED through the matops dispatch
    (``core.matops.panel_gram``) and accumulates in float64 regardless of
    the chunk dtype — a bf16/f32 shard stream still yields an f64 Gram;
  * column mean/variance stream alongside in ONE pass (Welford, with the
    Chan merge for chunk-at-a-time and ``merge()``), so ``center`` and
    ``standardize`` are applied *algebraically* at finalize — no second
    sweep ever happens for moment transforms;
  * the ``rank`` (nonparanormal) transform is order-based and uses the
    bounded two-pass mode (:func:`rank_gram`): ceil(p/panel) sweeps of a
    re-iterable source with O(n·panel) resident memory, a (n·p·8)-byte
    on-disk scratch memmap, then one streaming Gram pass over the scratch;
  * :func:`distributed_gram` is the multi-host twin: each host reduces its
    own shards to a partial (ΣXᵀX, Σx, Σx², n) image, and ONE ``psum``
    through ``comm/compat.py`` combines them — communication is O(p²)
    once, independent of n (the Arroyo-Hou reduce-to-sufficient-statistics
    pattern).
"""
from __future__ import annotations

import os
import tempfile
from typing import NamedTuple, Sequence

import numpy as np

from ..core.matops import panel_gram
from .shards import ChunkSource, as_source
from .transforms import StreamStats, Transform, get_transform

__all__ = [
    "GramAccumulator", "GramResult", "compute_gram", "distributed_gram",
    "rank_gram",
]

#: default column-panel edge for the blocked XᵀX products
DEFAULT_PANEL = 512

#: default resident-memory budget of the rank transform's column sweeps
RANK_BUDGET_BYTES = 256 * 1024 * 1024


class _NoSpan:
    """Do-nothing stand-in for a tracer span when obs is inactive."""
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def note(self, **attrs):
        return self


_NO_SPAN = _NoSpan()


def _obs_span(name: str, **attrs):
    """Tracer span IF the obs subsystem is active (``repro.obs.trace``
    already imported, mode scoped by the caller); the shared no-op
    otherwise — the data layer never imports ``repro.obs`` itself."""
    import sys
    tr = sys.modules.get("repro.obs.trace")
    if tr is None:
        return _NO_SPAN
    return tr.get_tracer().span(name, cat="data", level="trace", **attrs)


class GramResult(NamedTuple):
    """A finalized streaming Gram: the solver-ready sufficient statistic
    plus the stream statistics it was derived from."""
    s: np.ndarray           # (p, p) float64 Gram of the TRANSFORMED data
    n: int                  # rows streamed
    p: int
    transform: str          # transform name that produced s
    mean: np.ndarray        # (p,) f64 column means of the RAW stream
    var: np.ndarray         # (p,) f64 population variances of the raw stream
    n_chunks: int           # chunks consumed
    source_dtype: str       # dtype of the incoming chunks

    def to_meta(self) -> dict:
        """JSON-able metadata (everything but the arrays) for sidecar
        files written by ``launch/gram.py prep``."""
        return {
            "n": int(self.n), "p": int(self.p),
            "transform": self.transform,
            "n_chunks": int(self.n_chunks),
            "source_dtype": self.source_dtype,
            "gram_dtype": "float64",
            "mean_absmax": float(np.max(np.abs(self.mean))) if self.p else 0.0,
            "diag_mean": float(np.mean(np.diag(self.s))) if self.p else 0.0,
        }


class GramAccumulator:
    """Chunked one-pass Gram accumulator (moment transforms).

    ``update(chunk)`` streams an (m, p) row-block; ``finalize()`` returns
    the :class:`GramResult` under ``transform``.  State is O(p²) float64:
    the raw second-moment sum, running mean and M2 (Welford).  Order of
    chunks changes the result only at the usual f64 summation-order level
    (well inside the 1e-10 agreement the tests pin).

    The ``rank`` transform cannot accumulate one-pass (scores depend on
    global order statistics) — construct via :func:`compute_gram` /
    :func:`rank_gram` instead; passing it here raises.
    """

    def __init__(self, p: int | None = None, *,
                 transform: str | Transform = "none",
                 panel: int = DEFAULT_PANEL):
        self.transform = get_transform(transform)
        if self.transform.two_pass:
            raise ValueError(
                f"transform {self.transform.name!r} needs the two-pass "
                f"mode: use compute_gram(..., transform="
                f"{self.transform.name!r}) or rank_gram")
        if panel < 1:
            raise ValueError(f"panel must be >= 1, got {panel}")
        self.panel = int(panel)
        self.p = int(p) if p is not None else None
        self.n = 0
        self.n_chunks = 0
        self.source_dtype: str | None = None
        self._xx = self._mean = self._m2 = None
        if self.p is not None:
            self._alloc(self.p)

    def _alloc(self, p: int) -> None:
        self.p = p
        self._xx = np.zeros((p, p), np.float64)
        self._mean = np.zeros(p, np.float64)
        self._m2 = np.zeros(p, np.float64)

    def update(self, chunk) -> "GramAccumulator":
        """Fold one (m, p) row-block into the stream moments."""
        arr = np.asarray(chunk)
        if arr.ndim != 2:
            raise ValueError(f"chunk must be 2-D (rows, p), got {arr.shape}")
        if arr.shape[0] == 0:
            return self
        if self._xx is None:
            self._alloc(arr.shape[1])
        elif arr.shape[1] != self.p:
            raise ValueError(
                f"chunk has {arr.shape[1]} columns, accumulator is p={self.p}")
        if not np.all(np.isfinite(arr)):
            raise ValueError(
                f"chunk {self.n_chunks} contains non-finite values; refusing "
                f"to fold NaN/Inf into the Gram")
        self.source_dtype = self.source_dtype or arr.dtype.name
        with _obs_span("gram.chunk", chunk=self.n_chunks,
                       rows=int(arr.shape[0]), p=int(arr.shape[1])):
            a64 = np.ascontiguousarray(arr, np.float64)
            m = a64.shape[0]
            # blocked panel products through the matops dispatch, f64 always
            self._xx += np.asarray(panel_gram(a64, panel=self.panel))
            # Welford/Chan chunk merge of mean and M2
            cmean = a64.mean(axis=0)
            centered = a64 - cmean      # one chunk-sized temporary, reused
            cm2 = np.einsum("ij,ij->j", centered, centered)
            tot = self.n + m
            delta = cmean - self._mean
            self._mean += delta * (m / tot)
            self._m2 += cm2 + delta * delta * (self.n * m / tot)
            self.n = tot
            self.n_chunks += 1
        return self

    def merge(self, other: "GramAccumulator") -> "GramAccumulator":
        """Fold another accumulator's state in (pairwise Chan merge) —
        the host-side reduction used by :func:`distributed_gram`."""
        if other.n == 0:
            return self
        if self._xx is None:
            self._alloc(other.p)
        elif other.p != self.p:
            raise ValueError(f"cannot merge p={other.p} into p={self.p}")
        tot = self.n + other.n
        delta = other._mean - self._mean
        self._xx += other._xx
        self._mean += delta * (other.n / tot)
        self._m2 += other._m2 + delta * delta * (self.n * other.n / tot)
        self.n = tot
        self.n_chunks += other.n_chunks
        self.source_dtype = self.source_dtype or other.source_dtype
        return self

    def stats(self) -> StreamStats:
        if self.n == 0:
            raise ValueError("no rows accumulated")
        return StreamStats(n=self.n, mean=self._mean.copy(),
                           var=self._m2 / self.n, xx=self._xx)

    def finalize(self) -> GramResult:
        """Apply the transform algebraically and return the Gram."""
        st = self.stats()
        s = np.asarray(self.transform.finalize_gram(st), np.float64)
        s = 0.5 * (s + s.T)     # exact-symmetry insurance (BLAS panel
        #                         order could differ across the diagonal)
        return GramResult(
            s=s, n=st.n, p=self.p, transform=self.transform.name,
            mean=st.mean, var=st.var, n_chunks=self.n_chunks,
            source_dtype=self.source_dtype or "float64")


# ---------------------------------------------------------------------------
# two-pass rank / nonparanormal mode
# ---------------------------------------------------------------------------

def _count_rows(source: ChunkSource) -> int:
    if source.n_rows is not None:
        return int(source.n_rows)
    return sum(int(np.asarray(c).shape[0]) for c in source.chunks())


def rank_gram(data, *, panel: int = DEFAULT_PANEL,
              budget_bytes: int = RANK_BUDGET_BYTES,
              scratch_dir: str | None = None,
              chunk_rows: int | None = None) -> GramResult:
    """Bounded two-pass nonparanormal Gram (the ``rank`` transform).

    Memory contract (documented in the README): with w = the column-panel
    width fitted to ``budget_bytes`` (resident buffer is n·w f64 values),

      * pass 1: ceil(p / w) sweeps of the (re-iterable) source; sweep j
        loads only columns [jw, (j+1)w), rank-transforms each column, and
        writes the scores into an on-disk float64 scratch memmap — peak
        resident memory O(n·w), scratch disk n·p·8 bytes;
      * pass 2: one streaming :class:`GramAccumulator` pass over the
        scratch rows (O(p²) state), after which the scratch is deleted.

    One-shot iterators are rejected up front (``reiterable`` is required).
    """
    source = as_source(data, chunk_rows=chunk_rows)
    source.require_reiterable("the rank (nonparanormal) transform")
    from .transforms import rank_transform_column
    n = _count_rows(source)
    if n == 0:
        raise ValueError("empty source")
    first = next(iter(source.chunks()))
    p = np.asarray(first).shape[1]
    w = max(1, min(p, int(budget_bytes // max(n * 8, 1))))
    fd, scratch_path = tempfile.mkstemp(suffix=".rank.f64",
                                        dir=scratch_dir)
    os.close(fd)
    try:
        z = np.memmap(scratch_path, dtype=np.float64, mode="w+",
                      shape=(n, p))
        for lo in range(0, p, w):
            hi = min(lo + w, p)
            buf = np.empty((n, hi - lo), np.float64)
            row = 0
            for chunk in source.chunks():
                arr = np.asarray(chunk)
                if not np.all(np.isfinite(arr[:, lo:hi])):
                    raise ValueError(
                        "non-finite values in stream; refusing to rank")
                buf[row:row + arr.shape[0]] = arr[:, lo:hi]
                row += arr.shape[0]
            if row != n:
                raise ValueError(
                    f"re-iteration returned {row} rows, first sweep saw {n} "
                    f"(source is not stable across sweeps)")
            for j in range(hi - lo):
                buf[:, j] = rank_transform_column(buf[:, j])
            z[:, lo:hi] = buf
        z.flush()
        acc = GramAccumulator(p, transform="none")
        rows = chunk_rows or max(1, int(budget_bytes // max(p * 8, 1)))
        for lo in range(0, n, rows):
            acc.update(z[lo:lo + rows])
        res = acc.finalize()
    finally:
        try:
            del z
        except NameError:
            pass
        os.unlink(scratch_path)
    return res._replace(transform="rank",
                        source_dtype=np.asarray(first).dtype.name)


# ---------------------------------------------------------------------------
# front door + distributed twin
# ---------------------------------------------------------------------------

def compute_gram(data, *, transform: str | Transform = "none",
                 chunk_rows: int | None = None,
                 panel: int = DEFAULT_PANEL, **rank_kw) -> GramResult:
    """Stream any chunk-like input (array, iterator, shard paths, factory —
    see ``shards.as_source``) into a :class:`GramResult` under
    ``transform``.  Dispatches to the one-pass accumulator for moment
    transforms and to :func:`rank_gram` for order-based ones."""
    tf = get_transform(transform)
    if tf.two_pass:
        return rank_gram(data, panel=panel, chunk_rows=chunk_rows, **rank_kw)
    source = as_source(data, chunk_rows=chunk_rows)
    acc = GramAccumulator(source.p, transform=tf, panel=panel)
    for chunk in source.chunks():
        acc.update(chunk)
    return acc.finalize()


def _psum_moments(xx_l, s1_l, s2_l, n_l):
    """All-reduce the per-host raw-moment images over the 1-axis mesh.

    The one communication step of the streaming pipeline — routed through
    ``comm/compat.py`` like every collective outside the 1.5D layer."""
    from ..comm.compat import psum
    return (psum(xx_l, "hosts"), psum(s1_l, "hosts"),
            psum(s2_l, "hosts"), psum(n_l, "hosts"))


def distributed_gram(per_host_data: Sequence, *,
                     transform: str | Transform = "none",
                     chunk_rows: int | None = None,
                     panel: int = DEFAULT_PANEL) -> GramResult:
    """Multi-host streaming Gram: one chunk source per device, reduced
    with ONE ``psum`` through the ``comm/compat.py`` shims.

    Each host folds its own shards into a partial accumulator (no
    communication), the partial raw-moment images (ΣXᵀX, Σx, Σ(x-μ)²+nμ²,
    n) are stacked over a 1-axis mesh, and a single f32/f64 psum yields
    the global moments — total traffic O(p²) per host, independent of n.
    The rank transform is order-based across ALL hosts' rows and cannot be
    reduced this way; it raises.
    """
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from ..comm.compat import make_mesh, shard_map, use_mesh

    tf = get_transform(transform)
    if tf.two_pass:
        raise ValueError(
            f"transform {tf.name!r} is order-based across all hosts' rows "
            f"and cannot be psum-reduced; rank-transform the consolidated "
            f"stream via rank_gram instead")
    if not per_host_data:
        raise ValueError("no per-host sources")
    accs = [GramAccumulator(transform=tf, panel=panel) for _ in per_host_data]
    for acc, data in zip(accs, per_host_data):
        source = as_source(data, chunk_rows=chunk_rows)
        for chunk in source.chunks():
            acc.update(chunk)
    ps = {a.p for a in accs if a.p is not None}
    if len(ps) != 1:
        raise ValueError(f"hosts saw inconsistent column counts {ps}")
    p = ps.pop()

    n_dev = len(per_host_data)
    devices = jax.devices()
    if n_dev > len(devices):
        raise ValueError(
            f"{n_dev} per-host sources but only {len(devices)} devices")
    if not jax.config.jax_enable_x64:
        # the wire format must be f64 to preserve the accumulator's f64
        # contract (the paper's runs are double precision); without x64
        # the psum would silently truncate, so reduce host-side instead
        merged = accs[0]
        for a in accs[1:]:
            merged.merge(a)
        return merged.finalize()
    # raw-moment images: Welford state -> psum-able sums (exact in f64;
    # the one lossy step is this final merge, same as any tree reduction)
    xx = np.stack([a._xx for a in accs])
    s1 = np.stack([a._mean * a.n for a in accs])
    s2 = np.stack([a._m2 + a._mean ** 2 * a.n for a in accs])
    cnt = np.asarray([[float(a.n)] for a in accs])
    mesh = make_mesh((n_dev,), ("hosts",), devices=devices[:n_dev])

    with use_mesh(mesh):
        fn = shard_map(_psum_moments, mesh=mesh,
                       in_specs=(P("hosts"), P("hosts"), P("hosts"),
                                 P("hosts")),
                       out_specs=(P(), P(), P(), P()))
        g_xx, g_s1, g_s2, g_n = fn(
            jnp.asarray(xx, jnp.float64), jnp.asarray(s1, jnp.float64),
            jnp.asarray(s2, jnp.float64), jnp.asarray(cnt, jnp.float64))
    n = int(round(float(np.asarray(g_n)[0])))
    mean = np.asarray(g_s1, np.float64)[0] / n
    var = np.asarray(g_s2, np.float64)[0] / n - mean ** 2
    st = StreamStats(n=n, mean=mean, var=np.maximum(var, 0.0),
                     xx=np.asarray(g_xx, np.float64)[0])
    s = np.asarray(tf.finalize_gram(st), np.float64)
    s = 0.5 * (s + s.T)
    return GramResult(
        s=s, n=n, p=p, transform=tf.name, mean=st.mean, var=st.var,
        n_chunks=sum(a.n_chunks for a in accs),
        source_dtype=accs[0].source_dtype or "float64")


# ---------------------------------------------------------------------------
# analysis manifest (repro.analysis.jaxprpass)
# ---------------------------------------------------------------------------

def _analysis_panel_gram():
    import jax.numpy as jnp
    x = jnp.linspace(0.0, 1.0, 48, dtype=jnp.float64).reshape(6, 8)
    return {"fn": lambda xx: panel_gram(xx, panel=4), "args": (x,)}


def _analysis_distributed_reduce():
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from ..comm.compat import make_mesh, shard_map, use_mesh
    mesh = make_mesh((1,), ("hosts",), devices=jax.devices()[:1])
    fn = shard_map(_psum_moments, mesh=mesh, in_specs=(P("hosts"),) * 4,
                   out_specs=(P(),) * 4)
    p = 4
    return {
        "fn": fn,
        "args": (jnp.zeros((1, p, p), jnp.float64),
                 jnp.zeros((1, p), jnp.float64),
                 jnp.zeros((1, p), jnp.float64),
                 jnp.zeros((1, 1), jnp.float64)),
        "ctx": lambda: use_mesh(mesh),
    }


#: the f64 compute core of every streamed Gram, and the one-psum reduce
ANALYSIS_ENTRIES = [
    {"name": "data.gram.panel_gram", "path": "src/repro/core/matops.py",
     "axis_names": (), "build": _analysis_panel_gram},
    {"name": "data.gram.distributed_reduce",
     "path": "src/repro/data/gram.py", "axis_names": ("hosts",),
     "build": _analysis_distributed_reduce},
]
