"""repro.data — the streaming data/Gram subsystem.

HP-CONCORD consumes the Gram matrix S = XᵀX/n, never X itself; this
package turns arbitrarily large row-streams of X into that (p, p)
sufficient statistic with bounded memory, plus the synthetic worlds to
exercise it:

  shards      chunk sources: in-memory arrays, iterators, memory-mapped
              ``.npy``/raw shard files — one ``ChunkSource`` protocol
  transforms  pluggable per-chunk transforms (``none``/``center``/
              ``standardize`` one-pass via streamed moments; ``rank`` —
              the nonparanormal transform — bounded two-pass)
  gram        ``GramAccumulator`` (chunked, f64, Welford one-pass stats),
              ``compute_gram`` front door, ``distributed_gram`` (one psum
              through ``comm/compat``)
  scenarios   ≥5 graph families as (Ω_true, seeded chunked sampler)
              pairs with exact controlled condition number

    from repro.data import compute_gram, make_scenario
    sc = make_scenario("scale_free", p=512, cond=20.0)
    g = compute_gram(sc.source(n=1_000_000), transform="standardize")
    ConcordEstimator(lam1=0.15).fit_gram(g)
"""
from .gram import (  # noqa: F401
    GramAccumulator,
    GramResult,
    compute_gram,
    distributed_gram,
    rank_gram,
)
from .scenarios import (  # noqa: F401
    SCENARIO_FAMILIES,
    Scenario,
    available_families,
    make_scenario,
    register_family,
)
from .shards import (  # noqa: F401
    ChunkSource,
    as_source,
    open_shards,
    write_shards,
)
from .transforms import (  # noqa: F401
    StreamStats,
    Transform,
    available_transforms,
    get_transform,
    register_transform,
)

__all__ = [
    "ChunkSource",
    "GramAccumulator",
    "GramResult",
    "SCENARIO_FAMILIES",
    "Scenario",
    "StreamStats",
    "Transform",
    "as_source",
    "available_families",
    "available_transforms",
    "compute_gram",
    "distributed_gram",
    "get_transform",
    "make_scenario",
    "open_shards",
    "rank_gram",
    "register_family",
    "register_transform",
    "write_shards",
]
