"""Pluggable per-chunk transforms for the streaming Gram pipeline.

A transform decides what matrix the Gram is taken OF, without the pipeline
ever materializing that matrix:

  ``none``         S = XᵀX / n                     (raw second moment)
  ``center``       S = (X-μ)ᵀ(X-μ) / n            (covariance)
  ``standardize``  S = correlation matrix          (center + unit scale)
  ``rank``         S = ZᵀZ / n with z_ij = Φ⁻¹((rank_j(x_ij)-½)/n), each
                   column rescaled to unit variance — the nonparanormal /
                   Spearman-via-ranks transform backing CONCORD's
                   "no Gaussianity assumed" claim: S is invariant under
                   ANY strictly monotone distortion of the marginals.

``none``/``center``/``standardize`` are *moment transforms*: the
accumulator streams raw f64 moments (Welford mean/variance + ΣXᵀX) in ONE
pass and the transform is applied algebraically at ``finalize()`` —
standardization never needs a second sweep:

    S_center = ΣXᵀX/n − μμᵀ          S_std[i,j] = S_center[i,j]/(σ_i σ_j)

``rank`` is genuinely order-based and needs a bounded TWO-PASS mode (see
``gram.rank_gram``).  Memory contract: ceil(p / panel) sweeps of the
source build the per-column rank transform with O(n_rows · panel) resident
f64 values per sweep; the transformed columns go to an on-disk scratch
memmap (n·p·8 bytes) that the final streaming Gram pass reads back.  The
source must be re-iterable (``ChunkSource.reiterable``).

``register_transform`` lets downstream code plug in new names without
touching the accumulator.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, NamedTuple

import numpy as np

try:                                    # scipy ships with jax; f64 ndtri
    from scipy.special import ndtri as _ndtri
except ImportError:                     # pragma: no cover - minimal envs
    def _ndtri(q):
        import jax.numpy as jnp
        from jax.scipy.special import ndtri
        # exact f64 quantiles under x64; without x64 jax's canonical f32
        # ceiling applies (never a hard-coded narrow cast: forcing f32
        # here used to truncate even when x64 was on)
        return np.asarray(ndtri(jnp.asarray(np.asarray(q, np.float64))),
                          np.float64)

__all__ = [
    "StreamStats", "Transform", "available_transforms", "average_ranks",
    "get_transform", "rank_transform_column", "register_transform",
]

#: columns with population std below this are treated as constant (scale 1)
#: by ``standardize`` so a degenerate column cannot NaN the whole Gram.
STD_FLOOR = 1e-12


class StreamStats(NamedTuple):
    """One-pass f64 stream moments of the raw data (the accumulator's
    finalized state): everything a moment transform needs."""
    n: int                  # rows seen
    mean: np.ndarray        # (p,) column means
    var: np.ndarray         # (p,) population variances (M2 / n)
    xx: np.ndarray          # (p, p) RAW second-moment sum  Σ xᵀx  (not /n)

    @property
    def std(self) -> np.ndarray:
        sd = np.sqrt(np.maximum(self.var, 0.0))
        return np.where(sd < STD_FLOOR, 1.0, sd)


@dataclass(frozen=True)
class Transform:
    """A named Gram transform.

    ``finalize_gram(stats)`` turns one-pass stream moments into the (p, p)
    Gram of the transformed data (moment transforms only — ``two_pass``
    transforms raise here and are handled by ``gram.rank_gram``).
    ``apply(chunk, stats)`` maps a raw chunk into transformed coordinates
    given full-data stats (for scoring new data with training statistics).
    """
    name: str
    two_pass: bool = False
    _finalize: Callable | None = None
    _apply: Callable | None = None

    def finalize_gram(self, stats: StreamStats) -> np.ndarray:
        if self.two_pass or self._finalize is None:
            raise ValueError(
                f"transform {self.name!r} is order-based (two-pass); "
                f"stream it through gram.rank_gram / compute_gram, not "
                f"GramAccumulator.finalize")
        return self._finalize(stats)

    def apply(self, chunk, stats: StreamStats) -> np.ndarray:
        if self._apply is None:
            raise ValueError(
                f"transform {self.name!r} has no per-chunk application "
                f"(rank scores depend on the whole sample, not one chunk)")
        return self._apply(np.asarray(chunk, np.float64), stats)


# ---------------------------------------------------------------------------
# moment transforms
# ---------------------------------------------------------------------------

def _finalize_none(st: StreamStats) -> np.ndarray:
    return st.xx / st.n


def _finalize_center(st: StreamStats) -> np.ndarray:
    return st.xx / st.n - np.outer(st.mean, st.mean)


def _finalize_standardize(st: StreamStats) -> np.ndarray:
    sd = st.std
    return _finalize_center(st) / np.outer(sd, sd)


# ---------------------------------------------------------------------------
# rank / nonparanormal
# ---------------------------------------------------------------------------

def average_ranks(col: np.ndarray) -> np.ndarray:
    """Average ranks in [1, n] with ties sharing their group mean (the
    Spearman convention); pure numpy, exact."""
    col = np.asarray(col)
    _, inv, counts = np.unique(col, return_inverse=True, return_counts=True)
    ends = np.cumsum(counts)
    avg = (ends - counts + 1 + ends) / 2.0
    return avg[inv]


def rank_transform_column(col: np.ndarray) -> np.ndarray:
    """Nonparanormal scores of one column: z = Φ⁻¹((rank - ½)/n), rescaled
    to exactly unit population variance (so the Gram has unit diagonal and
    Spearman-like off-diagonals).  Depends on the ORDER of the values only.
    """
    n = col.shape[0]
    z = _ndtri((average_ranks(col) - 0.5) / n).astype(np.float64)
    sd = float(np.sqrt(np.mean(z * z) - np.mean(z) ** 2))
    if sd < STD_FLOOR:          # all-tied column -> all-zero scores
        return np.zeros_like(z)
    return z / sd


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, Transform] = {}


def register_transform(tf: Transform, *, overwrite: bool = False) -> None:
    if not overwrite and tf.name in _REGISTRY:
        raise ValueError(f"transform {tf.name!r} already registered")
    _REGISTRY[tf.name] = tf


def get_transform(name: str | Transform) -> Transform:
    if isinstance(name, Transform):
        return name
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown transform {name!r}; available: "
            f"{available_transforms()}") from None


def available_transforms() -> list[str]:
    return sorted(_REGISTRY)


register_transform(Transform(
    "none", _finalize=_finalize_none,
    _apply=lambda c, st: c))
register_transform(Transform(
    "center", _finalize=_finalize_center,
    _apply=lambda c, st: c - st.mean))
register_transform(Transform(
    "standardize", _finalize=_finalize_standardize,
    _apply=lambda c, st: (c - st.mean) / st.std))
register_transform(Transform("rank", two_pass=True))
