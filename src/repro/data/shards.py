"""Chunk sources: one interface over every way row-blocks of X can arrive.

The streaming Gram pipeline (``data.gram``) never wants the full (n, p)
observation matrix — only successive row-blocks ("chunks") of it.  This
module normalizes the four ways callers hold such data into one
:class:`ChunkSource` protocol:

  * an in-memory (n, p) array            -> :class:`ArraySource`
  * a generator / iterator of chunks     -> :class:`IterSource` (one-shot)
  * a zero-arg factory of fresh iters    -> :class:`CallableSource`
  * ``.npy`` shard files on disk         -> :class:`NpyShardSource`
    (memory-mapped; rows stream without ever loading a shard whole)
  * raw binary shards + explicit dtype/p -> :class:`RawShardSource`

``as_source(obj)`` dispatches; everything downstream (the accumulator,
the two-pass rank transform, the CLI) talks only to the protocol:

    src.chunks()    -> iterator of (m_i, p) numpy arrays
    src.p           -> column count (None until known for one-shot iters)
    src.n_rows      -> total rows when knowable upfront, else None
    src.reiterable  -> True when ``chunks()`` may be called again
                       (required by two-pass transforms, e.g. rank)

Chunks are yielded as numpy views/arrays in their stored dtype; the
consumer owns the f64 upcast (``GramAccumulator`` always accumulates in
float64 regardless of chunk dtype).
"""
from __future__ import annotations

import json
import os
from typing import Iterable, Iterator, Sequence

import numpy as np

__all__ = [
    "ArraySource", "CallableSource", "ChunkSource", "IterSource",
    "NpyShardSource", "RawShardSource", "as_source", "is_streaming_input",
    "open_shards", "write_shards",
]

DEFAULT_CHUNK_ROWS = 4096

#: sidecar filename written next to raw binary shards (dtype/p metadata)
RAW_META = "shards_meta.json"


class ChunkSource:
    """Protocol base: iterate row-blocks of a conceptual (n, p) matrix."""

    reiterable: bool = False

    @property
    def p(self) -> int | None:
        raise NotImplementedError

    @property
    def n_rows(self) -> int | None:
        return None

    def chunks(self) -> Iterator[np.ndarray]:
        raise NotImplementedError

    def require_reiterable(self, what: str) -> None:
        if not self.reiterable:
            raise ValueError(
                f"{what} needs a re-iterable chunk source (an array, a "
                f"chunk list, shard files, or a zero-arg factory) — a "
                f"one-shot iterator can only be swept once")


def _check_chunk(chunk, p: int | None) -> np.ndarray:
    arr = np.asarray(chunk)
    if arr.ndim != 2:
        raise ValueError(f"chunks must be 2-D (rows, p), got {arr.shape}")
    if p is not None and arr.shape[1] != p:
        raise ValueError(f"chunk has {arr.shape[1]} columns, expected {p}")
    return arr


class ArraySource(ChunkSource):
    """Row-block view over an in-memory (or memory-mapped) (n, p) array."""

    reiterable = True

    def __init__(self, x, chunk_rows: int = DEFAULT_CHUNK_ROWS):
        self._x = np.asarray(x) if not isinstance(x, np.memmap) else x
        if self._x.ndim != 2:
            raise ValueError(f"x must be 2-D (n, p), got {self._x.shape}")
        if chunk_rows < 1:
            raise ValueError(f"chunk_rows must be >= 1, got {chunk_rows}")
        self._rows = int(chunk_rows)

    @property
    def p(self) -> int:
        return self._x.shape[1]

    @property
    def n_rows(self) -> int:
        return self._x.shape[0]

    def chunks(self) -> Iterator[np.ndarray]:
        for lo in range(0, self._x.shape[0], self._rows):
            yield self._x[lo:lo + self._rows]


class IterSource(ChunkSource):
    """One-shot wrap of an iterator/generator of (m, p) chunks."""

    reiterable = False

    def __init__(self, it: Iterable):
        self._it = iter(it)
        self._consumed = False
        self._p: int | None = None

    @property
    def p(self) -> int | None:
        return self._p

    def chunks(self) -> Iterator[np.ndarray]:
        if self._consumed:
            raise ValueError("one-shot chunk iterator already consumed")
        self._consumed = True
        for chunk in self._it:
            arr = _check_chunk(chunk, self._p)
            self._p = arr.shape[1]
            yield arr


class CallableSource(ChunkSource):
    """Re-iterable source from a zero-arg factory of fresh chunk iterators
    (e.g. a seeded scenario sampler, or ``lambda: read_rows(path)``)."""

    reiterable = True

    def __init__(self, factory, p: int | None = None,
                 n_rows: int | None = None):
        if not callable(factory):
            raise TypeError(f"factory must be callable, got {factory!r}")
        self._factory = factory
        self._p = p
        self._n = n_rows

    @property
    def p(self) -> int | None:
        return self._p

    @property
    def n_rows(self) -> int | None:
        return self._n

    def chunks(self) -> Iterator[np.ndarray]:
        for chunk in self._factory():
            arr = _check_chunk(chunk, self._p)
            self._p = arr.shape[1]
            yield arr


class _FileShardSource(ChunkSource):
    """Shared row-streaming over a list of per-shard (n_i, p) arrays."""

    reiterable = True

    def __init__(self, chunk_rows: int = DEFAULT_CHUNK_ROWS):
        if chunk_rows < 1:
            raise ValueError(f"chunk_rows must be >= 1, got {chunk_rows}")
        self._rows = int(chunk_rows)

    def _open(self) -> Iterator[np.ndarray]:
        raise NotImplementedError

    def chunks(self) -> Iterator[np.ndarray]:
        for arr in self._open():
            for lo in range(0, arr.shape[0], self._rows):
                yield arr[lo:lo + self._rows]


class NpyShardSource(_FileShardSource):
    """Memory-mapped ``.npy`` shards, each holding (n_i, p) rows."""

    def __init__(self, paths: Sequence[str | os.PathLike],
                 chunk_rows: int = DEFAULT_CHUNK_ROWS):
        super().__init__(chunk_rows)
        self._paths = [os.fspath(p) for p in paths]
        if not self._paths:
            raise ValueError("no shard paths given")
        head = np.load(self._paths[0], mmap_mode="r")
        if head.ndim != 2:
            raise ValueError(
                f"shard {self._paths[0]} is {head.ndim}-D, want (rows, p)")
        self._p = int(head.shape[1])
        self._n = None

    @property
    def p(self) -> int:
        return self._p

    @property
    def n_rows(self) -> int | None:
        if self._n is None:
            self._n = sum(
                int(np.load(pa, mmap_mode="r").shape[0])
                for pa in self._paths)
        return self._n

    def _open(self) -> Iterator[np.ndarray]:
        for pa in self._paths:
            arr = np.load(pa, mmap_mode="r")
            _check_chunk(arr, self._p)
            yield arr


class RawShardSource(_FileShardSource):
    """Raw little-endian binary shards (row-major), dtype/p given
    explicitly or read from the ``shards_meta.json`` sidecar."""

    def __init__(self, paths: Sequence[str | os.PathLike], *,
                 p: int, dtype="float32",
                 chunk_rows: int = DEFAULT_CHUNK_ROWS):
        super().__init__(chunk_rows)
        self._paths = [os.fspath(pa) for pa in paths]
        if not self._paths:
            raise ValueError("no shard paths given")
        self._p = int(p)
        self._dtype = np.dtype(dtype)
        itemrow = self._p * self._dtype.itemsize
        for pa in self._paths:
            if os.path.getsize(pa) % itemrow:
                raise ValueError(
                    f"raw shard {pa} size is not a multiple of one row "
                    f"({self._p} x {self._dtype})")

    @property
    def p(self) -> int:
        return self._p

    @property
    def n_rows(self) -> int:
        itemrow = self._p * self._dtype.itemsize
        return sum(os.path.getsize(pa) // itemrow for pa in self._paths)

    def _open(self) -> Iterator[np.ndarray]:
        for pa in self._paths:
            yield np.memmap(pa, dtype=self._dtype, mode="r"
                            ).reshape(-1, self._p)


def write_shards(x, out_dir: str | os.PathLike, *,
                 rows_per_shard: int = 65536, raw: bool = False,
                 prefix: str = "shard") -> list[str]:
    """Split an (n, p) array into shard files under ``out_dir``.

    ``raw=False`` writes ``.npy`` shards (self-describing); ``raw=True``
    writes flat binary plus a ``shards_meta.json`` sidecar recording
    dtype/p so :func:`open_shards` can reopen them.  Returns the paths.
    """
    x = np.asarray(x)
    if x.ndim != 2:
        raise ValueError(f"x must be 2-D, got {x.shape}")
    os.makedirs(out_dir, exist_ok=True)
    paths = []
    for i, lo in enumerate(range(0, x.shape[0], rows_per_shard)):
        block = x[lo:lo + rows_per_shard]
        ext = "bin" if raw else "npy"
        path = os.path.join(os.fspath(out_dir), f"{prefix}_{i:05d}.{ext}")
        if raw:
            np.ascontiguousarray(block).tofile(path)
        else:
            np.save(path, block)
        paths.append(path)
    if raw:
        meta = {"p": int(x.shape[1]), "dtype": x.dtype.name,
                "rows_per_shard": int(rows_per_shard)}
        with open(os.path.join(os.fspath(out_dir), RAW_META), "w") as f:
            json.dump(meta, f)
    return paths


def open_shards(paths_or_dir, *,
                chunk_rows: int = DEFAULT_CHUNK_ROWS) -> ChunkSource:
    """Open ``.npy``/raw shards as a re-iterable source.  Accepts a
    directory (all shards inside, sorted) or an explicit path list; raw
    shards need the ``shards_meta.json`` sidecar next to them."""
    if isinstance(paths_or_dir, (str, os.PathLike)) \
            and os.path.isdir(paths_or_dir):
        d = os.fspath(paths_or_dir)
        names = sorted(os.listdir(d))
        paths = [os.path.join(d, nm) for nm in names
                 if nm.endswith((".npy", ".bin"))]
    else:
        paths = [os.fspath(p) for p in (
            [paths_or_dir] if isinstance(paths_or_dir, (str, os.PathLike))
            else paths_or_dir)]
    if not paths:
        raise ValueError(f"no shard files in {paths_or_dir!r}")
    n_npy = sum(p.endswith(".npy") for p in paths)
    if 0 < n_npy < len(paths):
        # a stray .npy parsed as raw binary would fold its header bytes
        # into the Gram as a garbage data row — refuse mixed sets
        raise ValueError(
            f"mixed shard formats in {paths_or_dir!r} ({n_npy} .npy of "
            f"{len(paths)} files); a shard set must be all .npy or all raw")
    if n_npy == len(paths):
        return NpyShardSource(paths, chunk_rows=chunk_rows)
    meta_path = os.path.join(os.path.dirname(paths[0]), RAW_META)
    if not os.path.exists(meta_path):
        raise ValueError(
            f"raw shards need a {RAW_META} sidecar (see write_shards)")
    with open(meta_path) as f:
        meta = json.load(f)
    return RawShardSource(paths, p=meta["p"], dtype=meta["dtype"],
                          chunk_rows=chunk_rows)


def is_streaming_input(data) -> bool:
    """True when ``data`` is chunk-stream-shaped rather than one (n, p)
    matrix: a ChunkSource, shard path(s), a factory, or a generator/
    iterator.  Arrays (anything with ``__array__``) and nested lists are
    NOT streams — they take the in-memory path."""
    if isinstance(data, (ChunkSource, str, os.PathLike)) or callable(data):
        return True
    if hasattr(data, "__array__") or isinstance(data, (list, tuple)):
        return False
    return isinstance(data, Iterable)


def as_source(data, *, chunk_rows: int | None = None) -> ChunkSource:
    """Normalize anything chunk-like into a :class:`ChunkSource`.

    Arrays (numpy/jax, anything with ``__array__``) become re-iterable
    row-block views; shard paths open memory-mapped; callables become
    re-iterable factories; lists of 2-D arrays become re-iterable chunk
    lists; any other iterable is wrapped one-shot.  ``chunk_rows=None``
    means :data:`DEFAULT_CHUNK_ROWS` (explicit 0/negative values are
    rejected by the sources, not silently defaulted).
    """
    if chunk_rows is None:
        chunk_rows = DEFAULT_CHUNK_ROWS
    if isinstance(data, ChunkSource):
        return data
    if isinstance(data, (str, os.PathLike)):
        return open_shards(data, chunk_rows=chunk_rows)
    if callable(data):
        return CallableSource(data)
    if hasattr(data, "__array__") or isinstance(data, np.ndarray):
        return ArraySource(data, chunk_rows=chunk_rows)
    if isinstance(data, (list, tuple)):
        if data and all(isinstance(c, (str, os.PathLike)) for c in data):
            return open_shards(list(data), chunk_rows=chunk_rows)
        chunk_list = [_check_chunk(c, None) for c in data]
        for c in chunk_list[1:]:
            _check_chunk(c, chunk_list[0].shape[1])
        return CallableSource(lambda: iter(chunk_list),
                              p=chunk_list[0].shape[1] if chunk_list else None,
                              n_rows=sum(c.shape[0] for c in chunk_list))
    if isinstance(data, Iterable):
        return IterSource(data)
    raise TypeError(
        f"cannot interpret {type(data).__name__} as a chunk source: want "
        f"an (n, p) array, an iterator of chunks, a chunk-list, shard "
        f"paths, or a callable factory")
