"""Synthetic ground-truth graphs, Gaussian/non-Gaussian sampling, recovery metrics.

Mirrors Section 4 of the paper: banded (chain, avg degree 2) and random
(Erdos-Renyi, avg degree ~60 at paper scale) strictly diagonally dominant
Omega^0, Gaussian samples X with cov = (Omega^0)^{-1}, and PPV/FDR support
metrics (Table 1).
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np


class Problem(NamedTuple):
    omega0: np.ndarray     # ground-truth inverse covariance (p, p)
    x: np.ndarray          # samples (n, p)
    s: np.ndarray          # sample covariance X^T X / n (p, p)


def chain_omega(p: int, *, weight: float = 0.4, dtype=np.float32) -> np.ndarray:
    """Tridiagonal (chain graph) strictly diagonally dominant Omega^0."""
    omega = np.eye(p, dtype=dtype)
    idx = np.arange(p - 1)
    omega[idx, idx + 1] = weight
    omega[idx + 1, idx] = weight
    return omega


def random_omega(
    p: int, *, avg_degree: int = 6, weight_scale: float = 0.3,
    seed: int = 0, dtype=np.float32,
) -> np.ndarray:
    """Erdos-Renyi graph with expected degree `avg_degree`, diagonally dominant."""
    rng = np.random.default_rng(seed)
    prob = min(1.0, avg_degree / max(p - 1, 1))
    upper = np.triu(rng.random((p, p)) < prob, k=1)
    signs = rng.choice([-1.0, 1.0], size=(p, p))
    mags = rng.uniform(0.5, 1.0, size=(p, p)) * weight_scale
    w = np.where(upper, signs * mags, 0.0)
    w = w + w.T
    # strict diagonal dominance => positive definite
    diag = np.abs(w).sum(axis=1) + 1.0
    omega = w + np.diag(diag)
    return omega.astype(dtype)


def sample_gaussian(omega0: np.ndarray, n: int, *, seed: int = 0) -> np.ndarray:
    """X ~ N(0, Sigma) with Sigma = inv(Omega^0), via cholesky solve.

    If Omega0 = L L^T then X = Z @ inv(L)^T has cov inv(Omega0).
    """
    rng = np.random.default_rng(seed)
    p = omega0.shape[0]
    chol = np.linalg.cholesky(omega0.astype(np.float64))
    z = rng.standard_normal((n, p))
    # solve L^T y^T = z^T  =>  y = z @ inv(L)^T
    x = np.linalg.solve(chol.T, z.T).T
    return x.astype(omega0.dtype)


def sample_nongaussian(omega0: np.ndarray, n: int, *, seed: int = 0,
                       df: float = 5.0) -> np.ndarray:
    """Multivariate-t style heavy-tailed samples with the same precision
    structure — exercises CONCORD's pseudolikelihood robustness claim."""
    rng = np.random.default_rng(seed)
    g = sample_gaussian(omega0, n, seed=seed)
    chi = rng.chisquare(df, size=(n, 1)) / df
    return (g / np.sqrt(chi)).astype(omega0.dtype)


def make_problem(kind: str, p: int, n: int, *, seed: int = 0,
                 avg_degree: int = 6, gaussian: bool = True) -> Problem:
    if kind == "chain":
        omega0 = chain_omega(p)
    elif kind == "random":
        omega0 = random_omega(p, avg_degree=avg_degree, seed=seed)
    else:
        raise ValueError(f"unknown graph kind {kind!r}")
    sampler = sample_gaussian if gaussian else sample_nongaussian
    x = sampler(omega0, n, seed=seed + 1)
    s = (x.T @ x / n).astype(omega0.dtype)
    return Problem(omega0=omega0, x=x, s=s)


# ---------------------------------------------------------------------------
# Support-recovery metrics (paper Table 1)
# ---------------------------------------------------------------------------

def support(omega: np.ndarray, *, tol: float = 0.0) -> np.ndarray:
    """Boolean off-diagonal support (upper triangle)."""
    a = np.abs(np.asarray(omega))
    mask = np.triu(np.ones_like(a, dtype=bool), k=1)
    return (a > tol) & mask


def ppv_fdr(est: np.ndarray, truth: np.ndarray, *, tol: float = 1e-8):
    """Positive predictive value and false discovery rate of edge recovery."""
    e, t = support(est, tol=tol), support(truth)
    tp = np.sum(e & t)
    fp = np.sum(e & ~t)
    denom = max(tp + fp, 1)
    ppv = tp / denom
    return float(ppv), float(1.0 - ppv)


def edge_count(omega: np.ndarray, *, tol: float = 1e-8) -> int:
    return int(np.sum(support(omega, tol=tol)))


def avg_degree(omega: np.ndarray, *, tol: float = 1e-8) -> float:
    p = omega.shape[0]
    return 2.0 * edge_count(omega, tol=tol) / p
