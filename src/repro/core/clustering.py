"""Graph clustering pipeline for the fMRI case-study analogue (paper Sec. 5).

Two clustering methods operating on the partial-correlation graph given by the
sparsity pattern of an HP-CONCORD estimate:

  * ``persistence_watershed``: the persistent-homology method of S.3.4 —
    map vertex degree onto a spatial topology graph (the paper uses the
    cortical-surface triangulation; we use any neighbor graph, e.g. a 2D
    grid), run a watershed sweep from high to low degree, build the dual
    label graph with persistence values on merge edges, and merge parcels
    whose persistence is <= eps.

  * ``label_propagation``: the Louvain-stand-in — asynchronous label
    propagation maximizing local agreement (no external deps).

Plus the modified Jaccard similarity of S.3.5 (maximum-weight bipartite
matching via scipy + greedy edge-cover completion for unmatched clusters).
"""
from __future__ import annotations

import numpy as np


def degrees_from_support(support: np.ndarray) -> np.ndarray:
    """Vertex degrees of the partial-correlation graph (symmetric support)."""
    a = np.asarray(support, dtype=bool)
    a = a | a.T
    np.fill_diagonal(a, False)
    return a.sum(axis=1)


def grid_neighbors(rows: int, cols: int) -> list[list[int]]:
    """4-neighborhood topology for variables laid out on a rows x cols grid
    (the synthetic analogue of the cortical-surface triangulation)."""
    nbrs: list[list[int]] = []
    for r in range(rows):
        for c in range(cols):
            cur = []
            if r > 0:
                cur.append((r - 1) * cols + c)
            if r < rows - 1:
                cur.append((r + 1) * cols + c)
            if c > 0:
                cur.append(r * cols + c - 1)
            if c < cols - 1:
                cur.append(r * cols + c + 1)
            nbrs.append(cur)
    return nbrs


def persistence_watershed(f: np.ndarray, neighbors: list[list[int]],
                          eps: float = 0.0) -> np.ndarray:
    """Watershed of scalar field `f` on a topology graph + persistence merging.

    Sweeps vertices from highest to lowest f. A vertex with no labeled
    neighbor starts a new label (a local max); otherwise it takes the label
    of the neighbor whose component has the highest birth value. When two
    components first meet at vertex v, the merge edge gets persistence
    min(birth_1, birth_2) - f(v); components joined by persistence <= eps are
    merged (union-find over the dual graph).
    """
    f = np.asarray(f, dtype=np.float64)
    n = f.shape[0]
    order = np.argsort(-f, kind="stable")
    labels = -np.ones(n, dtype=np.int64)
    birth: list[float] = []

    parent: list[int] = []

    def find(a: int) -> int:
        while parent[a] != a:
            parent[a] = parent[parent[a]]
            a = parent[a]
        return a

    comp_max: list[float] = []

    for v in order:
        lab_nbrs = {find(labels[u]) for u in neighbors[v] if labels[u] >= 0}
        if not lab_nbrs:
            lab = len(birth)
            birth.append(f[v])
            parent.append(lab)
            comp_max.append(f[v])
            labels[v] = lab
            continue
        # propagate the label with max component birth value (S.3.4)
        best = max(lab_nbrs, key=lambda l: comp_max[l])
        labels[v] = best
        for other in lab_nbrs:
            if other == best:
                continue
            pers = min(comp_max[best], comp_max[other]) - f[v]
            if pers <= eps:
                ra, rb = find(best), find(other)
                if ra != rb:
                    keep, drop = (ra, rb) if comp_max[ra] >= comp_max[rb] else (rb, ra)
                    parent[drop] = keep
                    comp_max[keep] = max(comp_max[keep], comp_max[drop])
                    best = keep
    out = np.array([find(l) for l in labels])
    # compact label ids
    _, out = np.unique(out, return_inverse=True)
    return out


def label_propagation(support: np.ndarray, *, max_sweeps: int = 50,
                      seed: int = 0) -> np.ndarray:
    """Asynchronous label propagation on the partial-correlation graph."""
    a = np.asarray(support, dtype=bool)
    a = a | a.T
    np.fill_diagonal(a, False)
    n = a.shape[0]
    rng = np.random.default_rng(seed)
    labels = np.arange(n)
    idx = np.arange(n)
    for _ in range(max_sweeps):
        rng.shuffle(idx)
        changed = 0
        for v in idx:
            nbr = np.nonzero(a[v])[0]
            if nbr.size == 0:
                continue
            counts = np.bincount(labels[nbr])
            best = np.argmax(counts)
            if labels[v] != best and counts[best] > 0:
                labels[v] = best
                changed += 1
        if changed == 0:
            break
    _, out = np.unique(labels, return_inverse=True)
    return out


def modified_jaccard(c1: np.ndarray, c2: np.ndarray) -> float:
    """Modified Jaccard similarity (paper eq. (S.3)).

    Sim = (1/max(k,l)) * sum of Jaccard weights over a maximum-weight edge
    cover of the bipartite cluster graph. We compute a maximum-weight
    matching (scipy assignment) and complete it to an edge cover by giving
    each unmatched cluster its heaviest incident edge.
    """
    from scipy.optimize import linear_sum_assignment

    c1 = np.asarray(c1)
    c2 = np.asarray(c2)
    ids1, inv1 = np.unique(c1, return_inverse=True)
    ids2, inv2 = np.unique(c2, return_inverse=True)
    k, l = len(ids1), len(ids2)
    inter = np.zeros((k, l), dtype=np.float64)
    np.add.at(inter, (inv1, inv2), 1.0)
    sz1 = np.bincount(inv1, minlength=k).astype(np.float64)
    sz2 = np.bincount(inv2, minlength=l).astype(np.float64)
    union = sz1[:, None] + sz2[None, :] - inter
    w = np.where(union > 0, inter / union, 0.0)

    rows, cols = linear_sum_assignment(-w)   # max-weight matching
    total = w[rows, cols].sum()
    covered1 = np.zeros(k, dtype=bool)
    covered2 = np.zeros(l, dtype=bool)
    covered1[rows] = True
    covered2[cols] = True
    # edge-cover completion: every cluster must be covered
    if not covered1.all():
        total += w[~covered1].max(axis=1).sum()
    if not covered2.all():
        total += w[:, ~covered2].max(axis=0).sum()
    return float(total / max(k, l))


def threshold_covariance_graph(s: np.ndarray, keep_frac: float) -> np.ndarray:
    """The paper's baseline: keep the largest-|S_ij| off-diagonal entries."""
    a = np.abs(np.asarray(s)).copy()
    np.fill_diagonal(a, 0.0)
    vals = a[np.triu_indices_from(a, k=1)]
    if vals.size == 0:
        return np.zeros_like(a, dtype=bool)
    kth = np.quantile(vals, 1.0 - keep_frac)
    return a >= kth
