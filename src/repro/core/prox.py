"""Proximal gradient driver for CONCORD/PseudoNet (paper Algorithms 1-3).

The loop is generic over a ``VariantOps`` bundle so that the single-device
reference (this file), the distributed Cov driver and the distributed Obs
driver (core/distributed.py) all share identical control flow:

    aux_of(omega, data[, mask]) -> aux     # the per-line-search product
                                           #   cov: W = Omega @ S
                                           #   obs: Y = Omega @ X^T
    g_of(omega, aux, data)     -> scalar   # smooth objective from aux
                                           #   (returns +inf when diag <= 0)
    grad_of(omega, aux, data)  -> grad     # once per outer iteration
                                           #   cov: uses W and the distributed
                                           #        transpose W^T
                                           #   obs: forms Z = Y @ X / n, Z^T
    dot(a, b)                  -> scalar   # global <A, B> (psum'd on shards)
    prox(z, penalty, tau, data) -> array   # prox of tau*penalty, diag exempt

The penalty is a :class:`repro.core.penalty.PenaltySpec`: a pytree whose
kind (l1 / weighted_l1 / scad / mcp / ...) is static and whose numeric
parameters are traced leaves, so a warm-started lambda path or a batched
grid with per-lane penalty parameters reuses ONE compiled program.  The
legacy ``lam1=`` float keyword still works everywhere and constructs the
equivalent l1 spec (bit-identical solve).

Three optional ops switch on the sparsity-aware matmul path (core.matops):

    prox_stats(z, penalty, tau, data) -> (array, mask)
                                           # prox + the harvested
                                           # block-occupancy mask of the
                                           # new iterate (free with the
                                           # fused Pallas prox kernel)
    mask_of(omega, data)       -> mask     # occupancy of a warm start
    density_of(mask)           -> scalar   # GLOBAL block density (psum'd
                                           # on shards)

When ``prox_stats`` is set, the loop threads the mask of the current
iterate through the carry and hands it to ``aux_of`` so every Ω-side
product can route through the block-sparse kernels once the observed
density crosses the policy threshold.

The distributed drivers run this exact function INSIDE shard_map: `omega`
and `aux` are then per-device shards and the ops close over collectives.
Control flow is fully jax.lax (while_loop both levels) so a whole solve
lowers as one XLA program with the 1.5D collectives inlined.

The loop is also ``jax.vmap``-able over a stacked problem axis (the batched
multi-problem engine in ``core.batch``): under vmap a ``while_loop`` keeps
running until EVERY lane's condition is false and the body executes for all
lanes each round, so both loop bodies freeze their already-finished lanes
(accepted line searches, converged/stalled outer iterations) by selecting
the old carry — finished problems hold their state bit-exactly while
stragglers keep iterating.
"""
from __future__ import annotations

import warnings
from functools import partial
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from . import matops
from .objective import (
    gradient_from_w,
    smooth_objective_cov,
    smooth_objective_obs,
)
from .penalty import PenaltySpec, normalize_penalty


class VariantOps(NamedTuple):
    aux_of: Callable
    g_of: Callable
    grad_of: Callable
    dot: Callable
    prox: Callable
    prox_stats: Callable | None = None    # enables the block-sparse path
    mask_of: Callable | None = None
    density_of: Callable | None = None


class ProxResult(NamedTuple):
    omega: jax.Array
    iters: jax.Array        # outer proximal-gradient iterations taken (s)
    ls_total: jax.Array     # total line-search trials (s*t)
    converged: jax.Array    # genuine delta < tol exit (never set on a stall)
    g_final: jax.Array
    delta_final: jax.Array
    stalled: jax.Array = False      # line search exhausted max_ls without
                                    # accepting a step; iterate unchanged
    block_density: jax.Array = 1.0  # observed final block density (1.0 dense)


class _Carry(NamedTuple):
    omega: jax.Array
    aux: jax.Array
    mask: jax.Array | None
    g_val: jax.Array
    step: jax.Array
    ls_total: jax.Array
    delta: jax.Array
    tau_prev: jax.Array
    stalled: jax.Array


class _LsCarry(NamedTuple):
    tau: jax.Array
    omega_new: jax.Array
    aux_new: jax.Array
    mask_new: jax.Array | None
    g_new: jax.Array
    accepted: jax.Array
    trials: jax.Array


def guard_nonpos_diag(g, min_diag):
    """+inf objective if any diagonal entry is non-positive (log barrier)."""
    bad = (min_diag <= 0.0) | jnp.isnan(g)
    return jnp.where(bad, jnp.inf, g)


# ---------------------------------------------------------------------------
# line-search step-size schedules (shared with the batched flat-step engine)
# ---------------------------------------------------------------------------

#: step-size schedules for the backtracking line search:
#:   "restart"  tau restarts at tau_init every outer iteration (the paper)
#:   "warm"     first trial is min(2 * last accepted tau, tau_init)
#:              (the legacy warm_start_tau=True behaviour, bit-identical)
#:   "greedy"   first-ever trial starts at tau_init/4 and later iterations
#:              grow the accepted tau by 1.3x (capped at tau_init).  On the
#:              benchmark path shapes this cuts total trials ~40% below
#:              "restart" while taking the IDENTICAL outer-iteration count
#:              (the accepted steps coincide; only rejected probes differ).
TAU_SCHEDULES = ("restart", "warm", "greedy")

#: "greedy" constants, measured on the BENCH_path_batch shapes: growing a
#: just-accepted tau by 1.3 (not 2.0) re-rejects far less often, and a
#: conservative first-ever trial skips the cold-start rejection cascade.
GREEDY_TAU_GROWTH = 1.3
GREEDY_TAU_FIRST = 0.25


def resolve_tau_schedule(tau_schedule: str | None,
                         warm_start_tau: bool) -> str:
    """Canonical schedule name; ``None`` keeps the legacy bool semantics
    (``warm_start_tau=True`` is exactly the "warm" schedule)."""
    if tau_schedule is None:
        return "warm" if warm_start_tau else "restart"
    if tau_schedule not in TAU_SCHEDULES:
        raise ValueError(f"tau_schedule must be one of {TAU_SCHEDULES} or "
                         f"None, got {tau_schedule!r}")
    return tau_schedule


def tau_first(schedule: str, tau_init):
    """First-ever trial step size (outer step 0) under a schedule."""
    return GREEDY_TAU_FIRST * tau_init if schedule == "greedy" else tau_init


def tau_start(schedule: str, step, tau_prev, tau_init, dtype):
    """First-trial step size of an outer iteration: ``step`` is the outer
    iteration counter and ``tau_prev`` the tau the previous line search
    ended at (its accepted step).  Shared verbatim by the sequential loop
    and the batched flat-step engine so their trial sequences — and hence
    iterates — stay bit-identical."""
    if schedule == "restart":
        return jnp.asarray(tau_init, dtype)
    growth = 2.0 if schedule == "warm" else GREEDY_TAU_GROWTH
    return jnp.where(
        step > 0,
        jnp.minimum(growth * tau_prev, tau_init),
        jnp.asarray(tau_first(schedule, tau_init), dtype),
    )


def ls_trial(ops: VariantOps, data, penalty, omega, grad, g_val, tau):
    """One backtracking trial at step size ``tau`` (dense product path).

    Returns ``(cand, aux_c, g_c, dot_dd, ok)``: the prox candidate, its
    aux product and smooth objective, the squared step norm
    ``<cand - omega, cand - omega>`` (reused by the relative-change test),
    and the sufficient-decrease acceptance.  This is the exact trial math
    of :func:`prox_gradient`'s inner loop, factored out so the batched
    flat-step engine (``core.batch``) replays bit-identical trials."""
    z = omega - tau * grad
    cand = ops.prox(z, penalty, tau, data)
    aux_c = ops.aux_of(cand, data)
    g_c = ops.g_of(cand, aux_c, data)
    diff = cand - omega
    dot_dd = ops.dot(diff, diff)
    rhs = g_val + ops.dot(diff, grad) + dot_dd / (2.0 * tau)
    return cand, aux_c, g_c, dot_dd, g_c <= rhs


def prox_gradient(
    omega0: jax.Array,
    data,
    ops: VariantOps,
    *,
    penalty: PenaltySpec | None = None,
    lam1: float | None = None,
    tol: float = 1e-5,
    max_iters: int = 500,
    max_ls: int = 30,
    tau_init: float = 1.0,
    warm_start_tau: bool = False,
    tau_schedule: str | None = None,
) -> ProxResult:
    """Run the CONCORD/PseudoNet proximal gradient method.

    The penalty enters only through ``ops.prox``/``ops.prox_stats``;
    pass a :class:`PenaltySpec` (its parameters stay traced), or the
    legacy ``lam1=`` float which builds the equivalent l1 spec.

    warm_start_tau=False reproduces the paper exactly (tau restarts at
    tau_init every outer iteration); True starts from 2x the previously
    accepted step, which typically saves 20-40% of line-search trials
    (beyond-paper knob, still provably convergent by the same argument).
    ``tau_schedule`` names a schedule from :data:`TAU_SCHEDULES` explicitly
    and overrides the bool ("greedy" saves the most trials); ``None``
    keeps the legacy ``warm_start_tau`` semantics bit-exactly.
    """
    if penalty is None:
        if lam1 is None:
            raise TypeError("prox_gradient needs penalty= (or the legacy "
                            "lam1= float)")
        # raw constructor on purpose: lam1 may be a tracer (vmapped lanes)
        penalty = PenaltySpec("l1", lam1)
    elif lam1 is not None:
        raise ValueError("pass either penalty= or lam1=, not both")
    schedule = resolve_tau_schedule(tau_schedule, warm_start_tau)
    dtype = jnp.result_type(omega0)
    sparse = ops.prox_stats is not None
    if sparse:
        mask0 = ops.mask_of(omega0, data)
        aux0 = ops.aux_of(omega0, data, mask0)
    else:
        mask0 = None
        aux0 = ops.aux_of(omega0, data)
    g0 = ops.g_of(omega0, aux0, data)

    def ls_cond(ls: _LsCarry):
        return (~ls.accepted) & (ls.trials < max_ls)

    def outer_body(carry: _Carry) -> _Carry:
        grad = ops.grad_of(carry.omega, carry.aux, data)

        tau0 = tau_start(schedule, carry.step, carry.tau_prev, tau_init,
                         dtype)

        def ls_try(tau):
            if sparse:
                z = carry.omega - tau * grad
                cand, mask_c = ops.prox_stats(z, penalty, tau, data)
                aux_c = ops.aux_of(cand, data, mask_c)
                g_c = ops.g_of(cand, aux_c, data)
                diff = cand - carry.omega
                rhs = (
                    carry.g_val
                    + ops.dot(diff, grad)
                    + ops.dot(diff, diff) / (2.0 * tau)
                )
                return cand, aux_c, mask_c, g_c, g_c <= rhs
            cand, aux_c, g_c, _, ok = ls_trial(
                ops, data, penalty, carry.omega, grad, carry.g_val, tau)
            return cand, aux_c, None, g_c, ok

        def ls_body(ls: _LsCarry) -> _LsCarry:
            tau = ls.tau * 0.5
            cand, aux_c, mask_c, g_c, ok = ls_try(tau)
            nxt = _LsCarry(tau, cand, aux_c, mask_c, g_c, ok, ls.trials + 1)
            # Freeze lanes that already accepted: under vmap the loop keeps
            # running while ANY lane still searches, and the body executes
            # for all of them.
            return jax.tree.map(
                lambda n, o: jnp.where(ls.accepted, o, n), nxt, ls)

        cand0, aux_c0, mask_c0, g_c0, ok0 = ls_try(tau0)
        ls = jax.lax.while_loop(
            ls_cond,
            ls_body,
            _LsCarry(tau0, cand0, aux_c0, mask_c0, g_c0, ok0,
                     jnp.asarray(1, jnp.int32)),
        )

        diff = ls.omega_new - carry.omega
        delta = jnp.sqrt(ops.dot(diff, diff)) / jnp.maximum(
            1.0, jnp.sqrt(ops.dot(carry.omega, carry.omega))
        )
        # If the line search exhausted max_ls without acceptance, keep the
        # old iterate and STALL: delta is zeroed so the outer loop exits,
        # and the stalled flag records that this was not a genuine
        # delta < tol convergence (the old behaviour reported
        # converged=True here, which lied).
        omega_next = jnp.where(ls.accepted, ls.omega_new, carry.omega)
        aux_next = jax.tree.map(
            lambda a, b: jnp.where(ls.accepted, a, b), ls.aux_new, carry.aux
        )
        mask_next = jax.tree.map(
            lambda a, b: jnp.where(ls.accepted, a, b), ls.mask_new, carry.mask
        )
        g_next = jnp.where(ls.accepted, ls.g_new, carry.g_val)
        delta = jnp.where(ls.accepted, delta, jnp.asarray(0.0, dtype))
        nxt = _Carry(
            omega=omega_next,
            aux=aux_next,
            mask=mask_next,
            g_val=g_next,
            step=carry.step + 1,
            ls_total=carry.ls_total + ls.trials,
            delta=delta,
            tau_prev=ls.tau,
            stalled=~ls.accepted,
        )
        # Freeze finished lanes (converged, stalled or iteration-capped):
        # under vmap the outer while_loop runs until every lane is done and
        # the body executes for all of them, so a finished problem must
        # hold its carry bit-exactly while stragglers keep iterating.
        active = outer_cond(carry)
        return jax.tree.map(lambda n, o: jnp.where(active, n, o), nxt, carry)

    def outer_cond(carry: _Carry):
        return (carry.step < max_iters) & (carry.delta >= tol)

    init = _Carry(
        omega=omega0,
        aux=aux0,
        mask=mask0,
        g_val=g0,
        step=jnp.asarray(0, jnp.int32),
        ls_total=jnp.asarray(0, jnp.int32),
        delta=jnp.asarray(jnp.inf, dtype),
        tau_prev=jnp.asarray(tau_init, dtype),
        stalled=jnp.asarray(False),
    )
    final = jax.lax.while_loop(outer_cond, outer_body, init)
    if sparse:
        density_of = ops.density_of or matops.block_density
        density = density_of(final.mask)
    else:
        density = jnp.asarray(1.0, matops.DENSITY_DTYPE)
    return ProxResult(
        omega=final.omega,
        iters=final.step,
        ls_total=final.ls_total,
        converged=(final.delta < tol) & ~final.stalled,
        g_final=final.g_val,
        delta_final=final.delta,
        stalled=final.stalled,
        block_density=density,
    )


# ---------------------------------------------------------------------------
# Single-device reference variants (the oracles for the distributed drivers).
# ---------------------------------------------------------------------------

def _ref_dot(a, b):
    return jnp.sum(a * b)


def _ref_prox(z, pen, tau, data):
    return pen.prox(z, tau)


def _ref_sparse_ops(policy: matops.MatmulPolicy, use_pallas: bool):
    """(prox_stats, mask_of, density_of) for the single-device variants.

    With ``use_pallas`` the occupancy mask is harvested for free from the
    fused prox kernel's per-tile nnz stats lane (soft-threshold penalty
    family only; SCAD/MCP fall back to the jnp prox + one mask pass); the
    jnp path computes the same mask in one extra cheap pass (it is the
    kernel's oracle)."""
    bs = policy.block_size

    def prox_stats(z, pen, tau, data):
        if use_pallas and pen.pallas_ok:
            from ..kernels import ops as kops
            eye = jnp.eye(z.shape[-1], dtype=z.dtype)
            out, _, _, _, _, bnnz = kops.fused_prox_stats(
                z, eye, tau * pen.lam1, weights=pen.weights, block=(bs, bs))
            return out, (bnnz > 0).astype(matops.MASK_DTYPE)
        out = pen.prox(z, tau)
        return out, matops.block_mask(out, bs)

    def mask_of(omega, data):
        return matops.block_mask(omega, bs)

    def density_of(mask):
        return matops.block_density(mask)

    return prox_stats, mask_of, density_of


def cov_ops(sparse_matmul: matops.MatmulPolicy | None = None,
            use_pallas: bool = False) -> VariantOps:
    """Reference Cov variant: data = {'s': S, 'lam2': lam2}.

    ``sparse_matmul`` routes W = Omega @ S through the matops block-sparse
    dispatch, with the occupancy mask maintained by the prox step."""
    policy = sparse_matmul

    def aux_of(omega, data, mask=None):
        return matops.matmul(omega, data["s"], mask=mask, policy=policy)

    def g_of(omega, w, data):
        g = smooth_objective_cov(omega, w, data["lam2"])
        return guard_nonpos_diag(g, jnp.min(jnp.diagonal(omega)))

    def grad_of(omega, w, data):
        return gradient_from_w(omega, w, data["lam2"])

    if policy is None or not policy.enabled:
        return VariantOps(aux_of, g_of, grad_of, _ref_dot, _ref_prox)
    return VariantOps(aux_of, g_of, grad_of, _ref_dot, _ref_prox,
                      *_ref_sparse_ops(policy, use_pallas))


def obs_ops(sparse_matmul: matops.MatmulPolicy | None = None,
            use_pallas: bool = False) -> VariantOps:
    """Reference Obs variant: data = {'x': X, 'lam2': lam2}; S never formed.

    ``sparse_matmul`` routes Y = Omega @ X^T through the matops dispatch."""
    policy = sparse_matmul

    def aux_of(omega, data, mask=None):
        return matops.matmul(omega, data["x"].T, mask=mask,
                             policy=policy)     # Y, unnormalized

    def g_of(omega, y, data):
        g = smooth_objective_obs(omega, y, data["x"].shape[0], data["lam2"])
        return guard_nonpos_diag(g, jnp.min(jnp.diagonal(omega)))

    def grad_of(omega, y, data):
        x = data["x"]
        z = (y @ x) / x.shape[0]              # Z = Omega S
        return gradient_from_w(omega, z, data["lam2"])

    if policy is None or not policy.enabled:
        return VariantOps(aux_of, g_of, grad_of, _ref_dot, _ref_prox)
    return VariantOps(aux_of, g_of, grad_of, _ref_dot, _ref_prox,
                      *_ref_sparse_ops(policy, use_pallas))


@partial(jax.jit, static_argnames=("variant", "tol", "max_iters", "max_ls",
                                   "warm_start_tau", "tau_schedule",
                                   "sparse_matmul", "use_pallas"))
def _solve_reference(
    s_or_x: jax.Array,
    penalty: PenaltySpec,
    omega0: jax.Array | None,
    *,
    variant: str,
    tol: float,
    max_iters: int,
    max_ls: int,
    warm_start_tau: bool,
    sparse_matmul: matops.MatmulPolicy | None,
    use_pallas: bool,
    tau_schedule: str | None = None,
) -> ProxResult:
    """Jitted engine behind :func:`solve_reference`.  The penalty spec's
    numeric leaves (lam1, lam2, shape, weights) and ``omega0`` are traced,
    so a regularization path over same-shape problems reuses one compiled
    program per (shape, penalty kind, statics) key."""
    if variant == "cov":
        data = {"s": s_or_x, "lam2": jnp.asarray(penalty.lam2, s_or_x.dtype)}
        ops = cov_ops(sparse_matmul, use_pallas)
    elif variant == "obs":
        data = {"x": s_or_x, "lam2": jnp.asarray(penalty.lam2, s_or_x.dtype)}
        ops = obs_ops(sparse_matmul, use_pallas)
    else:
        raise ValueError(f"unknown variant {variant!r}")
    p = s_or_x.shape[-1]
    if omega0 is None:
        omega0 = jnp.eye(p, dtype=s_or_x.dtype)
    return prox_gradient(
        omega0, data, ops, penalty=penalty, tol=tol,
        max_iters=max_iters, max_ls=max_ls, warm_start_tau=warm_start_tau,
        tau_schedule=tau_schedule,
    )


def solve_reference(
    s_or_x: jax.Array,
    lam1: float | None = None,
    lam2: float = 0.0,
    *,
    penalty: PenaltySpec | str | None = None,
    omega0: jax.Array | None = None,
    variant: str = "cov",
    tol: float = 1e-5,
    max_iters: int = 500,
    max_ls: int = 30,
    warm_start_tau: bool = False,
    tau_schedule: str | None = None,
    sparse_matmul: matops.MatmulPolicy | None = None,
    use_pallas: bool = False,
) -> ProxResult:
    """Single-device CONCORD/PseudoNet solve. variant='cov' expects S, 'obs'
    expects X. ``omega0`` warm-starts the iterates (defaults to the identity).

    The penalty comes either from ``penalty=`` (a
    :class:`~repro.core.penalty.PenaltySpec` or string form, which also
    carries the smooth ridge in its ``lam2`` field) or from the legacy
    ``lam1``/``lam2`` floats (the equivalent l1 spec, bit-identical solve).
    All penalty parameters and ``omega0`` are traced, so a regularization
    path over same-shape problems reuses one compiled program per
    (shape, penalty kind, statics) key.

    ``sparse_matmul`` (a hashable :class:`repro.core.matops.MatmulPolicy`)
    routes the Ω-side product through the block-sparse dispatch once the
    observed block density of the iterate drops below the policy threshold;
    ``use_pallas`` additionally harvests the occupancy mask from the fused
    Pallas prox kernel instead of a separate jnp pass.
    """
    spec = normalize_penalty(penalty, lam1, lam2)
    if spec.weights is not None:
        p = s_or_x.shape[-1]
        wshape = getattr(spec.weights, "shape", None)
        if wshape != (p, p):
            raise ValueError(
                f"penalty weights shape {wshape} must match the problem "
                f"dimension ({p}, {p})")
    return _solve_reference(
        s_or_x, spec, omega0, variant=variant, tol=tol,
        max_iters=max_iters, max_ls=max_ls, warm_start_tau=warm_start_tau,
        tau_schedule=tau_schedule, sparse_matmul=sparse_matmul,
        use_pallas=use_pallas,
    )


def fit_reference(
    s_or_x: jax.Array,
    lam1: float,
    lam2: float = 0.0,
    *,
    variant: str = "cov",
    tol: float = 1e-5,
    max_iters: int = 500,
    max_ls: int = 30,
    warm_start_tau: bool = False,
) -> ProxResult:
    """Deprecated shim — use :mod:`repro.estimator` (``ConcordEstimator`` with
    ``backend='reference'``) or :func:`solve_reference` directly."""
    warnings.warn(
        "fit_reference is deprecated; use repro.estimator.ConcordEstimator "
        "(backend='reference') or repro.core.prox.solve_reference",
        DeprecationWarning, stacklevel=2)
    return solve_reference(
        s_or_x, lam1, lam2, variant=variant, tol=tol,
        max_iters=max_iters, max_ls=max_ls, warm_start_tau=warm_start_tau,
    )


# ---------------------------------------------------------------------------
# analysis manifest (repro.analysis.jaxprpass)
# ---------------------------------------------------------------------------

def _analysis_solve():
    p = 8
    s = jnp.eye(p, dtype=jnp.float64) + 0.05 * jnp.ones((p, p), jnp.float64)
    spec = PenaltySpec("l1", jnp.asarray(0.1, jnp.float64),
                       jnp.asarray(0.0, jnp.float64))
    fn = partial(_solve_reference, variant="cov", tol=1e-4, max_iters=8,
                 max_ls=8, warm_start_tau=False, sparse_matmul=None,
                 use_pallas=False)
    return {"fn": fn, "args": (s, spec, None)}


def _analysis_solve_reuse():
    p = 6
    s = jnp.eye(p, dtype=jnp.float64) + 0.04 * jnp.ones((p, p), jnp.float64)

    def run(lam1):
        res = solve_reference(s, lam1, tol=1e-3, max_iters=5, max_ls=5)
        return res.omega.block_until_ready()

    # three path points, one shape: the compiled cache must hold after
    # the warmup call (lam1 is a traced leaf of the penalty spec)
    return {"watched": {"core.prox._solve_reference": _solve_reference},
            "calls": [partial(run, 0.10), partial(run, 0.18),
                      partial(run, 0.26)]}


#: the sequential reference solve — the oracle every other layer matches
ANALYSIS_ENTRIES = [
    {"name": "core.prox.solve_reference", "path": "src/repro/core/prox.py",
     "axis_names": (), "build": _analysis_solve,
     "reuse": _analysis_solve_reuse},
]
