"""Sparsity-aware matmul dispatch for the Ω-side products (the matops layer).

HP-CONCORD's dominant cost is the ΩŜ / ΩXᵀ product, and the iterate Ω
becomes extremely sparse as the solve proceeds — the regime the paper's
1.28M-dimension runs live in.  This module turns that emergent sparsity
into skipped work:

  * ``block_mask(a, bs)``       — block-occupancy mask of a matrix (one bit
                                  per bs x bs tile).  The solver harvests it
                                  for free from the prox step (the fused
                                  Pallas prox kernel emits per-tile nnz
                                  counts; the jnp path computes it in one
                                  cheap pass).
  * ``masked_matmul(...)``      — the block-gather product: gather only the
                                  occupied tiles of A (up to a static
                                  capacity), batched-matmul them against the
                                  matching row-blocks of B, scatter-add by
                                  block row.  Work is proportional to the
                                  capacity, not p^2.  This is the jittable
                                  fallback of the Pallas block-CSR kernel
                                  (``kernels.blocksparse_matmul``), which
                                  needs host-side CSR construction.
  * ``matmul(a, b, mask, policy)`` — the dispatch: a ``lax.cond``/``switch``
                                  on the *observed* block density routes to
                                  the dense path above the crossover
                                  threshold and to the block-gather path
                                  (with the smallest capacity tier that
                                  provably covers the occupied blocks)
                                  below it.  Both branches are exact: the
                                  sparse branch only ever runs when its
                                  capacity bounds the occupied-block count.

``MatmulPolicy`` is a hashable NamedTuple so it can ride through ``jax.jit``
static arguments (``solve_reference(sparse_matmul=...)``) and shard_map'd
distributed drivers alike.  The crossover threshold for ``mode="auto"`` is
produced by ``core.costmodel.crossover_density`` (calibrated by
``benchmarks/sparse_crossover.py``).
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

#: capacity ladder, as fractions of the policy threshold.  The dispatch
#: picks the smallest rung whose capacity covers the observed occupied
#: blocks, so late (very sparse) iterations do proportionally less work
#: instead of always paying for the full threshold capacity.
TIER_FRACTIONS = (0.125, 0.25, 0.5, 1.0)

#: dtype of every block-occupancy mask.  Fixed and compact on purpose: the
#: distributed drivers ppermute the mask around the 1.5D ring alongside the
#: Ω operand, so an operand-dtype mask would move 4-8 bytes per block where
#: one is enough (an f64 solve used to ship 8-byte masks).  Consumers only
#: ever test ``mask > 0``.
MASK_DTYPE = jnp.int8

#: dtype of block-density STATISTICS (the scalar the dispatch and the
#: solver report as ``block_density``).  f32 by policy: it is a diagnostic
#: ratio in [0, 1] compared against a crossover threshold, never part of
#: the f64 iterate arithmetic, and the distributed drivers psum it.
DENSITY_DTYPE = jnp.float32


class MatmulPolicy(NamedTuple):
    """Static (hashable) routing policy for Ω-side products.

    mode        "off" — always dense; "on" — block-sparse below
                ``threshold``; "auto" — same mechanics, but the threshold
                came from the cost model's dense↔block-sparse crossover.
    block_size  tile edge of the occupancy mask (MXU-aligned, 128, on TPU;
                anything that divides the operand on CPU tests).
    threshold   block-density crossover: observed density above it takes
                the dense path.
    """
    mode: str = "off"
    block_size: int = 128
    threshold: float = 0.25

    @property
    def enabled(self) -> bool:
        return self.mode != "off"


DENSE = MatmulPolicy()


def _cdiv(a: int, b: int) -> int:
    return -(-a // b)


def _pad2(a, rows: int, cols: int):
    r, c = a.shape
    if r == rows and c == cols:
        return a
    return jnp.pad(a, ((0, rows - r), (0, cols - c)))


def block_mask(a, block_size: int):
    """Block-occupancy mask: out[i, j] = 1 iff tile (i, j) has any nonzero.

    Shape is (cdiv(r, bs), cdiv(c, bs)), dtype ``MASK_DTYPE`` (compact and
    independent of the operand dtype — the distributed drivers rotate this
    around the ring); partial edge tiles are zero-padded (padding never
    flips a tile on).  Semantically identical to the per-tile nnz counts
    the fused prox kernel emits (``kernels.softthresh``).
    """
    r, c = a.shape
    bs = block_size
    nbr, nbc = _cdiv(r, bs), _cdiv(c, bs)
    ap = _pad2(a, nbr * bs, nbc * bs)
    tiles = jnp.abs(ap).reshape(nbr, bs, nbc, bs)
    return (tiles.max(axis=(1, 3)) > 0).astype(MASK_DTYPE)


def block_density(mask):
    """Fraction of occupied blocks (``DENSITY_DTYPE`` scalar)."""
    return jnp.mean((mask > 0).astype(DENSITY_DTYPE))


def capacity_tiers(total_blocks: int, threshold: float) -> list[int]:
    """Ascending block capacities the dispatch may gather (deduplicated,
    all < total_blocks — a capacity of the full grid saves nothing)."""
    caps = sorted({
        max(1, math.ceil(threshold * total_blocks * f))
        for f in TIER_FRACTIONS
    })
    return [c for c in caps if c < total_blocks]


def masked_matmul(a, b, mask, *, block_size: int, capacity: int):
    """Block-gather product: C = A @ B using only occupied bs x bs tiles
    of A (up to ``capacity`` of them, occupied-first).

    Correct whenever the occupied-block count is <= capacity: unoccupied
    tiles of A are exactly zero by construction of the mask, and gathered
    padding picks are zero-masked, so the result equals the dense product
    up to float summation order.  Cost: O(capacity * bs^2 * m) flops plus
    the gathers — i.e. proportional to nnz(Ω) instead of p^2.
    """
    p, k = a.shape
    kb, m = b.shape
    bs = block_size
    nbr, nbc = mask.shape
    ap = _pad2(a, nbr * bs, nbc * bs)
    bp = _pad2(b, nbc * bs, m)
    occupied = mask.reshape(-1) > 0
    order = jnp.argsort(~occupied)            # occupied block ids first
    idx = order[:capacity]
    r_idx = idx // nbc
    c_idx = idx % nbc
    a4 = ap.reshape(nbr, bs, nbc, bs)
    vals = a4[r_idx, :, c_idx, :]             # (capacity, bs, bs) gather
    vals = vals * occupied[idx][:, None, None].astype(vals.dtype)
    b3 = bp.reshape(nbc, bs, m)
    prods = jnp.einsum("nij,njm->nim", vals, b3[c_idx])
    out = jax.ops.segment_sum(prods, r_idx, num_segments=nbr)
    return out.reshape(nbr * bs, m)[:p]


def matmul(a, b, *, mask=None, policy: MatmulPolicy | None = None):
    """The Ω-side product dispatch.

    Dense ``a @ b`` when the policy is off (or no mask is available);
    otherwise a ``lax.switch`` on the observed block density of ``mask``:
    density above ``policy.threshold`` falls back to the dense path, below
    it the block-gather path runs with the smallest capacity tier that
    covers the occupied blocks.  Exact either way (see ``masked_matmul``).
    """
    if policy is None or not policy.enabled or mask is None:
        return a @ b
    bs = policy.block_size
    nbr, nbc = _cdiv(a.shape[0], bs), _cdiv(a.shape[1], bs)
    if mask.shape != (nbr, nbc):
        raise ValueError(
            f"mask shape {mask.shape} does not tile operand {a.shape} at "
            f"block_size={bs} (want {(nbr, nbc)})")
    total = nbr * nbc
    caps = capacity_tiers(total, policy.threshold)
    if not caps:
        return a @ b

    # Rung selection compares INTEGER occupied-block counts against the
    # integer capacities (a float density ratio loses ulps past 2^24
    # blocks and could under-select a rung, silently dropping occupied
    # blocks): rung i is the first with caps[i] >= occupied; past the
    # last rung (occupied > ceil(threshold * total)) -> dense.
    occupied = jnp.sum((mask > 0).astype(jnp.int32))
    bounds = jnp.asarray(caps, jnp.int32)
    ix = jnp.searchsorted(bounds, occupied, side="left")
    ix = jnp.minimum(ix, len(caps))

    def _make_sparse(cap):
        def _sparse(a_, b_, m_):
            return masked_matmul(a_, b_, m_, block_size=bs, capacity=cap)
        return _sparse

    branches = [_make_sparse(c) for c in caps]
    branches.append(lambda a_, b_, m_: a_ @ b_)
    return lax.switch(ix, branches, a, b, mask)


def panel_gram(x, *, panel: int = 512):
    """Blocked XᵀX: the (p, p) Gram of an (n, p) row-block, accumulated by
    column panels so each product is a bounded (panel, n) @ (n, p) slab —
    the data-side sibling of the Ω-product dispatch above, and the unit of
    work the streaming Gram accumulator (``data.gram``) folds per chunk.

    Every panel routes through :func:`matmul` (the dense path of the
    dispatch; the X operand carries no exploitable block sparsity).  The
    f64 contract of the accumulator is preserved: a float64 numpy input
    stays float64 even with jax x64 disabled — the panels then run
    host-side in numpy, because ``jnp.asarray`` would silently downcast
    to f32 and break the streamed-vs-dense 1e-10 agreement.
    """
    if panel < 1:
        raise ValueError(f"panel must be >= 1, got {panel}")
    if x.ndim != 2:
        raise ValueError(f"x must be 2-D (n, p), got shape {x.shape}")
    p = x.shape[1]
    host_f64 = (not isinstance(x, jax.Array)
                and np.asarray(x).dtype == np.float64
                and not jax.config.jax_enable_x64)
    if host_f64:
        xh = np.asarray(x)
        out = np.empty((p, p), np.float64)
        for lo in range(0, p, panel):
            out[lo:lo + panel] = xh[:, lo:lo + panel].T @ xh
        return out
    xj = jnp.asarray(x)
    blocks = [matmul(xj[:, lo:lo + panel].T, xj)
              for lo in range(0, p, panel)]
    return blocks[0] if len(blocks) == 1 else jnp.concatenate(blocks, axis=0)
