"""Composable penalty API: pluggable elementwise prox operators.

HP-CONCORD's pseudolikelihood objective is penalty-agnostic: the smooth
part g(Omega) never changes, and every solver layer only touches the
penalty through its proximal operator (the elementwise shrink applied
after each gradient step) and its value (for objective reporting).  A
:class:`PenaltySpec` packages exactly those two things plus the penalty's
parameters, so swapping l1 for adaptive/weighted lasso, SCAD, or MCP is a
constructor argument instead of a solver fork.

Specs are frozen, pytree-compatible records: the *kind* is static
metadata (it selects the prox formula, so changing it recompiles) while
every numeric parameter (``lam1``, the ridge ``lam2``, the SCAD/MCP shape
parameter, a full p x p weight matrix) is a pytree leaf.  Passed through
``jax.jit`` the parameters are traced, so a warm-started lambda path or a
batched multi-problem grid reuses ONE compiled program across penalty
values; under ``jax.vmap`` individual leaves may carry a leading batch
axis (``batch_axes``) so different lanes can run different penalty
parameters inside one program; under ``shard_map`` the weight matrix
shards with the Omega layout while scalars replicate.

Built-in kinds:

  ``l1``           lam1 * ||offdiag||_1 (+ optional smooth lam2 ridge) —
                   the paper's penalty and the default everywhere.
  ``elastic_net``  same operator, explicitly named l1 + ridge combination.
  ``weighted_l1``  lam1 * sum_ij w_ij |omega_ij| with a full symmetric
                   nonnegative weight matrix.  ``w_ij = 0`` leaves an
                   entry unpenalized (known edge), ``w_ij = inf`` forces
                   it to exactly zero (structural exclusion); finite
                   weights give the adaptive lasso.
  ``scad``         Fan & Li's smoothly clipped absolute deviation,
                   shape ``a > 2`` (default 3.7).
  ``mcp``          Zhang's minimax concave penalty, shape ``gamma > 1``
                   (default 3.0).

``lam2`` always denotes the SMOOTH ridge coefficient (it lives in the
differentiable part g, exactly like the pre-spec ``lam2=`` plumbing), so
``l1`` with ``lam2 > 0`` and ``elastic_net`` solve the same problem; the
prox side of every spec is purely the nonsmooth part.

``register_penalty`` adds new kinds without touching any solver layer.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

#: default SCAD shape parameter (Fan & Li's canonical choice)
SCAD_DEFAULT_A = 3.7

#: default MCP shape parameter
MCP_DEFAULT_GAMMA = 3.0

#: relative asymmetry above this rejects a weight matrix (mirrors the
#: covariance symmetry gate in ``estimator.backends``)
WEIGHT_SYMMETRY_RTOL = 1e-6


def _soft(z, thr):
    """Elementwise soft-thresholding (the l1 prox kernel)."""
    return jnp.sign(z) * jnp.maximum(jnp.abs(z) - thr, 0.0)


# ---------------------------------------------------------------------------
# per-kind prox / value implementations
#
# prox(spec, z, tau) returns the UNMASKED elementwise prox of
# tau * penalty; the caller applies the diagonal exemption.  All formulas
# are valid for the solver's step sizes tau <= tau_init = 1 (the shape
# validation a > 2 / gamma > 1 guarantees the piecewise subproblems stay
# strictly convex there).
# ---------------------------------------------------------------------------

def _prox_l1(spec, z, tau):
    return _soft(z, tau * spec.lam1)


def _prox_weighted_l1(spec, z, tau):
    w = jnp.asarray(spec.weights, z.dtype)
    alpha = tau * spec.lam1
    # inf weights must force exact zeros even at alpha == 0 (inf * 0 = nan)
    thr = jnp.where(jnp.isinf(w), jnp.inf, alpha * w)
    return _soft(z, thr)


def _prox_scad(spec, z, tau):
    a, lam = spec.shape, spec.lam1
    az = jnp.abs(z)
    inner = _soft(z, tau * lam)
    mid = ((a - 1.0) * z - jnp.sign(z) * (tau * a * lam)) / (a - 1.0 - tau)
    return jnp.where(
        az <= (1.0 + tau) * lam, inner,
        jnp.where(az <= a * lam, mid, z))


def _prox_mcp(spec, z, tau):
    gamma, lam = spec.shape, spec.lam1
    az = jnp.abs(z)
    shrunk = (gamma / (gamma - tau)) * _soft(z, tau * lam)
    return jnp.where(az <= gamma * lam, shrunk, z)


def _offdiag_mask(om):
    p = om.shape[-1]
    return 1.0 - jnp.eye(p, dtype=om.dtype)


def _value_l1(spec, om):
    return spec.lam1 * jnp.sum(jnp.abs(om) * _offdiag_mask(om))


def _value_weighted_l1(spec, om):
    w = jnp.asarray(spec.weights, om.dtype)
    av = jnp.abs(om)
    contrib = jnp.where(av == 0.0, 0.0, w * av)   # inf * 0 -> 0, not nan
    return spec.lam1 * jnp.sum(contrib * _offdiag_mask(om))


def _scad_value_elem(av, lam, a):
    quad = (2.0 * a * lam * av - av * av - lam * lam) / (2.0 * (a - 1.0))
    tail = 0.5 * lam * lam * (a + 1.0)
    return jnp.where(av <= lam, lam * av,
                     jnp.where(av <= a * lam, quad, tail))


def _value_scad(spec, om):
    av = jnp.abs(om)
    return jnp.sum(_scad_value_elem(av, spec.lam1, spec.shape)
                   * _offdiag_mask(om))


def _mcp_value_elem(av, lam, gamma):
    return jnp.where(av <= gamma * lam, lam * av - av * av / (2.0 * gamma),
                     0.5 * gamma * lam * lam)


def _value_mcp(spec, om):
    av = jnp.abs(om)
    return jnp.sum(_mcp_value_elem(av, spec.lam1, spec.shape)
                   * _offdiag_mask(om))


# ---------------------------------------------------------------------------
# validation (factories only — pytree unflatten and with_* helpers never
# re-validate, so traced leaves flow freely inside jit/vmap/shard_map)
# ---------------------------------------------------------------------------

def _is_tracer(v) -> bool:
    return isinstance(v, jax.core.Tracer)


def _check_scalar(name: str, v) -> None:
    if v is None or _is_tracer(v):
        return
    arr = np.asarray(v)
    if arr.ndim != 0:
        return          # batched leaf (leading lane axis) — checked per use
    f = float(arr)
    if not math.isfinite(f) or f < 0:
        raise ValueError(f"{name} must be finite and >= 0, got {f}")


def _check_shape_param(kind: str, v, low: float) -> None:
    if v is None or _is_tracer(v):
        return
    arr = np.asarray(v)
    if arr.ndim != 0:
        return
    f = float(arr)
    if not f > low:
        raise ValueError(
            f"{kind} shape parameter must be > {low:g}, got {f!r} (the "
            f"three-regime prox needs it above the solver's max step size "
            f"tau_init = 1; nonpositive values are never valid)")


def _check_weights(w) -> None:
    if w is None or _is_tracer(w):
        return
    arr = np.asarray(w)
    if arr.ndim != 2 or arr.shape[0] != arr.shape[1]:
        raise ValueError(
            f"penalty weights must be a square (p, p) matrix, got shape "
            f"{arr.shape}")
    if np.any(np.isnan(arr)):
        raise ValueError("penalty weights must not contain NaN")
    if np.any(arr < 0):
        raise ValueError(
            f"penalty weights must be nonnegative (min was "
            f"{float(arr.min()):g}); use 0 for unpenalized entries and inf "
            f"for structural zeros")
    inf_mask = np.isinf(arr)
    if not np.array_equal(inf_mask, inf_mask.T):
        raise ValueError(
            "penalty weights must be symmetric: the inf (structural-zero) "
            "pattern differs between w and w.T")
    finite = np.where(inf_mask, 0.0, arr)
    scale = float(np.max(finite)) if finite.size else 0.0
    asym = float(np.max(np.abs(finite - finite.T))) if finite.size else 0.0
    if asym > WEIGHT_SYMMETRY_RTOL * max(scale, 1.0):
        raise ValueError(
            f"penalty weights must be symmetric: max |w - w.T| = {asym:.3e} "
            f"at scale {scale:.3e} — the estimated Omega is symmetric, so an "
            f"asymmetric penalty is almost certainly a bug (symmetrize with "
            f"(w + w.T) / 2 if the asymmetry is intended rounding)")


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

class PenaltyDef(NamedTuple):
    """One penalty family: its prox, value, and construction-time checks."""
    kind: str
    prox: Callable          # (spec, z, tau) -> unmasked elementwise prox
    value: Callable         # (spec, omega)  -> nonsmooth penalty value
    validate: Callable      # (spec) -> None, raises ValueError
    pallas: bool = False    # routable through the fused Pallas prox kernel
    has_shape: bool = False
    default_shape: float | None = None


_REGISTRY: dict[str, PenaltyDef] = {}


def register_penalty(defn: PenaltyDef, *, overwrite: bool = False) -> None:
    """Register a penalty family under its kind string."""
    if not overwrite and defn.kind in _REGISTRY:
        raise ValueError(f"penalty kind {defn.kind!r} already registered")
    _REGISTRY[defn.kind] = defn


def penalty_kinds() -> list[str]:
    return sorted(_REGISTRY)


def _get_def(kind: str) -> PenaltyDef:
    try:
        return _REGISTRY[kind]
    except KeyError:
        raise ValueError(
            f"unknown penalty kind {kind!r}; available: {penalty_kinds()}"
        ) from None


def _validate_common(spec: "PenaltySpec") -> None:
    _check_scalar("lam1", spec.lam1)
    _check_scalar("lam2", spec.lam2)


def _validate_l1(spec) -> None:
    _validate_common(spec)


def _validate_weighted(spec) -> None:
    _validate_common(spec)
    if spec.weights is None:
        raise ValueError("weighted_l1 needs a (p, p) weight matrix")
    _check_weights(spec.weights)


def _validate_scad(spec) -> None:
    _validate_common(spec)
    _check_shape_param("scad", spec.shape, 2.0)


def _validate_mcp(spec) -> None:
    _validate_common(spec)
    _check_shape_param("mcp", spec.shape, 1.0)


register_penalty(PenaltyDef("l1", _prox_l1, _value_l1, _validate_l1,
                            pallas=True))
register_penalty(PenaltyDef("elastic_net", _prox_l1, _value_l1,
                            _validate_l1, pallas=True))
register_penalty(PenaltyDef("weighted_l1", _prox_weighted_l1,
                            _value_weighted_l1, _validate_weighted,
                            pallas=True))
register_penalty(PenaltyDef("scad", _prox_scad, _value_scad, _validate_scad,
                            has_shape=True, default_shape=SCAD_DEFAULT_A))
register_penalty(PenaltyDef("mcp", _prox_mcp, _value_mcp, _validate_mcp,
                            has_shape=True, default_shape=MCP_DEFAULT_GAMMA))


# ---------------------------------------------------------------------------
# the spec
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclass(frozen=True, eq=False)
class PenaltySpec:
    """A penalty as data: kind (static) + traced numeric parameters.

    Construct through the validated factories (:meth:`l1`,
    :meth:`weighted_l1`, :meth:`scad`, :meth:`mcp`, :meth:`elastic_net`)
    or :func:`as_penalty`; the raw constructor skips validation so traced
    values can flow through jit/vmap/shard_map reconstruction.
    """
    kind: str
    lam1: Any
    lam2: Any = 0.0
    shape: Any = None       # scad ``a`` / mcp ``gamma``
    weights: Any = None     # (p, p) for weighted_l1

    # -- pytree protocol (kind + presence flags are static metadata) ----

    def tree_flatten(self):
        leaves = [self.lam1, self.lam2]
        if self.shape is not None:
            leaves.append(self.shape)
        if self.weights is not None:
            leaves.append(self.weights)
        return leaves, (self.kind, self.shape is not None,
                        self.weights is not None)

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        kind, has_shape, has_weights = aux
        it = iter(leaves)
        lam1, lam2 = next(it), next(it)
        shape = next(it) if has_shape else None
        weights = next(it) if has_weights else None
        return cls(kind, lam1, lam2, shape, weights)

    # -- validated factories --------------------------------------------

    @classmethod
    def l1(cls, lam1: float, lam2: float = 0.0) -> "PenaltySpec":
        spec = cls("l1", lam1, lam2)
        _get_def("l1").validate(spec)
        return spec

    @classmethod
    def elastic_net(cls, lam1: float, lam2: float) -> "PenaltySpec":
        spec = cls("elastic_net", lam1, lam2)
        _get_def("elastic_net").validate(spec)
        return spec

    @classmethod
    def weighted_l1(cls, lam1: float, weights,
                    lam2: float = 0.0) -> "PenaltySpec":
        spec = cls("weighted_l1", lam1, lam2, weights=weights)
        _get_def("weighted_l1").validate(spec)
        return spec

    @classmethod
    def scad(cls, lam1: float, a: float = SCAD_DEFAULT_A,
             lam2: float = 0.0) -> "PenaltySpec":
        spec = cls("scad", lam1, lam2, shape=a)
        _get_def("scad").validate(spec)
        return spec

    @classmethod
    def mcp(cls, lam1: float, gamma: float = MCP_DEFAULT_GAMMA,
            lam2: float = 0.0) -> "PenaltySpec":
        spec = cls("mcp", lam1, lam2, shape=gamma)
        _get_def("mcp").validate(spec)
        return spec

    # -- unvalidated functional updates (jit/vmap-safe) -----------------

    def with_lam1(self, lam1) -> "PenaltySpec":
        """Replace the penalty strength (scalar or a (B,) lane vector)."""
        return dataclasses.replace(self, lam1=lam1)

    def with_weights(self, weights) -> "PenaltySpec":
        return dataclasses.replace(self, weights=weights)

    # -- solver interface -----------------------------------------------

    @property
    def pallas_ok(self) -> bool:
        """Whether the fused Pallas prox kernel implements this prox
        (soft-threshold family: scalar or weight-lane thresholds)."""
        return _get_def(self.kind).pallas

    def prox(self, z, step, diag_mask=None):
        """Elementwise prox of ``step * penalty`` with the diagonal exempt.

        ``diag_mask`` is the layout-specific 0/1 diagonal indicator (the
        distributed drivers pass their panel masks); ``None`` builds the
        square identity for a full (p, p) iterate."""
        out = _get_def(self.kind).prox(self, z, step)
        if diag_mask is None:
            diag_mask = jnp.eye(z.shape[-1], dtype=z.dtype)
        return out * (1.0 - diag_mask) + z * diag_mask

    def value(self, omega):
        """Nonsmooth penalty value h(Omega) over the off-diagonal (the
        smooth lam2 ridge lives in g, not here)."""
        return _get_def(self.kind).value(self, omega)

    # -- batching helpers -----------------------------------------------

    def _expected_ndims(self) -> list[int]:
        """Per-leaf base ndim in ``tree_flatten`` order (scalars 0,
        weights 2); a leaf with one extra leading axis of length B is a
        per-lane parameter."""
        dims = [0, 0]
        if self.shape is not None:
            dims.append(0)
        if self.weights is not None:
            dims.append(2)
        return dims

    def batch_axes(self, b: int) -> list:
        """Per-leaf ``jax.vmap`` axes in ``tree_flatten`` order: 0 on
        leaves carrying a leading (B,) lane axis, None on shared leaves.
        (A flat list, to be splatted alongside ``tree_flatten`` leaves —
        a PenaltySpec-shaped axes tree would not round-trip, since
        flattening re-derives the optional-field structure from None.)"""
        leaves, _ = jax.tree_util.tree_flatten(self)
        return [
            0 if (getattr(leaf, "ndim", 0) == nd + 1
                  and leaf.shape[0] == b) else None
            for leaf, nd in zip(leaves, self._expected_ndims())
        ]

    def lane(self, i: int, b: int) -> "PenaltySpec":
        """The scalar spec lane ``i`` of a (B,)-batched spec (shared
        leaves pass through)."""
        leaves, treedef = jax.tree_util.tree_flatten(self)
        picked = [
            leaf[i] if (getattr(leaf, "ndim", 0) == nd + 1
                        and leaf.shape[0] == b) else leaf
            for leaf, nd in zip(leaves, self._expected_ndims())
        ]
        return jax.tree_util.tree_unflatten(treedef, picked)

    # -- misc ------------------------------------------------------------

    def label(self) -> str:
        """Canonical display/parse string: 'l1', 'scad:3.7', ..."""
        if self.shape is not None and not _is_tracer(self.shape):
            arr = np.asarray(self.shape)
            if arr.ndim == 0:
                return f"{self.kind}:{float(arr):g}"
        return self.kind

    def __repr__(self) -> str:        # compact, array-safe
        parts = [f"kind={self.kind!r}", f"lam1={self.lam1!r}"]
        if not (np.isscalar(self.lam2) and float(self.lam2) == 0.0):
            parts.append(f"lam2={self.lam2!r}")
        if self.shape is not None:
            parts.append(f"shape={self.shape!r}")
        if self.weights is not None:
            parts.append(f"weights=<{getattr(self.weights, 'shape', '?')}>")
        return f"PenaltySpec({', '.join(parts)})"


# ---------------------------------------------------------------------------
# parsing / normalization
# ---------------------------------------------------------------------------

def parse_penalty(text: str) -> tuple[str, float | None]:
    """Parse a penalty string form: ``"l1"``, ``"scad"``, ``"scad:3.7"``,
    ``"mcp:2.5"``, ... Returns ``(kind, shape_or_None)``."""
    if not isinstance(text, str) or not text:
        raise ValueError(f"penalty string must be non-empty, got {text!r}")
    kind, sep, arg = text.partition(":")
    defn = _get_def(kind)
    if not sep:
        return kind, defn.default_shape
    if not defn.has_shape:
        raise ValueError(
            f"penalty {kind!r} takes no shape parameter (got {text!r})")
    try:
        shape = float(arg)
    except ValueError:
        raise ValueError(
            f"bad shape parameter in penalty string {text!r}: {arg!r} is "
            f"not a number") from None
    return kind, shape


def as_penalty(penalty=None, *, lam1=None, lam2=None,
               weights=None) -> PenaltySpec:
    """Normalize every accepted penalty form to a validated spec.

    ``penalty`` may be a :class:`PenaltySpec` (returned as-is; combining
    it with lam1/lam2/weights kwargs is an error), a string form
    (``"l1"``, ``"scad:3.7"``, ... — strength comes from ``lam1``/
    ``lam2``, and ``lam1`` is REQUIRED: a silently-defaulted strength
    would hand back a converged but wrongly-regularized estimate), a
    bare number (treated as lam1 of an l1 penalty), or None (l1 from
    the kwargs — the legacy ``lam1=``/``lam2=`` shim).
    """
    if isinstance(penalty, PenaltySpec):
        if lam1 is not None or lam2 is not None or weights is not None:
            raise ValueError(
                "a PenaltySpec already carries lam1/lam2/weights; pass "
                "either the spec or the scalar kwargs, not both")
        return penalty
    if penalty is not None and not isinstance(penalty, str):
        if lam1 is not None:
            raise ValueError("pass either a numeric penalty (= lam1) or "
                             "lam1=, not both")
        lam1, penalty = penalty, None
    if lam1 is None:
        raise TypeError(
            "the penalty strength lam1 is required alongside a penalty "
            "kind (there is no safe default)")
    lam2 = 0.0 if lam2 is None else lam2
    if penalty is None:
        if weights is not None:
            return PenaltySpec.weighted_l1(lam1, weights, lam2)
        return PenaltySpec.l1(lam1, lam2)
    kind, shape = parse_penalty(penalty)
    if kind == "weighted_l1":
        if weights is None:
            raise ValueError(
                'penalty="weighted_l1" needs the weight matrix: pass a '
                "PenaltySpec.weighted_l1(lam1, weights) instead of the "
                "string form")
        return PenaltySpec.weighted_l1(lam1, weights, lam2)
    if weights is not None:
        raise ValueError(f"penalty {kind!r} does not take weights")
    spec = PenaltySpec(kind, lam1, lam2, shape=shape)
    _get_def(kind).validate(spec)
    return spec


def normalize_penalty(penalty, lam1=None, lam2=None) -> PenaltySpec:
    """The one solver-entry normalization (solve_reference, fit_cov/obs,
    the batched engines): a :class:`PenaltySpec` passes through (lam1
    alongside it is an error), a string form is validated with strength
    from lam1/lam2, and the legacy floats build a raw l1 spec WITHOUT
    validation (lam1 may be a tracer inside vmapped lanes)."""
    if penalty is None:
        if lam1 is None:
            raise TypeError("pass lam1 (or penalty=)")
        return PenaltySpec("l1", lam1, 0.0 if lam2 is None else lam2)
    if isinstance(penalty, str):
        return as_penalty(penalty, lam1=lam1, lam2=lam2)
    if lam1 is not None:
        raise ValueError(
            "a PenaltySpec already carries lam1; pass one or the other")
    return as_penalty(penalty)


# ---------------------------------------------------------------------------
# adaptive lasso + numpy-side reporting value
# ---------------------------------------------------------------------------

def adaptive_weights(omega, eps: float = 1e-3,
                     normalize: bool = True) -> np.ndarray:
    """Stage-2 adaptive-lasso weights ``1 / (|omega_hat| + eps)``.

    ``omega_hat`` is symmetrized first (fit iterates are symmetric only to
    solver tolerance, and weight validation rightly rejects asymmetry);
    the diagonal weight is zeroed (it is unpenalized anyway).  With
    ``normalize`` the off-diagonal weights are rescaled to mean 1 so a
    stage-2 lam1 grid lives on the same scale as the stage-1 grid."""
    om = np.abs(np.asarray(omega, np.float64))
    if om.ndim != 2 or om.shape[0] != om.shape[1]:
        raise ValueError(f"omega must be square (p, p), got {om.shape}")
    if not (eps > 0):
        raise ValueError(f"eps must be > 0, got {eps}")
    sym = 0.5 * (om + om.T)
    w = 1.0 / (sym + eps)
    np.fill_diagonal(w, 0.0)
    if normalize:
        n_off = om.shape[0] * (om.shape[0] - 1)
        total = float(w.sum())
        if total > 0:
            w *= n_off / total
    return w


def penalty_value_np(spec: PenaltySpec, omega) -> float:
    """Host-side penalty value for FitReport objectives (numpy, so
    reporting never round-trips through the device dtype).  The l1 path
    accumulates in the estimate's own dtype, matching the pre-spec
    reporting bit-for-bit."""
    lam1 = float(np.asarray(spec.lam1))
    if spec.kind in ("l1", "elastic_net"):
        om = np.asarray(omega)
        return lam1 * float(np.sum(np.abs(om)) - np.sum(np.abs(np.diag(om))))
    om = np.asarray(omega, np.float64)
    av = np.abs(om)
    off = ~np.eye(om.shape[0], dtype=bool)
    if spec.kind == "weighted_l1":
        w = np.asarray(spec.weights, np.float64)
        contrib = np.zeros_like(av)
        nz = av != 0.0                  # inf * 0 must contribute 0, not nan
        contrib[nz] = w[nz] * av[nz]
        return lam1 * float(np.sum(contrib[off]))
    shp = float(np.asarray(spec.shape)) if spec.shape is not None else None
    if spec.kind == "scad":
        quad = (2.0 * shp * lam1 * av - av * av - lam1 * lam1) \
            / (2.0 * (shp - 1.0))
        tail = 0.5 * lam1 * lam1 * (shp + 1.0)
        vals = np.where(av <= lam1, lam1 * av,
                        np.where(av <= shp * lam1, quad, tail))
    elif spec.kind == "mcp":
        vals = np.where(av <= shp * lam1,
                        lam1 * av - av * av / (2.0 * shp),
                        0.5 * shp * lam1 * lam1)
    else:
        return float(np.asarray(spec.value(jnp.asarray(om))))
    return float(np.sum(vals[off]))
