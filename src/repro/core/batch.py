"""Batched multi-problem solve engine: one XLA program, many solves.

The paper's end-to-end workflow is never one solve — Section 5 sweeps a
tuning-parameter grid, and the BIGQUIC/pseudolikelihood lines of work all
select lambda by fitting whole regularization paths.  Running that grid as
a Python loop of sequential solves leaves the hardware idle between path
points.  This module instead ``vmap``s the generic ``prox_gradient`` loop
(``core.prox``) over a stacked problem axis, so an entire grid lowers to
ONE compiled program:

  * ``solve_path_batched`` — a lam1 VECTOR against shared data (the
    regularization path / model-selection sweep).  The data matrix is
    closed over (broadcast, one copy in memory); only the penalty and the
    iterates carry a batch axis.
  * ``solve_batch`` — stacked ``(B, ...)`` datasets (multi-subject /
    multi-tenant workloads), each with its own lam1/lam2 if desired.

Correctness of the batched ``while_loop``s: under vmap a while_loop runs
until EVERY lane's condition is false and the body executes for all lanes
each round, so ``prox_gradient`` freezes its finished lanes (accepted line
searches, converged/stalled outer iterations) by carry masking — a
finished problem holds its state bit-exactly, its ``iters``/``ls_total``
counters stop, and stragglers keep iterating.  Per-problem results
(``converged``, ``stalled``, ``iters``, ...) are therefore identical to
what B sequential solves would report.

Wall-clock cost of one batched step is the max over ACTIVE lanes, not the
sum — on parallel hardware the grid finishes in roughly the time of its
slowest problem.  The engine runs the dense product path: the block-sparse
dispatch's ``lax.switch`` on per-lane observed density would lower to
executing every branch under vmap, so routing is a per-problem (sequential
/ distributed) feature.

This is the single-device throughput substrate; sharded batches
(pmap-of-shard_map) layer on top of the same carry-masked loop.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .prox import ProxResult, cov_ops, obs_ops, prox_gradient

_SOLVER_STATICS = ("variant", "tol", "max_iters", "max_ls", "warm_start_tau")


def _variant_ops(variant: str):
    if variant == "cov":
        return cov_ops()
    if variant == "obs":
        return obs_ops()
    raise ValueError(f"unknown variant {variant!r}")


def _data_of(arr, lam2, variant: str):
    key = "s" if variant == "cov" else "x"
    return {key: arr, "lam2": jnp.asarray(lam2, arr.dtype)}


@partial(jax.jit, static_argnames=_SOLVER_STATICS)
def solve_path_batched(
    s_or_x: jax.Array,
    lam1_grid: jax.Array,
    lam2: float = 0.0,
    *,
    omega0: jax.Array | None = None,
    variant: str = "cov",
    tol: float = 1e-5,
    max_iters: int = 500,
    max_ls: int = 30,
    warm_start_tau: bool = False,
) -> ProxResult:
    """Solve a whole lam1 grid against SHARED data as one compiled program.

    ``s_or_x`` is the (p, p) sample covariance (variant="cov") or the
    (n, p) observations (variant="obs"), broadcast across the batch (one
    copy); ``lam1_grid`` is the (B,) penalty vector.  ``omega0`` may be
    None (identity start for every point), a single (p, p) warm start
    shared by all points, or a stacked (B, p, p) per-point start.  Returns
    a :class:`ProxResult` whose every field carries a leading (B,) axis;
    ``lam1_grid`` and ``omega0`` are traced, so re-solving a same-length
    grid reuses the compiled program.
    """
    lam1_grid = jnp.asarray(lam1_grid)
    if lam1_grid.ndim != 1:
        raise ValueError(f"lam1_grid must be 1-D, got shape {lam1_grid.shape}")
    ops = _variant_ops(variant)
    data = _data_of(s_or_x, lam2, variant)
    p = s_or_x.shape[-1]
    if omega0 is None:
        omega0 = jnp.eye(p, dtype=s_or_x.dtype)
        om_axis = None
    else:
        omega0 = jnp.asarray(omega0, s_or_x.dtype)
        om_axis = 0 if omega0.ndim == 3 else None

    def one(om0, lam1):
        return prox_gradient(
            om0, data, ops, lam1=lam1, tol=tol, max_iters=max_iters,
            max_ls=max_ls, warm_start_tau=warm_start_tau)

    return jax.vmap(one, in_axes=(om_axis, 0))(omega0, lam1_grid)


@partial(jax.jit, static_argnames=_SOLVER_STATICS)
def solve_batch(
    s_or_x: jax.Array,
    lam1: jax.Array,
    lam2: jax.Array = 0.0,
    *,
    omega0: jax.Array | None = None,
    variant: str = "cov",
    tol: float = 1e-5,
    max_iters: int = 500,
    max_ls: int = 30,
    warm_start_tau: bool = False,
) -> ProxResult:
    """Solve B stacked independent problems as one compiled program.

    ``s_or_x`` is (B, p, p) stacked covariances (variant="cov") or
    (B, n, p) stacked observation matrices (variant="obs") — every problem
    shares one shape, the server-side bucketing invariant.  ``lam1`` and
    ``lam2`` are scalars (shared) or (B,) vectors (per-problem);
    ``omega0`` is None, one shared (p, p) start, or stacked (B, p, p).
    Returns a :class:`ProxResult` with a leading (B,) axis on every field.
    """
    s_or_x = jnp.asarray(s_or_x)
    if s_or_x.ndim != 3:
        raise ValueError(
            f"solve_batch expects stacked (B, n|p, p) data, got shape "
            f"{s_or_x.shape}")
    b = s_or_x.shape[0]
    p = s_or_x.shape[-1]
    lam1 = jnp.broadcast_to(jnp.asarray(lam1, s_or_x.dtype), (b,))
    lam2 = jnp.broadcast_to(jnp.asarray(lam2, s_or_x.dtype), (b,))
    if omega0 is None:
        omega0 = jnp.eye(p, dtype=s_or_x.dtype)
        om_axis = None
    else:
        omega0 = jnp.asarray(omega0, s_or_x.dtype)
        om_axis = 0 if omega0.ndim == 3 else None

    def one(om0, arr, l1, l2):
        return prox_gradient(
            om0, _data_of(arr, l2, variant), _variant_ops(variant),
            lam1=l1, tol=tol, max_iters=max_iters, max_ls=max_ls,
            warm_start_tau=warm_start_tau)

    return jax.vmap(one, in_axes=(om_axis, 0, 0, 0))(
        omega0, s_or_x, lam1, lam2)
