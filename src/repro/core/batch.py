"""Batched multi-problem solve engine: one XLA program, many solves.

The paper's end-to-end workflow is never one solve — Section 5 sweeps a
tuning-parameter grid, and the BIGQUIC/pseudolikelihood lines of work all
select lambda by fitting whole regularization paths.  Running that grid as
a Python loop of sequential solves leaves the hardware idle between path
points.  This module instead ``vmap``s the generic ``prox_gradient`` loop
(``core.prox``) over a stacked problem axis, so an entire grid lowers to
ONE compiled program:

  * ``solve_path_batched`` — a lam1 VECTOR against shared data (the
    regularization path / model-selection sweep).  The data matrix is
    closed over (broadcast, one copy in memory); only the penalty and the
    iterates carry a batch axis.
  * ``solve_batch`` — stacked ``(B, ...)`` datasets (multi-subject /
    multi-tenant workloads), each with its own penalty if desired.

Penalties are :class:`repro.core.penalty.PenaltySpec` pytrees whose
numeric leaves are traced, so EVERY penalty parameter — not just lam1 —
may differ per lane inside the one compiled program: a spec leaf with a
leading (B,) axis (e.g. per-lane SCAD shapes, per-lane lam1) is vmapped,
shared leaves (e.g. one weight matrix) broadcast without copies
(``PenaltySpec.batch_axes``).  The legacy ``lam1``/``lam2`` arguments
build the equivalent l1 spec, bit-identically.

Correctness of the batched ``while_loop``s: under vmap a while_loop runs
until EVERY lane's condition is false and the body executes for all lanes
each round, so ``prox_gradient`` freezes its finished lanes (accepted line
searches, converged/stalled outer iterations) by carry masking — a
finished problem holds its state bit-exactly, its ``iters``/``ls_total``
counters stop, and stragglers keep iterating.  Per-problem results
(``converged``, ``stalled``, ``iters``, ...) are therefore identical to
what B sequential solves would report.

Wall-clock cost of one batched step is the max over ACTIVE lanes, not the
sum — on parallel hardware the grid finishes in roughly the time of its
slowest problem.  The engine runs the dense product path: the block-sparse
dispatch's ``lax.switch`` on per-lane observed density would lower to
executing every branch under vmap, so routing is a per-problem (sequential
/ distributed) feature.

This is the single-device throughput substrate; sharded batches
(pmap-of-shard_map) layer on top of the same carry-masked loop.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .penalty import PenaltySpec, normalize_penalty
from .prox import ProxResult, cov_ops, obs_ops, prox_gradient

_SOLVER_STATICS = ("variant", "tol", "max_iters", "max_ls", "warm_start_tau")


def _variant_ops(variant: str):
    if variant == "cov":
        return cov_ops()
    if variant == "obs":
        return obs_ops()
    raise ValueError(f"unknown variant {variant!r}")


def _data_of(arr, lam2, variant: str):
    key = "s" if variant == "cov" else "x"
    return {key: arr, "lam2": jnp.asarray(lam2, arr.dtype)}


def _resolve_spec(penalty, lam1, lam2) -> tuple[PenaltySpec, object]:
    """(spec, ridge) from either a penalty spec/string or legacy floats.
    The smooth ridge is returned separately (it feeds the per-lane data
    dict exactly like the pre-spec plumbing)."""
    spec = normalize_penalty(penalty, lam1, lam2)
    return spec, spec.lam2


def _omega0_axis(omega0, p, dtype):
    if omega0 is None:
        return jnp.eye(p, dtype=dtype), None
    omega0 = jnp.asarray(omega0, dtype)
    return omega0, (0 if omega0.ndim == 3 else None)


@partial(jax.jit, static_argnames=_SOLVER_STATICS)
def _solve_path_batched(
    s_or_x: jax.Array,
    penalty: PenaltySpec,
    ridge,
    omega0,
    *,
    variant: str,
    tol: float,
    max_iters: int,
    max_ls: int,
    warm_start_tau: bool,
) -> ProxResult:
    ops = _variant_ops(variant)
    data = _data_of(s_or_x, ridge, variant)
    omega0, om_axis = _omega0_axis(omega0, s_or_x.shape[-1], s_or_x.dtype)
    b = penalty.lam1.shape[0]
    pleaves, ptree = jax.tree_util.tree_flatten(penalty)

    def one(om0, *pl):
        pen = jax.tree_util.tree_unflatten(ptree, pl)
        return prox_gradient(
            om0, data, ops, penalty=pen, tol=tol, max_iters=max_iters,
            max_ls=max_ls, warm_start_tau=warm_start_tau)

    return jax.vmap(one, in_axes=(om_axis, *penalty.batch_axes(b)))(
        omega0, *pleaves)


def solve_path_batched(
    s_or_x: jax.Array,
    lam1_grid: jax.Array,
    lam2: float = 0.0,
    *,
    penalty: PenaltySpec | str | None = None,
    omega0: jax.Array | None = None,
    variant: str = "cov",
    tol: float = 1e-5,
    max_iters: int = 500,
    max_ls: int = 30,
    warm_start_tau: bool = False,
) -> ProxResult:
    """Solve a whole lam1 grid against SHARED data as one compiled program.

    ``s_or_x`` is the (p, p) sample covariance (variant="cov") or the
    (n, p) observations (variant="obs"), broadcast across the batch (one
    copy); ``lam1_grid`` is the (B,) penalty vector.  ``penalty`` swaps
    the penalty family for the whole grid (its lam1 is replaced by the
    grid; other parameters — SCAD shape, a weight matrix — are shared
    across lanes).  ``omega0`` may be None (identity start for every
    point), a single (p, p) warm start shared by all points, or a stacked
    (B, p, p) per-point start.  Returns a :class:`ProxResult` whose every
    field carries a leading (B,) axis; all penalty parameters and
    ``omega0`` are traced, so re-solving a same-length grid reuses the
    compiled program.
    """
    lam1_grid = jnp.asarray(lam1_grid)
    if lam1_grid.ndim != 1:
        raise ValueError(f"lam1_grid must be 1-D, got shape {lam1_grid.shape}")
    if penalty is None:
        spec, ridge = PenaltySpec("l1", lam1_grid), lam2
    else:
        # the grid IS the strength here, so a string form needs only its
        # kind/shape — feed a placeholder lam1 that the grid replaces
        base, ridge = _resolve_spec(
            penalty, 0.0 if isinstance(penalty, str) else None, lam2)
        spec = base.with_lam1(lam1_grid)
    return _solve_path_batched(
        s_or_x, spec, ridge, omega0, variant=variant, tol=tol,
        max_iters=max_iters, max_ls=max_ls, warm_start_tau=warm_start_tau)


@partial(jax.jit, static_argnames=_SOLVER_STATICS)
def _solve_batch(
    s_or_x: jax.Array,
    penalty: PenaltySpec,
    ridge: jax.Array,
    omega0,
    *,
    variant: str,
    tol: float,
    max_iters: int,
    max_ls: int,
    warm_start_tau: bool,
) -> ProxResult:
    b = s_or_x.shape[0]
    omega0, om_axis = _omega0_axis(omega0, s_or_x.shape[-1], s_or_x.dtype)
    pleaves, ptree = jax.tree_util.tree_flatten(penalty)

    def one(om0, arr, l2, *pl):
        pen = jax.tree_util.tree_unflatten(ptree, pl)
        return prox_gradient(
            om0, _data_of(arr, l2, variant), _variant_ops(variant),
            penalty=pen, tol=tol, max_iters=max_iters, max_ls=max_ls,
            warm_start_tau=warm_start_tau)

    return jax.vmap(one, in_axes=(om_axis, 0, 0, *penalty.batch_axes(b)))(
        omega0, s_or_x, ridge, *pleaves)


def solve_batch(
    s_or_x: jax.Array,
    lam1: jax.Array | None = None,
    lam2: jax.Array = 0.0,
    *,
    penalty: PenaltySpec | str | None = None,
    omega0: jax.Array | None = None,
    variant: str = "cov",
    tol: float = 1e-5,
    max_iters: int = 500,
    max_ls: int = 30,
    warm_start_tau: bool = False,
) -> ProxResult:
    """Solve B stacked independent problems as one compiled program.

    ``s_or_x`` is (B, p, p) stacked covariances (variant="cov") or
    (B, n, p) stacked observation matrices (variant="obs") — every problem
    shares one shape, the server-side bucketing invariant.  ``lam1`` and
    ``lam2`` are scalars (shared) or (B,) vectors (per-problem);
    equivalently ``penalty`` carries the whole spec, and ANY of its
    numeric leaves may be (B,)-batched for per-lane penalty parameters
    (e.g. per-lane SCAD shapes) inside the single compiled program.
    ``omega0`` is None, one shared (p, p) start, or stacked (B, p, p).
    Returns a :class:`ProxResult` with a leading (B,) axis on every field.
    """
    s_or_x = jnp.asarray(s_or_x)
    if s_or_x.ndim != 3:
        raise ValueError(
            f"solve_batch expects stacked (B, n|p, p) data, got shape "
            f"{s_or_x.shape}")
    b = s_or_x.shape[0]
    spec, ridge = _resolve_spec(penalty, lam1, lam2)
    lam1_b = jnp.broadcast_to(jnp.asarray(spec.lam1, s_or_x.dtype), (b,))
    spec = spec.with_lam1(lam1_b)
    ridge_b = jnp.broadcast_to(jnp.asarray(ridge, s_or_x.dtype), (b,))
    return _solve_batch(
        s_or_x, spec, ridge_b, omega0, variant=variant, tol=tol,
        max_iters=max_iters, max_ls=max_ls, warm_start_tau=warm_start_tau)


# ---------------------------------------------------------------------------
# analysis manifest (repro.analysis.jaxprpass)
# ---------------------------------------------------------------------------

def _analysis_cov(p):
    return jnp.eye(p, dtype=jnp.float64) + 0.05 * jnp.ones((p, p),
                                                           jnp.float64)


def _analysis_path():
    p, b = 6, 3
    spec = PenaltySpec("l1", jnp.linspace(0.1, 0.3, b, dtype=jnp.float64),
                       jnp.asarray(0.0, jnp.float64))
    fn = partial(_solve_path_batched, variant="cov", tol=1e-3, max_iters=5,
                 max_ls=5, warm_start_tau=False)
    return {"fn": fn,
            "args": (_analysis_cov(p), spec, jnp.asarray(0.0, jnp.float64),
                     None)}


def _analysis_path_reuse():
    s = _analysis_cov(6)

    def run(lo):
        grid = jnp.linspace(lo, lo + 0.2, 3, dtype=jnp.float64)
        res = solve_path_batched(s, grid, tol=1e-3, max_iters=4, max_ls=4)
        return res.omega.block_until_ready()

    return {"watched": {"core.batch._solve_path_batched":
                        _solve_path_batched},
            "calls": [partial(run, 0.10), partial(run, 0.15),
                      partial(run, 0.20)]}


def _analysis_batch():
    p, b = 6, 2
    s = jnp.stack([_analysis_cov(p)] * b)
    spec = PenaltySpec("l1", jnp.linspace(0.1, 0.2, b, dtype=jnp.float64),
                       jnp.asarray(0.0, jnp.float64))
    ridge = jnp.zeros((b,), jnp.float64)
    fn = partial(_solve_batch, variant="cov", tol=1e-3, max_iters=5,
                 max_ls=5, warm_start_tau=False)
    return {"fn": fn, "args": (s, spec, ridge, None)}


def _analysis_batch_reuse():
    s = jnp.stack([_analysis_cov(6)] * 2)

    def run(lam1):
        res = solve_batch(s, jnp.asarray([lam1, lam1 + 0.05], jnp.float64),
                          tol=1e-3, max_iters=4, max_ls=4)
        return res.omega.block_until_ready()

    return {"watched": {"core.batch._solve_batch": _solve_batch},
            "calls": [partial(run, 0.10), partial(run, 0.16),
                      partial(run, 0.22)]}


#: the batched lambda-path and multi-problem engines: one compiled
#: program per (shape, penalty kind, statics) key is THE contract here
ANALYSIS_ENTRIES = [
    {"name": "core.batch.solve_path_batched",
     "path": "src/repro/core/batch.py", "axis_names": (),
     "build": _analysis_path, "reuse": _analysis_path_reuse},
    {"name": "core.batch.solve_batch", "path": "src/repro/core/batch.py",
     "axis_names": (), "build": _analysis_batch,
     "reuse": _analysis_batch_reuse},
]
