"""Batched multi-problem solve engine: one XLA program, many solves.

The paper's end-to-end workflow is never one solve — Section 5 sweeps a
tuning-parameter grid, and the BIGQUIC/pseudolikelihood lines of work all
select lambda by fitting whole regularization paths.  Running that grid as
a Python loop of sequential solves leaves the hardware idle between path
points.  This module lowers an entire grid to compiled batched programs:

  * ``solve_path_batched`` — a lam1 VECTOR against shared data (the
    regularization path / model-selection sweep).  The data matrix is
    closed over (broadcast, one copy in memory); only the penalty and the
    iterates carry a batch axis.
  * ``solve_batch`` — stacked ``(B, ...)`` datasets (multi-subject /
    multi-tenant workloads), each with its own penalty if desired.

Two execution schedules share the same per-lane math:

``schedule="compact"`` (default) — the segmented compaction engine.  The
solve is flattened into FLAT STEPS (one line-search trial per lane per
step, replaying the sequential trial sequence exactly: per-lane step
sizes, per-lane backtracking, per-lane convergence).  Steps run in
fixed-size jitted chunks (``_path_chunk``); at every chunk boundary the
host gathers the still-live lanes to the front, pads to the nearest
capacity tier ({1, 2, 3} x powers of two, so the whole run compiles a
handful of programs total) and launches the next chunk — per-chunk flops
scale with ACTIVE lanes, not B.  Lanes are scheduled in difficulty order
(``core.costmodel.predict_path_iters``) so same-segment lanes converge
together, and finished lanes are harvested at the boundary they complete
in.  Gathers are pure row moves and every trial is the factored
``core.prox.ls_trial``, so per-lane iterates, iteration counts and
line-search totals are BIT-EXACTLY those of B sequential solves (the
compaction test asserts array equality in f64).

``schedule="monolithic"`` — the original single-program engine: ``vmap``
of the generic ``prox_gradient`` loop, one carry-masked ``while_loop``
where converged lanes freeze bit-exactly but still burn flops.  Kept as
the zero-host-sync fallback (one dispatch for the whole grid) and as the
reference the compaction engine is asserted against.

Penalties are :class:`repro.core.penalty.PenaltySpec` pytrees whose
numeric leaves are traced, so EVERY penalty parameter — not just lam1 —
may differ per lane inside one compiled program: a spec leaf with a
leading (B,) axis (e.g. per-lane SCAD shapes, per-lane lam1) is vmapped,
shared leaves (e.g. one weight matrix) broadcast without copies
(``PenaltySpec.batch_axes``).  The legacy ``lam1``/``lam2`` arguments
build the equivalent l1 spec, bit-identically.

``tau_schedule`` selects the line-search step-size schedule
(:data:`repro.core.prox.TAU_SCHEDULES`): "restart" is the paper's and is
bit-exact against default sequential solves; "greedy" cuts total trials
~40% at identical outer iterations (assert bit-exactness against a
sequential solve run with the SAME schedule).

``use_pallas`` routes the compact engine's flat step through the fused
path-step megakernel (``kernels.pathstep``): gradient + prox + acceptance
dot products + occupancy in one pass over the tiles (Cov variant,
soft-threshold penalty family; others fall back to the jnp path).  The
kernel's tile-order reductions are not bit-identical to ``jnp.sum``, so
this trades exact reproducibility for fused dispatch — leave it off when
asserting bit-exactness.

Wall-clock: the compact engine's cost is ``sum over flat steps of the
padded capacity`` times the per-lane trial cost, so a path whose lanes
finish at different times no longer pays B times its slowest lane —
see ``benchmarks/path_batch.py`` for the measured occupancy timeline and
the speedup gate.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import numpy as np

import jax
import jax.numpy as jnp

from . import costmodel, matops
from .penalty import PenaltySpec, normalize_penalty
from .prox import (
    ProxResult,
    cov_ops,
    ls_trial,
    obs_ops,
    prox_gradient,
    resolve_tau_schedule,
    tau_first,
    tau_start,
)

_SOLVER_STATICS = ("variant", "tol", "max_iters", "max_ls", "warm_start_tau",
                   "tau_schedule")


class _NoSpan:
    """Do-nothing stand-in for a tracer span when obs is inactive."""
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def note(self, **attrs):
        return self


_NO_SPAN = _NoSpan()


def _obs_span(name: str, **attrs):
    """Tracer span IF the obs subsystem is active (``repro.obs.trace``
    already imported and scoped by the caller's backend); the shared
    no-op otherwise — the engine itself never imports ``repro.obs``, so
    ``obs="off"`` runs are byte-identical to the pre-obs code path."""
    import sys
    tr = sys.modules.get("repro.obs.trace")
    if tr is None:
        return _NO_SPAN
    return tr.get_tracer().span(name, cat="batch", level="trace", **attrs)


def _obs_event(name: str, **attrs) -> None:
    import sys
    tr = sys.modules.get("repro.obs.trace")
    if tr is not None:
        tr.get_tracer().event(name, cat="batch", level="trace", **attrs)

#: execution schedules of the batched engine
BATCH_SCHEDULES = ("compact", "monolithic")

#: flat steps per compiled chunk: boundaries are where the host repacks
#: live lanes, so smaller chunks compact sooner but sync more often
DEFAULT_CHUNK = 32


def _variant_ops(variant: str):
    if variant == "cov":
        return cov_ops()
    if variant == "obs":
        return obs_ops()
    raise ValueError(f"unknown variant {variant!r}")


def _data_of(arr, lam2, variant: str):
    key = "s" if variant == "cov" else "x"
    return {key: arr, "lam2": jnp.asarray(lam2, arr.dtype)}


def _resolve_spec(penalty, lam1, lam2) -> tuple[PenaltySpec, object]:
    """(spec, ridge) from either a penalty spec/string or legacy floats.
    The smooth ridge is returned separately (it feeds the per-lane data
    dict exactly like the pre-spec plumbing)."""
    spec = normalize_penalty(penalty, lam1, lam2)
    return spec, spec.lam2


def _omega0_axis(omega0, p, dtype):
    if omega0 is None:
        return jnp.eye(p, dtype=dtype), None
    omega0 = jnp.asarray(omega0, dtype)
    return omega0, (0 if omega0.ndim == 3 else None)


# ---------------------------------------------------------------------------
# monolithic schedule: one vmapped while_loop for the whole grid
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=_SOLVER_STATICS)
def _solve_path_batched(
    s_or_x: jax.Array,
    penalty: PenaltySpec,
    ridge,
    omega0,
    *,
    variant: str,
    tol: float,
    max_iters: int,
    max_ls: int,
    warm_start_tau: bool,
    tau_schedule: str | None = None,
) -> ProxResult:
    ops = _variant_ops(variant)
    data = _data_of(s_or_x, ridge, variant)
    omega0, om_axis = _omega0_axis(omega0, s_or_x.shape[-1], s_or_x.dtype)
    b = penalty.lam1.shape[0]
    pleaves, ptree = jax.tree_util.tree_flatten(penalty)

    def one(om0, *pl):
        pen = jax.tree_util.tree_unflatten(ptree, pl)
        return prox_gradient(
            om0, data, ops, penalty=pen, tol=tol, max_iters=max_iters,
            max_ls=max_ls, warm_start_tau=warm_start_tau,
            tau_schedule=tau_schedule)

    return jax.vmap(one, in_axes=(om_axis, *penalty.batch_axes(b)))(
        omega0, *pleaves)


@partial(jax.jit, static_argnames=_SOLVER_STATICS)
def _solve_batch(
    s_or_x: jax.Array,
    penalty: PenaltySpec,
    ridge: jax.Array,
    omega0,
    *,
    variant: str,
    tol: float,
    max_iters: int,
    max_ls: int,
    warm_start_tau: bool,
    tau_schedule: str | None = None,
) -> ProxResult:
    b = s_or_x.shape[0]
    omega0, om_axis = _omega0_axis(omega0, s_or_x.shape[-1], s_or_x.dtype)
    pleaves, ptree = jax.tree_util.tree_flatten(penalty)

    def one(om0, arr, l2, *pl):
        pen = jax.tree_util.tree_unflatten(ptree, pl)
        return prox_gradient(
            om0, _data_of(arr, l2, variant), _variant_ops(variant),
            penalty=pen, tol=tol, max_iters=max_iters, max_ls=max_ls,
            warm_start_tau=warm_start_tau, tau_schedule=tau_schedule)

    return jax.vmap(one, in_axes=(om_axis, 0, 0, *penalty.batch_axes(b)))(
        omega0, s_or_x, ridge, *pleaves)


# ---------------------------------------------------------------------------
# compact schedule: segmented compaction over flat line-search steps
# ---------------------------------------------------------------------------

class _Lanes(NamedTuple):
    """Per-lane flat-step state (leading axis = padded capacity C)."""
    omega: jax.Array       # (C, p, p) current iterate
    aux: jax.Array         # (C, p, p) W = Omega S  /  (C, p, n) Y = Omega X^T
    g_val: jax.Array       # (C,) smooth objective at omega
    tau_try: jax.Array     # (C,) step size of the NEXT line-search trial
    delta: jax.Array       # (C,) last relative change (inf before 1st step)
    step: jax.Array        # (C,) int32 outer iterations completed
    trials: jax.Array      # (C,) int32 trials in the CURRENT outer iteration
    ls_total: jax.Array    # (C,) int32 cumulative trials
    stalled: jax.Array     # (C,) bool line search exhausted without accept
    done: jax.Array        # (C,) bool frozen (converged/stalled/capped/pad)


class BatchRunStats(NamedTuple):
    """Compaction telemetry of one batched solve (host-side ints)."""
    schedule: str          # "compact" or "monolithic"
    n_lanes: int           # B, the number of real problems
    chunk: int             # flat steps per compiled chunk
    segments: int          # chunk programs launched
    waves: int             # max_lanes waves the grid was split into
    occupancy: tuple       # live real lanes at each executed flat step
    capacities: tuple      # padded capacity at each executed flat step
    order: tuple           # lane processing order (difficulty sort)
    gemm: str = "xla"      # flat-step gemm backend (BATCH_GEMMS)
    pilot_lane: int = -1   # warm-start pilot lane index (-1 = none)

    @property
    def lane_steps(self) -> int:
        """Useful per-lane trials executed (sum of the occupancy line)."""
        return int(sum(self.occupancy))

    @property
    def padded_lane_steps(self) -> int:
        """Lane-trials actually paid for, padding included — the compact
        engine's wall-clock is proportional to this."""
        return int(sum(self.capacities))

    @property
    def mean_occupancy(self) -> float:
        """Fraction of paid lane-steps doing useful work (1.0 = no
        padding waste; the monolithic engine's analogue is
        lane_steps / (B * max lane steps))."""
        paid = self.padded_lane_steps
        return self.lane_steps / paid if paid else 1.0

    def summary(self) -> str:
        pilot = (f", pilot lane {self.pilot_lane}"
                 if self.pilot_lane >= 0 else "")
        return (f"[{self.schedule}/{self.gemm}] {self.n_lanes} lanes, "
                f"{self.segments} segments x {self.chunk} steps "
                f"({self.waves} wave{'s' if self.waves != 1 else ''}{pilot}), "
                f"occupancy {self.mean_occupancy:.0%} "
                f"({self.lane_steps}/{self.padded_lane_steps} lane-steps)")


def capacity_ladder(n_max: int) -> list:
    """Padded-capacity tiers {1, 2, 3} x powers of two up to ``n_max`` —
    the same geometric family as the matops gather tiers, bounding the
    number of compiled chunk programs at ~2 log2(B)."""
    tiers = set()
    k = 1
    while k <= n_max:
        tiers.add(k)
        if 3 * k // 2 <= n_max and (3 * k) % 2 == 0:
            tiers.add(3 * k // 2)
        k *= 2
    tiers.update({1, 2, 3} & set(range(1, n_max + 1)))
    return sorted(tiers)


def _capacity(n_live: int, b: int) -> int:
    """Smallest ladder tier >= n_live, never exceeding the grid size."""
    cap = 1
    while cap < n_live:
        cap = 3 * cap // 2 if cap % 2 == 0 and 3 * cap // 2 >= n_live \
            else cap * 2
    return min(cap, b) if cap >= n_live else b


_CHUNK_STATICS = ("variant", "tol", "max_iters", "max_ls", "tau_schedule",
                  "chunk", "stacked", "tau_init", "use_pallas")

#: gemm backends of the compact engine's flat step.  "xla" keeps the whole
#: chunk one compiled program (the default, bit-compatible with the
#: sequential reference).  "host" steps the chunk from the host and routes
#: the Omega @ S product through the platform BLAS (np.matmul): on the
#: benchmark CPU that product is ~1.5-2x faster than XLA's f64 GEMM, which
#: dominates the per-trial cost at p >= 512.  Host-BLAS results are not
#: bit-identical to XLA-GEMM results (different accumulation order), but
#: the engine stays bit-exact AGAINST ITSELF across batch sizes, waves and
#: compaction (np.matmul is bit-stable across leading batch dims), which
#: the consistency tests assert.
BATCH_GEMMS = ("xla", "host")


def _apply_trial(lanes: _Lanes, trial, *, tol: float, max_iters: int,
                 max_ls: int, tau_schedule: str, tau_init: float) -> _Lanes:
    """Advance every live lane by ONE line-search trial.

    ``trial`` is ``(cand, aux_c, g_c, dot_dd, ok, nrm2)`` — the per-lane
    candidate, its aux product and smooth objective, the squared step
    norm, the sufficient-decrease acceptance and ``<omega, omega>`` of
    the pre-trial iterate.  Accept updates the iterate and starts the
    next outer iteration at the schedule's tau, reject halves tau, and
    exhausting ``max_ls`` stalls the lane — exactly the sequential
    backtracking semantics of ``prox_gradient``, shared verbatim by the
    jitted chunk program and the host-stepped gemm="host" executor."""
    cand, aux_c, g_c, dot_dd, ok, nrm2 = trial
    dtype = lanes.omega.dtype
    live = ~lanes.done

    trials_new = lanes.trials + 1
    accept = live & ok
    exhaust = live & ~ok & (trials_new >= max_ls)
    reject = live & ~ok & (trials_new < max_ls)
    fin = accept | exhaust

    delta_acc = jnp.sqrt(dot_dd) / jnp.maximum(1.0, jnp.sqrt(nrm2))
    step_new = lanes.step + 1
    done_acc = (step_new >= max_iters) | (delta_acc < tol)
    tau_next = tau_start(tau_schedule, step_new, lanes.tau_try,
                         tau_init, dtype)

    def sel(mask, a, b):
        return jnp.where(mask.reshape(mask.shape + (1,) *
                                      (a.ndim - 1)), a, b)

    return _Lanes(
        omega=sel(accept, cand, lanes.omega),
        aux=sel(accept, aux_c, lanes.aux),
        g_val=jnp.where(accept, g_c, lanes.g_val),
        tau_try=jnp.where(
            accept, tau_next,
            jnp.where(reject, lanes.tau_try * 0.5, lanes.tau_try)),
        delta=jnp.where(accept, delta_acc,
                        jnp.where(exhaust, jnp.asarray(0.0, dtype),
                                  lanes.delta)),
        step=jnp.where(fin, step_new, lanes.step),
        trials=jnp.where(fin, 0,
                         jnp.where(reject, trials_new, lanes.trials)),
        ls_total=jnp.where(fin, lanes.ls_total + trials_new,
                           lanes.ls_total),
        stalled=lanes.stalled | exhaust,
        done=lanes.done | (accept & done_acc) | exhaust,
    )


@partial(jax.jit, static_argnames=("variant", "stacked", "tau_schedule",
                                   "tau_init"))
def _init_lanes(arr, ridge, omega0, *, variant: str, stacked: bool,
                tau_schedule: str, tau_init: float) -> _Lanes:
    """Flat-step state at the identity of the outer loop: aux and g at the
    warm start, first-trial tau from the schedule, counters zeroed."""
    ops = _variant_ops(variant)
    dtype = omega0.dtype
    c = omega0.shape[0]

    def one(om0, arr_i, l2):
        data = _data_of(arr_i, l2, variant)
        aux0 = ops.aux_of(om0, data)
        return aux0, ops.g_of(om0, aux0, data)

    aux0, g0 = jax.vmap(one, in_axes=(0, 0 if stacked else None, 0))(
        omega0, arr, ridge)
    return _Lanes(
        omega=omega0,
        aux=aux0,
        g_val=g0,
        tau_try=jnp.full((c,), tau_first(tau_schedule, tau_init), dtype),
        delta=jnp.full((c,), jnp.inf, dtype),
        step=jnp.zeros((c,), jnp.int32),
        trials=jnp.zeros((c,), jnp.int32),
        ls_total=jnp.zeros((c,), jnp.int32),
        stalled=jnp.zeros((c,), bool),
        done=jnp.zeros((c,), bool),
    )


@partial(jax.jit, static_argnames=_CHUNK_STATICS)
def _path_chunk(arr, ridge, lanes: _Lanes, penalty: PenaltySpec, *,
                variant: str, tol: float, max_iters: int, max_ls: int,
                tau_schedule: str, chunk: int, stacked: bool,
                tau_init: float, use_pallas: bool):
    """Run up to ``chunk`` flat steps (one line-search trial per live lane
    per step), exiting early once every lane is done.

    One flat step replays exactly one trial of the sequential backtracking
    loop: accept updates the iterate and starts the next outer iteration
    at the schedule's tau, reject halves tau, exhausting ``max_ls``
    stalls the lane — so per-lane trajectories, iteration counts and
    trial counts are bit-identical to ``prox_gradient``'s.  Done lanes
    (and capacity padding) are select-frozen; only the CHUNK BOUNDARY
    repacks them away, so varying live-lane counts reuse this one
    program per (capacity, statics) key.

    Returns ``(lanes, occ)`` where ``occ[t]`` is the live-lane count at
    executed step t (0 on steps skipped by the early exit).
    """
    ops = _variant_ops(variant)
    c = lanes.g_val.shape[0]
    dtype = lanes.omega.dtype
    pleaves, ptree = jax.tree_util.tree_flatten(penalty)
    pallas = (use_pallas and variant == "cov" and penalty.pallas_ok
              and not stacked)

    def trials_jnp(lanes):
        def one(om, aux, gv, tau, arr_i, l2, *pl):
            pen = jax.tree_util.tree_unflatten(ptree, pl)
            data = _data_of(arr_i, l2, variant)
            grad = ops.grad_of(om, aux, data)
            cand, aux_c, g_c, dot_dd, ok = ls_trial(
                ops, data, pen, om, grad, gv, tau)
            return cand, aux_c, g_c, dot_dd, ok, ops.dot(om, om)

        return jax.vmap(one, in_axes=(0, 0, 0, 0, 0 if stacked else None,
                                      0, *penalty.batch_axes(c)))(
            lanes.omega, lanes.aux, lanes.g_val, lanes.tau_try,
            arr, ridge, *pleaves)

    def trials_pallas(lanes):
        # fused megakernel: gradient tile + prox + acceptance dot products
        # + occupancy in one pass; only the p x p aux product and the
        # smooth objective stay in XLA (they need a matmul).
        from ..kernels import ops as kops
        tau = lanes.tau_try
        lam1 = jnp.broadcast_to(jnp.asarray(penalty.lam1, dtype), (c,))
        lam2 = jnp.broadcast_to(jnp.asarray(ridge, dtype), (c,))
        weights = penalty.weights
        if weights is not None and weights.ndim == 2:
            weights = jnp.broadcast_to(weights[None], (c,) + weights.shape)
        cand, stats = kops.fused_path_step(
            lanes.omega, lanes.aux, tau, lam1, lam2, weights=weights)
        dot_dg, dot_dd = stats[:, 0], stats[:, 1]
        aux_c = cand @ arr
        g_c = jax.vmap(
            lambda om, aux, l2: ops.g_of(om, aux, {"lam2": l2}))(
            cand, aux_c, jnp.asarray(lam2, dtype))
        ok = g_c <= lanes.g_val + dot_dg + dot_dd / (2.0 * tau)
        nrm2 = jnp.sum(lanes.omega * lanes.omega, axis=(1, 2))
        return cand, aux_c, g_c, dot_dd, ok, nrm2

    def body(state):
        t, lanes, occ = state
        occ = occ.at[t].set(jnp.sum(~lanes.done, dtype=jnp.int32))
        trial = trials_pallas(lanes) if pallas else trials_jnp(lanes)
        new = _apply_trial(lanes, trial, tol=tol, max_iters=max_iters,
                           max_ls=max_ls, tau_schedule=tau_schedule,
                           tau_init=tau_init)
        return t + 1, new, occ

    def cond(state):
        t, lanes, _ = state
        return (t < chunk) & jnp.any(~lanes.done)

    occ0 = jnp.zeros((chunk,), jnp.int32)
    _, lanes, occ = jax.lax.while_loop(
        cond, body, (jnp.asarray(0, jnp.int32), lanes, occ0))
    return lanes, occ


# ---------------------------------------------------------------------------
# gemm="host" executor: host-stepped chunks around the platform BLAS
# ---------------------------------------------------------------------------

def _to_host(x) -> np.ndarray:
    """Zero-copy view of a CPU jax array when possible, else a copy."""
    try:
        return np.from_dlpack(x)
    except (TypeError, RuntimeError, BufferError):
        return np.asarray(x)


@partial(jax.jit, static_argnames=("variant", "stacked"))
def _host_propose(arr, ridge, lanes: _Lanes, penalty: PenaltySpec, *,
                  variant: str, stacked: bool):
    """First half of a flat step: per-lane gradient and prox candidate at
    the lane's trial tau.  The aux product of the candidate is NOT taken
    here — the host executor runs it through np.matmul between this
    program and :func:`_host_update`."""
    ops = _variant_ops(variant)
    c = lanes.g_val.shape[0]
    pleaves, ptree = jax.tree_util.tree_flatten(penalty)

    def one(om, aux, tau, arr_i, l2, *pl):
        pen = jax.tree_util.tree_unflatten(ptree, pl)
        data = _data_of(arr_i, l2, variant)
        grad = ops.grad_of(om, aux, data)
        z = om - tau * grad
        return ops.prox(z, pen, tau, data), grad

    return jax.vmap(one, in_axes=(0, 0, 0, 0 if stacked else None, 0,
                                  *penalty.batch_axes(c)))(
        lanes.omega, lanes.aux, lanes.tau_try, arr, ridge, *pleaves)


@partial(jax.jit, static_argnames=("tol", "max_iters", "max_ls",
                                   "tau_schedule", "tau_init"))
def _host_update(ridge, lanes: _Lanes, cand, grad, aux_c, *, tol: float,
                 max_iters: int, max_ls: int, tau_schedule: str,
                 tau_init: float) -> _Lanes:
    """Second half of a flat step: smooth objective and acceptance dots of
    the host-multiplied candidate, then the shared trial-update selects.
    Cov variant only (its ``g_of``/``grad_of`` read just ``lam2`` from the
    data dict, so the data matrix never enters this program)."""
    ops = _variant_ops("cov")

    def one(om, gv, tau, cand_i, grad_i, aux_ci, l2):
        data = {"lam2": l2}
        g_c = ops.g_of(cand_i, aux_ci, data)
        diff = cand_i - om
        dot_dd = ops.dot(diff, diff)
        rhs = gv + ops.dot(diff, grad_i) + dot_dd / (2.0 * tau)
        return g_c, dot_dd, g_c <= rhs, ops.dot(om, om)

    g_c, dot_dd, ok, nrm2 = jax.vmap(one)(
        lanes.omega, lanes.g_val, lanes.tau_try, cand, grad, aux_c, ridge)
    return _apply_trial(lanes, (cand, aux_c, g_c, dot_dd, ok, nrm2),
                        tol=tol, max_iters=max_iters, max_ls=max_ls,
                        tau_schedule=tau_schedule, tau_init=tau_init)


@partial(jax.jit, static_argnames=("variant",))
def _init_g(omega0, aux0, ridge, *, variant: str):
    """Per-lane smooth objective at the warm start (aux supplied by the
    caller, so the host executor can feed a host-BLAS product)."""
    ops = _variant_ops(variant)
    return jax.vmap(lambda om, aux, l2: ops.g_of(om, aux, {"lam2": l2}))(
        omega0, aux0, ridge)


def _init_lanes_host(arr_np: np.ndarray, ridge, omega0, *,
                     tau_schedule: str, tau_init: float) -> _Lanes:
    """Host-gemm twin of :func:`_init_lanes` (cov variant): the warm-start
    aux product runs through np.matmul like every subsequent trial's."""
    dtype = omega0.dtype
    c = omega0.shape[0]
    aux0 = jnp.asarray(np.matmul(_to_host(omega0), arr_np))
    g0 = _init_g(omega0, aux0, ridge, variant="cov")
    return _Lanes(
        omega=omega0,
        aux=aux0,
        g_val=g0,
        tau_try=jnp.full((c,), tau_first(tau_schedule, tau_init), dtype),
        delta=jnp.full((c,), jnp.inf, dtype),
        step=jnp.zeros((c,), jnp.int32),
        trials=jnp.zeros((c,), jnp.int32),
        ls_total=jnp.zeros((c,), jnp.int32),
        stalled=jnp.zeros((c,), bool),
        done=jnp.zeros((c,), bool),
    )


def _host_chunk(arr, arr_np, ridge, lanes: _Lanes, penalty: PenaltySpec, *,
                variant: str, tol: float, max_iters: int, max_ls: int,
                tau_schedule: str, chunk: int, stacked: bool,
                tau_init: float, use_pallas: bool):
    """Host-stepped twin of :func:`_path_chunk`: identical flat-step
    semantics and occupancy accounting, but each step is two small jitted
    programs around a host np.matmul for the candidate's aux product.
    The host loop syncs per step anyway to drive BLAS, so the early exit
    reads the done mask directly."""
    del use_pallas  # the megakernel only applies to the jitted executor
    occ = np.zeros((chunk,), np.int32)
    for t in range(chunk):
        done_np = _to_host(lanes.done)
        # the host executor syncs per step BY DESIGN (it drives BLAS);
        # this pull is that sync, not an accidental one
        n_live = int(done_np.size - np.count_nonzero(done_np))  # ca: allow=CA106
        if n_live == 0:
            break
        occ[t] = n_live
        cand, grad = _host_propose(arr, ridge, lanes, penalty,
                                   variant=variant, stacked=stacked)
        aux_c = jnp.asarray(np.matmul(_to_host(cand), arr_np))
        lanes = _host_update(ridge, lanes, cand, grad, aux_c, tol=tol,
                             max_iters=max_iters, max_ls=max_ls,
                             tau_schedule=tau_schedule, tau_init=tau_init)
    return lanes, jnp.asarray(occ)


def _broadcast_spec(spec: PenaltySpec, b: int) -> PenaltySpec:
    """Every leaf broadcast to a lane-leading shape (lazily — no copies
    until the per-wave gather materializes a tier), so chunk-boundary
    gathers treat all penalty parameters uniformly."""
    leaves, tree = jax.tree_util.tree_flatten(spec)
    out = []
    for leaf, nd in zip(leaves, spec._expected_ndims()):
        arr = jnp.asarray(leaf)
        if arr.ndim == nd:
            arr = jnp.broadcast_to(arr, (b,) + arr.shape)
        elif arr.ndim != nd + 1 or arr.shape[0] != b:
            raise ValueError(
                f"penalty leaf of base ndim {nd} has shape {arr.shape}; "
                f"expected that or a (B={b},)-leading batch of it")
        out.append(arr)
    return jax.tree_util.tree_unflatten(tree, out)


def _take_lanes(tree, idx):
    idx = jnp.asarray(idx, jnp.int32)
    return jax.tree.map(lambda x: jnp.take(x, idx, axis=0), tree)


def _difficulty_order(spec: PenaltySpec, b: int, max_iters: int,
                      sort_lanes: bool) -> np.ndarray:
    """Processing order: hardest (most predicted iterations) first, so the
    easy tail of a wave drains together and compaction shrinks capacity
    early.  Stable-sorts on the cost model's per-lam1 prediction; without
    per-lane lam1 (or with sorting disabled) keeps input order."""
    if sort_lanes:
        lam1 = np.asarray(spec.lam1, np.float64)
        if lam1.shape == (b,) and np.all(np.isfinite(lam1)) \
                and np.all(lam1 > 0):
            pred = costmodel.predict_path_iters(lam1, max_iters=max_iters)
            return np.argsort(-pred, kind="stable").astype(np.int64)
    return np.arange(b, dtype=np.int64)


def _solve_compact(arr, spec, ridge, omega0, *, variant, tol, max_iters,
                   max_ls, tau_schedule, chunk, max_lanes, sort_lanes,
                   stacked, use_pallas, gemm="xla", warm_start=None):
    """Host driver of the compact schedule: difficulty-sorted waves, a
    gather/pad/launch loop per wave, per-boundary harvesting of finished
    lanes, and scatter back to input order.

    ``warm_start="pilot"`` prepends a one-lane wave solving the
    median-difficulty lane; every later lane warm-starts from its
    solution (each lane still bit-exactly matches a sequential solve run
    from the same omega0 — the pilot's from the user start, the rest from
    the pilot's omega).  ``gemm`` picks the flat-step executor (see
    :data:`BATCH_GEMMS`)."""
    arr = jnp.asarray(arr)
    dtype = arr.dtype
    p = arr.shape[-1]
    b = spec.lam1.shape[0]
    if chunk < 1:
        raise ValueError(f"chunk must be >= 1, got {chunk}")
    if gemm not in BATCH_GEMMS:
        raise ValueError(f"gemm must be one of {BATCH_GEMMS}, got {gemm!r}")
    if gemm == "host" and variant != "cov":
        raise ValueError("gemm='host' supports variant='cov' only")
    if gemm == "host" and use_pallas:
        raise ValueError("gemm='host' and use_pallas are mutually "
                         "exclusive (the megakernel lives in the jitted "
                         "executor)")
    if warm_start not in (None, "pilot"):
        raise ValueError(f"warm_start must be None or 'pilot', "
                         f"got {warm_start!r}")
    if warm_start == "pilot" and omega0 is not None:
        raise ValueError("warm_start='pilot' picks its own warm starts; "
                         "pass either it or omega0, not both")
    spec_b = _broadcast_spec(spec, b)
    ridge_b = jnp.broadcast_to(jnp.asarray(ridge, dtype), (b,))
    if omega0 is None:
        om_b = jnp.broadcast_to(jnp.eye(p, dtype=dtype)[None], (b, p, p))
    else:
        omega0 = jnp.asarray(omega0, dtype)
        om_b = jnp.broadcast_to(
            omega0[None] if omega0.ndim == 2 else omega0, (b, p, p))

    order = _difficulty_order(spec, b, max_iters, sort_lanes)
    wave_size = b if max_lanes is None else max(1, int(max_lanes))
    pilot_lane = -1
    if warm_start == "pilot" and b > 1:
        pilot_lane = int(order[len(order) // 2])
        rest = order[order != pilot_lane]
        waves = [np.asarray([pilot_lane], np.int64)]
        waves += [rest[i:i + wave_size] for i in range(0, b - 1, wave_size)]
    else:
        waves = [order[i:i + wave_size] for i in range(0, b, wave_size)]
    arr_np = _to_host(arr) if gemm == "host" else None

    statics = dict(variant=variant, tol=tol, max_iters=max_iters,
                   max_ls=max_ls, tau_schedule=tau_schedule, chunk=chunk,
                   stacked=stacked, tau_init=1.0, use_pallas=use_pallas)
    results: list = [None] * b
    occupancy: list = []
    capacities: list = []
    segments = 0

    def harvest(state, cur_ids):
        done = np.asarray(state.done)
        delta = np.asarray(state.delta)
        stall = np.asarray(state.stalled)
        for slot in np.flatnonzero(done & (cur_ids >= 0)):
            lane = int(cur_ids[slot])
            results[lane] = {
                "omega": np.asarray(state.omega[slot]),
                "iters": int(state.step[slot]),
                "ls_total": int(state.ls_total[slot]),
                "g_final": np.asarray(state.g_val[slot]),
                "delta_final": delta[slot],
                "stalled": bool(stall[slot]),
                "converged": bool(delta[slot] < tol) and not bool(
                    stall[slot]),
            }
        return done

    for wave_idx, wave in enumerate(waves):
        ids = np.asarray(wave, np.int64)
        cap = _capacity(len(ids), b)
        _obs_event("batch.wave", wave=wave_idx, lanes=len(ids))
        pad_idx = np.concatenate(
            [ids, np.full(cap - len(ids), ids[-1], np.int64)])
        real = jnp.asarray(np.arange(cap) < len(ids))
        arr_w = _take_lanes(arr, pad_idx) if stacked else arr
        ridge_w = _take_lanes(ridge_b, pad_idx)
        spec_w = _take_lanes(spec_b, pad_idx)
        om_w = _take_lanes(om_b, pad_idx)
        if gemm == "host":
            arr_np_w = _to_host(arr_w) if stacked else arr_np
            state = _init_lanes_host(arr_np_w, ridge_w, om_w,
                                     tau_schedule=tau_schedule,
                                     tau_init=1.0)
        else:
            arr_np_w = None
            state = _init_lanes(arr_w, ridge_w, om_w, variant=variant,
                                stacked=stacked, tau_schedule=tau_schedule,
                                tau_init=1.0)
        state = state._replace(done=state.done | ~real
                               | (max_iters <= 0))
        cur_ids = pad_idx.copy()
        cur_ids[len(ids):] = -1

        while True:
            n_real = int(np.sum(cur_ids >= 0))  # ca: allow=CA106 (np host array)
            with _obs_span("batch.segment", segment=segments,
                           wave=wave_idx, lanes=n_real, cap=cap):
                if gemm == "host":
                    state, occ = _host_chunk(arr_w, arr_np_w, ridge_w, state,
                                             spec_w, **statics)
                else:
                    state, occ = _path_chunk(arr_w, ridge_w, state, spec_w,
                                             **statics)
            segments += 1
            occ_np = np.asarray(occ)
            executed = occ_np[occ_np > 0]
            # a chunk's recorded count includes duplicated pad lanes;
            # clip to the real-lane count for an honest occupancy line
            occupancy.extend(int(min(v, n_real)) for v in executed)
            capacities.extend([cap] * len(executed))
            done = harvest(state, cur_ids)
            live = np.flatnonzero(~done)
            if live.size == 0:
                break
            new_cap = _capacity(live.size, b)
            slots = np.concatenate(
                [live, np.full(new_cap - live.size, live[-1], np.int64)])
            state = _take_lanes(state, slots)
            real = jnp.asarray(np.arange(new_cap) < live.size)
            state = state._replace(done=state.done | ~real)
            if stacked:
                arr_w = _take_lanes(arr_w, slots)
                if gemm == "host":
                    arr_np_w = _to_host(arr_w)
            ridge_w = _take_lanes(ridge_w, slots)
            spec_w = _take_lanes(spec_w, slots)
            cur_ids = cur_ids[slots]
            cur_ids[live.size:] = -1
            cap = new_cap

        if pilot_lane >= 0 and wave is waves[0]:
            om_b = jnp.broadcast_to(
                jnp.asarray(results[pilot_lane]["omega"], dtype)[None],
                (b, p, p))

    res = ProxResult(
        omega=jnp.asarray(np.stack([r["omega"] for r in results])),
        iters=jnp.asarray([r["iters"] for r in results], jnp.int32),
        ls_total=jnp.asarray([r["ls_total"] for r in results], jnp.int32),
        converged=jnp.asarray([r["converged"] for r in results], bool),
        g_final=jnp.asarray(np.stack([r["g_final"] for r in results])),
        delta_final=jnp.asarray(
            np.stack([r["delta_final"] for r in results])),
        stalled=jnp.asarray([r["stalled"] for r in results], bool),
        block_density=jnp.ones((b,), matops.DENSITY_DTYPE),
    )
    stats = BatchRunStats(
        schedule="compact", n_lanes=b, chunk=chunk, segments=segments,
        waves=len(waves), occupancy=tuple(occupancy),
        capacities=tuple(capacities), order=tuple(int(i) for i in order),
        gemm=gemm, pilot_lane=pilot_lane)
    return res, stats


def _monolithic_stats(b: int) -> BatchRunStats:
    return BatchRunStats(schedule="monolithic", n_lanes=b, chunk=0,
                         segments=1, waves=1, occupancy=(),
                         capacities=(), order=tuple(range(b)))


def _check_schedule(schedule: str) -> None:
    if schedule not in BATCH_SCHEDULES:
        raise ValueError(f"schedule must be one of {BATCH_SCHEDULES}, "
                         f"got {schedule!r}")


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------

def solve_path_batched(
    s_or_x: jax.Array,
    lam1_grid: jax.Array,
    lam2: float = 0.0,
    *,
    penalty: PenaltySpec | str | None = None,
    omega0: jax.Array | None = None,
    variant: str = "cov",
    tol: float = 1e-5,
    max_iters: int = 500,
    max_ls: int = 30,
    warm_start_tau: bool = False,
    tau_schedule: str | None = None,
    schedule: str = "compact",
    chunk: int = DEFAULT_CHUNK,
    max_lanes: int | None = None,
    sort_lanes: bool = True,
    use_pallas: bool = False,
    gemm: str = "xla",
    warm_start: str | None = None,
    return_stats: bool = False,
):
    """Solve a whole lam1 grid against SHARED data with batched programs.

    ``s_or_x`` is the (p, p) sample covariance (variant="cov") or the
    (n, p) observations (variant="obs"), broadcast across the batch (one
    copy); ``lam1_grid`` is the (B,) penalty vector.  ``penalty`` swaps
    the penalty family for the whole grid (its lam1 is replaced by the
    grid; other parameters — SCAD shape, a weight matrix — are shared
    across lanes).  ``omega0`` may be None (identity start for every
    point), a single (p, p) warm start shared by all points, or a stacked
    (B, p, p) per-point start.  Returns a :class:`ProxResult` whose every
    field carries a leading (B,) axis — per-lane values bit-exactly equal
    to B sequential solves — or ``(result, BatchRunStats)`` with
    ``return_stats``.

    ``schedule="compact"`` (default) runs the segmented compaction engine
    (chunked flat steps, live lanes repacked at boundaries so flops track
    active lanes); ``"monolithic"`` is the original one-dispatch vmapped
    while_loop.  ``chunk``/``max_lanes``/``sort_lanes`` tune the compact
    engine (steps per segment, wave size, difficulty-sorted scheduling);
    ``tau_schedule`` selects the per-lane line-search schedule
    (:data:`~repro.core.prox.TAU_SCHEDULES`); ``use_pallas`` routes the
    flat step through the fused path-step megakernel (Cov +
    soft-threshold penalties; not bit-exact, see the module docstring).

    ``gemm="host"`` steps chunks from the host and runs the candidate's
    aux product through the platform BLAS (:data:`BATCH_GEMMS` — Cov
    variant, compact schedule); ``warm_start="pilot"`` solves the
    median-difficulty lane first and warm-starts the rest from it.  Both
    preserve "each lane equals a sequential solve from the same omega0
    with the same gemm"; neither is bit-compatible with the defaults.
    """
    lam1_grid = jnp.asarray(lam1_grid)
    if lam1_grid.ndim != 1:
        raise ValueError(f"lam1_grid must be 1-D, got shape {lam1_grid.shape}")
    if penalty is None:
        spec, ridge = PenaltySpec("l1", lam1_grid), lam2
    else:
        # the grid IS the strength here, so a string form needs only its
        # kind/shape — feed a placeholder lam1 that the grid replaces
        base, ridge = _resolve_spec(
            penalty, 0.0 if isinstance(penalty, str) else None, lam2)
        spec = base.with_lam1(lam1_grid)
    _check_schedule(schedule)
    if schedule == "monolithic":
        if gemm != "xla" or warm_start is not None:
            raise ValueError("gemm/warm_start are compact-schedule knobs; "
                             "schedule='monolithic' supports neither")
        res = _solve_path_batched(
            s_or_x, spec, ridge, omega0, variant=variant, tol=tol,
            max_iters=max_iters, max_ls=max_ls,
            warm_start_tau=warm_start_tau, tau_schedule=tau_schedule)
        return (res, _monolithic_stats(lam1_grid.shape[0])) \
            if return_stats else res
    res, stats = _solve_compact(
        s_or_x, spec, ridge, omega0, variant=variant, tol=tol,
        max_iters=max_iters, max_ls=max_ls,
        tau_schedule=resolve_tau_schedule(tau_schedule, warm_start_tau),
        chunk=chunk, max_lanes=max_lanes, sort_lanes=sort_lanes,
        stacked=False, use_pallas=use_pallas, gemm=gemm,
        warm_start=warm_start)
    return (res, stats) if return_stats else res


def solve_batch(
    s_or_x: jax.Array,
    lam1: jax.Array | None = None,
    lam2: jax.Array = 0.0,
    *,
    penalty: PenaltySpec | str | None = None,
    omega0: jax.Array | None = None,
    variant: str = "cov",
    tol: float = 1e-5,
    max_iters: int = 500,
    max_ls: int = 30,
    warm_start_tau: bool = False,
    tau_schedule: str | None = None,
    schedule: str = "compact",
    chunk: int = DEFAULT_CHUNK,
    max_lanes: int | None = None,
    sort_lanes: bool = True,
    gemm: str = "xla",
    return_stats: bool = False,
):
    """Solve B stacked independent problems with batched programs.

    ``s_or_x`` is (B, p, p) stacked covariances (variant="cov") or
    (B, n, p) stacked observation matrices (variant="obs") — every problem
    shares one shape, the server-side bucketing invariant.  ``lam1`` and
    ``lam2`` are scalars (shared) or (B,) vectors (per-problem);
    equivalently ``penalty`` carries the whole spec, and ANY of its
    numeric leaves may be (B,)-batched for per-lane penalty parameters
    (e.g. per-lane SCAD shapes) inside the single compiled program.
    ``omega0`` is None, one shared (p, p) start, or stacked (B, p, p).
    Returns a :class:`ProxResult` with a leading (B,) axis on every field
    (or ``(result, BatchRunStats)`` with ``return_stats``); the
    ``schedule``/``chunk``/``max_lanes``/``sort_lanes``/``tau_schedule``
    knobs are as in :func:`solve_path_batched`.
    """
    s_or_x = jnp.asarray(s_or_x)
    if s_or_x.ndim != 3:
        raise ValueError(
            f"solve_batch expects stacked (B, n|p, p) data, got shape "
            f"{s_or_x.shape}")
    b = s_or_x.shape[0]
    spec, ridge = _resolve_spec(penalty, lam1, lam2)
    lam1_b = jnp.broadcast_to(jnp.asarray(spec.lam1, s_or_x.dtype), (b,))
    spec = spec.with_lam1(lam1_b)
    ridge_b = jnp.broadcast_to(jnp.asarray(ridge, s_or_x.dtype), (b,))
    _check_schedule(schedule)
    if schedule == "monolithic":
        if gemm != "xla":
            raise ValueError("gemm is a compact-schedule knob; "
                             "schedule='monolithic' is always XLA")
        res = _solve_batch(
            s_or_x, spec, ridge_b, omega0, variant=variant, tol=tol,
            max_iters=max_iters, max_ls=max_ls,
            warm_start_tau=warm_start_tau, tau_schedule=tau_schedule)
        return (res, _monolithic_stats(b)) if return_stats else res
    res, stats = _solve_compact(
        s_or_x, spec, ridge_b, omega0, variant=variant, tol=tol,
        max_iters=max_iters, max_ls=max_ls,
        tau_schedule=resolve_tau_schedule(tau_schedule, warm_start_tau),
        chunk=chunk, max_lanes=max_lanes, sort_lanes=sort_lanes,
        stacked=True, use_pallas=False, gemm=gemm)
    return (res, stats) if return_stats else res


# ---------------------------------------------------------------------------
# analysis manifest (repro.analysis.jaxprpass)
# ---------------------------------------------------------------------------

def _analysis_cov(p):
    return jnp.eye(p, dtype=jnp.float64) + 0.05 * jnp.ones((p, p),
                                                           jnp.float64)


def _analysis_path():
    p, b = 6, 3
    spec = PenaltySpec("l1", jnp.linspace(0.1, 0.3, b, dtype=jnp.float64),
                       jnp.asarray(0.0, jnp.float64))
    fn = partial(_solve_path_batched, variant="cov", tol=1e-3, max_iters=5,
                 max_ls=5, warm_start_tau=False)
    return {"fn": fn,
            "args": (_analysis_cov(p), spec, jnp.asarray(0.0, jnp.float64),
                     None)}


def _analysis_path_reuse():
    s = _analysis_cov(6)

    def run(lo):
        grid = jnp.linspace(lo, lo + 0.2, 3, dtype=jnp.float64)
        res = solve_path_batched(s, grid, tol=1e-3, max_iters=4, max_ls=4,
                                 schedule="monolithic")
        return res.omega.block_until_ready()

    return {"watched": {"core.batch._solve_path_batched":
                        _solve_path_batched},
            "calls": [partial(run, 0.10), partial(run, 0.15),
                      partial(run, 0.20)]}


def _analysis_batch():
    p, b = 6, 2
    s = jnp.stack([_analysis_cov(p)] * b)
    spec = PenaltySpec("l1", jnp.linspace(0.1, 0.2, b, dtype=jnp.float64),
                       jnp.asarray(0.0, jnp.float64))
    ridge = jnp.zeros((b,), jnp.float64)
    fn = partial(_solve_batch, variant="cov", tol=1e-3, max_iters=5,
                 max_ls=5, warm_start_tau=False)
    return {"fn": fn, "args": (s, spec, ridge, None)}


def _analysis_batch_reuse():
    s = jnp.stack([_analysis_cov(6)] * 2)

    def run(lam1):
        res = solve_batch(s, jnp.asarray([lam1, lam1 + 0.05], jnp.float64),
                          tol=1e-3, max_iters=4, max_ls=4,
                          schedule="monolithic")
        return res.omega.block_until_ready()

    return {"watched": {"core.batch._solve_batch": _solve_batch},
            "calls": [partial(run, 0.10), partial(run, 0.16),
                      partial(run, 0.22)]}


def _chunk_statics():
    return dict(variant="cov", tol=1e-3, max_iters=4, max_ls=4,
                tau_schedule="greedy", chunk=3, stacked=False,
                tau_init=1.0, use_pallas=False)


def _analysis_chunk():
    p, c = 6, 3
    s = _analysis_cov(p)
    spec = PenaltySpec("l1", jnp.linspace(0.1, 0.3, c, dtype=jnp.float64),
                       jnp.zeros((c,), jnp.float64))
    ridge = jnp.zeros((c,), jnp.float64)
    om0 = jnp.broadcast_to(jnp.eye(p, dtype=jnp.float64)[None], (c, p, p))
    lanes = _init_lanes(s, ridge, om0, variant="cov", stacked=False,
                        tau_schedule="greedy", tau_init=1.0)
    fn = partial(_path_chunk, **_chunk_statics())
    return {"fn": fn, "args": (s, ridge, lanes, spec)}


def _analysis_chunk_reuse():
    p, c = 6, 4
    s = _analysis_cov(p)
    spec = PenaltySpec("l1", jnp.full((c,), 0.2, jnp.float64),
                       jnp.zeros((c,), jnp.float64))
    ridge = jnp.zeros((c,), jnp.float64)
    om0 = jnp.broadcast_to(jnp.eye(p, dtype=jnp.float64)[None], (c, p, p))
    statics = dict(_chunk_statics(), tau_schedule="restart")

    def run(n_live):
        lanes = _init_lanes(s, ridge, om0, variant="cov", stacked=False,
                            tau_schedule="restart", tau_init=1.0)
        lanes = lanes._replace(done=jnp.arange(c) >= n_live)
        out, _ = _path_chunk(s, ridge, lanes, spec, **statics)
        return out.omega.block_until_ready()

    # the compaction contract: 4, then 2, then 1 live lanes at one
    # capacity tier must all hit the SAME compiled chunk program
    return {"watched": {"core.batch._path_chunk": _path_chunk},
            "calls": [partial(run, 4), partial(run, 2), partial(run, 1)]}


#: the batched lambda-path and multi-problem engines: one compiled
#: program per (shape, penalty kind, statics) key is THE contract here,
#: and for the compact engine one chunk program per capacity tier
#: regardless of how many lanes are live inside it
ANALYSIS_ENTRIES = [
    {"name": "core.batch.solve_path_batched",
     "path": "src/repro/core/batch.py", "axis_names": (),
     "build": _analysis_path, "reuse": _analysis_path_reuse},
    {"name": "core.batch.solve_batch", "path": "src/repro/core/batch.py",
     "axis_names": (), "build": _analysis_batch,
     "reuse": _analysis_batch_reuse},
    {"name": "core.batch.path_chunk", "path": "src/repro/core/batch.py",
     "axis_names": (), "build": _analysis_chunk,
     "reuse": _analysis_chunk_reuse},
]
