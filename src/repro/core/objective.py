"""CONCORD / PseudoNet objective, gradient, and proximal operator.

The PseudoNet criterion (paper eq. (1), internally-consistent scaling):

    F(Omega) = g(Omega) + h(Omega)
    g(Omega) = -sum_i log(omega_ii) + 1/2 tr(Omega S Omega) + lam2/2 ||Omega||_F^2
    h(Omega) = lam1 * ||Omega_X||_1           (off-diagonal l1)

    grad g(Omega) = -Omega_D^{-1} + 1/2 (W + W^T) + lam2 * Omega,   W = Omega S

which matches the gradient stated in Algorithm 2 of the paper (the paper's
line-7 objective display carries stray factors of 2 that are inconsistent
with its own gradient; we keep gradient == d/dOmega objective).

Everything here is pure jnp on a single logical array; the distributed
drivers in core/cov.py and core/obs.py reproduce these formulas on shards.

Two evaluation modes mirror the paper's variants:
  * "cov": W = Omega @ S with S = X^T X / n precomputed.
  * "obs": Y = Omega @ X^T / sqrt-free (we keep 1/n folded), Z = Y @ X, and
           tr(Omega S Omega) = ||Y||_F^2 * n ... see ObsState docs.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


def soft_threshold(z: jax.Array, alpha) -> jax.Array:
    """Elementwise soft-thresholding S_alpha(z) (paper eq. (2))."""
    return jnp.sign(z) * jnp.maximum(jnp.abs(z) - alpha, 0.0)


def prox_l1_offdiag(z: jax.Array, alpha) -> jax.Array:
    """Prox of alpha*||Z_X||_1: soft-threshold off-diagonal, keep diagonal."""
    st = soft_threshold(z, alpha)
    return st + (z - st) * jnp.eye(z.shape[-1], dtype=z.dtype)


def offdiag_l1(omega: jax.Array) -> jax.Array:
    p = omega.shape[-1]
    mask = 1.0 - jnp.eye(p, dtype=omega.dtype)
    return jnp.sum(jnp.abs(omega) * mask)


def smooth_objective_cov(omega: jax.Array, w: jax.Array, lam2) -> jax.Array:
    """g(Omega) given W = Omega @ S.

    tr(Omega S Omega) = tr(W Omega) = sum_ij W_ij Omega_ij for symmetric Omega.
    """
    diag = jnp.diagonal(omega, axis1=-2, axis2=-1)
    logdet_term = -jnp.sum(jnp.log(diag))
    quad = 0.5 * jnp.sum(w * omega)
    ridge = 0.5 * lam2 * jnp.sum(omega * omega)
    return logdet_term + quad + ridge


def smooth_objective_obs(omega: jax.Array, y: jax.Array, n: int, lam2) -> jax.Array:
    """g(Omega) given Y = Omega @ X^T (unnormalized).

    tr(Omega S Omega) = (1/n)||Omega X^T||_F^2 = ||Y||_F^2 / n.
    """
    diag = jnp.diagonal(omega, axis1=-2, axis2=-1)
    logdet_term = -jnp.sum(jnp.log(diag))
    quad = 0.5 * jnp.sum(y * y) / n
    ridge = 0.5 * lam2 * jnp.sum(omega * omega)
    return logdet_term + quad + ridge


def gradient_from_w(omega: jax.Array, w: jax.Array, lam2) -> jax.Array:
    """grad g = -Omega_D^{-1} + (W + W^T)/2 + lam2 * Omega."""
    p = omega.shape[-1]
    inv_diag = 1.0 / jnp.diagonal(omega, axis1=-2, axis2=-1)
    return (
        -jnp.eye(p, dtype=omega.dtype) * inv_diag
        + 0.5 * (w + jnp.swapaxes(w, -1, -2))
        + lam2 * omega
    )


def full_objective_cov(omega, s, lam1, lam2):
    w = omega @ s
    return smooth_objective_cov(omega, w, lam2) + lam1 * offdiag_l1(omega)


def full_objective_obs(omega, x, lam1, lam2):
    n = x.shape[0]
    y = omega @ x.T
    return smooth_objective_obs(omega, y, n, lam2) + lam1 * offdiag_l1(omega)


class ProxState(NamedTuple):
    """Carry for the proximal-gradient loop."""
    omega: jax.Array       # current iterate, (p, p)
    w: jax.Array           # W = Omega @ S  (cov) or Z = Y @ X / n (obs)
    g_val: jax.Array       # g(omega)
    step: jax.Array        # iteration counter
    tau: jax.Array         # last accepted step size
    delta: jax.Array       # ||omega_{k+1} - omega_k||_F / max(1, ||omega_k||_F)
    ls_iters: jax.Array    # cumulative line-search iterations (for cost model `t`)


def sufficient_decrease(g_new, g_old, omega_new, omega_old, grad, tau):
    """Backtracking acceptance (Algorithms 2/3 line 12).

    g(O+) <= g(O) + tr((O+ - O)^T G) + ||O+ - O||_F^2 / (2 tau)
    """
    diff = omega_new - omega_old
    rhs = g_old + jnp.sum(diff * grad) + jnp.sum(diff * diff) / (2.0 * tau)
    return g_new <= rhs
