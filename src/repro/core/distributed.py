"""Distributed HP-CONCORD drivers (paper Algorithms 2 and 3).

Both variants run the *entire* proximal-gradient solve (outer loop + line
search) inside one ``shard_map`` over the 1.5D grid mesh, so the whole fit
lowers to a single XLA program with the communication-avoiding collectives
(ring ppermutes, team allgathers/psums, replication-aware transposes)
inlined.  The control flow is the generic ``prox_gradient`` loop from
``core.prox``; only the ``VariantOps`` bundle differs:

  Cov  (Algorithm 2) — per-device state is an X-like column panel.
    aux_of  : W = Omega @ S          1.5D gather-rotation of Omega
                                     (stored as the local transpose of the
                                     column panel — valid because the
                                     iterates are symmetric; this is the
                                     paper's Figure-1 "local transpose")
    grad_of : W^T via the replication-aware distributed transpose
    S = X^T X / n is computed ONCE up front by rotating X^T (line 2).

  Obs  (Algorithm 3) — per-device state is an Omega-like row block.
    aux_of  : Y = Omega @ X^T        1.5D reduce-rotation of X^T
    grad_of : Z = Y @ X / n          1.5D gather-rotation of X,
              Z^T via the distributed transpose
    S is never formed.

Padding.  The layouts need p divisible by P.  We pad to p' = pad_p(p) and
*freeze* the padded coordinates: the padded diagonal starts at 1 and its
gradient is masked to zero, off-block entries are zero and stay zero
because the padded block of S (resp. the padded columns of X) is zero, so
the real (p x p) block of every iterate is EXACTLY the unpadded iterate.
The ridge term subtracts the constant contributed by the frozen diagonal
so reported objectives match the reference solver.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from ..comm import matmul1p5d as mm
from ..comm import sparse1p5d as sp
from ..comm.compat import axis_size, shard_map, use_mesh
from ..comm.grid import Grid1p5D
from . import matops
from .costmodel import Machine, ProblemShape, tune
from .penalty import PenaltySpec, normalize_penalty
from .prox import ProxResult, VariantOps, guard_nonpos_diag, prox_gradient

SPEC_XCOL = mm.SPEC_XCOL
SPEC_OM = mm.SPEC_OM


class FitResult(NamedTuple):
    omega: jax.Array
    iters: jax.Array
    ls_total: jax.Array
    converged: jax.Array    # genuine delta < tol exit (never set on a stall)
    g_final: jax.Array
    variant: str
    grid: Grid1p5D
    block_density: jax.Array | float = 1.0
    stalled: jax.Array | bool = False   # line search exhausted without accept


def _shard_policy(policy: matops.MatmulPolicy | None,
                  shard_shape: tuple[int, int],
                  also_divide: tuple[int, ...] = ()
                  ) -> matops.MatmulPolicy | None:
    """The policy actually usable on a per-device Ω shard: the mask is
    rotated/sliced at block granularity inside the ring loops, so the block
    grid must tile the shard (and any ``also_divide`` slice widths) exactly;
    otherwise fall back to dense."""
    if policy is None or not policy.enabled:
        return None
    bs = policy.block_size
    if any(d % bs for d in tuple(shard_shape) + tuple(also_divide)):
        warnings.warn(
            f"sparse_matmul block_size={bs} does not tile the local Omega "
            f"shard {shard_shape} (slice widths {also_divide}); falling back "
            f"to the dense path (pick a block size dividing p_pad/n_blocks)",
            stacklevel=3)
        return None
    return policy


# ---------------------------------------------------------------------------
# local-layout helpers (run inside shard_map)
# ---------------------------------------------------------------------------

def _block_x():
    """X-like block index t = i*c_omega + j of this device."""
    return lax.axis_index("i") * axis_size("j") + lax.axis_index("j")


def _eye_panel_x(p_pad: int, blk: int, dtype):
    """Local X-like column panel of the identity: ones at (t*blk + r, r)."""
    t = _block_x()
    rows = jnp.arange(p_pad)[:, None]
    cols = jnp.arange(blk)[None, :]
    return (rows == t * blk + cols).astype(dtype)


def _eye_rows_om(p_pad: int, blk: int, dtype):
    """Local Omega-like row block of the identity: ones at (r, u*blk + r)."""
    u = _block_om()
    rows = jnp.arange(blk)[:, None]
    cols = jnp.arange(p_pad)[None, :]
    return (cols == u * blk + rows).astype(dtype)


def _block_om():
    """Omega-like block index u = i*c_x + k of this device."""
    return lax.axis_index("i") * axis_size("k") + lax.axis_index("k")


def _diag_mask_panel_x(p_pad: int, blk: int, p_real: int, dtype):
    """(diag mask, frozen-padded-diag mask) for an X-like column panel."""
    t = _block_x()
    rows = jnp.arange(p_pad)[:, None]
    cols = jnp.arange(blk)[None, :]
    gcol = t * blk + cols
    on_diag = (rows == gcol).astype(dtype)
    padded = (rows == gcol) & (gcol >= p_real)
    return on_diag, padded.astype(dtype)


def _diag_mask_rows_om(p_pad: int, blk: int, p_real: int, dtype):
    u = _block_om()
    rows = jnp.arange(blk)[:, None]
    cols = jnp.arange(p_pad)[None, :]
    grow = u * blk + rows
    on_diag = (cols == grow).astype(dtype)
    padded = (cols == grow) & (grow >= p_real)
    return on_diag, padded.astype(dtype)


def _local_diag_panel_x(panel, blk):
    """Extract this panel's diagonal entries: panel[t*blk + r, r]."""
    t = _block_x()
    r = jnp.arange(blk)
    rows3 = lax.dynamic_slice_in_dim(panel, t * blk, blk, axis=0)
    return rows3[r, r]


def _local_diag_rows_om(rows_blk, blk):
    """Extract diagonal entries of an Omega-like row block: rows[r, u*blk+r]."""
    u = _block_om()
    r = jnp.arange(blk)
    cols3 = lax.dynamic_slice_in_dim(rows_blk, u * blk, blk, axis=1)
    return cols3[r, r]


def _psum_x(v):
    """Global sum of a per-X-block quantity (blocks indexed by (i, j))."""
    return lax.psum(v, ("i", "j"))


def _psum_om(v):
    return lax.psum(v, ("i", "k"))


def _pmin_x(v):
    return lax.pmin(v, ("i", "j"))


def _pmin_om(v):
    return lax.pmin(v, ("i", "k"))


def _dist_sparse_ops(policy: matops.MatmulPolicy, use_pallas: bool, dtype,
                     diag_mask_of, psum, prox):
    """(prox_stats, mask_of, density_of) shared by the Cov and Obs drivers —
    only the diag-mask layout and the psum axes differ between variants."""
    bs = policy.block_size

    def prox_stats(z, pen, tau, data):
        if use_pallas and pen.pallas_ok:
            # occupancy harvested for free from the fused kernel's nnz lane
            from ..kernels import ops as kops
            out, _, _, _, _, bnnz = kops.fused_prox_stats(
                z, diag_mask_of(), tau * pen.lam1, weights=pen.weights,
                block=(bs, bs))
            return out, (bnnz > 0).astype(matops.MASK_DTYPE)
        out = prox(z, pen, tau, data)
        return out, matops.block_mask(out, bs)

    def mask_of(omega_loc, data):
        return matops.block_mask(omega_loc, bs)

    def density_of(mask):
        # numerator and denominator both count each Omega block once per
        # partitioning team, so replication layers cancel in the ratio
        nnz = psum(jnp.sum((mask > 0).astype(matops.DENSITY_DTYPE)))
        total = psum(jnp.asarray(mask.size, matops.DENSITY_DTYPE))
        return nnz / total

    return prox_stats, mask_of, density_of


# ---------------------------------------------------------------------------
# Cov variant (Algorithm 2)
# ---------------------------------------------------------------------------

def _cov_local_ops(grid: Grid1p5D, p_pad: int, p_real: int, lam2, dtype,
                   use_pallas: bool = False,
                   sparse_matmul: matops.MatmulPolicy | None = None
                   ) -> VariantOps:
    blk = p_pad // grid.n_x
    n_pad_diag = p_pad - p_real
    policy = _shard_policy(sparse_matmul, (p_pad, blk))

    def aux_of(omega_panel, data, mask=None):
        # Figure 1: local transpose converts the column panel to the row
        # block the rotation consumes (iterates are symmetric).
        omega_rows = omega_panel.T
        if mask is None:
            return mm.omega_s_local(omega_rows, data["s"], grid,
                                    canonical="xlike")
        return sp.omega_s_local_sparse(omega_rows, mask.T, data["s"], grid,
                                       canonical="xlike", policy=policy)

    def g_of(omega_panel, w_panel, data):
        diag = _local_diag_panel_x(omega_panel, blk)
        logdet = -_psum_x(jnp.sum(jnp.log(jnp.maximum(diag, 1e-30))))
        quad = 0.5 * _psum_x(jnp.sum(w_panel * omega_panel))
        ridge = 0.5 * lam2 * (
            _psum_x(jnp.sum(omega_panel * omega_panel)) - n_pad_diag)
        g = logdet + quad + ridge
        return guard_nonpos_diag(g, _pmin_x(jnp.min(diag)))

    def grad_of(omega_panel, w_panel, data):
        wt_panel = mm.transpose_xlike_local(w_panel, grid)
        diag = _local_diag_panel_x(omega_panel, blk)
        diag_mask, pad_mask = _diag_mask_panel_x(p_pad, blk, p_real, dtype)
        t = _block_x()
        inv = jnp.zeros((p_pad, blk), dtype)
        inv = lax.dynamic_update_slice_in_dim(
            inv, jnp.diag(1.0 / diag), t * blk, axis=0)
        grad = -inv + 0.5 * (w_panel + wt_panel) + lam2 * omega_panel
        return grad * (1.0 - pad_mask)            # freeze padded diagonal

    def dot(a, b):
        return _psum_x(jnp.sum(a * b))

    def prox(z, pen, tau, data):
        diag_mask, _ = _diag_mask_panel_x(p_pad, blk, p_real, dtype)
        if use_pallas and pen.pallas_ok:
            from ..kernels import ops as kops
            return kops.fused_prox(z, diag_mask, tau * pen.lam1,
                                   weights=pen.weights)
        return pen.prox(z, tau, diag_mask)

    if policy is None:
        return VariantOps(aux_of, g_of, grad_of, dot, prox)
    return VariantOps(aux_of, g_of, grad_of, dot, prox, *_dist_sparse_ops(
        policy, use_pallas, dtype,
        lambda: _diag_mask_panel_x(p_pad, blk, p_real, dtype)[0],
        _psum_x, prox))


# ---------------------------------------------------------------------------
# Obs variant (Algorithm 3)
# ---------------------------------------------------------------------------

def _obs_local_ops(grid: Grid1p5D, p_pad: int, p_real: int, n: int, lam2,
                   dtype, use_pallas: bool = False,
                   sparse_matmul: matops.MatmulPolicy | None = None
                   ) -> VariantOps:
    blk = p_pad // grid.n_om
    n_pad_diag = p_pad - p_real
    # the reduce-flavor rotation slices Omega at blk_x granularity, so the
    # mask slice must land on block boundaries too
    policy = _shard_policy(sparse_matmul, (blk, p_pad),
                           also_divide=(p_pad // grid.n_x,))

    def aux_of(omega_rows, data, mask=None):
        xt_loc = data["x"].T                      # local transpose
        if mask is None:
            return mm.omega_xt_local(omega_rows, xt_loc, grid)  # Y, unnorm.
        return sp.omega_xt_local_sparse(omega_rows, mask, xt_loc, grid,
                                        policy=policy)

    def g_of(omega_rows, y_rows, data):
        diag = _local_diag_rows_om(omega_rows, blk)
        logdet = -_psum_om(jnp.sum(jnp.log(jnp.maximum(diag, 1e-30))))
        quad = 0.5 * _psum_om(jnp.sum(y_rows * y_rows)) / n
        ridge = 0.5 * lam2 * (
            _psum_om(jnp.sum(omega_rows * omega_rows)) - n_pad_diag)
        g = logdet + quad + ridge
        return guard_nonpos_diag(g, _pmin_om(jnp.min(diag)))

    def grad_of(omega_rows, y_rows, data):
        z = mm.y_x_local(y_rows, data["x"], grid, scale=1.0 / n)
        zt = mm.transpose_omegalike_local(z, grid)
        diag = _local_diag_rows_om(omega_rows, blk)
        diag_mask, pad_mask = _diag_mask_rows_om(p_pad, blk, p_real, dtype)
        u = _block_om()
        inv = jnp.zeros((blk, p_pad), dtype)
        inv = lax.dynamic_update_slice_in_dim(
            inv, jnp.diag(1.0 / diag), u * blk, axis=1)
        grad = -inv + 0.5 * (z + zt) + lam2 * omega_rows
        return grad * (1.0 - pad_mask)

    def dot(a, b):
        return _psum_om(jnp.sum(a * b))

    def prox(z, pen, tau, data):
        diag_mask, _ = _diag_mask_rows_om(p_pad, blk, p_real, dtype)
        if use_pallas and pen.pallas_ok:
            from ..kernels import ops as kops
            return kops.fused_prox(z, diag_mask, tau * pen.lam1,
                                   weights=pen.weights)
        return pen.prox(z, tau, diag_mask)

    if policy is None:
        return VariantOps(aux_of, g_of, grad_of, dot, prox)
    return VariantOps(aux_of, g_of, grad_of, dot, prox, *_dist_sparse_ops(
        policy, use_pallas, dtype,
        lambda: _diag_mask_rows_om(p_pad, blk, p_real, dtype)[0],
        _psum_om, prox))


# ---------------------------------------------------------------------------
# shard_map drivers
# ---------------------------------------------------------------------------

def _scalar_specs():
    return ProxResult(omega=None, iters=P(), ls_total=P(), converged=P(),
                      g_final=P(), delta_final=P(), stalled=P(),
                      block_density=P())


def _pad_omega0(omega0, p: int, p_pad: int, dtype):
    """Pad a warm-start iterate with the frozen identity diagonal so the
    padded block behaves exactly like a cold start there.  (Cold starts
    never call this: the identity is built per-shard inside shard_map.)"""
    omega0 = jnp.asarray(omega0, dtype)
    if p_pad != p:
        omega0 = jnp.pad(omega0, ((0, p_pad - p), (0, p_pad - p)))
        pad_idx = jnp.arange(p, p_pad)
        omega0 = omega0.at[pad_idx, pad_idx].set(1.0)
    return omega0


def _pad_spec_weights(spec: PenaltySpec, p: int, p_pad: int,
                      dtype) -> PenaltySpec:
    """Cast the weight matrix to the solve dtype and zero-pad it to the
    grid-padded dimension (padded off-diagonal entries stay exactly zero
    whatever their weight, so the pad value is inert)."""
    if spec.weights is None:
        return spec
    w = jnp.asarray(spec.weights, dtype)
    if w.shape != (p, p):
        raise ValueError(
            f"penalty weights shape {w.shape} must match the problem "
            f"dimension ({p}, {p})")
    if p_pad != p:
        w = jnp.pad(w, ((0, p_pad - p), (0, p_pad - p)))
    return dataclasses.replace(spec, weights=w)


def _spec_partition(spec: PenaltySpec, mat_spec):
    """shard_map in_specs tree for a penalty spec: the (p_pad, p_pad)
    weight matrix shards with the Omega layout, scalars replicate."""
    return jax.tree.map(
        lambda leaf: mat_spec if getattr(leaf, "ndim", 0) == 2 else P(),
        spec)


#: dispatch-observer hook (``repro.obs.commwatch``): when set, the driver
#: announces every jit dispatch (inside ``use_mesh``, before the call) and
#: its result.  The observer may re-trace the closure (``jax.make_jaxpr``)
#: but must not compile or execute anything — the solve itself is untouched.
_DISPATCH_OBSERVER = None


def set_dispatch_observer(observer):
    """Install ``observer`` (or None) on the driver dispatch hook; returns
    the previous observer so callers can restore it."""
    global _DISPATCH_OBSERVER
    prev = _DISPATCH_OBSERVER
    _DISPATCH_OBSERVER = observer
    return prev


def _dispatch(variant, fn, args, grid, meta):
    """Run one driver jit dispatch through the observer hook (no-op when
    no observer is installed)."""
    obs = _DISPATCH_OBSERVER
    token = None
    if obs is not None:
        token = obs.on_dispatch(variant, fn, args, grid, meta)
    res = jax.jit(fn)(*args)
    if obs is not None:
        obs.on_result(token, res)
    return res


def fit_cov(
    s: jax.Array,
    lam1: float | None = None,
    lam2: float = 0.0,
    *,
    grid: Grid1p5D,
    mesh=None,
    tol: float = 1e-5,
    max_iters: int = 500,
    max_ls: int = 30,
    warm_start_tau: bool = False,
    use_pallas: bool = False,
    omega0: jax.Array | None = None,
    penalty: PenaltySpec | str | None = None,
    sparse_matmul: matops.MatmulPolicy | None = None,
) -> FitResult:
    """Distributed Cov solve (Algorithm 2). ``s`` is the (p, p) sample cov.
    ``omega0`` optionally warm-starts the iterates (e.g. along a lam1 path).
    ``penalty`` swaps the prox operator (``core.penalty``): scalar penalty
    parameters travel replicated through the shard_map, a weighted-l1
    weight matrix is sharded with the Omega panel layout.  Legacy
    ``lam1``/``lam2`` floats build the equivalent l1 spec.
    ``sparse_matmul`` routes the W = Omega S rotation through the
    block-sparse local products of ``comm.sparse1p5d``."""
    if grid.c_x != grid.c_omega:
        raise ValueError("Cov keeps Omega in the X-like layout: c_x == c_omega")
    mesh = mesh or grid.make_mesh()
    p = s.shape[0]
    p_pad = grid.pad_p(p)
    dtype = s.dtype
    spec = _pad_spec_weights(normalize_penalty(penalty, lam1, lam2),
                             p, p_pad, dtype)
    if p_pad != p:
        s = jnp.pad(s, ((0, p_pad - p), (0, p_pad - p)))
    blk = p_pad // grid.n_x
    ops = _cov_local_ops(grid, p_pad, p, jnp.asarray(spec.lam2, dtype),
                         dtype, use_pallas, sparse_matmul)
    spec_parts = _spec_partition(spec, SPEC_XCOL)

    def solve_local(om0_panel, s_panel, pen):
        return prox_gradient(
            om0_panel, {"s": s_panel}, ops, penalty=pen, tol=tol,
            max_iters=max_iters, max_ls=max_ls, warm_start_tau=warm_start_tau)

    specs = _scalar_specs()._replace(omega=SPEC_XCOL)
    if omega0 is None:
        # cold start: build the identity panel per shard (never materialize
        # the full p_pad^2 identity on one device)
        def local(s_panel, pen):
            return solve_local(_eye_panel_x(p_pad, blk, dtype), s_panel, pen)

        fn = shard_map(local, mesh=mesh, in_specs=(SPEC_XCOL, spec_parts),
                       out_specs=ProxResult(*specs), check_vma=False)
        args = (s, spec)
    else:
        def local(s_panel, pen, om0_panel):
            return solve_local(om0_panel, s_panel, pen)

        fn = shard_map(local, mesh=mesh,
                       in_specs=(SPEC_XCOL, spec_parts, SPEC_XCOL),
                       out_specs=ProxResult(*specs), check_vma=False)
        args = (s, spec, _pad_omega0(omega0, p, p_pad, dtype))
    with use_mesh(mesh):
        res = _dispatch("cov", fn, args, grid,
                        {"p": p, "p_pad": p_pad, "n": None,
                         "dtype": jnp.dtype(dtype).name,
                         "sparse": ops.prox_stats is not None})
    return FitResult(res.omega[:p, :p], res.iters, res.ls_total,
                     res.converged, res.g_final, "cov", grid,
                     res.block_density, res.stalled)


def fit_obs(
    x: jax.Array,
    lam1: float | None = None,
    lam2: float = 0.0,
    *,
    grid: Grid1p5D,
    mesh=None,
    tol: float = 1e-5,
    max_iters: int = 500,
    max_ls: int = 30,
    warm_start_tau: bool = False,
    use_pallas: bool = False,
    omega0: jax.Array | None = None,
    penalty: PenaltySpec | str | None = None,
    sparse_matmul: matops.MatmulPolicy | None = None,
) -> FitResult:
    """Distributed Obs solve (Algorithm 3). ``x`` is the (n, p) data matrix.
    ``omega0`` optionally warm-starts the iterates (e.g. along a lam1 path).
    ``penalty`` swaps the prox operator (``core.penalty``); a weighted-l1
    weight matrix is sharded with the Omega row-block layout.  Legacy
    ``lam1``/``lam2`` floats build the equivalent l1 spec.
    ``sparse_matmul`` routes the Y = Omega X^T rotation through the
    block-sparse local products of ``comm.sparse1p5d``."""
    mesh = mesh or grid.make_mesh()
    n, p = x.shape
    p_pad = grid.pad_p(p)
    dtype = x.dtype
    spec = _pad_spec_weights(normalize_penalty(penalty, lam1, lam2),
                             p, p_pad, dtype)
    if p_pad != p:
        x = jnp.pad(x, ((0, 0), (0, p_pad - p)))
    blk = p_pad // grid.n_om
    ops = _obs_local_ops(grid, p_pad, p, n, jnp.asarray(spec.lam2, dtype),
                         dtype, use_pallas, sparse_matmul)
    spec_parts = _spec_partition(spec, SPEC_OM)

    def solve_local(om0_rows, x_loc, pen):
        return prox_gradient(
            om0_rows, {"x": x_loc}, ops, penalty=pen, tol=tol,
            max_iters=max_iters, max_ls=max_ls, warm_start_tau=warm_start_tau)

    specs = _scalar_specs()._replace(omega=SPEC_OM)
    if omega0 is None:
        def local(x_loc, pen):
            return solve_local(_eye_rows_om(p_pad, blk, dtype), x_loc, pen)

        fn = shard_map(local, mesh=mesh, in_specs=(SPEC_XCOL, spec_parts),
                       out_specs=ProxResult(*specs), check_vma=False)
        args = (x, spec)
    else:
        def local(x_loc, pen, om0_rows):
            return solve_local(om0_rows, x_loc, pen)

        fn = shard_map(local, mesh=mesh,
                       in_specs=(SPEC_XCOL, spec_parts, SPEC_OM),
                       out_specs=ProxResult(*specs), check_vma=False)
        args = (x, spec, _pad_omega0(omega0, p, p_pad, dtype))
    with use_mesh(mesh):
        res = _dispatch("obs", fn, args, grid,
                        {"p": p, "p_pad": p_pad, "n": n,
                         "dtype": jnp.dtype(dtype).name,
                         "sparse": ops.prox_stats is not None})
    return FitResult(res.omega[:p, :p], res.iters, res.ls_total,
                     res.converged, res.g_final, "obs", grid,
                     res.block_density, res.stalled)


# ---------------------------------------------------------------------------
# High-level estimator — the paper's cost-model-driven front door
# ---------------------------------------------------------------------------

def estimate_density(p: int, n: int, lam1: float) -> float:
    """Crude prior for d (avg nnz/row of the iterates) used by the tuner
    before any fit exists: heavier penalty -> sparser iterates."""
    return float(min(p, max(2.0, 0.05 * p / max(lam1, 1e-2))))


def fit(
    x: jax.Array | None = None,
    s: jax.Array | None = None,
    *,
    lam1: float,
    lam2: float = 0.0,
    variant: str = "auto",
    n_devices: int | None = None,
    c_x: int | None = None,
    c_omega: int | None = None,
    machine: Machine | None = None,
    n_samples: int | None = None,
    **kw,
) -> FitResult:
    """Deprecated shim — use :mod:`repro.estimator` (``ConcordEstimator`` or
    ``repro.estimator.fit``), which adds backend selection, warm starts and
    rich fit reports on top of the same cost-model dispatch.

    Pass ``x`` (n, p) to allow either variant, or only ``s`` (p, p) to force
    Cov. ``c_x``/``c_omega`` pin the replication factors (e.g. for the
    Figure-3 sweep); otherwise the tuner picks them.
    """
    warnings.warn(
        "distributed.fit is deprecated; use repro.estimator.ConcordEstimator "
        "or repro.estimator.fit", DeprecationWarning, stacklevel=2)
    if x is None and s is None:
        raise ValueError("pass x or s")
    P_ = n_devices or len(jax.devices())
    p = (x if x is not None else s).shape[-1]
    n = x.shape[0] if x is not None else (n_samples or p)
    m = machine or Machine()
    shape = ProblemShape(p=p, n=n, d=estimate_density(p, n, lam1))

    pinned_cx, pinned_co = c_x is not None, c_omega is not None
    user_pinned = pinned_cx or pinned_co
    if variant == "auto":
        variants = ("cov", "obs") if x is not None else ("cov",)
        best = tune(shape, P_, m, variants)
        variant = best.variant
        c_x = c_x if c_x is not None else best.c_x
        c_omega = c_omega if c_omega is not None else best.c_omega
    c_x = c_x or 1
    c_omega = c_omega or 1
    if variant == "cov":
        if pinned_co and c_omega != c_x:
            # same error as estimator.backends._check_grid — a pinned
            # c_omega must not be silently coerced to c_x
            raise ValueError(
                f"Cov keeps Omega in the X-like layout, so c_x must equal "
                f"c_omega (got c_x={c_x}, c_omega={c_omega})")
        c_omega = c_x  # Cov keeps Omega X-like
        if c_x * c_omega > P_ or P_ % (c_x * c_omega):
            if user_pinned:
                # Same error as estimator.backends._check_grid: never
                # silently rewrite a USER-pinned replication layout (the
                # old behaviour reset it to 1x1 behind the caller's back).
                raise ValueError(
                    f"replication c_x*c_omega={c_x * c_omega} must divide "
                    f"n_devices={P_} (got c_x={c_x}, c_omega={c_omega})")
            # tuner-derived factors may become infeasible after the Cov
            # c_omega = c_x coercion; repairing the tuner's own choice is
            # not a user-visible rewrite
            c_x = c_omega = 1
        grid = Grid1p5D(P_, c_x, c_omega)
        s_mat = s if s is not None else (x.T @ x) / n
        return fit_cov(s_mat, lam1, lam2, grid=grid, **kw)
    grid = Grid1p5D(P_, c_x, c_omega)
    if x is None:
        raise ValueError("Obs variant requires the data matrix x")
    return fit_obs(x, lam1, lam2, grid=grid, **kw)


def fit_path(
    x: jax.Array,
    lam1_grid,
    lam2: float = 0.0,
    *,
    variant: str = "obs",
    grid: Grid1p5D | None = None,
    **kw,
) -> list[FitResult]:
    """Deprecated shim — use ``repro.estimator.ConcordEstimator.fit_path``,
    which warm-starts consecutive path points and reuses the compiled solve.

    Fit a path of estimates over a lam1 grid (the paper's Section-5
    tuning-parameter sweep). Runs coarse-to-fine so sparser fits come first."""
    warnings.warn(
        "distributed.fit_path is deprecated; use "
        "repro.estimator.ConcordEstimator.fit_path", DeprecationWarning,
        stacklevel=2)
    P_ = len(jax.devices())
    grid = grid or Grid1p5D(P_, 1, 1)
    out = []
    for lam1 in sorted(lam1_grid, reverse=True):
        fn = fit_obs if variant == "obs" else fit_cov
        data = x if variant == "obs" else (x.T @ x) / x.shape[0]
        out.append(fn(data, lam1, lam2, grid=grid, **kw))
    return out


# ---------------------------------------------------------------------------
# analysis manifest (repro.analysis.jaxprpass)
# ---------------------------------------------------------------------------

def _analysis_fit_cov():
    grid = Grid1p5D(1, 1, 1)
    mesh = grid.make_mesh()
    p = 8
    s = jnp.eye(p, dtype=jnp.float64) + 0.05 * jnp.ones((p, p), jnp.float64)

    def run(s_):
        res = fit_cov(s_, 0.2, grid=grid, mesh=mesh, tol=1e-3, max_iters=4,
                      max_ls=4)
        return res.omega, res.iters, res.converged, res.block_density

    return {"fn": run, "args": (s,), "axis_sizes": dict(_AXIS_SIZES_1DEV)}


def _analysis_fit_obs():
    grid = Grid1p5D(1, 1, 1)
    mesh = grid.make_mesh()
    n, p = 12, 8
    x = jnp.linspace(-1.0, 1.0, n * p, dtype=jnp.float64).reshape(n, p)

    def run(x_):
        res = fit_obs(x_, 0.2, grid=grid, mesh=mesh, tol=1e-3, max_iters=4,
                      max_ls=4)
        return res.omega, res.iters, res.converged, res.block_density

    return {"fn": run, "args": (x,), "axis_sizes": dict(_AXIS_SIZES_1DEV)}


def _driver_contract():
    """Declared schedule of the end-to-end drivers (comm engine CA305/
    CA306 structure checks; no volume contract — the outer while_loop's
    trip count is dynamic, so bytes/invocation is not a static quantity
    here; the per-product volumes are pinned by the ``comm.matmul1p5d``
    and ``comm.sparse1p5d`` entries instead)."""
    from ..comm.contract import CommContract
    return CommContract(
        entry="core.distributed.fit",
        axes=("i", "j", "k"),
        kinds=("ppermute", "psum", "pmin", "pmax", "all_gather",
               "all_to_all"),
        # the iterate/objective arithmetic is f64 by contract; the ring
        # also rotates int8 occupancy masks and reduces f32 density
        # diagnostics and i32/bool loop control
        wire=("operand", "mask", "float32", "int32", "int64", "bool"),
        volume_class="shard_map driver (dynamic trip count)")


COMM_CONTRACT = {
    "fit_cov": _driver_contract(),
    "fit_obs": _driver_contract(),
}

#: both 1.5D shard_map drivers, traced end to end on a 1-device
#: (1, 1, 1) mesh: the jaxpr still contains every psum/axis binding of
#: the distributed iteration, so the dtype and axis contracts are
#: checked without multi-device hardware (axis extents are all 1 there,
#: hence no volume contract on these entries — see _driver_contract)
_AXIS_SIZES_1DEV = {"i": 1, "j": 1, "k": 1}
ANALYSIS_ENTRIES = [
    {"name": "core.distributed.fit_cov",
     "path": "src/repro/core/distributed.py",
     "axis_names": ("i", "j", "k"), "build": _analysis_fit_cov,
     "comm": lambda: {"contract": COMM_CONTRACT["fit_cov"], "params": {}}},
    {"name": "core.distributed.fit_obs",
     "path": "src/repro/core/distributed.py",
     "axis_names": ("i", "j", "k"), "build": _analysis_fit_obs,
     "comm": lambda: {"contract": COMM_CONTRACT["fit_obs"], "params": {}}},
]
