"""Analytic cost model for HP-CONCORD (paper Lemmas 3.1-3.5) + auto-tuner.

T = F*gamma + L*alpha + W*beta  with machine constants gamma (s/flop),
alpha (s/message) and beta (s/word).

TPU v5e constants (the repo's target part):
  * 197 TFLOP/s bf16 per chip  -> gamma = 1/197e12 (bf16), fp32 ~ x2
  * 819 GB/s HBM bandwidth
  * ~50 GB/s per ICI link; a ppermute "message" occupies one link for
    (words*bytes)/50e9 s; per-round launch overhead ~1us.

On TPU the paper's per-message latency alpha is the per-round collective
launch overhead; the ring shift of Algorithm 4 maps to lax.ppermute over
neighbor links, so bandwidth is per-link (not bisection).

The tuner enumerates feasible (c_X, c_Omega) pairs (divisors of P with
c_X*c_Omega <= P) under the memory caps M_Cov/M_Obs (paper Sec. 3) and
returns the variant+replication with the lowest modeled time — this is the
paper's main "communication-avoiding" decision procedure, exposed as a
first-class feature (used by the estimator and by benchmarks/fig3).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Iterable


@dataclass(frozen=True)
class Machine:
    """Machine-dependent constants (per chip)."""
    name: str = "tpu_v5e"
    peak_flops: float = 197e12        # bf16 FLOP/s
    hbm_bw: float = 819e9             # bytes/s
    link_bw: float = 50e9             # bytes/s per ICI link
    msg_overhead: float = 1e-6        # s per collective round (alpha)
    hbm_bytes: float = 16e9           # HBM capacity per chip
    word_bytes: int = 4               # fp32 words for Omega/S/X

    @property
    def gamma(self) -> float:
        return 1.0 / self.peak_flops

    @property
    def beta(self) -> float:
        return self.word_bytes / self.link_bw

    @property
    def alpha(self) -> float:
        return self.msg_overhead


EDISON = Machine(
    name="edison_xc30",
    peak_flops=460.8e9,     # 2x12 cores x 2.4GHz x 8 flops (per node)
    hbm_bw=100e9,
    link_bw=8e9,            # Aries per-direction
    msg_overhead=2e-6,
    hbm_bytes=64e9,
    word_bytes=8,           # paper ran double precision
)


@dataclass(frozen=True)
class ProblemShape:
    p: int                  # dimensions
    n: int                  # samples
    d: float                # avg nnz per row of Omega across iterations
    s: int = 30             # proximal-gradient iterations
    t: float = 10.0         # avg line-search trials per outer iteration


@dataclass
class CostBreakdown:
    variant: str
    c_x: int
    c_omega: int
    flops: float
    messages: float
    words: float
    mem_words: float
    t_compute: float = 0.0
    t_latency: float = 0.0
    t_bandwidth: float = 0.0

    @property
    def total(self) -> float:
        return self.t_compute + self.t_latency + self.t_bandwidth


def _q(P: int, c_x: int, c_omega: int) -> float:
    return max(P / c_x**2, P / c_omega**2)


def cov_costs(shape: ProblemShape, P: int, c_x: int, c_omega: int,
              m: Machine) -> CostBreakdown:
    """Lemma 3.4/3.5 (Cov): F, L, W and T for given replication factors."""
    p, n, d, s, t = shape.p, shape.n, shape.d, shape.s, shape.t
    Q = _q(P, c_x, c_omega)
    lg = math.log2(max(Q, 2))
    F = 2 * n * p**2 + 2 * d * p**2 * (s * t + 1)
    L = P / c_x**2 + s * t * P / (c_x * c_omega) + lg
    W = n * p / c_x + s * t * d * p / c_x + p**2 * (c_x * c_omega / P) * Q * lg
    M = c_omega * d * p + 3 * c_x * p**2          # per paper Sec 3 (total words)
    cb = CostBreakdown("cov", c_x, c_omega, F, L, W, M)
    # Lemma 3.4 counts messages/words along the critical path (per processor),
    # so T = (F/P)*gamma + L*alpha + W*beta directly (paper Lemma 3.5).
    cb.t_compute = F / P * m.gamma
    cb.t_latency = L * m.alpha
    cb.t_bandwidth = W * m.beta
    return cb


def obs_costs(shape: ProblemShape, P: int, c_x: int, c_omega: int,
              m: Machine) -> CostBreakdown:
    """Lemma 3.4/3.5 (Obs)."""
    p, n, d, s, t = shape.p, shape.n, shape.d, shape.s, shape.t
    Q = _q(P, c_x, c_omega)
    lg = math.log2(max(Q, 2))
    F = 2 * n * p**2 * s + 2 * d * n * p * (s * t + 1)
    L = s * (t + 1) * P / (c_omega * c_x) + lg
    W = s * (t + 1) * n * p / c_omega + p**2 * (c_x * c_omega / P) * Q * lg
    M = 2 * c_x * n * p + c_omega * (d * p + n * p + 2 * p**2)
    cb = CostBreakdown("obs", c_x, c_omega, F, L, W, M)
    cb.t_compute = F / P * m.gamma
    cb.t_latency = L * m.alpha
    cb.t_bandwidth = W * m.beta
    return cb


def cov_is_cheaper(shape: ProblemShape) -> bool:
    """Lemma 3.1 crossover: Cov wins iff d/p < (n/(p-n)) * (1/t)."""
    p, n, d, t = shape.p, shape.n, shape.d, shape.t
    if n >= p:
        return True
    return (d / p) < (n / (p - n)) / t


def _divisors(P: int) -> list[int]:
    return [c for c in range(1, P + 1) if P % c == 0]


def enumerate_configs(shape: ProblemShape, P: int, m: Machine,
                      variants: Iterable[str] = ("cov", "obs")
                      ) -> list[CostBreakdown]:
    """All feasible (variant, c_x, c_omega) under replication & memory caps."""
    out = []
    mem_cap_words = m.hbm_bytes / m.word_bytes * P   # aggregate capacity
    for c_x in _divisors(P):
        for c_omega in _divisors(P):
            if c_x * c_omega > P:
                continue
            for v in variants:
                fn = cov_costs if v == "cov" else obs_costs
                cb = fn(shape, P, c_x, c_omega, m)
                if cb.mem_words <= mem_cap_words:
                    out.append(cb)
    return out


def tune(shape: ProblemShape, P: int, m: Machine | None = None,
         variants: Iterable[str] = ("cov", "obs")) -> CostBreakdown:
    """Pick the best (variant, c_x, c_omega) for the problem — the paper's
    cost-model-driven configuration choice."""
    m = m or Machine()
    configs = enumerate_configs(shape, P, m, variants)
    if not configs:
        raise ValueError(
            f"no feasible replication config for p={shape.p} on P={P} "
            f"(need more chips: min aggregate memory ~{3*shape.p**2} words)")
    return min(configs, key=lambda cb: cb.total)


# ---------------------------------------------------------------------------
# dense vs block-sparse matmul crossover (the matops layer's cost model)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class BlockSparseModel:
    """Constants of the dense↔block-gather crossover for the Ω-side product
    C = A(p,p) @ B(p,m) with A at block density δ.

      T_dense(p, m)      = 2 p^2 m γ / dense_eff
      T_sparse(p, m, δ)  = 2 δ p^2 m γ / sparse_eff              (flops)
                         + δ nb (2 bs m + bs^2) w / B / gather_eff (gathers)

    with nb = ceil(p/bs)^2 total blocks, γ/w/B the machine's seconds-per-
    flop / word bytes / HBM bandwidth.  The *_eff fractions are achieved
    efficiency relative to machine peak, so the constants transfer across
    machines of similar balance; ``calibrate_block_model`` refits them from
    a ``benchmarks/sparse_crossover.py`` sweep on the actual hardware.
    Defaults are deliberately conservative (the modeled crossover sits
    below the measured one), so ``sparse_matmul="auto"`` never routes a
    product through the block path above the real break-even density.
    """
    dense_eff: float = 0.85       # dense matmul fraction-of-peak
    sparse_eff: float = 0.45      # block-gather matmul fraction-of-peak
    gather_eff: float = 0.50      # block gather/scatter fraction of HBM bw


def _nb_total(p: int, block_size: int) -> int:
    return (-(-p // block_size)) ** 2


def dense_matmul_time(p: int, m: int, machine: Machine | None = None,
                      model: BlockSparseModel | None = None) -> float:
    machine = machine or Machine()
    model = model or BlockSparseModel()
    return 2.0 * p * p * m * machine.gamma / model.dense_eff


def blocksparse_matmul_time(p: int, m: int, density: float, block_size: int,
                            machine: Machine | None = None,
                            model: BlockSparseModel | None = None) -> float:
    machine = machine or Machine()
    model = model or BlockSparseModel()
    bs = block_size
    flops = 2.0 * density * p * p * m * machine.gamma / model.sparse_eff
    gathered_bytes = (density * _nb_total(p, bs) * (2.0 * bs * m + bs * bs)
                      * machine.word_bytes)
    return flops + gathered_bytes / machine.hbm_bw / model.gather_eff


def crossover_density(p: int, m: int, block_size: int,
                      machine: Machine | None = None,
                      model: BlockSparseModel | None = None) -> float:
    """Block density δ* at which T_sparse(δ*) = T_dense — the routing
    threshold of ``sparse_matmul="auto"``.  Both sides are linear in δ, so
    δ* = T_dense / T_sparse(δ=1), clamped to [0, 1]."""
    td = dense_matmul_time(p, m, machine, model)
    ts1 = blocksparse_matmul_time(p, m, 1.0, block_size, machine, model)
    if ts1 <= 0.0:
        return 1.0
    return max(0.0, min(1.0, td / ts1))


def gram_chunk_rows(p: int, *, machine: Machine | None = None,
                    budget_bytes: float | None = None,
                    dtype_bytes: int = 8) -> int:
    """Chunk-size guidance for the streaming Gram pipeline (``data.gram``).

    Two constraints pick the row-block size m of a streamed XᵀX:

      * memory — the resident working set is the f64 chunk (m·p·8 B), one
        transform copy of it, and the (p, p) f64 accumulator; chunk +
        copy must fit what the budget leaves AFTER the accumulator
        (default budget: 1/8 of the machine's HBM, leaving room for the
        solve that follows);
      * efficiency — the panel product (panel, m) @ (m, p) has arithmetic
        intensity ~m flops/byte on the streamed operand, so m below a few
        hundred rows turns the accumulation bandwidth-bound.  We floor at
        256 rows and never ask for more than 2^20 (diminishing returns,
        and shard files are typically smaller anyway).

    Raises when the (p, p) accumulator alone exhausts the budget — at
    that point no chunk size makes the pipeline fit and the caller needs
    the distributed twin (one accumulator shard per host) or a bigger
    budget, not a smaller chunk.

    Used as the default by ``launch/gram.py prep`` and documented in the
    README's chunk-size guidance.
    """
    if p < 1:
        raise ValueError(f"p must be >= 1, got {p}")
    machine = machine or Machine()
    budget = budget_bytes if budget_bytes is not None \
        else machine.hbm_bytes / 8.0
    left = budget - float(p) * p * dtype_bytes
    if left <= 0:
        raise ValueError(
            f"the (p, p) f64 accumulator alone ({p}^2 x {dtype_bytes} B = "
            f"{p * p * dtype_bytes / 1e9:.1f} GB) exceeds the "
            f"{budget / 1e9:.1f} GB budget; shard the Gram across hosts "
            f"(data.distributed_gram) or raise budget_bytes")
    rows = int(left // (2 * p * dtype_bytes))
    return max(256, min(rows, 1 << 20))


def calibrate_block_model(rows, machine: Machine | None = None
                          ) -> BlockSparseModel:
    """Refit :class:`BlockSparseModel` from measured sweep rows (dicts with
    ``p``, ``m``, ``block_size``, ``density``, ``t_dense``, ``t_sparse``) —
    the output of ``benchmarks/sparse_crossover.py``."""
    import numpy as np

    machine = machine or Machine()
    rows = [r for r in rows if r.get("t_dense", 0) > 0 and
            r.get("t_sparse", 0) > 0]
    if not rows:
        raise ValueError("no usable rows to calibrate from")
    dense_effs = [2.0 * r["p"] ** 2 * r["m"] * machine.gamma / r["t_dense"]
                  for r in rows]
    dense_eff = float(np.median(dense_effs))
    # least squares for the two sparse-path coefficients
    a = np.array([[2.0 * r["density"] * r["p"] ** 2 * r["m"] * machine.gamma,
                   r["density"] * _nb_total(r["p"], r["block_size"])
                   * (2.0 * r["block_size"] * r["m"] + r["block_size"] ** 2)
                   * machine.word_bytes / machine.hbm_bw]
                  for r in rows])
    y = np.array([r["t_sparse"] for r in rows])
    coef, *_ = np.linalg.lstsq(a, y, rcond=None)
    inv_sparse_eff = max(float(coef[0]), 1e-12)
    inv_gather_eff = max(float(coef[1]), 1e-12)
    return BlockSparseModel(dense_eff=max(dense_eff, 1e-12),
                            sparse_eff=1.0 / inv_sparse_eff,
                            gather_eff=1.0 / inv_gather_eff)
