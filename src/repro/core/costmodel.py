"""Analytic cost model for HP-CONCORD (paper Lemmas 3.1-3.5) + auto-tuner.

T = F*gamma + L*alpha + W*beta  with machine constants gamma (s/flop),
alpha (s/message) and beta (s/word).

TPU v5e constants (the repo's target part):
  * 197 TFLOP/s bf16 per chip  -> gamma = 1/197e12 (bf16), fp32 ~ x2
  * 819 GB/s HBM bandwidth
  * ~50 GB/s per ICI link; a ppermute "message" occupies one link for
    (words*bytes)/50e9 s; per-round launch overhead ~1us.

On TPU the paper's per-message latency alpha is the per-round collective
launch overhead; the ring shift of Algorithm 4 maps to lax.ppermute over
neighbor links, so bandwidth is per-link (not bisection).

The tuner enumerates feasible (c_X, c_Omega) pairs (divisors of P with
c_X*c_Omega <= P) under the memory caps M_Cov/M_Obs (paper Sec. 3) and
returns the variant+replication with the lowest modeled time — this is the
paper's main "communication-avoiding" decision procedure, exposed as a
first-class feature (used by the estimator and by benchmarks/fig3).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Iterable


@dataclass(frozen=True)
class Machine:
    """Machine-dependent constants (per chip)."""
    name: str = "tpu_v5e"
    peak_flops: float = 197e12        # bf16 FLOP/s
    hbm_bw: float = 819e9             # bytes/s
    link_bw: float = 50e9             # bytes/s per ICI link
    msg_overhead: float = 1e-6        # s per collective round (alpha)
    hbm_bytes: float = 16e9           # HBM capacity per chip
    word_bytes: int = 4               # fp32 words for Omega/S/X

    @property
    def gamma(self) -> float:
        return 1.0 / self.peak_flops

    @property
    def beta(self) -> float:
        return self.word_bytes / self.link_bw

    @property
    def alpha(self) -> float:
        return self.msg_overhead


EDISON = Machine(
    name="edison_xc30",
    peak_flops=460.8e9,     # 2x12 cores x 2.4GHz x 8 flops (per node)
    hbm_bw=100e9,
    link_bw=8e9,            # Aries per-direction
    msg_overhead=2e-6,
    hbm_bytes=64e9,
    word_bytes=8,           # paper ran double precision
)


@dataclass(frozen=True)
class ProblemShape:
    p: int                  # dimensions
    n: int                  # samples
    d: float                # avg nnz per row of Omega across iterations
    s: int = 30             # proximal-gradient iterations
    t: float = 10.0         # avg line-search trials per outer iteration


@dataclass
class CostBreakdown:
    variant: str
    c_x: int
    c_omega: int
    flops: float
    messages: float
    words: float
    mem_words: float
    t_compute: float = 0.0
    t_latency: float = 0.0
    t_bandwidth: float = 0.0

    @property
    def total(self) -> float:
        return self.t_compute + self.t_latency + self.t_bandwidth


def _q(P: int, c_x: int, c_omega: int) -> float:
    return max(P / c_x**2, P / c_omega**2)


def cov_costs(shape: ProblemShape, P: int, c_x: int, c_omega: int,
              m: Machine) -> CostBreakdown:
    """Lemma 3.4/3.5 (Cov): F, L, W and T for given replication factors."""
    p, n, d, s, t = shape.p, shape.n, shape.d, shape.s, shape.t
    Q = _q(P, c_x, c_omega)
    lg = math.log2(max(Q, 2))
    F = 2 * n * p**2 + 2 * d * p**2 * (s * t + 1)
    L = P / c_x**2 + s * t * P / (c_x * c_omega) + lg
    W = n * p / c_x + s * t * d * p / c_x + p**2 * (c_x * c_omega / P) * Q * lg
    M = c_omega * d * p + 3 * c_x * p**2          # per paper Sec 3 (total words)
    cb = CostBreakdown("cov", c_x, c_omega, F, L, W, M)
    # Lemma 3.4 counts messages/words along the critical path (per processor),
    # so T = (F/P)*gamma + L*alpha + W*beta directly (paper Lemma 3.5).
    cb.t_compute = F / P * m.gamma
    cb.t_latency = L * m.alpha
    cb.t_bandwidth = W * m.beta
    return cb


def obs_costs(shape: ProblemShape, P: int, c_x: int, c_omega: int,
              m: Machine) -> CostBreakdown:
    """Lemma 3.4/3.5 (Obs)."""
    p, n, d, s, t = shape.p, shape.n, shape.d, shape.s, shape.t
    Q = _q(P, c_x, c_omega)
    lg = math.log2(max(Q, 2))
    F = 2 * n * p**2 * s + 2 * d * n * p * (s * t + 1)
    L = s * (t + 1) * P / (c_omega * c_x) + lg
    W = s * (t + 1) * n * p / c_omega + p**2 * (c_x * c_omega / P) * Q * lg
    M = 2 * c_x * n * p + c_omega * (d * p + n * p + 2 * p**2)
    cb = CostBreakdown("obs", c_x, c_omega, F, L, W, M)
    cb.t_compute = F / P * m.gamma
    cb.t_latency = L * m.alpha
    cb.t_bandwidth = W * m.beta
    return cb


def cov_is_cheaper(shape: ProblemShape) -> bool:
    """Lemma 3.1 crossover: Cov wins iff d/p < (n/(p-n)) * (1/t)."""
    p, n, d, t = shape.p, shape.n, shape.d, shape.t
    if n >= p:
        return True
    return (d / p) < (n / (p - n)) / t


def _divisors(P: int) -> list[int]:
    return [c for c in range(1, P + 1) if P % c == 0]


def enumerate_configs(shape: ProblemShape, P: int, m: Machine,
                      variants: Iterable[str] = ("cov", "obs")
                      ) -> list[CostBreakdown]:
    """All feasible (variant, c_x, c_omega) under replication & memory caps."""
    out = []
    mem_cap_words = m.hbm_bytes / m.word_bytes * P   # aggregate capacity
    for c_x in _divisors(P):
        for c_omega in _divisors(P):
            if c_x * c_omega > P:
                continue
            for v in variants:
                fn = cov_costs if v == "cov" else obs_costs
                cb = fn(shape, P, c_x, c_omega, m)
                if cb.mem_words <= mem_cap_words:
                    out.append(cb)
    return out


def tune(shape: ProblemShape, P: int, m: Machine | None = None,
         variants: Iterable[str] = ("cov", "obs")) -> CostBreakdown:
    """Pick the best (variant, c_x, c_omega) for the problem — the paper's
    cost-model-driven configuration choice."""
    m = m or Machine()
    configs = enumerate_configs(shape, P, m, variants)
    if not configs:
        raise ValueError(
            f"no feasible replication config for p={shape.p} on P={P} "
            f"(need more chips: min aggregate memory ~{3*shape.p**2} words)")
    return min(configs, key=lambda cb: cb.total)
