"""Analytic cost model for HP-CONCORD (paper Lemmas 3.1-3.5) + auto-tuner.

T = F*gamma + L*alpha + W*beta  with machine constants gamma (s/flop),
alpha (s/message) and beta (s/word).

TPU v5e constants (the repo's target part):
  * 197 TFLOP/s bf16 per chip  -> gamma = 1/197e12 (bf16), fp32 ~ x2
  * 819 GB/s HBM bandwidth
  * ~50 GB/s per ICI link; a ppermute "message" occupies one link for
    (words*bytes)/50e9 s; per-round launch overhead ~1us.

On TPU the paper's per-message latency alpha is the per-round collective
launch overhead; the ring shift of Algorithm 4 maps to lax.ppermute over
neighbor links, so bandwidth is per-link (not bisection).

The tuner enumerates feasible (c_X, c_Omega) pairs (divisors of P with
c_X*c_Omega <= P) under the memory caps M_Cov/M_Obs (paper Sec. 3) and
returns the variant+replication with the lowest modeled time — this is the
paper's main "communication-avoiding" decision procedure, exposed as a
first-class feature (used by the estimator and by benchmarks/fig3).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from fractions import Fraction
from typing import Iterable


@dataclass(frozen=True)
class Machine:
    """Machine-dependent constants (per chip)."""
    name: str = "tpu_v5e"
    peak_flops: float = 197e12        # bf16 FLOP/s
    hbm_bw: float = 819e9             # bytes/s
    link_bw: float = 50e9             # bytes/s per ICI link
    msg_overhead: float = 1e-6        # s per collective round (alpha)
    hbm_bytes: float = 16e9           # HBM capacity per chip
    word_bytes: int = 4               # fp32 words for Omega/S/X

    @property
    def gamma(self) -> float:
        return 1.0 / self.peak_flops

    @property
    def beta(self) -> float:
        return self.word_bytes / self.link_bw

    @property
    def alpha(self) -> float:
        return self.msg_overhead


EDISON = Machine(
    name="edison_xc30",
    peak_flops=460.8e9,     # 2x12 cores x 2.4GHz x 8 flops (per node)
    hbm_bw=100e9,
    link_bw=8e9,            # Aries per-direction
    msg_overhead=2e-6,
    hbm_bytes=64e9,
    word_bytes=8,           # paper ran double precision
)


@dataclass(frozen=True)
class ProblemShape:
    p: int                  # dimensions
    n: int                  # samples
    d: float                # avg nnz per row of Omega across iterations
    s: int = 30             # proximal-gradient iterations
    t: float = 10.0         # avg line-search trials per outer iteration


@dataclass
class CostBreakdown:
    variant: str
    c_x: int
    c_omega: int
    flops: float
    messages: float
    words: float
    mem_words: float
    t_compute: float = 0.0
    t_latency: float = 0.0
    t_bandwidth: float = 0.0

    @property
    def total(self) -> float:
        return self.t_compute + self.t_latency + self.t_bandwidth


def _q(P: int, c_x: int, c_omega: int) -> float:
    return max(P / c_x**2, P / c_omega**2)


def cov_costs(shape: ProblemShape, P: int, c_x: int, c_omega: int,
              m: Machine) -> CostBreakdown:
    """Lemma 3.4/3.5 (Cov): F, L, W and T for given replication factors."""
    p, n, d, s, t = shape.p, shape.n, shape.d, shape.s, shape.t
    Q = _q(P, c_x, c_omega)
    lg = math.log2(max(Q, 2))
    F = 2 * n * p**2 + 2 * d * p**2 * (s * t + 1)
    L = P / c_x**2 + s * t * P / (c_x * c_omega) + lg
    W = n * p / c_x + s * t * d * p / c_x + p**2 * (c_x * c_omega / P) * Q * lg
    M = c_omega * d * p + 3 * c_x * p**2          # per paper Sec 3 (total words)
    cb = CostBreakdown("cov", c_x, c_omega, F, L, W, M)
    # Lemma 3.4 counts messages/words along the critical path (per processor),
    # so T = (F/P)*gamma + L*alpha + W*beta directly (paper Lemma 3.5).
    cb.t_compute = F / P * m.gamma
    cb.t_latency = L * m.alpha
    cb.t_bandwidth = W * m.beta
    return cb


def obs_costs(shape: ProblemShape, P: int, c_x: int, c_omega: int,
              m: Machine) -> CostBreakdown:
    """Lemma 3.4/3.5 (Obs)."""
    p, n, d, s, t = shape.p, shape.n, shape.d, shape.s, shape.t
    Q = _q(P, c_x, c_omega)
    lg = math.log2(max(Q, 2))
    F = 2 * n * p**2 * s + 2 * d * n * p * (s * t + 1)
    L = s * (t + 1) * P / (c_omega * c_x) + lg
    W = s * (t + 1) * n * p / c_omega + p**2 * (c_x * c_omega / P) * Q * lg
    M = 2 * c_x * n * p + c_omega * (d * p + n * p + 2 * p**2)
    cb = CostBreakdown("obs", c_x, c_omega, F, L, W, M)
    cb.t_compute = F / P * m.gamma
    cb.t_latency = L * m.alpha
    cb.t_bandwidth = W * m.beta
    return cb


def cov_is_cheaper(shape: ProblemShape) -> bool:
    """Lemma 3.1 crossover: Cov wins iff d/p < (n/(p-n)) * (1/t)."""
    p, n, d, t = shape.p, shape.n, shape.d, shape.t
    if n >= p:
        return True
    return (d / p) < (n / (p - n)) / t


def _divisors(P: int) -> list[int]:
    return [c for c in range(1, P + 1) if P % c == 0]


def enumerate_configs(shape: ProblemShape, P: int, m: Machine,
                      variants: Iterable[str] = ("cov", "obs")
                      ) -> list[CostBreakdown]:
    """All feasible (variant, c_x, c_omega) under replication & memory caps."""
    out = []
    mem_cap_words = m.hbm_bytes / m.word_bytes * P   # aggregate capacity
    for c_x in _divisors(P):
        for c_omega in _divisors(P):
            if c_x * c_omega > P:
                continue
            for v in variants:
                fn = cov_costs if v == "cov" else obs_costs
                cb = fn(shape, P, c_x, c_omega, m)
                if cb.mem_words <= mem_cap_words:
                    out.append(cb)
    return out


def tune(shape: ProblemShape, P: int, m: Machine | None = None,
         variants: Iterable[str] = ("cov", "obs")) -> CostBreakdown:
    """Pick the best (variant, c_x, c_omega) for the problem — the paper's
    cost-model-driven configuration choice."""
    m = m or Machine()
    configs = enumerate_configs(shape, P, m, variants)
    if not configs:
        raise ValueError(
            f"no feasible replication config for p={shape.p} on P={P} "
            f"(need more chips: min aggregate memory ~{3*shape.p**2} words)")
    return min(configs, key=lambda cb: cb.total)


# ---------------------------------------------------------------------------
# dense vs block-sparse matmul crossover (the matops layer's cost model)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class BlockSparseModel:
    """Constants of the dense↔block-gather crossover for the Ω-side product
    C = A(p,p) @ B(p,m) with A at block density δ.

      T_dense(p, m)      = 2 p^2 m γ / dense_eff
      T_sparse(p, m, δ)  = 2 δ p^2 m γ / sparse_eff              (flops)
                         + δ nb (2 bs m + bs^2) w / B / gather_eff (gathers)

    with nb = ceil(p/bs)^2 total blocks, γ/w/B the machine's seconds-per-
    flop / word bytes / HBM bandwidth.  The *_eff fractions are achieved
    efficiency relative to machine peak, so the constants transfer across
    machines of similar balance; ``calibrate_block_model`` refits them from
    a ``benchmarks/sparse_crossover.py`` sweep on the actual hardware.
    Defaults are deliberately conservative (the modeled crossover sits
    below the measured one), so ``sparse_matmul="auto"`` never routes a
    product through the block path above the real break-even density.
    """
    dense_eff: float = 0.85       # dense matmul fraction-of-peak
    sparse_eff: float = 0.45      # block-gather matmul fraction-of-peak
    gather_eff: float = 0.50      # block gather/scatter fraction of HBM bw


def _nb_total(p: int, block_size: int) -> int:
    return (-(-p // block_size)) ** 2


def dense_matmul_time(p: int, m: int, machine: Machine | None = None,
                      model: BlockSparseModel | None = None) -> float:
    machine = machine or Machine()
    model = model or BlockSparseModel()
    return 2.0 * p * p * m * machine.gamma / model.dense_eff


def blocksparse_matmul_time(p: int, m: int, density: float, block_size: int,
                            machine: Machine | None = None,
                            model: BlockSparseModel | None = None) -> float:
    machine = machine or Machine()
    model = model or BlockSparseModel()
    bs = block_size
    flops = 2.0 * density * p * p * m * machine.gamma / model.sparse_eff
    gathered_bytes = (density * _nb_total(p, bs) * (2.0 * bs * m + bs * bs)
                      * machine.word_bytes)
    return flops + gathered_bytes / machine.hbm_bw / model.gather_eff


def crossover_density(p: int, m: int, block_size: int,
                      machine: Machine | None = None,
                      model: BlockSparseModel | None = None) -> float:
    """Block density δ* at which T_sparse(δ*) = T_dense — the routing
    threshold of ``sparse_matmul="auto"``.  Both sides are linear in δ, so
    δ* = T_dense / T_sparse(δ=1), clamped to [0, 1]."""
    td = dense_matmul_time(p, m, machine, model)
    ts1 = blocksparse_matmul_time(p, m, 1.0, block_size, machine, model)
    if ts1 <= 0.0:
        return 1.0
    return max(0.0, min(1.0, td / ts1))


def gram_chunk_rows(p: int, *, machine: Machine | None = None,
                    budget_bytes: float | None = None,
                    dtype_bytes: int = 8) -> int:
    """Chunk-size guidance for the streaming Gram pipeline (``data.gram``).

    Two constraints pick the row-block size m of a streamed XᵀX:

      * memory — the resident working set is the f64 chunk (m·p·8 B), one
        transform copy of it, and the (p, p) f64 accumulator; chunk +
        copy must fit what the budget leaves AFTER the accumulator
        (default budget: 1/8 of the machine's HBM, leaving room for the
        solve that follows);
      * efficiency — the panel product (panel, m) @ (m, p) has arithmetic
        intensity ~m flops/byte on the streamed operand, so m below a few
        hundred rows turns the accumulation bandwidth-bound.  We floor at
        256 rows and never ask for more than 2^20 (diminishing returns,
        and shard files are typically smaller anyway).

    Raises when the (p, p) accumulator alone exhausts the budget — at
    that point no chunk size makes the pipeline fit and the caller needs
    the distributed twin (one accumulator shard per host) or a bigger
    budget, not a smaller chunk.

    Used as the default by ``launch/gram.py prep`` and documented in the
    README's chunk-size guidance.
    """
    if p < 1:
        raise ValueError(f"p must be >= 1, got {p}")
    machine = machine or Machine()
    budget = budget_bytes if budget_bytes is not None \
        else machine.hbm_bytes / 8.0
    left = budget - float(p) * p * dtype_bytes
    if left <= 0:
        raise ValueError(
            f"the (p, p) f64 accumulator alone ({p}^2 x {dtype_bytes} B = "
            f"{p * p * dtype_bytes / 1e9:.1f} GB) exceeds the "
            f"{budget / 1e9:.1f} GB budget; shard the Gram across hosts "
            f"(data.distributed_gram) or raise budget_bytes")
    rows = int(left // (2 * p * dtype_bytes))
    return max(256, min(rows, 1 << 20))


# ---------------------------------------------------------------------------
# batched-vs-sequential path scheduling (the core.batch compact engine's
# difficulty model and fit_path(mode="auto")'s decision procedure)
# ---------------------------------------------------------------------------

#: measured average line-search trials per outer iteration for each
#: tau schedule (BENCH_path_batch shapes, identity cold start): "restart"
#: re-rejects from tau_init every iteration, "greedy" grows the accepted
#: tau by 1.3x and almost always accepts first try
TAU_TRIALS_PER_ITER = {"restart": 2.3, "warm": 1.7, "greedy": 1.35}


@dataclass(frozen=True)
class PathIterModel:
    """Power-law iteration predictor for a cold-started proximal-gradient
    solve at penalty strength lam1:

        iters(lam1) ~= base_iters * lam1 ** -exponent

    Smaller lam1 means a denser estimate and a flatter objective, so
    iteration counts grow as the penalty shrinks.  The constants are fit
    to the BENCH_path_batch chain-scenario paths (p = 128..512,
    tol = 1e-6); only the ORDERING and the rough totals matter — the
    compact engine uses this to schedule lanes hardest-first and
    ``choose_path_mode`` to pick an execution mode, neither of which
    needs per-problem accuracy."""
    base_iters: float = 11.0     # iters at lam1 = 1
    exponent: float = 1.0


def predict_path_iters(lam1, *, model: PathIterModel | None = None,
                       max_iters: int = 500):
    """Predicted outer-iteration counts for a lam1 grid (elementwise,
    clipped to [1, max_iters]).  Monotone decreasing in lam1, so sorting
    by the prediction is sorting hardest-first."""
    import numpy as np

    model = model or PathIterModel()
    lam1 = np.asarray(lam1, np.float64)
    pred = model.base_iters * np.power(np.maximum(lam1, 1e-12),
                                       -model.exponent)
    return np.clip(pred, 1.0, float(max(max_iters, 1)))


#: measured per-lane-step wall-clock of the compact engine's gemm routes
#: relative to the sequential XLA baseline on a one-core CPU host
#: (BENCH_path_batch, p = 512 f64: host BLAS stepper ~10 ms/lane-step vs
#: ~14.5 ms through XLA)
GEMM_STEP_COST = {"xla": 1.0, "host": 0.70}

#: measured flat-step reduction of warm_start="pilot" on the non-pilot
#: lanes (cold 202 -> pilot-warmed 141 total ls trials on the
#: BENCH_path_batch 8-point grid; the pilot lane itself runs cold)
PILOT_WARM_FACTOR = 0.70


def _ladder_tier(n: int) -> int:
    cap = 1
    while cap < n:
        cap = 3 * cap // 2 if cap % 2 == 0 and 3 * cap // 2 >= n \
            else cap * 2
    return cap


def _padded_compact_cost(steps, chunk: int) -> int:
    """Padded lane-steps of the compact schedule: each segment of
    ``chunk`` steps pays the capacity tier of its live-lane count, and
    lanes only leave at segment boundaries."""
    import numpy as np

    remaining = np.sort(np.asarray(steps, np.int64))[::-1].copy()
    padded = 0
    while remaining.size:
        tier = _ladder_tier(int(remaining.size))
        dt = min(int(chunk), int(remaining.max()))
        padded += tier * dt
        remaining = remaining - dt
        remaining = remaining[remaining > 0]
    return padded


def predict_batched_speedup(lam1_grid, *, tau_schedule: str = "restart",
                            chunk: int = 32, max_iters: int = 500,
                            gemm: str = "xla",
                            warm_start: str | None = None,
                            model: PathIterModel | None = None) -> float:
    """Predicted wall-clock ratio sequential/compact-batched for a lam1
    path on throughput-limited hardware (one device, cost proportional to
    lane-steps executed).

    Simulates the compact engine's segmented schedule on the predicted
    per-lane flat-step counts (see :func:`_padded_compact_cost`), then
    applies the engine's per-step cost factor (``gemm``,
    :data:`GEMM_STEP_COST`) and the pilot warm-start step reduction
    (``warm_start="pilot"``, :data:`PILOT_WARM_FACTOR`).  The sequential
    baseline is the shipped default: cold XLA solves, plain sum of
    per-lane steps.  >1 means batching is predicted to win; the
    estimator's ``fit_path(mode="auto")`` thresholds this."""
    import numpy as np

    trials = TAU_TRIALS_PER_ITER.get(tau_schedule,
                                     TAU_TRIALS_PER_ITER["restart"])
    iters = predict_path_iters(lam1_grid, model=model, max_iters=max_iters)
    steps = np.maximum(np.rint(iters * trials), 1.0).astype(np.int64)
    seq = int(steps.sum())
    step_cost = GEMM_STEP_COST.get(gemm, 1.0)
    if warm_start == "pilot" and steps.size > 1:
        # the median-difficulty pilot runs cold and alone; every other
        # lane starts from its solution and converges in fewer steps
        order = np.argsort(steps)
        pilot = order[len(order) // 2]
        rest = np.delete(steps, pilot)
        rest = np.maximum(np.rint(rest * PILOT_WARM_FACTOR), 1.0)
        padded = int(steps[pilot]) + _padded_compact_cost(rest, chunk)
    else:
        padded = _padded_compact_cost(steps, chunk)
    return seq / (padded * step_cost) if padded else 1.0


def choose_path_mode(lam1_grid, *, tau_schedule: str = "restart",
                     chunk: int = 32, max_iters: int = 500,
                     gemm: str = "xla", warm_start: str | None = None,
                     threshold: float = 1.05) -> str:
    """The ``fit_path(mode="auto")`` decision: "batched" when the
    compact engine's predicted speedup clears ``threshold`` (a short or
    uniformly-hard grid has too little compaction headroom to pay the
    batched program's padding), else "sequential"."""
    import numpy as np

    grid = np.asarray(lam1_grid, np.float64)
    if grid.size <= 1:
        return "sequential"
    speedup = predict_batched_speedup(
        grid, tau_schedule=tau_schedule, chunk=chunk, max_iters=max_iters,
        gemm=gemm, warm_start=warm_start)
    return "batched" if speedup >= threshold else "sequential"


def calibrate_block_model(rows, machine: Machine | None = None
                          ) -> BlockSparseModel:
    """Refit :class:`BlockSparseModel` from measured sweep rows (dicts with
    ``p``, ``m``, ``block_size``, ``density``, ``t_dense``, ``t_sparse``) —
    the output of ``benchmarks/sparse_crossover.py``."""
    import numpy as np

    machine = machine or Machine()
    rows = [r for r in rows if r.get("t_dense", 0) > 0 and
            r.get("t_sparse", 0) > 0]
    if not rows:
        raise ValueError("no usable rows to calibrate from")
    dense_effs = [2.0 * r["p"] ** 2 * r["m"] * machine.gamma / r["t_dense"]
                  for r in rows]
    dense_eff = float(np.median(dense_effs))
    # least squares for the two sparse-path coefficients
    a = np.array([[2.0 * r["density"] * r["p"] ** 2 * r["m"] * machine.gamma,
                   r["density"] * _nb_total(r["p"], r["block_size"])
                   * (2.0 * r["block_size"] * r["m"] + r["block_size"] ** 2)
                   * machine.word_bytes / machine.hbm_bw]
                  for r in rows])
    y = np.array([r["t_sparse"] for r in rows])
    coef, *_ = np.linalg.lstsq(a, y, rcond=None)
    inv_sparse_eff = max(float(coef[0]), 1e-12)
    inv_gather_eff = max(float(coef[1]), 1e-12)
    return BlockSparseModel(dense_eff=max(dense_eff, 1e-12),
                            sparse_eff=1.0 / inv_sparse_eff,
                            gather_eff=1.0 / inv_gather_eff)


# ---------------------------------------------------------------------------
# exact communication-volume accounting (CA303 analytic side)
# ---------------------------------------------------------------------------
# Where Lemmas 3.4/3.5 above model asymptotic words moved as float cost
# terms, this layer is EXACT: per-processor bytes-on-wire along the
# critical path of one invocation, as `fractions.Fraction`s, so the comm
# engine (`repro.analysis.commpass`, rule CA303) can cross-check the
# schedule it statically extracts from a jaxpr against these formulas
# with == instead of a tolerance.
#
# Conventions (one per collective primitive, extent E = product of the
# bound mesh-axis sizes):
#   ppermute      payload bytes once — ZERO if the permutation table is
#                 the identity (no pair moves: jax still emits the eqn,
#                 the wire does not see it)
#   psum/pmin/..  bandwidth-optimal all-reduce: 2 (E-1)/E * payload
#   all_gather    ring gather: (E-1) * payload(input shard)
#   all_to_all / reduce_scatter / psum_scatter: (E-1)/E * payload
# E <= 1 is always zero bytes.

#: wire width of the dtypes the schedules ship (kept jnp-free on purpose:
#: the analytic side must not depend on a backend being importable)
DTYPE_BYTES = {
    "float64": 8, "float32": 4, "bfloat16": 2, "float16": 2,
    "int64": 8, "int32": 4, "int16": 2, "int8": 1, "uint8": 1,
    "bool": 1,
}

_REDUCE_PRIMS = frozenset({"psum", "pmin", "pmax", "psum_invariant"})
_GATHER_PRIMS = frozenset({"all_gather", "all_gather_invariant"})
_SCATTER_PRIMS = frozenset({"all_to_all", "reduce_scatter", "psum_scatter"})


def collective_wire_bytes(prim: str, payload_bytes, extent,
                          *, moves: bool = True) -> Fraction:
    """Per-processor critical-path bytes of ONE collective invocation."""
    b = Fraction(payload_bytes)
    if extent is None or extent <= 1:
        return Fraction(0)
    if prim in ("ppermute", "pbroadcast"):
        return b if moves else Fraction(0)
    if prim in _REDUCE_PRIMS:
        return Fraction(2 * (extent - 1), extent) * b
    if prim in _GATHER_PRIMS:
        return (extent - 1) * b
    if prim in _SCATTER_PRIMS:
        return Fraction(extent - 1, extent) * b
    raise ValueError(f"no wire-byte convention for primitive {prim!r}")


@dataclass(frozen=True)
class CommVolume:
    """Exact per-processor bytes of one 1.5D product invocation."""
    flavor: str
    rounds: int              # ring-scan length (Alg. 4 rotation rounds)
    ring_bytes: Fraction     # stagger + per-round shift ppermutes
    finish_bytes: Fraction   # team all_gather (gather) / psum (reduce)

    @property
    def total(self) -> Fraction:
        return self.ring_bytes + self.finish_bytes


def _perm_moves(perm) -> int:
    """1 if the ppermute actually puts bytes on a wire, else 0."""
    return int(any(s != d for s, d in perm))


def comm_volume(p: int, n: int, n_devices: int, c_x: int, c_omega: int, *,
                flavor: str, dtype: str = "float64",
                canonical: str | None = None, masked: bool = False,
                block_size: int | None = None) -> CommVolume:
    """Exact bytes-on-wire of one 1.5D matmul (paper Algorithm 4).

    ``flavor`` is one of the four ring products of ``comm.matmul1p5d``:

      * ``"xtx"``      gather flavor, S = X^T X        (Cov line 2)
      * ``"omega_s"``  gather flavor, W = Omega S      (Cov; ``canonical``
                       "omegalike" standalone / "xlike" inside the driver;
                       ``masked`` adds the rotating int8 occupancy mask)
      * ``"y_x"``      gather flavor, Z = Y X          (Obs line 4)
      * ``"omega_xt"`` reduce flavor, Y = Omega X^T    (Obs lines 2/10;
                       ``masked`` adds NOTHING — the mask is fixed and
                       sliced locally, it never rides the ring)

    Stagger/shift movement is decided by constructing the very same
    permutation tables the schedule uses (``comm.grid.Grid1p5D``) and
    asking whether any pair moves — the closed-form identity conditions
    are full of corner cases (e.g. the xtx stagger IS the identity at
    c_x = P even though c_x > 1) and getting one wrong here would make
    the CA303 gate cry wolf.
    """
    from ..comm.grid import Grid1p5D  # lazy: core must stay importable alone

    g = Grid1p5D(n_devices, c_x, c_omega)
    w = DTYPE_BYTES[dtype]
    n_x, n_om = g.n_x, g.n_om
    blk_x, blk_om = p // n_x, p // n_om
    if masked and flavor in ("xtx", "y_x"):
        raise ValueError(f"flavor {flavor!r} has no masked variant")
    if masked and not block_size:
        raise ValueError("masked volume needs the mask block_size")

    if flavor == "omega_xt":
        rounds = n_x // c_omega
        ring_moves = (_perm_moves(g.stagger_perm("xlike", "omega", n_x))
                      + rounds * _perm_moves(g.shift_perm("omega", c_omega)))
        ring = Fraction(ring_moves * blk_x * n * w)
        finish = collective_wire_bytes("psum", blk_om * n * w, c_omega)
        return CommVolume(flavor, rounds, ring, finish)

    # gather flavors: (ring ordering, fixed-operand replication c_F,
    # rotating block count n_R, canonical layout, rotating payload,
    # gathered tile (rows, cols), team-layer extent)
    if flavor == "xtx":
        ring_name, c_f, n_r, canon = "x", c_x, n_x, "xlike"
        payload, tile, team = blk_x * n, (blk_x, blk_x), c_x
    elif flavor == "omega_s":
        canon = canonical or "omegalike"
        n_r = n_om if canon == "omegalike" else n_x
        blk_r = p // n_r
        ring_name, c_f = "x", c_x
        payload, tile, team = blk_r * p, (blk_r, blk_x), c_x
    elif flavor == "y_x":
        ring_name, c_f, n_r, canon = "omega", c_omega, n_x, "xlike"
        payload, tile, team = n * blk_x, (blk_om, blk_x), c_omega
    else:
        raise ValueError(f"unknown flavor {flavor!r}")

    rounds = max(1, n_r // c_f)
    moves = (_perm_moves(g.stagger_perm(canon, ring_name, n_r))
             + rounds * _perm_moves(g.shift_perm(ring_name, c_f)))
    ring = Fraction(moves * payload * w)
    if masked:   # the occupancy mask rides the same stagger + shifts
        rows, cols = (p // n_r) // block_size, p // block_size
        ring += Fraction(moves * rows * cols * DTYPE_BYTES["int8"])
    finish = collective_wire_bytes(
        "all_gather", rounds * tile[0] * tile[1] * w, team)
    return CommVolume(flavor, rounds, ring, finish)


def ring_allreduce_int8_volume(size: int, extent: int) -> Fraction:
    """Exact bytes of ``comm.collectives.ring_allreduce_int8`` on a float64
    input of ``size`` elements over a ring of ``extent`` devices.

    (extent-1) reduce-scatter rounds each ship one int8 chunk plus its
    f64 scale scalar (the quantizer derives the scale from the f64 input
    under the x64 contract); the finishing all_gather ships the REDUCED
    chunk at full f64 — int8 compression buys its 8x only on the
    reduce-scatter phase, which is the phase that repeats.
    """
    if extent <= 1:
        return Fraction(0)
    pad = (-size) % extent
    chunk = (size + pad) // extent
    rs = (extent - 1) * (chunk * DTYPE_BYTES["int8"] + DTYPE_BYTES["float64"])
    ag = collective_wire_bytes(
        "all_gather", chunk * DTYPE_BYTES["float64"], extent)
    return Fraction(rs) + ag


def compressed_psum_volume(size: int, extent: int, *,
                           method: str = "bf16") -> Fraction:
    """Exact bytes of ``comm.collectives.compressed_psum``: one
    bandwidth-optimal all-reduce of ``size`` elements at the method's
    wire width (bf16 = 2 bytes; the int8 method psums the DEQUANTIZED
    float32 values — its 1-byte wire only exists in the explicit ring)."""
    wire = {"bf16": DTYPE_BYTES["bfloat16"], "int8": DTYPE_BYTES["float32"],
            "none": DTYPE_BYTES["float64"]}[method]
    return collective_wire_bytes("psum", size * wire, extent)
