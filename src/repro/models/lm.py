"""Training / serving entry points for the LM zoo.

``make_train_step``  — loss + grad + AdamW update, microbatched, with
                       chunked-vocab cross entropy (beyond-paper memory
                       optimization: never materializes the full
                       (B, L, V) logits when cfg.loss_chunk > 0).
``make_prefill``     — populate the serve cache from a prompt.
``make_decode_step`` — one token with the ring-buffered KV / SSM cache.
``shardings``        — NamedSharding pytrees for params / opt / cache /
                       batch derived from the logical-axes trees.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..train.optim import AdamW, accumulate_gradients
from .config import ModelConfig, logical_to_spec, tree_shardings
from . import transformer as T


class Batch(NamedTuple):
    tokens: jax.Array               # (B, L) int32
    targets: jax.Array              # (B, L) int32 (next-token labels)
    frames: jax.Array | None = None  # (B, enc_len, d) enc-dec stub input


def cross_entropy(cfg: ModelConfig, params, hidden, targets):
    """Mean next-token xent; chunked over the sequence dim when
    cfg.loss_chunk > 0 so the (B, L, V) logits are never all live."""
    B, L, d = hidden.shape

    def xent(h, t):
        logits = T.lm_head(cfg, params, h)
        lse = jax.nn.logsumexp(logits, axis=-1)
        picked = jnp.take_along_axis(logits, t[..., None], axis=-1)[..., 0]
        return jnp.sum(lse - picked)

    if cfg.loss_chunk and L % cfg.loss_chunk == 0 and L > cfg.loss_chunk:
        nc = L // cfg.loss_chunk
        hs = hidden.reshape(B, nc, cfg.loss_chunk, d).transpose(1, 0, 2, 3)
        ts = targets.reshape(B, nc, cfg.loss_chunk).transpose(1, 0, 2)

        # checkpoint: recompute each chunk's (b, chunk, V) logits in the
        # backward pass instead of keeping nc of them live
        @jax.checkpoint
        def body(acc, xs):
            h, t = xs
            return acc + xent(h, t), None
        total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (hs, ts))
    else:
        total = xent(hidden, targets)
    return total / (B * L)


def cast_params(cfg: ModelConfig, params):
    """fp32 master weights -> compute dtype ONCE per step, before the
    layer loop: FSDP all-gathers then move bf16 (half the wire bytes and
    half the gather working set vs gathering fp32)."""
    dt = jnp.dtype(cfg.dtype)
    return jax.tree.map(
        lambda p: p.astype(dt) if p.dtype == jnp.float32 else p, params)


def loss_fn(cfg: ModelConfig, params, batch: Batch):
    B, L = batch.tokens.shape
    positions = jnp.arange(L)
    pc = cast_params(cfg, params)
    hidden, _, aux = T.forward(cfg, pc, batch.tokens, positions,
                               enc_frames=batch.frames)
    loss = cross_entropy(cfg, pc, hidden, batch.targets)
    total = loss + 0.01 * aux
    return total, {"loss": loss, "aux_loss": aux}


class TrainState(NamedTuple):
    params: dict
    opt: object
    step: jax.Array


def make_train_step(cfg: ModelConfig, optimizer: AdamW, lr_schedule,
                    n_micro: int | None = None):
    """Returns train_step(state, batch) -> (state, metrics)."""
    n_micro = n_micro if n_micro is not None else cfg.n_micro

    def train_step(state: TrainState, batch: Batch):
        (total, aux), grads = accumulate_gradients(
            partial(loss_fn, cfg), state.params, batch, n_micro)
        lr = lr_schedule(state.step)
        new_params, new_opt, gnorm = optimizer.update(
            grads, state.opt, state.params, lr=lr)
        metrics = {"loss": aux["loss"], "aux_loss": aux["aux_loss"],
                   "grad_norm": gnorm, "lr": lr}
        return TrainState(new_params, new_opt, state.step + 1), metrics

    return train_step


def make_prefill(cfg: ModelConfig, max_len: int):
    """prefill(params, cache, tokens[, frames]) -> (cache, last_logits).

    With cfg.prefill_chunk > 0 the prompt is consumed in segments with
    the cache threaded through (chunked prefill): peak activation memory
    drops from O(L) to O(chunk) — required to fit the 1M-token
    prefill_32k cells of the biggest archs.
    """

    def prefill(params, cache, tokens, frames=None):
        B, L = tokens.shape
        ck = cfg.prefill_chunk
        if ck and L > ck and L % ck == 0 and not cfg.enc_dec:
            nc = L // ck
            toks = tokens.reshape(B, nc, ck).transpose(1, 0, 2)

            def body(carry, xs):
                cache, i = carry
                seg = xs
                positions = i * ck + jnp.arange(ck)
                hidden, cache, _ = T.forward(cfg, params, seg, positions,
                                             caches=cache, fresh_kv=False)
                return (cache, i + 1), hidden[:, -1:]

            (new_cache, _), last_h = jax.lax.scan(
                body, (cache, jnp.zeros((), jnp.int32)), toks)
            logits = T.lm_head(cfg, params, last_h[-1])
            return new_cache, logits[:, 0]

        positions = jnp.arange(L)
        hidden, new_cache, _ = T.forward(cfg, params, tokens, positions,
                                         caches=cache, enc_frames=frames)
        logits = T.lm_head(cfg, params, hidden[:, -1:])
        return new_cache, logits[:, 0]

    return prefill


def make_decode_step(cfg: ModelConfig):
    """decode(params, cache, token (B,), step_scalar) -> (cache, next (B,))."""

    def decode(params, cache, token, step):
        positions = step[None]  # (1,)
        hidden, new_cache, _ = T.forward(cfg, params, token[:, None],
                                         positions, caches=cache)
        logits = T.lm_head(cfg, params, hidden)
        nxt = jnp.argmax(logits[:, 0], axis=-1).astype(token.dtype)
        return new_cache, nxt

    return decode


# ---------------------------------------------------------------------------
# shardings
# ---------------------------------------------------------------------------

def param_shardings(cfg: ModelConfig, mesh: Mesh, max_len: int = 0):
    logical = T.logical_axes(cfg, max_len)
    shapes = jax.eval_shape(
        lambda: T.init_params(cfg, jax.random.PRNGKey(0), max_len))
    return tree_shardings(logical, shapes, mesh, cfg.rules())


def opt_shardings(cfg: ModelConfig, mesh: Mesh, optimizer, max_len: int = 0):
    ps = param_shardings(cfg, mesh, max_len)
    shapes = jax.eval_shape(
        lambda: T.init_params(cfg, jax.random.PRNGKey(0), max_len))
    o_shapes = jax.eval_shape(optimizer.init, shapes)
    scalar = NamedSharding(mesh, P())
    return type(o_shapes)(
        step=scalar,
        m=ps,
        v=ps if o_shapes.v else {},
    )


def cache_shardings(cfg: ModelConfig, mesh: Mesh, batch: int, max_len: int):
    logical = T.cache_logical_axes(cfg)
    shapes = jax.eval_shape(lambda: T.init_cache(cfg, batch, max_len))
    rules = cfg.rules()

    def map_one(lg, sh):
        return NamedSharding(mesh, logical_to_spec(lg, sh.shape, mesh, rules))

    # logical tree leaves are tuples of names; align trees manually
    def walk(lg_tree, sh_tree):
        if isinstance(sh_tree, dict):
            return {k: walk(lg_tree[k], sh_tree[k]) for k in sh_tree}
        return map_one(lg_tree, sh_tree)

    return walk(logical, shapes)


def batch_shardings(cfg: ModelConfig, mesh: Mesh):
    rules = cfg.rules()
    tok = NamedSharding(mesh, logical_to_spec(
        ("batch", "seq"), (1 << 30, 1 << 30), mesh, rules))
    if cfg.enc_dec:
        fr = NamedSharding(mesh, logical_to_spec(
            ("batch", "seq", "embed"), (1 << 30, 1 << 30, 1 << 30),
            mesh, rules))
        return Batch(tokens=tok, targets=tok, frames=fr)
    return Batch(tokens=tok, targets=tok, frames=None)
