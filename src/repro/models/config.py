"""Model configuration + logical->mesh sharding rules for the LM zoo.

One ``ModelConfig`` describes every assigned architecture (dense GQA
transformers, MoE, early-fusion VLM, Mamba2 SSM, Zamba2 hybrid, Whisper
enc-dec).  Sharding is expressed with LOGICAL axis names which a
``ShardingRules`` table maps to physical mesh axes; a dimension that does
not divide its mapped mesh axes falls back to replication automatically,
so one rule set covers e.g. kv_heads=2 and kv_heads=32.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# ---------------------------------------------------------------------------
# logical axes
# ---------------------------------------------------------------------------
# batch   — global batch            -> ("pod", "data") (DP)
# embed   — d_model                 -> "data"  (FSDP shards weights on embed)
# heads   — attention heads / d_ff  -> "model" (TP)
# kv      — kv heads                -> "model"
# vocab   — vocabulary              -> "model"
# expert  — MoE experts             -> "model" (EP) or None (TP-in-expert)
# seq     — sequence                -> None in train; "model" for SP decode
# layers / conv / state / none      -> replicated

DEFAULT_RULES: dict[str, tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "embed": ("data",),
    "heads": ("model",),
    "kv": ("model",),
    "q_heads": ("model",),
    "mlp": ("model",),
    "vocab": ("model",),
    "expert": ("model",),
    "expert_mlp": (),       # d_ff inside an expert; EP archs keep it local
    "capacity": ("pod", "data"),  # MoE dispatch-buffer slot axis
    "seq": (),
    # decode KV-cache sequence axis: sequence-parallel fallback — takes the
    # first axis (pod > data > model) not already used by batch/kv-heads
    "kv_seq": ("pod", "data", "model"),
    "layers": (),
    "none": (),
}

VOCAB_PAD = 256  # embedding tables padded so "vocab" shards over any axis


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | vlm | ssm | hybrid | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: int | None = None  # default d_model // n_heads
    # attention flavor
    qkv_bias: bool = False
    window: int | None = None            # uniform sliding window
    local_global: bool = False           # gemma2 alternating local/global
    local_window: int = 4096
    softcap: float | None = None         # gemma2 logit softcapping
    final_softcap: float | None = None   # gemma2 final-logit softcap
    rope_theta: float = 10_000.0
    # MLP flavor
    mlp: str = "swiglu"                  # swiglu | gelu
    # MoE
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 1.25
    expert_sharding: str = "ep"          # "ep": experts over model;
                                         # "tp": d_ff_expert over model;
                                         # "ep_virtual": each expert split
                                         #   into `virtual_split` f-slices
                                         #   that dispatch as independent
                                         #   experts (exact decomposition,
                                         #   no within-expert all-reduce)
    virtual_split: int = 2
    # SSM (mamba2)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_conv: int = 4
    ssm_ngroups: int = 1
    ssm_chunk: int = 256
    # hybrid (zamba2): one shared attention block every `shared_every` layers
    shared_every: int = 0
    # enc-dec (whisper)
    enc_dec: bool = False
    n_enc_layers: int = 0
    enc_len: int = 1500                  # stub frontend frame count
    # norms / misc
    norm: str = "rmsnorm"                # rmsnorm | layernorm
    norm_eps: float = 1e-6
    post_norm: bool = False              # gemma2 post-attn/ffn norms
    tie_embeddings: bool = True
    # numerics / perf knobs
    dtype: str = "bfloat16"              # activation/compute dtype
    param_dtype: str = "float32"
    remat: bool = True
    remat_group: int = 0                 # >1: two-level remat — checkpoint
                                         # groups of layers AND each layer
                                         # (sqrt-remat: saved carries drop
                                         # from n_layers to n_layers/group)
    attention_impl: str = "chunked"      # chunked (mea) | ref | flash
    attn_chunk: int = 1024               # kv-chunk of the mea attention
    scan_layers: bool = True             # False: unroll (flop measurement)
    n_micro: int = 1                     # microbatch accumulation steps
    prefill_chunk: int = 0               # chunked prefill segment (0 = off)
    # beyond-paper knobs
    ca_lm_head: bool = False             # route lm_head through 1.5D matmul
    loss_chunk: int = 0                  # chunked-vocab loss (0 = off)
    sharding_overrides: dict = field(default_factory=dict)

    @property
    def hd(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def vocab_pad(self) -> int:
        """Embedding-table rows, padded so the vocab axis always shards
        (padded logit lanes are masked to -inf in lm_head)."""
        return -(-self.vocab // VOCAB_PAD) * VOCAB_PAD

    @property
    def attn_free(self) -> bool:
        return self.family == "ssm"

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def n_experts_disp(self) -> int:
        """Expert count seen by dispatch/buffers (virtual splits count)."""
        if self.expert_sharding == "ep_virtual":
            return self.n_experts * self.virtual_split
        return self.n_experts

    @property
    def d_ff_expert_disp(self) -> int:
        if self.expert_sharding == "ep_virtual":
            return self.d_ff_expert // self.virtual_split
        return self.d_ff_expert

    def rules(self) -> dict[str, tuple[str, ...]]:
        r = dict(DEFAULT_RULES)
        r.update(self.sharding_overrides)
        if self.n_experts and self.expert_sharding == "tp":
            r["expert"] = ()
            r["expert_mlp"] = ("model",)
        return r

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # -- parameter count (for 6ND model flops) ---------------------------
    def param_count(self, *, active_only: bool = False) -> int:
        d, L = self.d_model, self.n_layers
        hd, Hq, Hkv = self.hd, self.n_heads, self.n_kv
        n = self.vocab * d                      # embeddings
        if not self.tie_embeddings:
            n += self.vocab * d
        if self.family == "ssm":
            return n + L * self._ssm_block_params()
        per_attn = d * (Hq * hd) + 2 * d * (Hkv * hd) + (Hq * hd) * d
        mlp_mult = 3 if self.mlp == "swiglu" else 2
        per_dense_mlp = mlp_mult * d * self.d_ff if self.d_ff else 0
        per_expert = mlp_mult * d * self.d_ff_expert
        if self.family == "hybrid":
            n += L * self._ssm_block_params()
            n += per_attn + per_dense_mlp       # ONE shared block
            return n
        if self.enc_dec:
            n += self.n_enc_layers * (per_attn + per_dense_mlp)
            n += L * (2 * per_attn + per_dense_mlp)   # self + cross attn
            return n
        if self.n_experts:
            e = self.top_k if active_only else self.n_experts
            n += L * (per_attn + e * per_expert + d * self.n_experts)
            return n
        n += L * (per_attn + per_dense_mlp)
        return n

    def _ssm_block_params(self) -> int:
        d, di, ns = self.d_model, self.d_inner, self.ssm_state
        g, hd_ = self.ssm_ngroups, self.ssm_headdim
        nh = self.ssm_nheads
        in_proj = d * (2 * di + 2 * g * ns + nh)
        conv = self.ssm_conv * (di + 2 * g * ns)
        out_proj = di * d
        return in_proj + conv + out_proj + 2 * nh + di


# ---------------------------------------------------------------------------
# logical specs -> physical NamedSharding
# ---------------------------------------------------------------------------

def _fits(size: int, axes: tuple[str, ...], mesh: Mesh) -> bool:
    n = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
    return n > 0 and size % n == 0


def logical_to_spec(logical: Sequence[str], shape: Sequence[int], mesh: Mesh,
                    rules: dict[str, tuple[str, ...]]) -> P:
    """Map logical axis names to a PartitionSpec, dropping any mapping the
    dimension size cannot honor and never using a mesh axis twice."""
    used: set[str] = set()
    out = []
    for name, size in zip(logical, shape):
        axes = tuple(a for a in rules.get(name, ())
                     if a in mesh.shape and a not in used)
        placed = False
        # longest usable prefix of the mapped axes, then single axes
        for k in range(len(axes), 0, -1):
            cand = axes[:k]
            if _fits(size, cand, mesh):
                out.append(cand if len(cand) > 1 else cand[0])
                used.update(cand)
                placed = True
                break
        if not placed:
            for a in axes:
                if size % mesh.shape[a] == 0:
                    out.append(a)
                    used.add(a)
                    placed = True
                    break
        if not placed:
            out.append(None)
    return P(*out)


def constrain(x, logical: Sequence[str], rules: dict):
    """with_sharding_constraint against the ambient mesh (set_mesh
    context); a NO-OP when no mesh is active (single-device tests) or
    when a dimension cannot honor its mapping (auto fallback)."""
    from ..comm.compat import get_abstract_mesh
    mesh = get_abstract_mesh()
    if mesh.empty or not mesh.shape:
        return x
    spec = logical_to_spec(logical, x.shape, mesh, rules)
    return jax.lax.with_sharding_constraint(x, spec)


def tree_shardings(logical_tree, shape_tree, mesh: Mesh,
                   rules: dict[str, tuple[str, ...]]):
    """Build a NamedSharding pytree from a logical-axes pytree."""
    return jax.tree.map(
        lambda lg, sh: NamedSharding(
            mesh, logical_to_spec(lg, sh.shape, mesh, rules)),
        logical_tree, shape_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, str) for e in x),
    )
