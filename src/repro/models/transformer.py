"""Model assembly for every assigned architecture family.

  * decoder-only (dense / moe / vlm): scan-over-layers with per-layer
    sliding-window values carried as scanned data, so gemma2's alternating
    local/global pattern lives in ONE scanned stack (no unrolling).
  * ssm (mamba2): scan over Mamba2 blocks.
  * hybrid (zamba2): outer scan over groups, each group = one invocation
    of the SHARED attention block (single parameter set, per-group KV
    cache) followed by `shared_every` Mamba2 layers.
  * audio enc-dec (whisper): bidirectional encoder over stub frame
    embeddings + causal decoder with cross attention.

Parameters are nested dicts; `logical_axes` returns the matching tree of
logical sharding names (see config.tree_shardings).  All layer loops are
lax.scan with optional jax.checkpoint (remat) around the body.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax import lax

from . import layers as L
from . import ssm as S
from .config import ModelConfig, constrain


# ---------------------------------------------------------------------------
# schemas
# ---------------------------------------------------------------------------

def decoder_block_schema(cfg: ModelConfig):
    s = {}
    s.update(L.norm_schema(cfg, "ln1"))
    s.update(L.norm_schema(cfg, "ln2"))
    if cfg.post_norm:
        s.update(L.norm_schema(cfg, "pn1"))
        s.update(L.norm_schema(cfg, "pn2"))
    s.update(L.attn_schema(cfg))
    if cfg.n_experts:
        s.update(L.moe_schema(cfg))
    else:
        s.update(L.mlp_schema(cfg))
    return s


def ssm_block_schema(cfg: ModelConfig):
    s = {}
    s.update(L.norm_schema(cfg, "ln1"))
    s.update(S.ssm_schema(cfg))
    return s


def enc_block_schema(cfg: ModelConfig):
    s = {}
    s.update(L.norm_schema(cfg, "ln1"))
    s.update(L.norm_schema(cfg, "ln2"))
    s.update(L.attn_schema(cfg))
    s.update(L.mlp_schema(cfg))
    return s


def xdec_block_schema(cfg: ModelConfig):
    """Whisper decoder block: self-attn + cross-attn + mlp."""
    s = {}
    s.update(L.norm_schema(cfg, "ln1"))
    s.update(L.norm_schema(cfg, "ln2"))
    s.update(L.norm_schema(cfg, "ln3"))
    s.update(L.attn_schema(cfg, "attn"))
    s.update(L.attn_schema(cfg, "xattn"))
    s.update(L.mlp_schema(cfg))
    return s


def model_schema(cfg: ModelConfig, max_len: int = 0):
    d, V = cfg.d_model, cfg.vocab_pad
    tree = {
        "embed": {"tok": ((V, d), ("vocab", "embed"), 1e-2)},
        "final": L.norm_schema(cfg, "fn"),
    }
    if not cfg.tie_embeddings:
        tree["embed"]["unembed"] = ((V, d), ("vocab", "embed"), 1e-2)
    if cfg.rope_theta == 0:  # learned absolute positions (whisper)
        tree["embed"]["pos"] = ((max_len, d), ("none", "embed"), 1e-2)
    if cfg.family == "ssm":
        tree["blocks"] = L.stack_schema(ssm_block_schema(cfg), cfg.n_layers)
    elif cfg.family == "hybrid":
        tree["blocks"] = L.stack_schema(ssm_block_schema(cfg), cfg.n_layers)
        shared = {}
        shared.update(L.norm_schema(cfg, "ln1"))
        shared.update(L.norm_schema(cfg, "ln2"))
        shared.update(L.attn_schema(cfg))
        shared.update(L.mlp_schema(cfg))
        tree["shared"] = shared
    elif cfg.enc_dec:
        tree["embed"]["pos_enc"] = ((cfg.enc_len, d), ("none", "embed"), 1e-2)
        tree["enc"] = L.stack_schema(enc_block_schema(cfg), cfg.n_enc_layers)
        tree["enc_final"] = L.norm_schema(cfg, "efn")
        tree["blocks"] = L.stack_schema(xdec_block_schema(cfg), cfg.n_layers)
    else:
        tree["blocks"] = L.stack_schema(decoder_block_schema(cfg),
                                        cfg.n_layers)
    return tree


def init_params(cfg: ModelConfig, key, max_len: int = 0):
    schema = model_schema(cfg, max_len)
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, len(schema))
    return {name: L.build_params(sub, k, dtype)
            for (name, sub), k in zip(sorted(schema.items()), ks)}


def logical_axes(cfg: ModelConfig, max_len: int = 0):
    schema = model_schema(cfg, max_len)
    return {name: L.build_logical(sub) for name, sub in schema.items()}


def window_pattern(cfg: ModelConfig) -> jnp.ndarray:
    """Per-layer sliding-window size; 0 = global attention."""
    if cfg.local_global:
        return jnp.asarray(
            [cfg.local_window if l % 2 == 0 else 0
             for l in range(cfg.n_layers)], jnp.int32)
    w = cfg.window or 0
    return jnp.full((cfg.n_layers,), w, jnp.int32)


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------

def apply_decoder_block(cfg: ModelConfig, p, h, positions, window,
                        cache=None, fresh_kv=True):
    x = L.apply_norm(cfg, p, "ln1", h)
    if cfg.attention_impl == "flash" and cache is None:
        a, new_cache = L.attention_flash(cfg, p, x, positions,
                                         window=cfg.window)
    else:
        a, new_cache = L.attention(cfg, p, x, positions, window=window,
                                   cache=cache, fresh_kv=fresh_kv)
    if cfg.post_norm:
        a = L.apply_norm(cfg, p, "pn1", a)
    h = h + a
    x = L.apply_norm(cfg, p, "ln2", h)
    if cfg.n_experts:
        m, aux = L.apply_moe(cfg, p, x)
    else:
        m, aux = L.apply_mlp(cfg, p, x), 0.0
    if cfg.post_norm:
        m = L.apply_norm(cfg, p, "pn2", m)
    return h + m, new_cache, aux


def apply_ssm_block(cfg: ModelConfig, p, h, cache=None):
    x = L.apply_norm(cfg, p, "ln1", h)
    y, new_cache = S.mamba2_block(cfg, p, x, cache=cache)
    return h + y, new_cache


def apply_xdec_block(cfg: ModelConfig, p, h, positions, enc_out,
                     cache=None):
    x = L.apply_norm(cfg, p, "ln1", h)
    a, new_self = L.attention(cfg, p, x, positions, prefix="attn",
                              cache=None if cache is None else cache["self"])
    h = h + a
    x = L.apply_norm(cfg, p, "ln2", h)
    a, _ = L.attention(cfg, p, x, positions, prefix="xattn", kv_x=enc_out)
    h = h + a
    x = L.apply_norm(cfg, p, "ln3", h)
    h = h + L.apply_mlp(cfg, p, x)
    new_cache = None if cache is None else {"self": new_self}
    return h, new_cache


# ---------------------------------------------------------------------------
# forward passes
# ---------------------------------------------------------------------------

def _embed(cfg: ModelConfig, params, tokens, positions):
    dt = jnp.dtype(cfg.dtype)
    h = params["embed"]["tok"].astype(dt)[tokens]
    if cfg.name.startswith("gemma"):
        h = h * jnp.asarray(cfg.d_model ** 0.5, dt)
    if cfg.rope_theta == 0 and "pos" in params["embed"]:
        h = h + params["embed"]["pos"].astype(dt)[positions]
    return constrain(h, ("batch", "seq", "none"), cfg.rules())


def _maybe_remat(cfg: ModelConfig, fn):
    return jax.checkpoint(fn) if cfg.remat else fn


def _index(tree, l):
    return jax.tree.map(
        lambda a: lax.dynamic_index_in_dim(a, l, 0, keepdims=False), tree)


def _serve_loop(body, h, params_stacked, caches, n: int,
                unroll: bool = False):
    """fori_loop over layers with the STACKED CACHE AS LOOP CARRY,
    updated in place with dynamic_update_index.  A lax.scan would emit
    the new cache as a fresh `ys` allocation — double-buffering the
    whole KV cache (measured +6..13 GB/device on the decode_32k cells);
    the carried-buffer form updates in place.

    unroll=True (cfg.scan_layers=False) is for the dry-run's flop
    measurement — loop bodies are counted once by cost_analysis."""
    def f(l, carry):
        h, cache = carry
        p_l = _index(params_stacked, l)
        c_l = _index(cache, l)
        h, nc = body(h, p_l, c_l, l)
        cache = jax.tree.map(
            lambda a, nv: lax.dynamic_update_index_in_dim(
                a, nv.astype(a.dtype), l, 0), cache, nc)
        return (h, cache)

    if unroll:
        carry = (h, caches)
        for l in range(n):
            carry = f(l, carry)
        return carry
    return lax.fori_loop(0, n, f, (h, caches))


def _grouped_scan(cfg: ModelConfig, body, carry, xs, n: int):
    """Two-level remat: outer scan over groups (checkpointed) of an inner
    scan over cfg.remat_group layers (each also checkpointed).  Saved
    residuals between layers drop from n to n/group at ~one extra forward
    recompute — what fits qwen1.5-110b's 80-layer train step in HBM."""
    g = cfg.remat_group
    G = n // g
    xs_g = jax.tree.map(lambda a: a.reshape((G, g) + a.shape[1:]), xs)

    @jax.checkpoint
    def outer(c, xg):
        c, _ = lax.scan(jax.checkpoint(body), c, xg)
        return c, None

    return lax.scan(outer, carry, xs_g)


def _scan(cfg: ModelConfig, body, carry, xs):
    """lax.scan when cfg.scan_layers (compact HLO, one body in the IR) or
    an unrolled Python loop (used by the dry-run's flop measurement —
    XLA's cost_analysis counts loop bodies ONCE, so trip-count-sensitive
    metrics are extrapolated from small unrolled lowerings)."""
    if cfg.scan_layers:
        return lax.scan(body, carry, xs)
    n = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        carry, y = body(carry, jax.tree.map(lambda a: a[i], xs))
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    else:
        ys = None
    return carry, ys


def encode(cfg: ModelConfig, params, frames):
    """Whisper encoder over stub frame embeddings (B, enc_len, d)."""
    dt = jnp.dtype(cfg.dtype)
    h = frames.astype(dt) + params["embed"]["pos_enc"].astype(dt)
    positions = jnp.arange(h.shape[1])

    def body(h, p):
        x = L.apply_norm(cfg, p, "ln1", h)
        a, _ = L.attention(cfg, p, x, positions, causal=False)
        h = h + a
        x = L.apply_norm(cfg, p, "ln2", h)
        return h + L.apply_mlp(cfg, p, x), None

    h, _ = _scan(cfg, _maybe_remat(cfg, body), h, params["enc"])
    return L.apply_norm(cfg, params["enc_final"], "efn", h)


def forward(cfg: ModelConfig, params, tokens, positions, *, caches=None,
            enc_frames=None, enc_out=None, fresh_kv=True):
    """Token ids -> final hidden states.

    Returns (hidden, new_caches, aux_loss).  ``caches`` is the pytree from
    init_cache (serve path) or None (train path).
    """
    h = _embed(cfg, params, tokens, positions)
    aux0 = jnp.zeros((), jnp.float32)

    if cfg.enc_dec:
        if enc_out is None:
            if enc_frames is not None:
                enc_out = encode(cfg, params, enc_frames)
            elif caches is not None:
                enc_out = caches["enc_out"].astype(h.dtype)
            else:
                raise ValueError("enc-dec forward needs frames or enc_out")

        if caches is None:
            def body(h, p):
                h, _ = apply_xdec_block(cfg, p, h, positions, enc_out)
                return h, None
            h, _ = _scan(cfg, _maybe_remat(cfg, body), h, params["blocks"])
            new_caches = None
        else:
            def body(h, p, c, l):
                return apply_xdec_block(cfg, p, h, positions, enc_out,
                                        cache=c)
            h, layer_caches = _serve_loop(body, h, params["blocks"],
                                          caches["layers"], cfg.n_layers,
                                          unroll=not cfg.scan_layers)
            new_caches = {"layers": layer_caches,
                          "enc_out": enc_out.astype(caches["enc_out"].dtype)}
        h = L.apply_norm(cfg, params["final"], "fn", h)
        return h, new_caches, aux0

    if cfg.family == "ssm":
        if caches is None:
            def body(h, p):
                h, _ = apply_ssm_block(cfg, p, h)
                return h, None
            h, _ = _scan(cfg, _maybe_remat(cfg, body), h, params["blocks"])
            new_caches = None
        else:
            def body(h, p, c, l):
                return apply_ssm_block(cfg, p, h, cache=c)
            h, new_caches = _serve_loop(body, h, params["blocks"], caches,
                                        cfg.n_layers,
                                        unroll=not cfg.scan_layers)
        h = L.apply_norm(cfg, params["final"], "fn", h)
        return h, new_caches, aux0

    if cfg.family == "hybrid":
        G = cfg.n_layers // cfg.shared_every
        per = cfg.shared_every
        blocks = jax.tree.map(
            lambda a: a.reshape((G, per) + a.shape[1:]), params["blocks"])
        shared = params["shared"]

        def shared_attn(h, attn_c):
            x = L.apply_norm(cfg, shared, "ln1", h)
            a, new_attn_c = L.attention(cfg, shared, x, positions,
                                        cache=attn_c, fresh_kv=fresh_kv)
            h = h + a
            x = L.apply_norm(cfg, shared, "ln2", h)
            return h + L.apply_mlp(cfg, shared, x), new_attn_c

        if caches is None:
            def group_body(h, mb):
                h, _ = shared_attn(h, None)

                def inner(h, p):
                    h, _ = apply_ssm_block(cfg, p, h)
                    return h, None
                h, _ = _scan(cfg, inner, h, mb)
                return h, None
            h, _ = _scan(cfg, _maybe_remat(cfg, group_body), h, blocks)
            new_caches = None
        else:
            # nested fori_loops with the whole cache as carry (in-place)
            def outer(g, carry):
                h, cache = carry
                mb = _index(blocks, g)
                h, new_attn_c = shared_attn(h, _index(cache["shared"], g))

                def inner(h, p, cc, j):
                    return apply_ssm_block(cfg, p, h, cache=cc)
                h, new_ssm_c = _serve_loop(
                    inner, h, mb, _index(cache["mamba"], g), per,
                    unroll=not cfg.scan_layers)
                upd = lambda a, nv, i=g: lax.dynamic_update_index_in_dim(
                    a, nv.astype(a.dtype), i, 0)
                cache = {
                    "shared": jax.tree.map(upd, cache["shared"],
                                           new_attn_c),
                    "mamba": jax.tree.map(upd, cache["mamba"], new_ssm_c),
                }
                return (h, cache)

            if cfg.scan_layers:
                h, new_caches = lax.fori_loop(0, G, outer, (h, caches))
            else:
                carry = (h, caches)
                for g_ in range(G):
                    carry = outer(g_, carry)
                h, new_caches = carry
        h = L.apply_norm(cfg, params["final"], "fn", h)
        return h, new_caches, aux0

    # plain decoder-only (dense / moe / vlm)
    windows = window_pattern(cfg)
    if caches is None:
        def body(carry, xs):
            h, aux = carry
            p, w = xs
            h, _, a = apply_decoder_block(cfg, p, h, positions, w)
            return (h, aux + a), None
        if cfg.remat_group > 1 and cfg.scan_layers \
                and cfg.n_layers % cfg.remat_group == 0:
            (h, aux0), _ = _grouped_scan(cfg, body, (h, aux0),
                                         (params["blocks"], windows),
                                         cfg.n_layers)
        else:
            (h, aux0), _ = _scan(cfg, _maybe_remat(cfg, body), (h, aux0),
                                 (params["blocks"], windows))
        new_caches = None
    else:
        def body(h, p, c, l):
            w = windows[l]
            h, nc, _ = apply_decoder_block(cfg, p, h, positions, w,
                                           cache=c, fresh_kv=fresh_kv)
            return h, nc
        h, new_caches = _serve_loop(body, h, params["blocks"], caches,
                                    cfg.n_layers,
                                    unroll=not cfg.scan_layers)
    h = L.apply_norm(cfg, params["final"], "fn", h)
    return h, new_caches, aux0


def lm_head(cfg: ModelConfig, params, h):
    """Final hidden -> logits over the PADDED vocab (fp32; padded lanes
    masked to -inf so lse/argmax ignore them), tied embeddings by
    default."""
    emb = params["embed"].get("unembed", params["embed"]["tok"])
    logits = jnp.einsum("bld,vd->blv", h, emb.astype(h.dtype)
                        ).astype(jnp.float32)
    logits = constrain(logits, ("batch", "seq", "vocab"), cfg.rules())
    if cfg.final_softcap is not None:
        logits = cfg.final_softcap * jnp.tanh(logits / cfg.final_softcap)
    if cfg.vocab_pad != cfg.vocab:
        lane = jnp.arange(cfg.vocab_pad)
        logits = jnp.where(lane < cfg.vocab, logits, -1e30)
    return logits


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    """Build the serve-path cache pytree (zeros; pos = -1 means empty).

    SWA models ring-buffer only `window` slots — this is what makes
    long_500k decode feasible for danube/mixtral; SSM state is O(1)."""
    dt = jnp.dtype(cfg.dtype)

    def attn_cache(width):
        return {
            "k": jnp.zeros((batch, cfg.n_kv, width, cfg.hd), dt),
            "v": jnp.zeros((batch, cfg.n_kv, width, cfg.hd), dt),
            "pos": jnp.full((width,), -1, jnp.int32),
        }

    def ssm_cache():
        shp = S.ssm_cache_shape(cfg, batch)
        return {"conv": jnp.zeros(shp["conv"], dt),
                "h": jnp.zeros(shp["h"], jnp.float32)}

    def stack(tree, n):
        return jax.tree.map(lambda a: jnp.broadcast_to(
            a, (n,) + a.shape).copy(), tree)

    if cfg.family == "ssm":
        return stack(ssm_cache(), cfg.n_layers)
    if cfg.family == "hybrid":
        G = cfg.n_layers // cfg.shared_every
        width = (min(max_len, cfg.window + cfg.prefill_chunk)
                 if cfg.window else max_len)
        return {"shared": stack(attn_cache(width), G),
                "mamba": stack(stack(ssm_cache(), cfg.shared_every), G)}
    if cfg.enc_dec:
        return {"layers": stack({"self": attn_cache(max_len)}, cfg.n_layers),
                "enc_out": jnp.zeros((batch, cfg.enc_len, cfg.d_model), dt)}
    if cfg.local_global:
        # alternating layers need different widths; use per-layer max
        widths = [cfg.local_window if l % 2 == 0 else max_len
                  for l in range(cfg.n_layers)]
        width = max(min(w, max_len) for w in widths)
        return stack(attn_cache(width), cfg.n_layers)
    if cfg.window:
        # chunked prefill writes a whole segment before any query reads:
        # ring must hold window + chunk keys so nothing needed is evicted
        width = min(max_len, cfg.window + cfg.prefill_chunk)
    else:
        width = max_len
    return stack(attn_cache(width), cfg.n_layers)


def cache_logical_axes(cfg: ModelConfig):
    """Logical axes tree matching init_cache output."""
    attn = {"k": ("layers", "batch", "kv", "kv_seq", "none"),
            "v": ("layers", "batch", "kv", "kv_seq", "none"),
            "pos": ("layers", "none")}
    ssm = {"conv": ("layers", "batch", "none", "heads"),
           "h": ("layers", "batch", "heads", "none", "none")}
    if cfg.family == "ssm":
        return ssm
    if cfg.family == "hybrid":
        deep = {"conv": ("layers", "layers", "batch", "none", "heads"),
                "h": ("layers", "layers", "batch", "heads", "none", "none")}
        return {"shared": attn, "mamba": deep}
    if cfg.enc_dec:
        return {"layers": {"self": attn},
                "enc_out": ("batch", "seq", "embed")}
    return attn
