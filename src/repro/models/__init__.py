"""LM-zoo substrate: configs, layers, SSM, assembly, train/serve steps."""
from . import config, layers, lm, ssm, transformer  # noqa: F401
from .config import ModelConfig  # noqa: F401
