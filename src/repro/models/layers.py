"""LM-zoo building blocks: norms, RoPE, GQA attention (causal / sliding
window / softcap / qk-norm), SwiGLU & GELU MLPs, and top-k MoE with
scatter-based expert-parallel dispatch.

Parameters are plain dict pytrees built from *schemas*: each schema entry
is ``name -> (shape, logical_axes, init_scale)`` so the parameter tree,
its logical-sharding tree, and its initializer never drift apart.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from .config import ModelConfig, constrain

# ---------------------------------------------------------------------------
# schema machinery
# ---------------------------------------------------------------------------

def build_params(schema: dict, key, dtype):
    out = {}
    names = sorted(schema)
    keys = jax.random.split(key, len(names))
    for k_, name in zip(keys, names):
        shape, _, scale = schema[name]
        if scale == 0.0:
            out[name] = jnp.zeros(shape, dtype)
        elif scale == 1.0 and len(shape) <= 1:
            out[name] = jnp.ones(shape, dtype)
        else:
            out[name] = (jax.random.normal(k_, shape) * scale).astype(dtype)
    return out


def build_logical(schema: dict):
    return {name: tuple(spec[1]) for name, spec in schema.items()}


def stack_schema(schema: dict, n: int):
    """Add a scanned leading `layers` dimension to every entry."""
    return {name: ((n,) + tuple(shape), ("layers",) + tuple(lg), scale)
            for name, (shape, lg, scale) in schema.items()}


def fan_in(*dims):
    return 1.0 / math.sqrt(dims[0])


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm(x, scale, eps):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return ((x * lax.rsqrt(var + eps)) * (1.0 + scale.astype(jnp.float32))
            ).astype(dt)


def layernorm(x, scale, bias, eps):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    y = (x - mu) * lax.rsqrt(var + eps) * scale + bias
    return y.astype(dt)


def norm_schema(cfg: ModelConfig, prefix: str):
    d = cfg.d_model
    if cfg.norm == "layernorm":
        return {f"{prefix}_scale": ((d,), ("none",), 1.0),
                f"{prefix}_bias": ((d,), ("none",), 0.0)}
    return {f"{prefix}_scale": ((d,), ("none",), 0.0)}  # rms: 1 + scale


def apply_norm(cfg: ModelConfig, p, prefix: str, x):
    if cfg.norm == "layernorm":
        return layernorm(x, p[f"{prefix}_scale"], p[f"{prefix}_bias"],
                         cfg.norm_eps)
    return rmsnorm(x, p[f"{prefix}_scale"], cfg.norm_eps)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x, positions, theta):
    """x: (..., L, H, hd); positions: (..., L)."""
    hd = x.shape[-1]
    half = hd // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None, None] * freq  # (L, 1, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    dt = x.dtype
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [x1f * cos - x2f * sin, x2f * cos + x1f * sin], -1).astype(dt)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def attn_schema(cfg: ModelConfig, prefix: str = "attn"):
    d, hd, Hq, Hkv = cfg.d_model, cfg.hd, cfg.n_heads, cfg.n_kv
    s = {
        f"{prefix}_wq": ((d, Hq * hd), ("embed", "q_heads"), fan_in(d)),
        f"{prefix}_wk": ((d, Hkv * hd), ("embed", "kv"), fan_in(d)),
        f"{prefix}_wv": ((d, Hkv * hd), ("embed", "kv"), fan_in(d)),
        f"{prefix}_wo": ((Hq * hd, d), ("q_heads", "embed"), fan_in(Hq * hd)),
    }
    if cfg.qkv_bias:
        s[f"{prefix}_bq"] = ((Hq * hd,), ("q_heads",), 0.0)
        s[f"{prefix}_bk"] = ((Hkv * hd,), ("kv",), 0.0)
        s[f"{prefix}_bv"] = ((Hkv * hd,), ("kv",), 0.0)
    if getattr(cfg, "qk_norm", False) or cfg.family == "vlm":
        s[f"{prefix}_qnorm"] = ((hd,), ("none",), 0.0)
        s[f"{prefix}_knorm"] = ((hd,), ("none",), 0.0)
    return s


def _mask_logits(logits, qpos, kpos, *, causal, window):
    """window may be a traced per-layer scalar; 0/None => no window."""
    mask = kpos >= 0
    if causal:
        mask = mask & (kpos[None, :] <= qpos[:, None])
    if window is not None:
        w = jnp.asarray(window)
        no_win = w <= 0
        mask = mask & (no_win | (kpos[None, :] > qpos[:, None] - w))
    return jnp.where(mask[None, None], logits, -1e30)


# ---------------------------------------------------------------------------
# memory-efficient attention (flash semantics in pure XLA, custom VJP)
# ---------------------------------------------------------------------------
#
# The Pallas kernel (kernels/flash_attention.py) is the TPU hot-path; this
# is its XLA-native twin used where pallas cannot compile (CPU dry-run) and
# as the scan-over-kv-chunks formulation XLA fuses well.  The custom VJP is
# what keeps the backward pass O(L * chunk) memory: without it, jax's scan
# AD would store every chunk's probabilities and regress to O(L^2).

def _softcap_fwd(s, softcap):
    if softcap is None:
        return s, None
    t = jnp.tanh(s / softcap)
    return softcap * t, t


def _mea_mask(qpos, kpos, causal, window):
    m = kpos[None, :] >= 0
    if causal:
        m = m & (kpos[None, :] <= qpos[:, None])
    w = jnp.asarray(window)
    m = m & ((w <= 0) | (kpos[None, :] > qpos[:, None] - w))
    return m                                            # (Lq, Ck)


@partial(jax.custom_vjp, nondiff_argnums=(6, 7, 8, 9))
def mea_attention(q, k, v, qpos, kpos, window, causal, scale,
                  softcap, chunk):
    """q: (B,H,Lq,D); k,v: (B,H,Lk,D); qpos: (Lq,); kpos: (Lk,).
    window: int32 scalar ARRAY (may be traced, e.g. gemma2's scanned
    per-layer pattern); <= 0 means no window.
    """
    out, _ = _mea_fwd_impl(q, k, v, qpos, kpos, window, causal,
                           scale, softcap, chunk)
    return out


def _mea_fwd_impl(q, k, v, qpos, kpos, window, causal, scale, softcap,
                  chunk):
    B, H, Lq, D = q.shape
    Lk = k.shape[2]
    nc = max(1, Lk // chunk)
    ck = Lk // nc
    qf = q.astype(jnp.float32)
    ks = k.astype(jnp.float32).reshape(B, H, nc, ck, D).transpose(2, 0, 1, 3, 4)
    vs = v.astype(jnp.float32).reshape(B, H, nc, ck, D).transpose(2, 0, 1, 3, 4)
    kps = kpos.reshape(nc, ck)

    def body(carry, xs):
        m, l, acc = carry
        kc, vc, kpc = xs
        s = jnp.einsum("bhqd,bhkd->bhqk", qf, kc) * scale
        s, _ = _softcap_fwd(s, softcap)
        s = jnp.where(_mea_mask(qpos, kpc, causal, window)[None, None],
                      s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l = l * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum("bhqk,bhkd->bhqd", p, vc)
        return (m_new, l, acc), None

    m0 = jnp.full((B, H, Lq), -1e30, jnp.float32)
    l0 = jnp.zeros((B, H, Lq), jnp.float32)
    a0 = jnp.zeros((B, H, Lq, D), jnp.float32)
    (m, l, acc), _ = lax.scan(body, (m0, l0, a0), (ks, vs, kps))
    l = jnp.maximum(l, 1e-30)
    out = (acc / l[..., None]).astype(q.dtype)
    lse = m + jnp.log(l)
    return out, lse


def _mea_vjp_fwd(q, k, v, qpos, kpos, window, causal, scale, softcap,
                 chunk):
    out, lse = _mea_fwd_impl(q, k, v, qpos, kpos, window, causal, scale,
                             softcap, chunk)
    return out, (q, k, v, qpos, kpos, window, out, lse)


def _mea_vjp_bwd(causal, scale, softcap, chunk, res, dout):
    q, k, v, qpos, kpos, window, out, lse = res
    B, H, Lq, D = q.shape
    Lk = k.shape[2]
    nc = max(1, Lk // chunk)
    ck = Lk // nc
    qf = q.astype(jnp.float32)
    dof = dout.astype(jnp.float32)
    delta = jnp.sum(dof * out.astype(jnp.float32), axis=-1)   # (B,H,Lq)
    ks = k.astype(jnp.float32).reshape(B, H, nc, ck, D).transpose(2, 0, 1, 3, 4)
    vs = v.astype(jnp.float32).reshape(B, H, nc, ck, D).transpose(2, 0, 1, 3, 4)
    kps = kpos.reshape(nc, ck)

    def body(dq, xs):
        kc, vc, kpc = xs
        s_raw = jnp.einsum("bhqd,bhkd->bhqk", qf, kc) * scale
        s, t = _softcap_fwd(s_raw, softcap)
        s = jnp.where(_mea_mask(qpos, kpc, causal, window)[None, None],
                      s, -1e30)
        p = jnp.exp(s - lse[..., None])                  # exact probs
        dp = jnp.einsum("bhqd,bhkd->bhqk", dof, vc)
        ds = p * (dp - delta[..., None])
        if softcap is not None:
            ds = ds * (1.0 - t * t)                      # d tanh
        dv_c = jnp.einsum("bhqk,bhqd->bhkd", p, dof)
        dk_c = jnp.einsum("bhqk,bhqd->bhkd", ds, qf) * scale
        dq = dq + jnp.einsum("bhqk,bhkd->bhqd", ds, kc) * scale
        return dq, (dk_c, dv_c)

    dq0 = jnp.zeros((B, H, Lq, D), jnp.float32)
    dq, (dks, dvs) = lax.scan(body, dq0, (ks, vs, kps))
    dk = dks.transpose(1, 2, 0, 3, 4).reshape(B, H, Lk, D)
    dv = dvs.transpose(1, 2, 0, 3, 4).reshape(B, H, Lk, D)
    return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype),
            None, None, None)


mea_attention.defvjp(_mea_vjp_fwd, _mea_vjp_bwd)


def attention(cfg: ModelConfig, p, x, positions, *, prefix="attn",
              causal=True, window=None, cache=None, kv_x=None,
              fresh_kv=True):
    """GQA attention. x: (B, L, d). positions: (L,) absolute positions.

    cache: None (training / encoder) or a dict
      {k: (B, Hkv, W, hd), v: ..., pos: (W,) int32} — ring-buffered keys.
      Returns (out, new_cache).
    kv_x: cross-attention source (B, Lkv, d) (whisper decoder).
    """
    B, L, d = x.shape
    hd, Hq, Hkv = cfg.hd, cfg.n_heads, cfg.n_kv
    dt = x.dtype
    q = x @ p[f"{prefix}_wq"].astype(dt)
    src = kv_x if kv_x is not None else x
    k = src @ p[f"{prefix}_wk"].astype(dt)
    v = src @ p[f"{prefix}_wv"].astype(dt)
    if cfg.qkv_bias:
        q = q + p[f"{prefix}_bq"].astype(dt)
        k = k + p[f"{prefix}_bk"].astype(dt)
        v = v + p[f"{prefix}_bv"].astype(dt)
    rules = cfg.rules()
    q = constrain(q.reshape(B, L, Hq, hd),
                  ("batch", "seq", "q_heads", "none"), rules)
    Lk = src.shape[1]
    k = constrain(k.reshape(B, Lk, Hkv, hd),
                  ("batch", "seq", "kv", "none"), rules)
    v = constrain(v.reshape(B, Lk, Hkv, hd),
                  ("batch", "seq", "kv", "none"), rules)
    if f"{prefix}_qnorm" in p:
        q = rmsnorm(q, p[f"{prefix}_qnorm"], cfg.norm_eps)
        k = rmsnorm(k, p[f"{prefix}_knorm"], cfg.norm_eps)
    if kv_x is None and cfg.rope_theta > 0:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    q = q.transpose(0, 2, 1, 3)                       # (B, Hq, L, hd)
    k = k.transpose(0, 2, 1, 3)                       # (B, Hkv, Lk, hd)
    v = v.transpose(0, 2, 1, 3)

    scale = getattr(cfg, "query_scale", None) or hd ** -0.5
    group = Hq // Hkv
    win_arr = jnp.asarray(0 if window is None else window, jnp.int32)

    new_cache = None
    if cache is not None and kv_x is None:
        W = cache["k"].shape[2]
        slots = positions % W
        ck = cache["k"].at[:, :, slots].set(k.astype(cache["k"].dtype))
        cv = cache["v"].at[:, :, slots].set(v.astype(cache["v"].dtype))
        cpos = cache["pos"].at[slots].set(positions)
        new_cache = {"k": ck, "v": cv, "pos": cpos}
        if L == 1:
            # decode: grouped attention over the ring cache (kv_seq may be
            # sequence-parallel-sharded; heads stay grouped to avoid a
            # group-repeat of the whole cache)
            kc, vc, kpos = ck.astype(dt), cv.astype(dt), cpos
            qg = q.reshape(B, Hkv, group, L, hd)
            logits = jnp.einsum("bhgqd,bhkd->bhgqk", qg, kc,
                                preferred_element_type=jnp.float32) * scale
            if cfg.softcap is not None:
                logits = cfg.softcap * jnp.tanh(logits / cfg.softcap)
            logits = _mask_logits(
                logits.reshape(B, Hq, L, -1), positions, kpos,
                causal=causal, window=window,
            ).reshape(B, Hkv, group, L, -1)
            probs = jax.nn.softmax(logits, axis=-1).astype(dt)
            out = jnp.einsum("bhgqk,bhkd->bhgqd", probs, vc)
            out = out.reshape(B, Hq, L, hd)
            out = out.transpose(0, 2, 1, 3).reshape(B, L, -1)
            out = out @ p[f"{prefix}_wo"].astype(dt)
            return constrain(out, ("batch", "seq", "none"), rules), new_cache
        # prefill (L > 1):
        #  * fresh_kv=True — single-shot prefill: the fresh k/v ARE the
        #    whole history; fall through to the training formulation
        #    (exact, and a window-sized ring may already have dropped
        #    interior keys mid-write, so the cache must not be read).
        #  * fresh_kv=False — CHUNKED prefill: attend against the full
        #    updated cache (ring width is window + prefill_chunk so no
        #    key a query still needs is overwritten); invalid slots
        #    carry pos = -1 and are masked inside mea.
        if not fresh_kv:
            kc, vc, cp = ck.astype(dt), cv.astype(dt), cpos
            if group > 1:
                kc = jnp.repeat(kc, group, axis=1)
                vc = jnp.repeat(vc, group, axis=1)
            kc = constrain(kc, ("batch", "q_heads", "kv_seq", "none"),
                           rules)
            vc = constrain(vc, ("batch", "q_heads", "kv_seq", "none"),
                           rules)
            chunk = _pick_chunk(kc.shape[2], cfg.attn_chunk)
            out = mea_attention(q, kc, vc, positions, cp, win_arr, causal,
                                scale, cfg.softcap, chunk)
            out = out.astype(dt).transpose(0, 2, 1, 3).reshape(B, L, -1)
            out = out @ p[f"{prefix}_wo"].astype(dt)
            out = constrain(out, ("batch", "seq", "none"), rules)
            return out, new_cache

    kpos = positions if kv_x is None else jnp.arange(Lk)
    qpos = positions

    # repeat kv-heads up to q-heads: keeps every operand sharded on the
    # head axis over "model" (the grouped einsum forced XLA to all-gather
    # the (B,H,L,L) logits; see EXPERIMENTS.md §Perf iteration 1)
    if group > 1:
        k = jnp.repeat(k, group, axis=1)
        v = jnp.repeat(v, group, axis=1)
    k = constrain(k, ("batch", "q_heads", "seq", "none"), rules)
    v = constrain(v, ("batch", "q_heads", "seq", "none"), rules)

    if cfg.attention_impl == "chunked":
        chunk = _pick_chunk(Lk, cfg.attn_chunk)
        out = mea_attention(q, k, v, qpos, kpos, win_arr,
                            causal and kv_x is None, scale, cfg.softcap,
                            chunk)
    else:
        logits = jnp.einsum("bhqd,bhkd->bhqk", q, k,
                            preferred_element_type=jnp.float32) * scale
        if cfg.softcap is not None:
            logits = cfg.softcap * jnp.tanh(logits / cfg.softcap)
        logits = _mask_logits(logits, qpos, kpos,
                              causal=causal and kv_x is None, window=window)
        probs = jax.nn.softmax(logits, axis=-1).astype(dt)
        out = jnp.einsum("bhqk,bhkd->bhqd", probs, v)
    out = out.astype(dt).transpose(0, 2, 1, 3).reshape(B, L, -1)
    out = out @ p[f"{prefix}_wo"].astype(dt)
    out = constrain(out, ("batch", "seq", "none"), rules)
    return out, new_cache


def _pick_chunk(lk: int, target: int) -> int:
    """Largest divisor of lk that is <= target."""
    c = min(target, lk)
    while lk % c:
        c -= 1
    return max(c, 1)


def attention_flash(cfg: ModelConfig, p, x, positions, *, prefix="attn",
                    causal=True, window=None):
    """Training-path attention routed through the Pallas flash kernel
    (static window only)."""
    from ..kernels import ops as kops
    B, L, d = x.shape
    hd, Hq, Hkv = cfg.hd, cfg.n_heads, cfg.n_kv
    dt = x.dtype
    q = (x @ p[f"{prefix}_wq"].astype(dt)).reshape(B, L, Hq, hd)
    k = (x @ p[f"{prefix}_wk"].astype(dt)).reshape(B, L, Hkv, hd)
    v = (x @ p[f"{prefix}_wv"].astype(dt)).reshape(B, L, Hkv, hd)
    if cfg.qkv_bias:
        q = q + p[f"{prefix}_bq"].astype(dt).reshape(Hq, hd)
        k = k + p[f"{prefix}_bk"].astype(dt).reshape(Hkv, hd)
        v = v + p[f"{prefix}_bv"].astype(dt).reshape(Hkv, hd)
    if cfg.rope_theta > 0:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    out = kops.flash_attention(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), causal=causal,
        window=int(window) if window else None,
        softcap=cfg.softcap)
    out = out.transpose(0, 2, 1, 3).reshape(B, L, -1)
    return out @ p[f"{prefix}_wo"].astype(dt), None


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def mlp_schema(cfg: ModelConfig, prefix: str = "mlp", d_ff: int | None = None):
    d = cfg.d_model
    f = d_ff if d_ff is not None else cfg.d_ff
    if cfg.mlp == "swiglu":
        return {
            f"{prefix}_wg": ((d, f), ("embed", "mlp"), fan_in(d)),
            f"{prefix}_wu": ((d, f), ("embed", "mlp"), fan_in(d)),
            f"{prefix}_wd": ((f, d), ("mlp", "embed"), fan_in(f)),
        }
    return {
        f"{prefix}_wu": ((d, f), ("embed", "mlp"), fan_in(d)),
        f"{prefix}_bu": ((f,), ("mlp",), 0.0),
        f"{prefix}_wd": ((f, d), ("mlp", "embed"), fan_in(f)),
        f"{prefix}_bd": ((d,), ("none",), 0.0),
    }


def apply_mlp(cfg: ModelConfig, p, x, prefix: str = "mlp"):
    dt = x.dtype
    rules = cfg.rules()
    hidden_lg = ("batch", "seq", "mlp")
    if cfg.mlp == "swiglu":
        g = jax.nn.silu(constrain(x @ p[f"{prefix}_wg"].astype(dt),
                                  hidden_lg, rules))
        u = constrain(x @ p[f"{prefix}_wu"].astype(dt), hidden_lg, rules)
        out = (g * u) @ p[f"{prefix}_wd"].astype(dt)
    else:
        h = jax.nn.gelu(constrain(x @ p[f"{prefix}_wu"].astype(dt),
                                  hidden_lg, rules)
                        + p[f"{prefix}_bu"].astype(dt))
        out = h @ p[f"{prefix}_wd"].astype(dt) + p[f"{prefix}_bd"].astype(dt)
    return constrain(out, ("batch", "seq", "none"), rules)


# ---------------------------------------------------------------------------
# MoE (token-choice top-k, scatter-based expert-parallel dispatch)
# ---------------------------------------------------------------------------

def moe_schema(cfg: ModelConfig, prefix: str = "moe"):
    d = cfg.d_model
    # weights stored at DISPATCH granularity: with "ep_virtual" each
    # expert is split into virtual_split f-slices that behave as
    # independent experts (y = x Wg1 Wd1 + x Wg2 Wd2 is exact)
    E, f = cfg.n_experts_disp, cfg.d_ff_expert_disp
    return {
        f"{prefix}_router": ((d, cfg.n_experts), ("embed", "expert"),
                             fan_in(d)),
        f"{prefix}_wg": ((E, d, f), ("expert", "embed", "expert_mlp"),
                         fan_in(d)),
        f"{prefix}_wu": ((E, d, f), ("expert", "embed", "expert_mlp"),
                         fan_in(d)),
        f"{prefix}_wd": ((E, f, d), ("expert", "expert_mlp", "embed"),
                         fan_in(f)),
    }


CAPACITY_QUANTUM = 4096  # divisible by any (pod x data) shard count


def moe_capacity(cfg: ModelConfig, n_tokens: int) -> int:
    c = int(math.ceil(n_tokens * cfg.top_k * cfg.capacity_factor
                      / cfg.n_experts))
    q = CAPACITY_QUANTUM if n_tokens >= CAPACITY_QUANTUM else 128
    return max(q, -(-c // q) * q)


def positions_in_expert(flat_ids: jax.Array, n_experts: int,
                        block: int = 256) -> jax.Array:
    """Position of each assignment within its expert (stable order).

    A flat jnp.cumsum over millions of rows is costed (and on some
    backends executed) quadratically; this hierarchical version does the
    intra-block prefix sums as a lower-triangular MATMUL (MXU-friendly)
    and a cheap cumsum only over block counts.
    """
    n = flat_ids.shape[0]
    nb = -(-n // block)
    pad = nb * block - n
    ids = jnp.pad(flat_ids, (0, pad), constant_values=n_experts)
    onehot = jax.nn.one_hot(ids.reshape(nb, block), n_experts,
                            dtype=jnp.float32)               # (nb, bs, E)
    tri = jnp.tril(jnp.ones((block, block), jnp.float32))
    intra = jnp.einsum("qk,nke->nqe", tri, onehot)           # inclusive
    counts = jnp.sum(onehot, axis=1)                         # (nb, E)
    offsets = jnp.cumsum(counts, axis=0) - counts            # exclusive
    pos = offsets[:, None, :] + intra - 1.0                  # (nb, bs, E)
    picked = jnp.take_along_axis(
        pos.reshape(nb * block, n_experts),
        jnp.clip(ids, 0, n_experts - 1).reshape(-1, 1), axis=1)[:, 0]
    return picked[:n].astype(jnp.int32)


def _moe_dispatch_local(cfg: ModelConfig, xt, router, c_loc: int,
                        rank, n_shards: int, t_global: int):
    """Per-data-shard dispatch: router -> top-k -> local positions ->
    local scatter into this shard's capacity slice.  Runs either inside
    shard_map (sharded over the batch axes) or plainly on one device."""
    dt = xt.dtype
    E, K = cfg.n_experts, cfg.top_k
    t_loc, d = xt.shape
    logits = (xt @ router.astype(dt)).astype(jnp.float32)
    gate_vals, ids = lax.top_k(logits, K)
    gates = jax.nn.softmax(gate_vals, axis=-1)

    # load-balance aux (Switch-style); local sums -> global means
    probs = jax.nn.softmax(logits, axis=-1)
    sel = jnp.sum(jax.nn.one_hot(ids, E, dtype=jnp.float32), axis=1)
    me_sum = jnp.sum(probs, axis=0)
    ce_sum = jnp.sum(sel, axis=0)

    if cfg.expert_sharding == "ep_virtual":
        # expand each assignment to its virtual f-slices; same gate on
        # every slice (their partial outputs sum to the expert output)
        v = cfg.virtual_split
        ids = (ids[..., None] * v +
               jnp.arange(v, dtype=ids.dtype)).reshape(t_loc, K * v)
        gates = jnp.repeat(gates, v, axis=-1)
        E, K = E * v, K * v

    flat_ids = ids.reshape(-1)
    pos = positions_in_expert(flat_ids, E)
    keep = pos < c_loc
    slot = jnp.where(keep, flat_ids * c_loc + pos, E * c_loc)
    xr = jnp.repeat(xt, K, axis=0)
    buf = jnp.zeros((E * c_loc + 1, d), dt).at[slot].add(xr)
    buf = buf[:-1].reshape(E, c_loc, d)
    return buf, slot, gates, keep, (me_sum, ce_sum)


def _moe_combine_local(out_e_loc, slot, gates, keep, K: int):
    """Per-data-shard combine: by construction each shard's tokens were
    scattered into ITS OWN capacity slice, so the gather is local."""
    E, c_loc, d = out_e_loc.shape
    flat = out_e_loc.reshape(E * c_loc, d)
    g = flat[jnp.minimum(slot, E * c_loc - 1)]
    g = g * (gates.reshape(-1)[:, None] * keep[:, None]).astype(flat.dtype)
    return jnp.sum(g.reshape(-1, K, d), axis=1)          # (T_loc, d)


def apply_moe(cfg: ModelConfig, p, x, prefix: str = "moe"):
    """x: (B, L, d). Token-choice top-k with capacity + dropping.

    The dispatch (router/top-k/positions/scatter) runs PER DATA SHARD
    inside shard_map — a global scatter across shards forces XLA to
    replicate-and-all-reduce the whole (E, C, d) buffer (measured 64 GB
    per step for mixtral; see EXPERIMENTS.md §Perf).  The expert matmuls
    stay in pjit-land on the (E, C[data-sharded], d) buffer: "ep" archs
    shard E over "model" (expert parallelism), "tp" archs shard d_ff
    over "model" with the expert weights explicitly all-gathered over
    "data" (weights move, not the much larger activations).
    Returns (out, aux_loss).
    """
    B, L, d = x.shape
    dt = x.dtype
    E, K = cfg.n_experts, cfg.top_k
    K_comb = K * (cfg.virtual_split
                  if cfg.expert_sharding == "ep_virtual" else 1)
    T = B * L
    rules = cfg.rules()
    xt = x.reshape(T, d)
    router = p[f"{prefix}_router"]

    from ..comm.compat import get_abstract_mesh
    mesh = get_abstract_mesh()
    batch_rule = rules.get("batch", ("pod", "data"))
    data_axes = tuple(a for a in batch_rule
                      if not mesh.empty and a in mesh.shape)
    n_shards = 1
    for a in data_axes:
        n_shards *= mesh.shape[a]
    C_g = moe_capacity(cfg, T)
    c_loc = C_g // n_shards
    sharded = bool(data_axes) and T % n_shards == 0 and C_g % n_shards == 0

    if sharded:
        from jax.sharding import PartitionSpec as P

        from ..comm.compat import psum, shard_map

        def disp(xt_loc, router_f):
            buf, slot, gates, keep, (me_s, ce_s) = _moe_dispatch_local(
                cfg, xt_loc, router_f, c_loc, 0, n_shards, T)
            me_s = psum(me_s, data_axes)
            ce_s = psum(ce_s, data_axes)
            return buf, slot, gates, keep, me_s, ce_s
        buf, slot, gates, keep, me_s, ce_s = shard_map(
            disp, mesh=mesh,
            in_specs=(P(data_axes, None), P(None, None)),
            out_specs=(P(None, data_axes, None), P(data_axes),
                       P(data_axes, None), P(data_axes), P(None), P(None)),
            check_vma=False,
        )(xt, router)
    else:
        buf, slot, gates, keep, (me_s, ce_s) = _moe_dispatch_local(
            cfg, xt, router, C_g, 0, 1, T)
        c_loc = C_g
    aux = E * jnp.sum((me_s / T) * (ce_s / T))

    buf = constrain(buf, ("expert", "capacity", "none"), rules)
    wg, wu, wd = (p[f"{prefix}_wg"], p[f"{prefix}_wu"], p[f"{prefix}_wd"])
    if cfg.expert_sharding == "tp":
        # gather the WEIGHTS over the fsdp axis (not the activations)
        wlg = ("expert", "none", "expert_mlp")
        wg = constrain(wg, wlg, rules)
        wu = constrain(wu, wlg, rules)
        wd = constrain(wd, ("expert", "expert_mlp", "none"), rules)
    wg, wu, wd = wg.astype(dt), wu.astype(dt), wd.astype(dt)
    hid_lg = ("expert", "capacity", "expert_mlp")
    h = jax.nn.silu(constrain(
        jnp.einsum("ecd,edf->ecf", buf, wg), hid_lg, rules)) * \
        constrain(jnp.einsum("ecd,edf->ecf", buf, wu), hid_lg, rules)
    out_e = constrain(jnp.einsum("ecf,efd->ecd", h, wd),
                      ("expert", "capacity", "none"), rules)  # (E, C, d)

    if sharded:
        from jax.sharding import PartitionSpec as P
        from ..comm.compat import shard_map
        out = shard_map(
            partial(_moe_combine_local, K=K_comb), mesh=mesh,
            in_specs=(P(None, data_axes, None), P(data_axes),
                      P(data_axes, None), P(data_axes)),
            out_specs=P(data_axes, None),
            check_vma=False,
        )(out_e, slot, gates, keep)
    else:
        out = _moe_combine_local(out_e, slot, gates, keep, K_comb)
    return out.reshape(B, L, d), aux
