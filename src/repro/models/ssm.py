"""Mamba2 (SSD — state-space duality) blocks, chunked-scan training path,
O(1)-state decode path, and a naive recurrent oracle for tests.

Shapes follow the Mamba2 paper: d_inner = expand * d_model, heads
nh = d_inner / headdim, per-head state size N = ssm_state, B/C shared
across heads in ssm_ngroups groups.  The chunked algorithm splits L into
chunks of Q tokens; intra-chunk terms are a masked quadratic form (maps
onto the MXU), inter-chunk terms are a length-L/Q scan over the running
state h: (nh, hp, N) — this is what makes long_500k decode O(1) in
sequence length.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax import lax

from .config import ModelConfig, constrain
from .layers import fan_in, rmsnorm


def ssm_schema(cfg: ModelConfig, prefix: str = "ssm"):
    d = cfg.d_model
    di = cfg.d_inner
    g, ns, nh = cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_nheads
    conv_dim = di + 2 * g * ns
    return {
        f"{prefix}_in": ((d, 2 * di + 2 * g * ns + nh),
                         ("embed", "heads"), fan_in(d)),
        f"{prefix}_conv": ((cfg.ssm_conv, conv_dim), ("none", "heads"),
                           fan_in(cfg.ssm_conv)),
        f"{prefix}_conv_b": ((conv_dim,), ("heads",), 0.0),
        f"{prefix}_alog": ((nh,), ("none",), 1.0),     # A = -exp(alog)
        f"{prefix}_dtb": ((nh,), ("none",), 0.0),      # dt bias
        f"{prefix}_d": ((nh,), ("none",), 1.0),        # skip D
        f"{prefix}_gnorm": ((di,), ("none",), 0.0),    # gated RMSNorm
        f"{prefix}_out": ((di, d), ("heads", "embed"), fan_in(di)),
    }


def _split_in(cfg: ModelConfig, zxbcdt):
    di = cfg.d_inner
    g, ns, nh = cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_nheads
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di:di + di + 2 * g * ns]
    dt = zxbcdt[..., -nh:]
    return z, xbc, dt


def _causal_conv(xbc, w, b, *, state=None):
    """Depthwise causal conv over time. xbc: (B, L, C); w: (K, C).

    state: (B, K-1, C) previous inputs (decode); returns (out, new_state).
    """
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros(xbc.shape[:1] + (K - 1,) + xbc.shape[2:], xbc.dtype)
    else:
        pad = state.astype(xbc.dtype)
    full = jnp.concatenate([pad, xbc], axis=1)            # (B, L+K-1, C)
    out = sum(full[:, i:i + xbc.shape[1]] * w[i] for i in range(K)) + b
    new_state = full[:, -(K - 1):] if K > 1 else None
    return jax.nn.silu(out), new_state


def segsum(x):
    """Stable segment-sum: out[..., i, j] = sum_{j < k <= i} x[..., k]."""
    L = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((L, L), bool), 0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked(x, dt, a, b, c, *, chunk: int, h0=None):
    """SSD forward. x: (B, L, nh, hp); dt: (B, L, nh) (post-softplus);
    a: (nh,) negative; b, c: (B, L, g, N).  Returns (y, h_last) with
    h_last: (B, nh, hp, N).
    """
    B, L, nh, hp = x.shape
    g, N = b.shape[2], b.shape[3]
    Q = min(chunk, L)
    L_real = L
    if L % Q:
        # zero-pad: dt=0 padding contributes nothing (unit decay, zero
        # input), so the result and final state are exact
        pad = Q - L % Q
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0), (0, 0)))
        L = L + pad
    nc = L // Q
    rep = nh // g

    f32 = jnp.float32
    xc = x.reshape(B, nc, Q, nh, hp).astype(f32)
    dtc = dt.reshape(B, nc, Q, nh).astype(f32)
    bc = jnp.repeat(b.reshape(B, nc, Q, g, N), rep, axis=3).astype(f32)
    cc = jnp.repeat(c.reshape(B, nc, Q, g, N), rep, axis=3).astype(f32)
    da = dtc * a.astype(f32)                              # (B, nc, Q, nh)
    xdt = xc * dtc[..., None]

    # intra-chunk (quadratic, MXU-friendly)
    Lmat = jnp.exp(segsum(da.transpose(0, 1, 3, 2)))      # (B, nc, nh, Q, Q)
    scores = jnp.einsum("bcqhn,bckhn->bchqk", cc, bc)
    y_intra = jnp.einsum("bchqk,bchqk,bckhp->bcqhp",
                         scores, Lmat, xdt)

    # chunk states: S_c = sum_j exp(sum_{k>j} da_k) * b_j x_j^T
    cum = jnp.cumsum(da, axis=2)
    decay_to_end = jnp.exp(cum[..., -1:, :] - cum)        # (B, nc, Q, nh)
    states = jnp.einsum("bcqhn,bcqhp,bcqh->bchpn", bc, xdt, decay_to_end)

    # inter-chunk scan over running state
    chunk_decay = jnp.exp(cum[..., -1, :])                # (B, nc, nh)

    def scan_fn(h, inp):
        s_c, dec = inp
        h_before = h
        h = h * dec[..., None, None] + s_c
        return h, h_before

    hinit = (jnp.zeros((B, nh, hp, N), f32) if h0 is None
             else h0.astype(f32))
    h_last, h_befores = lax.scan(
        scan_fn,
        hinit,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    h_befores = h_befores.transpose(1, 0, 2, 3, 4)        # (B, nc, nh, hp, N)

    in_decay = jnp.exp(cum)                               # (B, nc, Q, nh)
    y_inter = jnp.einsum("bcqhn,bchpn,bcqh->bcqhp", cc, h_befores, in_decay)

    y = (y_intra + y_inter).reshape(B, L, nh, hp)[:, :L_real]
    return y.astype(x.dtype), h_last


def ssd_recurrent_ref(x, dt, a, b, c, *, h0=None):
    """Naive per-step recurrence oracle (also the decode semantics)."""
    B, L, nh, hp = x.shape
    g, N = b.shape[2], b.shape[3]
    rep = nh // g
    f32 = jnp.float32
    bf = jnp.repeat(b, rep, axis=2).astype(f32)
    cf = jnp.repeat(c, rep, axis=2).astype(f32)

    def step(h, inp):
        xt, dtt, bt, ct = inp                  # (B,nh,hp), (B,nh), (B,nh,N)
        dec = jnp.exp(dtt * a.astype(f32))     # (B, nh)
        h = h * dec[..., None, None] + jnp.einsum(
            "bhn,bhp,bh->bhpn", bt, xt.astype(f32), dtt)
        y = jnp.einsum("bhn,bhpn->bhp", ct, h)
        return h, y

    hinit = (jnp.zeros((B, nh, hp, N), f32) if h0 is None
             else h0.astype(f32))
    h, ys = lax.scan(step, hinit,
                     (x.transpose(1, 0, 2, 3), dt.astype(f32).transpose(1, 0, 2),
                      bf.transpose(1, 0, 2, 3), cf.transpose(1, 0, 2, 3)))
    return ys.transpose(1, 0, 2, 3).astype(x.dtype), h


def mamba2_block(cfg: ModelConfig, p, x, *, prefix="ssm", cache=None):
    """Full Mamba2 block. x: (B, L, d). cache: None or
    {conv: (B, K-1, convdim), h: (B, nh, hp, N)} for decode/chunked prefill.
    Returns (out, new_cache)."""
    B, L, d = x.shape
    dt_ = x.dtype
    di = cfg.d_inner
    g, ns, nh = cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_nheads
    hp = cfg.ssm_headdim

    rules = cfg.rules()
    zxbcdt = constrain(x @ p[f"{prefix}_in"].astype(dt_),
                       ("batch", "seq", "heads"), rules)
    z, xbc, dtr = _split_in(cfg, zxbcdt)
    conv_state = cache["conv"] if cache is not None else None
    xbc, new_conv = _causal_conv(
        xbc, p[f"{prefix}_conv"].astype(dt_),
        p[f"{prefix}_conv_b"].astype(dt_), state=conv_state)
    xs = xbc[..., :di].reshape(B, L, nh, hp)
    bmat = xbc[..., di:di + g * ns].reshape(B, L, g, ns)
    cmat = xbc[..., di + g * ns:].reshape(B, L, g, ns)
    dt = jax.nn.softplus(dtr.astype(jnp.float32)
                         + p[f"{prefix}_dtb"].astype(jnp.float32))
    a = -jnp.exp(p[f"{prefix}_alog"].astype(jnp.float32))

    h0 = cache["h"] if cache is not None else None
    if L == 1:  # decode fast path: one recurrence step, no chunking
        y, h = ssd_recurrent_ref(xs, dt, a, bmat, cmat, h0=h0)
    else:
        y, h = ssd_chunked(xs, dt, a, bmat, cmat, chunk=cfg.ssm_chunk, h0=h0)
    y = y + xs * p[f"{prefix}_d"].astype(dt_)[None, None, :, None]
    y = constrain(y.reshape(B, L, di), ("batch", "seq", "heads"), rules)
    y = rmsnorm(y * jax.nn.silu(z), p[f"{prefix}_gnorm"], cfg.norm_eps)
    out = constrain(y @ p[f"{prefix}_out"].astype(dt_),
                    ("batch", "seq", "none"), rules)
    new_cache = None
    if cache is not None:
        new_cache = {"conv": new_conv.astype(cache["conv"].dtype),
                     "h": h.astype(cache["h"].dtype)}
    return out, new_cache


def ssm_cache_shape(cfg: ModelConfig, batch: int):
    di = cfg.d_inner
    conv_dim = di + 2 * cfg.ssm_ngroups * cfg.ssm_state
    return {
        "conv": (batch, cfg.ssm_conv - 1, conv_dim),
        "h": (batch, cfg.ssm_nheads, cfg.ssm_headdim, cfg.ssm_state),
    }
