"""1.5D communication-avoiding matmuls + transposes (paper Algorithm 4, S.2).

Two flavors of the rotation:

  * gather-flavor — the rotating operand R contributes different OUTPUT
    blocks each round (the contraction is fully local).  Used for
    S = X^T X (Cov), W = Omega S (Cov), Z = Y X (Obs).  After
    n_R/c_F rounds each team allgathers its panel (Alg. 4 line 8).

  * reduce-flavor — the rotating operand R contributes different slices of
    the CONTRACTION dim; partial products accumulate into a stationary
    output, finished with a psum over the team layer (Alg. 4 line 8).
    Used for Y = Omega X^T (Obs).

The ring shift is one lax.ppermute per round (TPU: one ICI neighbor hop);
the shift and the local dot both read the same buffer, so they have no data
dependence and XLA's latency-hiding scheduler overlaps them (the paper's
overlap of MPI_Isend with dgemm).

All functions with the ``_local`` suffix run INSIDE shard_map (shards in,
shards out, collectives inline) so the distributed CONCORD loop can call
them from within one big shard_map'd while_loop.  The module-level
functions are standalone shard_map wrappers used by tests and benchmarks.

Replication-aware transposes implement Lemma 3.2: with replication c, the
all-to-all neighborhood shrinks from P to P/c^2 (each replica layer
exchanges only a 1/c slice, finished by an allgather over the layer).
"""
from __future__ import annotations

from functools import partial

import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from ..core import matops
from . import compat
from .grid import AXES, Grid1p5D

# Layout shorthands (see grid.py):
#   X-like     : global (r, p) col-blocked  -> spec P(None, ("i","j")) or
#                global (p, r) row-blocked  -> spec P(("i","j"), None)
#   Omega-like : global (p, r) row-blocked  -> spec P(("i","k"), None)
SPEC_XCOL = P(None, ("i", "j"))
SPEC_XROW = P(("i", "j"), None)
SPEC_OM = P(("i", "k"), None)


def _team_x():
    return lax.axis_index("i") * compat.axis_size("j") + lax.axis_index("j")


def _team_om():
    return lax.axis_index("i") * compat.axis_size("k") + lax.axis_index("k")


def _ring_pos_om(grid: Grid1p5D):
    return _team_om() * grid.c_omega + lax.axis_index("j")


# ---------------------------------------------------------------------------
# gather-flavor rotation (runs inside shard_map)
# ---------------------------------------------------------------------------

def rot_gather_local(r_blk, f_loc, grid: Grid1p5D, *, n_r: int,
                     canonical: str, ring: str, r_mask=None,
                     policy: matops.MatmulPolicy | None = None):
    """Rotate R around `ring`, multiplying with the fixed local block.

    ring="x":      tile = r_visit @ f_loc   (R row-block x fixed col-block)
                   team layer = "k", c_F = c_x
    ring="omega":  tile = f_loc @ r_visit   (fixed row-block x R col-block)
                   team layer = "j", c_F = c_omega

    With ``r_mask`` (the rotating operand's block-occupancy mask, ring="x"
    only — i.e. R is the Ω iterate), the mask travels around the ring with
    R and every local tile product routes through the block-sparse
    dispatch of ``core.matops``, skipping absent blocks past the policy's
    density crossover.

    Returns the stacked tile sequence (n_r, *tile.shape) reordered so index
    b holds the tile of R block b (the caller reshapes into a panel).
    """
    c_f = grid.c_x if ring == "x" else grid.c_omega
    layer_axis = "k" if ring == "x" else "j"
    if c_f < n_r and n_r % c_f:
        raise ValueError(f"need c_F | n_R (or c_F >= n_R): c_F={c_f}, n_R={n_r}")
    if r_mask is not None and ring != "x":
        raise ValueError("masked rotation is defined for ring='x' (the "
                         "rotating operand is the Omega iterate)")
    rounds = max(1, n_r // c_f)
    stagger = grid.stagger_perm(canonical, ring, n_r)
    shift = grid.shift_perm(ring, c_f)

    cur0 = lax.ppermute(r_blk, AXES, stagger)
    msk0 = None if r_mask is None else lax.ppermute(r_mask, AXES, stagger)

    def body(carry, _):
        cur, msk = carry
        nxt = lax.ppermute(cur, AXES, shift)
        nmsk = None if msk is None else lax.ppermute(msk, AXES, shift)
        if ring == "x":
            tile = matops.matmul(cur, f_loc, mask=msk, policy=policy)
        else:
            tile = f_loc @ cur
        return (nxt, nmsk), tile

    (_, _), tiles = lax.scan(body, (cur0, msk0), None,
                             length=rounds)            # (rounds, br, bc)
    g = lax.all_gather(tiles, layer_axis)                 # (c_f, rounds, ...)
    seq = jnp.swapaxes(g, 0, 1).reshape((rounds * c_f,) + tiles.shape[1:])
    team = _team_x() if ring == "x" else _team_om()
    # sequence position m holds the tile of block (team*c_f + m) mod n_r;
    # when c_f > n_r team members hold duplicates — the mod-take dedupes.
    idx = jnp.mod(jnp.arange(n_r) - team * c_f, n_r)
    return jnp.take(seq, idx, axis=0)


def xtx_local(x_loc, grid: Grid1p5D, *, scale=1.0):
    """S = scale * X^T X from the local X col-block (n, blk_x).  Cov line 2."""
    xt_loc = x_loc.T  # canonical X-like row-block of X^T
    seq = rot_gather_local(xt_loc, x_loc, grid, n_r=grid.n_x,
                           canonical="xlike", ring="x")
    blk = x_loc.shape[1]
    return seq.reshape(grid.n_x * blk, blk) * scale     # S col-panel (p, blk_x)


def omega_s_local(omega_rows, s_panel, grid: Grid1p5D, *, canonical="omegalike"):
    """W = Omega @ S.  omega_rows: R row-block; s_panel: fixed (p, blk_x).

    canonical="omegalike" for the standalone op (Omega in its canonical
    layout, n_om blocks); the Cov driver stores Omega X-like-transposed
    (c_omega == c_x) and passes canonical="xlike"."""
    n_r = grid.n_om if canonical == "omegalike" else grid.n_x
    seq = rot_gather_local(omega_rows, s_panel, grid, n_r=n_r,
                           canonical=canonical, ring="x")
    blk_r, blk_c = omega_rows.shape[0], s_panel.shape[1]
    return seq.reshape(n_r * blk_r, blk_c)              # W col-panel (p, blk_x)


def y_x_local(y_rows, x_loc, grid: Grid1p5D, *, scale=1.0):
    """Z = scale * Y @ X.  y_rows: fixed Omega-like (blk_om, n);
    x_loc: rotating X col-block (n, blk_x).  Obs line 4."""
    seq = rot_gather_local(x_loc, y_rows, grid, n_r=grid.n_x,
                           canonical="xlike", ring="omega")
    # seq: (n_x, blk_om, blk_x) with block v at index v -> concat on cols
    blk_om = y_rows.shape[0]
    z = jnp.transpose(seq, (1, 0, 2)).reshape(blk_om, -1)
    return z * scale                                    # Z row-block (blk_om, p)


# ---------------------------------------------------------------------------
# reduce-flavor rotation (runs inside shard_map)
# ---------------------------------------------------------------------------

def omega_xt_local(omega_rows, xt_loc, grid: Grid1p5D, *, scale=1.0,
                   omega_mask=None,
                   policy: matops.MatmulPolicy | None = None):
    """Y = scale * Omega @ X^T.  omega_rows: fixed Omega-like (blk_om, p);
    xt_loc: rotating X^T row-block (blk_x, n).  Obs lines 2/10.

    With ``omega_mask`` (the fixed operand's (blk_om/bs, p/bs) occupancy),
    each round gates the contracted Omega column-slice with the matching
    mask column-slice through the ``core.matops`` dispatch (requires the
    policy block size to divide blk_x)."""
    if omega_mask is not None and policy is None:
        raise ValueError("omega_mask requires a matops policy (they are "
                         "only meaningful together)")
    n_x, c_om = grid.n_x, grid.c_omega
    blk_om, p = omega_rows.shape
    blk_x, n = xt_loc.shape
    mcols_blk = None if omega_mask is None else blk_x // policy.block_size
    rounds = n_x // c_om
    stagger = grid.stagger_perm("xlike", "omega", n_x)
    shift = grid.shift_perm("omega", c_om)

    cur0 = lax.ppermute(xt_loc, AXES, stagger)
    v0 = jnp.mod(_ring_pos_om(grid), n_x).astype(jnp.int32)

    def body(carry, _):
        cur, acc, v = carry
        nxt = lax.ppermute(cur, AXES, shift)
        cols = lax.dynamic_slice(omega_rows, (jnp.int32(0), v * blk_x),
                                 (blk_om, blk_x))
        if omega_mask is None:
            acc = acc + cols @ cur
        else:
            mcols = lax.dynamic_slice(
                omega_mask, (jnp.int32(0), v * mcols_blk),
                (omega_mask.shape[0], mcols_blk))
            acc = acc + matops.matmul(cols, cur, mask=mcols, policy=policy)
        v = jnp.mod(v + c_om, n_x)
        return (nxt, acc, v), None

    acc0 = jnp.zeros((blk_om, n), dtype=jnp.result_type(omega_rows, xt_loc))
    (_, acc, _), _ = lax.scan(body, (cur0, acc0, v0), None, length=rounds)
    y = lax.psum(acc, "j")                              # finish team reduce
    return y * scale                                    # Y row-block (blk_om, n)


# ---------------------------------------------------------------------------
# replication-aware distributed transposes (Lemma 3.2)
# ---------------------------------------------------------------------------

def transpose_xlike_local(w_panel, grid: Grid1p5D):
    """(p, blk_x) col-panel of W  ->  (p, blk_x) col-panel of W^T.

    Each replica layer k exchanges only its 1/c_x row-slice (Lemma 3.2),
    finished by an allgather over "k"."""
    n_x, c_x = grid.n_x, grid.c_x
    p, blk = w_panel.shape
    sub = blk // c_x
    k = lax.axis_index("k")
    w3 = w_panel.reshape(n_x, blk, blk)
    mine = lax.dynamic_slice_in_dim(w3, k * sub, sub, axis=1)   # (n_x, sub, blk)
    rcv = lax.all_to_all(mine, ("i", "j"), split_axis=0, concat_axis=0,
                         tiled=True)                            # (n_x, sub, blk)
    rows = jnp.transpose(rcv, (1, 0, 2)).reshape(sub, p)        # W[t-rows k-slice, :]
    cols_t = rows.T                                             # (p, sub)
    g = lax.all_gather(cols_t, "k")                             # (c_x, p, sub)
    return jnp.transpose(g, (1, 0, 2)).reshape(p, blk)


def transpose_omegalike_local(z_rows, grid: Grid1p5D):
    """(blk_om, p) row-block of Z  ->  (blk_om, p) row-block of Z^T."""
    n_om, c_om = grid.n_om, grid.c_omega
    blk, p = z_rows.shape
    sub = blk // c_om
    j = lax.axis_index("j")
    z3 = z_rows.reshape(blk, n_om, blk)
    mine = lax.dynamic_slice_in_dim(z3, j * sub, sub, axis=0)   # (sub, n_om, blk)
    rcv = lax.all_to_all(mine, ("i", "k"), split_axis=1, concat_axis=1,
                         tiled=True)                            # (sub, n_om, blk)
    part = jnp.transpose(rcv, (2, 1, 0))                        # (blk, n_om, sub)
    g = lax.all_gather(part, "j")                               # (c_om, blk, n_om, sub)
    return jnp.transpose(g, (1, 2, 0, 3)).reshape(blk, p)


# ---------------------------------------------------------------------------
# standalone wrappers (own shard_map; used by tests, benchmarks, lm-head)
# ---------------------------------------------------------------------------

def _smap(grid, mesh, fn, in_specs, out_specs):
    from .compat import shard_map
    return shard_map(fn, mesh=mesh, in_specs=in_specs,
                     out_specs=out_specs, check_vma=False)


def xtx(x, grid: Grid1p5D, mesh, *, scale=1.0):
    """S = scale * X^T X.  x: (n, p) -> S: (p, p) X-like col-blocked."""
    fn = partial(xtx_local, grid=grid, scale=scale)
    return _smap(grid, mesh, fn, (SPEC_XCOL,), SPEC_XCOL)(x)


def omega_s(omega, s, grid: Grid1p5D, mesh):
    """W = Omega @ S.  omega: (p, p) Omega-like; s: (p, p) X-like col."""
    fn = partial(omega_s_local, grid=grid, canonical="omegalike")
    return _smap(grid, mesh, fn, (SPEC_OM, SPEC_XCOL), SPEC_XCOL)(omega, s)


def omega_xt(omega, x, grid: Grid1p5D, mesh, *, scale=1.0):
    """Y = scale * Omega @ X^T.  omega: (p, p) Omega-like; x: (n, p)."""
    def fn(om_loc, x_loc):
        return omega_xt_local(om_loc, x_loc.T, grid, scale=scale)
    return _smap(grid, mesh, fn, (SPEC_OM, SPEC_XCOL), SPEC_OM)(omega, x)


def y_x(y, x, grid: Grid1p5D, mesh, *, scale=1.0):
    """Z = scale * Y @ X.  y: (p, n) Omega-like rows; x: (n, p)."""
    fn = partial(y_x_local, grid=grid, scale=scale)
    return _smap(grid, mesh, fn, (SPEC_OM, SPEC_XCOL), SPEC_OM)(y, x)


def transpose_xlike(w, grid: Grid1p5D, mesh):
    fn = partial(transpose_xlike_local, grid=grid)
    return _smap(grid, mesh, fn, (SPEC_XCOL,), SPEC_XCOL)(w)


def transpose_omegalike(z, grid: Grid1p5D, mesh):
    fn = partial(transpose_omegalike_local, grid=grid)
    return _smap(grid, mesh, fn, (SPEC_OM,), SPEC_OM)(z)


# ---------------------------------------------------------------------------
# declared collective schedules + analysis manifest (repro.analysis)
# ---------------------------------------------------------------------------
# Every ring product above DECLARES its schedule: which axes it may bind,
# which collective kinds it may post, how many rotation rounds its ring
# scan runs, what may travel the wire, and — exactly — how many bytes one
# invocation moves (core.costmodel.comm_volume, the analytic side of the
# paper's W term).  The comm engine (rules CA301-CA306) verifies the
# declarations against the schedule it extracts from the traced jaxpr, so
# a refactor that adds a collective, drops a round, or widens the wire
# dtype fails `python -m repro.analysis` before it ever runs distributed.

def _contract(entry, flavor, *, kinds, masked=False, block_size=None,
              canonical=None):
    from ..core.costmodel import comm_volume
    from .contract import CommContract

    def vol(**kw):
        return comm_volume(flavor=flavor, masked=masked,
                           block_size=block_size, canonical=canonical, **kw)

    return CommContract(
        entry=entry, axes=AXES, kinds=kinds,
        rounds=lambda **kw: vol(**kw).rounds,
        wire=("operand", "mask") if masked else ("operand",),
        volume=lambda **kw: vol(**kw).total,
        volume_class=("ring+allgather" if flavor != "omega_xt"
                      else "ring+psum") + (" masked" if masked else ""))


COMM_CONTRACT = {
    "xtx_local": _contract(
        "comm.matmul1p5d.xtx_local", "xtx",
        kinds=("ppermute", "all_gather")),
    "omega_s_local": _contract(
        "comm.matmul1p5d.omega_s_local", "omega_s",
        kinds=("ppermute", "all_gather")),
    "y_x_local": _contract(
        "comm.matmul1p5d.y_x_local", "y_x",
        kinds=("ppermute", "all_gather")),
    "omega_xt_local": _contract(
        "comm.matmul1p5d.omega_xt_local", "omega_xt",
        kinds=("ppermute", "psum")),
}

#: the representative multi-device schedule every entry traces: P=8 with
#: both replication factors ON (c_x = c_omega = 2) so staggers, shifts
#: and team finishes all actually move bytes; p % P == 0 keeps every
#: layout constraint (grid.pad_p)
_TRACE_GRID = dict(n_devices=8, c_x=2, c_omega=2)
_TRACE_P, _TRACE_N = 32, 12


def _trace_setup():
    grid = Grid1p5D(**_TRACE_GRID)
    env = (("i", grid.n_i), ("j", grid.c_omega), ("k", grid.c_x))
    params = dict(p=_TRACE_P, n=_TRACE_N, dtype="float64", **_TRACE_GRID)
    return grid, env, params


def _entry_xtx():
    grid, env, _ = _trace_setup()
    x_loc = jnp.linspace(-1.0, 1.0, _TRACE_N * (_TRACE_P // grid.n_x),
                         dtype=jnp.float64).reshape(_TRACE_N, -1)
    return {"fn": lambda x: xtx_local(x, grid), "args": (x_loc,),
            "axis_env": env}


def _entry_omega_s():
    grid, env, _ = _trace_setup()
    blk_om, blk_x = _TRACE_P // grid.n_om, _TRACE_P // grid.n_x
    om = jnp.linspace(0.0, 1.0, blk_om * _TRACE_P,
                      dtype=jnp.float64).reshape(blk_om, _TRACE_P)
    s = jnp.linspace(0.0, 1.0, _TRACE_P * blk_x,
                     dtype=jnp.float64).reshape(_TRACE_P, blk_x)
    return {"fn": lambda a, b: omega_s_local(a, b, grid,
                                             canonical="omegalike"),
            "args": (om, s), "axis_env": env}


def _entry_y_x():
    grid, env, _ = _trace_setup()
    blk_om, blk_x = _TRACE_P // grid.n_om, _TRACE_P // grid.n_x
    y = jnp.ones((blk_om, _TRACE_N), jnp.float64)
    x_loc = jnp.ones((_TRACE_N, blk_x), jnp.float64)
    return {"fn": lambda a, b: y_x_local(a, b, grid), "args": (y, x_loc),
            "axis_env": env}


def _entry_omega_xt():
    grid, env, _ = _trace_setup()
    blk_om, blk_x = _TRACE_P // grid.n_om, _TRACE_P // grid.n_x
    om = jnp.ones((blk_om, _TRACE_P), jnp.float64)
    xt = jnp.ones((blk_x, _TRACE_N), jnp.float64)
    return {"fn": lambda a, b: omega_xt_local(a, b, grid), "args": (om, xt),
            "axis_env": env}


def _comm(fn_name):
    _, _, params = _trace_setup()
    return {"contract": COMM_CONTRACT[fn_name], "params": params}


_PATH = "src/repro/comm/matmul1p5d.py"
ANALYSIS_ENTRIES = [
    {"name": "comm.matmul1p5d.xtx_ring", "path": _PATH,
     "axis_names": AXES, "build": _entry_xtx,
     "comm": lambda: _comm("xtx_local")},
    {"name": "comm.matmul1p5d.omega_s_ring", "path": _PATH,
     "axis_names": AXES, "build": _entry_omega_s,
     "comm": lambda: _comm("omega_s_local")},
    {"name": "comm.matmul1p5d.y_x_ring", "path": _PATH,
     "axis_names": AXES, "build": _entry_y_x,
     "comm": lambda: _comm("y_x_local")},
    {"name": "comm.matmul1p5d.omega_xt_ring", "path": _PATH,
     "axis_names": AXES, "build": _entry_omega_xt,
     "comm": lambda: _comm("omega_xt_local")},
]
