"""Communication-avoiding distributed linear algebra (paper Algorithm 4).

``grid``        — 1.5D processor-grid index math and ppermute permutations.
``matmul1p5d``  — shard_map 1.5D matmuls (gather & reduce flavors) and the
                  replication-aware distributed transposes (Lemma 3.2).
``sparse1p5d``  — sparsity-aware twins of the Ω-side 1.5D products: the
                  iterate's block-occupancy mask travels with the Ω
                  operand so local tile products skip absent blocks.
``collectives`` — compressed gradient collectives (beyond-paper).
"""
from . import grid, matmul1p5d, sparse1p5d  # noqa: F401
