"""Communication-avoiding distributed linear algebra (paper Algorithm 4).

``grid``        — 1.5D processor-grid index math and ppermute permutations.
``matmul1p5d``  — shard_map 1.5D matmuls (gather & reduce flavors) and the
                  replication-aware distributed transposes (Lemma 3.2).
``collectives`` — compressed gradient collectives (beyond-paper).
"""
from . import grid, matmul1p5d  # noqa: F401
