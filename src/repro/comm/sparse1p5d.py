"""Sparsity-aware 1.5D Ω-side products (the distributed half of the matops
layer).

These are the masked entry points for the two Ω-side products of
``comm.matmul1p5d`` — W = Omega S (Cov, gather flavor) and Y = Omega X^T
(Obs, reduce flavor).  The ring schedules live in ``matmul1p5d`` itself
(one implementation, optionally masked); this module only packages the
Ω-iterate + occupancy-mask calling convention the solver drivers use:

  * gather flavor (Cov): the Omega row-block ROTATES around the x-ring, so
    its mask rotates with it (the same stagger/shift ppermutes applied to
    both).  Each round's local product routes through
    :func:`repro.core.matops.matmul` with the visiting block's mask.
  * reduce flavor (Obs): Omega is the FIXED operand; each round contracts
    a dynamic column-slice of it, gated by the matching block-column slice
    of the fixed mask.

The mask is tiny — (rows/bs, cols/bs) entries of the compact fixed
``core.matops.MASK_DTYPE`` (int8, one byte per block, independent of the
operand dtype — an f64 solve must not ship 8-byte masks around the ring)
— so rotating it adds a negligible fraction of the Ω traffic; in
exchange, the local dgemm of every round skips absent blocks once the
iterate is past the density crossover.  Both paths are exact (see
``core.matops``): the dispatch only takes the block-gather branch when
its capacity provably covers the
occupied blocks, so results match the dense rotation up to float
summation order.

All functions run INSIDE shard_map (shards in, shards out, collectives
inline), like their ``matmul1p5d`` counterparts.
"""
from __future__ import annotations

from ..core import matops
from . import matmul1p5d as mm
from .grid import Grid1p5D


def omega_s_local_sparse(omega_rows, omega_mask, s_panel, grid: Grid1p5D, *,
                         policy: matops.MatmulPolicy,
                         canonical: str = "omegalike"):
    """W = Omega @ S with block-sparse local products.

    ``omega_rows``: the rotating Omega row-block; ``omega_mask``: its
    (rows/bs, cols/bs) occupancy; ``s_panel``: the fixed (p, blk_x) column
    panel.  Same layouts/canonical conventions as
    ``matmul1p5d.omega_s_local``.
    """
    n_r = grid.n_om if canonical == "omegalike" else grid.n_x
    seq = mm.rot_gather_local(omega_rows, s_panel, grid, n_r=n_r,
                              canonical=canonical, ring="x",
                              r_mask=omega_mask, policy=policy)
    blk_r, blk_c = omega_rows.shape[0], s_panel.shape[1]
    return seq.reshape(n_r * blk_r, blk_c)              # W col-panel (p, blk_x)


def omega_xt_local_sparse(omega_rows, omega_mask, xt_loc, grid: Grid1p5D, *,
                          policy: matops.MatmulPolicy, scale=1.0):
    """Y = scale * Omega @ X^T with block-sparse local products.

    ``omega_rows``: fixed Omega-like (blk_om, p); ``omega_mask``: its
    (blk_om/bs, p/bs) occupancy; ``xt_loc``: rotating X^T row-block.
    Same schedule as ``matmul1p5d.omega_xt_local``.
    """
    return mm.omega_xt_local(omega_rows, xt_loc, grid, scale=scale,
                             omega_mask=omega_mask, policy=policy)


# ---------------------------------------------------------------------------
# declared collective schedules + analysis manifest (repro.analysis)
# ---------------------------------------------------------------------------
# The masked gather flavor ships the int8 occupancy mask around the ring
# with Omega (wire = operand + mask); the masked reduce flavor ships
# NOTHING extra — the mask is fixed and sliced locally.  Both facts are
# part of the declared volume (core.costmodel.comm_volume masked=...),
# so a refactor that starts rotating the reduce-flavor mask, or ships it
# at the operand dtype, fails the CA303/CA306 gates.

def _sparse_contract(entry, flavor, block_size):
    from ..core.costmodel import comm_volume
    from .contract import CommContract

    def vol(**kw):
        # block_size rides in via the entry params (kw)
        return comm_volume(flavor=flavor, masked=(flavor == "omega_s"), **kw)

    return CommContract(
        entry=entry, axes=mm.AXES,
        kinds=(("ppermute", "all_gather") if flavor == "omega_s"
               else ("ppermute", "psum")),
        rounds=lambda **kw: vol(**kw).rounds,
        wire=("operand", "mask"),
        volume=lambda **kw: vol(**kw).total,
        volume_class=("ring+allgather masked" if flavor == "omega_s"
                      else "ring+psum masked-local"))


_TRACE_BS = 4   # mask tile edge of the traced entries (divides blk_x = 8)

COMM_CONTRACT = {
    "omega_s_local_sparse": _sparse_contract(
        "comm.sparse1p5d.omega_s_local_sparse", "omega_s", _TRACE_BS),
    "omega_xt_local_sparse": _sparse_contract(
        "comm.sparse1p5d.omega_xt_local_sparse", "omega_xt", _TRACE_BS),
}


def _sparse_setup():
    import jax.numpy as jnp
    grid, env, params = mm._trace_setup()
    p, n = mm._TRACE_P, mm._TRACE_N
    policy = matops.MatmulPolicy(mode="on", block_size=_TRACE_BS,
                                 threshold=0.5)
    blk_om, blk_x = p // grid.n_om, p // grid.n_x
    om = jnp.eye(blk_om, p, dtype=jnp.float64)
    mask = matops.block_mask(om, _TRACE_BS)
    return grid, env, params, policy, (om, mask, blk_x, n, p)


def _entry_omega_s_sparse():
    import jax.numpy as jnp
    grid, env, _, policy, (om, mask, blk_x, n, p) = _sparse_setup()
    s = jnp.linspace(0.0, 1.0, p * blk_x,
                     dtype=jnp.float64).reshape(p, blk_x)
    return {"fn": lambda a, m, b: omega_s_local_sparse(
                a, m, b, grid, policy=policy, canonical="omegalike"),
            "args": (om, mask, s), "axis_env": env}


def _entry_omega_xt_sparse():
    import jax.numpy as jnp
    grid, env, _, policy, (om, mask, blk_x, n, p) = _sparse_setup()
    xt = jnp.ones((blk_x, n), jnp.float64)
    return {"fn": lambda a, m, b: omega_xt_local_sparse(
                a, m, b, grid, policy=policy),
            "args": (om, mask, xt), "axis_env": env}


def _comm(fn_name):
    _, _, params = mm._trace_setup()
    return {"contract": COMM_CONTRACT[fn_name],
            "params": dict(params, block_size=_TRACE_BS)}


_PATH = "src/repro/comm/sparse1p5d.py"
ANALYSIS_ENTRIES = [
    {"name": "comm.sparse1p5d.omega_s_ring_sparse", "path": _PATH,
     "axis_names": mm.AXES, "build": _entry_omega_s_sparse,
     "comm": lambda: _comm("omega_s_local_sparse")},
    {"name": "comm.sparse1p5d.omega_xt_ring_sparse", "path": _PATH,
     "axis_names": mm.AXES, "build": _entry_omega_xt_sparse,
     "comm": lambda: _comm("omega_xt_local_sparse")},
]
