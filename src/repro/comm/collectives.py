"""Compressed gradient collectives (beyond-paper distributed-optimization
trick) + error feedback.

The DP all-reduce of LM training moves 4 bytes/param/step at fp32.  Two
compressors cut that:

  * ``bf16``  — 2x: round-to-nearest bf16 before psum, fp32 after.
  * ``int8``  — 4x: per-tensor symmetric int8 quantization with ERROR
    FEEDBACK (the quantization residual is added back into the next
    step's gradient), which keeps SGD/Adam convergence unbiased in
    practice [Seide et al. 2014; Karimireddy et al. 2019].

Both run inside shard_map (psum over the data axes) or as pre/post hooks
around a pjit-inserted all-reduce.  ``compress_tree``/``decompress_tree``
are pure and jit-safe.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class CompressState(NamedTuple):
    """Error-feedback residual, same structure as the gradient tree."""
    residual: dict


def init_error_feedback(grads) -> CompressState:
    return CompressState(jax.tree.map(
        lambda g: jnp.zeros_like(g, jnp.float32), grads))


def _quant_int8(x):
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequant_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compress_tree(grads, state: CompressState | None, *, method: str):
    """Returns (payload_tree, new_state). payload leaves are
    (q, scale) for int8, bf16 arrays for bf16, identity otherwise."""
    if method == "none":
        return grads, state
    if method == "bf16":
        return jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads), state
    if method == "int8":
        if state is None:
            state = init_error_feedback(grads)
        corrected = jax.tree.map(
            lambda g, r: g.astype(jnp.float32) + r, grads, state.residual)
        qs = jax.tree.map(_quant_int8, corrected)
        payload = jax.tree.map(lambda t: t, qs,
                               is_leaf=lambda t: isinstance(t, tuple))
        new_res = jax.tree.map(
            lambda c, t: c - _dequant_int8(*t), corrected, payload,
            is_leaf=lambda t: isinstance(t, tuple))
        return payload, CompressState(new_res)
    raise ValueError(method)


def decompress_tree(payload, *, method: str):
    if method == "none":
        return payload
    if method == "bf16":
        return jax.tree.map(lambda g: g.astype(jnp.float32), payload)
    if method == "int8":
        return jax.tree.map(lambda t: _dequant_int8(*t), payload,
                            is_leaf=lambda t: isinstance(t, tuple))
    raise ValueError(method)


def compressed_psum(grads, axis, state=None, *, method: str = "bf16"):
    """All-reduce a gradient tree over ``axis`` (inside shard_map) with
    the chosen wire format. int8 payloads psum the dequantized values but
    ship int8 over the wire in the ppermute-based ring below."""
    payload, state = compress_tree(grads, state, method=method)
    if method == "int8":
        summed = jax.tree.map(
            lambda t: jax.lax.psum(_dequant_int8(*t), axis), payload,
            is_leaf=lambda t: isinstance(t, tuple))
    else:
        summed = jax.tree.map(lambda g: jax.lax.psum(g, axis), payload)
    return decompress_tree(
        summed, method="none" if method == "int8" else method), state


def ring_allreduce_int8(x, axis: str):
    """Explicit bandwidth-optimal ring all-reduce that ships int8 chunks
    (reduce-scatter + all-gather over ppermute), for when the wire format
    must really be 1 byte/word. x: any float array; runs inside shard_map."""
    from .compat import axis_size
    n = axis_size(axis)
    if n == 1:
        return x
    shape = x.shape
    flat = x.reshape(-1)
    pad = (-flat.size) % n
    flat = jnp.pad(flat, (0, pad)).reshape(n, -1)
    idx = jax.lax.axis_index(axis)
    perm = [(i, (i + 1) % n) for i in range(n)]

    # reduce-scatter: after n-1 rounds, chunk (idx+1) holds the full sum
    def rs_body(i, carry):
        acc, cur = carry
        send = jnp.take(cur, (idx - i) % n, axis=0)
        q, s = _quant_int8(send)
        q = jax.lax.ppermute(q, axis, perm)
        s = jax.lax.ppermute(s, axis, perm)
        recv = _dequant_int8(q, s)
        tgt = (idx - i - 1) % n
        cur = cur.at[tgt].add(recv)
        return acc, cur

    _, reduced = jax.lax.fori_loop(0, n - 1, rs_body, (0, flat))
    mine = jnp.take(reduced, (idx + 1) % n, axis=0)

    # all-gather the reduced chunks (int8 shipping matters on the
    # reduce-scatter phase — the gather moves final values once)
    gathered = jax.lax.all_gather(mine, axis)        # row r = chunk (r+1)%n
    buf = jnp.roll(gathered, 1, axis=0)              # row k = chunk k
    out = buf.reshape(-1)
    out = out[:x.size] if pad else out
    return out.reshape(shape)


# ---------------------------------------------------------------------------
# declared collective schedules + analysis manifest (repro.analysis)
# ---------------------------------------------------------------------------
# The whole point of this module is the WIRE FORMAT, so the contracts pin
# it: the explicit ring may ship int8 chunks and operand-dtype scales /
# reduced chunks (never a full-width payload per round beyond those), and
# the bf16 psum may ship bfloat16 only — an f64 payload through either
# path trips CA306 and the exact CA303 byte budget.  The int8 ring's
# reduce-scatter phase is (extent-1) scan rounds of two ppermutes over a
# single full-ring table, declared below and traced under axis_env.

_RING_AXIS, _RING_EXTENT = "dp", 4
_RING_SIZE = 10                 # deliberately not divisible: pads to 12
_PSUM_SIZE = 24


def _ring_contract():
    from ..core.costmodel import ring_allreduce_int8_volume
    from .contract import CommContract
    return CommContract(
        entry="comm.collectives.ring_allreduce_int8",
        axes=(_RING_AXIS,), kinds=("ppermute", "all_gather"),
        rounds=lambda size, extent: extent - 1,
        wire=("int8", "operand"),
        volume=lambda size, extent: ring_allreduce_int8_volume(size, extent),
        volume_class="int8 reduce-scatter ring + f64 allgather")


def _bf16_psum_contract():
    from ..core.costmodel import compressed_psum_volume
    from .contract import CommContract
    return CommContract(
        entry="comm.collectives.compressed_psum[bf16]",
        axes=(_RING_AXIS,), kinds=("psum",),
        wire=("bfloat16",),
        volume=lambda size, extent: compressed_psum_volume(
            size, extent, method="bf16"),
        volume_class="bf16 all-reduce")


COMM_CONTRACT = {
    "ring_allreduce_int8": _ring_contract(),
    "compressed_psum_bf16": _bf16_psum_contract(),
}


def _entry_ring_int8():
    x = jnp.linspace(-3.0, 3.0, _RING_SIZE, dtype=jnp.float64)
    return {"fn": lambda a: ring_allreduce_int8(a, _RING_AXIS),
            "args": (x,), "axis_env": ((_RING_AXIS, _RING_EXTENT),)}


def _entry_bf16_psum():
    g = {"grad": jnp.linspace(0.0, 1.0, _PSUM_SIZE,
                              dtype=jnp.float64).reshape(6, 4)}
    return {"fn": lambda t: compressed_psum(t, _RING_AXIS,
                                            method="bf16")[0],
            "args": (g,), "axis_env": ((_RING_AXIS, _RING_EXTENT),)}


_PATH = "src/repro/comm/collectives.py"
ANALYSIS_ENTRIES = [
    {"name": "comm.collectives.ring_allreduce_int8", "path": _PATH,
     "axis_names": (_RING_AXIS,), "build": _entry_ring_int8,
     "comm": lambda: {"contract": COMM_CONTRACT["ring_allreduce_int8"],
                      "params": {"size": _RING_SIZE,
                                 "extent": _RING_EXTENT}},
     # the quantizer's f64 -> int8/f32 casts ARE the feature here; the
     # wire policy (CA306) and exact byte budget (CA303) take over from
     # the blanket no-narrowing rule
     "skip": ("CA201",)},
    {"name": "comm.collectives.compressed_psum_bf16", "path": _PATH,
     "axis_names": (_RING_AXIS,), "build": _entry_bf16_psum,
     "comm": lambda: {"contract": COMM_CONTRACT["compressed_psum_bf16"],
                      "params": {"size": _PSUM_SIZE,
                                 "extent": _RING_EXTENT}},
     # f64 -> bf16 on the wire is this path's declared compression
     "skip": ("CA201",)},
]
