"""Declared collective-schedule contracts (``COMM_CONTRACT``).

The paper's headline claim is *communication avoidance*: the 1.5D
schedules move provably fewer words than a 2D layout, and the whole
point of the replication factor c is the words-vs-memory trade.  A
refactor that silently adds an all-reduce, drops a ring round, or widens
a wire dtype destroys that property without failing a single numeric
test — so every module that posts collectives DECLARES its schedule,
and the ``repro.analysis`` comm engine (rules CA301–CA306) verifies the
declaration against the schedule actually traced out of the jaxpr.

A module exports ``COMM_CONTRACT``, a dict mapping the entry-point
function name to a :class:`CommContract`.  The module's
``ANALYSIS_ENTRIES`` build specs reference these contracts (together
with the shape parameters the contract's callables are evaluated at),
so the declaration lives WITH the schedule it describes and the
analysis package only ever *verifies*, never infers.

Conventions (shared with ``core.costmodel.collective_wire_bytes``):
bytes-on-wire are counted per processor along the critical path, the
paper's W measure — a ppermute ships its payload once (zero if the
permutation is the identity), a ring all-gather over extent E ships
(E-1) input shards, a bandwidth-optimal all-reduce ships 2.(E-1)/E
payloads, an all-to-all / reduce-scatter ships (E-1)/E.  Counts are
exact :class:`fractions.Fraction`s so the static-vs-analytic
cross-check in CA303 is an equality, not a tolerance.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable


@dataclass(frozen=True)
class CommContract:
    """One entry point's declared collective schedule.

    Callable fields receive the entry's ``params`` dict (spread as
    keyword arguments), so a contract can be exact at any traced shape.
    """

    #: dotted name of the entry point this contract binds to (display)
    entry: str
    #: mesh axes the schedule may bind; None = inherit the manifest
    #: entry's declared ``axis_names``
    axes: tuple[str, ...] | None = None
    #: collective primitive names the schedule may post (None = any)
    kinds: tuple[str, ...] | None = None
    #: expected ring length of every ppermute-bearing scan, as an int or
    #: ``params -> int`` (None = no round contract)
    rounds: int | Callable[..., int] | None = None
    #: dtypes allowed on the wire.  Literal dtype names plus two
    #: wildcards: "operand" (any dtype of the entry's operands — the
    #: solve dtype) and "mask" (``core.matops.MASK_DTYPE``, int8).
    #: None = no wire policy (CA306 skipped).
    wire: tuple[str, ...] | None = None
    #: expected total bytes-on-wire per invocation, as ``params ->
    #: Fraction|int`` (None = no volume contract, CA303 skipped)
    volume: Callable[..., object] | None = None
    #: human label of the schedule family, e.g. "ring+allgather"
    volume_class: str = ""
    #: free-form knobs (e.g. require_full_ring for CA302)
    extra: dict = field(default_factory=dict)

    def expected_rounds(self, params: dict) -> int | None:
        if self.rounds is None or isinstance(self.rounds, int):
            return self.rounds
        return int(self.rounds(**params))

    def expected_volume(self, params: dict):
        if self.volume is None:
            return None
        return self.volume(**params)
