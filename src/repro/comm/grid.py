"""1.5D processor-grid logic for the paper's Algorithm 4.

The machine is a flat ring of P devices organized as a 3-axis mesh

    ("i", "j", "k")  with sizes  (P / (c_x * c_omega), c_omega, c_x)

which simultaneously expresses BOTH logical grids of the paper:

  * X-like arrays (replication factor c_x; X, X^T, S, W in Cov):
      partitioned into n_x = P/c_x blocks indexed by t = i*c_omega + j,
      replicated along "k".   "X-team" t = the c_x devices (i, j, :).
  * Omega-like arrays (replication factor c_omega; Omega, Y, Z, G in Obs):
      partitioned into n_om = P/c_omega blocks indexed by u = i*c_x + k,
      replicated along "j".   "Omega-team" u = the c_omega devices (i, :, k).

Ring orderings: Algorithm 4 rotates the R operand around a ring whose teams
must be contiguous.  Two flat orderings of the same devices are used:

  * x-major flat:     f  = (i*c_omega + j)*c_x + k     (row-major (i,j,k))
    -> X-teams contiguous; used when the FIXED operand is X-like (Cov).
  * omega-major flat: f' = (i*c_x + k)*c_omega + j
    -> Omega-teams contiguous; used when the fixed operand is Omega-like (Obs).

``lax.ppermute`` over the axis tuple ("i","j","k") interprets indices in
row-major order == x-major flat; all permutations below are emitted in that
numbering (omega-major rings are converted).

The initial "shift by delta" of Algorithm 4 (staggering, so team members
hold distinct R blocks) is one arbitrary ppermute: STAGGER.  At round r of
the rotation, the device at ring-flat position f holds R block
(f + r*shift) mod n_R, where shift = c_F (the fixed operand's replication).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import numpy as np

AXES = ("i", "j", "k")


@dataclass(frozen=True)
class Grid1p5D:
    n_devices: int          # P
    c_x: int                # replication factor of X-like arrays
    c_omega: int            # replication factor of Omega-like arrays

    def __post_init__(self):
        P, cx, co = self.n_devices, self.c_x, self.c_omega
        if cx < 1 or co < 1 or cx * co > P:
            raise ValueError(f"need 1 <= c_x*c_omega <= P, got {cx}*{co} > {P}")
        if P % (cx * co) != 0:
            raise ValueError(f"c_x*c_omega={cx*co} must divide P={P}")

    # -- sizes ---------------------------------------------------------
    @property
    def n_i(self) -> int:
        return self.n_devices // (self.c_x * self.c_omega)

    @property
    def n_x(self) -> int:
        """Number of X-like blocks (P / c_x)."""
        return self.n_devices // self.c_x

    @property
    def n_om(self) -> int:
        """Number of Omega-like blocks (P / c_omega)."""
        return self.n_devices // self.c_omega

    @property
    def rounds(self) -> int:
        """Rotation rounds of Algorithm 4: P / (c_x * c_omega)."""
        return self.n_devices // (self.c_x * self.c_omega)

    def mesh_shape(self) -> tuple[int, int, int]:
        return (self.n_i, self.c_omega, self.c_x)

    def make_mesh(self, devices=None) -> jax.sharding.Mesh:
        from .compat import make_mesh
        if devices is None:
            return make_mesh(self.mesh_shape(), AXES)
        return make_mesh(self.mesh_shape(), AXES,
                         devices=np.asarray(devices).reshape(-1))

    # -- flat-index conversions (all return x-major flat rank) ----------
    def coords_to_flat(self, i: int, j: int, k: int) -> int:
        return (i * self.c_omega + j) * self.c_x + k

    def flat_to_coords(self, f: int) -> tuple[int, int, int]:
        k = f % self.c_x
        t = f // self.c_x
        return t // self.c_omega, t % self.c_omega, k

    def omajor_to_flat(self, fo: int) -> int:
        """omega-major ring position -> x-major flat rank."""
        j = fo % self.c_omega
        u = fo // self.c_omega
        i, k = u // self.c_x, u % self.c_x
        return self.coords_to_flat(i, j, k)

    def flat_to_omajor(self, f: int) -> int:
        i, j, k = self.flat_to_coords(f)
        return (i * self.c_x + k) * self.c_omega + j

    # -- permutations (x-major flat (src, dst) pairs for lax.ppermute) --
    def stagger_perm(self, canonical: str, ring: str, n_r: int) -> list[tuple[int, int]]:
        """Initial 'shift by delta' (Alg. 4 lines 2-3): move R from its
        canonical replicated layout to the staggered rotation layout where
        ring position f holds block (f mod n_r).

        canonical: layout R is stored in — "xlike" (block t=i*c_om+j,
        replica index k) or "omegalike" (block u=i*c_x+k, replica index j).
        ring: "x" or "omega" — which flat ordering the rotation uses.
        """
        perm = []
        for f in range(self.n_devices):
            i, j, k = self.flat_to_coords(f)
            if canonical == "xlike":
                block, rep = i * self.c_omega + j, k
            elif canonical == "omegalike":
                block, rep = i * self.c_x + k, j
            else:
                raise ValueError(canonical)
            # replica `rep` of block `block` serves ring slot block + rep*n_r
            dst_ring = block + rep * n_r
            dst = dst_ring if ring == "x" else self.omajor_to_flat(dst_ring)
            perm.append((f, dst))
        self._check_perm(perm)
        return perm

    def shift_perm(self, ring: str, shift: int) -> list[tuple[int, int]]:
        """One rotation step: ring position f receives from f+shift
        (equivalently: src s sends to (s - shift) mod P in ring order)."""
        P = self.n_devices
        perm = []
        for s_ring in range(P):
            d_ring = (s_ring - shift) % P
            if ring == "x":
                perm.append((s_ring, d_ring))
            else:
                perm.append((self.omajor_to_flat(s_ring), self.omajor_to_flat(d_ring)))
        self._check_perm(perm)
        return perm

    @staticmethod
    def _check_perm(perm):
        srcs = {s for s, _ in perm}
        dsts = {d for _, d in perm}
        assert len(srcs) == len(perm) and len(dsts) == len(perm), "not a permutation"

    # -- padding helper --------------------------------------------------
    def pad_p(self, p: int) -> int:
        """Smallest p' >= p divisible by P.

        p % P == 0 guarantees every layout constraint at once: n_x | p,
        n_om | p, and the per-block sub-slicing of the replication-aware
        transposes (blk_x % c_x == 0, blk_om % c_omega == 0)."""
        m = self.n_devices
        return ((p + m - 1) // m) * m


def best_grid(P: int, p: int, n: int, d: float, *, variant: str,
              machine=None, s_iters: int = 30, t_ls: float = 10.0) -> Grid1p5D:
    """Pick (c_x, c_omega) for a problem with the paper's cost model
    (core.costmodel); Cov additionally requires c_x**2 | P (the X^T X
    rotation has c_R = c_F = c_x)."""
    from ..core.costmodel import Machine, ProblemShape, cov_costs, obs_costs

    m = machine or Machine()
    shape = ProblemShape(p=p, n=n, d=d, s=s_iters, t=t_ls)
    best, best_t = None, float("inf")
    c = 1
    cands = []
    while c <= P:
        cands.append(c)
        c *= 2
    for cx in cands:
        for co in cands:
            if cx * co > P or P % (cx * co):
                continue
            if variant == "cov" and (P % (cx * cx) or co != cx):
                # driver keeps Omega in X-like layout between iterations
                continue
            fn = cov_costs if variant == "cov" else obs_costs
            cb = fn(shape, P, cx, co, m)
            if cb.mem_words * m.word_bytes > m.hbm_bytes * P:
                continue
            if cb.total < best_t:
                best, best_t = (cx, co), cb.total
    if best is None:
        raise ValueError(f"no feasible grid for P={P}, p={p}")
    return Grid1p5D(P, best[0], best[1])
