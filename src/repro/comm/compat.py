"""jax version-compatibility shims.

The repo is written against the current jax API (``jax.shard_map``,
``jax.set_mesh``, ``jax.make_mesh(..., axis_types=...)``).  Older jax
releases (<= 0.4.x, the version baked into some CPU test containers) expose
the same functionality under different names:

  * ``jax.shard_map(check_vma=...)``  -> ``jax.experimental.shard_map``'s
    ``shard_map(check_rep=...)``
  * ``with jax.set_mesh(mesh): ...``  -> ``with mesh: ...`` (Mesh is itself
    a context manager)
  * ``jax.make_mesh(shape, axes, axis_types=...)`` -> same without
    ``axis_types``

Everything in-repo should import ``shard_map`` / ``use_mesh`` / ``make_mesh``
from here instead of touching ``jax.*`` directly so a single module absorbs
the API skew.
"""
from __future__ import annotations

import jax

__all__ = ["shard_map", "use_mesh", "make_mesh", "axis_size",
           "get_abstract_mesh", "psum", "set_collective_watcher"]


if hasattr(jax, "shard_map"):

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)

else:
    from jax.experimental.shard_map import shard_map as _shard_map_legacy

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False):
        return _shard_map_legacy(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_rep=check_vma)


#: collective-wrapper watcher (``repro.obs.commwatch``): when set, every
#: collective POSTED through a compat wrapper is announced with its
#: (prim, axis, operand).  Posting happens at trace time — a cached
#: compiled program re-executes without re-posting — so the watcher
#: counts program construction, while runtime execution counts come from
#: the jaxpr walk on the dispatch hook.
_COLLECTIVE_WATCHER = None


def set_collective_watcher(watcher):
    """Install ``watcher`` (or None); returns the previous watcher."""
    global _COLLECTIVE_WATCHER
    prev = _COLLECTIVE_WATCHER
    _COLLECTIVE_WATCHER = watcher
    return prev


def psum(x, axis_name):
    """``lax.psum`` re-export: the blessed spelling outside the collective
    layer (``comm/``, ``core/distributed.py``), so every cross-device
    reduction in model/data code is greppable here and covered by the
    same skew-absorbing module as ``shard_map``."""
    from jax import lax
    if _COLLECTIVE_WATCHER is not None:
        _COLLECTIVE_WATCHER.on_collective("psum", axis_name, x)
    return lax.psum(x, axis_name)


def axis_size(name):
    """``lax.axis_size`` fallback: psum of a literal 1 resolves to the axis
    size at trace time on older jax."""
    from jax import lax
    if hasattr(lax, "axis_size"):
        return lax.axis_size(name)
    return lax.psum(1, name)


def use_mesh(mesh):
    """Context manager installing ``mesh`` as the ambient mesh."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh  # legacy Mesh objects are context managers themselves


def get_abstract_mesh():
    """The ambient mesh installed by :func:`use_mesh` (``.empty`` when none).

    New jax exposes it as ``jax.sharding.get_abstract_mesh()``; on older
    releases the ``with mesh:`` context lives in thread resources."""
    if hasattr(jax.sharding, "get_abstract_mesh"):
        return jax.sharding.get_abstract_mesh()
    from jax._src import mesh as mesh_lib
    return mesh_lib.thread_resources.env.physical_mesh


def make_mesh(axis_shapes, axis_names, devices=None):
    """``jax.make_mesh`` with explicit Auto axis types where supported.

    With an explicit ``devices`` sequence the mesh is built directly from
    ``jax.sharding.Mesh`` in the GIVEN order — ``jax.make_mesh`` may
    permute explicit devices for locality, which would silently scramble
    the 1.5D ring's flat-rank numbering (``comm.grid``)."""
    if devices is not None:
        import numpy as np
        devs = np.asarray(devices).reshape(tuple(axis_shapes))
        return jax.sharding.Mesh(devs, tuple(axis_names))
    if hasattr(jax.sharding, "AxisType"):
        kwargs = {"axis_types": (jax.sharding.AxisType.Auto,) * len(axis_names)}
    else:
        kwargs = {}
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kwargs)
