"""Counters, gauges and exponential-bucket histograms with Prometheus
and JSON exporters.

Everything here is host-side bookkeeping over python floats — metrics
are fed at chunk/solve boundaries, never from inside a traced program.

Histograms use exponential buckets (upper bounds ``start * growth**i``)
so p50/p95/p99 latency quantiles stay meaningful across six decades of
solve time with O(64) cells; :meth:`Histogram.quantile` interpolates
linearly inside the winning bucket, so on known samples it matches
``numpy.quantile`` to within one bucket's relative width (= ``growth``).

Flop/byte work counters are fed from :mod:`repro.core.costmodel`'s
analytic formulas (paper Lemma 3.4) evaluated at the *observed* problem
shape, iteration count and density — see :func:`record_solve_cost`.
"""
from __future__ import annotations

import json
import threading
from bisect import bisect_left
from dataclasses import dataclass, field


def _label_key(labels: dict) -> tuple:
    return tuple(sorted(labels.items()))


def _label_str(labels: tuple) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in labels)
    return "{" + inner + "}"


@dataclass
class Counter:
    """Monotone accumulator (events, flops, bytes)."""
    name: str
    labels: tuple = ()
    value: float = 0.0

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError(f"counter {self.name} cannot decrease "
                             f"(inc by {amount})")
        self.value += amount


@dataclass
class Gauge:
    """Last-write-wins instantaneous value (queue depth, occupancy)."""
    name: str
    labels: tuple = ()
    value: float = 0.0

    def set(self, value: float) -> None:
        self.value = float(value)


DEFAULT_START = 1e-6        # 1 us
DEFAULT_GROWTH = 2 ** 0.25  # 4 buckets per octave, ~19% relative error
DEFAULT_BUCKETS = 96        # covers 1 us .. ~16e3 s


@dataclass
class Histogram:
    """Exponential-bucket histogram with interpolated quantiles.

    Bucket ``i`` holds samples in ``(bounds[i-1], bounds[i]]`` with
    ``bounds[i] = start * growth**i``; one underflow cell catches
    ``v <= start`` and one overflow cell catches ``v > bounds[-1]``.
    """
    name: str
    labels: tuple = ()
    start: float = DEFAULT_START
    growth: float = DEFAULT_GROWTH
    n_buckets: int = DEFAULT_BUCKETS
    counts: list = field(default_factory=list)
    total: int = 0
    sum: float = 0.0
    min: float = float("inf")
    max: float = float("-inf")

    def __post_init__(self):
        if self.growth <= 1.0:
            raise ValueError(f"growth must exceed 1, got {self.growth}")
        self.bounds = [self.start * self.growth ** i
                       for i in range(self.n_buckets)]
        if not self.counts:
            self.counts = [0] * (self.n_buckets + 1)

    def observe(self, value: float) -> None:
        v = float(value)
        i = bisect_left(self.bounds, v)
        self.counts[i] += 1
        self.total += 1
        self.sum += v
        self.min = min(self.min, v)
        self.max = max(self.max, v)

    def quantile(self, q: float) -> float:
        """Linear interpolation inside the bucket holding rank
        ``q * (total - 1)`` (the same rank convention as
        ``numpy.quantile``'s default)."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"q must be in [0, 1], got {q}")
        if self.total == 0:
            return float("nan")
        rank = q * (self.total - 1)
        seen = 0
        for i, c in enumerate(self.counts):
            if c and seen + c > rank:
                lo = self.bounds[i - 1] if i > 0 else min(self.min,
                                                          self.bounds[0])
                hi = self.bounds[i] if i < self.n_buckets else self.max
                lo = max(lo, self.min)
                hi = min(hi, self.max)
                if c == 1:
                    return (lo + hi) / 2
                # position of the target rank inside this bucket's span
                frac = (rank - seen) / (c - 1) if c > 1 else 0.0
                return lo + frac * (hi - lo)
            seen += c
        return self.max

    def percentiles(self) -> dict:
        return {"p50": self.quantile(0.50), "p95": self.quantile(0.95),
                "p99": self.quantile(0.99)}

    def to_json(self) -> dict:
        out = {"count": self.total, "sum": self.sum}
        if self.total:
            out.update(min=self.min, max=self.max, **self.percentiles())
        return out


class MetricsRegistry:
    """Get-or-create registry keyed on (name, sorted labels)."""

    def __init__(self):
        self._metrics: dict = {}
        self._lock = threading.Lock()

    def _get(self, cls, name: str, labels: dict, **kwargs):
        key = (name, _label_key(labels))
        with self._lock:
            m = self._metrics.get(key)
            if m is None:
                m = cls(name=name, labels=key[1], **kwargs)
                self._metrics[key] = m
            elif not isinstance(m, cls):
                raise TypeError(
                    f"metric {name}{dict(key[1])} already registered as "
                    f"{type(m).__name__}, requested {cls.__name__}")
            return m

    def counter(self, name: str, **labels) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str, *, start: float = DEFAULT_START,
                  growth: float = DEFAULT_GROWTH,
                  n_buckets: int = DEFAULT_BUCKETS, **labels) -> Histogram:
        return self._get(Histogram, name, labels, start=start,
                         growth=growth, n_buckets=n_buckets)

    def clear(self) -> None:
        with self._lock:
            self._metrics.clear()

    def __len__(self) -> int:
        return len(self._metrics)

    # -- export ----------------------------------------------------------
    def snapshot(self) -> dict:
        """JSON-able snapshot: ``{"name{labels}": value-or-summary}``."""
        out = {}
        with self._lock:
            items = sorted(self._metrics.items())
        for (name, labels), m in items:
            key = name + _label_str(labels)
            if isinstance(m, Histogram):
                out[key] = m.to_json()
            else:
                out[key] = m.value
        return out

    def export_json(self, path) -> None:
        with open(path, "w", encoding="utf-8") as f:
            json.dump(self.snapshot(), f, indent=2, sort_keys=True)

    def to_prometheus(self) -> str:
        """Prometheus text exposition (gauges for histogram quantiles —
        the pull-time summary form, not raw cumulative buckets)."""
        lines = []
        with self._lock:
            items = sorted(self._metrics.items())
        for (name, labels), m in items:
            if isinstance(m, Counter):
                lines.append(f"# TYPE {name} counter")
                lines.append(f"{name}{_label_str(labels)} {m.value:g}")
            elif isinstance(m, Gauge):
                lines.append(f"# TYPE {name} gauge")
                lines.append(f"{name}{_label_str(labels)} {m.value:g}")
            else:
                lines.append(f"# TYPE {name} summary")
                base = dict(labels)
                for q, v in (("0.5", m.quantile(.5)), ("0.95", m.quantile(.95)),
                             ("0.99", m.quantile(.99))):
                    if m.total:
                        ql = _label_str(_label_key({**base, "quantile": q}))
                        lines.append(f"{name}{ql} {v:g}")
                lines.append(f"{name}_sum{_label_str(labels)} {m.sum:g}")
                lines.append(f"{name}_count{_label_str(labels)} {m.total}")
        return "\n".join(lines) + "\n"


# ---------------------------------------------------------------------------
# analytic work accounting (costmodel formulas at observed shapes)
# ---------------------------------------------------------------------------

def record_solve_cost(registry: MetricsRegistry, *, variant: str,
                      p: int, n: int | None, iters: int, ls_total: int,
                      density: float = 1.0, n_devices: int = 1,
                      c_x: int = 1, c_omega: int = 1,
                      wall_s: float | None = None) -> dict:
    """Feed the flop/word counters from the paper's Lemma 3.4 cost model
    evaluated at the OBSERVED shape: ``s`` = outer iterations, ``t`` =
    mean line-search trials per iteration, ``d`` = observed nnz/row.

    Returns the computed ``{"flops", "words"}`` so callers can attach
    them to telemetry without re-deriving."""
    from ..core import costmodel

    s = max(int(iters), 1)
    t = max(float(ls_total) / s, 1.0)
    # n is unknown when the caller handed a precomputed Gram (fit_cov
    # without n_samples) — the solve then performs no Gram-formation
    # flops, so the 2np^2 term is correctly zero
    shape = costmodel.ProblemShape(p=p, n=n if n is not None else 0,
                                   d=max(density * p, 1.0), s=s, t=t)
    fn = costmodel.cov_costs if variant == "cov" else costmodel.obs_costs
    cb = fn(shape, max(n_devices, 1), c_x, c_omega, costmodel.EDISON)
    registry.counter("repro_solve_flops_total", variant=variant).inc(cb.flops)
    registry.counter("repro_solve_comm_words_total",
                     variant=variant).inc(cb.words)
    registry.counter("repro_solves_total", variant=variant).inc()
    registry.counter("repro_solve_iters_total", variant=variant).inc(iters)
    registry.counter("repro_solve_ls_total", variant=variant).inc(ls_total)
    if wall_s is not None:
        registry.histogram("repro_solve_wall_seconds",
                           variant=variant).observe(wall_s)
    return {"flops": cb.flops, "words": cb.words}


# ---------------------------------------------------------------------------
# process-global registry (created lazily, like the tracer)
# ---------------------------------------------------------------------------

_REGISTRY: MetricsRegistry | None = None


def get_registry() -> MetricsRegistry:
    global _REGISTRY
    if _REGISTRY is None:
        _REGISTRY = MetricsRegistry()
    return _REGISTRY
