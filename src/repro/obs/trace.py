"""Host-side span/event tracer with ring buffer and trace exporters.

Spans mark host-boundary work (a solve dispatch, a compact-schedule
segment, a Gram chunk update, a serve request); instant events mark
points in time.  Everything is recorded on the host with
``time.perf_counter`` — the tracer is never visible to jax tracing, so
turning it on cannot change a compiled program or its numerics.

Two verbosity levels nest the taxonomy:

  * ``"summary"`` — one span per coarse unit of work (solve, path
    point, request).  Cheap enough to leave on in production; the
    overhead gate in ``benchmarks/obs_overhead.py`` holds it under 2%.
  * ``"trace"``  — adds fine-grained spans (compile vs execute split,
    per-segment chunk launches, per-chunk Gram updates).

``mode="off"`` short-circuits every call through a shared no-op span —
no allocation, no clock read.

Exporters: :meth:`Tracer.export_jsonl` (one JSON object per line) and
:meth:`Tracer.export_chrome` (Perfetto / ``chrome://tracing``
``trace_event`` JSON); :func:`load_chrome` and :func:`load_jsonl` read
both back for round-trip tests and the ``repro-obs`` CLI.
"""
from __future__ import annotations

import json
import threading
import time
from collections import deque
from contextlib import contextmanager
from dataclasses import dataclass, field

MODES = ("off", "summary", "trace")
_LEVEL_RANK = {"off": 0, "summary": 1, "trace": 2}

#: ring-buffer capacity: old spans fall off rather than growing without
#: bound in an always-on service
RING_CAPACITY = 4096


@dataclass
class Span:
    """One recorded span (``phase="span"``) or instant event
    (``phase="instant"``).  Times are ``time.perf_counter`` seconds."""
    name: str
    cat: str = "solver"
    t_start: float = 0.0
    duration: float = 0.0
    level: str = "summary"
    phase: str = "span"
    args: dict = field(default_factory=dict)

    def note(self, **attrs) -> "Span":
        """Attach attributes discovered while the span is open (iteration
        counts, convergence flags, ...)."""
        self.args.update(attrs)
        return self

    def to_json(self) -> dict:
        return {
            "name": self.name, "cat": self.cat, "ph": self.phase,
            "t_start": self.t_start, "duration": self.duration,
            "level": self.level, "args": dict(self.args),
        }

    def to_chrome(self, pid: int = 0, tid: int = 0) -> dict:
        """Perfetto ``trace_event``: complete event ("X") for spans,
        instant event ("i") for point events; timestamps in us."""
        ev = {
            "name": self.name, "cat": self.cat,
            "ts": self.t_start * 1e6, "pid": pid, "tid": tid,
            "args": {**self.args, "level": self.level},
        }
        if self.phase == "span":
            ev["ph"] = "X"
            ev["dur"] = self.duration * 1e6
        else:
            ev["ph"] = "i"
            ev["s"] = "t"
        return ev


class _NullSpan:
    """Shared do-nothing span for disabled levels: supports the same
    ``with``/``note`` surface with no allocation per call."""
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def note(self, **attrs):
        return self


_NULL_SPAN = _NullSpan()


class _LiveSpan:
    """Context manager recording one span into the tracer's ring on
    exit (completion order; Chrome sorts by ``ts`` on import)."""
    __slots__ = ("_tracer", "_span")

    def __init__(self, tracer: "Tracer", span: Span):
        self._tracer = tracer
        self._span = span

    def __enter__(self) -> Span:
        self._span.t_start = time.perf_counter()
        return self._span

    def __exit__(self, *exc):
        self._span.duration = time.perf_counter() - self._span.t_start
        self._tracer._record(self._span)
        return False

    def note(self, **attrs):
        self._span.note(**attrs)
        return self


class Tracer:
    """Mode-gated span recorder over a bounded ring buffer."""

    def __init__(self, mode: str = "off", capacity: int = RING_CAPACITY):
        self._mode = "off"
        self.set_mode(mode)
        self._events: deque = deque(maxlen=capacity)
        self._lock = threading.Lock()

    # -- mode ------------------------------------------------------------
    @property
    def mode(self) -> str:
        return self._mode

    def set_mode(self, mode: str) -> None:
        if mode not in MODES:
            raise ValueError(f"obs mode must be one of {MODES}, got {mode!r}")
        self._mode = mode

    def enabled(self, level: str = "summary") -> bool:
        return _LEVEL_RANK[self._mode] >= _LEVEL_RANK.get(level, 99)

    @contextmanager
    def scoped(self, mode: str):
        """Temporarily run the tracer at ``mode`` (how a backend applies
        ``SolverConfig.obs`` for the duration of one solve)."""
        prev = self._mode
        self.set_mode(mode)
        try:
            yield self
        finally:
            self._mode = prev

    # -- recording -------------------------------------------------------
    def span(self, name: str, *, cat: str = "solver",
             level: str = "summary", **attrs):
        """``with tracer.span("fit", p=64) as s: ... s.note(iters=12)``"""
        if not self.enabled(level):
            return _NULL_SPAN
        return _LiveSpan(self, Span(name=name, cat=cat, level=level,
                                    args=dict(attrs)))

    def event(self, name: str, *, cat: str = "solver",
              level: str = "summary", **attrs) -> None:
        if not self.enabled(level):
            return
        self._record(Span(name=name, cat=cat, t_start=time.perf_counter(),
                          duration=0.0, level=level, phase="instant",
                          args=dict(attrs)))

    def _record(self, span: Span) -> None:
        with self._lock:
            self._events.append(span)

    # -- inspection ------------------------------------------------------
    def snapshot(self) -> tuple:
        """Point-in-time copy of the ring (oldest first)."""
        with self._lock:
            return tuple(self._events)

    def clear(self) -> None:
        with self._lock:
            self._events.clear()

    def __len__(self) -> int:
        return len(self._events)

    # -- export ----------------------------------------------------------
    def export_jsonl(self, path) -> int:
        spans = self.snapshot()
        with open(path, "w", encoding="utf-8") as f:
            for s in spans:
                f.write(json.dumps(s.to_json()) + "\n")
        return len(spans)

    def export_chrome(self, path, *, pid: int = 0) -> int:
        spans = self.snapshot()
        doc = {
            "displayTimeUnit": "ms",
            "traceEvents": [s.to_chrome(pid=pid) for s in spans],
        }
        with open(path, "w", encoding="utf-8") as f:
            json.dump(doc, f, indent=1)
        return len(spans)


def _span_from_json(d: dict) -> Span:
    return Span(name=d["name"], cat=d.get("cat", "solver"),
                t_start=d["t_start"], duration=d["duration"],
                level=d.get("level", "summary"),
                phase=d.get("ph", "span"), args=dict(d.get("args", ())))


def load_jsonl(path) -> list:
    with open(path, encoding="utf-8") as f:
        return [_span_from_json(json.loads(line))
                for line in f if line.strip()]


def load_chrome(path) -> list:
    """Read a Chrome-trace export back into :class:`Span` records (the
    inverse of :meth:`Tracer.export_chrome`, up to float round-trip)."""
    with open(path, encoding="utf-8") as f:
        doc = json.load(f)
    spans = []
    for ev in doc.get("traceEvents", ()):
        args = dict(ev.get("args", ()))
        level = args.pop("level", "summary")
        spans.append(Span(
            name=ev["name"], cat=ev.get("cat", "solver"),
            t_start=ev["ts"] / 1e6,
            duration=ev.get("dur", 0.0) / 1e6,
            level=level,
            phase="span" if ev.get("ph") == "X" else "instant",
            args=args))
    return spans


# ---------------------------------------------------------------------------
# process-global tracer (created lazily: obs="off" paths never touch it)
# ---------------------------------------------------------------------------

_TRACER: Tracer | None = None


def get_tracer() -> Tracer:
    global _TRACER
    if _TRACER is None:
        _TRACER = Tracer()
    return _TRACER
