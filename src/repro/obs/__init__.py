"""Runtime observability for the repro solver stack.

Three layers, all host-side (nothing here is ever traced into a jitted
program, so enabling observability cannot change compiled executables or
numerics):

  * :mod:`repro.obs.trace` — span/event tracer with an in-memory ring
    buffer and JSONL / Chrome-trace (Perfetto ``trace_event``) exporters.
  * :mod:`repro.obs.metrics` — counters, gauges and exponential-bucket
    latency histograms (p50/p95/p99) with Prometheus-text and JSON
    snapshot exporters, plus flop/byte accounting fed from
    :mod:`repro.core.costmodel`'s analytic formulas at observed shapes.
  * :mod:`repro.obs.commwatch` — static-vs-measured communication
    reconciliation: the collective schedule of a distributed solve is
    extracted from its jaxpr at dispatch time, expanded with the solve's
    own observed trip counts, and checked for EXACT per-(prim, axes)
    count and bytes-on-wire equality against the analytic
    ``core.costmodel.comm_volume`` predictions (the CA303 contract).

The estimator plumbs ``SolverConfig.obs = "off" | "summary" | "trace"``
through every backend; ``"off"`` (the default) never imports this
package at all.
"""
from __future__ import annotations

from .metrics import MetricsRegistry, get_registry
from .trace import Span, Tracer, get_tracer

__all__ = [
    "MetricsRegistry",
    "Span",
    "Tracer",
    "get_registry",
    "get_tracer",
]
