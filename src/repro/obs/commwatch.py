"""Static-vs-measured communication reconciliation (the CA303 closure).

The static analysis suite (``repro.analysis.commpass``) proves each ring
product's bytes-on-wire per *invocation*; what it cannot know statically
is how many times the solver's dynamic ``while_loop``s invoke each ring.
This module closes that loop:

**Measured side.**  A :class:`CommWatch` installed on
``core.distributed``'s dispatch hook sees every ``fit_cov``/``fit_obs``
jit dispatch.  It re-traces the exact shard_map closure being dispatched
with ``jax.make_jaxpr`` (tracing only — no compile, so zero extra
compiled programs) and walks the jaxpr into collective events carrying
their while-nesting depth and static scan multiplicity.  After the solve
returns, the solve's OWN observed trip counts (``iters``, ``ls_total``
— device-computed by the solver itself) expand each event into an exact
execution count:

    depth 0 (outside both loops)  x 1
    depth 1 (outer prox loop)     x iters
    depth 2 (line-search loop)    x (ls_total - iters)

(The first line-search trial of every outer iteration runs in the outer
body; the inner loop only runs the backtracking re-trials, hence the
``ls_total - iters`` residual.)  Bytes use the same
``core.costmodel.collective_wire_bytes`` conventions as CA303.

**Predicted side.**  An independent analytic table built from
``core.costmodel.comm_volume`` (paper Algorithm 4 ring/finish volumes)
plus the closed-form per-phase collective counts of the
``core.prox.prox_gradient`` control flow.

:func:`CommWatch.reconcile` demands EXACT equality (integer counts,
``Fraction`` bytes) per (primitive, axes) — a single extra collective or
one widened payload anywhere in the stack is a reportable finding.

Scope: the dense product path.  The block-sparse policy adds mask ring
traffic and density reductions whose analytic volume lives in
``comm.sparse1p5d``'s contracts; reconciling those is out of scope here
and :func:`predict_schedule` refuses rather than guessing.

The module also implements the ``comm/compat.py`` wrapper watcher: every
collective *posted through the compat layer* (trace-time) is counted
with its per-call payload bytes per (prim, axis) — see
:class:`CommWatch.posted`.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from fractions import Fraction

from ..analysis.commpass import EVENT_PRIMS, _payload
from ..analysis.jaxprpass import _axis_names_of, _sub_jaxprs
from ..core.costmodel import DTYPE_BYTES, collective_wire_bytes, comm_volume

#: vma-variant primitive names fold onto their canonical collective so
#: the measured and predicted tables key identically on every jax version
NORMALIZE_PRIM = {"psum_invariant": "psum", "all_gather_invariant": "all_gather"}


class ReconcileError(RuntimeError):
    """A schedule this reconciler cannot expand or predict exactly."""


@dataclass(frozen=True)
class WalkedEvent:
    """One collective eqn of a dispatched program, pre-expansion."""
    prim: str              # normalized primitive name
    axes: tuple            # mesh axes bound, in eqn order
    extent: int            # product of bound axis sizes
    payload_bytes: int
    moves: bool            # ppermute tables that are the identity ship 0
    depth: int             # while-loop nesting depth at the eqn
    static_times: int      # product of enclosing scan lengths
    in_cond: bool          # inside a while cond_jaxpr (not expandable)


def walk_collectives(jaxpr, axis_sizes: dict, *, _depth: int = 0,
                     _times: int = 1, _in_cond: bool = False,
                     _out: list | None = None) -> list:
    """Walk a (Closed)Jaxpr into :class:`WalkedEvent` records.

    Unlike ``analysis.commpass.extract_schedule`` (which poisons repeat
    counts at the first ``while``), this walker keeps the *static*
    multiplicity per while-depth so the runtime trip counts can expand it
    exactly."""
    out = _out if _out is not None else []
    inner = getattr(jaxpr, "jaxpr", jaxpr)
    for eqn in inner.eqns:
        name = eqn.primitive.name
        if name == "scan":
            length = eqn.params.get("length") or 0
            walk_collectives(eqn.params["jaxpr"], axis_sizes, _depth=_depth,
                             _times=_times * length, _in_cond=_in_cond,
                             _out=out)
        elif name == "while":
            if _times != 1:
                raise ReconcileError(
                    "while_loop nested inside a scan: the depth-based "
                    "expansion cannot attribute trip counts here")
            walk_collectives(eqn.params["cond_jaxpr"], axis_sizes,
                             _depth=_depth + 1, _times=_times,
                             _in_cond=True, _out=out)
            walk_collectives(eqn.params["body_jaxpr"], axis_sizes,
                             _depth=_depth + 1, _times=_times,
                             _in_cond=_in_cond, _out=out)
        elif name == "cond":
            # CA301 guarantees every branch posts the identical collective
            # sequence, so one representative branch is the schedule
            walk_collectives(eqn.params["branches"][0], axis_sizes,
                             _depth=_depth, _times=_times,
                             _in_cond=_in_cond, _out=out)
        elif name in EVENT_PRIMS:
            axes = tuple(_axis_names_of(eqn))
            extent = 1
            for a in axes:
                size = axis_sizes.get(a)
                if size is None:
                    raise ReconcileError(f"collective binds axis {a!r} with "
                                         f"unknown extent")
                extent *= size
            _, _, nbytes = _payload(eqn)
            perm = eqn.params.get("perm")
            out.append(WalkedEvent(
                prim=NORMALIZE_PRIM.get(name, name), axes=axes,
                extent=extent, payload_bytes=nbytes,
                moves=(perm is None or any(s != d for s, d in perm)),
                depth=_depth, static_times=_times, in_cond=_in_cond))
        else:
            for sub in _sub_jaxprs(eqn.params):
                walk_collectives(sub, axis_sizes, _depth=_depth,
                                 _times=_times, _in_cond=_in_cond, _out=out)
    return out


def expand_counts(events: list, iters: int, ls_total: int) -> dict:
    """Expand walked events with observed trip counts into the measured
    table ``{(prim, axes): {"count": int, "bytes": Fraction}}``."""
    mult = {0: 1, 1: iters, 2: ls_total - iters}
    table: dict = {}
    for e in events:
        if e.in_cond:
            raise ReconcileError(
                f"collective {e.prim} inside a while cond_jaxpr: cond "
                f"fires trips+1 times, which the result scalars do not "
                f"record")
        if e.depth not in mult:
            raise ReconcileError(
                f"collective {e.prim} at while depth {e.depth}: only the "
                f"prox outer/line-search nesting (depth <= 2) is "
                f"expandable")
        count = e.static_times * mult[e.depth]
        one = collective_wire_bytes(e.prim, e.payload_bytes, e.extent,
                                    moves=e.moves)
        row = table.setdefault((e.prim, e.axes),
                               {"count": 0, "bytes": Fraction(0)})
        row["count"] += count
        row["bytes"] += count * one
    return table


# ---------------------------------------------------------------------------
# analytic prediction (costmodel volumes x prox_gradient phase counts)
# ---------------------------------------------------------------------------

def predict_schedule(variant: str, *, p_pad: int, n: int | None, grid,
                     iters: int, ls_total: int,
                     dtype: str = "float64") -> dict:
    """The analytic twin of :func:`expand_counts` for one dense
    ``fit_cov``/``fit_obs`` solve: per-(prim, axes) execution counts and
    exact ``Fraction`` bytes-on-wire built from ``comm_volume`` (ring
    products) and the closed-form collective census of the
    ``prox_gradient`` phases:

      aux+objective runs ``1 + ls_total`` times (cold start + every
      line-search trial), the gradient runs ``iters`` times, each trial
      posts two global dots, and each outer iteration posts the two
      relative-change dots.
    """
    w = DTYPE_BYTES[dtype]
    P, cx, co = grid.n_devices, grid.c_x, grid.c_omega
    n_x, n_om, n_i = grid.n_x, grid.n_om, grid.n_i
    blk_x, blk_om = p_pad // n_x, p_pad // n_om
    aux_calls = 1 + ls_total
    table: dict = {}

    def add(prim, axes, count, nbytes):
        row = table.setdefault((prim, tuple(axes)),
                               {"count": 0, "bytes": Fraction(0)})
        row["count"] += count
        row["bytes"] += Fraction(nbytes)

    def wire(prim, payload_elems, extent):
        return collective_wire_bytes(prim, payload_elems * w, extent)

    ring_axes = ("i", "j", "k")
    if variant == "cov":
        # aux_of: W = Omega S, gather ring (Omega stored X-like)
        vol = comm_volume(p_pad, p_pad, P, cx, co, flavor="omega_s",
                          dtype=dtype, canonical="xlike")
        add("ppermute", ring_axes, aux_calls * (1 + vol.rounds),
            aux_calls * vol.ring_bytes)
        add("all_gather", ("k",), aux_calls, aux_calls * vol.finish_bytes)
        # grad_of: replication-aware transpose of W (Lemma 3.2)
        sub = blk_x // cx
        add("all_to_all", ("i", "j"), iters,
            iters * wire("all_to_all", n_x * sub * blk_x, n_x))
        add("all_gather", ("k",), iters,
            iters * wire("all_gather", p_pad * sub, cx))
        scalar_axes, scalar_extent = ("i", "j"), n_i * co
    elif variant == "obs":
        if n is None:
            raise ReconcileError("obs prediction needs the sample count n")
        # aux_of: Y = Omega X^T, reduce ring
        vol = comm_volume(p_pad, n, P, cx, co, flavor="omega_xt",
                          dtype=dtype)
        add("ppermute", ring_axes, aux_calls * (1 + vol.rounds),
            aux_calls * vol.ring_bytes)
        add("psum", ("j",), aux_calls, aux_calls * vol.finish_bytes)
        # grad_of: Z = Y X gather ring + transpose of Z
        voly = comm_volume(p_pad, n, P, cx, co, flavor="y_x", dtype=dtype)
        add("ppermute", ring_axes, iters * (1 + voly.rounds),
            iters * voly.ring_bytes)
        add("all_gather", ("j",), iters, iters * voly.finish_bytes)
        sub = blk_om // co
        add("all_to_all", ("i", "k"), iters,
            iters * wire("all_to_all", sub * n_om * blk_om, n_om))
        add("all_gather", ("j",), iters,
            iters * wire("all_gather", blk_om * n_om * sub, co))
        scalar_axes, scalar_extent = ("i", "k"), n_i * cx
    else:
        raise ReconcileError(f"unknown variant {variant!r}")

    # scalar collectives of the objective/line-search phases: 3 psums +
    # 1 pmin guard per objective, 2 dot-psums per trial, 2 per iteration
    n_psum = 3 * aux_calls + 2 * ls_total + 2 * iters
    add("psum", scalar_axes, n_psum, n_psum * wire("psum", 1, scalar_extent))
    add("pmin", scalar_axes, aux_calls,
        aux_calls * wire("pmin", 1, scalar_extent))
    return table


# ---------------------------------------------------------------------------
# reconciliation report
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ReconcileRow:
    prim: str
    axes: tuple
    measured_count: int
    predicted_count: int
    measured_bytes: Fraction
    predicted_bytes: Fraction

    @property
    def match(self) -> bool:
        return (self.measured_count == self.predicted_count
                and self.measured_bytes == self.predicted_bytes)

    def to_json(self) -> dict:
        return {"prim": self.prim, "axes": list(self.axes),
                "measured_count": self.measured_count,
                "predicted_count": self.predicted_count,
                "measured_bytes": str(self.measured_bytes),
                "predicted_bytes": str(self.predicted_bytes),
                "match": self.match}


@dataclass(frozen=True)
class ReconcileReport:
    variant: str
    p: int
    p_pad: int
    n: int | None
    n_devices: int
    c_x: int
    c_omega: int
    iters: int
    ls_total: int
    rows: tuple

    @property
    def ok(self) -> bool:
        return all(r.match for r in self.rows)

    @property
    def measured_total(self) -> Fraction:
        return sum((r.measured_bytes for r in self.rows), Fraction(0))

    @property
    def predicted_total(self) -> Fraction:
        return sum((r.predicted_bytes for r in self.rows), Fraction(0))

    def to_json(self) -> dict:
        return {"variant": self.variant, "p": self.p, "p_pad": self.p_pad,
                "n": self.n, "n_devices": self.n_devices, "c_x": self.c_x,
                "c_omega": self.c_omega, "iters": self.iters,
                "ls_total": self.ls_total, "ok": self.ok,
                "measured_bytes_total": str(self.measured_total),
                "predicted_bytes_total": str(self.predicted_total),
                "rows": [r.to_json() for r in self.rows]}

    def render(self) -> str:
        hdr = (f"{self.variant}: p={self.p} (pad {self.p_pad}) "
               f"P={self.n_devices} c_x={self.c_x} c_omega={self.c_omega} "
               f"iters={self.iters} ls_total={self.ls_total}")
        lines = [hdr, f"{'prim':<12} {'axes':<12} {'measured':>22} "
                      f"{'predicted':>22}  match"]
        for r in self.rows:
            m = f"{r.measured_count}x / {_fmt_bytes(r.measured_bytes)}"
            p_ = f"{r.predicted_count}x / {_fmt_bytes(r.predicted_bytes)}"
            lines.append(f"{r.prim:<12} {','.join(r.axes):<12} {m:>22} "
                         f"{p_:>22}  {'OK' if r.match else 'MISMATCH'}")
        lines.append(f"total measured {_fmt_bytes(self.measured_total)} vs "
                     f"predicted {_fmt_bytes(self.predicted_total)} -> "
                     f"{'EXACT MATCH' if self.ok else 'DIVERGENCE'}")
        return "\n".join(lines)


def _fmt_bytes(b: Fraction) -> str:
    f = float(b)
    return f"{f:.0f}B" if f == int(f) else f"{f:.1f}B"


def _table_to_rows(measured: dict, predicted: dict) -> tuple:
    rows = []
    for key in sorted(set(measured) | set(predicted)):
        m = measured.get(key, {"count": 0, "bytes": Fraction(0)})
        p = predicted.get(key, {"count": 0, "bytes": Fraction(0)})
        rows.append(ReconcileRow(
            prim=key[0], axes=key[1],
            measured_count=m["count"], predicted_count=p["count"],
            measured_bytes=m["bytes"], predicted_bytes=p["bytes"]))
    return tuple(rows)


# ---------------------------------------------------------------------------
# the dispatch observer
# ---------------------------------------------------------------------------

@dataclass
class DispatchRecord:
    """One observed driver dispatch, filled in across the hook protocol."""
    variant: str
    grid: object
    meta: dict
    events: list
    result: object = None


class CommWatch:
    """Observer over the distributed drivers and the compat wrappers.

    Usage::

        with CommWatch() as watch:
            res = dist.fit_cov(s, lam1, grid=grid)
        report = watch.reconcile()[0]
        assert report.ok

    ``install``/``uninstall`` (or the context manager) register this
    object on ``core.distributed.set_dispatch_observer`` and
    ``comm.compat.set_collective_watcher``.
    """

    def __init__(self):
        self.records: list = []
        #: collectives posted through comm/compat.py wrappers:
        #: {(prim, axis): {"calls": int, "bytes": int}}
        self.posted: dict = {}
        self._prev_dispatch = None
        self._prev_wrapper = None
        self._installed = False

    # -- lifecycle -------------------------------------------------------
    def install(self) -> "CommWatch":
        from ..comm import compat
        from ..core import distributed
        if self._installed:
            return self
        self._prev_dispatch = distributed.set_dispatch_observer(self)
        self._prev_wrapper = compat.set_collective_watcher(self)
        self._installed = True
        return self

    def uninstall(self) -> None:
        from ..comm import compat
        from ..core import distributed
        if not self._installed:
            return
        distributed.set_dispatch_observer(self._prev_dispatch)
        compat.set_collective_watcher(self._prev_wrapper)
        self._installed = False

    def __enter__(self) -> "CommWatch":
        return self.install()

    def __exit__(self, *exc):
        self.uninstall()
        return False

    # -- core.distributed dispatch-observer protocol ---------------------
    def on_dispatch(self, variant: str, fn, args, grid, meta: dict):
        """Called inside ``use_mesh`` right before the driver's jit call.
        ``make_jaxpr`` only traces — no compile, no numeric effect."""
        import jax

        axis_sizes = {"i": grid.n_i, "j": grid.c_omega, "k": grid.c_x}
        jaxpr = jax.make_jaxpr(fn)(*args)
        rec = DispatchRecord(variant=variant, grid=grid, meta=dict(meta),
                             events=walk_collectives(jaxpr, axis_sizes))
        self.records.append(rec)
        return rec

    def on_result(self, token: DispatchRecord, result) -> None:
        token.result = result

    # -- comm.compat wrapper-watcher protocol ----------------------------
    def on_collective(self, prim: str, axis_name, operand) -> None:
        """Count a collective posted through a compat wrapper (trace-time
        semantics: a cached program re-executes without re-posting)."""
        axes = tuple(axis_name) if isinstance(axis_name, (tuple, list)) \
            else (axis_name,)
        shape = getattr(operand, "shape", ())
        dtype = getattr(operand, "dtype", None)
        nbytes = math.prod(shape) * getattr(dtype, "itemsize", 8)
        row = self.posted.setdefault((prim, axes), {"calls": 0, "bytes": 0})
        row["calls"] += 1
        row["bytes"] += nbytes

    # -- reconciliation --------------------------------------------------
    def reconcile(self) -> list:
        """One :class:`ReconcileReport` per observed dispatch.  Pulls the
        solve's observed ``iters``/``ls_total`` (the only device sync this
        subsystem ever does, after the solve is already finished)."""
        reports = []
        for rec in self.records:
            if rec.result is None:
                raise ReconcileError(
                    f"{rec.variant} dispatch was observed but its result "
                    f"never arrived (solve still running or crashed)")
            if rec.meta.get("sparse"):
                raise ReconcileError(
                    "block-sparse solves add mask ring traffic the dense "
                    "predictor does not model; reconcile dense solves")
            iters = int(rec.result.iters)
            ls_total = int(rec.result.ls_total)
            measured = expand_counts(rec.events, iters, ls_total)
            predicted = predict_schedule(
                rec.variant, p_pad=rec.meta["p_pad"], n=rec.meta.get("n"),
                grid=rec.grid, iters=iters, ls_total=ls_total,
                dtype=rec.meta.get("dtype", "float64"))
            reports.append(ReconcileReport(
                variant=rec.variant, p=rec.meta.get("p", rec.meta["p_pad"]),
                p_pad=rec.meta["p_pad"], n=rec.meta.get("n"),
                n_devices=rec.grid.n_devices, c_x=rec.grid.c_x,
                c_omega=rec.grid.c_omega, iters=iters, ls_total=ls_total,
                rows=_table_to_rows(measured, predicted)))
        return reports

    def clear(self) -> None:
        self.records.clear()
        self.posted.clear()


# ---------------------------------------------------------------------------
# analysis manifest (repro.analysis.jaxprpass — CA202 reuse recipe)
# ---------------------------------------------------------------------------

def _analysis_obs_build():
    """Trace the reference solve step with the span tracer armed at
    ``trace`` (via ctx): instrumentation is host-side only, so the traced
    program — and with it the CA201/CA203 contracts — must be exactly the
    one core.prox exports untraced."""
    from functools import partial

    import jax.numpy as jnp

    from ..core.prox import PenaltySpec, _solve_reference
    from .trace import get_tracer

    p = 8
    s = jnp.eye(p, dtype=jnp.float64) + 0.05 * jnp.ones((p, p), jnp.float64)
    spec = PenaltySpec("l1", jnp.asarray(0.1, jnp.float64),
                       jnp.asarray(0.0, jnp.float64))
    fn = partial(_solve_reference, variant="cov", tol=1e-4, max_iters=8,
                 max_ls=8, warm_start_tau=False, sparse_matmul=None,
                 use_pallas=False)
    return {"fn": fn, "args": (s, spec, None),
            "ctx": lambda: get_tracer().scoped("trace")}


def _analysis_obs_reuse():
    """CA202: solving at ``obs="trace"`` must add ZERO compiled programs —
    the tracer wraps dispatch at host boundaries and the comm watcher only
    re-traces (``make_jaxpr``), so the reference engine's compiled cache
    must hold across traced path points exactly as it does untraced."""
    import numpy as np

    from ..core.prox import _solve_reference
    from ..estimator import ConcordEstimator, SolverConfig

    rng = np.random.default_rng(0)
    x = rng.standard_normal((20, 8))
    config = SolverConfig(backend="reference", variant="cov", tol=1e-3,
                          max_iters=5, max_ls=5, obs="trace")

    def run(lam1):
        ConcordEstimator(lam1=lam1, config=config).fit(x)

    from functools import partial
    return {"watched": {"core.prox._solve_reference": _solve_reference},
            "calls": [partial(run, 0.20), partial(run, 0.26),
                      partial(run, 0.32)]}


#: the comm engine (CA3xx) skips — this host-side module declares no
#: COMM_CONTRACT of its own; the CA202 recipe and the armed-tracer trace
#: (identical program to core.prox's) are the contracts here
ANALYSIS_ENTRIES = [
    {"name": "obs.commwatch.traced_solve_reuse",
     "path": "src/repro/obs/commwatch.py",
     "axis_names": (),
     "build": _analysis_obs_build,
     "reuse": _analysis_obs_reuse,
     "skip": ("CA300", "CA301", "CA302", "CA303",
              "CA304", "CA305", "CA306")},
]
