"""``repro-obs`` — inspect, diff, export and gate observability artifacts.

Subcommands:

  * ``print <trace>``       pretty-print a JSONL or Chrome-trace export
  * ``diff <a> <b>``        per-span-name count/duration deltas between
                            two trace exports (regression triage)
  * ``export <in> <out>``   convert between the JSONL and Chrome-trace
                            formats (by file extension: ``.jsonl`` vs
                            ``.json``)
  * ``reconcile``           run a distributed solve on N forced host
                            devices with the comm watcher armed and
                            check measured == static comm bytes
                            per (prim, axes); exit 1 on ANY divergence
                            (the CI gate), optionally exporting the
                            Perfetto trace and the reconciliation JSON
                            as artifacts.

``reconcile`` must own the process: it sets
``XLA_FLAGS=--xla_force_host_platform_device_count`` before jax loads,
so run it as its own invocation (as CI does), not after something else
imported jax.
"""
from __future__ import annotations

import argparse
import json
import sys
from collections import defaultdict

from .trace import load_chrome, load_jsonl


def _load_any(path: str):
    """A trace export, whichever format: Chrome-trace JSON documents are
    objects with a ``traceEvents`` key, JSONL files are one span/line."""
    with open(path, encoding="utf-8") as f:
        head = f.read(64).lstrip()
    if head.startswith("{") and '"traceEvents"' in open(
            path, encoding="utf-8").read(4096):
        return load_chrome(path)
    return load_jsonl(path)


def _by_name(spans) -> dict:
    agg: dict = defaultdict(lambda: {"count": 0, "duration": 0.0})
    for s in spans:
        agg[s.name]["count"] += 1
        agg[s.name]["duration"] += s.duration
    return dict(agg)


def cmd_print(args) -> int:
    spans = _load_any(args.trace)
    print(f"{args.trace}: {len(spans)} events")
    for s in sorted(spans, key=lambda s: s.t_start):
        extras = " ".join(f"{k}={v}" for k, v in sorted(s.args.items()))
        kind = "span " if s.phase == "span" else "event"
        print(f"  {s.t_start:12.6f}s {kind} {s.cat}/{s.name:<24} "
              f"{s.duration * 1e3:9.3f}ms  {extras}")
    agg = _by_name(spans)
    print("by name:")
    for name, row in sorted(agg.items(),
                            key=lambda kv: -kv[1]["duration"]):
        print(f"  {name:<28} x{row['count']:<5} "
              f"{row['duration'] * 1e3:10.3f}ms total")
    return 0


def cmd_diff(args) -> int:
    a, b = _by_name(_load_any(args.a)), _by_name(_load_any(args.b))
    print(f"{'span':<28} {'count A->B':>14} {'duration A->B (ms)':>26}")
    for name in sorted(set(a) | set(b)):
        ra = a.get(name, {"count": 0, "duration": 0.0})
        rb = b.get(name, {"count": 0, "duration": 0.0})
        print(f"{name:<28} {ra['count']:>6} -> {rb['count']:<5} "
              f"{ra['duration'] * 1e3:>11.3f} -> {rb['duration'] * 1e3:.3f}")
    return 0


def cmd_export(args) -> int:
    from .trace import Tracer
    spans = _load_any(args.src)
    t = Tracer(mode="trace", capacity=max(len(spans), 1))
    for s in spans:
        t._record(s)
    if args.dst.endswith(".jsonl"):
        n = t.export_jsonl(args.dst)
    else:
        n = t.export_chrome(args.dst)
    print(f"wrote {n} events to {args.dst}")
    return 0


def cmd_reconcile(args) -> int:
    import os
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={args.devices} "
        + os.environ.get("XLA_FLAGS", ""))
    import numpy as np

    import jax
    jax.config.update("jax_enable_x64", True)

    from ..comm.grid import Grid1p5D
    from ..core import distributed as dist
    from .commwatch import CommWatch
    from .trace import get_tracer

    rng = np.random.default_rng(args.seed)
    x = rng.standard_normal((args.n, args.p))
    s = (x.T @ x) / args.n
    grid = Grid1p5D(args.devices, args.c_x, args.c_omega)
    tracer = get_tracer()
    tracer.set_mode("trace")
    reports = []
    for variant in args.variants.split(","):
        with CommWatch() as watch:
            with tracer.span(f"reconcile.{variant}", p=args.p,
                             n_devices=args.devices):
                if variant == "cov":
                    res = dist.fit_cov(s, args.lam1, grid=grid,
                                       max_iters=args.max_iters)
                else:
                    res = dist.fit_obs(x, args.lam1, grid=grid,
                                       max_iters=args.max_iters)
                jax.block_until_ready(res.omega)
        reports.extend(watch.reconcile())
    for rep in reports:
        print(rep.render())
        print()
    if args.trace_out:
        tracer.export_chrome(args.trace_out)
        print(f"trace -> {args.trace_out}")
    if args.json_out:
        with open(args.json_out, "w", encoding="utf-8") as f:
            json.dump([r.to_json() for r in reports], f, indent=2)
        print(f"reconciliation -> {args.json_out}")
    if not all(r.ok for r in reports):
        print("FAIL: measured collective schedule diverges from the "
              "static comm_volume prediction", file=sys.stderr)
        return 1
    print("OK: measured == static for every (prim, axes)")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="repro-obs", description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("print", help="pretty-print a trace export")
    p.add_argument("trace")
    p.set_defaults(fn=cmd_print)

    p = sub.add_parser("diff", help="diff two trace exports by span name")
    p.add_argument("a")
    p.add_argument("b")
    p.set_defaults(fn=cmd_diff)

    p = sub.add_parser("export", help="convert jsonl <-> chrome trace")
    p.add_argument("src")
    p.add_argument("dst")
    p.set_defaults(fn=cmd_export)

    p = sub.add_parser(
        "reconcile",
        help="distributed solve with the comm watcher armed; exit 1 on "
             "measured != static bytes (sets XLA_FLAGS, run standalone)")
    p.add_argument("--devices", type=int, default=4)
    p.add_argument("--c-x", type=int, default=1)
    p.add_argument("--c-omega", type=int, default=1)
    p.add_argument("--p", type=int, default=32)
    p.add_argument("--n", type=int, default=48)
    p.add_argument("--lam1", type=float, default=0.3)
    p.add_argument("--max-iters", type=int, default=6)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--variants", default="cov,obs",
                   help="comma list of cov/obs")
    p.add_argument("--trace-out", default=None,
                   help="write the Perfetto trace here")
    p.add_argument("--json-out", default=None,
                   help="write the reconciliation rows here")
    p.set_defaults(fn=cmd_reconcile)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
