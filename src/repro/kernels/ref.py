"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic ground truth the kernels are sweep-tested
against (tests/test_kernels.py, interpret=True on CPU).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .softthresh import STATS_MIN_DTYPE

#: softmax accumulation floor of the attention oracle (matches the Pallas
#: flash kernel's f32 accumulator; the output is cast back to q.dtype)
ATTN_ACCUM_DTYPE = jnp.float32


# ---------------------------------------------------------------------------
# fused prox (softthresh.py)
# ---------------------------------------------------------------------------

def fused_prox(z: jax.Array, diag_mask: jax.Array, alpha,
               *, weights=None) -> jax.Array:
    """Soft-threshold off-diagonal entries, pass the diagonal through.

    ``weights`` (optional, same shape as ``z``) switches to the weighted
    threshold ``alpha * w`` with ``w = inf`` forcing exact zeros."""
    if weights is None:
        thr = alpha
    else:
        w = jnp.asarray(weights, z.dtype)
        thr = jnp.where(jnp.isinf(w), jnp.inf, alpha * w)
    st = jnp.sign(z) * jnp.maximum(jnp.abs(z) - thr, 0.0)
    return st * (1.0 - diag_mask) + z * diag_mask


def block_nnz(a: jax.Array, block) -> jax.Array:
    """Per-tile nonzero count on the fused-prox stats grid: tile (i, j) of
    size block counts nonzeros of a[i*bm:(i+1)*bm, j*bn:(j+1)*bn] (edge
    tiles zero-padded)."""
    m, n = a.shape
    bm = min(block[0], m)
    bn = min(block[1], n)
    gm, gn = -(-m // bm), -(-n // bn)
    ap = jnp.pad(a, ((0, gm * bm - m), (0, gn * bn - n)))
    tiles = ap.reshape(gm, bm, gn, bn)
    nnz_dtype = jnp.promote_types(a.dtype, STATS_MIN_DTYPE)
    return jnp.sum((tiles != 0).astype(nnz_dtype), axis=(1, 3))


def fused_prox_stats(z: jax.Array, diag_mask: jax.Array, alpha,
                     *, weights=None, block=(256, 256)):
    """Prox + the objective reduction pieces in one logical pass.

    Returns (out, logdet, l1_offdiag, sumsq, min_diag, block_nnz) where
      logdet     = sum over diag of log(out)
      l1_offdiag = sum over off-diag of |out|  (unweighted, both lanes)
      sumsq      = ||out||_F^2
      min_diag   = min over diag of out  (positivity guard)
      block_nnz  = per-block-tile nonzero counts (the occupancy harvest
                   the block-sparse matmul dispatch consumes)
    """
    out = fused_prox(z, diag_mask, alpha, weights=weights)
    d = diag_mask > 0
    logdet = jnp.sum(jnp.where(d, jnp.log(jnp.maximum(out, 1e-30)), 0.0))
    l1 = jnp.sum(jnp.where(d, 0.0, jnp.abs(out)))
    sumsq = jnp.sum(out * out)
    min_diag = jnp.min(jnp.where(d, out, jnp.inf))
    return out, logdet, l1, sumsq, min_diag, block_nnz(out, block)


# ---------------------------------------------------------------------------
# fused path step (pathstep.py)
# ---------------------------------------------------------------------------

def fused_path_step(omega: jax.Array, w: jax.Array, tau, lam1, lam2,
                    *, weights=None):
    """One fused flat step of the batched path engine, pure jnp.

    omega/w: (C, p, p) lane iterates and cached aux products W = Omega S;
    tau/lam1/lam2: (C,) per-lane scalars.  The op order mirrors the tile
    kernel exactly (grad assembled as 0.5*(W + W^T) + lam2*Omega with the
    -1/diag correction folded in as one add) so under jit the elementwise
    candidate is bit-identical (eager dispatch fuses multiply-adds
    differently — up to one ulp); the (C, 5) stats reductions differ only
    by tile summation order.
    """
    c_lanes, p, _ = omega.shape
    dtype = omega.dtype
    diag = jnp.eye(p, dtype=bool)[None]
    tau = jnp.broadcast_to(jnp.asarray(tau, dtype), (c_lanes,))[:, None, None]
    alpha = tau * jnp.broadcast_to(
        jnp.asarray(lam1, dtype), (c_lanes,))[:, None, None]
    lam2 = jnp.broadcast_to(
        jnp.asarray(lam2, dtype), (c_lanes,))[:, None, None]
    grad = 0.5 * (w + jnp.swapaxes(w, -1, -2)) + lam2 * omega
    grad = jnp.where(diag, grad - 1.0 / omega, grad)
    z = omega - tau * grad
    if weights is None:
        thr = alpha
    else:
        wt = jnp.asarray(weights, dtype)
        thr = jnp.where(jnp.isinf(wt), jnp.inf, alpha * wt)
    soft = jnp.sign(z) * jnp.maximum(jnp.abs(z) - thr, 0.0)
    cand = jnp.where(diag, z, soft)
    diff = cand - omega
    stats_dtype = jnp.promote_types(dtype, STATS_MIN_DTYPE)
    red = lambda x: jnp.sum(x, axis=(-2, -1)).astype(stats_dtype)
    stats = jnp.stack([
        red(diff * grad),
        red(diff * diff),
        red(cand * cand),
        red(jnp.where(diag, 0.0, jnp.abs(cand))),
        red((cand != 0.0).astype(dtype)),
    ], axis=-1)
    return cand, stats


# ---------------------------------------------------------------------------
# block-sparse x dense matmul (blocksparse_matmul.py)
# ---------------------------------------------------------------------------

def block_csr_to_dense(values: jax.Array, row_idx: jax.Array,
                       col_idx: jax.Array, p: int) -> jax.Array:
    """Materialize a block-CSR matrix (nb, bs, bs) into dense (p, p)."""
    bs = values.shape[1]
    dense = jnp.zeros((p, p), values.dtype)

    def body(i, d):
        r, c = row_idx[i], col_idx[i]
        return jax.lax.dynamic_update_slice(d, values[i], (r * bs, c * bs))

    return jax.lax.fori_loop(0, values.shape[0], body, dense)


def blocksparse_matmul(values, row_idx, col_idx, b, p: int):
    """A @ B with A given in block-CSR coordinates."""
    return block_csr_to_dense(values, row_idx, col_idx, p) @ b


def dense_to_block_csr(a: np.ndarray, bs: int, *, tol: float = 0.0):
    """Host-side: dense (p, p) -> (values, row_idx, col_idx) keeping only
    nonzero bs x bs tiles. Every block-row gets at least one (zero) block so
    the kernel's accumulation-initialization logic always fires."""
    a = np.asarray(a)
    p = a.shape[0]
    nbr = p // bs
    vals, rows, cols = [], [], []
    for r in range(nbr):
        found = False
        for c in range(nbr):
            blk = a[r * bs:(r + 1) * bs, c * bs:(c + 1) * bs]
            if np.abs(blk).max() > tol:
                vals.append(blk)
                rows.append(r)
                cols.append(c)
                found = True
        if not found:
            vals.append(np.zeros((bs, bs), a.dtype))
            rows.append(r)
            cols.append(r)
    return (np.stack(vals), np.asarray(rows, np.int32),
            np.asarray(cols, np.int32))


# ---------------------------------------------------------------------------
# flash attention (flash_attention.py)
# ---------------------------------------------------------------------------

def attention(q, k, v, *, causal=True, window=None, softcap=None,
              scale=None):
    """Reference multi-head attention with GQA, causal/sliding-window masks
    and logit soft-capping.

    q: (B, Hq, Lq, D); k, v: (B, Hkv, Lkv, D) with Hkv | Hq.
    window: sliding-window size (attend to keys in (qpos-window, qpos]).
    softcap: gemma2-style cap*tanh(logits/cap).
    """
    B, Hq, Lq, D = q.shape
    Hkv, Lkv = k.shape[1], k.shape[2]
    group = Hq // Hkv
    kq = jnp.repeat(k, group, axis=1)
    vq = jnp.repeat(v, group, axis=1)
    scale = scale if scale is not None else 1.0 / jnp.sqrt(D).astype(q.dtype)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, kq) * scale
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    qpos = jnp.arange(Lq)[:, None] + (Lkv - Lq)   # align ends (decode-friendly)
    kpos = jnp.arange(Lkv)[None, :]
    mask = jnp.ones((Lq, Lkv), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None], logits, -1e30)
    probs = jax.nn.softmax(
        logits.astype(ATTN_ACCUM_DTYPE), axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, vq)
