"""Flash-attention Pallas TPU kernel (online-softmax, VMEM-tiled).

The LM-zoo hot-spot.  Supports every attention flavor the assigned
architectures need in one kernel:

  * GQA              — kv-head picked by q-head // group in the index map,
                       so no repeat/materialization of K/V.
  * causal masking   — kv tiles entirely in the future are skipped
                       (@pl.when on the tile, not just masked).
  * sliding window   — danube / mixtral / gemma2-local; tiles entirely
                       OUTSIDE the window are skipped, giving the
                       O(L * window) flop count instead of O(L^2).
  * logit softcap    — gemma2's cap * tanh(logits / cap).

Online softmax state (running max m, denominator l, accumulator acc) lives
in VMEM scratch across the kv-tile grid dimension (the innermost one), as
in the canonical TPU flash attention.  Block sizes are MXU/lane aligned
(q, kv tiles multiples of 128 when the problem allows).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
            *, scale, causal, window, softcap, block_q, block_k,
            q_offset, kv_len):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # tile-level skip: lowest q position this tile can see / highest needed
    q_lo = iq * block_q + q_offset          # global position of first query
    k_lo = ik * block_k
    run = jnp.asarray(True)
    if causal:
        run &= k_lo <= q_lo + block_q - 1   # some key not in the future
    if window is not None:
        run &= k_lo + block_k - 1 > q_lo - window  # some key inside window

    @pl.when(run)
    def _body():
        # zero edge-tile padding (interpret mode pads with NaN; 0 * NaN = NaN
        # would otherwise leak through p @ v)
        kvalid = (k_lo + jax.lax.broadcasted_iota(
            jnp.int32, (block_k, 1), 0)) < kv_len
        q = q_ref[0, 0].astype(jnp.float32)  # (bq, d)
        k = jnp.where(kvalid, k_ref[0, 0], 0.0).astype(jnp.float32)
        v = jnp.where(kvalid, v_ref[0, 0], 0.0).astype(jnp.float32)
        q = jnp.where(jnp.isnan(q), 0.0, q)  # padded query rows (discarded)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = kpos < kv_len                 # kv padding
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                  # (bq, 128) broadcast lanes
        m_cur = jnp.max(s, axis=1)[:, None]
        m_new = jnp.maximum(m_prev, jnp.broadcast_to(m_cur, m_prev.shape))
        p = jnp.exp(s - m_new[:, :1])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.broadcast_to(
            jnp.sum(p, axis=1)[:, None], m_prev.shape)
        acc_ref[...] = acc_ref[...] * alpha[:, :1] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finish():
        l = l_ref[..., :1]
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


def kernel_layout(B: int, Hq: int, Hkv: int, Lq: int, Lkv: int, D: int,
                  *, block_q: int = DEFAULT_BLOCK_Q,
                  block_k: int = DEFAULT_BLOCK_K) -> dict:
    """Grid + BlockSpec geometry of the flash-attention ``pallas_call``.

    Shared by the wrapper below and the CA4xx kernel verifier (via
    ``kernels.manifest``).  The out spec ignores the kv grid dim (dim 3,
    the innermost one): the kernel revisits its output block across kv
    tiles with VMEM scratch accumulators, declared to the verifier as a
    sequential-accumulation dim.  The kv index maps carry ``group`` as a
    bound default arg, so their non-default arity stays the grid rank.
    """
    group = Hq // Hkv
    bq = min(block_q, Lq)
    bk = min(block_k, Lkv)
    gq, gk = pl.cdiv(Lq, bq), pl.cdiv(Lkv, bk)
    return {
        "grid": (B, Hq, gq, gk),
        "in_specs": [
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, i, j, g=group: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, i, j, g=group: (b, h // g, j, 0)),
        ],
        "out_specs": pl.BlockSpec(
            (1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
        "out_shapes": ((B, Hq, Lq, D),),
        "bq": bq,
        "bk": bk,
    }


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "scale",
                     "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int | None = None,
                    softcap: float | None = None, scale: float | None = None,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K,
                    interpret: bool = True):
    """q: (B, Hq, Lq, D); k, v: (B, Hkv, Lkv, D). Returns (B, Hq, Lq, D).

    Lq may be shorter than Lkv (chunked prefill / decode): query position i
    is aligned so the LAST query attends to the LAST key.
    """
    B, Hq, Lq, D = q.shape
    Hkv, Lkv = k.shape[1], k.shape[2]
    lay = kernel_layout(B, Hq, Hkv, Lq, Lkv, D,
                        block_q=block_q, block_k=block_k)
    bq, bk = lay["bq"], lay["bk"]
    scale = scale if scale is not None else float(D) ** -0.5
    q_offset = Lkv - Lq

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window, softcap=softcap,
        block_q=bq, block_k=bk, q_offset=q_offset, kv_len=Lkv)

    out = pl.pallas_call(
        kernel,
        grid=lay["grid"],
        in_specs=lay["in_specs"],
        out_specs=lay["out_specs"],
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out
