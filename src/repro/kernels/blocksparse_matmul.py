"""Block-sparse x dense Pallas TPU matmul — the TPU adaptation of the
paper's sparse-dense multiply (W = Omega S, Y = Omega X^T).

The CPU code calls MKL CSR x dense; TPU has no scalar-gather sparse units,
so sparsity is expressed at MXU granularity: Omega is stored as block-CSR
with 128-aligned tiles and the kernel simply SKIPS absent tiles.  The cost
model's d (nnz per row) becomes block density, and the flop saving is
(1 - block_density) of the dense product, realized on the systolic array
with zero gather overhead.

Layout: values (nb, bs, bs) with COO-expanded, row-major-sorted
(row_idx, col_idx) int32 vectors (every block-row holds >= 1 entry — the
builder inserts a zero block for empty rows so output initialization
always fires).  Grid is (col_tiles, nb): for a fixed output column tile we
sweep the nonzero blocks in CSR order, so all contributions to one output
tile are consecutive grid steps and accumulate in VMEM; the output block
switches exactly when row_idx changes.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(row_ref, col_ref, v_ref, b_ref, o_ref):
    i = pl.program_id(1)                      # nnz-block index (fast dim)

    @pl.when((i == 0) | (row_ref[i] != row_ref[jnp.maximum(i - 1, 0)]))
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    v = v_ref[0]                              # (bs, bs)
    b = b_ref[...]                            # (bs, bn)
    o_ref[...] += jnp.dot(v, b, preferred_element_type=o_ref.dtype)


def kernel_layout(nb: int, bs: int, p: int, m: int,
                  *, block_n: int = 256) -> dict:
    """Grid + BlockSpec geometry of the block-sparse ``pallas_call``.

    Shared by the wrapper below and the CA4xx kernel verifier (via
    ``kernels.manifest``).  ``in_specs`` covers the two non-prefetch
    operands (values, b); the ``row``/``col`` scalar-prefetch vectors are
    appended to every index-map call, which is how the out-spec scatters
    on ``row[i]`` — the aliasing hazard CA401 enumerates concretely.
    """
    bn = min(block_n, m)
    nt = pl.cdiv(m, bn)
    return {
        "grid": (nt, nb),
        "num_scalar_prefetch": 2,
        "in_specs": [
            pl.BlockSpec((1, bs, bs), lambda j, i, row, col: (i, 0, 0)),
            pl.BlockSpec((bs, bn), lambda j, i, row, col: (col[i], j)),
        ],
        "out_specs": pl.BlockSpec(
            (bs, bn), lambda j, i, row, col: (row[i], j)),
        "out_shapes": ((p, m),),
    }


def _validate_row_runs(row_idx) -> None:
    """The CA401 aliasing contract, enforced at trace time on concrete
    ids: each block-row id must appear as ONE contiguous run.  The kernel
    re-zeroes its output tile whenever ``row_idx`` changes, so a row id
    that returns after an interruption would silently clobber the partial
    sums already flushed for that row.  Abstract ids (inside an outer
    jit/vmap) skip the check — the static verifier covers the manifest
    configs there."""
    if isinstance(row_idx, jax.core.Tracer):
        return
    rows = np.asarray(row_idx)
    if rows.size <= 1:
        return
    change = np.flatnonzero(np.diff(rows) != 0)
    run_starts = rows[np.concatenate(([0], change + 1))]
    uniq, counts = np.unique(run_starts, return_counts=True)
    dupes = uniq[counts > 1]
    if dupes.size:
        raise ValueError(
            f"blocksparse_matmul row_idx revisits block-row(s) "
            f"{dupes.tolist()} non-contiguously: all entries of a "
            f"block-row must form one contiguous run (CSR row-major "
            f"order, see dense_to_block_csr), otherwise the kernel's "
            f"output tile for that row is re-zeroed on the second visit "
            f"and the first visit's accumulation is silently lost")


@partial(jax.jit, static_argnames=("block_n", "interpret"))
def _blocksparse_matmul(values: jax.Array, row_idx: jax.Array,
                        col_idx: jax.Array, b: jax.Array,
                        *, block_n: int = 256, interpret: bool = True):
    nb, bs, _ = values.shape
    p, m = b.shape
    lay = kernel_layout(nb, bs, p, m, block_n=block_n)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=lay["num_scalar_prefetch"],
        grid=lay["grid"],
        in_specs=lay["in_specs"],
        out_specs=lay["out_specs"],
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(lay["out_shapes"][0], b.dtype),
        interpret=interpret,
    )(row_idx, col_idx, values, b)


def blocksparse_matmul(values: jax.Array, row_idx: jax.Array,
                       col_idx: jax.Array, b: jax.Array,
                       *, block_n: int = 256, interpret: bool = True):
    """C = A @ B with A in block-CSR ((nb, bs, bs) + sorted row/col ids).

    b: (p, m). Returns (p, m). Requires every block-row represented at
    least once AND each row id's entries contiguous (CSR row-major order;
    see dense_to_block_csr in ref.py) — concrete ``row_idx`` violating
    the contiguity contract raises ValueError at trace time.
    """
    _validate_row_runs(row_idx)
    return _blocksparse_matmul(values, row_idx, col_idx, b,
                               block_n=block_n, interpret=interpret)
