"""Block-sparse x dense Pallas TPU matmul — the TPU adaptation of the
paper's sparse-dense multiply (W = Omega S, Y = Omega X^T).

The CPU code calls MKL CSR x dense; TPU has no scalar-gather sparse units,
so sparsity is expressed at MXU granularity: Omega is stored as block-CSR
with 128-aligned tiles and the kernel simply SKIPS absent tiles.  The cost
model's d (nnz per row) becomes block density, and the flop saving is
(1 - block_density) of the dense product, realized on the systolic array
with zero gather overhead.

Layout: values (nb, bs, bs) with COO-expanded, row-major-sorted
(row_idx, col_idx) int32 vectors (every block-row holds >= 1 entry — the
builder inserts a zero block for empty rows so output initialization
always fires).  Grid is (col_tiles, nb): for a fixed output column tile we
sweep the nonzero blocks in CSR order, so all contributions to one output
tile are consecutive grid steps and accumulate in VMEM; the output block
switches exactly when row_idx changes.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(row_ref, col_ref, v_ref, b_ref, o_ref):
    i = pl.program_id(1)                      # nnz-block index (fast dim)

    @pl.when((i == 0) | (row_ref[i] != row_ref[jnp.maximum(i - 1, 0)]))
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    v = v_ref[0]                              # (bs, bs)
    b = b_ref[...]                            # (bs, bn)
    o_ref[...] += jnp.dot(v, b, preferred_element_type=o_ref.dtype)


@partial(jax.jit, static_argnames=("block_n", "interpret"))
def blocksparse_matmul(values: jax.Array, row_idx: jax.Array,
                       col_idx: jax.Array, b: jax.Array,
                       *, block_n: int = 256, interpret: bool = True):
    """C = A @ B with A in block-CSR ((nb, bs, bs) + sorted row/col ids).

    b: (p, m). Returns (p, m). Requires every block-row represented at
    least once (see dense_to_block_csr in ref.py).
    """
    nb, bs, _ = values.shape
    p, m = b.shape
    bn = min(block_n, m)
    nt = pl.cdiv(m, bn)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(nt, nb),
        in_specs=[
            pl.BlockSpec((1, bs, bs), lambda j, i, row, col: (i, 0, 0)),
            pl.BlockSpec((bs, bn), lambda j, i, row, col: (col[i], j)),
        ],
        out_specs=pl.BlockSpec((bs, bn), lambda j, i, row, col: (row[i], j)),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((p, m), b.dtype),
        interpret=interpret,
    )(row_idx, col_idx, values, b)
